"""Single-dispatch fused executor: call counting, host parity, bucketed batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.executor import BiathlonConfig, run_exact
from repro.core.executor_fused import build_fused_executor, fused_rows_per_iteration
from repro.core.pipeline import AggFeature, Pipeline
from repro.data.store import ColumnStore, build_table
from repro.data.synthetic import PipelineBundle, make_pipeline
from repro.models.tabular import LinearRegression
from repro.serving import BatchedFusedServer, BiathlonServer
from repro.serving.batched import straggler_report

SMALL = dict(rows_per_group=1200, n_train_groups=100, n_serve_groups=5, n_requests=4)


# ------------------------------------------------------- one dispatch per iter
def test_exactly_one_model_call_per_iteration():
    """The while-loop body must contain a single model_fn dispatch.

    Trace-time counting: jitting the executor traces model_fn exactly three
    times — the AMI-only init (m+1 rows), the lax.cond-guarded init Sobol
    block ((k+2)*m_sobol rows), and ONE megabatch inside the loop body
    (m + 1 + (k+2)*m_sobol rows).  A duplicate pre-step evaluation or a
    separate per-iteration Sobol batch would show up as extra traced calls
    or wrong row counts.
    """
    m, m_sobol, k = 64, 16, 2
    calls: list[int] = []
    w = jnp.asarray([3.0, 1.0])

    def model_fn(rows, exact):
        calls.append(int(rows.shape[0]))
        return rows @ w

    fused = build_fused_executor(
        model_fn, k=k, task="regression", m=m, m_sobol=m_sobol,
        alpha=0.05, gamma=0.01, tau=0.95, max_iters=16,
    )
    rng = np.random.default_rng(0)
    cap = 1024
    vals = jnp.asarray(rng.normal(0, 1, (k, cap)).astype(np.float32))
    n = jnp.asarray([cap, cap], jnp.int32)
    res = fused(
        vals, n, jnp.zeros((k,), jnp.int32),
        jnp.asarray(0.05, jnp.float32), jnp.zeros((0,), jnp.float32),
    )
    sobol_block = (k + 2) * m_sobol
    megabatch = fused_rows_per_iteration(k, m, m_sobol)
    assert megabatch == m + 1 + sobol_block
    assert len(calls) == 3, f"init AMI + init Sobol + body, got {calls}"
    assert sorted(calls) == sorted([m + 1, sobol_block, megabatch]), calls
    # exactly ONE traced call is the per-iteration megabatch (the loop body)
    assert calls.count(megabatch) == 1
    assert int(res.iters) >= 1  # the loop actually iterated

    # same shapes -> cached executable, no retrace, still 3 traced calls
    fused(
        vals, n, jnp.zeros((k,), jnp.int32),
        jnp.asarray(0.05, jnp.float32), jnp.zeros((0,), jnp.float32),
    )
    assert len(calls) == 3


# ------------------------------------------------------------- host parity
def test_fused_vs_host_parity_parametric_pipeline():
    """On a parametric-only pipeline both executors meet Eq. 1 at the same
    (alpha, gamma, tau, delta) and land within tolerance of each other."""
    b = make_pipeline("turbofan", **SMALL)
    cfg = BiathlonConfig(m=192, m_sobol=48)
    host = BiathlonServer(b, cfg, mode="host")
    fused = BiathlonServer(b, cfg, mode="fused")
    delta = b.pipeline.delta_default
    agree = 0
    reqs = b.requests[:4]
    for i, req in enumerate(reqs):
        rh = host.serve(req, jax.random.PRNGKey(i))
        rf = fused.serve(req)
        y_ex, _ = run_exact(b.store, b.pipeline, req)
        # each path satisfied Eq. 1 (or provably exhausted to exact)
        assert rh["prob"] >= cfg.tau or rh["sample_frac"] >= 0.999
        assert rf["prob"] >= cfg.tau or rf["sample_frac"] >= 0.999
        if (
            abs(rf["y_hat"] - rh["y_hat"]) <= 2 * delta + 1e-6
            and abs(rf["y_hat"] - y_ex) <= delta + 1e-6
        ):
            agree += 1
    # tau=0.95 per request; allow one miss across paths on a small log
    assert agree >= len(reqs) - 1


# ----------------------------------------------------------- bucketed batches
@pytest.fixture(scope="module")
def mixed_bundle():
    """10 small groups (120 rows) + 3 large groups (5000 rows), linear model."""
    rng = np.random.default_rng(0)
    sizes = [120] * 10 + [5000] * 3
    gid = np.concatenate([np.full(s, g) for g, s in enumerate(sizes)])
    mu = rng.normal(0, 5, len(sizes))
    vals = mu[gid] + rng.normal(0, 2.0, len(gid))
    aux = 0.5 * mu[gid] + rng.normal(0, 1.0, len(gid))
    store = ColumnStore().add("t", build_table({"v": vals, "a": aux}, gid, seed=1))
    X = np.stack([mu, 0.5 * mu], axis=1)
    y = 3 * X[:, 0] + X[:, 1] + rng.normal(0, 0.01, len(sizes))
    pipe = Pipeline(
        name="mixed",
        agg_features=[
            AggFeature("avg_v", "t", "v", "avg", "g"),
            AggFeature("avg_a", "t", "a", "avg", "g"),
        ],
        exact_features=[],
        model=LinearRegression().fit(X, y),
        task="regression",
        scaler_mean=np.zeros(2, np.float32),
        scaler_scale=np.ones(2, np.float32),
        delta_default=0.5,
    )
    return PipelineBundle(
        pipeline=pipe, store=store, requests=[{"g": g} for g in range(len(sizes))],
        labels=y, table_rows=len(gid), name="mixed",
    )


def test_batched_cap_derives_from_admission_batch(mixed_bundle):
    srv = BatchedFusedServer(mixed_bundle, BiathlonConfig(m=96, m_sobol=32))
    small = [{"g": 0}, {"g": 1}, {"g": 2}]
    large = [{"g": 10}, {"g": 11}]
    mixed = [{"g": 3}, {"g": 12}]
    assert srv.batch_cap(small) == 128          # bucket(120), NOT the store max
    assert srv.batch_cap(large) == 8192
    assert srv.batch_cap(mixed) == 8192         # batch max rules

    rs = srv.serve_batch(small)
    assert rs.cap == 128
    rl = srv.serve_batch(large)
    assert rl.cap == 8192
    assert sorted(srv.compiled_buckets) == [128, 8192]
    for res in (rs, rl):
        assert np.isfinite(res.y_hat).all()
        assert ((res.prob >= 0.95) | (res.sample_frac >= 0.999)).all()
        assert res.batch_iters == int(res.iters.max())


def test_straggler_report(mixed_bundle):
    srv = BatchedFusedServer(mixed_bundle, BiathlonConfig(m=96, m_sobol=32))
    res = srv.serve_batch([{"g": 4}, {"g": 5}, {"g": 12}])
    rep = straggler_report(res)
    assert rep["batch_iters"] == int(res.iters.max())
    assert (rep["wasted_iters"] >= 0).all()
    assert (rep["wasted_iters"] == rep["batch_iters"] - res.iters).all()
    assert 0.0 <= rep["wasted_frac"] <= 1.0
    assert rep["cap"] == res.cap
    assert rep["straggler"] == int(np.argmax(res.iters))
