"""Sharded fused serving: mesh-parallel lanes vs the single-device server.

Covers the PR-4 tentpole contract:

* per-lane results are IDENTICAL across serving-mesh sizes — bitwise for
  the integer z-plans and iteration counts, fp-tolerance for predictions —
  for a parametric (turbofan) and a holistic (sensor_health) pipeline.
  Device counts {1, 2, 8} are exercised in a forked subprocess under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (jax fixes its
  device list at first init, so the parent process can't host the sweep);
* the fixed-lane compile contract is mesh-invariant: one executable per
  power-of-two cap bucket across fills 1/3/batch_size AND device counts;
* ``make_serving_mesh`` / ``BatchedFusedServer(mesh=...)`` validation;
* per-device fill + lane-imbalance reporting (``straggler_report``,
  ``RuntimeStats.summary``) including empty-input guards.

The in-process tests run on whatever devices are visible (a 1-device mesh
still exercises the full shard_map path); CI additionally runs this file
with 8 forced host devices so the subprocess sweep is cheap there.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.executor import BiathlonConfig
from repro.core.pipeline import AggFeature, Pipeline
from repro.data.store import ColumnStore, build_table
from repro.data.synthetic import PipelineBundle
from repro.launch.mesh import LANES_AXIS, make_serving_mesh
from repro.models.tabular import LinearRegression
from repro.serving import (
    BatchedFusedServer,
    BatchResult,
    RequestRecord,
    RuntimeStats,
    device_fill,
    straggler_report,
)

_MARK = "SHARDED_PARITY_JSON:"
DEVICE_COUNTS = (1, 2, 8)

CFG = BiathlonConfig(m=64, m_sobol=16, n_bootstrap=32)
SMALL = dict(rows_per_group=300, n_train_groups=30, n_serve_groups=4, n_requests=8)


# --------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def small_bundle():
    """Two-feature linear pipeline, group sizes spanning two cap buckets."""
    rng = np.random.default_rng(0)
    sizes = [120] * 8 + [900] * 2
    gid = np.concatenate([np.full(s, g) for g, s in enumerate(sizes)])
    mu = rng.normal(0, 5, len(sizes))
    vals = mu[gid] + rng.normal(0, 2.0, len(gid))
    aux = 0.5 * mu[gid] + rng.normal(0, 1.0, len(gid))
    store = ColumnStore().add("t", build_table({"v": vals, "a": aux}, gid, seed=1))
    X = np.stack([mu, 0.5 * mu], axis=1)
    y = 3 * X[:, 0] + X[:, 1] + rng.normal(0, 0.01, len(sizes))
    pipe = Pipeline(
        name="small",
        agg_features=[
            AggFeature("avg_v", "t", "v", "avg", "g"),
            AggFeature("avg_a", "t", "a", "avg", "g"),
        ],
        exact_features=[],
        model=LinearRegression().fit(X, y),
        task="regression",
        scaler_mean=np.zeros(2, np.float32),
        scaler_scale=np.ones(2, np.float32),
        delta_default=0.5,
    )
    return PipelineBundle(
        pipeline=pipe, store=store,
        requests=[{"g": g} for g in range(len(sizes))],
        labels=y, table_rows=len(gid), name="small",
    )


# ----------------------------------------------------------- mesh builder
def test_make_serving_mesh_validation():
    mesh = make_serving_mesh(1)
    assert mesh.axis_names == (LANES_AXIS,)
    assert mesh.devices.size == 1
    # default = every visible device
    assert make_serving_mesh().devices.size == len(__import__("jax").devices())
    with pytest.raises(ValueError, match=">= 1"):
        make_serving_mesh(0)
    # the over-subscription error must teach the CPU simulation knob
    with pytest.raises(ValueError, match="xla_force_host_platform_device_count"):
        make_serving_mesh(10_000)


def test_server_rejects_indivisible_batch_size(small_bundle):
    class _FakeMesh:
        devices = np.empty(3, dtype=object)
        axis_names = (LANES_AXIS,)

    with pytest.raises(ValueError, match="divisible"):
        BatchedFusedServer(small_bundle, CFG, batch_size=4, mesh=_FakeMesh())

    class _FakeMesh2D:
        devices = np.empty((2, 2), dtype=object)
        axis_names = ("data", "model")

    with pytest.raises(ValueError, match="1-D"):
        BatchedFusedServer(small_bundle, CFG, batch_size=4, mesh=_FakeMesh2D())

    class _FakeMeshWrongAxis:
        devices = np.empty(2, dtype=object)
        axis_names = ("data",)

    # shard_lanes_executor partitions on the literal "lanes" axis — a
    # mis-named mesh must fail loudly at construction, not inside tracing
    with pytest.raises(ValueError, match="named 'lanes'"):
        BatchedFusedServer(
            small_bundle, CFG, batch_size=4, mesh=_FakeMeshWrongAxis()
        )


# -------------------------------------------- in-process sharded parity
def test_sharded_matches_unsharded(small_bundle):
    """A shard_map-wrapped server returns the same per-lane results as the
    plain vmapped one: identical z-plans/iters, fp-close predictions."""
    base = BatchedFusedServer(small_bundle, CFG, batch_size=4)
    shard = BatchedFusedServer(
        small_bundle, CFG, batch_size=4, mesh=make_serving_mesh(1)
    )
    assert shard.n_devices == 1
    reqs = small_bundle.requests[:3]
    rb, rs = base.serve_batch(reqs), shard.serve_batch(reqs)
    np.testing.assert_array_equal(rb.z, rs.z)
    np.testing.assert_array_equal(rb.iters, rs.iters)
    np.testing.assert_allclose(rb.y_hat, rs.y_hat, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(rb.prob, rs.prob, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(rb.sample_frac, rs.sample_frac, rtol=1e-7)
    assert rs.n_devices == 1 and rb.n_devices == 1


def test_sharded_compile_count_per_bucket_across_fills(small_bundle):
    """The fixed-lane no-recompile contract survives shard_map: fills
    1/3/batch_size share ONE executable per cap bucket."""
    srv = BatchedFusedServer(
        small_bundle, CFG, batch_size=4, mesh=make_serving_mesh(1)
    )
    assert srv.compile_count == 0
    srv.serve_batch([{"g": 0}])
    srv.serve_batch([{"g": 1}, {"g": 2}, {"g": 3}])
    srv.serve_batch([{"g": c} for c in range(4)])
    assert srv.compile_count == 1, "fill variation must not recompile"
    # sharded servers assert through the 'sharded_lanes' registry contract
    srv.check_compile_contract(buckets=[128])
    srv.serve_batch([{"g": 8}])  # a new cap bucket is the ONLY compile trigger
    srv.check_compile_contract(buckets=[128, 1024])


# ------------------------------------------------- per-device accounting
def test_device_fill_partition():
    np.testing.assert_array_equal(device_fill(5, 8, 4), [2, 2, 1, 0])
    np.testing.assert_array_equal(device_fill(0, 8, 2), [0, 0])
    np.testing.assert_array_equal(device_fill(8, 8, 1), [8])
    with pytest.raises(ValueError, match="divisible"):
        device_fill(3, 8, 3)


def _result(iters, lanes, n_devices):
    r = len(iters)
    z = np.zeros((r, 2), np.int32)
    f = np.zeros((r,), np.float32)
    return BatchResult(
        y_hat=f, prob=f, iters=np.asarray(iters, np.int32), sample_frac=f,
        batch_iters=int(max(iters, default=0)), cap=128, lanes=lanes, z=z,
        n_devices=n_devices,
    )


def test_straggler_report_per_device_fields():
    """Sharded waste is measured against the lane's OWN device-block max —
    each device's while-loop exits independently."""
    rep = straggler_report(_result([1, 5, 2, 0, 7], lanes=8, n_devices=4))
    assert rep["n_devices"] == 4
    np.testing.assert_allclose(rep["per_device_fill"], [1.0, 1.0, 0.5, 0.0])
    assert rep["lane_imbalance"] == pytest.approx(1.0)
    # device blocks of 2 lanes: maxes are [5, 2, 7] -> waits are local
    np.testing.assert_array_equal(rep["wasted_iters"], [4, 0, 0, 2, 0])
    assert rep["wasted_frac"] == pytest.approx(6 / (5 + 5 + 2 + 2 + 7))
    # single device: identical to the legacy global-straggler accounting
    rep1 = straggler_report(_result([1, 5, 2, 0, 7], lanes=8, n_devices=1))
    np.testing.assert_array_equal(rep1["wasted_iters"], [6, 2, 5, 7, 0])
    assert rep1["per_device_fill"] == pytest.approx([5 / 8])
    assert rep1["lane_imbalance"] == 0.0


def test_straggler_report_empty_sharded():
    rep = straggler_report(_result([], lanes=8, n_devices=4))
    assert rep["straggler"] == -1
    assert rep["n_devices"] == 4
    np.testing.assert_allclose(rep["per_device_fill"], [0.0] * 4)
    assert rep["lane_imbalance"] == 0.0
    assert rep["wasted_frac"] == 0.0


def test_runtime_stats_device_fields_and_empty_guard():
    # multi-device, no records: zeros, never a crash
    s = RuntimeStats(tau=0.95, n_devices=4, lanes=8).summary()
    assert s["n_devices"] == 4
    assert s["per_device_fill"] == [0.0] * 4
    assert s["mean_lane_imbalance"] == 0.0
    # unknown lane count (hand-built stats WITH records): zeros, never a
    # partition guessed from n_devices alone
    rec = RequestRecord(
        req_id=0, arrival_t=0.0, admit_t=0.0, done_t=0.01, queue_delay_s=0.0,
        exec_s=0.01, latency_s=0.01, batch_id=0, batch_fill=6, y_hat=0.0,
        prob=1.0, iters=1, sample_frac=0.1,
    )
    s0 = RuntimeStats(tau=0.95, records=[rec], n_devices=4, lanes=0).summary()
    assert s0["per_device_fill"] == [0.0] * 4
    assert s0["mean_lane_imbalance"] == 0.0
    # single device: the per-device keys are omitted, not silently [1.0]
    s1 = RuntimeStats(tau=0.95, n_devices=1, lanes=8).summary()
    assert s1["n_devices"] == 1
    assert "per_device_fill" not in s1


# ------------------------------------- cross-device parity (subprocess)
def _run_worker(pipeline: str) -> dict:
    from repro.launch.mesh import forced_host_devices_env

    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--worker", pipeline],
        env=forced_host_devices_env(max(DEVICE_COUNTS)),
        capture_output=True, text=True, timeout=1200,
    )
    assert proc.returncode == 0, f"worker failed:\n{proc.stdout}\n{proc.stderr}"
    payload = [l for l in proc.stdout.splitlines() if l.startswith(_MARK)]
    assert payload, f"no payload in worker output:\n{proc.stdout}"
    return json.loads(payload[-1][len(_MARK):])


@pytest.mark.parametrize("pipeline", ["turbofan", "sensor_health"])
def test_cross_device_parity(pipeline):
    """Identical requests through the unsharded server and mesh sizes
    {1, 2, 8} produce bitwise-identical z-plans/iters and fp-close
    predictions, and every server compiles once per cap bucket."""
    out = _run_worker(pipeline)
    assert out["n_visible_devices"] >= max(DEVICE_COUNTS)
    base = out["baseline"]
    for d in map(str, DEVICE_COUNTS):
        run = out["devices"][d]
        assert run["z"] == base["z"], f"z-plan drift at {d} devices"
        assert run["iters"] == base["iters"]
        np.testing.assert_allclose(
            run["y_hat"], base["y_hat"], rtol=1e-5, atol=1e-6
        )
        np.testing.assert_allclose(
            run["prob"], base["prob"], rtol=1e-5, atol=1e-6
        )
        # mesh-invariant fixed-lane contract: fills 1/3/8 never recompile
        assert run["compile_count"] == len(run["compiled_buckets"])
        assert run["compiled_buckets"] == base["compiled_buckets"]


# ----------------------------------------------------------- worker main
def _serve_sweep(server, requests) -> dict:
    full = server.serve_batch(requests)
    server.serve_batch(requests[:1])   # fill variation: must not recompile
    server.serve_batch(requests[:3])
    return {
        "z": np.asarray(full.z).tolist(),
        "iters": np.asarray(full.iters).tolist(),
        "y_hat": np.asarray(full.y_hat, np.float64).tolist(),
        "prob": np.asarray(full.prob, np.float64).tolist(),
        "compile_count": server.compile_count,
        "compiled_buckets": server.compiled_buckets,
    }


def _worker_main(pipeline: str) -> None:
    import jax

    from repro.data.synthetic import make_pipeline

    bundle = make_pipeline(pipeline, **SMALL)
    reqs = bundle.requests[: max(DEVICE_COUNTS)]
    out = {
        "pipeline": pipeline,
        "n_visible_devices": len(jax.devices()),
        "baseline": _serve_sweep(
            BatchedFusedServer(bundle, CFG, batch_size=len(reqs)), reqs
        ),
        "devices": {},
    }
    for d in DEVICE_COUNTS:
        srv = BatchedFusedServer(
            bundle, CFG, batch_size=len(reqs), mesh=make_serving_mesh(d)
        )
        out["devices"][str(d)] = _serve_sweep(srv, reqs)
    print(_MARK + json.dumps(out))


if __name__ == "__main__":
    assert sys.argv[1] == "--worker"
    _worker_main(sys.argv[2])
