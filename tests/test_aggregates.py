"""Online-aggregation estimators: correctness, exactness, CI coverage."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.data.aggregates import estimate, exact_value

KEY = jax.random.PRNGKey(0)


def _buf(vals, cap):
    out = np.zeros(cap, np.float32)
    out[: len(vals)] = vals
    return jnp.asarray(out)


@pytest.mark.parametrize("agg", ["avg", "sum", "var", "std"])
def test_exact_when_full_sample(agg):
    rng = np.random.default_rng(0)
    vals = rng.normal(3.0, 2.0, 100).astype(np.float32)
    res = estimate(agg, _buf(vals, 128), jnp.asarray(100), jnp.asarray(100), KEY)
    expected = {
        "avg": vals.mean(),
        "sum": vals.sum(),
        "var": vals.var(ddof=1),
        "std": vals.std(ddof=1),
    }[agg]
    assert abs(float(res.value) - expected) < 1e-2 * max(abs(expected), 1.0)
    assert float(res.sigma) == 0.0  # finite-population correction kills it


def test_count_estimator():
    rng = np.random.default_rng(1)
    ind = (rng.random(1000) < 0.3).astype(np.float32)
    res = estimate("count", _buf(ind[:200], 256), jnp.asarray(200), jnp.asarray(1000), KEY)
    assert abs(float(res.value) - 1000 * ind[:200].mean()) < 1e-3
    assert float(res.sigma) > 0


def test_median_bootstrap_captures_truth():
    rng = np.random.default_rng(2)
    vals = rng.normal(5.0, 1.0, 4096).astype(np.float32)
    z = 512
    res = estimate(
        "median", _buf(vals[:1024], 1024), jnp.asarray(z), jnp.asarray(4096), KEY
    )
    assert bool(res.is_empirical)
    reps = np.asarray(res.replicates)
    assert np.all(np.diff(reps) >= 0), "replicates must be sorted"
    true_med = np.median(vals)
    lo, hi = np.percentile(reps, [0.5, 99.5])
    assert lo - 0.2 <= true_med <= hi + 0.2


@settings(max_examples=25, deadline=None)
@given(
    mu=st.floats(-10, 10),
    sd=st.floats(0.1, 5.0),
    z=st.sampled_from([64, 128, 256]),
)
def test_avg_ci_is_calibrated(mu, sd, z):
    """Hypothesis property: |estimate - truth| <= 4 sigma_hat (w.h.p.)."""
    rng = np.random.default_rng(abs(hash((mu, sd, z))) % 2**32)
    n = 2048
    vals = rng.normal(mu, sd, n).astype(np.float32)
    res = estimate("avg", _buf(vals[:z], z), jnp.asarray(z), jnp.asarray(n), KEY)
    err = abs(float(res.value) - vals.mean())
    assert err <= 4.5 * float(res.sigma) + 1e-4


@pytest.mark.parametrize("agg", ["median", "quantile"])
def test_empty_prefix_quantile_returns_zero(agg):
    """z == 0 regression: the +inf-padded sort must not leak into the value
    (rank-0 gather) or the bootstrap replicates (vals[0] garbage) — an empty
    prefix returns 0.0, the same convention as the parametric mean."""
    vals = _buf(np.full(7, 123.0, np.float32), 16)  # garbage the bug would leak
    res = estimate(
        agg, vals, jnp.asarray(0), jnp.asarray(512), KEY, n_boot=32, quantile=0.9
    )
    assert float(res.value) == 0.0
    assert float(res.sigma) == 0.0
    reps = np.asarray(res.replicates)
    assert np.isfinite(reps).all()
    assert (reps == 0.0).all()


def test_empty_prefix_parametric_matches_convention():
    """Parametric estimators on z == 0 keep the mean-0 convention too."""
    vals = _buf(np.full(7, 9.0, np.float32), 16)
    for agg in ("avg", "sum", "var", "std"):
        res = estimate(agg, vals, jnp.asarray(0), jnp.asarray(64), KEY)
        assert float(res.value) == 0.0, agg
        assert np.isfinite(float(res.sigma)), agg


def test_sigma_decreases_with_samples():
    rng = np.random.default_rng(3)
    vals = rng.normal(0, 1, 4096).astype(np.float32)
    sig = []
    for z in (64, 256, 1024):
        r = estimate("avg", _buf(vals[:1024], 1024), jnp.asarray(z), jnp.asarray(4096), KEY)
        sig.append(float(r.sigma))
    assert sig[0] > sig[1] > sig[2]


def test_exact_value_matches_numpy():
    rng = np.random.default_rng(4)
    vals = rng.normal(1, 2, 500).astype(np.float32)
    for agg, exp in [
        ("avg", vals.mean()),
        ("sum", vals.sum()),
        ("std", vals.std(ddof=1)),
        ("median", np.median(vals)),
    ]:
        got = float(exact_value(agg, jnp.asarray(vals), 500))
        assert abs(got - exp) < 2e-2 * max(abs(exp), 1.0), agg
