"""Shared tiny serving bundle for degradation/fault/runtime tests.

One linear-model pipeline over two AVG features, 8 groups of 120 rows (one
128-cap bucket) plus 2 groups of 900 rows (a 1024-cap bucket) — small
enough that a full admission batch serves in milliseconds on CPU, big
enough that requests iterate a heterogeneous number of planner steps.
"""
import numpy as np

from repro.core.executor import BiathlonConfig
from repro.core.pipeline import AggFeature, Pipeline
from repro.data.store import ColumnStore, build_table
from repro.data.synthetic import PipelineBundle
from repro.models.tabular import LinearRegression

SMALL_CFG = BiathlonConfig(m=64, m_sobol=16)


def make_small_bundle(seed: int = 0) -> PipelineBundle:
    """8 groups of 120 rows + 2 groups of 900 rows, linear model."""
    rng = np.random.default_rng(seed)
    sizes = [120] * 8 + [900] * 2
    gid = np.concatenate([np.full(s, g) for g, s in enumerate(sizes)])
    mu = rng.normal(0, 5, len(sizes))
    vals = mu[gid] + rng.normal(0, 2.0, len(gid))
    aux = 0.5 * mu[gid] + rng.normal(0, 1.0, len(gid))
    store = ColumnStore().add(
        "t", build_table({"v": vals, "a": aux}, gid, seed=1)
    )
    X = np.stack([mu, 0.5 * mu], axis=1)
    y = 3 * X[:, 0] + X[:, 1] + rng.normal(0, 0.01, len(sizes))
    pipe = Pipeline(
        name="small",
        agg_features=[
            AggFeature("avg_v", "t", "v", "avg", "g"),
            AggFeature("avg_a", "t", "a", "avg", "g"),
        ],
        exact_features=[],
        model=LinearRegression().fit(X, y),
        task="regression",
        scaler_mean=np.zeros(2, np.float32),
        scaler_scale=np.ones(2, np.float32),
        delta_default=0.5,
    )
    return PipelineBundle(
        pipeline=pipe, store=store,
        requests=[{"g": g} for g in range(len(sizes))],
        labels=y, table_rows=len(gid), name="small",
    )
