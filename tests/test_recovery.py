"""Fault-tolerant continuous serving: rollback, quarantine, recovery.

Pins the PR-10 contracts (DESIGN.md § Fault tolerance):

* chunk-granular fault schedules are pure functions of (profile, call
  index) — byte-identical replay;
* a chunk-dispatch failure rolls the lane table back to its chunk-boundary
  checkpoint and replays BITWISE-identically to a fault-free run (the
  counter-based-RNG payoff), minting zero executables;
* lane poisoning quarantines exactly the poisoned lane — bounded
  re-admission recovers it, neighbors are bitwise-untouched;
* the feature store recovers from a torn crash state by journal replay,
  byte-identical to the never-crashed table, and the feature cache detects
  a flipped byte via its power-sum checksum;
* non-finite inputs are rejected (loudly, naming the offender) or clamped
  at both ingest and serving edges;
* retry backoff burns SLO slack: retried requests re-tier against their
  post-backoff deadline budget.
"""
import numpy as np
import pytest
from serving_fixtures import SMALL_CFG, make_small_bundle

from repro.serving import (
    BatchedFusedServer,
    ChunkDispatchError,
    ContinuousBatchedServer,
    ContinuousServingRuntime,
    DegradationController,
    FaultProfile,
    FaultyContinuousServer,
    FaultyServer,
    ServingRuntime,
    TransientExecutorError,
    corrupt_cache_entry,
    default_tiers,
)

CFG = SMALL_CFG
ARRIVALS = [(0.0, {"g": g}) for g in range(6)]


@pytest.fixture(scope="module")
def small_bundle():
    return make_small_bundle()


@pytest.fixture(scope="module")
def cont4(small_bundle):
    srv = ContinuousBatchedServer(small_bundle, CFG, batch_size=4,
                                  chunk_iters=2)
    # pre-warm the INNER server so fault call indices start at 0 for
    # measured traffic and fault runs can assert compile_count == 0
    ContinuousServingRuntime(srv).warmup([a[1] for a in ARRIVALS])
    return srv


def _run(server, arrivals=ARRIVALS, **kw):
    rt = ContinuousServingRuntime(server, backoff_s=0.001, **kw)
    return rt.run(arrivals, warmup=False)


def _z_by_req(stats):
    return {r.req_id: r.z for r in stats.records if r.disposition == "ok"}


# ------------------------------------------------------------- schedules
def test_continuous_fault_streams_are_seeded_and_independent():
    a = FaultProfile(seed=3, chunk_fail_prob=0.3, refill_fail_prob=0.3,
                     poison_prob=0.3)
    b = FaultProfile(seed=3, chunk_fail_prob=0.3, refill_fail_prob=0.3,
                     poison_prob=0.3)
    other = FaultProfile(seed=4, chunk_fail_prob=0.3, refill_fail_prob=0.3,
                         poison_prob=0.3)
    for stream in ("chunk_fails_at", "refill_fails_at", "poisons_at"):
        hits = [c for c in range(200) if getattr(a, stream)(c)]
        assert hits == [c for c in range(200) if getattr(b, stream)(c)]
        assert 0 < len(hits) < 200
        assert hits != [c for c in range(200) if getattr(other, stream)(c)]
    # the three continuous streams are independent draws, not one coin
    chunk = [c for c in range(200) if a.chunk_fails_at(c)]
    refill = [c for c in range(200) if a.refill_fails_at(c)]
    poison = [c for c in range(200) if a.poisons_at(c)]
    assert chunk != refill and chunk != poison and refill != poison
    # lane choice for a poison event is seeded and in range
    lanes = [a.poison_lane(c, 4) for c in range(50)]
    assert lanes == [b.poison_lane(c, 4) for c in range(50)]
    assert all(0 <= l < 4 for l in lanes) and len(set(lanes)) > 1


def test_pinned_continuous_calls_override_probability():
    p = FaultProfile(chunk_fail_calls=(2,), refill_fail_calls=(1,),
                     poison_calls=(0, 3))
    assert [c for c in range(5) if p.chunk_fails_at(c)] == [2]
    assert [c for c in range(5) if p.refill_fails_at(c)] == [1]
    assert [c for c in range(5) if p.poisons_at(c)] == [0, 3]


# ---------------------------------------------------------- wrapper unit
def test_faultless_continuous_wrapper_is_transparent(cont4):
    want = _z_by_req(_run(cont4))
    fs = FaultyContinuousServer(cont4, FaultProfile(), sleep=lambda s: None)
    got = _z_by_req(_run(fs))
    assert want == got and fs.events == []


def test_chunk_failure_raises_with_wrecked_table(cont4):
    fs = FaultyContinuousServer(cont4, FaultProfile(chunk_fail_calls=(0,)))
    cap = cont4.trace_cap([{"g": 0}])
    table, _ = cont4.admit(cont4.new_table(cap), cap, [(0, {"g": 0}, None)])
    with pytest.raises(ChunkDispatchError) as ei:
        fs.run_chunk(table)
    wreck = ei.value.table
    assert wreck is not None
    assert np.isnan(np.asarray(wreck.y_hat)).all()
    assert (np.asarray(wreck.z) == -1).all()
    assert fs.events == [(0, "chunk_fail")]


# ----------------------------------------------------- rollback / replay
def test_chunk_failure_rolls_back_and_replays_bitwise(cont4):
    want = _z_by_req(_run(cont4))
    fs = FaultyContinuousServer(cont4, FaultProfile(chunk_fail_calls=(1,)))
    stats = _run(fs, max_retries=2)
    assert stats.n_rollbacks == 1 and stats.n_retries == 1
    assert stats.n_failed == 0
    assert [r.disposition for r in stats.records] == ["ok"] * 6
    # the rollback invariant: replay == fault-free run, bit for bit
    assert _z_by_req(stats) == want


def test_chunk_retry_exhaustion_fails_residents_and_drains(cont4):
    fs = FaultyContinuousServer(
        cont4, FaultProfile(chunk_fail_calls=(0, 1, 2))
    )
    stats = _run(fs, max_retries=2)
    assert stats.n_rollbacks == 3
    assert stats.n_failed > 0
    failed = [r for r in stats.records if r.disposition == "failed"]
    assert all(np.isnan(r.y_hat) for r in failed)
    # the run still drained: every offered request got a record
    assert len(stats.records) == len(ARRIVALS)
    assert any(r.disposition == "ok" for r in stats.records)


def test_refill_failure_is_retried_idempotently(cont4):
    want = _z_by_req(_run(cont4))
    fs = FaultyContinuousServer(cont4, FaultProfile(refill_fail_calls=(0,)))
    stats = _run(fs, max_retries=2)
    assert stats.n_retries == 1 and stats.n_failed == 0
    assert _z_by_req(stats) == want  # the retried admit re-inits identically


def test_fault_storm_replays_byte_identically(cont4):
    prof = FaultProfile(seed=11, chunk_fail_prob=0.25, refill_fail_prob=0.15,
                        poison_prob=0.2)

    def go():
        fs = FaultyContinuousServer(cont4, prof)
        st = _run(fs, max_retries=2, poison_retries=1)
        disp = [(r.req_id, r.disposition, r.z) for r in
                sorted(st.records, key=lambda r: r.req_id)]
        return fs.events, disp, st.n_rollbacks, st.n_retries, st.n_poisoned

    assert go() == go()


# ------------------------------------------------------------ quarantine
def _poison_seed(stats, lanes=4):
    """A seed whose chunk-0 poison lands on a lane occupied during chunk 0."""
    live = {r.lane for r in stats.records
            if r.batch_id == 0 and r.n_chunks >= 1}
    return next(s for s in range(100)
                if FaultProfile(seed=s).poison_lane(0, lanes) in live)


def test_poison_quarantines_exactly_one_lane(cont4):
    free = _run(cont4)
    seed = _poison_seed(free)
    lane = FaultProfile(seed=seed).poison_lane(0, 4)
    fs = FaultyContinuousServer(
        cont4, FaultProfile(seed=seed, poison_calls=(0,))
    )
    stats = _run(fs, poison_retries=0)
    assert fs.events == [(0, f"poison:{lane}")]
    poisoned = [r for r in stats.records if r.disposition == "poisoned"]
    assert len(poisoned) == 1 and stats.n_poisoned == 1
    assert poisoned[0].lane == lane and np.isnan(poisoned[0].y_hat)
    # every OTHER request is bitwise-identical to the fault-free run
    want = _z_by_req(free)
    got = _z_by_req(stats)
    assert got == {k: v for k, v in want.items() if k != poisoned[0].req_id}


def test_poisoned_lane_readmission_recovers_bitwise(cont4):
    free = _run(cont4)
    seed = _poison_seed(free)
    fs = FaultyContinuousServer(
        cont4, FaultProfile(seed=seed, poison_calls=(0,))
    )
    stats = _run(fs, poison_retries=1)
    assert stats.n_poisoned == 0 and stats.n_failed == 0
    assert [r.disposition for r in stats.records] == ["ok"] * 6
    # the full re-admission re-initializes the lane: results match fault-free
    assert _z_by_req(stats) == _z_by_req(free)


def test_zero_compiles_under_fault_storm(cont4):
    before = cont4.compile_count
    fs = FaultyContinuousServer(
        cont4,
        FaultProfile(seed=11, chunk_fail_prob=0.25, poison_prob=0.2),
    )
    _run(fs, max_retries=2, poison_retries=1)
    # checkpoints, rollbacks, quarantine evictions and re-admissions are
    # all host buffer swaps: the warmed refill+chunk pair serves the storm
    assert cont4.compile_count == before
    cont4.check_compile_contract()


# --------------------------------------------------- store crash recovery
def test_store_recover_matches_never_crashed_table():
    b = make_small_bundle()
    t = b.store["t"]
    t.append({"v": [1.5, 2.5], "a": [0.5, 0.25]}, group_key=[0, 3])
    t.append({"v": [-1.0], "a": [0.125]}, group_key=[11])  # new group
    want = (t.perm.copy(), t.group_ptr.copy(), dict(t.group_ids),
            list(t.versions))
    # tear every derived structure the way a crash mid-append would
    t.perm = np.random.default_rng(0).permutation(t.perm)
    t.group_ptr = t.group_ptr + 3
    t.versions = []
    t._log = {}
    info = t.recover()
    assert info["replayed"] == 4  # 3 insertions + 1 group registration
    np.testing.assert_array_equal(t.perm, want[0])
    np.testing.assert_array_equal(t.group_ptr, want[1])
    assert t.group_ids == want[2] and t.versions == want[3]
    # the rebuilt index serves: prefix reads see the appended rows
    assert t.group_size(11) == 1 and t.lookup("v", 11) == -1.0


def test_store_recover_detects_journal_gap():
    b = make_small_bundle()
    t = b.store["t"]
    for v in (1.0, 2.0, 3.0):
        t.append({"v": [v], "a": [0.0]}, group_key=[0])
    del t._journal[1]  # a torn journal: seqs (1, 3) with seq 2 lost
    with pytest.raises(ValueError, match="gap-free"):
        t.recover()


def test_store_recover_revalidates_caches():
    from repro.serving.server import BiathlonServer

    b = make_small_bundle()
    srv = BiathlonServer(b, CFG, mode="fused", cache_size=4)
    srv.serve({"g": 0})
    t = b.store["t"]
    t.append({"v": [9.0], "a": [1.0]}, group_key=[0])  # entry now stale
    info = t.recover(caches=(srv.cache,))
    assert info["cache_entries_dropped"] == 1
    assert len(srv.cache) == 0


# ------------------------------------------------------- cache integrity
def test_cache_detects_flipped_byte_and_rebuilds():
    from repro.serving.server import BiathlonServer

    b = make_small_bundle()
    srv = BiathlonServer(b, CFG, mode="fused", cache_size=4)
    want = srv.serve({"g": 0})
    srv.cache.verify_hits = True
    assert corrupt_cache_entry(srv.cache, seed=0)
    got = srv.serve({"g": 0})  # detect -> drop -> cold rebuild
    assert srv.cache.corruptions == 1
    np.testing.assert_array_equal(want["z"], got["z"])
    assert want["y_hat"] == got["y_hat"]


def test_revalidate_drops_corrupt_entries():
    from repro.serving.server import BiathlonServer

    b = make_small_bundle()
    srv = BiathlonServer(b, CFG, mode="fused", cache_size=4)
    srv.serve({"g": 0})
    srv.serve({"g": 1})
    assert corrupt_cache_entry(srv.cache, seed=1)
    dropped = srv.cache.revalidate()
    assert dropped == 1 and srv.cache.corruptions == 1
    assert len(srv.cache) == 1  # the intact entry survives


def test_corrupt_cache_entry_empty_cache_is_a_noop():
    from repro.serving.feature_cache import FeatureCache

    b = make_small_bundle()
    cache = FeatureCache(b.store, lambda v, n: None, lambda *a: None,
                         maxsize=2)
    assert corrupt_cache_entry(cache) is False


# ----------------------------------------------------- input sanitization
def test_append_rejects_nonfinite_loudly():
    b = make_small_bundle()
    t = b.store["t"]
    with pytest.raises(ValueError) as ei:
        t.append({"v": [1.0, np.nan], "a": [0.0, 0.0]}, group_key=[0, 0])
    msg = str(ei.value)
    assert "'t'" in msg and "'v'" in msg and "row 1" in msg
    # the rejected batch must not have been partially applied
    assert not t._journal


def test_append_clamp_coerces_to_observed_range():
    b = make_small_bundle()
    t = b.store["t"]
    hi = float(t.columns["v"].max())
    lo = float(t.columns["v"].min())
    t.append({"v": [np.nan, np.inf, -np.inf], "a": [0.0, 0.0, 0.0]},
             group_key=[0, 0, 0], sanitize="clamp")
    got = t.columns["v"][-3:]
    assert got[0] == 0.0 and got[1] == hi and got[2] == lo


def test_serve_batch_rejects_corrupted_store_values():
    b = make_small_bundle()
    t = b.store["t"]
    row = int(t.perm[int(t.group_ptr[0])])
    t.columns["v"][row] = np.nan  # upstream corruption past the append gate
    srv = BatchedFusedServer(b, CFG, batch_size=2)
    with pytest.raises(ValueError, match="serve_batch lane 0"):
        srv.serve_batch([{"g": 0}])
    clamping = BatchedFusedServer(b, CFG, batch_size=2, sanitize="clamp")
    res = clamping.serve_batch([{"g": 0}])  # clamped to 0.0, served
    assert np.isfinite(res.y_hat[0])


def test_continuous_admit_rejects_corrupted_store_values():
    b = make_small_bundle()
    t = b.store["t"]
    row = int(t.perm[int(t.group_ptr[0])])
    t.columns["v"][row] = np.inf
    srv = ContinuousBatchedServer(b, CFG, batch_size=2, chunk_iters=2)
    cap = srv.trace_cap([{"g": 0}])
    with pytest.raises(ValueError, match="admit lane 0"):
        srv.admit(srv.new_table(cap), cap, [(0, {"g": 0}, None)])
    with pytest.raises(ValueError, match="sanitize"):
        ContinuousBatchedServer(b, CFG, sanitize="bogus")


# --------------------------------------------- retry backoff burns slack
def test_fixed_lane_retry_backoff_repriced_against_slack(small_bundle):
    srv = BatchedFusedServer(small_bundle, CFG, batch_size=4)
    srv.serve_batch([{"g": 0}])  # warm

    def tiers(fail):
        prof = FaultProfile(fail_calls=(0,) if fail else ())
        fs = FaultyServer(srv, prof, sleep=lambda s: None)
        ctl = DegradationController(
            default_tiers(CFG.tau, CFG.max_iters), service_est_s=1.0, lanes=4
        )
        rt = ServingRuntime(fs, max_wait_s=0.001, max_retries=2,
                            backoff_s=5.0, controller=ctl)
        stats = rt.run([(0.0, {"g": g}, 6.0) for g in range(4)],
                       warmup=False)
        assert all(r.disposition == "ok" for r in stats.records)
        return stats.n_retries, max(r.tier for r in stats.records)

    retries_ok, tier_ok = tiers(fail=False)
    retries_f, tier_f = tiers(fail=True)
    assert retries_ok == 0 and tier_ok == 0
    # the 5s backoff burned the 6s budget: the retried batch re-tiered
    assert retries_f == 1 and tier_f > 0


def test_continuous_retry_backoff_repriced_against_slack(cont4):
    def tiers(fail):
        prof = FaultProfile(refill_fail_calls=(0,) if fail else ())
        fs = FaultyContinuousServer(cont4, prof)
        ctl = DegradationController(
            default_tiers(CFG.tau, CFG.max_iters), service_est_s=1.0, lanes=4
        )
        rt = ContinuousServingRuntime(fs, controller=ctl, max_retries=2,
                                      backoff_s=5.0)
        stats = rt.run([(0.0, {"g": g}, 6.0) for g in range(4)],
                       warmup=False)
        ok = [r for r in stats.records if r.disposition == "ok"]
        assert ok, "every request shed"
        return stats.n_retries, max(r.tier for r in ok)

    retries_ok, tier_ok = tiers(fail=False)
    retries_f, tier_f = tiers(fail=True)
    assert retries_ok == 0 and tier_ok == 0
    assert retries_f == 1 and tier_f > 0


def test_transient_error_subclass_relationship():
    assert issubclass(ChunkDispatchError, TransientExecutorError)
    e = ChunkDispatchError("boom")
    assert e.table is None
