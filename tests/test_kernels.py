"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qmc import sobol_uint32
from repro.data.aggregates import estimate, masked_estimates_batch
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.sampled_agg.ops import (
    masked_estimates,
    masked_quantile_estimates,
)
from repro.kernels.sampled_agg.quantile_select import masked_select_ranks
from repro.kernels.sampled_agg.ref import (
    N_MOMENTS,
    masked_select_ranks_ref,
    sampled_moments_ref,
)
from repro.kernels.sampled_agg.sampled_agg import sampled_moments
from repro.kernels.sobol.sobol import sobol_points
from repro.kernels.tree_qmc.tree_qmc import ensemble_sum
from repro.models.lm.layers import attention_full
from repro.models.tabular.trees import GradientBoosting, ensemble_predict_sum


# ------------------------------------------------------------- sampled_agg
@pytest.mark.parametrize("k,cap,block_k,block_c", [
    (4, 512, 4, 128),
    (8, 2048, 8, 1024),
    (16, 1024, 4, 256),
    (2, 64, 2, 64),
])
def test_sampled_agg_sweep(k, cap, block_k, block_c):
    key = jax.random.PRNGKey(k * cap)
    vals = jax.random.normal(key, (k, cap)) * 3.0 + 1.0
    z = jax.random.randint(jax.random.PRNGKey(1), (k,), 0, cap + 1)
    got = sampled_moments(vals, z, block_k=block_k, block_c=block_c, interpret=True)
    want = sampled_moments_ref(vals, z)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=1e-3)


def test_sampled_agg_dtype_bf16_input():
    vals = (jax.random.normal(jax.random.PRNGKey(0), (4, 256))).astype(jnp.bfloat16)
    z = jnp.asarray([0, 17, 128, 256], jnp.int32)
    got = sampled_moments(vals.astype(jnp.float32), z, interpret=True)
    want = sampled_moments_ref(vals.astype(jnp.float32), z)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=1e-3)


def test_sampled_agg_emits_five_power_sums():
    """[count, Σv, Σv², Σv³, Σv⁴] — the 4th power feeds VAR/STD sigmas."""
    vals = jax.random.normal(jax.random.PRNGKey(2), (2, 128)) * 2.0 + 0.5
    z = jnp.asarray([31, 128], jnp.int32)
    out = np.asarray(sampled_moments(vals, z, interpret=True))
    assert out.shape == (2, N_MOMENTS) == (2, 5)
    v = np.asarray(vals)
    for j, zz in enumerate([31, 128]):
        pre = v[j, :zz].astype(np.float64)
        np.testing.assert_allclose(out[j, 0], zz, rtol=1e-6)
        for p in range(1, 5):
            np.testing.assert_allclose(
                out[j, p], (pre**p).sum(), rtol=5e-5, atol=1e-3
            )


@pytest.mark.parametrize("use_kernel", [True, False])
def test_sampled_agg_estimates_match_masked_oracle(use_kernel):
    """Kernel power sums -> (value, sigma) vs masked_estimates_batch, ragged z
    including the z=0 and z=cap edges, across every parametric aggregate."""
    cap = 512
    vals = jax.random.normal(jax.random.PRNGKey(7), (10, cap)) * 3.0 + 1.0
    z = jnp.asarray([0, 1, 2, 7, 64, 200, 511, 512, 0, 512], jnp.int32)
    n = jnp.asarray([1024, 1024, 2, 1024, 64, 1024, 1024, 512, 4096, 4096], jnp.int32)
    agg_ids = jnp.asarray([0, 1, 2, 3, 4, 0, 3, 4, 1, 2], jnp.int32)
    got_v, got_s = masked_estimates(vals, z, n, agg_ids, use_kernel=use_kernel)
    want_v, want_s = masked_estimates_batch(vals, z, n, agg_ids)
    np.testing.assert_allclose(
        np.asarray(got_v), np.asarray(want_v), rtol=2e-3, atol=2e-3
    )
    # sigma: raw-vs-centered moment arithmetic in float32 — looser tolerance
    np.testing.assert_allclose(
        np.asarray(got_s), np.asarray(want_s), rtol=2e-2, atol=5e-3
    )
    # exactness edges: z >= n must kill sigma entirely on both paths
    exact_rows = np.asarray(z) >= np.asarray(n)
    assert (np.asarray(got_s)[exact_rows] == 0).all()


def test_power_sum_estimates_keep_sigma_when_mean_dominates():
    """|mean| >> std: raw-moment cancellation noise must NOT collapse sigma
    to zero — a sigma of 0 here would fake a satisfied Eq. 1 guarantee."""
    cap = 1024
    vals = jax.random.normal(jax.random.PRNGKey(3), (5, cap)) * 3.0 + 200.0
    z = jnp.full((5,), 256, jnp.int32)
    n = jnp.full((5,), 4096, jnp.int32)
    agg_ids = jnp.asarray([0, 1, 2, 3, 4], jnp.int32)  # avg sum count var std
    got_v, got_s = masked_estimates(vals, z, n, agg_ids, use_kernel=False)
    want_v, want_s = masked_estimates_batch(vals, z, n, agg_ids)
    assert (np.asarray(got_s) > 0).all(), "sigma collapsed to zero"
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v), rtol=1e-3)
    # shifted accumulation keeps cancellation at O(std^4), so the sigmas
    # agree tightly even though mean^4 ~ 1.6e9 in float32
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), rtol=2e-2)


# -------------------------------------------------------- quantile select
@pytest.mark.parametrize("k,cap,R,block_k,block_ci,block_cj", [
    (4, 512, 33, 4, 128, 128),
    (7, 300, 17, 2, 64, 128),
    (1, 64, 5, 4, 64, 64),
    (16, 1024, 65, 8, 256, 128),
])
def test_masked_select_ranks_matches_ref(k, cap, R, block_k, block_ci, block_cj):
    """Stable-rank-count selection == sort+gather oracle, bit exact, over
    ragged z including the z=0 and z=cap edges and tied values."""
    rng = np.random.default_rng(k * cap + R)
    # round half the rows to force ties (stable tie-break must match sort)
    vals = rng.normal(0, 3, (k, cap)).astype(np.float32)
    vals[::2] = np.round(vals[::2])
    z = rng.integers(0, cap + 1, k).astype(np.int32)
    z[0] = 0
    z[-1] = cap
    targets = np.stack(
        [rng.integers(0, max(zz, 1), R) for zz in z]
    ).astype(np.int32)
    got = masked_select_ranks(
        jnp.asarray(vals), jnp.asarray(z), jnp.asarray(targets),
        block_k=block_k, block_ci=block_ci, block_cj=block_cj, interpret=True,
    )
    want = masked_select_ranks_ref(
        jnp.asarray(vals), jnp.asarray(z), jnp.asarray(targets)
    )
    # z=0 rows gather the +inf padding on both paths (callers override)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_masked_select_ranks_non_dividing_blocks():
    """Regression: block_ci != block_cj where the smaller block does not
    divide the padded cap must still visit every candidate column (the
    padding now rounds to lcm(block_ci, block_cj))."""
    vals = np.zeros((4, 200), np.float32)
    vals[:, 199] = 50.0                       # the max lives in the last column
    z = jnp.full((4,), 200, jnp.int32)
    targets = jnp.asarray(np.tile([0, 199], (4, 1)), jnp.int32)
    got = masked_select_ranks(
        jnp.asarray(vals), z, targets,
        block_k=4, block_ci=96, block_cj=128, interpret=True,
    )
    want = masked_select_ranks_ref(jnp.asarray(vals), z, targets)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(got)[0, 1] == 50.0


@pytest.mark.parametrize("use_kernel", [True, False])
def test_masked_quantile_estimates_conventions(use_kernel):
    """Empty prefix -> (0, zeros); exact (z >= n) -> degenerate replicates at
    the exact quantile; sampled rows -> sorted replicates bracketing truth."""
    rng = np.random.default_rng(11)
    cap = 256
    vals = jnp.asarray(rng.normal(5.0, 2.0, (4, cap)).astype(np.float32))
    z = jnp.asarray([0, cap, 64, 200], jnp.int32)
    n = jnp.asarray([1024, cap, 4096, 4096], jnp.int32)
    qs = jnp.asarray([0.5, 0.5, 0.9, 0.5], jnp.float32)
    value, reps = masked_quantile_estimates(
        vals, z, n, qs, jax.random.PRNGKey(3), 64, use_kernel=use_kernel
    )
    value, reps = np.asarray(value), np.asarray(reps)
    assert np.isfinite(value).all() and np.isfinite(reps).all()
    assert value[0] == 0.0 and (reps[0] == 0.0).all()          # empty prefix
    v = np.asarray(vals)
    # nearest-rank median of the full (exact) row, not np.median's midpoint
    np.testing.assert_allclose(
        value[1], np.sort(v[1])[int(np.floor(0.5 * (cap - 1) + 0.5))], atol=1e-6
    )
    assert (reps[1] == value[1]).all()                          # exact row
    assert (np.diff(reps, axis=1) >= 0).all()                   # sorted
    # sampled rows: replicate spread brackets the buffer's true quantile
    assert reps[2].min() <= np.quantile(v[2], 0.9) + 0.5
    assert reps[2].max() >= np.quantile(v[2], 0.9) - 0.5
    # point estimates match the per-feature estimate() oracle
    for j, (zz, nn, qq) in enumerate([(0, 1024, 0.5), (cap, cap, 0.5),
                                      (64, 4096, 0.9), (200, 4096, 0.5)]):
        res = estimate(
            "quantile", vals[j], jnp.asarray(zz), jnp.asarray(nn),
            jax.random.PRNGKey(0), n_boot=8, quantile=qq,
        )
        np.testing.assert_allclose(value[j], float(res.value), atol=1e-6)


# ------------------------------------------------------------------ sobol
@pytest.mark.parametrize("m,d,block_m", [(256, 4, 64), (512, 21, 256), (128, 1, 128)])
def test_sobol_kernel_bit_exact(m, d, block_m):
    got = sobol_points(m, d, 0, block_m=block_m, interpret=True)
    want = sobol_uint32(m, d, 0)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_sobol_kernel_skip():
    got = sobol_points(128, 6, skip=64, interpret=True)
    want = sobol_uint32(128, 6, 64)
    assert (np.asarray(got) == np.asarray(want)).all()


# --------------------------------------------------------------- tree_qmc
@pytest.fixture(scope="module")
def small_ensemble():
    rng = np.random.default_rng(0)
    X = rng.normal(0, 1, (1500, 5)).astype(np.float32)
    y = X[:, 0] * 2 + np.sin(X[:, 1] * 3)
    gb = GradientBoosting(n_trees=12, max_depth=4).fit(X, y)
    return gb.ensemble


@pytest.mark.parametrize("m,block_m,block_t", [(256, 64, 4), (512, 256, 12), (128, 128, 6)])
def test_tree_qmc_sweep(small_ensemble, m, block_m, block_t):
    e = small_ensemble
    x = jax.random.normal(jax.random.PRNGKey(m), (m, 5), jnp.float32)
    got = ensemble_sum(
        e.feature, e.threshold, e.left, e.right, e.value, x,
        depth=e.depth, block_m=block_m, block_t=block_t, interpret=True,
    )
    want = ensemble_predict_sum(e, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,h,s,d,bq,bk", [
    (1, 2, 128, 64, 64, 64),
    (2, 1, 256, 32, 128, 128),
    (1, 2, 256, 64, 128, 64),
])
def test_flash_attention_sweep(causal, b, h, s, d, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(s + d), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, h, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, h, s, d), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=True)
    want = attention_full(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = attention_full(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2
    )
