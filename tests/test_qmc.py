"""Sobol sequence + QMC transform correctness and quality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.qmc import (
    digital_shift,
    discrepancy_proxy,
    normal_qmc_samples,
    sobol_sequence,
    sobol_uint32,
)
from repro.core.sobol_tables import DIRECTION_NUMBERS


def test_direction_numbers_shape():
    assert DIRECTION_NUMBERS.shape == (64, 32)
    assert DIRECTION_NUMBERS.dtype == np.uint32
    # first dimension is the van-der-Corput sequence: v_b = 2^(31-b)
    np.testing.assert_array_equal(
        DIRECTION_NUMBERS[0], (1 << np.arange(31, -1, -1)).astype(np.uint32)
    )


def test_gray_code_construction_matches_recurrence():
    """Direct (parallel) construction == classic one-at-a-time recurrence."""
    n, d = 128, 8
    got = np.asarray(sobol_uint32(n, d))
    x = np.zeros(d, np.uint32)
    exp = np.zeros((n, d), np.uint32)
    for i in range(1, n):
        c = (i & -i).bit_length() - 1
        x = x ^ DIRECTION_NUMBERS[:d, c]
        exp[i] = x
    np.testing.assert_array_equal(got, exp)


def test_skip_consistency():
    full = np.asarray(sobol_uint32(64, 4))
    tail = np.asarray(sobol_uint32(32, 4, skip=32))
    np.testing.assert_array_equal(full[32:], tail)


def test_sobol_beats_monte_carlo_discrepancy():
    n, d = 256, 4
    qmc_pts = np.asarray(sobol_sequence(n, d))
    mc_pts = np.asarray(jax.random.uniform(jax.random.PRNGKey(0), (n, d)))
    assert discrepancy_proxy(qmc_pts) < 0.3 * discrepancy_proxy(mc_pts)


def test_digital_shift_preserves_marginals():
    pts = sobol_uint32(512, 6)
    shifted = digital_shift(jax.random.PRNGKey(1), pts)
    u = np.asarray(shifted).astype(np.float64) / 2**32
    # still near-uniform per dimension
    assert np.all(np.abs(u.mean(0) - 0.5) < 0.05)
    # and actually different points
    assert (np.asarray(shifted) != np.asarray(pts)).any()


def test_normal_qmc_moments():
    z = np.asarray(normal_qmc_samples(2048, 4))
    assert np.all(np.abs(z.mean(0)) < 0.02)
    assert np.all(np.abs(z.std(0) - 1.0) < 0.02)
    assert np.isfinite(z).all()


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([16, 64, 256]),
    d=st.integers(min_value=1, max_value=21),
)
def test_sobol_in_unit_cube(n, d):
    u = np.asarray(sobol_sequence(n, d))
    assert u.shape == (n, d)
    assert (u >= 0).all() and (u < 1).all()


def test_dim_limit():
    with pytest.raises(ValueError):
        sobol_uint32(8, 65)
