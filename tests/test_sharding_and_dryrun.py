"""Sharding rules + dry-run plumbing (mesh-free parts; full cells run via
``python -m repro.launch.dryrun`` which owns the 512-device env flag)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, cell_applicable, cells, get_config
from repro.launch.hlo_cost import analyze_hlo
from repro.models.lm import LM
from repro.models.lm.sharding import ShardingRules, param_pspecs


@pytest.fixture(scope="module")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_cell_applicability_matrix():
    cs = cells()
    assert len(cs) == 40
    skipped = [(a, s) for a, s, ok, _ in cs if not ok]
    # exactly the 8 full-attention long_500k cells skip
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s in skipped)
    ok_long = [a for a, s, ok, _ in cs if ok and s == "long_500k"]
    assert sorted(ok_long) == ["xlstm-1.3b", "zamba2-2.7b"]


def test_param_pspecs_cover_all_leaves(mesh11):
    for arch in ("qwen3-8b", "deepseek-v2-236b", "zamba2-2.7b", "xlstm-1.3b",
                 "seamless-m4t-large-v2"):
        cfg = get_config(arch).reduced()
        model = LM(cfg)
        shapes = model.init_shapes()
        rules = ShardingRules(mesh11, cfg)
        specs = param_pspecs(rules, shapes)
        n_shapes = len(jax.tree.leaves(shapes))
        n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
        assert n_shapes == n_specs, arch


def test_divisibility_guard():
    """granite KV heads (8) must fall back to replicated on a 16-way axis."""
    mesh = jax.make_mesh((1, 16), ("data", "model"), devices=np.array(
        [jax.devices()[0]] * 16
    )) if False else None
    # can't build a 16-device mesh on CPU here; check the rule logic directly
    from repro.models.lm.sharding import _match_spec

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    cfg = get_config("granite-moe-1b-a400m")
    rules = ShardingRules.__new__(ShardingRules)
    object.__setattr__(rules, "mesh", FakeMesh())
    object.__setattr__(rules, "cfg", cfg)
    object.__setattr__(rules, "dp_axes", ("data",))
    object.__setattr__(rules, "tp_axis", "model")
    spec = _match_spec("/blocks/attn/wk", (24, 1024, 8, 64), rules)
    assert spec == P(None, None, None, None)  # kv=8 not divisible -> replicated
    spec_q = _match_spec("/blocks/attn/wq", (24, 1024, 16, 64), rules)
    assert spec_q == P(None, None, "model", None)


def test_input_specs_all_cells():
    from repro.launch.dryrun import input_specs

    for arch in ARCH_IDS:
        for shape_name in SHAPES:
            cfg = get_config(arch)
            ok, _ = cell_applicable(cfg, SHAPES[shape_name])
            if not ok:
                continue
            specs = input_specs(arch, shape_name)
            assert "tokens" in specs
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)


def test_hlo_cost_trip_count_accounting():
    def g(a, ws):
        def body(x, w):
            return jax.nn.relu(x @ w), None
        out, _ = jax.lax.scan(body, a, ws)
        return out

    c = jax.jit(g).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((10, 64, 64), jnp.float32),
    ).compile()
    hc = analyze_hlo(c.as_text(), 1)
    assert hc.flops == 10 * 2 * 64**3


def test_hlo_cost_handles_tuple_types():
    def g(a):
        def body(c, _):
            return (c[0] @ c[0], c[1] + 1), None
        (out, cnt), _ = jax.lax.scan(body, (a, jnp.zeros((), jnp.int32)), None, length=5)
        return out, cnt

    c = jax.jit(g).lower(jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    hc = analyze_hlo(c.as_text(), 1)
    assert hc.flops == 5 * 2 * 32**3
