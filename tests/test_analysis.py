"""Static contract checker: registry, linters, and mutation sensitivity.

Three layers, mirroring how the checker is built:

1. the registry — declaration semantics (idempotent re-register, loud
   conflicts, the compile-count arithmetic in assert_compile_contract);
2. the lint passes — each rule on minimal good/bad programs, including the
   one subtlety the real codebase exercised: ``random_split`` of a
   ``fold_in``-derived key inside a loop body is counter-based fan-out,
   NOT a violation;
3. the seeded mutations (repro.analysis.mutations) — every deliberately
   broken executable must be caught, or the checker is vacuously green.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import jaxpr_lint, mutations
from repro.analysis.contracts import (
    ExecutableContract,
    all_contracts,
    assert_compile_contract,
    contract_for,
    register_contract,
)
from repro.serving.batched import BatchedFusedServer
from repro.serving.degrade import LaneKnobs

from serving_fixtures import SMALL_CFG, make_small_bundle


# ------------------------------------------------------------- registry
def test_builders_register_their_contracts_on_import():
    names = set(all_contracts())
    assert {"fused", "chunk", "refill", "sharded_lanes"} <= names
    assert contract_for("fused").executables_per_bucket == 1
    assert contract_for("fused").collectives == 0
    assert contract_for("sharded_lanes").collectives == 0
    assert contract_for("chunk").while_body_flat
    assert contract_for("refill").donated


def test_reregister_identical_is_noop_conflict_raises():
    c = contract_for("fused")
    assert register_contract(c) is c  # idempotent
    evil = ExecutableContract(
        name="fused", builder=c.builder, executables_per_bucket=99
    )
    with pytest.raises(ValueError, match="conflicting contract"):
        register_contract(evil)


def test_unknown_contract_names_the_known_ones():
    with pytest.raises(KeyError, match="fused"):
        contract_for("definitely_not_registered")


class _FakeServer:
    def __init__(self, count, buckets):
        self.compile_count = count
        self.compiled_buckets = buckets


def test_assert_compile_contract_arithmetic():
    assert_compile_contract(_FakeServer(2, [128, 1024]), "fused")
    assert_compile_contract(_FakeServer(4, [128, 1024]), ("refill", "chunk"))
    with pytest.raises(AssertionError, match="'fused'"):
        assert_compile_contract(_FakeServer(3, [128, 1024]), "fused")
    with pytest.raises(AssertionError, match="refill"):
        assert_compile_contract(_FakeServer(5, [128, 1024]), ("refill", "chunk"))
    with pytest.raises(AssertionError, match="cap buckets"):
        assert_compile_contract(
            _FakeServer(2, [128, 1024]), "fused", buckets=[128, 2048]
        )


def test_server_integration_check_compile_contract():
    srv = BatchedFusedServer(make_small_bundle(), SMALL_CFG, batch_size=4)
    srv.serve_batch([{"g": 0}])
    srv.check_compile_contract(buckets=[128])
    srv._compile_count += 1  # simulate an untracked recompile
    with pytest.raises(AssertionError, match="'fused'"):
        srv.check_compile_contract()


# ------------------------------------------------------------ RNG rules
def _while_jaxpr(body, carry):
    return jax.make_jaxpr(
        lambda c: jax.lax.while_loop(lambda c: c[-1] < 8, body, c)
    )(carry)


def test_counter_based_fold_in_loop_is_clean():
    base = jax.random.PRNGKey(0)

    def body(c):
        acc, i = c
        k = jax.random.fold_in(base, i)
        return acc + jax.random.normal(k, ()), i + 1

    jaxpr = _while_jaxpr(body, (jnp.float32(0.0), jnp.int32(0)))
    assert jaxpr_lint.check_rng(jaxpr, "good/fold_in") == []


def test_split_of_fold_in_key_in_loop_is_clean():
    """Fixed fan-out of a counter-derived key: bitwise parity preserved."""
    base = jax.random.PRNGKey(0)

    def body(c):
        acc, i = c
        k1, k2 = jax.random.split(jax.random.fold_in(base, i))
        return acc + jax.random.normal(k1, ()) * jax.random.uniform(k2), i + 1

    jaxpr = _while_jaxpr(body, (jnp.float32(0.0), jnp.int32(0)))
    assert jaxpr_lint.check_rng(jaxpr, "good/fold_in_fanout") == []


def test_split_without_fold_in_is_flagged():
    def body(c):
        key, acc, i = c
        key, sub = jax.random.split(key)
        return key, acc + jax.random.normal(sub, ()), i + 1

    jaxpr = _while_jaxpr(
        body, (jax.random.PRNGKey(0), jnp.float32(0.0), jnp.int32(0))
    )
    found = jaxpr_lint.check_rng(jaxpr, "bad/split")
    assert found and all(f.contract == "rng" for f in found)


def test_typed_key_carry_is_flagged():
    def body(c):
        key, i = c
        return jax.random.fold_in(key, i), i + 1  # evolved key re-carried

    jaxpr = _while_jaxpr(body, (jax.random.key(0), jnp.int32(0)))
    found = jaxpr_lint.check_rng(jaxpr, "bad/key_carry")
    assert any("carry" in f.where for f in found)


def test_split_in_scan_without_fold_in_is_flagged():
    def step(key, _):
        key, sub = jax.random.split(key)
        return key, jax.random.normal(sub, ())

    jaxpr = jax.make_jaxpr(
        lambda k: jax.lax.scan(step, k, None, length=4)
    )(jax.random.PRNGKey(0))
    assert jaxpr_lint.check_rng(jaxpr, "bad/scan_split")


# ------------------------------------------------ host-sync and dtypes
def test_callback_in_loop_flagged_as_per_iteration():
    def body(c):
        jax.debug.print("i={i}", i=c[1])
        return c[0] + 1.0, c[1] + 1

    jaxpr = _while_jaxpr(body, (jnp.float32(0.0), jnp.int32(0)))
    found = jaxpr_lint.check_host_sync(jaxpr, "bad/debug_print")
    assert any("loop body" in f.message for f in found)


def test_traced_bool_coercion_becomes_a_finding():
    def branchy(x):
        if x > 0:  # traced-bool coercion: host sync at trace time
            return x
        return -x

    jaxpr, findings = jaxpr_lint.trace_for_lint(
        branchy, jnp.float32(1.0), executable="bad/bool"
    )
    assert jaxpr is None
    assert findings and findings[0].contract == "host_sync"


def test_weak_input_flagged_pinned_input_clean():
    f = lambda x, d: x * d  # noqa: E731
    weak = jax.make_jaxpr(f)(jnp.zeros((4,), jnp.float32), 0.5)
    found = jaxpr_lint.check_dtypes(weak, "bad/weak")
    assert found and found[0].contract == "weak_type_inputs"
    strong = jax.make_jaxpr(f)(jnp.zeros((4,), jnp.float32), np.float32(0.5))
    assert jaxpr_lint.check_dtypes(strong, "good/pinned") == []


def test_lane_knobs_are_pinned_at_construction():
    """Satellite of the same contract: LaneKnobs can never leak a weak
    scalar into a traced call, no matter what the call site does."""
    kn = LaneKnobs(delta=0.5, tau=0.95, iter_cap=64)
    assert kn.delta.dtype == np.float32
    assert kn.tau.dtype == np.float32
    assert kn.iter_cap.dtype == np.int32
    jaxpr = jax.make_jaxpr(lambda x, d: x * d)(
        jnp.zeros((2,), jnp.float32), kn.delta
    )
    assert jaxpr_lint.check_dtypes(jaxpr, "knobs") == []


# ------------------------------------------------------------ mutations
@pytest.mark.parametrize("name", sorted(mutations.MUTATIONS))
def test_seeded_mutation_is_caught(name):
    findings = mutations.MUTATIONS[name]()
    assert findings, f"checker is blind to seeded mutation {name!r}"
    for f in findings:
        # actionable: names the violated contract and where
        assert f.contract and f.message and f.executable


def test_mutation_messages_name_the_contract_field():
    by_name = {
        "injected_collective": "collectives",
        "split_rng_bootstrap": "rng",
        "dropped_donation": "donated",
        "weak_type_knob": "weak_type_inputs",
        "host_callback_in_loop": "host_sync",
        "cap_leak_in_loop_body": "while_body_flat",
    }
    for name, field in by_name.items():
        found = mutations.MUTATIONS[name]()
        assert any(f.contract == field for f in found), (name, found)
