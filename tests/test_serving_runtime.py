"""Arrival-driven serving runtime: fixed lanes, admission policy, stats.

Covers the PR-2 serving contract:

* one compiled executable per power-of-two cap bucket across batch fills
  r = 1, 3, batch_size (the fixed-lane property);
* padded-lane results identical to an exact-``r`` batch;
* sample_frac parity across serve modes (true-group-size denominator);
* empty-input guards (ServerStats.summary, straggler_report, RuntimeStats);
* the max-wait / max-size admission policy and the Poisson trace generator;
* per-request queue-delay vs execution-latency accounting.
"""
import numpy as np
import pytest

from repro.core.executor import BiathlonConfig
from repro.core.pipeline import AggFeature, Pipeline
from repro.data.store import ColumnStore, build_table
from repro.data.synthetic import PipelineBundle, poisson_arrivals
from repro.models.tabular import LinearRegression
from repro.serving import (
    AdmissionBatcher,
    BatchedFusedServer,
    BiathlonServer,
    ServerStats,
    ServingRuntime,
)
from repro.serving.batched import BatchResult, straggler_report

CFG = BiathlonConfig(m=64, m_sobol=16)


@pytest.fixture(scope="module")
def small_bundle():
    """8 groups of 120 rows + 2 groups of 900 rows, linear model."""
    rng = np.random.default_rng(0)
    sizes = [120] * 8 + [900] * 2
    gid = np.concatenate([np.full(s, g) for g, s in enumerate(sizes)])
    mu = rng.normal(0, 5, len(sizes))
    vals = mu[gid] + rng.normal(0, 2.0, len(gid))
    aux = 0.5 * mu[gid] + rng.normal(0, 1.0, len(gid))
    store = ColumnStore().add("t", build_table({"v": vals, "a": aux}, gid, seed=1))
    X = np.stack([mu, 0.5 * mu], axis=1)
    y = 3 * X[:, 0] + X[:, 1] + rng.normal(0, 0.01, len(sizes))
    pipe = Pipeline(
        name="small",
        agg_features=[
            AggFeature("avg_v", "t", "v", "avg", "g"),
            AggFeature("avg_a", "t", "a", "avg", "g"),
        ],
        exact_features=[],
        model=LinearRegression().fit(X, y),
        task="regression",
        scaler_mean=np.zeros(2, np.float32),
        scaler_scale=np.ones(2, np.float32),
        delta_default=0.5,
    )
    return PipelineBundle(
        pipeline=pipe, store=store,
        requests=[{"g": g} for g in range(len(sizes))],
        labels=y, table_rows=len(gid), name="small",
    )


@pytest.fixture(scope="module")
def server8(small_bundle):
    return BatchedFusedServer(small_bundle, CFG, batch_size=8)


# ---------------------------------------------------------------- fixed lanes
def test_one_compile_per_cap_bucket_across_fills(small_bundle):
    """Fills r=1, 3, batch_size share ONE executable per cap bucket."""
    srv = BatchedFusedServer(small_bundle, CFG, batch_size=4)
    assert srv.compile_count == 0
    r1 = srv.serve_batch([{"g": 0}])
    r3 = srv.serve_batch([{"g": 1}, {"g": 2}, {"g": 3}])
    r4 = srv.serve_batch([{"g": c} for c in range(4)])
    assert srv.compile_count == 1, "fill variation must not recompile"
    # the 1-executable-per-bucket arithmetic lives in the contract registry
    # (repro.analysis.contracts), shared with python -m repro.analysis.check
    srv.check_compile_contract(buckets=[128])
    assert r1.lanes == r3.lanes == r4.lanes == 4
    assert (r1.y_hat.shape, r3.y_hat.shape, r4.y_hat.shape) == ((1,), (3,), (4,))
    # a new cap bucket is the ONLY thing that compiles
    rb = srv.serve_batch([{"g": 8}])
    assert srv.compile_count == 2
    srv.check_compile_contract(buckets=[128, 1024])
    assert rb.cap == 1024


def test_padded_lane_results_match_unpadded(small_bundle, server8):
    """r < batch_size padded to fixed lanes == exact-r lane count."""
    reqs = [{"g": 1}, {"g": 2}, {"g": 3}]
    padded = server8.serve_batch(reqs)               # 3 active lanes of 8
    exact = BatchedFusedServer(small_bundle, CFG, batch_size=3).serve_batch(reqs)
    assert padded.lanes == 8 and exact.lanes == 3
    np.testing.assert_allclose(padded.y_hat, exact.y_hat, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(padded.iters, exact.iters)
    np.testing.assert_allclose(padded.sample_frac, exact.sample_frac, rtol=1e-7)
    np.testing.assert_allclose(padded.prob, exact.prob, rtol=1e-5, atol=1e-6)


# ------------------------------------------------------- sample_frac parity
def test_sample_frac_true_denominator_across_modes(small_bundle):
    """§4 sample fraction must be touched-rows / TRUE group rows in every
    mode, including when a max_cap ceiling clips the buffers."""
    cap = 64  # < the 120-row groups: numerator is clipped, denominator not
    batched = BatchedFusedServer(small_bundle, CFG, batch_size=2, max_cap=cap)
    fused = BiathlonServer(small_bundle, CFG, mode="fused", max_cap=cap)
    req = {"g": 4}
    rb = batched.serve_batch([req])
    rf = fused.serve(req)
    # identical executors + identical buffers -> identical fractions
    assert rb.sample_frac[0] == pytest.approx(rf["sample_frac"], rel=1e-7)
    # the fraction is measured against the true 120-row group
    assert rb.sample_frac[0] <= cap * 2 / (120 * 2) + 1e-9
    host = BiathlonServer(small_bundle, CFG, mode="host")
    rh = host.serve(req)
    assert 0.0 < rh["sample_frac"] <= 1.0  # same true-size denominator scale


# ----------------------------------------------------------- empty guards
def test_server_stats_summary_empty():
    s = ServerStats().summary(delta=0.5, task="regression")
    assert s["n"] == 0
    assert s["speedup"] == 0.0
    assert np.isnan(s["mean_latency_s"])
    assert np.isnan(s["p95_latency_s"])


def test_straggler_report_empty_and_padded(server8):
    empty = BatchResult(
        y_hat=np.zeros((0,), np.float32), prob=np.zeros((0,), np.float32),
        iters=np.zeros((0,), np.int32), sample_frac=np.zeros((0,), np.float32),
        batch_iters=0, cap=0, lanes=8,
    )
    rep = straggler_report(empty)
    assert rep["batch_iters"] == 0
    assert rep["straggler"] == -1
    assert rep["wasted_frac"] == 0.0
    assert rep["fill"] == 0.0
    # unsharded results still carry the per-device fields (trivially)
    assert rep["n_devices"] == 1
    assert rep["per_device_fill"] == pytest.approx([0.0])
    assert rep["lane_imbalance"] == 0.0

    res = server8.serve_batch([{"g": 5}, {"g": 6}, {"g": 8}])
    rep = straggler_report(res)
    assert len(rep["per_request_iters"]) == 3   # active lanes only
    assert rep["lanes"] == 8
    assert rep["fill"] == pytest.approx(3 / 8)
    assert (rep["wasted_iters"] >= 0).all()
    assert rep["straggler"] == int(np.argmax(res.iters))


def test_serve_batch_empty(server8):
    res = server8.serve_batch([])
    assert res.y_hat.shape == (0,)
    assert res.batch_iters == 0


def test_serve_batch_rejects_oversize(server8):
    """> batch_size would compile per distinct oversize fill — refuse it."""
    reqs = [{"g": i % 4} for i in range(server8.batch_size + 1)]
    with pytest.raises(ValueError, match="fixed lane count"):
        server8.serve_batch(reqs)


# ------------------------------------------------------------ admission policy
def test_admission_batcher_policy():
    b = AdmissionBatcher(max_size=4, max_wait_s=0.02)
    assert not b.ready(0, 0.0, more_coming=True)      # empty never admits
    assert not b.ready(2, 0.001, more_coming=True)    # partial, fresh, waiting
    assert b.ready(4, 0.0, more_coming=True)          # full batch
    assert b.ready(1, 0.02, more_coming=True)         # max-wait expired
    assert b.ready(1, 0.02 - 1e-12, more_coming=True)  # fp-tolerant deadline
    assert b.ready(1, 0.0, more_coming=False)         # drained trace flushes
    with pytest.raises(ValueError):
        AdmissionBatcher(0, 0.01)
    with pytest.raises(ValueError):
        AdmissionBatcher(4, -1.0)


def test_admission_batcher_eps_absorbs_clock_roundoff():
    """Direct regression for the ``_EPS`` livelock fix: the runtime idles to
    ``t_oldest + max_wait_s`` and recomputes ``now - t_oldest``, which in
    binary floating point can land just UNDER max_wait_s.  Without the
    epsilon that state admits nothing and the virtual clock never advances.
    """
    t_oldest, max_wait = 0.7, 0.1
    now = t_oldest + max_wait          # 0.7999999999999999
    wait = now - t_oldest              # 0.09999999999999987 < 0.1 (!)
    assert wait < max_wait, "precondition: roundoff actually bites here"
    b = AdmissionBatcher(max_size=8, max_wait_s=max_wait)
    assert b.ready(1, wait, more_coming=True)
    # and the epsilon is a roundoff tolerance, not an early-admit loophole
    assert not b.ready(1, max_wait / 2, more_coming=True)


def test_poisson_arrivals_guards():
    """rate <= 0 / non-finite rate / negative n fail LOUDLY; n == 0 and an
    empty request list are well-defined empty traces."""
    reqs = [{"g": 0}]
    for bad_rate in (0.0, -1.0, float("nan"), float("inf")):
        with pytest.raises(ValueError, match="rate_rps"):
            poisson_arrivals(reqs, rate_rps=bad_rate, n=4)
    with pytest.raises(ValueError, match="n must"):
        poisson_arrivals(reqs, rate_rps=5.0, n=-1)
    assert poisson_arrivals(reqs, rate_rps=5.0, n=0) == []
    assert poisson_arrivals([], rate_rps=5.0, n=10) == []


def test_poisson_arrivals_deterministic_and_sorted(small_bundle):
    reqs = small_bundle.requests[:3]
    a1 = poisson_arrivals(reqs, rate_rps=100.0, n=50, seed=7)
    a2 = poisson_arrivals(reqs, rate_rps=100.0, n=50, seed=7)
    assert a1 == a2
    ts = [t for t, _ in a1]
    assert ts == sorted(ts) and ts[0] > 0.0
    assert len(a1) == 50
    assert [r for _, r in a1[:4]] == [reqs[0], reqs[1], reqs[2], reqs[0]]
    # mean gap ~ 1/rate (loose: 50 samples)
    gaps = np.diff([0.0] + ts)
    assert 0.3 / 100 < gaps.mean() < 3.0 / 100
    with pytest.raises(ValueError):
        poisson_arrivals(reqs, rate_rps=0.0)
    assert poisson_arrivals([], rate_rps=5.0) == []


# ------------------------------------------------------------ runtime loop
def test_runtime_serves_all_and_accounts_delay(small_bundle, server8):
    runtime = ServingRuntime(server8, max_wait_s=0.01)
    arrivals = poisson_arrivals(small_bundle.requests, rate_rps=300.0, n=16, seed=3)
    stats = runtime.run(arrivals)
    assert len(stats.records) == 16
    # after warmup, fill variation must not compile anything new
    assert stats.compile_count == 0
    for rec in stats.records:
        assert rec.queue_delay_s >= 0.0
        assert rec.exec_s > 0.0
        assert rec.latency_s == pytest.approx(
            rec.queue_delay_s + (rec.done_t - rec.admit_t), abs=1e-9
        )
        assert 1 <= rec.batch_fill <= server8.batch_size
        assert np.isfinite(rec.y_hat)
    s = stats.summary()
    assert s["n"] == 16
    assert s["throughput_rps"] > 0
    assert s["n_batches"] == len({r.batch_id for r in stats.records})
    assert s["p99_latency_ms"] >= s["p50_latency_ms"] > 0
    assert 0 < s["mean_batch_fill"] <= server8.batch_size
    # single-device run: n_devices reported, per-device split omitted
    assert s["n_devices"] == 1
    assert "per_device_fill" not in s

    # empty trace: well-defined zeros, no crash
    empty = ServingRuntime(server8).run([])
    assert empty.summary()["n"] == 0


def test_runtime_max_batch_respects_lanes(server8):
    with pytest.raises(ValueError):
        ServingRuntime(server8, max_batch=server8.batch_size + 1)
    rt = ServingRuntime(server8, max_wait_s=0.0, max_batch=2)
    arrivals = [(0.001 * i, {"g": i % 4}) for i in range(6)]
    stats = rt.run(arrivals)
    assert all(r.batch_fill <= 2 for r in stats.records)
