"""Holistic (MEDIAN/QUANTILE) aggregates in the fused serving paths.

Covers the PR-3 tentpole: host-vs-fused parity on median/quantile pipelines
(regression + classification), the z == 0 edge inside the fused program, the
Fig. 10 ``approximate=False`` exactness knob across all three serving modes,
and the arrival-driven runtime over a holistic pipeline.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.executor import BiathlonConfig, run_exact
from repro.core.executor_fused import (
    build_fused_executor,
    pipeline_executor_kwargs,
)
from repro.core.pipeline import AggFeature, Pipeline
from repro.data.store import ColumnStore, build_table
from repro.data.synthetic import (
    PipelineBundle,
    make_pipeline,
    make_pipeline_median,
    poisson_arrivals,
)
from repro.models.tabular import LinearRegression
from repro.serving import BatchedFusedServer, BiathlonServer, ServingRuntime

SMALL = dict(rows_per_group=1200, n_train_groups=100, n_serve_groups=5, n_requests=4)
CFG = BiathlonConfig(m=192, m_sobol=48, n_bootstrap=128)


# ------------------------------------------------------- host-vs-fused parity
@pytest.mark.parametrize(
    "name,median",
    [("turbofan", True), ("bearing_imbalance", True), ("sensor_health", False)],
)
def test_fused_vs_host_parity_holistic(name, median):
    """MEDIAN/QUANTILE pipelines run the fused path end to end (no
    ValueError) and land within tolerance of the host loop and the exact
    baseline, at the same guarantee."""
    b = (make_pipeline_median if median else make_pipeline)(name, **SMALL)
    assert any(
        f.agg in ("median", "quantile") for f in b.pipeline.agg_features
    )
    host = BiathlonServer(b, CFG, mode="host")
    fused = BiathlonServer(b, CFG, mode="fused")
    delta = b.pipeline.delta_default
    tol = 2 * delta + 1e-6 if b.pipeline.task == "regression" else 0.5
    agree = 0
    reqs = b.requests[:4]
    for i, req in enumerate(reqs):
        rh = host.serve(req, jax.random.PRNGKey(i))
        rf = fused.serve(req)
        assert rh["prob"] >= CFG.tau or rh["sample_frac"] >= 0.999
        assert rf["prob"] >= CFG.tau or rf["sample_frac"] >= 0.999
        y_ex, _ = run_exact(b.store, b.pipeline, req)
        if b.pipeline.task == "regression":
            if (
                abs(rf["y_hat"] - rh["y_hat"]) <= tol
                and abs(rf["y_hat"] - y_ex) <= delta + 1e-6
            ):
                agree += 1
        else:
            if rf["y_hat"] == rh["y_hat"] == y_ex:
                agree += 1
    # tau=0.95 per request; allow one miss across paths on a small log
    assert agree >= len(reqs) - 1


def test_batched_fused_serves_holistic():
    """BatchedFusedServer admits a MEDIAN pipeline and matches the
    single-request fused path on the same buffers."""
    b = make_pipeline_median("turbofan", **SMALL)
    srv = BatchedFusedServer(b, CFG, batch_size=4)
    fused = BiathlonServer(b, CFG, mode="fused")
    res = srv.serve_batch(b.requests[:3])
    assert np.isfinite(res.y_hat).all()
    assert ((res.prob >= CFG.tau) | (res.sample_frac >= 0.999)).all()
    for lane, req in enumerate(b.requests[:3]):
        rf = fused.serve(req)
        # same compiled algorithm over the same gathered buffers
        assert res.y_hat[lane] == pytest.approx(rf["y_hat"], rel=1e-5, abs=1e-5)


def test_runtime_serves_holistic_arrivals():
    """The arrival-driven runtime drains a Poisson trace over a holistic
    pipeline — the fastest path now covers appendix-D operators."""
    b = make_pipeline_median("tick_price", **SMALL)
    srv = BatchedFusedServer(b, CFG, batch_size=4)
    runtime = ServingRuntime(srv, max_wait_s=0.005)
    stats = runtime.run(poisson_arrivals(b.requests, 200.0, n=6, seed=1))
    s = stats.summary()
    assert s["n"] == 6
    assert s["guarantee_rate"] == 1.0


# -------------------------------------------------------------- z == 0 edge
def test_fused_holistic_empty_group():
    """A holistic feature over an empty group must keep the fused program
    finite (value 0 by the empty-prefix convention, degenerate replicates)."""
    w = jnp.asarray([1.5, 1.0])

    def model_fn(rows, exact):
        return rows @ w

    fused = build_fused_executor(
        model_fn, k=2, task="regression", m=64, m_sobol=16,
        holistic=(1,), quantiles=(0.5,), n_boot=32, max_iters=4,
    )
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.normal(3.0, 1.0, (2, 128)).astype(np.float32))
    n = jnp.asarray([128, 0], jnp.int32)
    res = fused(
        vals, n, jnp.asarray([0, 5], jnp.int32),
        jnp.asarray(0.5, jnp.float32), jnp.zeros((0,), jnp.float32),
    )
    assert np.isfinite(float(res.y_hat))
    assert np.isfinite(float(res.prob))
    # the empty feature is exhausted immediately (z = n = 0)
    assert int(res.z[1]) == 0


# --------------------------------------------------- Fig. 10 exactness knob
@pytest.fixture(scope="module")
def exactness_bundle():
    """2-feature linear pipeline; feature 1 is declared exact-only."""
    rng = np.random.default_rng(3)
    sizes = [400] * 6
    gid = np.concatenate([np.full(s, g) for g, s in enumerate(sizes)])
    mu = rng.normal(0, 4, len(sizes))
    v = mu[gid] + rng.normal(0, 2.0, len(gid))
    a = 0.5 * mu[gid] + rng.normal(0, 1.5, len(gid))
    store = ColumnStore().add("t", build_table({"v": v, "a": a}, gid, seed=2))
    X = np.stack([mu, 0.5 * mu], axis=1)
    y = 2 * X[:, 0] + 3 * X[:, 1]
    pipe = Pipeline(
        name="exactness",
        agg_features=[
            AggFeature("avg_v", "t", "v", "avg", "g"),
            AggFeature("med_a", "t", "a", "median", "g", approximate=False),
        ],
        exact_features=[],
        model=LinearRegression().fit(X, y),
        task="regression",
        scaler_mean=np.zeros(2, np.float32),
        scaler_scale=np.ones(2, np.float32),
        delta_default=1.0,
    )
    return PipelineBundle(
        pipeline=pipe, store=store, requests=[{"g": g} for g in range(6)],
        labels=y, table_rows=len(gid), name="exactness",
    )


def test_pipeline_executor_kwargs(exactness_bundle):
    kw = pipeline_executor_kwargs(exactness_bundle.pipeline.agg_features)
    assert kw["holistic"] == (1,)
    assert kw["quantiles"] == (0.5,)
    assert kw["approximate"] == (True, False)
    assert list(np.asarray(kw["agg_ids"])) == [0, 5]

    class _Fake:
        agg = "p99"
        approximate = True
        quantile = 0.5

    with pytest.raises(ValueError, match="unsupported"):
        pipeline_executor_kwargs([_Fake()])


def test_approximate_false_stays_exact_all_modes(exactness_bundle):
    """The Fig. 10 knob: a feature declared approximate=False must consume
    its full group (z == n) in host, fused, and batched serving — previously
    both fused paths silently approximated it."""
    b = exactness_bundle
    cfg = BiathlonConfig(m=96, m_sobol=32, n_bootstrap=64)
    req = b.requests[0]
    n = b.pipeline.group_sizes(b.store, req)

    host = BiathlonServer(b, cfg, mode="host").serve(req)
    assert host["z"][1] == n[1]

    fused = BiathlonServer(b, cfg, mode="fused").serve(req)
    assert fused["z"][1] == fused["n"][1]

    batched = BatchedFusedServer(b, cfg, batch_size=2)
    res = batched.serve_batch([req, b.requests[1]])
    assert (res.z[:, 1] == np.minimum(n[1], res.cap)).all()
    # the approximable feature is NOT forced exact by the knob
    assert res.z[0, 0] <= n[0]
