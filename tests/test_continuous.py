"""Continuous batching: chunked executor + lane recycling — the PR-7 contract.

Covers, in order:

* chunked-vs-monolithic executor parity: with ``chunk_iters >= max_iters``
  one chunk IS the monolithic while_loop (bitwise z-plans and iteration
  counts), and small chunks dispatched back-to-back replay the same
  sequence — on a synthetic parametric executor AND on real parametric
  (turbofan) / holistic (sensor_health) pipelines through the servers;
* recycling-vs-serial-replay parity: a saturating trace through the
  lane-table scheduler, with lanes recycled mid-trace, yields per-request
  z/iters/predictions identical to serving each request alone — the
  counter-based bootstrap RNG makes trajectories lane-placement-free;
* the continuous compile contract: exactly TWO executables (refill +
  chunk) per power-of-two cap bucket, across fills, admission patterns and
  repeat runs;
* ``chunked_straggler_report``: empty-safe, device-block waste accounting,
  occupancy-true per-device fill with recycled (partially occupied) lanes;
* mesh parity: the shard_map lane table matches the unsharded one.

CI runs this file under both ``REPRO_AFC_BACKEND`` legs with 8 forced host
devices (the ``continuous`` job), so the multi-device parity test is cheap
there; locally it skips when only one device is visible.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.executor import BiathlonConfig
from repro.core.executor_fused import (
    LaneState,
    build_chunked_executor,
    build_fused_executor,
)
from repro.data.synthetic import make_pipeline
from repro.launch.mesh import make_serving_mesh
from repro.serving import (
    BatchedFusedServer,
    ContinuousBatchedServer,
    ContinuousServingRuntime,
    ServingRuntime,
    chunked_straggler_report,
)

from serving_fixtures import SMALL_CFG, make_small_bundle

CFG = BiathlonConfig(m=64, m_sobol=16, n_bootstrap=32)
SMALL = dict(rows_per_group=300, n_train_groups=30, n_serve_groups=4, n_requests=6)


# ------------------------------------------- executor-level chunk parity
def _lane_inputs(k, cap, seed):
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.normal(2.0, 3.0, (k, cap)).astype(np.float32))
    n = jnp.asarray(rng.integers(cap // 2, cap + 1, k), jnp.int32)
    return vals, n


def _drain(chunk, state, max_dispatches=64):
    """Dispatch chunks until the lane reports done; count dispatches."""
    d = 0
    while not bool(state.done):
        state = chunk(state)
        d += 1
        assert d <= max_dispatches, "chunked executor failed to converge"
    return state, d


@pytest.mark.parametrize("chunk_iters", [1, 2, 16])
def test_chunked_matches_monolithic_synthetic(chunk_iters):
    """Bitwise z/iters parity between the monolithic while_loop and the
    chunked executor, at chunk_iters below / at the max_iters bound."""
    k, cap, max_iters = 3, 256, 16
    w = jnp.asarray([1.0, -2.0, 0.5])
    kwargs = dict(
        k=k, task="regression", m=32, m_sobol=8, max_iters=max_iters,
        gamma=0.02, n_boot=16,
    )
    mono = build_fused_executor(lambda rows, exact: rows @ w, **kwargs)
    init, chunk = build_chunked_executor(
        lambda rows, exact: rows @ w, chunk_iters=chunk_iters, **kwargs
    )
    init, chunk = jax.jit(init), jax.jit(chunk)
    agg_ids = jnp.zeros((k,), jnp.int32)
    exact = jnp.zeros((0,), jnp.float32)
    delta = jnp.asarray(0.3, jnp.float32)
    for seed in range(4):
        vals, n = _lane_inputs(k, cap, seed)
        want = mono(vals, n, agg_ids, delta, exact)
        state = init(vals, n, agg_ids, delta, exact,
                     jnp.asarray(True), jnp.asarray(0.95, jnp.float32),
                     jnp.asarray(max_iters, jnp.int32))
        state, dispatches = _drain(chunk, state)
        np.testing.assert_array_equal(np.asarray(state.z), np.asarray(want.z))
        assert int(state.it) == int(want.iters)
        assert float(state.y_hat) == float(want.y_hat)
        assert float(state.prob) == float(want.prob)
        if chunk_iters >= max_iters:
            assert dispatches <= 1, "one chunk must BE the monolithic loop"
        else:
            assert dispatches >= -(-int(want.iters) // chunk_iters)


def test_chunked_inactive_lane_is_inert():
    """active=False forces done at init with zero iterations — the empty
    lane-table invariant new_table relies on."""
    k, cap = 2, 128
    w = jnp.asarray([1.0, 1.0])
    init, chunk = build_chunked_executor(
        lambda rows, exact: rows @ w, chunk_iters=2,
        k=k, task="regression", m=16, m_sobol=8, max_iters=8,
    )
    vals, n = _lane_inputs(k, cap, 0)
    state = jax.jit(init)(
        vals, n, jnp.zeros((k,), jnp.int32), jnp.asarray(0.3, jnp.float32),
        jnp.zeros((0,), jnp.float32), jnp.asarray(False),
        jnp.asarray(0.95, jnp.float32), jnp.asarray(8, jnp.int32),
    )
    assert bool(state.done) and int(state.it) == 0
    state = jax.jit(chunk)(state)
    assert bool(state.done) and int(state.it) == 0


def test_build_chunked_executor_validates_chunk_iters():
    with pytest.raises(ValueError, match="chunk_iters"):
        build_chunked_executor(
            lambda rows, exact: rows, chunk_iters=0, k=1, task="regression"
        )


# --------------------------------------- pipeline-level chunk parity
def _drain_table(srv, table, max_dispatches=200):
    chunks = 0
    out = srv.readback(table)
    while not out["done"].all():
        table = srv.run_chunk(table)
        out = srv.readback(table)
        chunks += 1
        assert chunks <= max_dispatches
    return table, out


@pytest.mark.parametrize("pipeline", ["turbofan", "sensor_health"])
@pytest.mark.parametrize("chunk_iters", [2, 64])
def test_pipeline_chunked_matches_fixed_lane(pipeline, chunk_iters):
    """Admitting a whole batch into the lane table and draining it matches
    BatchedFusedServer.serve_batch bitwise (z, iters) and exactly on
    predictions — parametric AND holistic pipelines, chunk_iters both far
    below and at/above max_iters (64 >= default max_iters)."""
    b = make_pipeline(pipeline, **SMALL)
    reqs = b.requests[:4]
    fixed = BatchedFusedServer(b, CFG, batch_size=len(reqs))
    want = fixed.serve_batch(reqs)

    srv = ContinuousBatchedServer(
        b, CFG, batch_size=len(reqs), chunk_iters=chunk_iters
    )
    cap = srv.trace_cap(reqs)
    assert cap == want.cap, "parity needs both paths at the same cap bucket"
    table, _ = srv.admit(
        srv.new_table(cap), cap, [(i, r, None) for i, r in enumerate(reqs)]
    )
    table, out = _drain_table(srv, table)
    np.testing.assert_array_equal(out["z"], np.asarray(want.z))
    np.testing.assert_array_equal(out["it"], np.asarray(want.iters))
    np.testing.assert_array_equal(out["y_hat"], np.asarray(want.y_hat))
    np.testing.assert_array_equal(out["prob"], np.asarray(want.prob))


# ------------------------------------- recycling vs serial replay parity
def test_recycling_matches_serial_replay():
    """The acceptance bitwise-parity relation: a saturating trace served
    WITH lane recycling produces, per request, the same z-plan, iteration
    count and prediction as serving that request alone.  Single cap bucket
    (groups 0..7 = 128) so the serial replay traces identical shapes."""
    b = make_small_bundle()
    reqs = [{"g": g} for g in range(8)]
    from repro.data.synthetic import poisson_arrivals

    arrivals = poisson_arrivals(reqs, 500.0, n=20, seed=13)
    srv = ContinuousBatchedServer(b, SMALL_CFG, batch_size=2, chunk_iters=2)
    stats = ContinuousServingRuntime(srv).run(arrivals)
    s = stats.summary()
    assert s["n"] == 20
    assert s["n_recycles"] > 0, "trace did not exercise recycling"
    assert s["compile_count"] == 0

    serial = BatchedFusedServer(b, SMALL_CFG, batch_size=1)
    for rec in stats.records:
        res = serial.serve_batch([arrivals[rec.req_id][1]])
        # integer plans are the bitwise contract; predictions fp-close only
        # (vmap width 1 vs 2 may re-associate the replicate reductions)
        assert rec.z == tuple(int(x) for x in res.z[0]), rec.req_id
        assert rec.iters == int(res.iters[0])
        scale = max(abs(float(res.y_hat[0])), 1.0)
        assert abs(rec.y_hat - float(res.y_hat[0])) <= 1e-5 * scale
        assert abs(rec.prob - float(res.prob[0])) <= 1e-5


# --------------------------------------------- continuous compile contract
def test_compile_count_two_per_bucket_across_fills():
    """Exactly refill + chunk per cap bucket: partial admits, full admits,
    repeated chunks and a second trace through the same table never mint a
    third executable; a NEW cap bucket mints exactly two more."""
    b = make_small_bundle()
    srv = ContinuousBatchedServer(b, SMALL_CFG, batch_size=4, chunk_iters=3)
    assert srv.compile_count == 0
    table = srv.new_table(128)
    assert srv.compile_count == 0, "new_table must not compile"
    table, _ = srv.admit(table, 128, [(0, {"g": 0}, None)])
    table, _ = _drain_table(srv, table)
    assert (srv.refill_compiles, srv.chunk_compiles) == (1, 1)
    # fill variation, lane reuse, different assignment patterns: no compile
    table, _ = srv.admit(
        table, 128, [(i, {"g": i}, None) for i in (0, 2, 3)]
    )
    table, _ = _drain_table(srv, table)
    table, _ = srv.admit(table, 128, [(1, {"g": 5}, None)])
    table, _ = _drain_table(srv, table)
    assert srv.compile_count == 2
    # the refill+chunk 2-per-bucket arithmetic lives in the contract registry
    # (repro.analysis.contracts), shared with python -m repro.analysis.check
    srv.check_compile_contract(buckets=[128])
    # a new cap bucket is the ONLY compile trigger: two more executables
    big = srv.new_table(1024)
    big, _ = srv.admit(big, 1024, [(0, {"g": 8}, None)])
    _drain_table(srv, big)
    srv.check_compile_contract(buckets=[128, 1024])
    assert srv.refill_compiles == srv.chunk_compiles == 2


def test_admit_validation():
    b = make_small_bundle()
    srv = ContinuousBatchedServer(b, SMALL_CFG, batch_size=2, chunk_iters=2)
    table = srv.new_table(128)
    with pytest.raises(ValueError, match="lane"):
        srv.admit(table, 128, [(2, {"g": 0}, None)])
    with pytest.raises(ValueError, match="twice"):
        srv.admit(table, 128, [(0, {"g": 0}, None), (0, {"g": 1}, None)])
    with pytest.raises(ValueError, match="cap"):
        srv.admit(table, 128, [(0, {"g": 8}, None)])  # 900-row group


# ------------------------------------------- chunk-boundary accounting
def test_chunked_straggler_report_empty():
    rep = chunked_straggler_report(
        np.zeros((0, 4), np.int64), np.zeros((0, 4), bool), lanes=4,
        n_devices=2,
    )
    assert rep["n_chunks"] == 0
    assert rep["lane_occupancy"] == 0.0
    assert rep["wasted_frac"] == 0.0
    assert rep["per_device_fill"] == pytest.approx([0.0, 0.0])
    assert rep["lane_imbalance"] == 0.0


def test_chunked_straggler_report_device_blocks():
    """Waste is charged against the lane's own device-block max PER CHUNK,
    and empty lanes are neither charged nor counted as fill."""
    iters = np.array([[3, 1, 2, 2],     # dev0 max 3, dev1 max 2
                      [0, 2, 4, 0]])    # dev0 max 2, dev1 max 4
    occ = np.array([[True, True, True, True],
                    [False, True, True, True]])
    rep = chunked_straggler_report(iters, occ, lanes=4, n_devices=2)
    assert rep["n_chunks"] == 2
    assert rep["lane_occupancy"] == pytest.approx(7 / 8)
    # chunk 0 waste: [0, 2, 0, 0]; chunk 1: [-, 0, 0, 4] (lane 0 empty)
    np.testing.assert_array_equal(rep["wasted_iters"], [0, 2, 0, 4])
    assert rep["wasted_frac"] == pytest.approx(6 / (3 + 3 + 2 + 2 + 2 + 4 + 4))
    # occupancy-true per-device fill: dev0 saw 3/4 occupied lane-chunks
    assert rep["per_device_fill"] == pytest.approx([3 / 4, 1.0])
    assert rep["lane_imbalance"] == pytest.approx(0.25)
    assert rep["total_iters"] == 14


def test_chunked_straggler_report_validates_alignment():
    with pytest.raises(ValueError):
        chunked_straggler_report(
            np.zeros((2, 3), np.int64), np.zeros((2, 3), bool), lanes=4
        )


# ----------------------------------------------------------- mesh parity
def _table_trace(srv, reqs):
    cap = srv.trace_cap(reqs)
    table, _ = srv.admit(
        srv.new_table(cap), cap, [(i, r, None) for i, r in enumerate(reqs)]
    )
    # recycle lane 0 mid-trace to exercise the per-device swap path
    table = srv.run_chunk(table)
    table, _ = srv.admit(table, cap, [(0, reqs[-1], None)])
    table, out = _drain_table(srv, table)
    return out


def test_mesh_table_matches_unsharded():
    """A 1-device mesh exercises the full shard_map refill/chunk path and
    must match the plain vmapped table bitwise."""
    b = make_small_bundle()
    reqs = [{"g": g} for g in range(4)]
    base = ContinuousBatchedServer(b, SMALL_CFG, batch_size=4, chunk_iters=2)
    mesh = ContinuousBatchedServer(
        b, SMALL_CFG, batch_size=4, chunk_iters=2, mesh=make_serving_mesh(1)
    )
    assert mesh.n_devices == 1
    ob, om = _table_trace(base, reqs), _table_trace(mesh, reqs)
    for key in ("z", "it", "y_hat", "prob", "done"):
        np.testing.assert_array_equal(ob[key], om[key])
    mesh.check_compile_contract()


@pytest.mark.skipif(
    len(jax.devices()) < 2, reason="needs >= 2 devices (CI forces 8)"
)
def test_mesh_table_matches_unsharded_multidevice():
    """Same trace, lanes partitioned over 2 devices: identical results —
    the collective-free per-device lane swap contract."""
    b = make_small_bundle()
    reqs = [{"g": g} for g in range(4)]
    base = ContinuousBatchedServer(b, SMALL_CFG, batch_size=4, chunk_iters=2)
    mesh = ContinuousBatchedServer(
        b, SMALL_CFG, batch_size=4, chunk_iters=2, mesh=make_serving_mesh(2)
    )
    assert mesh.n_devices == 2
    ob, om = _table_trace(base, reqs), _table_trace(mesh, reqs)
    for key in ("z", "it"):
        np.testing.assert_array_equal(ob[key], om[key])
    np.testing.assert_allclose(ob["y_hat"], om["y_hat"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ob["prob"], om["prob"], rtol=1e-5, atol=1e-6)


# ------------------------------------------------- runtime summary surface
def test_continuous_runtime_summary_keys():
    b = make_small_bundle()
    from repro.data.synthetic import poisson_arrivals

    reqs = [{"g": g} for g in range(8)]
    arrivals = poisson_arrivals(reqs, 300.0, n=6, seed=3)
    srv = ContinuousBatchedServer(b, SMALL_CFG, batch_size=2, chunk_iters=2)
    rt = ContinuousServingRuntime(srv)
    s = rt.run(arrivals).summary()
    for key in ("n_chunks", "n_recycles", "lane_occupancy",
                "chunk_wasted_frac"):
        assert key in s, key
    assert s["n"] == 6
    assert 0.0 < s["lane_occupancy"] <= 1.0
    assert s["compile_count"] == 0  # warmup owns both executables
    # fixed-lane runs must NOT grow the new keys
    fixed = BatchedFusedServer(b, SMALL_CFG, batch_size=2)
    sf = ServingRuntime(fixed).run(arrivals).summary()
    assert "n_chunks" not in sf and "lane_occupancy" not in sf
