"""Per-architecture smoke tests (reduced configs, brief deliverable f).

Every assigned architecture instantiates at REDUCED scale and runs one
forward/train step on CPU asserting output shapes + no NaNs; the serving
path (prefill -> decode) is exercised too, plus prefill/decode consistency
and chunked-vs-recurrent SSM equivalence — the invariants the full-scale
dry-run cells rely on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.lm import LM

B, S = 2, 64


def _batch(cfg, key):
    s_text = S - cfg.n_frontend_tokens if cfg.family == "vlm" else S
    batch = {"tokens": jax.random.randint(key, (B, s_text + 1), 0, cfg.vocab)}
    if cfg.frontend:
        batch["frontend"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    model = LM(cfg, remat=False, attn_block=64, loss_chunk=32)
    params = model.init(jax.random.PRNGKey(0))
    loss, metrics = jax.jit(model.train_loss)(params, _batch(cfg, jax.random.PRNGKey(1)))
    assert np.isfinite(float(loss))
    assert 0.0 <= float(metrics["acc"]) <= 1.0
    # loss should be near ln(vocab) at init
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.5


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serve_smoke(arch):
    cfg = get_config(arch).reduced()
    model = LM(cfg, remat=False, attn_block=64, loss_chunk=32)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    fe = (
        jax.random.normal(key, (B, cfg.n_frontend_tokens, cfg.d_model))
        if cfg.frontend
        else None
    )
    s_text = S - cfg.n_frontend_tokens if cfg.family == "vlm" else S
    tokens = jax.random.randint(key, (B, s_text), 0, cfg.vocab)
    logits, cache = model.prefill(params, tokens, fe) if fe is not None else model.prefill(params, tokens)
    assert logits.shape == (B, model.vp)
    assert np.isfinite(np.asarray(logits)).all()
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = model.decode_step(params, cache, nxt)
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "zamba2-2.7b", "xlstm-1.3b"])
def test_prefill_decode_consistency(arch):
    """decode(prefill(t[:-1]), t[-1]) must match prefill(t) logits."""
    cfg = get_config(arch).reduced()
    model = LM(cfg, remat=False, attn_block=64, loss_chunk=32)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    full_logits, _ = model.prefill(params, tokens)
    part_logits, cache = model.prefill(params, tokens[:, : S - 1])
    step_logits, _ = model.decode_step(params, cache, tokens[:, S - 1 :])
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32),
        np.asarray(full_logits, np.float32),
        rtol=5e-2, atol=5e-1,  # bf16 path: decode recurrence vs chunked scan
    )


def test_decode_capacity_guard():
    """Decoding past the cache's reserved headroom must raise, not clamp.

    dynamic_update_slice clamps out-of-range starts onto the newest cached
    slot — the silent corruption behind the old qwen prefill/decode
    inconsistency.  The eager decode path now refuses the write instead.
    """
    cfg = get_config("qwen1.5-0.5b").reduced()
    model = LM(cfg, remat=False, attn_block=64, loss_chunk=32)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, 16), 0, cfg.vocab)
    _, cache = model.prefill(params, tokens, max_seq=16)  # zero headroom
    with pytest.raises(ValueError, match="cache exhausted"):
        model.decode_step(params, cache, tokens[:, :1])
    # two reserved slots: two decode steps succeed, the third refuses
    _, cache = model.prefill(params, tokens, max_seq=18)
    _, cache = model.decode_step(params, cache, tokens[:, :1])
    _, cache = model.decode_step(params, cache, tokens[:, :1])
    with pytest.raises(ValueError, match="cache exhausted"):
        model.decode_step(params, cache, tokens[:, :1])


def test_mamba2_chunked_equals_recurrent():
    """Chunked SSD scan == token-by-token recurrence (zamba2 decode)."""
    from repro.models.lm import ssm as ssm_lib

    cfg = get_config("zamba2-2.7b").reduced()
    key = jax.random.PRNGKey(0)
    p = ssm_lib.init_mamba2(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model), jnp.float32) * 0.1
    full = ssm_lib.mamba2_block(p, x, cfg)
    s = cfg.ssm
    di = s.expand * cfg.d_model
    h = di // s.head_dim
    conv = jnp.zeros((1, s.d_conv - 1, di + 2 * s.d_state), jnp.float32)
    state = jnp.zeros((1, h, s.d_state, s.head_dim), jnp.float32)
    outs = []
    for t in range(32):
        o, conv, state = ssm_lib.mamba2_decode(p, x[:, t : t + 1], conv, state, cfg)
        outs.append(o)
    rec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(rec), rtol=2e-3, atol=2e-3)


def test_mlstm_chunked_equals_recurrent():
    from repro.models.lm import ssm as ssm_lib

    cfg = get_config("xlstm-1.3b").reduced()
    p = ssm_lib.init_mlstm(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model), jnp.float32) * 0.1
    full = ssm_lib.mlstm_block(p, x, cfg)
    s = cfg.ssm
    di = s.expand * cfg.d_model
    P = di // cfg.n_heads
    state = (
        jnp.zeros((1, cfg.n_heads, P, P), jnp.float32),
        jnp.zeros((1, cfg.n_heads, P), jnp.float32),
        jnp.full((1, cfg.n_heads), -1e30, jnp.float32),
    )
    outs = []
    for t in range(32):
        o, state = ssm_lib.mlstm_decode(p, x[:, t : t + 1], state, cfg)
        outs.append(o)
    rec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(rec), rtol=3e-3, atol=3e-3)


def test_padded_heads_exactness():
    """Zero-padded q heads must not change the logical model output."""
    import dataclasses

    from repro.models.lm.layers import attention_block, init_attention

    cfg = get_config("qwen3-14b").reduced()  # 4 heads, 2 kv heads (gq = 2)
    cfg_nopad = dataclasses.replace(cfg, pad_heads_to=1)
    key = jax.random.PRNGKey(0)
    p = init_attention(key, cfg_nopad, jnp.float32)
    # manually zero-pad 4 heads -> 8 PER KV GROUP: group j's live heads move
    # to slots [j*gq_p, j*gq_p + gq) so the GQA mapping is preserved.
    d, h, hd = p["wq"].shape
    hkv, gq, gq_p = 2, 2, 4
    idx = jnp.asarray([0, 1, 4, 5])
    wq = jnp.zeros((d, 8, hd)).at[:, idx].set(p["wq"])
    wo = jnp.zeros((8, hd, d)).at[idx].set(p["wo"])
    p_pad = dict(p, wq=wq, wo=wo)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model), jnp.float32)
    out_nopad = attention_block(p, x, cfg_nopad, block=64)
    out_pad = attention_block(p_pad, x, cfg_nopad, block=64)
    np.testing.assert_allclose(
        np.asarray(out_pad), np.asarray(out_nopad), rtol=1e-4, atol=1e-4
    )
    # and init with padding zeroes exactly the per-group pad slots
    cfg_pad = dataclasses.replace(cfg, pad_heads_to=8)
    p2 = init_attention(key, cfg_pad, jnp.float32)
    assert p2["wq"].shape[1] == 8
    np.testing.assert_array_equal(np.asarray(p2["wq"][:, jnp.asarray([2, 3, 6, 7])]), 0.0)
    np.testing.assert_array_equal(np.asarray(p2["wo"][jnp.asarray([2, 3, 6, 7])]), 0.0)


def test_param_counts_match_published():
    expected = {
        "deepseek-v2-236b": 236e9,
        "qwen3-14b": 14.8e9,
        "qwen3-8b": 8.2e9,
        "qwen1.5-0.5b": 0.62e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.05, (arch, got, want)
