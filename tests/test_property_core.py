"""Property-based coverage for the planner and the Eq. 1 guarantee.

``core/planner.py`` and ``core/guarantee.py`` previously had only
example-based tests; these properties pin down the invariants the fused
while-loop executor silently relies on:

* plans are monotone non-decreasing across iterations and never exceed n
  (the prefix-mask trick is only sound for growing prefixes);
* the step direction is one-hot over non-exhausted features (or zero when
  every feature is exhausted);
* the guarantee probability is a true probability, monotone in the error
  budget delta, and CONSERVATIVE: whenever ``satisfied`` reports ok, a
  Monte-Carlo estimate of Pr(|Y − ŷ| ≤ δ) under the same Normal model
  is at least tau (up to MC noise).

Runs under the optional-hypothesis shim: with hypothesis installed
(requirements-dev.txt / CI) each property is fuzzed; without it the tests
collect as clean skips.
"""
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core.guarantee import regression_prob, satisfied
from repro.core.planner import direction, gamma_abs, initial_plan, next_plan
from repro.core.propagation import InferenceUncertainty

_sizes = st.lists(st.integers(min_value=0, max_value=20_000), min_size=1, max_size=6)


def _unc(y_hat, mean, std):
    return InferenceUncertainty(
        y_hat=jnp.asarray(y_hat, jnp.float32),
        mean=jnp.asarray(mean, jnp.float32),
        std=jnp.asarray(std, jnp.float32),
        probs=jnp.zeros((0,), jnp.float32),
        samples=jnp.zeros((0,), jnp.float32),
    )


# ------------------------------------------------------------------ planner
@settings(max_examples=60, deadline=None)
@given(_sizes, st.floats(min_value=1e-4, max_value=0.9))
def test_initial_plan_within_bounds(sizes, alpha):
    n = jnp.asarray(sizes, jnp.int32)
    z0 = np.asarray(initial_plan(n, alpha))
    assert (z0 <= sizes).all(), "z0 may never exceed the group size"
    assert (z0 >= np.minimum(2, sizes)).all(), "need >= 2 samples for a variance"
    assert (z0 >= np.minimum(np.ceil(alpha * np.asarray(sizes)), sizes)).all()


@settings(max_examples=40, deadline=None)
@given(
    _sizes,
    st.floats(min_value=1e-4, max_value=0.5),
    st.floats(min_value=1e-3, max_value=0.2),
    st.lists(
        st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=6, max_size=6),
        min_size=1,
        max_size=8,
    ),
)
def test_plans_monotone_and_bounded_across_iterations(sizes, alpha, gamma, idx_rows):
    """Replaying the planner against arbitrary Sobol-index sequences: z is
    monotone non-decreasing, never exceeds n, and each iteration grows at
    most one feature by at most the absolute step."""
    n = jnp.asarray(sizes, jnp.int32)
    k = len(sizes)
    step = gamma_abs(n, gamma)
    assert int(step) >= 1
    z = initial_plan(n, alpha)
    for row in idx_rows:
        indices = jnp.asarray(row[:k], jnp.float32)
        d = direction(indices, z, n)
        z_next = next_plan(z, d, step, n)
        dz = np.asarray(z_next) - np.asarray(z)
        assert (dz >= 0).all(), "plans must be monotone non-decreasing"
        assert (np.asarray(z_next) <= np.asarray(n)).all(), "z may never exceed n"
        assert (dz > 0).sum() <= 1, "LFP direction grows at most one feature"
        assert dz.sum() <= int(step)
        z = z_next


@settings(max_examples=60, deadline=None)
@given(
    _sizes,
    st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=6, max_size=6),
    st.data(),
)
def test_direction_one_hot_over_non_exhausted(sizes, idx_row, data):
    n = np.asarray(sizes, np.int64)
    z_list = [data.draw(st.integers(min_value=0, max_value=int(nj))) for nj in n]
    z = jnp.asarray(z_list, jnp.int32)
    indices = jnp.asarray(idx_row[: len(sizes)], jnp.float32)
    d = np.asarray(direction(indices, z, jnp.asarray(n, jnp.int32)))
    assert set(np.unique(d)) <= {0, 1}
    if (np.asarray(z_list) >= n).all():
        assert d.sum() == 0, "all-exhausted plans have no direction"
    else:
        assert d.sum() == 1, "direction is one-hot"
        assert z_list[int(np.argmax(d))] < n[int(np.argmax(d))], (
            "the selected feature must have samples remaining"
        )


# ---------------------------------------------------------------- guarantee
@settings(max_examples=60, deadline=None)
@given(
    st.floats(min_value=-50, max_value=50),
    st.floats(min_value=-50, max_value=50),
    st.floats(min_value=0.0, max_value=20.0),
    st.floats(min_value=0.0, max_value=10.0),
    st.floats(min_value=0.0, max_value=10.0),
)
def test_guarantee_prob_bounded_and_monotone_in_delta(y_hat, mean, std, d1, d2):
    lo, hi = sorted((d1, d2))
    u = _unc(y_hat, mean, std)
    p_lo = float(regression_prob(u, jnp.asarray(lo, jnp.float32)))
    p_hi = float(regression_prob(u, jnp.asarray(hi, jnp.float32)))
    assert -1e-6 <= p_lo <= 1 + 1e-6 and -1e-6 <= p_hi <= 1 + 1e-6
    assert p_hi >= p_lo - 1e-6, "a wider error budget can only help"
    if std == 0.0:
        # degenerate sigma: exact indicator, not NaN
        assert p_hi in (0.0, 1.0)
        assert p_hi == float(abs(mean - y_hat) <= hi)


@settings(max_examples=25, deadline=None)
@given(
    st.floats(min_value=-5, max_value=5),     # bias = mean - y_hat
    st.floats(min_value=1e-3, max_value=5.0),  # std
    st.floats(min_value=1e-2, max_value=10.0),  # delta
    st.floats(min_value=0.5, max_value=0.99),  # tau
)
def test_guarantee_conservative_under_random_specs(bias, std, delta, tau):
    """Eq. 1's analytic probability must match (within MC noise) the TRUE
    Pr(|Y − ŷ| ≤ δ) of the Normal inference-uncertainty model it claims to
    bound — so ``ok`` is never granted to a spec whose real coverage is
    materially below tau."""
    u = _unc(0.0, bias, std)
    prob, ok = satisfied(u, delta, tau, "regression")
    prob = float(prob)
    rng = np.random.default_rng(12345)
    y = rng.normal(bias, std, 20_000)
    empirical = float(np.mean(np.abs(y) <= delta))
    mc_noise = 3.5 * np.sqrt(max(empirical * (1 - empirical), 1e-4) / 20_000)
    assert abs(prob - empirical) <= mc_noise + 1e-3
    if bool(ok):
        assert empirical >= tau - mc_noise - 1e-3, (
            "satisfied() granted a spec whose true coverage misses tau"
        )
