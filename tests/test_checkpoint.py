"""Checkpoint manager: roundtrip, atomicity, retention, corruption, reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, load_pytree, save_pytree


@pytest.fixture
def tree():
    k = jax.random.PRNGKey(0)
    return {
        "a": jax.random.normal(k, (16, 8), jnp.float32),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.ones((3,), jnp.bfloat16)},
    }


def test_roundtrip_exact(tmp_path, tree):
    path = str(tmp_path / "x.ckpt")
    save_pytree(tree, path, {"step": 7})
    loaded, meta = load_pytree(path, tree)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_no_tmp_left_behind(tmp_path, tree):
    path = str(tmp_path / "x.ckpt")
    save_pytree(tree, path)
    assert not any(f.endswith(".tmp") for f in os.listdir(tmp_path))


def test_crc_detects_corruption(tmp_path, tree):
    path = str(tmp_path / "x.ckpt")
    save_pytree(tree, path)
    with open(path, "r+b") as f:
        f.seek(-4, os.SEEK_END)
        f.write(b"\x00\x00\x00\x01")
    with pytest.raises(Exception):
        load_pytree(path, tree)


def test_retention_gc(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30, 40):
        mgr.save(s, tree)
    assert mgr.steps() == [30, 40]
    assert mgr.latest_step() == 40


def test_restore_latest(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    t2 = jax.tree.map(lambda x: x * 2, tree)
    mgr.save(1, tree)
    mgr.save(2, t2)
    loaded, meta = mgr.restore(tree)
    assert meta["step"] == 2
    np.testing.assert_allclose(np.asarray(loaded["a"]), np.asarray(t2["a"]))


def test_elastic_reshard_roundtrip(tmp_path, tree):
    """Restore with explicit (single-device) shardings — the elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    path = str(tmp_path / "x.ckpt")
    save_pytree(tree, path)
    loaded, _ = load_pytree(path, tree, shardings)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_missing_leaf_raises(tmp_path, tree):
    path = str(tmp_path / "x.ckpt")
    save_pytree({"a": tree["a"]}, path)
    with pytest.raises(KeyError):
        load_pytree(path, tree)


def test_shape_mismatch_raises(tmp_path, tree):
    path = str(tmp_path / "x.ckpt")
    save_pytree(tree, path)
    bad = dict(tree, a=jnp.zeros((4, 4)))
    with pytest.raises(ValueError):
        load_pytree(path, bad)
