"""Uncertainty propagation + Sobol indices + guarantee + planner."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core.guarantee import regression_prob, satisfied
from repro.core.planner import direction, gamma_abs, initial_plan, next_plan
from repro.core.propagation import (
    InferenceUncertainty,
    propagate_classification,
    propagate_regression,
)
from repro.core.sobol_indices import main_effect_indices
from repro.core.uncertainty import FeatureUncertainty, exact_uncertainty, sample_features


def _normal_unc(values, sigmas, n_rep=16):
    values = jnp.asarray(values, jnp.float32)
    sigmas = jnp.asarray(sigmas, jnp.float32)
    k = values.shape[0]
    return FeatureUncertainty(
        value=values,
        sigma=sigmas,
        replicates=jnp.broadcast_to(values[:, None], (k, n_rep)),
        is_empirical=jnp.zeros((k,), bool),
    )


# ---------------------------------------------------------------- propagation
def test_linear_model_variance_propagation():
    """For y = c.x, Var(y) = sum c_j^2 sigma_j^2 — QMC must recover it."""
    c = jnp.asarray([2.0, -1.0, 0.5])
    unc = _normal_unc([1.0, 2.0, 3.0], [0.3, 0.2, 0.1])
    out = propagate_regression(lambda x: x @ c, unc, m=1024)
    analytic_sd = float(jnp.sqrt(jnp.sum((c * unc.sigma) ** 2)))
    assert abs(float(out.std) - analytic_sd) / analytic_sd < 0.05
    assert abs(float(out.mean) - float(unc.value @ c)) < 0.02
    assert abs(float(out.y_hat) - float(unc.value @ c)) < 1e-5


def test_exact_features_give_zero_uncertainty():
    unc = exact_uncertainty(jnp.asarray([1.0, -2.0]))
    out = propagate_regression(lambda x: x.sum(-1), unc, m=64)
    assert float(out.std) == 0.0


def test_classification_propagation_probs():
    unc = _normal_unc([0.0], [1.0])
    out = propagate_classification(
        lambda x: (x[:, 0] > 0).astype(jnp.int32), unc, m=2048, n_classes=2
    )
    # P(x > 0) = 0.5 for N(0,1): both classes about equally likely
    assert abs(float(out.probs[1]) - 0.5) < 0.05
    assert float(out.probs.sum()) == 1.0


def test_empirical_replicate_sampling():
    reps = jnp.sort(jnp.asarray([[1.0, 2.0, 3.0, 4.0]]), axis=1)
    unc = FeatureUncertainty(
        value=jnp.asarray([2.5]),
        sigma=jnp.zeros((1,)),
        replicates=reps,
        is_empirical=jnp.ones((1,), bool),
    )
    u = jnp.linspace(0.01, 0.99, 64)[:, None]
    x = sample_features(unc, u)
    assert set(np.unique(np.asarray(x))) <= {1.0, 2.0, 3.0, 4.0}


# ---------------------------------------------------------------- sobol idx
def test_main_effect_indices_linear_additive():
    """Linear additive model: I_j = c_j^2 s_j^2 / sum(c^2 s^2) exactly."""
    c = jnp.asarray([3.0, 1.0, 0.0])
    unc = _normal_unc([0.0, 0.0, 0.0], [1.0, 1.0, 1.0])
    est = main_effect_indices(lambda x: x @ c, unc, m=512)
    expected = np.array([9.0, 1.0, 0.0]) / 10.0
    np.testing.assert_allclose(np.asarray(est.indices), expected, atol=0.06)


def test_indices_track_importance_not_scale():
    # feature 1 has larger sigma -> more output variance -> higher index
    c = jnp.asarray([1.0, 1.0])
    unc = _normal_unc([0.0, 0.0], [2.0, 0.5])
    est = main_effect_indices(lambda x: x @ c, unc, m=512)
    assert float(est.indices[0]) > float(est.indices[1])


# ---------------------------------------------------------------- guarantee
def test_regression_prob_known_values():
    u = InferenceUncertainty(
        y_hat=jnp.asarray(0.0), mean=jnp.asarray(0.0), std=jnp.asarray(1.0),
        probs=jnp.zeros((0,)), samples=jnp.zeros((4,)),
    )
    # P(|N(0,1)| <= 1.96) ~ 0.95
    assert abs(float(regression_prob(u, jnp.asarray(1.96))) - 0.95) < 0.005
    prob, ok = satisfied(u, 1.96, 0.94, "regression")
    assert bool(ok)


def test_guarantee_degenerate_sigma():
    u = InferenceUncertainty(
        y_hat=jnp.asarray(1.0), mean=jnp.asarray(1.0), std=jnp.asarray(0.0),
        probs=jnp.zeros((0,)), samples=jnp.zeros((4,)),
    )
    assert float(regression_prob(u, jnp.asarray(0.1))) == 1.0


# ---------------------------------------------------------------- planner
@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(2, 8),
    seed=st.integers(0, 1000),
)
def test_direction_is_lfp_argmax(k, seed):
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.random(k), jnp.float32)
    n = jnp.asarray(rng.integers(100, 1000, k), jnp.int32)
    z = jnp.asarray(rng.integers(1, 99, k), jnp.int32)
    d = np.asarray(direction(idx, z, n))
    assert d.sum() == 1
    score = np.asarray(idx) / np.asarray(n - z)
    assert d[np.argmax(score)] == 1


def test_direction_excludes_exhausted():
    idx = jnp.asarray([10.0, 0.1])
    n = jnp.asarray([100, 100])
    z = jnp.asarray([100, 50])  # feature 0 exhausted despite high importance
    d = np.asarray(direction(idx, z, n))
    assert d[0] == 0 and d[1] == 1


def test_plan_monotone_and_clipped():
    n = jnp.asarray([100, 200])
    z = initial_plan(n, 0.05)
    assert np.all(np.asarray(z) >= 2)
    step = gamma_abs(n, 0.5)
    z2 = next_plan(z, jnp.asarray([1, 0]), step, n)
    assert int(z2[0]) == 100  # clipped at N
    assert int(z2[1]) == int(z[1])
