"""Online feature store (PR 9): streaming append + hot-group cache.

Covers, in order: ``Table.append`` permutation/version/log semantics and
determinism, empty-group and unknown-key handling, the kernel-level delta
updates (``append_power_sums`` bitwise vs rebuild on exactly-representable
data, ``merge_sorted_prefix`` bitwise vs a full re-sort), the cache-aware
``resolve_afc_plan`` precedence, ``FeatureCache`` hit/refresh/rebuild/LRU
behaviour, and served parity + compile contracts for all three cached
servers (cache hit == cache miss == uncached, before and after appends).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.executor_fused import build_afc_precompute
from repro.data.store import MAX_APPEND_LOG, ColumnStore, build_table
from repro.kernels.sampled_agg.ops import resolve_afc_plan
from repro.kernels.sampled_agg.prefix_stats import (
    append_power_sums,
    merge_sorted_prefix,
    prefix_power_sums_ref,
)
from repro.serving import (
    BatchedFusedServer,
    BiathlonServer,
    ContinuousBatchedServer,
)
from repro.serving.feature_cache import FeatureCache

from tests.serving_fixtures import SMALL_CFG, make_small_bundle


def _toy_table(seed=0, sizes=(5, 3, 4)):
    gid = np.concatenate([np.full(s, g) for g, s in enumerate(sizes)])
    rng = np.random.default_rng(seed + 100)
    t = build_table(
        {"v": rng.normal(size=len(gid)), "a": rng.normal(size=len(gid))},
        gid, seed=seed,
    )
    t.name = "toy"
    return t


# ------------------------------------------------------- streaming append
def test_append_keeps_perm_a_valid_group_partition():
    t = _toy_table()
    t.append(
        {"v": np.arange(4.0), "a": np.arange(4.0)},
        group_key=np.array([0, 2, 2, 7]),  # 7 = brand-new group
    )
    assert t.n_rows == 12 + 4
    # perm is a permutation of all row ids
    assert sorted(t.perm.tolist()) == list(range(t.n_rows))
    # each group's slice holds exactly its own rows
    all_gid = np.concatenate(
        [np.full(s, g) for g, s in enumerate((5, 3, 4))] + [[0, 2, 2, 7]]
    )
    for key, g in t.group_ids.items():
        s, e = int(t.group_ptr[g]), int(t.group_ptr[g + 1])
        assert (all_gid[t.perm[s:e]] == key).all()
    assert t.group_size(7) == 1 and t.group_size(2) == 6


def test_append_is_deterministic_given_seed():
    rows = {"v": np.arange(6.0), "a": -np.arange(6.0)}
    keys = np.array([0, 1, 0, 2, 2, 0])
    a, b = _toy_table(seed=3), _toy_table(seed=3)
    a.append(rows, keys)
    b.append(rows, keys)
    np.testing.assert_array_equal(a.perm, b.perm)
    np.testing.assert_array_equal(a.group_ptr, b.group_ptr)


def test_append_insertion_positions_span_uniform_range():
    """j ~ Uniform{0..m}: over many appends into one group every prefix
    position (including both ends) gets hit — the prefix-is-SRS invariant
    needs the full support, not append-at-tail."""
    t = _toy_table(seed=5)
    seen = set()
    for i in range(64):
        m = t.group_size(0)
        before = t.perm[int(t.group_ptr[0]) : int(t.group_ptr[1])].copy()
        t.append({"v": [float(i)], "a": [0.0]}, group_key=[0])
        after = t.perm[int(t.group_ptr[0]) : int(t.group_ptr[1])]
        (j,) = np.where(after == t.n_rows - 1)[0]
        seen.add((int(j), m))
        # insertion only shifts; the surviving order is untouched
        np.testing.assert_array_equal(np.delete(after, j), before)
    js = {j for j, _m in seen}
    assert 0 in js and max(js) >= 60  # both ends of Uniform{0..m} exercised


def test_append_bumps_versions_and_events_since():
    t = _toy_table()
    assert t.version(1) == 0
    assert t.events_since(1, 0) == []  # current = no events
    t.append({"v": [1.0, 2.0], "a": [0.0, 0.0]}, group_key=[1, 1])
    assert t.version(1) == 2
    ev = t.events_since(1, 0)
    assert len(ev) == 2
    for j, row_id in ev:
        assert 0 <= j <= t.group_size(1)
        assert row_id in (12, 13)
    assert t.events_since(1, 1) == ev[1:]
    assert t.events_since(1, 2) == []


def test_events_since_ages_out_past_log_bound():
    t = _toy_table()
    n = MAX_APPEND_LOG + 2
    t.append(
        {"v": np.zeros(n), "a": np.zeros(n)}, group_key=np.zeros(n, int)
    )
    assert t.events_since(0, 0) is None  # log no longer reaches version 0
    assert len(t.events_since(0, 2)) == MAX_APPEND_LOG
    assert t.events_since(0, n) == []


def test_append_validates_columns_and_lengths():
    t = _toy_table()
    with pytest.raises(ValueError, match="missing \\['a'\\]"):
        t.append({"v": [1.0]}, group_key=[0])
    with pytest.raises(ValueError, match="unexpected \\['b'\\]"):
        t.append({"v": [1.0], "a": [1.0], "b": [1.0]}, group_key=[0])
    with pytest.raises(ValueError, match="column 'a' has 2 rows"):
        t.append({"v": [1.0], "a": [1.0, 2.0]}, group_key=[0])


# ----------------------------------------- empty-group / unknown-key paths
def test_empty_group_reads_neutral_not_neighbor():
    t = _toy_table()
    # register two empty groups, then fill only the SECOND: the first is a
    # middle-empty group whose ptr slice is zero-width between live data
    t.add_group(50)
    t.add_group(51)
    t.append({"v": [9.0], "a": [9.0]}, group_key=[51])
    assert t.group_size(50) == 0
    assert t.version(50) == 0
    assert t.lookup("v", 50) == 0.0  # NOT group 51's 9.0
    np.testing.assert_array_equal(t.sample_prefix("v", 50, 8), np.zeros(8))
    assert t.lookup("v", 51) == 9.0
    # trailing-empty group behaves the same
    t.add_group(60)
    assert t.lookup("v", 60) == 0.0
    np.testing.assert_array_equal(t.sample_prefix("a", 60, 4), np.zeros(4))
    # add_group is idempotent
    assert t.add_group(51) == t.group_ids[51]


def test_unknown_group_key_raises_named_valueerror():
    t = _toy_table()
    for op in (
        lambda: t.lookup("v", 99),
        lambda: t.group_size(99),
        lambda: t.sample_prefix("v", 99, 8),
        lambda: t.version(99),
        lambda: t.events_since(99, 0),
    ):
        with pytest.raises(ValueError, match="table 'toy'.*unknown group key 99"):
            op()


# ------------------------------------------------ delta-update kernel math
def _ptab_fixture(rng, k=3, cap=32, ints=False):
    if ints:
        vals = rng.integers(-8, 8, size=(k, cap)).astype(np.float32)
        x = rng.integers(-8, 8, size=(k,)).astype(np.float32)
    else:
        vals = rng.normal(size=(k, cap)).astype(np.float32)
        x = rng.normal(size=(k,)).astype(np.float32)
    shift = vals[:, 0]
    return vals, shift, x


def _rebuild_after_insert(vals, shift, j, x):
    """Oracle: the post-insertion buffer, rebuilt from scratch."""
    k, cap = vals.shape
    new = np.stack([np.insert(vals[r], j, x[r])[:cap] for r in range(k)])
    return np.asarray(prefix_power_sums_ref(jnp.asarray(new), jnp.asarray(shift)))


@pytest.mark.parametrize("j", [1, 7, 31])
def test_append_power_sums_bitwise_matches_rebuild_on_ints(j):
    """On integer-valued data in [-8, 8) every partial sum of u^4 stays
    below 2^24, f32 arithmetic is exact, and the two-sum delta update is
    BITWISE identical to a from-scratch table rebuild."""
    rng = np.random.default_rng(j)
    vals, shift, x = _ptab_fixture(rng, ints=True)
    ptab = prefix_power_sums_ref(jnp.asarray(vals), jnp.asarray(shift))
    upd = append_power_sums(
        ptab, jnp.asarray(shift), jnp.asarray(j, jnp.int32), jnp.asarray(x)
    )
    want = _rebuild_after_insert(vals, shift, j, x)
    np.testing.assert_array_equal(np.asarray(upd), want)


def test_append_power_sums_close_on_floats_and_masks_aff():
    rng = np.random.default_rng(0)
    vals, shift, x = _ptab_fixture(rng)
    ptab = prefix_power_sums_ref(jnp.asarray(vals), jnp.asarray(shift))
    aff = jnp.asarray([True, False, True])
    upd = np.asarray(append_power_sums(
        ptab, jnp.asarray(shift), jnp.asarray(5, jnp.int32),
        jnp.asarray(x), aff,
    ))
    want = _rebuild_after_insert(vals, shift, 5, x)
    np.testing.assert_allclose(upd[[0, 2]], want[[0, 2]], rtol=0, atol=1e-4)
    np.testing.assert_array_equal(upd[1], np.asarray(ptab)[1])  # masked row


def test_append_power_sums_past_cap_is_noop():
    rng = np.random.default_rng(1)
    vals, shift, x = _ptab_fixture(rng)
    ptab = prefix_power_sums_ref(jnp.asarray(vals), jnp.asarray(shift))
    upd = append_power_sums(
        ptab, jnp.asarray(shift), jnp.asarray(vals.shape[1], jnp.int32),
        jnp.asarray(x),
    )
    np.testing.assert_array_equal(np.asarray(upd), np.asarray(ptab))


def _sorted_runs(vals, n, cap):
    """The build_rank_index argsort convention: +inf tail, positions in
    order, stable (value, position)-lexicographic order."""
    pos = np.arange(cap)
    masked = np.where(pos[None, :] < n[:, None], vals, np.inf)
    sidx = np.argsort(masked, axis=1, kind="stable").astype(np.int32)
    svals = np.take_along_axis(masked, sidx, axis=1).astype(np.float32)
    return svals, sidx


@pytest.mark.parametrize("j,full", [(0, False), (4, False), (9, False), (3, True)])
def test_merge_sorted_prefix_bitwise_matches_resort(j, full):
    """One merged append event == a full stable re-sort, bitwise — for
    insertions at the head, middle and tail of a partial prefix, and into
    a FULL buffer (where the element past cap must drop)."""
    rng = np.random.default_rng(j + 10 * full)
    h, cap = 3, 12
    vals = rng.normal(size=(h, cap)).astype(np.float32)
    n = np.full(h, cap if full else 9, np.int32)
    svals, sidx = _sorted_runs(vals, n, cap)
    x = rng.normal(size=(h,)).astype(np.float32)

    msv, msi, mn = merge_sorted_prefix(
        jnp.asarray(svals), jnp.asarray(sidx), jnp.asarray(n), cap,
        jnp.asarray(j, jnp.int32), jnp.asarray(x),
    )
    # oracle: dense insert, trim to cap, stable re-sort
    new = np.stack([np.insert(vals[r, : n[r]], j, x[r])[:cap] for r in range(h)])
    n2 = np.minimum(n + 1, cap)
    padded = np.zeros((h, cap), np.float32)
    for r in range(h):
        padded[r, : n2[r]] = new[r]
    wsv, wsi = _sorted_runs(padded, n2, cap)
    np.testing.assert_array_equal(np.asarray(mn), n2)
    np.testing.assert_array_equal(np.asarray(msv), wsv)
    np.testing.assert_array_equal(np.asarray(msi), wsi)


def test_merge_sorted_prefix_aff_and_past_cap_are_noops():
    rng = np.random.default_rng(2)
    h, cap = 2, 8
    vals = rng.normal(size=(h, cap)).astype(np.float32)
    n = np.full(h, 6, np.int32)
    svals, sidx = _sorted_runs(vals, n, cap)
    x = rng.normal(size=(h,)).astype(np.float32)
    # aff=False rows untouched
    msv, msi, mn = merge_sorted_prefix(
        jnp.asarray(svals), jnp.asarray(sidx), jnp.asarray(n), cap,
        jnp.asarray(2, jnp.int32), jnp.asarray(x),
        jnp.asarray([False, True]),
    )
    np.testing.assert_array_equal(np.asarray(msv)[0], svals[0])
    np.testing.assert_array_equal(np.asarray(mn), [6, 7])
    # j >= cap: the event landed beyond the prefix buffer entirely
    msv, msi, mn = merge_sorted_prefix(
        jnp.asarray(svals), jnp.asarray(sidx), jnp.asarray(n), cap,
        jnp.asarray(cap, jnp.int32), jnp.asarray(x),
    )
    np.testing.assert_array_equal(np.asarray(msv), svals)
    np.testing.assert_array_equal(np.asarray(msi), sidx)
    np.testing.assert_array_equal(np.asarray(mn), n)


# ---------------------------------------- cache-aware strategy resolution
def test_resolve_afc_plan_cached_beats_small_cap_heuristic(monkeypatch):
    monkeypatch.delenv("REPRO_AFC_BACKEND", raising=False)
    # uncached small caps take the rescan path (the PR-5 crossover)...
    assert resolve_afc_plan("auto", 256) == (False, None)
    assert resolve_afc_plan("auto", 1024) == (False, None)
    assert resolve_afc_plan("auto", 2048) == (True, None)
    # ...but prebuilt tables pay zero precompute: cached wins at every cap
    assert resolve_afc_plan("auto", 256, cached=True) == (True, None)
    assert resolve_afc_plan("auto", 1024, cached=True) == (True, None)
    assert resolve_afc_plan("auto", None, cached=True) == (True, None)


def test_resolve_afc_plan_env_and_explicit_still_win(monkeypatch):
    monkeypatch.setenv("REPRO_AFC_BACKEND", "ref")
    # the ref-parity CI leg stays pinned even on cached paths
    assert resolve_afc_plan("auto", 256, cached=True) == (False, False)
    monkeypatch.setenv("REPRO_AFC_BACKEND", "incremental")
    assert resolve_afc_plan("auto", 256, cached=True) == (True, False)
    monkeypatch.delenv("REPRO_AFC_BACKEND", raising=False)
    assert resolve_afc_plan("ref", 8192, cached=True) == (False, False)
    assert resolve_afc_plan("kernel", 256, cached=True) == (True, True)


# ----------------------------------------------------- FeatureCache unit
def _small_cache(maxsize=8):
    b = make_small_bundle()
    pre = build_afc_precompute(k=2)
    cache = FeatureCache(
        b.store, pre.cold, pre.refresh, maxsize=maxsize
    )
    return b, cache


def _specs(g):
    return [("t", "v", g), ("t", "a", g)]


def test_cache_hit_returns_same_entry():
    b, cache = _small_cache()
    e1 = cache.get(_specs(0), 128)
    e2 = cache.get(_specs(0), 128)
    assert e2 is e1
    assert cache.stats == dict(
        hits=1, misses=1, refreshes=0, corruptions=0, entries=1
    )


def test_cache_append_triggers_delta_refresh_matching_rebuild():
    b, cache = _small_cache()
    table = b.store["t"]
    cache.get(_specs(0), 128)
    table.append({"v": [4.5, -1.0], "a": [0.25, 2.0]}, group_key=[0, 0])
    entry = cache.get(_specs(0), 128)
    assert cache.refreshes == 1 and cache.misses == 1
    assert entry.versions == b.store.spec_versions(_specs(0))
    # the shifted values buffer matches a fresh gather bitwise
    want_vals, want_n = b.store.request_buffers(_specs(0), 128)
    np.testing.assert_array_equal(np.asarray(entry.vals), np.asarray(want_vals))
    np.testing.assert_array_equal(np.asarray(entry.n), np.asarray(want_n))
    # the delta-updated tables match a cold rebuild to fp tolerance
    rebuilt = cache.cold(want_vals, want_n)
    np.testing.assert_array_equal(
        np.asarray(entry.tables.shift), np.asarray(rebuilt.shift)
    )
    np.testing.assert_allclose(
        np.asarray(entry.tables.ptab), np.asarray(rebuilt.ptab),
        rtol=0, atol=1e-3,
    )


def test_cache_shift_basis_event_falls_back_to_rebuild():
    """An insertion at j=0 replaces the power-sum shift basis, which the
    delta path cannot express — the cache must cold-rebuild.  An append
    into an EMPTY group always draws j=0 (Uniform{0..0})."""
    b, cache = _small_cache()
    table = b.store["t"]
    table.add_group(77)
    cache.get(_specs(77), 128)  # all-pad entry for the empty group
    table.append({"v": [3.0], "a": [1.5]}, group_key=[77])
    assert table.events_since(77, 0) == [(0, table.n_rows - 1)]
    entry = cache.get(_specs(77), 128)
    assert cache.misses == 2 and cache.refreshes == 0
    assert np.asarray(entry.n).tolist() == [1, 1]
    assert float(entry.vals[0, 0]) == 3.0


def test_cache_aged_log_falls_back_to_rebuild():
    b, cache = _small_cache()
    table = b.store["t"]
    cache.get(_specs(1), 128)
    n = MAX_APPEND_LOG + 1
    table.append(
        {"v": np.zeros(n), "a": np.zeros(n)}, group_key=np.ones(n, int)
    )
    cache.get(_specs(1), 128)
    assert cache.misses == 2 and cache.refreshes == 0


def test_cache_lru_evicts_oldest():
    b, cache = _small_cache(maxsize=2)
    cache.get(_specs(0), 128)
    cache.get(_specs(1), 128)
    cache.get(_specs(2), 128)  # evicts group 0
    assert len(cache) == 2
    cache.get(_specs(1), 128)  # still resident
    cache.get(_specs(0), 128)  # re-miss
    assert cache.stats["hits"] == 1 and cache.stats["misses"] == 4


# -------------------------------------------- served parity + contracts
def test_cached_server_parity_hits_and_appends():
    """Cache hit == cache miss == uncached: the single-request server with
    a feature cache serves the identical z-plan (bitwise) and matching
    prediction on the first (miss) and second (hit) pass, keeps serving
    after appends (delta refresh), and mints zero executables on hits."""
    b = make_small_bundle()
    oracle = BiathlonServer(make_small_bundle(), SMALL_CFG, mode="fused")
    srv = BiathlonServer(b, SMALL_CFG, mode="fused", cache_size=8)
    reqs = [{"g": g} for g in (0, 1, 2)]
    miss = [srv.serve(r) for r in reqs]
    compiles_after_miss = srv.compile_count
    hit = [srv.serve(r) for r in reqs]
    assert srv.compile_count == compiles_after_miss, "a hit minted code"
    assert srv.cache.stats["hits"] == len(reqs)
    for r, a, h in zip(reqs, miss, hit):
        want = oracle.serve(r)
        np.testing.assert_array_equal(a["z"], want["z"])
        np.testing.assert_array_equal(a["z"], h["z"])
        scale = max(abs(want["y_hat"]), 1.0)
        assert abs(a["y_hat"] - want["y_hat"]) <= 1e-4 * scale
        assert a["y_hat"] == h["y_hat"]
    srv.check_compile_contract()

    # stream rows into a served group: both servers see the same store
    # mutation (the oracle rebuilds, the cached server delta-refreshes)
    rows = {"v": [2.0, -3.0, 0.5], "a": [1.0, 1.0, 0.0]}
    b.store["t"].append(rows, group_key=[0, 0, 0])
    oracle.bundle.store["t"].append(rows, group_key=[0, 0, 0])
    # identical RNG streams => identical insertion positions
    got, want = srv.serve(reqs[0]), oracle.serve(reqs[0])
    assert srv.cache.stats["refreshes"] == 1
    np.testing.assert_array_equal(got["z"], want["z"])
    assert abs(got["y_hat"] - want["y_hat"]) <= 1e-3 * max(abs(want["y_hat"]), 1.0)
    srv.check_compile_contract()


def test_batched_cached_parity_and_mesh_exclusion():
    b = make_small_bundle()
    reqs = [{"g": g} for g in range(4)]
    plain = BatchedFusedServer(b, SMALL_CFG, batch_size=4)
    want = plain.serve_batch(reqs)
    srv = BatchedFusedServer(b, SMALL_CFG, batch_size=4, cache_size=8)
    got = srv.serve_batch(reqs)
    np.testing.assert_array_equal(np.asarray(got.z), np.asarray(want.z))
    np.testing.assert_array_equal(
        np.asarray(got.iters), np.asarray(want.iters)
    )
    np.testing.assert_allclose(
        np.asarray(got.y_hat), np.asarray(want.y_hat), rtol=1e-4, atol=1e-5
    )
    again = srv.serve_batch(reqs)  # all-hit pass: bitwise stable
    np.testing.assert_array_equal(np.asarray(again.z), np.asarray(got.z))
    np.testing.assert_array_equal(
        np.asarray(again.y_hat), np.asarray(got.y_hat)
    )
    srv.check_compile_contract()

    from repro.launch.mesh import make_serving_mesh

    with pytest.raises(ValueError, match="mutually exclusive"):
        BatchedFusedServer(
            b, SMALL_CFG, batch_size=4, mesh=make_serving_mesh(1), cache_size=4
        )


def test_continuous_cached_parity_and_contract():
    b = make_small_bundle()
    reqs = [{"g": g} for g in range(4)]
    fixed = BatchedFusedServer(b, SMALL_CFG, batch_size=4)
    want = fixed.serve_batch(reqs)

    srv = ContinuousBatchedServer(
        b, SMALL_CFG, batch_size=4, chunk_iters=3, cache_size=8
    )
    cap = srv.trace_cap(reqs)
    table = srv.new_table(cap)
    assert srv.compile_count == 0, "new_table must stay abstract (eval_shape)"
    table, _ = srv.admit(
        table, cap, [(i, r, None) for i, r in enumerate(reqs)]
    )
    out = srv.readback(table)
    while not out["done"].all():
        table = srv.run_chunk(table)
        out = srv.readback(table)
    np.testing.assert_array_equal(out["z"], np.asarray(want.z))
    np.testing.assert_array_equal(out["it"], np.asarray(want.iters))
    np.testing.assert_allclose(
        out["y_hat"], np.asarray(want.y_hat), rtol=1e-4, atol=1e-5
    )
    compiles = srv.compile_count
    table, _ = srv.admit(table, cap, [(0, reqs[0], None)])  # cache hit
    assert srv.compile_count == compiles
    srv.check_compile_contract()

    from repro.launch.mesh import make_serving_mesh

    with pytest.raises(ValueError, match="mutually exclusive"):
        ContinuousBatchedServer(
            b, SMALL_CFG, batch_size=4, mesh=make_serving_mesh(1),
            cache_size=4,
        )
