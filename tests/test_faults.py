"""Fault-injection harness: seeded schedules, retry/backoff, recovery.

Pins the contract of serving/faults.py plus its integration with the
runtime's bounded-retry loop and the degradation controller's feedback
path: faults are a pure function of ``(profile, call index)`` so every
run replays identically; a transient failure costs one virtual backoff,
not a lost batch; a service-time spike loosens knobs and the system
returns to the baseline tier once the backlog clears.
"""
import numpy as np
import pytest
from serving_fixtures import SMALL_CFG, make_small_bundle

from repro.serving import (
    BatchedFusedServer,
    DegradationController,
    FaultProfile,
    FaultyServer,
    ServingRuntime,
    TransientExecutorError,
    default_tiers,
    inject_burst,
)

CFG = SMALL_CFG


@pytest.fixture(scope="module")
def small_bundle():
    return make_small_bundle()


@pytest.fixture(scope="module")
def server4(small_bundle):
    srv = BatchedFusedServer(small_bundle, CFG, batch_size=4)
    srv.serve_batch([{"g": 0}])  # pre-warm the 128 bucket on the INNER
    return srv  # server so fault call indices start at 0 for real traffic


class _StubServer:
    """Minimal serve_batch target for unit-testing the wrapper alone."""

    batch_size = 4

    def __init__(self):
        self.seen = []

    def serve_batch(self, requests, knobs=None):
        self.seen.append((tuple(r["g"] for r in requests), knobs))
        return "result"


# ------------------------------------------------------------- schedules
def test_schedule_is_deterministic_and_seeded():
    a = FaultProfile(seed=3, spike_prob=0.3, fail_prob=0.2)
    b = FaultProfile(seed=3, spike_prob=0.3, fail_prob=0.2)
    other = FaultProfile(seed=4, spike_prob=0.3, fail_prob=0.2)
    spikes = [c for c in range(200) if a.spikes_at(c)]
    fails = [c for c in range(200) if a.fails_at(c)]
    assert spikes == [c for c in range(200) if b.spikes_at(c)]
    assert fails == [c for c in range(200) if b.fails_at(c)]
    assert 0 < len(spikes) < 200 and 0 < len(fails) < 200
    assert spikes != [c for c in range(200) if other.spikes_at(c)]
    # spike and fail streams are independent draws, not the same coin
    assert spikes != fails


def test_pinned_calls_override_probability():
    p = FaultProfile(spike_calls=(2, 5), fail_calls=(1,))
    assert [c for c in range(8) if p.spikes_at(c)] == [2, 5]
    assert [c for c in range(8) if p.fails_at(c)] == [1]


# ---------------------------------------------------------- wrapper unit
def test_faulty_server_spikes_sleep_then_delegate():
    inner = _StubServer()
    slept = []
    fs = FaultyServer(
        inner,
        FaultProfile(spike_calls=(0,), spike_s=0.25),
        sleep=slept.append,
    )
    out = fs.serve_batch([{"g": 1}], knobs="KN")
    assert out == "result"
    assert slept == [0.25]
    assert fs.events == [(0, "spike")]
    assert inner.seen == [((1,), "KN")]  # knobs pass through untouched
    fs.serve_batch([{"g": 2}])
    assert slept == [0.25]  # only the scheduled call spiked
    assert fs.calls == 2
    assert fs.batch_size == 4  # attribute proxying to the inner server


def test_faulty_server_failure_raises_before_serving():
    inner = _StubServer()
    fs = FaultyServer(inner, FaultProfile(fail_calls=(0,)), sleep=lambda s: None)
    with pytest.raises(TransientExecutorError):
        fs.serve_batch([{"g": 0}])
    assert inner.seen == []  # the failure pre-empted the dispatch
    assert fs.events == [(0, "fail")]
    assert fs.calls == 1
    fs.serve_batch([{"g": 0}])  # the next call index is clean
    assert len(inner.seen) == 1


def test_faultless_wrapper_is_transparent(small_bundle, server4):
    fs = FaultyServer(server4, FaultProfile(), sleep=lambda s: None)
    direct = server4.serve_batch([{"g": 3}])
    wrapped = fs.serve_batch([{"g": 3}])
    np.testing.assert_array_equal(direct.z, wrapped.z)
    np.testing.assert_array_equal(direct.y_hat, wrapped.y_hat)


# ------------------------------------------------------ runtime integration
def test_transient_failure_retried_with_virtual_backoff(server4):
    fs = FaultyServer(server4, FaultProfile(fail_calls=(0,)))
    rt = ServingRuntime(fs, max_wait_s=0.001, max_retries=2, backoff_s=0.01)
    arrivals = [(0.0, {"g": g}) for g in range(4)]
    stats = rt.run(arrivals, warmup=False)
    assert stats.n_retries == 1
    assert stats.n_failed == 0
    assert [r.disposition for r in stats.records] == ["ok"] * 4
    # the failed attempt's wall-clock AND the backoff land on the clock
    assert all(r.latency_s >= 0.01 for r in stats.records)
    assert stats.summary()["n_retries"] == 1
    assert fs.events == [(0, "fail")]


def test_exhausted_retries_mark_the_batch_failed(server4):
    fs = FaultyServer(server4, FaultProfile(fail_calls=(0, 1, 2)))
    rt = ServingRuntime(fs, max_wait_s=0.001, max_retries=2, backoff_s=0.01)
    stats = rt.run([(0.0, {"g": g}) for g in range(4)], warmup=False)
    assert fs.calls == 3  # 1 attempt + 2 retries, then give up
    assert stats.n_retries == 2
    assert stats.n_failed == 4
    for r in stats.records:
        assert r.disposition == "failed"
        assert np.isnan(r.y_hat)
    s = stats.summary()
    assert s["n"] == 0 and s["n_failed"] == 4 and s["n_offered"] == 4


def test_fault_runs_replay_identically(server4):
    """Same seed, same trace -> byte-identical event schedule and
    disposition sequence (the harness's whole reason to exist)."""
    prof = FaultProfile(seed=7, fail_prob=0.4)
    arrivals = [(0.05 * k, {"g": k % 8}) for k in range(12)]

    def go():
        fs = FaultyServer(server4, prof)
        rt = ServingRuntime(fs, max_wait_s=0.001, max_retries=1, backoff_s=0.01)
        st = rt.run(arrivals, warmup=False)
        return fs.events, [r.disposition for r in st.records], st.n_retries

    ev1, disp1, ret1 = go()
    ev2, disp2, ret2 = go()
    assert ev1 == ev2 and disp1 == disp2 and ret1 == ret2


def test_spike_degrades_then_recovers_to_baseline(server4):
    """A service-time spike under deadline pressure loosens knobs (or
    sheds); once the backlog clears, later requests serve at tier 0."""
    fs = FaultyServer(server4, FaultProfile(spike_calls=(0,), spike_s=0.2))
    ctl = DegradationController(
        default_tiers(CFG.tau, CFG.max_iters), service_est_s=0.01, lanes=4
    )
    rt = ServingRuntime(fs, max_wait_s=0.001, controller=ctl)
    # phase 1: a clump of 12 tight-deadline requests lands on the spike
    phase1 = [(0.001 * k, {"g": k % 8}, 0.3) for k in range(12)]
    # phase 2: widely-spaced generous-deadline requests after the storm
    phase2 = [(10.0 + 0.5 * k, {"g": k % 8}, 10.0) for k in range(8)]
    stats = rt.run(phase1 + phase2, warmup=False)
    recs = sorted(stats.records, key=lambda r: r.req_id)
    p1, p2 = recs[:12], recs[12:]
    assert any((0, "spike") == e for e in fs.events)
    # knob tightening: post-spike admissions ran degraded or were shed
    assert max(r.tier for r in p1) > 0
    # recovery: the tail of phase 2 is back at the baseline tier, served
    for r in p2[-4:]:
        assert r.disposition == "ok" and r.tier == 0 and r.deadline_met
    assert ctl.load_tier == 0
    assert stats.compile_count == 0  # degradation stayed pure data


# ----------------------------------------------------------------- bursts
def test_inject_burst_is_seeded_and_sorted():
    base = [(0.0, {"g": 0}), (1.0, {"g": 1})]
    a = inject_burst(base, at_t=0.5, n=5, width_s=0.1, seed=3)
    b = inject_burst(base, at_t=0.5, n=5, width_s=0.1, seed=3)
    c = inject_burst(base, at_t=0.5, n=5, width_s=0.1, seed=4)
    assert a == b and a != c
    assert len(a) == 7
    assert [t for t, *_ in a] == sorted(t for t, *_ in a)
    injected = [x for x in a if x not in base]
    assert all(0.5 <= t < 0.6 for t, *_ in injected)
    # burst requests are drawn from the base trace's own population
    assert all(r in ({"g": 0}, {"g": 1}) for _, r in injected)


def test_inject_burst_attaches_slo_and_validates():
    base = [(0.0, {"g": 0})]
    out = inject_burst(base, at_t=0.0, n=3, width_s=0.1, slo_s=0.25)
    assert sum(len(x) == 3 and x[2] == 0.25 for x in out) == 3
    with pytest.raises(ValueError, match="empty"):
        inject_burst([], at_t=0.0, n=1, width_s=0.1)
    with pytest.raises(ValueError, match="width"):
        inject_burst(base, at_t=0.0, n=1, width_s=0.0)
    with pytest.raises(ValueError, match="n must"):
        inject_burst(base, at_t=0.0, n=-1, width_s=0.1)
