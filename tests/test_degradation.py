"""SLO-aware graceful degradation: knobs, controller, shedding, runtime.

Pins the degradation contract (DESIGN.md § Graceful degradation):

* (delta, tau, iter_cap) are TRACED per-lane executor inputs — varying them
  batch-to-batch never compiles a new executable per cap bucket;
* default knobs reproduce the knob-less path bitwise (z-plans) — the
  degradation layer is a strict superset, not a fork;
* the controller's decision functions are deterministic and monotone:
  tighter remaining SLO budget (or a deeper queue) never yields a stricter
  tier, and a shed decision at some slack implies shedding at any smaller
  slack;
* the hysteresis: load tier ratchets up immediately at the high watermark,
  steps down only after ``cooldown`` consecutive calm observations;
* the runtime sheds infeasible requests explicitly (``shed`` disposition)
  instead of queueing unboundedly, and the summary judges each served
  request against the tau it was actually served under.
"""
import math

import numpy as np
import pytest
from hypothesis_compat import given, settings, st
from serving_fixtures import SMALL_CFG, make_small_bundle

from repro.serving import (
    BatchedFusedServer,
    DegradationController,
    KnobTier,
    LaneKnobs,
    RequestRecord,
    RuntimeStats,
    ServingRuntime,
    default_tiers,
    validate_tiers,
)
from repro.data.synthetic import poisson_arrivals

CFG = SMALL_CFG


@pytest.fixture(scope="module")
def small_bundle():
    return make_small_bundle()


@pytest.fixture(scope="module")
def server4(small_bundle):
    return BatchedFusedServer(small_bundle, CFG, batch_size=4)


def _controller(**kw):
    kw.setdefault("service_est_s", 0.01)
    kw.setdefault("lanes", 4)
    return DegradationController(default_tiers(0.95, 32), **kw)


# ------------------------------------------------- traced-knob executor path
def test_knob_variation_never_recompiles(server4):
    """delta/tau/iter_cap changes are data: ZERO new executables."""
    server4.serve_batch([{"g": 0}])  # warm the 128 bucket
    before = server4.compile_count
    for kn in (
        LaneKnobs(delta=0.5, tau=0.95, iter_cap=32),
        LaneKnobs(delta=0.75, tau=0.92, iter_cap=16),
        LaneKnobs(delta=1.25, tau=0.88, iter_cap=8),
        LaneKnobs(delta=2.0, tau=0.80, iter_cap=1),
    ):
        server4.serve_batch([{"g": 0}, {"g": 1}], knobs=[kn, None])
    assert server4.compile_count == before, "knob changes must not recompile"


def test_default_knobs_match_knobless_path(small_bundle, server4):
    """Explicit baseline knobs == the knob-less call, bitwise on z."""
    delta = small_bundle.pipeline.delta_default
    kn = LaneKnobs(delta=delta, tau=CFG.tau, iter_cap=CFG.max_iters)
    reqs = [{"g": 2}, {"g": 3}]
    with_knobs = server4.serve_batch(reqs, knobs=[kn, kn])
    without = server4.serve_batch(reqs)
    np.testing.assert_array_equal(with_knobs.z, without.z)
    np.testing.assert_array_equal(with_knobs.iters, without.iters)
    np.testing.assert_allclose(with_knobs.y_hat, without.y_hat, rtol=1e-6)


def test_looser_knobs_do_less_work(server4):
    """Each knob individually can only shorten the planner loop."""
    base = server4.serve_batch([{"g": 0}]).iters[0]
    capped = server4.serve_batch(
        [{"g": 0}], knobs=[LaneKnobs(delta=0.5, tau=0.95, iter_cap=2)]
    ).iters[0]
    low_tau = server4.serve_batch(
        [{"g": 0}], knobs=[LaneKnobs(delta=0.5, tau=0.5, iter_cap=32)]
    ).iters[0]
    wide_delta = server4.serve_batch(
        [{"g": 0}], knobs=[LaneKnobs(delta=50.0, tau=0.95, iter_cap=32)]
    ).iters[0]
    assert base > 2, "baseline must actually iterate for this test to bite"
    assert capped <= 2
    assert low_tau <= base
    assert wide_delta <= base
    # iter_cap=0 skips the while_loop entirely (init dispatch only)
    zero = server4.serve_batch(
        [{"g": 0}], knobs=[LaneKnobs(delta=0.5, tau=0.95, iter_cap=0)]
    )
    assert zero.iters[0] == 0


def test_knob_misalignment_rejected(server4):
    with pytest.raises(ValueError, match="align"):
        server4.serve_batch([{"g": 0}], knobs=[None, None])


# ----------------------------------------------------------- tier validation
def test_validate_tiers_rejects_non_monotone():
    ok = default_tiers(0.95, 32)
    assert validate_tiers(ok) == ok
    with pytest.raises(ValueError, match="at least one"):
        validate_tiers(())
    with pytest.raises(ValueError, match="tau"):
        validate_tiers((KnobTier("x", 1.0, 1.5, 4),))
    with pytest.raises(ValueError, match="delta_scale"):
        validate_tiers((KnobTier("x", 0.5, 0.9, 4),))
    with pytest.raises(ValueError, match="strictest"):
        validate_tiers(
            (KnobTier("a", 1.0, 0.9, 4), KnobTier("b", 2.0, 0.95, 2))
        )
    with pytest.raises(ValueError, match="strictest"):
        validate_tiers(
            (KnobTier("a", 1.0, 0.9, 4), KnobTier("b", 2.0, 0.85, 8))
        )


# --------------------------------------------------- controller determinism
@settings(max_examples=60, deadline=None)
@given(
    st.floats(min_value=1e-4, max_value=1.0),   # service estimate
    st.integers(min_value=0, max_value=64),     # queue depth
    st.floats(min_value=1e-4, max_value=10.0),  # slack a
    st.floats(min_value=1e-4, max_value=10.0),  # slack b
)
def test_tier_monotone_in_slack(est, depth, slack_a, slack_b):
    """Tighter remaining budget never yields a stricter (slower) tier."""
    ctl = _controller(service_est_s=est)
    lo, hi = min(slack_a, slack_b), max(slack_a, slack_b)
    assert ctl.tier_for(lo, depth) >= ctl.tier_for(hi, depth)
    # deterministic: same inputs, same controller state -> same answer
    assert ctl.tier_for(lo, depth) == ctl.tier_for(lo, depth)
    # no deadline only ever contributes the load tier
    assert ctl.tier_for(None, depth) == ctl.load_tier


@settings(max_examples=60, deadline=None)
@given(
    st.floats(min_value=1e-4, max_value=1.0),
    st.integers(min_value=0, max_value=64),
    st.integers(min_value=0, max_value=64),
    st.floats(min_value=1e-4, max_value=10.0),
)
def test_tier_monotone_in_queue_depth(est, depth_a, depth_b, slack):
    ctl = _controller(service_est_s=est)
    lo, hi = min(depth_a, depth_b), max(depth_a, depth_b)
    assert ctl.tier_for(slack, hi) >= ctl.tier_for(slack, lo)


@settings(max_examples=60, deadline=None)
@given(
    st.floats(min_value=1e-4, max_value=1.0),
    st.integers(min_value=0, max_value=64),
    st.floats(min_value=0.0, max_value=10.0),
    st.floats(min_value=0.0, max_value=10.0),
)
def test_shed_monotone_and_deterministic(est, depth, slack_a, slack_b):
    """Shedding at some slack implies shedding at any smaller slack, and
    the decision is a pure function of (state, args)."""
    ctl = _controller(service_est_s=est, max_queue=32)
    lo, hi = min(slack_a, slack_b), max(slack_a, slack_b)
    if ctl.should_shed(hi, depth):
        assert ctl.should_shed(lo, depth)
    assert ctl.should_shed(lo, depth) == ctl.should_shed(lo, depth)
    # deciding must not mutate state
    tier_before = ctl.load_tier
    est_before = ctl.service_est_s
    ctl.should_shed(lo, depth)
    ctl.tier_for(lo, depth)
    assert ctl.load_tier == tier_before and ctl.service_est_s == est_before
    # the queue bound sheds regardless of slack
    assert ctl.should_shed(hi, 33)


def test_shed_floor_is_loosest_tier_estimate():
    ctl = _controller(service_est_s=0.1, floor_speedup=0.5)
    assert ctl.min_service_s == pytest.approx(0.05)
    assert ctl.should_shed(0.04, 0)        # below even the loosest tier
    assert not ctl.should_shed(0.06, 0)    # the floor tier can still make it
    assert not ctl.should_shed(None, 0)    # no deadline -> never deadline-shed


# ------------------------------------------------------------- hysteresis
def test_load_tier_hysteresis():
    ctl = _controller(queue_high=2.0, queue_low=0.5, cooldown=3)
    hi = int(2.0 * ctl.lanes)
    lo = int(0.5 * ctl.lanes)
    assert ctl.load_tier == 0
    ctl.observe(0.01, hi)          # ratchets up immediately
    assert ctl.load_tier == 1
    ctl.observe(0.01, hi + 5)
    assert ctl.load_tier == 2
    ctl.observe(0.01, lo)          # calm 1/3: no change yet
    ctl.observe(0.01, lo)          # calm 2/3
    assert ctl.load_tier == 2
    ctl.observe(0.01, lo)          # calm 3/3: one rung down
    assert ctl.load_tier == 1
    ctl.observe(0.01, hi - 1)      # mid-band resets the calm counter
    ctl.observe(0.01, lo)
    ctl.observe(0.01, lo)
    assert ctl.load_tier == 1
    ctl.observe(0.01, lo)
    assert ctl.load_tier == 0
    ctl.observe(0.01, lo)          # never below baseline
    ctl.observe(0.01, lo)
    ctl.observe(0.01, lo)
    assert ctl.load_tier == 0


def test_ewma_service_estimate():
    ctl = _controller(service_est_s=0.01, ewma_alpha=0.5)
    ctl.observe(0.03, 0)
    assert ctl.service_est_s == pytest.approx(0.02)
    ctl.observe(0.02, 0)
    assert ctl.service_est_s == pytest.approx(0.02)


def test_knobs_for_resolves_and_clamps():
    ctl = _controller()
    kn0 = ctl.knobs_for(0, base_delta=0.5)
    assert kn0 == LaneKnobs(delta=0.5, tau=0.95, iter_cap=32, tier=0)
    kn_last = ctl.knobs_for(99, base_delta=0.5)  # clamped to the floor tier
    assert kn_last.tier == len(ctl.tiers) - 1
    assert kn_last.delta == pytest.approx(0.5 * ctl.tiers[-1].delta_scale)


# ------------------------------------------------------- runtime integration
def test_runtime_sheds_infeasible_requests(small_bundle, server4):
    """A budget below even the loosest tier's service floor sheds at
    admission — explicitly, not by queueing forever."""
    ctl = DegradationController(
        default_tiers(CFG.tau, CFG.max_iters), service_est_s=0.05, lanes=4,
        ewma_alpha=1e-6,  # pin the estimate: shed decisions stay static
    )
    rt = ServingRuntime(
        server4, max_wait_s=0.001, slo_s=0.01, controller=ctl
    )
    arrivals = poisson_arrivals(small_bundle.requests[:8], 500.0, n=12, seed=9)
    stats = rt.run(arrivals)
    s = stats.summary()
    assert stats.n_shed > 0
    assert s["shed_rate"] == pytest.approx(stats.n_shed / 12)
    assert s["n_offered"] == 12
    shed = [r for r in stats.records if r.disposition == "shed"]
    assert len(shed) == stats.n_shed
    for r in shed:
        assert math.isnan(r.y_hat) and r.batch_id == -1
        assert not r.deadline_met and math.isfinite(r.deadline_t)
    # served requests carry the knobs they ran under
    for r in stats.records:
        if r.disposition == "ok":
            assert r.tau is not None and r.delta is not None
    # degradation is data: nothing recompiled post-warmup
    assert stats.compile_count == 0


def test_runtime_generous_slo_serves_everything(small_bundle, server4):
    ctl = DegradationController(
        default_tiers(CFG.tau, CFG.max_iters), service_est_s=0.005, lanes=4
    )
    rt = ServingRuntime(server4, max_wait_s=0.001, slo_s=60.0, controller=ctl)
    arrivals = poisson_arrivals(small_bundle.requests[:8], 200.0, n=10, seed=2)
    stats = rt.run(arrivals)
    assert stats.n_shed == 0
    assert stats.summary()["n"] == 10
    assert stats.summary()["deadline_met_rate"] == 1.0


def test_summary_uses_per_request_tau():
    """The guarantee is judged against the tau each request was served
    under, not a blanket config value."""
    base = dict(
        req_id=0, arrival_t=0.0, admit_t=0.0, done_t=0.01, queue_delay_s=0.0,
        exec_s=0.01, latency_s=0.01, batch_id=0, batch_fill=1, y_hat=1.0,
        iters=1, sample_frac=0.1,
    )
    recs = [
        RequestRecord(**{**base, "prob": 0.90, "tau": 0.88}),  # degraded: met
        RequestRecord(**{**base, "prob": 0.90, "tau": 0.95}),  # baseline: not
        RequestRecord(**{**base, "prob": 0.90}),  # legacy: falls back to 0.95
    ]
    s = RuntimeStats(tau=0.95, records=recs, makespan_s=1.0).summary()
    assert s["guarantee_rate"] == pytest.approx(1 / 3)


def test_runtime_stats_tau_required():
    with pytest.raises(TypeError):
        RuntimeStats()  # the silent-divergence hazard: no default tau
