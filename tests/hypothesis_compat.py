"""Optional-hypothesis shim (requirements-dev.txt).

``from hypothesis_compat import given, settings, st`` gives test modules the
real hypothesis API when installed; otherwise property-based tests collect
as clean skips (pytest.importorskip semantics scoped to the decorated test,
not the whole module) and every plain test keeps running.

CI sets ``REQUIRE_HYPOTHESIS=1``: there the skip path is a hard error, so
the property tests can never silently rot back into permanent skips (they
did exactly that between the dep landing in requirements-dev.txt and CI
actually asserting on it).
"""
from __future__ import annotations

import os

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the dev dep
    HAVE_HYPOTHESIS = False
    if os.environ.get("REQUIRE_HYPOTHESIS", "0") == "1":
        raise ImportError(
            "REQUIRE_HYPOTHESIS=1 but hypothesis is not importable — "
            "install requirements-dev.txt (CI must run the property tests, "
            "not skip them)"
        )

    def given(*_a, **_kw):
        def deco(fn):
            def stub():
                pytest.importorskip("hypothesis")

            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub

        return deco

    def settings(*_a, **_kw):
        return lambda fn: fn

    class _AnyStrategy:
        def __getattr__(self, _name):
            return lambda *a, **kw: None

    st = _AnyStrategy()
