"""The seven paper pipelines (Table 1) at test scale + serving runtime."""
import jax
import numpy as np
import pytest

from repro.core.executor import BiathlonConfig, HostLoopExecutor, run_exact
from repro.data.synthetic import PIPELINE_NAMES, make_pipeline, make_pipeline_median
from repro.serving import BiathlonServer

SMALL = dict(rows_per_group=1200, n_train_groups=100, n_serve_groups=5, n_requests=3)


@pytest.mark.parametrize("name", PIPELINE_NAMES)
def test_pipeline_structure_matches_table1(name):
    b = make_pipeline(name, **SMALL)
    expected_k = {
        "trip_fare": 3, "tick_price": 1, "battery": 10, "turbofan": 9,
        "bearing_imbalance": 8, "fraud_detection": 3, "student_qa": 21,
    }[name]
    expected_exact = {
        "trip_fare": 5, "tick_price": 6, "battery": 1, "turbofan": 0,
        "bearing_imbalance": 0, "fraud_detection": 6, "student_qa": 0,
    }[name]
    assert b.pipeline.k == expected_k
    assert len(b.pipeline.exact_features) == expected_exact
    if b.pipeline.task == "classification":
        assert b.pipeline.delta_default == 0.0
    else:
        assert b.pipeline.delta_default > 0.0


@pytest.mark.parametrize("name", ["trip_fare", "fraud_detection", "turbofan"])
def test_pipeline_serving_guarantee(name):
    b = make_pipeline(name, **SMALL)
    ex = HostLoopExecutor(b.store, BiathlonConfig(m=256, m_sobol=64))
    ok = 0
    for i, req in enumerate(b.requests[:3]):
        y_exact, _ = run_exact(b.store, b.pipeline, req)
        r = ex.run(b.pipeline, req, jax.random.PRNGKey(i))
        tol = max(b.pipeline.delta_default, 1e-9)
        if abs(r.y_hat - y_exact) <= tol:
            ok += 1
        assert r.sample_fraction <= 1.0
    assert ok >= 2  # tau=0.95 with 3 requests: allow one miss


def test_median_pipeline_variant():
    b = make_pipeline_median("tick_price", **SMALL)
    assert any(f.agg == "median" for f in b.pipeline.agg_features)
    ex = HostLoopExecutor(b.store, BiathlonConfig(m=192, m_sobol=48))
    req = b.requests[0]
    y_exact, _ = run_exact(b.store, b.pipeline, req)
    r = ex.run(b.pipeline, req, jax.random.PRNGKey(0))
    assert np.isfinite(r.y_hat)
    assert abs(r.y_hat - y_exact) <= 3 * max(b.pipeline.delta_default, 0.05)


def test_server_stats_host_mode():
    b = make_pipeline("tick_price", **SMALL)
    srv = BiathlonServer(b, BiathlonConfig(m=192, m_sobol=48), mode="host")
    stats = srv.serve_all(b.requests[:2])
    s = stats.summary(b.pipeline.delta_default, b.pipeline.task)
    assert s["n"] == 2
    assert s["mean_sample_frac"] <= 1.0
    assert s["guarantee_rate"] >= 0.5


def test_server_fused_mode_classification():
    b = make_pipeline("fraud_detection", **SMALL)
    srv = BiathlonServer(b, BiathlonConfig(m=192, m_sobol=48), mode="fused")
    stats = srv.serve_all(b.requests[:2])
    s = stats.summary(0.0, "classification")
    assert s["guarantee_rate"] >= 0.5


def test_batched_fused_server():
    from repro.serving import BatchedFusedServer

    b = make_pipeline("turbofan", **SMALL)
    from repro.core.executor import BiathlonConfig as _Cfg

    srv = BatchedFusedServer(b, _Cfg(m=128, m_sobol=48))
    res = srv.serve_batch(b.requests[:3])
    assert res.y_hat.shape == (3,)
    assert (res.sample_frac <= 1.0).all()
    import numpy as _np

    assert _np.isfinite(res.y_hat).all()
    # every request either satisfied or exhausted
    assert ((res.prob >= 0.95) | (res.sample_frac >= 0.999)).all()
