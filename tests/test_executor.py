"""End-to-end Biathlon executor behaviour (the paper's core loop)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.executor import BiathlonConfig, HostLoopExecutor, run_exact
from repro.core.executor_fused import build_fused_executor
from repro.core.pipeline import AggFeature, Pipeline
from repro.data.store import ColumnStore, build_table
from repro.models.tabular import LinearRegression


@pytest.fixture(scope="module")
def toy():
    rng = np.random.default_rng(0)
    G, R = 30, 3000
    gid = np.repeat(np.arange(G), R)
    mu = rng.normal(0, 5, G)
    vals = mu[gid] + rng.normal(0, 2.0, G * R)
    aux = 0.5 * mu[gid] + rng.normal(0, 1.0, G * R)
    store = ColumnStore().add("t", build_table({"v": vals, "a": aux}, gid, seed=1))
    X = np.stack([mu, 0.5 * mu], axis=1)
    y = 3 * X[:, 0] + 1.0 * X[:, 1] + rng.normal(0, 0.01, G)
    lr = LinearRegression().fit(X, y)
    pipe = Pipeline(
        name="toy",
        agg_features=[
            AggFeature("avg_v", "t", "v", "avg", "g"),
            AggFeature("avg_a", "t", "a", "avg", "g"),
        ],
        exact_features=[],
        model=lr,
        task="regression",
        scaler_mean=np.zeros(2, np.float32),
        scaler_scale=np.ones(2, np.float32),
        delta_default=0.5,
    )
    return store, pipe


def test_guarantee_holds_statistically(toy):
    store, pipe = toy
    ex = HostLoopExecutor(store, BiathlonConfig(m=400, m_sobol=96))
    hits = 0
    n_req = 8
    for i in range(n_req):
        req = {"g": i}
        y_exact, _ = run_exact(store, pipe, req)
        r = ex.run(pipe, req, jax.random.PRNGKey(i))
        assert r.satisfied
        if abs(r.y_hat - y_exact) <= 0.5:
            hits += 1
    # tau = 0.95 with slack for small n
    assert hits >= n_req - 1


def test_sample_fraction_small(toy):
    store, pipe = toy
    ex = HostLoopExecutor(store, BiathlonConfig(m=400, m_sobol=96))
    r = ex.run(pipe, {"g": 3}, jax.random.PRNGKey(42))
    assert r.sample_fraction < 0.5
    assert r.iters <= 10


def test_tighter_delta_needs_more_samples(toy):
    store, pipe = toy
    loose = HostLoopExecutor(store, BiathlonConfig(delta=2.0, m=400, m_sobol=96))
    tight = HostLoopExecutor(store, BiathlonConfig(delta=0.08, m=400, m_sobol=96))
    rl = loose.run(pipe, {"g": 5}, jax.random.PRNGKey(0))
    rt = tight.run(pipe, {"g": 5}, jax.random.PRNGKey(0))
    assert rt.samples_used >= rl.samples_used


def test_worst_case_falls_back_to_exact(toy):
    """With an impossible delta=0 the loop must exhaust to exact features."""
    store, pipe = toy
    ex = HostLoopExecutor(store, BiathlonConfig(delta=0.0, m=128, m_sobol=64, max_iters=200))
    r = ex.run(pipe, {"g": 1}, jax.random.PRNGKey(0))
    # all features exact -> deterministic model -> satisfied with prob 1
    assert r.satisfied
    assert np.all(r.z == r.n)
    y_exact, _ = run_exact(store, pipe, {"g": 1})
    assert abs(r.y_hat - y_exact) < 1e-3


def test_fused_matches_host(toy):
    store, pipe = toy
    cfg = BiathlonConfig(m=400, m_sobol=96)
    host = HostLoopExecutor(store, cfg)
    model = pipe.model

    def model_fn(aggs, exact):
        return model.predict(aggs)

    fused = build_fused_executor(
        model_fn, k=2, task="regression", m=cfg.m, m_sobol=cfg.m_sobol,
        alpha=cfg.alpha, gamma=cfg.gamma, tau=cfg.tau,
    )
    req = {"g": 7}
    n = pipe.group_sizes(store, req)
    cap = 4096
    vals, sizes = store.request_buffers(pipe.agg_specs(req), cap)
    res = fused(
        vals, jnp.asarray(n, jnp.int32), jnp.asarray([0, 0], jnp.int32),
        jnp.asarray(0.5, jnp.float32), jnp.zeros((0,), jnp.float32),
    )
    rh = host.run(pipe, req, jax.random.PRNGKey(3))
    y_exact, _ = run_exact(store, pipe, req)
    assert abs(float(res.y_hat) - y_exact) <= 0.5 + 1e-6
    assert abs(rh.y_hat - y_exact) <= 0.5 + 1e-6
    assert float(res.prob) >= cfg.tau or int(res.samples_used) == int(n.sum())
