"""Incremental AFC (prefix-stats precompute) — the PR-5 tentpole contract.

Covers, in order: compensated-accumulation precision at 60k rows (the
5-power-sum fp fix), prefix-table kernel/oracle parity at non-divisible
shapes, the O(1) query path vs the full-pass oracles at the z edges,
holistic rank-index queries vs the sort oracle over the whole plan ladder,
incremental-vs-rescan executor parity (bitwise z-plans), the while-body
HLO-cost flatness claim, and the serving buffer-donation (no-copy)
contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.executor import BiathlonConfig
from repro.core.executor_fused import build_fused_executor
from repro.core.pipeline import AggFeature, Pipeline
from repro.data.store import ColumnStore, build_table
from repro.data.synthetic import PipelineBundle, make_pipeline
from repro.kernels.sampled_agg.compensated import comp_cumsum, comp_sum
from repro.kernels.sampled_agg.ops import (
    beta_order_stat,
    bootstrap_rank_targets,
    finish_quantile_estimates,
    masked_estimates,
    masked_quantile_estimates,
    prefix_power_sums as prefix_power_sums_dispatch,
)
from repro.kernels.sampled_agg.prefix_stats import (
    build_rank_index,
    prefix_moments_at,
    prefix_power_sums,
    prefix_power_sums_ref,
    select_ranks_indexed,
)
from repro.kernels.sampled_agg.ref import (
    masked_select_ranks_ref,
    sampled_moments_ref,
)
from repro.kernels.sampled_agg.sampled_agg import sampled_moments
from repro.launch.hlo_cost import while_costs
from repro.models.tabular import LinearRegression
from repro.serving import BatchedFusedServer, BiathlonServer

SMALL = dict(rows_per_group=1200, n_train_groups=100, n_serve_groups=5, n_requests=4)


# --------------------------------------------------- fp accumulation @ 60k
def _heavy_tailed(n=60000, seed=7):
    """One dominant burst + a dense small tail: the Σv⁴ drift scenario."""
    rng = np.random.default_rng(seed)
    v = rng.normal(1.25, 0.12, n).astype(np.float32)
    v[0] = 100.0  # v⁴ = 1e8; each tail element contributes ~2.4
    return v


def test_power_sums_compensated_at_60k():
    """All four power-sum paths stay within 1e-6 of float64 at n=60k, where
    a naive sequential f32 accumulator (the streaming-AFC baseline this
    guards against) drifts by ~1e-3 on Σv⁴."""
    v = _heavy_tailed()
    n = v.size
    vals = jnp.asarray(v[None, :])
    z = jnp.asarray([n], jnp.int32)
    want = np.array(
        [n] + [float((v.astype(np.float64) ** p).sum()) for p in range(1, 5)]
    )
    for name, got in [
        ("ref", sampled_moments_ref(vals, z)),
        ("kernel", sampled_moments(vals, z, interpret=True)),
    ]:
        rel = np.abs(np.asarray(got)[0] - want) / np.abs(want)
        assert rel.max() < 1e-6, (name, rel)
    # prefix tables: every cumulative position, not just the total
    f64 = np.stack(
        [(v.astype(np.float64) ** p).cumsum() for p in range(1, 5)], axis=-1
    )
    for name, tab in [
        ("prefix_ref", prefix_power_sums_ref(vals)),
        ("prefix_kernel", prefix_power_sums(vals, interpret=True)),
    ]:
        rel = np.max(np.abs(np.asarray(tab)[0] - f64) / (np.abs(f64) + 1e-30))
        assert rel < 1e-6, (name, rel)
    # the naive baseline really does lose the tail: strictly-sequential f32
    seq = np.float32(0.0)
    for x in v:
        seq = np.float32(seq + np.float32(x) ** 4)
    assert abs(seq - want[4]) / want[4] > 1e-4, "scenario lost its teeth"


def test_comp_sum_matches_f64_where_plain_f32_cannot():
    """comp_sum/comp_cumsum recover increments far below the running sum's
    f32 ulp (carry 1e8, increments of 3 -> plain sequential f32 drops them
    all)."""
    x = np.full(60000, 3.0, np.float32)
    x[0] = 1.0e8
    want = 1.0e8 + 3.0 * (x.size - 1)
    got = float(comp_sum(jnp.asarray(x)))
    assert abs(got - want) / want < 1e-7
    cum = np.asarray(comp_cumsum(jnp.asarray(x)))
    want_cum = 1.0e8 + 3.0 * np.arange(x.size)
    assert np.max(np.abs(cum - want_cum) / want_cum) < 1e-7


def test_beta_order_stat_matches_beta_moments():
    """The fixed-round MT sampler is distributionally Beta(a, b): mean and
    variance match the analytic moments within MC error across the regimes
    the bootstrap hits (small/large/asymmetric integer params)."""
    n = 100_000
    for i, (a, b) in enumerate([(1.0, 1.0), (2.0, 5.0), (50.0, 50.0),
                                (3277.0, 29000.0), (10000.0, 10.0)]):
        s = np.asarray(
            beta_order_stat(
                jax.random.PRNGKey(i), jnp.asarray(a), jnp.asarray(b), (n,)
            ),
            np.float64,
        )
        mean = a / (a + b)
        var = a * b / ((a + b) ** 2 * (a + b + 1.0))
        assert (s > 0).all() and (s < 1).all()
        assert abs(s.mean() - mean) < 5.0 * np.sqrt(var / n) + 1e-6, (a, b)
        assert abs(s.var() - var) < 0.05 * var + 1e-9, (a, b)


# ------------------------------------------ prefix tables: kernel vs oracle
@pytest.mark.parametrize("k,cap,block_k,block_c", [
    (4, 512, 4, 128),
    (5, 129, 8, 64),      # neither dim divides its block
    (3, 1000, 2, 256),
    (1, 64, 8, 1024),     # blocks larger than the data
])
def test_prefix_power_sums_kernel_matches_ref(k, cap, block_k, block_c):
    rng = np.random.default_rng(k * cap)
    vals = jnp.asarray(rng.normal(1.0, 3.0, (k, cap)).astype(np.float32))
    shift = vals[:, 0]
    got = prefix_power_sums(
        vals, shift, block_k=block_k, block_c=block_c, interpret=True
    )
    want = prefix_power_sums_ref(vals, shift)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=3e-5, atol=1e-3
    )


@pytest.mark.parametrize("z_list", [[0, 1, 7, 300], [300, 299, 2, 1]])
def test_prefix_query_matches_masked_estimates(z_list):
    """One (k, 5) gather into the tables == the full rescan AFC, at the
    z ∈ {0, 1, n} edges and in between, for every parametric operator —
    under BOTH table backends (ops dispatch honors use_kernel)."""
    k, cap = 4, 300
    rng = np.random.default_rng(3)
    vals = jnp.asarray(rng.normal(50.0, 4.0, (k, cap)).astype(np.float32))
    z = jnp.asarray(z_list, jnp.int32)
    n = jnp.asarray([300, 300, 300, 300], jnp.int32)
    agg_ids = jnp.asarray([0, 3, 4, 1], jnp.int32)
    shift = vals[:, 0]
    from repro.data.aggregates import estimates_from_power_sums

    want_v, want_s = masked_estimates(vals, z, n, agg_ids, use_kernel=False)
    for use_kernel in (False, True):
        tab = prefix_power_sums_dispatch(vals, shift, use_kernel=use_kernel)
        got_v, got_s = estimates_from_power_sums(
            prefix_moments_at(tab, z), z, n, agg_ids, shift
        )
        np.testing.assert_allclose(
            np.asarray(got_v), np.asarray(want_v), rtol=2e-4, atol=2e-4
        )
        np.testing.assert_allclose(
            np.asarray(got_s), np.asarray(want_s), rtol=2e-2, atol=5e-3
        )
    # empty prefix: value/sigma match the oracle's empty convention exactly
    empty = np.asarray(z) == 0
    assert (np.asarray(got_v)[empty] == np.asarray(want_v)[empty]).all()


# -------------------------------------------- holistic rank-index queries
def test_rank_index_select_matches_sort_oracle_over_ladder():
    """Prefix-membership rank queries == sort+gather oracle, bit exact,
    over the entire candidate-z ladder (incl. z = 0, 1, n), with ties and a
    block-non-divisible cap."""
    rng = np.random.default_rng(11)
    h, cap = 3, 777
    vals = rng.normal(0, 2, (h, cap)).astype(np.float32)
    vals[0] = np.round(vals[0])                     # ties
    n = np.array([777, 500, 64], np.int32)
    ladder = np.stack(
        [np.minimum(np.array([min(i, 1) + 13 * i for i in range(33)]), nn)
         for nn in n]
    ).astype(np.int32)                              # starts at 0, then 1, ...
    idx = build_rank_index(jnp.asarray(vals), jnp.asarray(n), jnp.asarray(ladder))
    for col in range(ladder.shape[1]):
        z = ladder[:, col]
        targets = np.stack(
            [rng.integers(0, max(int(t), 1), 17) for t in z]
        ).astype(np.int32)
        got = select_ranks_indexed(idx, jnp.asarray(z), jnp.asarray(targets))
        want = masked_select_ranks_ref(
            jnp.asarray(vals), jnp.asarray(z), jnp.asarray(targets)
        )
        finite = np.asarray(z) > 0
        np.testing.assert_array_equal(
            np.asarray(got)[finite], np.asarray(want)[finite]
        )
        # z == 0 returns +inf on both paths (callers override)
        assert np.isinf(np.asarray(got)[~finite]).all()


def test_incremental_quantile_estimates_bitwise_vs_rescan():
    """Same counter-based key -> bitwise-identical (value, replicates) from
    the rank-index path and masked_quantile_estimates — the holistic half
    of the z-plan parity contract."""
    rng = np.random.default_rng(5)
    h, cap = 2, 640
    vals = jnp.asarray(rng.normal(5.0, 2.0, (h, cap)).astype(np.float32))
    n = jnp.asarray([640, 400], jnp.int32)
    qs = jnp.asarray([0.5, 0.9], jnp.float32)
    key = jax.random.PRNGKey(3)
    ladder = jnp.minimum(
        jnp.asarray([2, 64])[:, None]
        + jnp.arange(9, dtype=jnp.int32)[None, :] * 50,
        n[:, None],
    )
    idx = build_rank_index(vals, n, ladder)
    for col in range(9):
        z = ladder[:, col]
        targets = bootstrap_rank_targets(z, qs, key, 64)
        got_v, got_r = finish_quantile_estimates(
            select_ranks_indexed(idx, z, targets), z, n
        )
        want_v, want_r = masked_quantile_estimates(
            vals, z, n, qs, key, 64, use_kernel=False
        )
        np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
        np.testing.assert_array_equal(np.asarray(got_r), np.asarray(want_r))


# ------------------------------------------------ executor z-plan parity
@pytest.mark.parametrize(
    "name,median",
    [("turbofan", False), ("sensor_health", False), ("turbofan", True)],
)
def test_incremental_vs_rescan_executor_parity(name, median):
    """Acceptance: bitwise-identical z-plans and fp-close predictions vs
    the pre-refactor rescan path, on a parametric AND holistic pipelines
    (incl. the appendix-D median-substituted variant the benchmark runs)."""
    from repro.data.synthetic import make_pipeline_median

    b = (make_pipeline_median if median else make_pipeline)(name, **SMALL)
    cfg = BiathlonConfig(m=192, m_sobol=48, n_bootstrap=128)
    inc = BiathlonServer(b, cfg, mode="fused", afc_backend="incremental")
    ref = BiathlonServer(b, cfg, mode="fused", afc_backend="ref")
    for req in b.requests[:4]:
        ri = inc.serve(req)
        rr = ref.serve(req)
        assert (ri["z"] == rr["z"]).all(), (ri["z"], rr["z"])
        assert ri["iters"] == rr["iters"]
        scale = max(abs(rr["y_hat"]), 1.0)
        assert abs(ri["y_hat"] - rr["y_hat"]) <= 1e-4 * scale
        assert abs(ri["prob"] - rr["prob"]) <= 1e-4


def test_incremental_respects_exactness_pins():
    """approximate=False features stay pinned to z = n on the incremental
    path (the candidate ladder collapses to {n})."""
    k, cap = 2, 512
    rng = np.random.default_rng(0)
    w = jnp.asarray([2.0, 1.0])
    fused = build_fused_executor(
        lambda rows, exact: rows @ w,
        k=k, task="regression", m=64, m_sobol=16, max_iters=8,
        afc_backend="incremental", approximate=(False, True),
    )
    vals = jnp.asarray(rng.normal(0, 1, (k, cap)).astype(np.float32))
    n = jnp.asarray([500, 512], jnp.int32)
    res = fused(vals, n, jnp.zeros((k,), jnp.int32),
                jnp.asarray(0.05, jnp.float32), jnp.zeros((0,), jnp.float32))
    assert int(res.z[0]) == 500


# ------------------------------------- per-cap-bucket AFC heuristic (PR 7)
def test_resolve_afc_plan_cap_heuristic(monkeypatch):
    """"auto" picks rescan at/below AFC_REF_MAX_CAP (where BENCH_fused.json
    measured the prefix tables not amortizing) and incremental above; with
    no cap (build-time validation) the incremental default stands."""
    from repro.kernels.sampled_agg.ops import AFC_REF_MAX_CAP, resolve_afc_plan

    monkeypatch.delenv("REPRO_AFC_BACKEND", raising=False)
    assert resolve_afc_plan("auto", cap=AFC_REF_MAX_CAP) == (False, None)
    assert resolve_afc_plan("auto", cap=128) == (False, None)
    assert resolve_afc_plan("auto", cap=AFC_REF_MAX_CAP * 2) == (True, None)
    assert resolve_afc_plan("auto", cap=None) == (True, None)
    with pytest.raises(ValueError, match="unknown afc_backend"):
        resolve_afc_plan("bogus")


def test_resolve_afc_plan_overrides_beat_heuristic(monkeypatch):
    """Explicit build arguments and the env pin win over the cap heuristic
    at BOTH sides of the threshold — parity legs stay pinned."""
    from repro.kernels.sampled_agg.ops import resolve_afc_plan

    monkeypatch.delenv("REPRO_AFC_BACKEND", raising=False)
    for cap in (128, 65536):
        assert resolve_afc_plan("ref", cap=cap) == (False, False)
        assert resolve_afc_plan("kernel", cap=cap) == (True, True)
        assert resolve_afc_plan("incremental", cap=cap) == (True, False)
        assert resolve_afc_plan("inc", cap=cap) == (True, False)
    # env force-overrides consulted under "auto" only
    for env, want in [("ref", (False, False)), ("kernel", (True, True)),
                      ("incremental", (True, False)), ("inc", (True, False))]:
        monkeypatch.setenv("REPRO_AFC_BACKEND", env)
        assert resolve_afc_plan("auto", cap=128) == want
        assert resolve_afc_plan("auto", cap=65536) == want
        # ...but never over an explicit build argument
        assert resolve_afc_plan("ref", cap=65536) == (False, False)


@pytest.mark.parametrize("cap_factor", [1, 2])
def test_auto_heuristic_executor_parity_at_crossover(monkeypatch, cap_factor):
    """The executor built with "auto" is bitwise-identical to the strategy
    the heuristic resolves to, at the cap bucket just below and just above
    the crossover — strategy selection must never change results."""
    from repro.kernels.sampled_agg.ops import AFC_REF_MAX_CAP

    monkeypatch.delenv("REPRO_AFC_BACKEND", raising=False)
    cap = AFC_REF_MAX_CAP * cap_factor
    forced = "ref" if cap <= AFC_REF_MAX_CAP else "incremental"
    k = 2
    w = jnp.asarray([2.0, -1.0])
    kwargs = dict(k=k, task="regression", m=32, m_sobol=8, max_iters=8,
                  n_boot=16)
    auto = build_fused_executor(
        lambda rows, exact: rows @ w, afc_backend="auto", **kwargs
    )
    pinned = build_fused_executor(
        lambda rows, exact: rows @ w, afc_backend=forced, **kwargs
    )
    rng = np.random.default_rng(cap)
    vals = jnp.asarray(rng.normal(0, 2, (k, cap)).astype(np.float32))
    n = jnp.asarray([cap, cap - 7], jnp.int32)
    args = (vals, n, jnp.zeros((k,), jnp.int32),
            jnp.asarray(0.1, jnp.float32), jnp.zeros((0,), jnp.float32))
    ra, rp = auto(*args), pinned(*args)
    np.testing.assert_array_equal(np.asarray(ra.z), np.asarray(rp.z))
    assert int(ra.iters) == int(rp.iters)
    assert float(ra.y_hat) == float(rp.y_hat)


# ------------------------------------------------- HLO-cost flatness claim
def _executor_hlo(cap: int, afc_backend: str) -> str:
    k = 3
    w = jnp.asarray([1.0, -2.0, 0.5])
    fused = build_fused_executor(
        lambda rows, exact: rows @ w,
        k=k, task="regression", m=16, m_sobol=8, max_iters=8, n_boot=16,
        holistic=(1,), quantiles=(0.5,), afc_backend=afc_backend,
    )
    args = (
        jax.ShapeDtypeStruct((k, cap), jnp.float32),
        jax.ShapeDtypeStruct((k,), jnp.int32),
        jax.ShapeDtypeStruct((k,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((0,), jnp.float32),
    )
    return fused.lower(*args).compile().as_text()


def _planner_body_cost(text: str):
    costs = while_costs(text)
    assert costs, "no while loop found in the compiled executor"
    return max(costs, key=lambda c: c["cost"].bytes)["cost"]


def test_while_body_cost_independent_of_cap():
    """The core claim of this PR: the compiled while_loop body's HLO cost
    (FLOPs and HBM bytes) is flat across cap ∈ {1k, 8k, 64k} on the
    incremental path, while the rescan oracle's body bytes scale ~linearly
    with cap.  (The once-per-request precompute outside the loop is allowed
    to scale — that is the point of the precompute/query split.)"""
    caps = (1024, 8192, 65536)
    inc = [_planner_body_cost(_executor_hlo(c, "incremental")) for c in caps]
    assert inc[0].bytes > 0
    for cost in inc[1:]:
        assert cost.bytes <= 1.3 * inc[0].bytes, [c.bytes for c in inc]
        assert cost.flops <= 1.05 * max(inc[0].flops, 1.0)
    # sensitivity check: the same probe sees the rescan body grow with cap
    ref = [_planner_body_cost(_executor_hlo(c, "ref")) for c in (1024, 8192)]
    assert ref[1].bytes >= 4.0 * ref[0].bytes, [c.bytes for c in ref]


# --------------------------------------------------- donation (no-copy)
@pytest.fixture(scope="module")
def tiny_bundle():
    rng = np.random.default_rng(0)
    sizes = [300] * 6
    gid = np.concatenate([np.full(s, g) for g, s in enumerate(sizes)])
    mu = rng.normal(0, 5, len(sizes))
    vals = mu[gid] + rng.normal(0, 2.0, len(gid))
    store = ColumnStore().add("t", build_table({"v": vals}, gid, seed=1))
    y = 3 * mu + rng.normal(0, 0.01, len(sizes))
    pipe = Pipeline(
        name="tiny",
        agg_features=[AggFeature("avg_v", "t", "v", "avg", "g")],
        exact_features=[],
        model=LinearRegression().fit(mu[:, None], y),
        task="regression",
        scaler_mean=np.zeros(1, np.float32),
        scaler_scale=np.ones(1, np.float32),
        delta_default=0.5,
    )
    return PipelineBundle(
        pipeline=pipe, store=store,
        requests=[{"g": g} for g in range(len(sizes))],
        labels=y, table_rows=len(gid), name="tiny",
    )


def test_batched_server_donates_values_buffer(tiny_bundle):
    """The (lanes, k, cap) values buffer must be donated AND aliased to the
    lane_vals output — i.e. per-batch serving does not copy it.  Asserted
    via the compiled executable's memory analysis, plus a behavioral check
    that serving still works across batches after donation."""
    srv = BatchedFusedServer(tiny_bundle, BiathlonConfig(m=64, m_sobol=16),
                             batch_size=4)
    r1 = srv.serve_batch(tiny_bundle.requests[:3])
    r2 = srv.serve_batch(tiny_bundle.requests[3:6])
    assert np.isfinite(r1.y_hat).all() and np.isfinite(r2.y_hat).all()

    lanes, k, cap = 4, 1, r1.cap
    args = (
        jnp.zeros((lanes, k, cap), jnp.float32),
        jnp.zeros((lanes, k), jnp.int32),
        jnp.zeros((lanes, k), jnp.int32),
        jnp.zeros((lanes,), jnp.float32),
        jnp.zeros((lanes, 0), jnp.float32),
        jnp.zeros((lanes,), bool),
        jnp.full((lanes,), 0.95, jnp.float32),   # traced tau (PR 6)
        jnp.full((lanes,), 64, jnp.int32),       # traced iter_cap (PR 6)
    )
    compiled = srv._batched.lower(*args).compile()
    vals_bytes = lanes * k * cap * 4
    ma = compiled.memory_analysis()
    assert ma.alias_size_in_bytes >= vals_bytes, (
        f"donated values buffer not aliased: alias={ma.alias_size_in_bytes} "
        f"< vals={vals_bytes}"
    )
    assert "input_output_alias" in compiled.as_text()
