"""Optimizer substrate: AdamW, clipping, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.optim.adamw import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    linear_warmup_cosine,
)
from repro.optim.compress import compress_with_error_feedback, dequantize_int8, quantize_int8


def test_adamw_converges_quadratic():
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros((3,))}
    opt = adamw_init(params)

    @jax.jit
    def step(p, o):
        g = jax.grad(lambda pp: jnp.sum((pp["w"] - target) ** 2))(p)
        return adamw_update(g, o, p, 5e-2, weight_decay=0.0)

    for _ in range(300):
        params, opt = step(params, opt)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    cn = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert abs(cn - 1.0) < 1e-4


def test_schedule_warmup_and_decay():
    sched = linear_warmup_cosine(1.0, warmup=10, total_steps=100)
    assert float(sched(jnp.asarray(0))) < 0.11
    assert abs(float(sched(jnp.asarray(10))) - 1.0) < 1e-5
    assert float(sched(jnp.asarray(100))) <= 0.11


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100), scale=st.floats(1e-3, 1e3))
def test_int8_quant_roundtrip_error(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1000,)) * scale
    q, s, shape, pad = quantize_int8(x)
    back = dequantize_int8(q, s, shape, pad)
    blockmax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(back - x))) <= blockmax / 127.0 + 1e-6


def test_error_feedback_accumulates():
    grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (512,)) * 0.1}
    ef = None
    for _ in range(3):
        newg, ef, rel = compress_with_error_feedback(grads, ef)
    assert float(rel) < 0.05
    # residual is bounded by one quantization step
    assert float(jnp.max(jnp.abs(ef["w"]))) < float(jnp.max(jnp.abs(grads["w"]))) / 64
