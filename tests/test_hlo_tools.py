"""Edge cases of the HLO text tooling (launch/hlo_cost.py, hlo_stats.py).

The static contract checker (repro.analysis) stands on these parsers, so
the degenerate inputs it can hit — empty modules, modules with no while
loop, multiple whiles (the continuous refill + chunk pair), gather-heavy
incremental-AFC bodies — must behave, not explode.  Synthetic HLO text
pins the parser semantics independent of the installed XLA's exact output;
a few real lowerings cover the integration.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo_lint
from repro.launch.hlo_cost import HloCost, analyze_hlo, while_costs
from repro.launch.hlo_stats import collect_collective_stats


# ----------------------------------------------------------- empty module
def test_empty_module_costs_nothing():
    cost = analyze_hlo("", 1)
    assert (cost.flops, cost.bytes, cost.link_bytes) == (0.0, 0.0, 0.0)
    assert while_costs("") == []
    stats = collect_collective_stats("", 1)
    assert stats.per_op_count == {} and stats.link_bytes == 0.0


def test_garbage_module_costs_nothing():
    text = "HloModule nonsense\n\nthis is not hlo at all\n"
    assert analyze_hlo(text, 1) == HloCost()
    assert while_costs(text) == []


# ------------------------------------------------------- module, no while
def test_module_without_while_loop():
    """A straight-line program: while_costs is empty (not an error), and the
    checker's planner probe correctly reports 'no while' as None."""
    w = jnp.ones((8, 4), jnp.float32)
    text = (
        jax.jit(lambda x: x @ w)
        .lower(jax.ShapeDtypeStruct((3, 8), jnp.float32))
        .compile()
        .as_text()
    )
    assert while_costs(text) == []
    assert hlo_lint.planner_body_cost(text) is None
    cost = analyze_hlo(text, 1)
    assert cost.flops > 0 or cost.bytes > 0  # still priced as a program


# ----------------------------------------------- multiple while loops
_TWO_WHILES = """\
HloModule two_whiles

%big_body (pb: (s32[], f32[4096])) -> (s32[], f32[4096]) {
  %pb = (s32[], f32[4096]) parameter(0)
  %ib = s32[] get-tuple-element(%pb), index=0
  %oneb = s32[] constant(1)
  %nib = s32[] add(%ib, %oneb)
  %vb = f32[4096] get-tuple-element(%pb), index=1
  %nvb = f32[4096] copy(%vb)
  ROOT %tb = (s32[], f32[4096]) tuple(%nib, %nvb)
}

%big_cond (pc: (s32[], f32[4096])) -> pred[] {
  %pc = (s32[], f32[4096]) parameter(0)
  %ic = s32[] get-tuple-element(%pc), index=0
  %limc = s32[] constant(7)
  ROOT %cmpc = pred[] compare(%ic, %limc), direction=LT
}

%small_body (ps: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ps = (s32[], f32[8]) parameter(0)
  %is = s32[] get-tuple-element(%ps), index=0
  %ones = s32[] constant(1)
  %nis = s32[] add(%is, %ones)
  %vs = f32[8] get-tuple-element(%ps), index=1
  %nvs = f32[8] copy(%vs)
  ROOT %ts = (s32[], f32[8]) tuple(%nis, %nvs)
}

%small_cond (pd: (s32[], f32[8])) -> pred[] {
  %pd = (s32[], f32[8]) parameter(0)
  %id = s32[] get-tuple-element(%pd), index=0
  %limd = s32[] constant(3)
  ROOT %cmpd = pred[] compare(%id, %limd), direction=LT
}

ENTRY %main (x: f32[4096], y: f32[8]) -> f32[8] {
  %x = f32[4096] parameter(0)
  %y = f32[8] parameter(1)
  %zero = s32[] constant(0)
  %init1 = (s32[], f32[4096]) tuple(%zero, %x)
  %w1 = (s32[], f32[4096]) while(%init1), condition=%big_cond, body=%big_body
  %init2 = (s32[], f32[8]) tuple(%zero, %y)
  %w2 = (s32[], f32[8]) while(%init2), condition=%small_cond, body=%small_body
  ROOT %o = f32[8] get-tuple-element(%w2), index=1
}
"""


def test_multiple_while_loops_each_reported():
    """Refill + chunk shape: two independent whiles, each with its own body
    cost and trip count — and the planner probe picks the expensive one."""
    costs = while_costs(_TWO_WHILES)
    assert len(costs) == 2
    by_body = {c["body"]: c for c in costs}
    assert set(by_body) == {"big_body", "small_body"}
    assert by_body["big_body"]["trips"] == 7
    assert by_body["small_body"]["trips"] == 3
    # per-trip body cost reflects the carried buffer width
    assert by_body["big_body"]["cost"].bytes > 100 * by_body["small_body"]["cost"].bytes
    probe = hlo_lint.planner_body_cost(_TWO_WHILES)
    assert probe is not None
    assert probe.bytes == by_body["big_body"]["cost"].bytes


def test_real_two_while_program_parses():
    """A lowered program with two genuinely separate while loops."""
    def f(x, y):
        x = jax.lax.fori_loop(0, 7, lambda i, v: v * 1.5, x)
        y = jax.lax.fori_loop(0, 3, lambda i, v: v + 1.0, y)
        return x.sum() + y.sum()

    text = (
        jax.jit(f)
        .lower(
            jax.ShapeDtypeStruct((4096,), jnp.float32),
            jax.ShapeDtypeStruct((8,), jnp.float32),
        )
        .compile()
        .as_text()
    )
    costs = while_costs(text)
    # XLA may unroll/fuse the tiny loop away, but the big one must survive
    assert len(costs) >= 1
    assert max(c["trips"] for c in costs) >= 1


# -------------------------------------------- gather-bytes (incremental)
_GATHER = """\
HloModule gather_probe

ENTRY %main (tab: f32[3,4096,4], idx: s32[3,1]) -> f32[3,4] {
  %tab = f32[3,4096,4] parameter(0)
  %idx = s32[3,1] parameter(1)
  ROOT %g = f32[3,4] gather(%tab, %idx), offset_dims={1}
}
"""


def test_gather_charges_addressed_rows_not_the_table():
    """The incremental-AFC promise lives here: an O(1) prefix lookup must
    bill the gathered rows + indices, NOT the (k, cap, 4) table it indexes
    — otherwise every while body would look O(cap) and the flatness
    contract could never hold."""
    cost = analyze_hlo(_GATHER, 1)
    idx_bytes = 3 * 1 * 4
    result_bytes = 3 * 4 * 4
    table_bytes = 3 * 4096 * 4 * 4
    assert cost.bytes == pytest.approx(idx_bytes + 2 * result_bytes)
    assert cost.bytes < table_bytes / 100


def test_incremental_body_gathers_stay_flat_across_cap():
    """Integration: the real incremental executor's while body is priced
    cap-independent (the contract checker's flatness probe in miniature)."""
    from repro.core.executor_fused import build_fused_executor

    def body_bytes(cap):
        w = jnp.asarray([1.0, -2.0, 0.5])
        fused = build_fused_executor(
            lambda rows, exact: rows @ w,
            k=3, task="regression", m=16, m_sobol=8, max_iters=8, n_boot=16,
            afc_backend="incremental",
        )
        args = (
            jax.ShapeDtypeStruct((3, cap), jnp.float32),
            jax.ShapeDtypeStruct((3,), jnp.int32),
            jax.ShapeDtypeStruct((3,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((0,), jnp.float32),
        )
        text = jax.jit(fused).lower(*args).compile().as_text()
        probe = hlo_lint.planner_body_cost(text)
        assert probe is not None
        return probe.bytes

    small, big = body_bytes(1024), body_bytes(8192)
    assert big <= 1.3 * small


# --------------------------------------------------- collective stats
_COLLECTIVE = """\
HloModule coll

ENTRY %main (x: f32[1024]) -> f32[1024] {
  %x = f32[1024] parameter(0)
  ROOT %ar = f32[1024] all-reduce(%x), replica_groups=[2,4], to_apply=%sum
}
"""


def test_collective_stats_ring_weighting():
    stats = collect_collective_stats(_COLLECTIVE, 8)
    assert stats.per_op_count == {"all-reduce": 1}
    buf = 1024 * 4
    assert stats.per_op_bytes["all-reduce"] == pytest.approx(buf)
    # iota groups [2,4]: group size 4 -> ring all-reduce 2*(g-1)/g
    assert stats.link_bytes == pytest.approx(2.0 * 3 / 4 * buf)


def test_collective_stats_ignore_non_collective_lines():
    text = (
        "HloModule none\n\nENTRY %m (x: f32[64]) -> f32[64] {\n"
        "  %x = f32[64] parameter(0)\n"
        "  ROOT %y = f32[64] add(%x, %x)\n}\n"
    )
    stats = collect_collective_stats(text, 4)
    assert stats.per_op_count == {}
    assert stats.link_bytes == 0.0


def test_empty_group_defaults_to_n_devices():
    text = (
        "HloModule d\n\nENTRY %m (x: f32[256]) -> f32[256] {\n"
        "  %x = f32[256] parameter(0)\n"
        "  ROOT %ag = f32[256] all-gather(%x), dimensions={0}\n}\n"
    )
    stats = collect_collective_stats(text, 8)
    buf = 256 * 4
    # no replica_groups annotation: group size falls back to n_devices
    assert stats.link_bytes == pytest.approx((8 - 1) / 8 * buf)
