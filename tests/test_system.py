"""End-to-end behaviour of the whole system (replaces the scaffold stub).

The paper's acceptance criteria, checked live:
  1. Biathlon returns within the error bound vs the exact baseline (Eq. 1)
     at rate >= tau across a request log,
  2. it touches a small fraction of the data (the speedup driver),
  3. the trainer substrate trains a real (reduced) LM with checkpoint/resume.
"""
import numpy as np

from repro.core.executor import BiathlonConfig
from repro.data.synthetic import make_pipeline
from repro.serving import BiathlonServer


def test_end_to_end_serving_guarantee_and_savings():
    b = make_pipeline(
        "trip_fare", rows_per_group=2000, n_train_groups=120,
        n_serve_groups=6, n_requests=6,
    )
    srv = BiathlonServer(b, BiathlonConfig(m=256, m_sobol=64), mode="host")
    stats = srv.serve_all(b.requests)
    s = stats.summary(b.pipeline.delta_default, b.pipeline.task)
    assert s["guarantee_rate"] >= 0.66        # tau=.95, n=6: allow 2 misses
    assert s["mean_sample_frac"] < 0.6        # way less than exact
    # predictions correlate with exact baseline
    r = np.corrcoef(stats.y_hats, stats.y_exacts)[0, 1]
    assert r > 0.95


def test_end_to_end_training_with_restart(tmp_path):
    from repro.configs import get_config
    from repro.models.lm import LM
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config("qwen1.5-0.5b").reduced()
    model = LM(cfg, remat=False, attn_block=64, loss_chunk=32)
    tc = TrainerConfig(batch_size=4, seq_len=64, total_steps=16, save_every=8, lr=1e-3)
    tr = Trainer(model, str(tmp_path), tc)
    _, hist = tr.run(steps=9)                 # past first checkpoint
    tr2 = Trainer(model, str(tmp_path), tc)   # simulated preemption
    _, hist2 = tr2.run()
    assert hist2[0]["step"] == 8
    assert hist2[-1]["step"] == 15
    assert np.isfinite([h["loss"] for h in hist2]).all()
