"""End-to-end training driver: train a ~100M-param LM for a few hundred steps.

Exercises the full training substrate on CPU: deterministic data pipeline,
pure-JAX AdamW, remat, atomic checkpoints with auto-resume (kill it halfway
and re-run — it continues from the last checkpoint).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--d-model 512]
"""
import argparse
import dataclasses
import time

from repro.configs import get_config
from repro.models.lm import LM
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: a shrunk qwen-style dense decoder
    cfg = dataclasses.replace(
        get_config("qwen1.5-0.5b"),
        n_layers=args.layers,
        d_model=args.d_model,
        n_heads=8,
        n_kv_heads=8,
        d_ff=args.d_model * 3,
        vocab=32000,
        head_dim=None,
        pad_heads_to=1,
    )
    model = LM(cfg, remat=True, attn_block=128, loss_chunk=128)
    n_params = cfg.param_count()
    print(f"training {n_params/1e6:.0f}M-param LM for {args.steps} steps "
          f"(seq={args.seq}, batch={args.batch})")

    tc = TrainerConfig(
        batch_size=args.batch, seq_len=args.seq, total_steps=args.steps,
        save_every=max(args.steps // 4, 10), lr=3e-4, warmup=20,
    )
    trainer = Trainer(model, args.ckpt, tc)
    t0 = time.time()
    state, history = trainer.run()
    dt = time.time() - t0
    if not history:
        print("nothing to do (checkpointed run already finished) — "
              f"latest step {trainer.manager.latest_step()}")
        return
    first, last = history[0], history[-1]
    tok_s = args.batch * args.seq * len(history) / dt
    print(f"steps {first['step']}..{last['step']}: "
          f"loss {first['loss']:.3f} -> {last['loss']:.3f} "
          f"({tok_s:.0f} tok/s on CPU)")
    print(f"checkpoints: {trainer.manager.steps()} in {args.ckpt}")
    assert last["loss"] < first["loss"], "loss must decrease"


if __name__ == "__main__":
    main()
