"""Quickstart: serve one inference pipeline with Biathlon.

Builds the Trip-Fare pipeline (synthetic NYC-taxi-like data, GBDT model
trained in-repo), then serves a request log two ways:

  * exact baseline — every aggregate over all rows (the paper's `Y`),
  * Biathlon       — adaptive approximate aggregation with the Eq. 1
                     guarantee Pr(|Y - y| <= delta) >= tau.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core.executor import BiathlonConfig, HostLoopExecutor, run_exact
from repro.data.synthetic import make_pipeline


def main():
    print("building trip_fare pipeline (synthetic, ~1.4M rows)...")
    bundle = make_pipeline(
        "trip_fare", rows_per_group=40000, n_train_groups=200,
        n_serve_groups=6, n_requests=8,
    )
    pipe, store = bundle.pipeline, bundle.store
    delta, tau = pipe.delta_default, 0.95
    print(f"model=GBDT  k={pipe.k} aggregate features  "
          f"delta=MAE={delta:.3f}  tau={tau}")

    executor = HostLoopExecutor(store, BiathlonConfig(m=500, m_sobol=128))
    # warm the jit caches so timings reflect steady-state serving
    executor.run(pipe, bundle.requests[0], jax.random.PRNGKey(99))
    run_exact(store, pipe, bundle.requests[0])

    print(f"\n{'req':>4} {'exact':>10} {'biathlon':>10} {'err':>8} "
          f"{'frac':>6} {'iters':>5} {'t_exact':>8} {'t_bia':>8}")
    errs, fracs, speedups = [], [], []
    for i, req in enumerate(bundle.requests):
        y_exact, t_exact = run_exact(store, pipe, req)
        r = executor.run(pipe, req, jax.random.PRNGKey(i))
        err = abs(r.y_hat - y_exact)
        errs.append(err)
        fracs.append(r.sample_fraction)
        speedups.append(t_exact / r.t_total)
        print(f"{i:>4} {y_exact:>10.3f} {r.y_hat:>10.3f} {err:>8.3f} "
              f"{r.sample_fraction:>6.3f} {r.iters:>5} "
              f"{t_exact*1e3:>7.1f}ms {r.t_total*1e3:>7.1f}ms")

    within = np.mean([e <= delta for e in errs])
    print(f"\nguarantee satisfied: {within:.0%} of requests (target >= {tau:.0%})")
    print(f"mean data touched:   {np.mean(fracs):.1%} of rows "
          f"(I/O-bound speedup bound: {1/np.mean(fracs):.1f}x)")
    print(f"mean wall speedup:   {np.mean(speedups):.2f}x on this CPU container")


if __name__ == "__main__":
    main()
