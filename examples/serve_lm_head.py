"""Biathlon on an LM pipeline: approximate aggregation features feeding a
prediction head over frozen backbone features (DESIGN.md §Arch-applicability).

Scenario: a click-through scorer — the request's prompt runs ONCE through a
(reduced) qwen backbone; user-history aggregates (avg dwell time, click
count, engagement std over a large event log) are Biathlon-approximated and
feed a small MLP head together with the pooled backbone state.  Uncertainty
propagates through the *head* only (m QMC evals of a tiny MLP), exactly the
adaptation rule the paper's §5 caveat implies for deep pipelines.

Run:  PYTHONPATH=src python examples/serve_lm_head.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.executor_fused import build_fused_executor
from repro.data.store import ColumnStore, build_table
from repro.models.lm import LM
from repro.models.tabular import MLP


def main():
    rng = np.random.default_rng(0)
    # --- event log: 40 users x 50k events ---------------------------------
    G, R = 40, 50000
    gid = np.repeat(np.arange(G), R)
    engage = rng.normal(rng.normal(0, 1, G)[gid], 1.0)
    dwell = np.abs(rng.normal(3.0, 1.0, G)[gid] + rng.normal(0, 0.5, G * R))
    clicked = (rng.random(G * R) < rng.uniform(0.05, 0.4, G)[gid]).astype(np.float32)
    store = ColumnStore().add(
        "events", build_table({"engage": engage, "dwell": dwell, "click": clicked}, gid)
    )
    k = 3  # avg(engage), avg(dwell), count(click)

    # --- frozen LM backbone ------------------------------------------------
    cfg = get_config("qwen1.5-0.5b").reduced()
    lm = LM(cfg, remat=False, attn_block=64, loss_chunk=32)
    params = lm.init(jax.random.PRNGKey(0))

    @jax.jit
    def pooled_state(tokens):
        x = params["embed"][jnp.clip(tokens, 0, lm.vp - 1)].astype(lm.dtype)
        h = lm._backbone(params, x)
        return h.mean(axis=1).astype(jnp.float32)  # (B, D)

    # --- feature scaler from population statistics -------------------------
    # (like the tabular pipelines: the head consumes standardized aggregates)
    pop = np.stack(
        [
            [store["events"].full_values(c, g).mean() if c != "click"
             else store["events"].full_values(c, g).sum() for g in range(G)]
            for c in ("engage", "dwell", "click")
        ],
        axis=1,
    )  # (G, k)
    agg_mean = jnp.asarray(pop.mean(0), jnp.float32)
    agg_std = jnp.asarray(np.maximum(pop.std(0), 1e-6), jnp.float32)

    # --- head: MLP over [backbone_state; scaled agg features] --------------
    d = cfg.d_model
    head = MLP(hidden=(32,), task="regression", epochs=10, seed=1)
    Xh = np.concatenate(
        [rng.normal(0, 0.05, (2000, d)), rng.normal(0, 1, (2000, k))], axis=1
    ).astype(np.float32)
    yh = 2.0 * Xh[:, d] - 0.5 * Xh[:, d + 1] + Xh[:, d + 2] + 0.05 * Xh[:, :8].sum(1)
    head.fit(Xh, yh)

    # --- Biathlon executor over the head -----------------------------------
    def model_fn(agg_rows, backbone_vec):
        m = agg_rows.shape[0]
        scaled = (agg_rows - agg_mean[None, :]) / agg_std[None, :]
        full = jnp.concatenate(
            [jnp.broadcast_to(backbone_vec[None, :], (m, d)), scaled], axis=1
        )
        return head.predict(full)

    fused = build_fused_executor(
        model_fn, k=k, task="regression", m=400, m_sobol=96, tau=0.95
    )
    agg_ids = jnp.asarray([0, 0, 2], jnp.int32)  # avg, avg, count

    print("serving 6 requests (backbone runs once; Biathlon approximates the "
          "history aggregates feeding the head):")
    for i in range(6):
        user = int(rng.integers(0, G))
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, 48)), jnp.int32)
        t0 = time.perf_counter()
        state = pooled_state(tokens)[0]
        cap = 65536
        bufs, _ = store.request_buffers(
            [("events", "engage", user), ("events", "dwell", user),
             ("events", "click", user)], cap,
        )
        n = jnp.asarray([R, R, R], jnp.int32)
        res = fused(bufs, n, agg_ids, jnp.asarray(0.25, jnp.float32), state)
        dt = time.perf_counter() - t0
        print(f"  user {user:>3}: score={float(res.y_hat):7.3f} "
              f"prob={float(res.prob):.3f} iters={int(res.iters)} "
              f"frac={float(res.samples_used)/(3*R):.3f} t={dt*1e3:.1f}ms")


if __name__ == "__main__":
    main()
