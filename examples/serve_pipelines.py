"""Batched serving across all seven paper pipelines, host vs fused executor.

Drains each pipeline's request log through the BiathlonServer and prints the
paper's §4 metrics (latency, speedup, sample fraction, guarantee rate), then
compares the paper-faithful host loop against the fused single-XLA-program
executor on the parametric pipelines.

Run:  PYTHONPATH=src python examples/serve_pipelines.py [--full]
"""
import argparse

from repro.core.executor import BiathlonConfig
from repro.data.synthetic import PIPELINE_NAMES, make_pipeline
from repro.serving import BiathlonServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="benchmark-scale groups")
    args = ap.parse_args()
    scale = (
        dict(rows_per_group=40000, n_train_groups=200, n_serve_groups=6, n_requests=8)
        if args.full
        else dict(rows_per_group=4000, n_train_groups=120, n_serve_groups=4, n_requests=5)
    )
    cfg = BiathlonConfig(m=400, m_sobol=96)

    print(f"{'pipeline':20s} {'mode':6s} {'lat_ms':>8} {'exact_ms':>9} "
          f"{'speedup':>8} {'frac':>6} {'guar':>5}")
    for name in PIPELINE_NAMES:
        bundle = make_pipeline(name, **scale)
        task = bundle.pipeline.task
        delta = bundle.pipeline.delta_default
        srv = BiathlonServer(bundle, cfg, mode="host")
        srv.serve(bundle.requests[0])  # warm
        stats = srv.serve_all(bundle.requests)
        s = stats.summary(delta, task)
        print(f"{name:20s} {'host':6s} {s['mean_latency_s']*1e3:>8.1f} "
              f"{s['mean_exact_latency_s']*1e3:>9.1f} {s['speedup']:>8.2f} "
              f"{s['mean_sample_frac']:>6.3f} {s['guarantee_rate']:>5.2f}")
        # fused executor supports the parametric-aggregate pipelines
        try:
            srv_f = BiathlonServer(bundle, cfg, mode="fused")
        except ValueError:
            continue
        srv_f.serve(bundle.requests[0])
        stats_f = srv_f.serve_all(bundle.requests)
        s_f = stats_f.summary(delta, task)
        print(f"{'':20s} {'fused':6s} {s_f['mean_latency_s']*1e3:>8.1f} "
              f"{s_f['mean_exact_latency_s']*1e3:>9.1f} {s_f['speedup']:>8.2f} "
              f"{s_f['mean_sample_frac']:>6.3f} {s_f['guarantee_rate']:>5.2f}")


if __name__ == "__main__":
    main()
