"""Fig. 7: speedup/accuracy vs error bound delta (regression only)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import DEFAULT_CFG, bundle, csv_row, serve_log, summarize
from repro.core.executor import BiathlonConfig

PIPES = ("trip_fare", "tick_price", "battery", "turbofan")
MULTS = (0.25, 0.5, 1.0, 2.0, 4.0)


def run(pipelines=PIPES, mults=MULTS) -> list[str]:
    out = []
    for name in pipelines:
        b = bundle(name)
        base_delta = b.pipeline.delta_default
        for mlt in mults:
            cfg = BiathlonConfig(delta=base_delta * mlt, **DEFAULT_CFG)
            rows = serve_log(b, cfg)
            s = summarize(rows, base_delta * mlt, "regression")
            err = np.array([abs(r["y_hat"] - r["y_exact"]) for r in rows])
            out.append(
                csv_row(
                    f"fig7/{name}/delta={mlt}xMAE",
                    s["latency_ms"] * 1e3,
                    f"speedup={s['speedup']:.2f};frac={s['frac']:.3f};"
                    f"err_vs_exact={err.mean():.4f};guarantee={s['guarantee_rate']:.2f}",
                )
            )
    return out
