"""§Roofline: aggregate the dry-run JSONs into the per-cell roofline table.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
one CSV row per (arch x shape x mesh) cell with the three roofline terms,
the dominant bottleneck, and the useful-FLOP ratio.  Also writes the
markdown table consumed by EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv_row

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "../experiments/dryrun")


def load_records(mesh: str | None = None) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def markdown_table(mesh: str = "16x16") -> str:
    rows = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "useful_flops | roofline_frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load_records(mesh):
        if r.get("status") == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | - | - | - | skipped | - | - |"
            )
            continue
        t = r["terms"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"{t['dominant'].replace('_s','')} | {t['useful_flop_ratio']:.2f} | "
            f"{t['roofline_fraction']:.4f} |"
        )
    return "\n".join(rows)


def run() -> list[str]:
    out = []
    for r in load_records():
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("status") == "skipped":
            out.append(csv_row(name, 0.0, f"skipped:{r['reason'][:40]}"))
            continue
        t = r["terms"]
        step_s = max(t["compute_s"], t["memory_s"], t["collective_s"])
        out.append(
            csv_row(
                name,
                step_s * 1e6,
                f"dominant={t['dominant']};compute_s={t['compute_s']:.3e};"
                f"memory_s={t['memory_s']:.3e};collective_s={t['collective_s']:.3e};"
                f"useful={t['useful_flop_ratio']:.2f};"
                f"roofline_frac={t['roofline_fraction']:.4f}",
            )
        )
    return out
