"""Fig. 5: latency breakdown (AFC / AMI / Planner) per pipeline."""
from __future__ import annotations

import numpy as np

from benchmarks.common import DEFAULT_CFG, bundle, csv_row, serve_log
from repro.core.executor import BiathlonConfig
from repro.data.synthetic import PIPELINE_NAMES


def run(pipelines=PIPELINE_NAMES) -> list[str]:
    out = []
    for name in pipelines:
        b = bundle(name)
        rows = serve_log(b, BiathlonConfig(**DEFAULT_CFG))
        afc = np.mean([r["t_afc"] for r in rows])
        ami = np.mean([r["t_ami"] for r in rows])
        pl = np.mean([r["t_planner"] for r in rows])
        tot = np.mean([r["t"] for r in rows])
        out.append(
            csv_row(
                f"fig5/{name}",
                tot * 1e6,
                f"afc%={100*afc/tot:.0f};ami%={100*ami/tot:.0f};"
                f"planner%={100*pl/tot:.0f};iters={np.mean([r['iters'] for r in rows]):.1f}",
            )
        )
    return out
