"""Shared benchmark infrastructure: bundle cache, warmup, CSV rows, JSON log."""
from __future__ import annotations

import functools
import json
import pathlib
import time

import jax
import numpy as np

from repro.core.executor import BiathlonConfig, HostLoopExecutor, run_exact
from repro.data.synthetic import make_pipeline, make_pipeline_median

# Benchmark scale: groups big enough that exact aggregation dominates the
# request (the paper's regime: 3B-row tables behind ClickHouse).  Reduce via
# QUICK=1 env for smoke runs.
import os

QUICK = os.environ.get("QUICK", "0") == "1"
SCALE = dict(
    rows_per_group=4000 if QUICK else 60000,
    n_train_groups=120 if QUICK else 250,
    n_serve_groups=4 if QUICK else 6,
    n_requests=4 if QUICK else 10,
)
DEFAULT_CFG = dict(m=256 if QUICK else 500, m_sobol=64 if QUICK else 128)


@functools.lru_cache(maxsize=None)
def bundle(name: str, median: bool = False, seed: int = 0):
    fn = make_pipeline_median if median else make_pipeline
    return fn(name, seed=seed, **SCALE)


def serve_log(b, config: BiathlonConfig, n_requests: int | None = None, warmup: int = 1):
    """Run the request log through host-loop Biathlon + exact baseline."""
    ex = HostLoopExecutor(b.store, config)
    reqs = b.requests[: n_requests or len(b.requests)]
    # warmup: compile all bucket shapes on a throwaway request
    for w in range(warmup):
        ex.run(b.pipeline, reqs[0], jax.random.PRNGKey(10_000 + w))
        run_exact(b.store, b.pipeline, reqs[0])
    rows = []
    for i, req in enumerate(reqs):
        y_ex, t_ex = run_exact(b.store, b.pipeline, req)
        r = ex.run(b.pipeline, req, jax.random.PRNGKey(i))
        rows.append(
            dict(
                y_hat=r.y_hat, y_exact=y_ex, t=r.t_total, t_exact=t_ex,
                iters=r.iters, frac=r.sample_fraction, prob=r.prob,
                t_afc=r.t_afc, t_ami=r.t_ami, t_planner=r.t_planner,
            )
        )
    return rows


def summarize(rows, delta: float, task: str) -> dict:
    t = np.array([r["t"] for r in rows])
    te = np.array([r["t_exact"] for r in rows])
    err = np.array([abs(r["y_hat"] - r["y_exact"]) for r in rows])
    ok = err <= (delta + 1e-9 if task == "regression" else 1e-9)
    frac = float(np.mean([r["frac"] for r in rows]))
    return dict(
        latency_ms=1e3 * t.mean(),
        exact_ms=1e3 * te.mean(),
        speedup=te.mean() / t.mean(),
        # the paper's regime: datastore scan I/O dominates, so the speedup
        # bound is the inverse touched-fraction (our CPU wall-clock also pays
        # jit dispatch the paper's C++/ClickHouse stack does not)
        io_bound_speedup=1.0 / max(frac, 1e-9),
        frac=frac,
        iters=float(np.mean([r["iters"] for r in rows])),
        guarantee_rate=float(ok.mean()),
        err=float(err.mean()),
    )


def accuracy(b, y_hats: np.ndarray, labels: np.ndarray | None = None) -> float:
    """Paper metric: r2 (regression) / accuracy (classification) vs labels."""
    y = labels if labels is not None else b.labels
    y_hats = np.asarray(y_hats, np.float64)
    if b.pipeline.task == "regression":
        ss = np.var(y)
        return float(1.0 - np.mean((y_hats - y) ** 2) / max(ss, 1e-12))
    return float(np.mean((y_hats > 0.5).astype(np.float64) == y))


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


# --------------------------------------------------------------------------
# Machine-readable perf trajectory (tracked across PRs)
# --------------------------------------------------------------------------
BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_fused.json"


def write_bench_json(section: str, payload: dict, path: str | None = None) -> None:
    """Merge ``payload`` under ``section`` in BENCH_fused.json at the repo root.

    Sections are overwritten wholesale; other sections are preserved, so
    individual benchmarks can update their slice independently.
    """
    p = pathlib.Path(path) if path else BENCH_JSON
    data: dict = {}
    if p.exists():
        try:
            data = json.loads(p.read_text())
        except (json.JSONDecodeError, OSError):
            data = {}
    data[section] = payload
    p.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")


def latency_stats(seconds: list[float] | np.ndarray) -> dict:
    """mean/p50/p99 in microseconds — the BENCH_fused.json latency contract."""
    t = np.asarray(seconds, np.float64) * 1e6
    return {
        "mean_us": float(t.mean()),
        "p50_us": float(np.percentile(t, 50)),
        "p99_us": float(np.percentile(t, 99)),
        "n": int(t.size),
    }


def timed(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") or hasattr(out, "shape") else None
    return (time.perf_counter() - t0) / reps * 1e6, out
