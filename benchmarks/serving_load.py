"""Arrival-driven serving load curves: throughput / latency vs Poisson rate.

Drives the fixed-lane serving runtime (serving/runtime.py) with open-loop
Poisson arrival traces at several rates around the server's measured
saturation point, and records the provisioning curve InferLine-style
pipeline serving needs: per-rate throughput, p50/p99 latency, queueing
delay vs execution time, batch fill, and the compile count (which must stay
at one executable per power-of-two cap bucket regardless of batch fill —
the fixed-lane property).

Rates are chosen RELATIVE to measured FULL-BATCH capacity (``batch_size /
full_batch_service_time``, the per-lane-amortized best case) so the curve
shape is machine-independent; absolute rates are recorded in the payload.
Note the batch cost is nearly fill-invariant (a 2-lane batch costs almost
as much as a full one), so effective capacity at low arrival rates — where
admission fills are small — is WELL below the full-batch number: expect
high utilization even at the lowest load factor.  The saturation signal to
read is queueing delay and throughput plateau, not utilization.
Writes ``BENCH_serving.json`` at the repo root.
"""
from __future__ import annotations

import pathlib
import time

from benchmarks.common import DEFAULT_CFG, bundle, csv_row, write_bench_json
from repro.core.executor import BiathlonConfig
from repro.data.synthetic import poisson_arrivals
from repro.serving import BatchedFusedServer, ServingRuntime

BENCH_SERVING_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serving.json"

PIPE = "turbofan"
BATCH_SIZE = 8
MAX_WAIT_MS = 20.0
# offered load as a fraction of full-batch (per-lane-amortized) capacity;
# see the module docstring for why 0.3x is not "30% utilization"
LOAD_FACTORS = (0.3, 1.0, 3.0)
N_REQUESTS = 48


def _measure_capacity(srv: BatchedFusedServer, requests: list[dict]) -> float:
    """Steady-state full-batch service rate (req/s), post-warmup."""
    batch = [requests[i % len(requests)] for i in range(srv.batch_size)]
    srv.serve_batch(batch)  # warm every shape this batch hits
    reps = 3
    t0 = time.perf_counter()
    for _ in range(reps):
        srv.serve_batch(batch)
    dt = (time.perf_counter() - t0) / reps
    return srv.batch_size / max(dt, 1e-9)


def run(pipeline: str = PIPE) -> list[str]:
    out = []
    cfg = BiathlonConfig(**DEFAULT_CFG)
    b = bundle(pipeline)
    srv = BatchedFusedServer(b, cfg, batch_size=BATCH_SIZE)
    runtime = ServingRuntime(srv, max_wait_s=MAX_WAIT_MS / 1e3)
    runtime.warmup(b.requests)

    capacity_rps = _measure_capacity(srv, b.requests)
    payload = {
        "pipeline": pipeline,
        "batch_size": BATCH_SIZE,
        "max_wait_ms": MAX_WAIT_MS,
        "n_requests_per_rate": N_REQUESTS,
        "capacity_rps": capacity_rps,
        "config": {"m": cfg.m, "m_sobol": cfg.m_sobol, "tau": cfg.tau},
        "rates": [],
    }
    for j, lf in enumerate(LOAD_FACTORS):
        rate = lf * capacity_rps
        arrivals = poisson_arrivals(b.requests, rate, n=N_REQUESTS, seed=100 + j)
        stats = runtime.run(arrivals, warmup=False)
        s = stats.summary()
        s["load_factor"] = lf
        s["rate_rps"] = rate
        payload["rates"].append(s)
        out.append(
            csv_row(
                f"serving_load/{pipeline}/x{lf:g}",
                1e3 * s["p50_latency_ms"],
                f"rate={rate:.1f}rps;thru={s['throughput_rps']:.1f}rps;"
                f"p99_ms={s['p99_latency_ms']:.1f};"
                f"qdelay_ms={s['mean_queue_delay_ms']:.1f};"
                f"fill={s['mean_batch_fill']:.1f};"
                f"compiles={s['compile_count']}",
            )
        )
    # fixed lanes: the whole sweep (fills 1..batch_size across all rates)
    # may only ever compile one executable per cap bucket
    payload["total_compile_count"] = srv.compile_count
    payload["compiled_buckets"] = srv.compiled_buckets
    write_bench_json("serving_load", payload, path=str(BENCH_SERVING_JSON))
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run():
        print(row)
