"""Arrival-driven serving load curves: throughput / latency vs Poisson rate.

Drives the fixed-lane serving runtime (serving/runtime.py) with open-loop
Poisson arrival traces at several rates around the server's measured
saturation point, and records the provisioning curve InferLine-style
pipeline serving needs: per-rate throughput, p50/p99 latency, queueing
delay vs execution time, batch fill, and the compile count (which must stay
at one executable per power-of-two cap bucket regardless of batch fill —
the fixed-lane property).

Rates are chosen RELATIVE to measured FULL-BATCH capacity (``batch_size /
full_batch_service_time``, the per-lane-amortized best case) so the curve
shape is machine-independent; absolute rates are recorded in the payload.
Note the batch cost is nearly fill-invariant (a 2-lane batch costs almost
as much as a full one), so effective capacity at low arrival rates — where
admission fills are small — is WELL below the full-batch number: expect
high utilization even at the lowest load factor.  The saturation signal to
read is queueing delay and throughput plateau, not utilization.

The ``--sharded-worker`` half sweeps SERVING-MESH device counts at fixed
batch size (the PR-4 lane-sharding backend): each admission batch's lanes
are partitioned over a 1-D mesh, so a device only runs its own lane block's
while-loop — stragglers stall 1/D of the batch instead of all of it, and
the per-device programs execute concurrently.  The sweep needs
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` BEFORE jax
initializes, so the parent entrypoint re-execs itself into a worker
subprocess pinned to CPU with that flag set — the sweep is always a
host-device SIMULATION (a real TPU deployment hands
``make_serving_mesh`` over its actual chips to ``BatchedFusedServer``
instead of re-execing).  A tighter-than-default delta makes iteration
counts heterogeneous across lanes — the regime where straggler
localization pays.
Writes ``BENCH_serving.json`` at the repo root.
"""
from __future__ import annotations

import pathlib
import subprocess
import sys
import time

from benchmarks.common import DEFAULT_CFG, bundle, csv_row, write_bench_json
from repro.core.executor import BiathlonConfig
from repro.data.synthetic import poisson_arrivals
from repro.serving import BatchedFusedServer, ServingRuntime

BENCH_SERVING_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serving.json"

PIPE = "turbofan"
BATCH_SIZE = 8
MAX_WAIT_MS = 20.0
# offered load as a fraction of full-batch (per-lane-amortized) capacity;
# see the module docstring for why 0.3x is not "30% utilization"
LOAD_FACTORS = (0.3, 1.0, 3.0)
N_REQUESTS = 48

# ---- sharded lane-parallel sweep (run in the forced-device subprocess) ----
DEVICE_COUNTS = (1, 2, 4, 8)
# fraction of the pipeline's default delta: tight enough that requests
# iterate a heterogeneous number of times (the straggler regime)
SHARDED_DELTA_FRAC = 0.35
SHARDED_RATE_FACTOR = 3.0  # offered load vs 1-device capacity (saturating)


def _measure_capacity(
    srv: BatchedFusedServer, requests: list[dict], reps: int = 3,
    best_of: bool = False,
) -> float:
    """Steady-state full-batch service rate (req/s), post-warmup.

    ``best_of=False`` keeps the mean-of-reps methodology the tracked
    ``serving_load`` section of BENCH_serving.json was measured with (so
    re-runs stay comparable across PRs); the sharded sweep uses best-of to
    suppress 2-core scheduling noise and records that choice in its
    payload.
    """
    batch = [requests[i % len(requests)] for i in range(srv.batch_size)]
    srv.serve_batch(batch)  # warm every shape this batch hits
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        srv.serve_batch(batch)
        times.append(time.perf_counter() - t0)
    dt = min(times) if best_of else sum(times) / len(times)
    return srv.batch_size / max(dt, 1e-9)


def run(pipeline: str = PIPE) -> list[str]:
    out = []
    cfg = BiathlonConfig(**DEFAULT_CFG)
    b = bundle(pipeline)
    srv = BatchedFusedServer(b, cfg, batch_size=BATCH_SIZE)
    runtime = ServingRuntime(srv, max_wait_s=MAX_WAIT_MS / 1e3)
    runtime.warmup(b.requests)

    capacity_rps = _measure_capacity(srv, b.requests)
    payload = {
        "pipeline": pipeline,
        "batch_size": BATCH_SIZE,
        "max_wait_ms": MAX_WAIT_MS,
        "n_requests_per_rate": N_REQUESTS,
        "capacity_rps": capacity_rps,
        "config": {"m": cfg.m, "m_sobol": cfg.m_sobol, "tau": cfg.tau},
        "rates": [],
    }
    for j, lf in enumerate(LOAD_FACTORS):
        rate = lf * capacity_rps
        arrivals = poisson_arrivals(b.requests, rate, n=N_REQUESTS, seed=100 + j)
        stats = runtime.run(arrivals, warmup=False)
        s = stats.summary()
        s["load_factor"] = lf
        s["rate_rps"] = rate
        payload["rates"].append(s)
        out.append(
            csv_row(
                f"serving_load/{pipeline}/x{lf:g}",
                1e3 * s["p50_latency_ms"],
                f"rate={rate:.1f}rps;thru={s['throughput_rps']:.1f}rps;"
                f"p99_ms={s['p99_latency_ms']:.1f};"
                f"qdelay_ms={s['mean_queue_delay_ms']:.1f};"
                f"fill={s['mean_batch_fill']:.1f};"
                f"compiles={s['compile_count']}",
            )
        )
    # fixed lanes: the whole sweep (fills 1..batch_size across all rates)
    # may only ever compile one executable per cap bucket
    payload["total_compile_count"] = srv.compile_count
    payload["compiled_buckets"] = srv.compiled_buckets
    write_bench_json("serving_load", payload, path=str(BENCH_SERVING_JSON))
    return out


# ------------------------------------------------------------------------
# SLO-aware graceful degradation: latency/guarantee Pareto + 3x overload
# ------------------------------------------------------------------------
# per-request latency budgets as multiples of the full-batch service time;
# tighter budgets buy latency with accuracy (looser knobs) and shed rate
SLO_FACTORS = (4.0, 8.0, 16.0, 32.0)
OVERLOAD_FACTOR = 3.0
N_REQUESTS_SLO = 64


def run_adaptive_slo(pipeline: str = PIPE) -> list[str]:
    """Deadline-driven degradation under 3x overload (BENCH adaptive_slo).

    The same saturating Poisson trace (3x measured full-batch capacity) is
    replayed once WITHOUT degradation — the PR-2 behavior, queue delay
    absorbing the whole overload, p99 growing with trace length — and then
    across a sweep of per-request SLO budgets with the knob-tier admission
    controller installed.  Each sweep point reports the latency/guarantee
    trade InferLine/Loki frame: p99 over served requests, achieved
    guarantee rate (each request judged against the tau it was actually
    served under), shed rate, and mean knob tier.  The fixed-lane compile
    contract must hold throughout: knob changes and fill variation are
    traced data, so every sweep point asserts ZERO new executables.
    """
    from repro.serving import DegradationController, default_tiers

    cfg = BiathlonConfig(**DEFAULT_CFG)
    b = bundle(pipeline)
    srv = BatchedFusedServer(b, cfg, batch_size=BATCH_SIZE)
    runtime = ServingRuntime(srv, max_wait_s=MAX_WAIT_MS / 1e3)
    runtime.warmup(b.requests)
    capacity_rps = _measure_capacity(srv, b.requests)
    service_s = BATCH_SIZE / capacity_rps
    rate = OVERLOAD_FACTOR * capacity_rps
    arrivals = poisson_arrivals(b.requests, rate, n=N_REQUESTS_SLO, seed=424)

    out = []
    payload = {
        "pipeline": pipeline,
        "batch_size": BATCH_SIZE,
        "max_wait_ms": MAX_WAIT_MS,
        "n_requests": N_REQUESTS_SLO,
        "capacity_rps": capacity_rps,
        "full_batch_service_ms": 1e3 * service_s,
        "overload_factor": OVERLOAD_FACTOR,
        "rate_rps": rate,
        "config": {"m": cfg.m, "m_sobol": cfg.m_sobol, "tau": cfg.tau},
        "pareto": [],
    }
    # -- baseline: no degradation, queue absorbs the 3x overload unboundedly
    base = runtime.run(arrivals, warmup=False).summary()
    payload["overload_baseline"] = {
        k: base[k]
        for k in (
            "n", "p50_latency_ms", "p99_latency_ms", "mean_queue_delay_ms",
            "guarantee_rate", "shed_rate", "compile_count",
        )
    }
    out.append(
        csv_row(
            f"adaptive_slo/{pipeline}/baseline",
            1e3 * base["p50_latency_ms"],
            f"p99_ms={base['p99_latency_ms']:.1f};shed=0.00;"
            f"guar={base['guarantee_rate']:.3f};compiles={base['compile_count']}",
        )
    )
    # -- Pareto sweep: degradation on, SLO budget varied
    for slo_f in SLO_FACTORS:
        slo_s = slo_f * service_s
        ctl = DegradationController(
            default_tiers(cfg.tau, cfg.max_iters),
            service_est_s=service_s,
            lanes=BATCH_SIZE,
        )
        rt = ServingRuntime(
            srv, max_wait_s=MAX_WAIT_MS / 1e3, slo_s=slo_s, controller=ctl,
        )
        stats = rt.run(arrivals, warmup=False)
        s = stats.summary()
        entry = {
            "slo_factor": slo_f,
            "slo_ms": 1e3 * slo_s,
            **{
                k: s[k]
                for k in (
                    "n", "n_offered", "n_shed", "shed_rate",
                    "deadline_met_rate", "p50_latency_ms", "p99_latency_ms",
                    "mean_queue_delay_ms", "guarantee_rate", "mean_tier",
                    "max_tier", "mean_sample_frac", "compile_count",
                )
            },
        }
        payload["pareto"].append(entry)
        out.append(
            csv_row(
                f"adaptive_slo/{pipeline}/slo{slo_f:g}x",
                1e3 * s["p50_latency_ms"],
                f"slo_ms={1e3 * slo_s:.0f};p99_ms={s['p99_latency_ms']:.1f};"
                f"shed={s['shed_rate']:.2f};guar={s['guarantee_rate']:.3f};"
                f"tier={s['mean_tier']:.2f};compiles={s['compile_count']}",
            )
        )
    # knob changes + fill variation are traced data: the whole sweep may
    # never mint an executable beyond the warmed cap buckets
    payload["zero_compiles_during_measurement"] = bool(
        base["compile_count"] == 0
        and all(e["compile_count"] == 0 for e in payload["pareto"])
    )
    payload["p99_bounded_vs_baseline"] = bool(
        payload["pareto"]
        and min(e["p99_latency_ms"] for e in payload["pareto"])
        < payload["overload_baseline"]["p99_latency_ms"]
    )
    write_bench_json("adaptive_slo", payload, path=str(BENCH_SERVING_JSON))
    return out


# ------------------------------------------------------------------------
# Continuous batching: chunked lane recycling vs fixed-lane admission
# ------------------------------------------------------------------------
CONTINUOUS_CHUNK_ITERS = 4
CONTINUOUS_RATE_FACTOR = 3.0  # saturating, like the sharded sweep
# Tight enough for CAP-BOUND stragglers next to converge-at-init requests
# (measured turbofan full-batch iters at 0.08: [0, 0, 64, 6, 2, 0, 0, 64]).
# The sharded sweep's 0.35 is NOT that regime — there every request
# converges at init (iters <= 5, mean_sample_frac ~ 0.06), so a fixed
# batch never waits on a straggler and recycling has nothing to reclaim.
CONTINUOUS_DELTA_FRAC = 0.08


def run_continuous(pipeline: str = PIPE) -> list[str]:
    """Fixed-lane vs continuous batching on the SAME saturating trace.

    One Poisson trace at 3x the fixed-lane full-batch capacity, with a
    tight delta so per-request iteration counts are heterogeneous, replayed
    through (a) the PR-3 fixed-lane runtime — every admission batch held
    open until its slowest lane converges — and (b) the chunked lane-table
    runtime, which refills a converged lane from the queue at the next
    chunk boundary.  Same pipeline, same batch_size (= lanes), same
    requests: ``throughput_gain`` isolates the scheduling policy.

    Tracked invariants (BENCH_serving.json["continuous_batching"]):
    ``zero_compiles_during_measurement`` (2 executables per cap bucket,
    all minted during warmup) and ``occupancy_gain`` — chunk-boundary lane
    occupancy above the fixed path's ``mean_batch_fill / lanes``.
    """
    from repro.serving import ContinuousBatchedServer, ContinuousServingRuntime

    b = bundle(pipeline)
    cfg = BiathlonConfig(
        **DEFAULT_CFG, delta=b.pipeline.delta_default * CONTINUOUS_DELTA_FRAC
    )
    # -- fixed-lane baseline on the shared trace
    srv_f = BatchedFusedServer(b, cfg, batch_size=BATCH_SIZE)
    rt_f = ServingRuntime(srv_f, max_wait_s=MAX_WAIT_MS / 1e3)
    rt_f.warmup(b.requests)
    capacity_rps = _measure_capacity(srv_f, b.requests, reps=5, best_of=True)
    rate = CONTINUOUS_RATE_FACTOR * capacity_rps
    arrivals = poisson_arrivals(b.requests, rate, n=N_REQUESTS, seed=321)
    fixed_stats = rt_f.run(arrivals, warmup=False)
    fixed = fixed_stats.summary()
    # iteration-level lane occupancy of the fixed path: useful iterations /
    # lane-iterations held open.  This is the number straggler waste eats,
    # and the like-for-like twin of the continuous path's chunk-slot
    # ``lane_occupancy`` — admission-time ``mean_batch_fill`` is NOT (at
    # overload every fixed batch admits full, yet its lanes then idle
    # behind the straggler; converge-at-init requests hold no loop
    # residency on either path).
    by_batch: dict[int, list[int]] = {}
    for r in fixed_stats.records:
        by_batch.setdefault(r.batch_id, []).append(r.iters)
    held = sum(BATCH_SIZE * max(its) for its in by_batch.values())
    fixed_iter_occ = (
        sum(sum(its) for its in by_batch.values()) / held if held else 0.0
    )

    # -- continuous: persistent lane table, chunked dispatch, recycling
    srv_c = ContinuousBatchedServer(
        b, cfg, batch_size=BATCH_SIZE, chunk_iters=CONTINUOUS_CHUNK_ITERS
    )
    rt_c = ContinuousServingRuntime(srv_c)
    rt_c.warmup([a[1] for a in arrivals])
    cont = rt_c.run(arrivals, warmup=False).summary()

    gain = cont["throughput_rps"] / max(fixed["throughput_rps"], 1e-9)
    payload = {
        "pipeline": pipeline,
        "batch_size": BATCH_SIZE,
        "chunk_iters": CONTINUOUS_CHUNK_ITERS,
        "n_requests": N_REQUESTS,
        "delta_frac": CONTINUOUS_DELTA_FRAC,
        "rate_factor": CONTINUOUS_RATE_FACTOR,
        "capacity_rps": capacity_rps,
        "rate_rps": rate,
        "config": {"m": cfg.m, "m_sobol": cfg.m_sobol, "tau": cfg.tau},
        "fixed": fixed,
        "continuous": cont,
        "throughput_gain": gain,
        "lane_occupancy": cont["lane_occupancy"],
        "fixed_mean_fill_frac": fixed["mean_batch_fill"] / BATCH_SIZE,
        "fixed_iter_occupancy": fixed_iter_occ,
        "occupancy_above_fixed": bool(
            cont["lane_occupancy"] > fixed_iter_occ
        ),
        "occupancy_gain": cont["lane_occupancy"] / max(fixed_iter_occ, 1e-9),
        "zero_compiles_during_measurement": bool(
            fixed["compile_count"] == 0 and cont["compile_count"] == 0
        ),
    }
    write_bench_json("continuous_batching", payload, path=str(BENCH_SERVING_JSON))
    return [
        csv_row(
            f"continuous/{pipeline}/fixed",
            1e3 * fixed["p50_latency_ms"],
            f"thru={fixed['throughput_rps']:.1f}rps;"
            f"p99_ms={fixed['p99_latency_ms']:.1f};"
            f"fill={fixed['mean_batch_fill']:.1f};"
            f"compiles={fixed['compile_count']}",
        ),
        csv_row(
            f"continuous/{pipeline}/chunk{CONTINUOUS_CHUNK_ITERS}",
            1e3 * cont["p50_latency_ms"],
            f"thru={cont['throughput_rps']:.1f}rps;"
            f"p99_ms={cont['p99_latency_ms']:.1f};"
            f"occ={cont['lane_occupancy']:.2f};"
            f"recycles={cont['n_recycles']};gain={gain:.2f}x;"
            f"compiles={cont['compile_count']}",
        ),
    ]


# ------------------------------------------------------------------------
# Device-scaling sweep: sharded lanes over a 1-D serving mesh
# ------------------------------------------------------------------------
def run_sharded(pipeline: str = PIPE) -> list[str]:
    """Sweep serving-mesh sizes at fixed batch size (worker half).

    Must run in a process with >= max(DEVICE_COUNTS) visible devices — the
    parent entrypoint (``run_sharded_subprocess``) forces them on CPU.  The
    same saturating Poisson trace (rate pinned to 3x the 1-device capacity)
    is replayed at every device count, so ``throughput_rps`` isolates the
    sharding effect: lane blocks run concurrently and each device's
    while-loop exits at ITS stragglers, not the batch's.
    """
    import jax

    from repro.launch.mesh import make_serving_mesh

    n_visible = len(jax.devices())
    if n_visible < max(DEVICE_COUNTS):
        raise RuntimeError(
            f"need {max(DEVICE_COUNTS)} devices, have {n_visible}; run via "
            "run_sharded_subprocess() or set "
            "XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{max(DEVICE_COUNTS)}"
        )
    b = bundle(pipeline)
    cfg = BiathlonConfig(
        **DEFAULT_CFG, delta=b.pipeline.delta_default * SHARDED_DELTA_FRAC
    )
    out = []
    payload = {
        "pipeline": pipeline,
        "batch_size": BATCH_SIZE,
        "max_wait_ms": MAX_WAIT_MS,
        "n_requests": N_REQUESTS,
        "delta_frac": SHARDED_DELTA_FRAC,
        "rate_factor": SHARDED_RATE_FACTOR,
        "capacity_method": "best_of_5",
        "config": {"m": cfg.m, "m_sobol": cfg.m_sobol, "tau": cfg.tau},
        "devices": [],
    }
    rate = None
    for d in DEVICE_COUNTS:
        srv = BatchedFusedServer(
            b, cfg, batch_size=BATCH_SIZE, mesh=make_serving_mesh(d)
        )
        runtime = ServingRuntime(srv, max_wait_s=MAX_WAIT_MS / 1e3)
        runtime.warmup(b.requests)
        capacity_rps = _measure_capacity(srv, b.requests, reps=5, best_of=True)
        if rate is None:  # pin the trace to the 1-device saturation point
            rate = SHARDED_RATE_FACTOR * capacity_rps
        arrivals = poisson_arrivals(b.requests, rate, n=N_REQUESTS, seed=777)
        stats = runtime.run(arrivals, warmup=False)
        s = stats.summary()
        entry = {
            "n_devices": d,
            "capacity_rps": capacity_rps,
            "rate_rps": rate,
            **s,
        }
        payload["devices"].append(entry)
        out.append(
            csv_row(
                f"serving_sharded/{pipeline}/dev{d}",
                1e3 * s["p50_latency_ms"],
                f"cap={capacity_rps:.1f}rps;thru={s['throughput_rps']:.1f}rps;"
                f"p99_ms={s['p99_latency_ms']:.1f};"
                f"imb={s.get('mean_lane_imbalance', 0.0):.2f};"
                f"compiles={s['compile_count']}",
            )
        )
    d1 = payload["devices"][0]["throughput_rps"]
    payload["speedup_vs_1dev"] = [
        e["throughput_rps"] / max(d1, 1e-9) for e in payload["devices"]
    ]
    write_bench_json("sharded_scaling", payload, path=str(BENCH_SERVING_JSON))
    return out


def run_sharded_subprocess(pipeline: str = PIPE) -> list[str]:
    """Re-exec this module as a worker with forced host devices.

    jax fixes its device list at first initialization, so the sweep cannot
    run in a process that already touched jax with the default flags.
    """
    from repro.launch.mesh import forced_host_devices_env

    repo = pathlib.Path(__file__).resolve().parent.parent
    env = forced_host_devices_env(max(DEVICE_COUNTS))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.serving_load",
         "--sharded-worker", pipeline],
        env=env, cwd=str(repo), text=True, capture_output=True,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded worker failed:\n{proc.stdout}\n{proc.stderr}"
        )
    return [l for l in proc.stdout.splitlines() if l.startswith("serving_sharded/")]


# ------------------------------------------------------------------------
# Availability under faults: storm replay vs fault-free ground truth
# ------------------------------------------------------------------------
FAULT_SEED = 11
FAULT_CHUNK_FAIL_PROB = 0.15
FAULT_POISON_PROB = 0.10
FAULT_REFILL_FAIL_PROB = 0.05
FAULT_CACHE_CORRUPT_CALLS = (1,)
# p99 under the storm must stay within this factor of the fault-free p99 —
# retries are bounded (max_retries) and backoff is virtual, so blowups here
# mean unbounded retry queueing, the failure mode this section guards
FAULT_P99_BOUND = 20.0


def run_fault_recovery(pipeline: str = PIPE) -> list[str]:
    """Continuous serving through a seeded fault storm at ~capacity load.

    One warmed :class:`ContinuousBatchedServer` serves the same Poisson
    trace twice: bare (ground truth), then wrapped in a
    :class:`FaultyContinuousServer` injecting chunk-dispatch failures
    (rolled back to the chunk-boundary checkpoint and replayed), lane
    poisoning (quarantined, re-admitted once), admission failures
    (retried whole — admission is idempotent), and one feature-cache
    corruption (detected by the power-sum checksum, rebuilt cold).

    Tracked invariants (BENCH_serving.json["fault_recovery"]): every
    surviving request's z-plan bitwise-matches the fault-free replay
    (checkpoint restore + counter-based RNG make recovery exact, not
    approximate), p99 stays within ``FAULT_P99_BOUND`` of fault-free
    (bounded retries, no unbounded queueing), zero executables are minted
    during either measured run, and the two recovery mutants introduced
    with this section are caught by the checker (9/9 overall).
    """
    from repro.analysis.mutations import MUTATIONS
    from repro.serving import (
        ContinuousBatchedServer,
        ContinuousServingRuntime,
        FaultProfile,
        FaultyContinuousServer,
    )

    b = bundle(pipeline)
    cfg = BiathlonConfig(
        **DEFAULT_CFG, delta=b.pipeline.delta_default * CONTINUOUS_DELTA_FRAC
    )
    # capacity is priced on the fixed-lane twin (serve_batch amortization),
    # as every other section does — the trace runs at 1x that rate
    srv_cap = BatchedFusedServer(b, cfg, batch_size=BATCH_SIZE)
    srv_cap.serve_batch(b.requests[:BATCH_SIZE])
    capacity_rps = _measure_capacity(srv_cap, b.requests, reps=5, best_of=True)
    arrivals = poisson_arrivals(
        b.requests, capacity_rps, n=N_REQUESTS, seed=555
    )

    srv = ContinuousBatchedServer(
        b, cfg, batch_size=BATCH_SIZE, chunk_iters=CONTINUOUS_CHUNK_ITERS,
        cache_size=8,
    )
    ContinuousServingRuntime(srv).warmup([a[1] for a in arrivals])
    compiles_before = srv.compile_count

    free = ContinuousServingRuntime(srv).run(arrivals, warmup=False)
    want = {r.req_id: r.z for r in free.records if r.disposition == "ok"}

    srv.cache.verify_hits = True  # the storm corrupts an entry; detect it
    prof = FaultProfile(
        seed=FAULT_SEED,
        chunk_fail_prob=FAULT_CHUNK_FAIL_PROB,
        poison_prob=FAULT_POISON_PROB,
        refill_fail_prob=FAULT_REFILL_FAIL_PROB,
        cache_corrupt_calls=FAULT_CACHE_CORRUPT_CALLS,
    )
    fsrv = FaultyContinuousServer(srv, prof)
    storm = ContinuousServingRuntime(fsrv).run(arrivals, warmup=False)
    srv.cache.verify_hits = False

    ok = [r for r in storm.records if r.disposition == "ok"]
    survivors_match = bool(ok) and all(r.z == want[r.req_id] for r in ok)
    s_free, s_storm = free.summary(), storm.summary()
    p99_ratio = s_storm["p99_latency_ms"] / max(s_free["p99_latency_ms"], 1e-9)
    mutations = {name: bool(fn()) for name, fn in MUTATIONS.items()}
    new_muts = ("rollback_skips_bootstrap_carry",
                "quarantine_readmit_without_reset")

    payload = {
        "pipeline": pipeline,
        "batch_size": BATCH_SIZE,
        "chunk_iters": CONTINUOUS_CHUNK_ITERS,
        "n_requests": N_REQUESTS,
        "delta_frac": CONTINUOUS_DELTA_FRAC,
        "rate_rps": capacity_rps,
        "config": {"m": cfg.m, "m_sobol": cfg.m_sobol, "tau": cfg.tau},
        "fault_profile": {
            "seed": FAULT_SEED,
            "chunk_fail_prob": FAULT_CHUNK_FAIL_PROB,
            "poison_prob": FAULT_POISON_PROB,
            "refill_fail_prob": FAULT_REFILL_FAIL_PROB,
            "cache_corrupt_calls": list(FAULT_CACHE_CORRUPT_CALLS),
        },
        "fault_events": len(fsrv.events),
        "fault_free": s_free,
        "storm": s_storm,
        "n_ok": len(ok),
        "n_rollbacks": storm.n_rollbacks,
        "n_retries": storm.n_retries,
        "n_poisoned": storm.n_poisoned,
        "n_failed": storm.n_failed,
        "cache_corruptions_detected": srv.cache.corruptions,
        "survivors_bitwise_match": survivors_match,
        "p99_ratio_vs_fault_free": p99_ratio,
        "p99_bounded": bool(p99_ratio < FAULT_P99_BOUND),
        "zero_compiles_during_measurement": bool(
            srv.compile_count == compiles_before
        ),
        "mutations_caught": sum(mutations.values()),
        "mutations_total": len(mutations),
        "new_mutations_caught": bool(all(mutations[n] for n in new_muts)),
    }
    write_bench_json("fault_recovery", payload, path=str(BENCH_SERVING_JSON))
    return [
        csv_row(
            f"fault_recovery/{pipeline}/storm",
            1e3 * s_storm["p50_latency_ms"],
            f"events={len(fsrv.events)};ok={len(ok)}/{N_REQUESTS};"
            f"rollbacks={storm.n_rollbacks};poisoned={storm.n_poisoned};"
            f"bitwise={'Y' if survivors_match else 'N'};"
            f"p99x={p99_ratio:.1f};"
            f"muts={sum(mutations.values())}/{len(mutations)};"
            f"compiles={srv.compile_count - compiles_before}",
        )
    ]


if __name__ == "__main__":
    if "--sharded-worker" in sys.argv:
        pipe = sys.argv[sys.argv.index("--sharded-worker") + 1]
        for row in run_sharded(pipe):
            print(row)
    else:
        print("name,us_per_call,derived")
        for row in run():
            print(row)
        for row in run_adaptive_slo():
            print(row)
        for row in run_continuous():
            print(row)
        for row in run_fault_recovery():
            print(row)
        for row in run_sharded_subprocess():
            print(row)
