"""Kernel micro-benchmarks (oracle path timings on CPU; the Pallas kernels
are TPU-target and validated in interpret mode — timing interpret mode would
measure the Python interpreter, not the kernel)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, timed, write_bench_json
from repro.core.qmc import sobol_uint32
from repro.kernels.sampled_agg.ref import sampled_moments_ref
from repro.models.tabular.trees import GradientBoosting, ensemble_predict_sum
from repro.models.lm.layers import attention_blockwise, attention_full


def run() -> list[str]:
    out = []
    micro: dict = {}
    # sampled moments: k=16 features x 64k rows
    vals = jax.random.normal(jax.random.PRNGKey(0), (16, 65536))
    z = jnp.full((16,), 32768, jnp.int32)
    f = jax.jit(sampled_moments_ref)
    us, _ = timed(lambda: jax.block_until_ready(f(vals, z)))
    out.append(csv_row("kernel/sampled_moments_16x64k", us, "oracle_jit"))
    micro["sampled_moments_16x64k_us"] = us

    # AFC estimates (moments + estimator tail), the fused loop's per-iter cost
    from repro.kernels.sampled_agg.ops import masked_estimates

    ids = jnp.zeros((16,), jnp.int32)
    n = jnp.full((16,), 65536, jnp.int32)
    g_est = jax.jit(lambda v, zz: masked_estimates(v, zz, n, ids, use_kernel=False))
    us, _ = timed(lambda: jax.block_until_ready(g_est(vals, z)))
    out.append(csv_row("kernel/afc_estimates_16x64k", us, "oracle_jit"))
    micro["afc_estimates_16x64k_us"] = us

    # sobol generation: 1000 x 21 (paper default m, max k)
    g = jax.jit(lambda: sobol_uint32(1024, 21))
    us, _ = timed(lambda: jax.block_until_ready(g()))
    out.append(csv_row("kernel/sobol_1024x21", us, "oracle_jit"))
    micro["sobol_1024x21_us"] = us

    # tree ensemble over QMC batch: 60 trees depth 5, m(k+2)=11.5k rows
    rng = np.random.default_rng(0)
    X = rng.normal(0, 1, (4000, 10)).astype(np.float32)
    gb = GradientBoosting(n_trees=60, max_depth=5).fit(X, X[:, 0] * 2)
    xq = jnp.asarray(rng.normal(0, 1, (11520, 10)).astype(np.float32))
    t = jax.jit(lambda x: ensemble_predict_sum(gb.ensemble, x))
    us, _ = timed(lambda: jax.block_until_ready(t(xq)))
    out.append(csv_row("kernel/tree_qmc_60x11520", us, "oracle_jit"))
    micro["tree_qmc_60x11520_us"] = us

    # blockwise vs full attention (the XLA fallback pair), 2x8x2048x64
    q = jax.random.normal(jax.random.PRNGKey(1), (2, 2048, 8, 64), jnp.float32)
    fb = jax.jit(lambda q: attention_blockwise(q, q, q, causal=True, block=512))
    us_b, _ = timed(lambda: jax.block_until_ready(fb(q)))
    ff = jax.jit(lambda q: attention_full(q, q, q, causal=True))
    us_f, _ = timed(lambda: jax.block_until_ready(ff(q)))
    out.append(
        csv_row("kernel/attention_2k_blockwise_vs_full", us_b, f"full_us={us_f:.0f}")
    )
    micro["attention_2k_blockwise_us"] = us_b
    micro["attention_2k_full_us"] = us_f
    write_bench_json("kernel_micro", micro)
    return out
