"""Fig. 6: speedup/accuracy vs confidence level tau (oracle = exact preds)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import DEFAULT_CFG, bundle, csv_row, serve_log, summarize
from repro.core.executor import BiathlonConfig

PIPES = ("trip_fare", "turbofan")
TAUS = (0.5, 0.9, 0.95, 0.99)


def run(pipelines=PIPES, taus=TAUS) -> list[str]:
    out = []
    for name in pipelines:
        b = bundle(name)
        for tau in taus:
            rows = serve_log(b, BiathlonConfig(tau=tau, **DEFAULT_CFG))
            s = summarize(rows, b.pipeline.delta_default, b.pipeline.task)
            # accuracy with the exact prediction as oracle label (paper §4.2)
            err = np.array([abs(r["y_hat"] - r["y_exact"]) for r in rows])
            out.append(
                csv_row(
                    f"fig6/{name}/tau={tau}",
                    s["latency_ms"] * 1e3,
                    f"speedup={s['speedup']:.2f};frac={s['frac']:.3f};"
                    f"err_vs_exact={err.mean():.4f};guarantee={s['guarantee_rate']:.2f}",
                )
            )
    return out
