"""Online feature store: hot-group cache vs per-request precompute (PR 9).

A skewed (hot-group) request log served twice per cap bucket:

* **before** — the uncached fused server: every request re-gathers its
  (k, cap) host buffers (H2D) and re-runs the AFC precompute inside the
  program.  Under the ``auto`` strategy this is the small-cap regime that
  regressed in the PR-5 ``incremental_afc`` sweep (rescan wins the loop
  body but precompute dominates the request at cap <= 1k).
* **after** — the same server with ``cache_size`` set: hot keys are served
  from the version-keyed LRU (serving/feature_cache.py), so a hit pays
  zero precompute and zero H2D — only the already-compiled prebuilt
  dispatch.

Writes the ``feature_store`` section of BENCH_fused.json: steady-state
latency + speedup per cap, host-side ``cache.get`` hit/miss cost (the
"cached precompute ~ 0" evidence), and the small-cap verdict — cached
speedup must be >= 1.0x at EVERY cap <= 1k, erasing the regression the
cache-aware ``resolve_afc_plan`` heuristic exists to fix.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    DEFAULT_CFG,
    QUICK,
    csv_row,
    latency_stats,
    write_bench_json,
)
from repro.core.executor import BiathlonConfig
from repro.serving import BiathlonServer

# every cap <= 1k (the regressed regime) plus one large cap as the control
CAPS = (256, 1024) if QUICK else (256, 512, 1024, 8192)
PIPE = "turbofan"
# hot-key skew: passes over the same few groups — the head of a production
# key distribution, where the LRU converges to all-hits
HOT_GROUPS = 3
PASSES = 2 if QUICK else 4


def _hot_log(b, n_groups: int, passes: int) -> list[dict]:
    reqs = b.requests[:n_groups]
    return [r for _ in range(passes) for r in reqs]


def _steady_state(srv, log) -> dict:
    """Serve the log once to warm (compiles + cache fills), then measure."""
    for req in log:
        srv.serve(req)
    lat = []
    for req in log:
        t0 = time.perf_counter()
        srv.serve(req)
        lat.append(time.perf_counter() - t0)
    return latency_stats(lat)


def _get_cost_us(srv, req, cap_hint: int) -> dict:
    """Host-side cache.get latency: hit vs (evict-forced) miss."""
    p = srv.pipeline
    specs = p.agg_specs(req)
    cap = min(srv._cap, cap_hint)
    srv.cache.get(specs, cap)  # ensure resident
    t0 = time.perf_counter()
    entry = srv.cache.get(specs, cap)
    hit_us = (time.perf_counter() - t0) * 1e6
    srv.cache._entries.clear()  # force the cold path once
    t0 = time.perf_counter()
    srv.cache.get(specs, cap)
    miss_us = (time.perf_counter() - t0) * 1e6
    assert entry is not None
    return {"hit_us": float(hit_us), "miss_us": float(miss_us)}


def run(caps=CAPS) -> list[str]:
    from repro.data.synthetic import make_pipeline

    out = []
    cfg = BiathlonConfig(**DEFAULT_CFG)
    payload: dict = {
        "config": {**DEFAULT_CFG, "hot_groups": HOT_GROUPS, "passes": PASSES},
        "caps": list(caps),
        "pipeline": PIPE,
        "per_cap": {},
    }
    small_cap_speedups = {}
    for cap in caps:
        # 0.79*cap keeps every group inside one power-of-two bucket (= cap)
        b = make_pipeline(
            PIPE, rows_per_group=int(cap * 0.79), n_train_groups=40,
            n_serve_groups=max(HOT_GROUPS, 4), n_requests=HOT_GROUPS,
        )
        log = _hot_log(b, HOT_GROUPS, PASSES)
        before_srv = BiathlonServer(b, cfg, mode="fused")
        before = _steady_state(before_srv, log)
        after_srv = BiathlonServer(b, cfg, mode="fused", cache_size=16)
        after = _steady_state(after_srv, log)
        after_srv.check_compile_contract()  # hits minted zero executables
        get_cost = _get_cost_us(after_srv, log[0], cap)
        speedup = before["mean_us"] / after["mean_us"]
        if cap <= 1024:
            small_cap_speedups[str(cap)] = speedup
        payload["per_cap"][str(cap)] = {
            "before": before,
            "after": after,
            "speedup": speedup,
            "cache_get": get_cost,
            "cache_stats": after_srv.cache.stats,
        }
        out.append(
            csv_row(
                f"perf/feature_store/{PIPE}@{cap}",
                after["mean_us"],
                f"before_us={before['mean_us']:.0f};speedup={speedup:.2f};"
                f"hit_get_us={get_cost['hit_us']:.0f};"
                f"hits={after_srv.cache.stats['hits']}",
            )
        )
    payload["small_cap"] = {
        "speedups": small_cap_speedups,
        "all_geq_1": bool(all(s >= 1.0 for s in small_cap_speedups.values())),
    }
    write_bench_json("feature_store", payload)
    return out
