"""Fig. 10 (appendix C): speedup vs #approximated aggregation operators.

Bearing-Imbalance has 8 aggregate features; we approximate the first j and
compute the rest exactly, for j in {0, 2, 4, 6, 8} — the paper's ablation.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import DEFAULT_CFG, bundle, csv_row, serve_log, summarize
from repro.core.executor import BiathlonConfig


def run(counts=(0, 2, 4, 6, 8)) -> list[str]:
    base = bundle("bearing_imbalance")
    out = []
    for j in counts:
        feats = [
            dataclasses.replace(f, approximate=(i < j))
            for i, f in enumerate(base.pipeline.agg_features)
        ]
        pipe = dataclasses.replace(base.pipeline, agg_features=feats)
        b = dataclasses.replace(base, pipeline=pipe)
        rows = serve_log(b, BiathlonConfig(**DEFAULT_CFG))
        s = summarize(rows, 0.0, "classification")
        out.append(
            csv_row(
                f"fig10/bearing/approx_ops={j}",
                s["latency_ms"] * 1e3,
                f"speedup={s['speedup']:.2f};frac={s['frac']:.3f};"
                f"guarantee={s['guarantee_rate']:.2f}",
            )
        )
    return out
