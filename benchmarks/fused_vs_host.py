"""Beyond-paper §Perf: FusedExecutor vs the paper-faithful host loop.

Measures the serving-side optimization recorded in EXPERIMENTS.md §Perf:
one XLA program per request (lax.while_loop, prefix-masked buffers) vs the
host-driven feedback loop with its per-iteration dispatch + D2H syncs.

Besides the CSV rows, writes mean/p50/p99 latency and the per-iteration
model-row counts (pre-fusion three-dispatch body vs the single megabatch)
to ``BENCH_fused.json`` at the repo root so the perf trajectory is tracked
across PRs.

``run_holistic`` measures the same comparison on MEDIAN/QUANTILE pipelines
(the appendix-D operators the fused path now serves) and writes the
``fused_vs_host_holistic`` section; the host loop pays per-feature bootstrap
dispatches there, so scale is the QUICK-tier bundle.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    DEFAULT_CFG,
    bundle,
    csv_row,
    latency_stats,
    write_bench_json,
)
from repro.core.executor import BiathlonConfig
from repro.core.executor_fused import fused_rows_per_iteration
from repro.data.store import bucket_size
from repro.serving import BiathlonServer

PIPES = ("bearing_imbalance", "tick_price", "turbofan")
# (pipeline, appendix-D median substitution?) — holistic-featured workloads
HOLISTIC_PIPES = (("sensor_health", False), ("turbofan", True))
# incremental-AFC sweep across caps: (pipeline, median substitution?) —
# parametric turbofan, its appendix-D holistic variant, and the
# model-heavy sensor_health as the Amdahl reference point
LARGE_N_CAPS = (1024, 8192, 65536)
LARGE_N_PIPES = (("turbofan", False), ("turbofan", True), ("sensor_health", False))


def model_rows_per_iteration(k: int, m: int, m_sobol: int) -> dict:
    """Model rows the while-loop body evaluates, before vs after fusion.

    Before: two AMI evaluations, each two model_fn calls (m QMC rows + the
    1-row point estimate), plus a separate Saltelli batch — five model_fn
    calls.  After: ONE call on the concatenated megabatch.
    """
    sobol_rows = (k + 2) * m_sobol
    return {
        "before": 2 * (m + 1) + sobol_rows,
        "after": fused_rows_per_iteration(k, m, m_sobol),
        "before_dispatches": 5,
        "after_dispatches": 1,
        "sobol_rows": sobol_rows,
    }


def _measure_modes(b, cfg, *, compare_exact, quality: bool = False) -> dict:
    """Warm every cap bucket, then serve the full log in host + fused modes.

    One warm request per distinct cap bucket (serving is steady-state:
    ≤ log2(max_cap) compiles ever, paid once).  ``compare_exact(mode)``
    decides whether the exact baseline runs alongside; ``quality`` adds
    guarantee-rate / mean-|err| fields (needs compare_exact truthy).
    """
    bucket_reps = {}
    for req in b.requests:
        n_max = int(b.pipeline.group_sizes(b.store, req).max())
        bucket_reps.setdefault(bucket_size(n_max), req)
    out = {}
    for mode in ("host", "fused"):
        srv = BiathlonServer(b, cfg, mode=mode)
        for req in bucket_reps.values():
            srv.serve(req)
        stats = srv.serve_all(b.requests, compare_exact=compare_exact(mode))
        out[mode] = dict(
            latency=latency_stats(stats.latencies),
            frac=float(np.mean(stats.sample_fracs)),
            iters=float(np.mean(stats.iters)),
        )
        if quality:
            err = np.asarray(stats.errors_vs_exact)
            tol = (
                b.pipeline.delta_default + 1e-9
                if b.pipeline.task == "regression"
                else 1e-9
            )
            out[mode]["guarantee_rate"] = float(np.mean(err <= tol))
            out[mode]["mean_abs_err"] = float(err.mean())
    return out


def run(pipelines=PIPES) -> list[str]:
    out = []
    cfg = BiathlonConfig(**DEFAULT_CFG)
    payload: dict = {
        "config": {"m": cfg.m, "m_sobol": cfg.m_sobol, "tau": cfg.tau},
        "pipelines": {},
    }
    for name in pipelines:
        b = bundle(name)
        res = _measure_modes(b, cfg, compare_exact=lambda mode: mode == "host")
        rows = model_rows_per_iteration(b.pipeline.k, cfg.m, cfg.m_sobol)
        speedup = res["host"]["latency"]["mean_us"] / res["fused"]["latency"]["mean_us"]
        payload["pipelines"][name] = {
            "k": b.pipeline.k,
            "model_rows_per_iter": rows,
            "host": res["host"],
            "fused": res["fused"],
            "speedup_vs_host": speedup,
        }
        out.append(
            csv_row(
                f"perf/fused_vs_host/{name}",
                res["fused"]["latency"]["mean_us"],
                f"host_us={res['host']['latency']['mean_us']:.0f};"
                f"speedup={speedup:.2f};"
                f"rows_per_iter={rows['before']}->{rows['after']};"
                f"frac_host={res['host']['frac']:.3f};frac_fused={res['fused']['frac']:.3f}",
            )
        )
    write_bench_json("fused_vs_host", payload)
    return out


def run_holistic(pipelines=HOLISTIC_PIPES, scale: dict | None = None) -> list[str]:
    """Fused-vs-host on MEDIAN/QUANTILE pipelines -> BENCH_fused.json.

    Also records guarantee rate and mean |err| vs the exact baseline for the
    fused path — the acceptance evidence that the holistic fused executor
    matches the host loop's quality, not just its speed.  Holistic host
    iterations pay B-replicate bootstraps per feature, so this section runs
    at a reduced scale (recorded in the payload).
    """
    from repro.data.synthetic import make_pipeline, make_pipeline_median

    scale = scale or dict(
        rows_per_group=8000, n_train_groups=150, n_serve_groups=5, n_requests=8
    )
    out = []
    cfg = BiathlonConfig(**DEFAULT_CFG)
    payload: dict = {
        "config": {"m": cfg.m, "m_sobol": cfg.m_sobol, "tau": cfg.tau,
                   "n_bootstrap": cfg.n_bootstrap},
        "scale": scale,
        "pipelines": {},
    }
    for name, median in pipelines:
        label = f"{name}_median" if median else name
        b = (make_pipeline_median if median else make_pipeline)(name, **scale)
        res = _measure_modes(b, cfg, compare_exact=lambda mode: True, quality=True)
        speedup = res["host"]["latency"]["mean_us"] / res["fused"]["latency"]["mean_us"]
        payload["pipelines"][label] = {
            "k": b.pipeline.k,
            "holistic_features": sum(
                f.agg in ("median", "quantile") for f in b.pipeline.agg_features
            ),
            "delta": b.pipeline.delta_default,
            "host": res["host"],
            "fused": res["fused"],
            "speedup_vs_host": speedup,
        }
        out.append(
            csv_row(
                f"perf/fused_vs_host_holistic/{label}",
                res["fused"]["latency"]["mean_us"],
                f"host_us={res['host']['latency']['mean_us']:.0f};"
                f"speedup={speedup:.2f};"
                f"guar_fused={res['fused']['guarantee_rate']:.2f};"
                f"guar_host={res['host']['guarantee_rate']:.2f};"
                f"frac_fused={res['fused']['frac']:.3f}",
            )
        )
    write_bench_json("fused_vs_host_holistic", payload)
    return out


def run_large_n(caps=LARGE_N_CAPS, pipelines=LARGE_N_PIPES) -> list[str]:
    """Incremental AFC vs the rescan oracle across group sizes (PR-5).

    Both servers run the SAME fused while_loop executor; the only delta is
    the AFC strategy — ``before`` re-scans the (k, cap) buffers every
    planner iteration (afc_backend="ref", the pre-refactor path), ``after``
    queries the once-per-request prefix tables / rank index
    (afc_backend="incremental").  δ is tightened per (pipeline, cap) —
    estimates sharpen as groups grow, so a fixed δ stops iterating at
    large caps and would measure the init dispatch, not the loop body; the
    scales below keep mean iteration counts in a steady-state band (~4-30)
    and are recorded in the payload.  Writes the ``incremental_afc``
    section of BENCH_fused.json with per-request and per-iteration latency
    at each cap — the acceptance evidence that the loop body no longer
    scales with the group size.
    """
    from repro.data.synthetic import make_pipeline, make_pipeline_median

    out = []
    cfg_kw = dict(DEFAULT_CFG)
    delta_scales = {
        "turbofan": {1024: 0.35, 8192: 0.2, 65536: 0.12},
        "turbofan_median": {1024: 0.35, 8192: 0.2, 65536: 0.05},
        "sensor_health": {1024: 0.35, 8192: 0.02, 65536: 0.008},
    }
    payload: dict = {
        "config": {**cfg_kw, "delta_scales": {
            p: {str(c): s for c, s in m.items()} for p, m in delta_scales.items()
        }},
        "caps": list(caps),
        "pipelines": {},
    }
    for name, median in pipelines:
        label = f"{name}_median" if median else name
        entry: dict = {}
        for cap in caps:
            # group sizes vary ±25% around rows_per_group; 0.79·cap keeps
            # every group inside ONE power-of-two bucket (= cap, no clip)
            scale = dict(
                rows_per_group=int(cap * 0.79),
                n_train_groups=40,
                n_serve_groups=4,
                n_requests=6,
            )
            b = (make_pipeline_median if median else make_pipeline)(name, **scale)
            delta_scale = delta_scales.get(label, {}).get(cap, 0.2)
            cfg = BiathlonConfig(
                **cfg_kw, delta=delta_scale * b.pipeline.delta_default
            )
            per: dict = {}
            for phase, backend in (("before", "ref"), ("after", "incremental")):
                srv = BiathlonServer(b, cfg, mode="fused", afc_backend=backend)
                srv.serve(b.requests[0])  # warm the single cap bucket
                stats = srv.serve_all(b.requests, compare_exact=False)
                lat = latency_stats(stats.latencies)
                iters = float(np.mean(stats.iters))
                per[phase] = {
                    "latency": lat,
                    "iters": iters,
                    # + 1: the init dispatch evaluates the z⁰ plan too
                    "per_iter_us": lat["mean_us"] / (iters + 1.0),
                }
            # NB: bitwise z-plan parity makes before/after iteration counts
            # equal, so a per-iteration speedup would be identical to this
            # mean-latency speedup — per_iter_us per phase is recorded, the
            # redundant ratio is not.
            per["speedup"] = (
                per["before"]["latency"]["mean_us"]
                / per["after"]["latency"]["mean_us"]
            )
            per["delta_scale"] = delta_scale
            entry[str(cap)] = per
            out.append(
                csv_row(
                    f"perf/incremental_afc/{label}@{cap}",
                    per["after"]["latency"]["mean_us"],
                    f"before_us={per['before']['latency']['mean_us']:.0f};"
                    f"speedup={per['speedup']:.2f};"
                    f"iters={per['after']['iters']:.1f}",
                )
            )
        payload["pipelines"][label] = entry
    write_bench_json("incremental_afc", payload)
    return out
