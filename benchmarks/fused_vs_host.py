"""Beyond-paper §Perf: FusedExecutor vs the paper-faithful host loop.

Measures the serving-side optimization recorded in EXPERIMENTS.md §Perf:
one XLA program per request (lax.while_loop, prefix-masked buffers) vs the
host-driven feedback loop with its per-iteration dispatch + D2H syncs.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import DEFAULT_CFG, bundle, csv_row
from repro.core.executor import BiathlonConfig
from repro.serving import BiathlonServer

PIPES = ("bearing_imbalance", "tick_price", "turbofan")


def run(pipelines=PIPES) -> list[str]:
    out = []
    for name in pipelines:
        b = bundle(name)
        cfg = BiathlonConfig(**DEFAULT_CFG)
        res = {}
        for mode in ("host", "fused"):
            srv = BiathlonServer(b, cfg, mode=mode)
            srv.serve(b.requests[0])  # warm / compile
            stats = srv.serve_all(b.requests, compare_exact=(mode == "host"))
            lat = np.mean(stats.latencies)
            res[mode] = dict(
                lat=lat,
                frac=np.mean(stats.sample_fracs),
                iters=np.mean(stats.iters),
            )
        out.append(
            csv_row(
                f"perf/fused_vs_host/{name}",
                res["fused"]["lat"] * 1e6,
                f"host_us={res['host']['lat']*1e6:.0f};"
                f"speedup={res['host']['lat']/res['fused']['lat']:.2f};"
                f"frac_host={res['host']['frac']:.3f};frac_fused={res['fused']['frac']:.3f}",
            )
        )
    return out
