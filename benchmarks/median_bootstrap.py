"""Figs. 11-12 (appendix D): MEDIAN substitution + bootstrap error capture."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import DEFAULT_CFG, bundle, csv_row, serve_log, summarize
from repro.core.executor import BiathlonConfig
from repro.data import aggregates

PIPES = ("tick_price", "bearing_imbalance")


def bootstrap_calibration(n_trials: int = 64, z: int = 256, n: int = 4096) -> float:
    """Fig. 11 analogue: fraction of trials where the bootstrap error
    distribution covers the true median (target ~ its nominal level)."""
    rng = np.random.default_rng(0)
    hits = 0
    for t in range(n_trials):
        vals = rng.normal(rng.normal(0, 2), 1.0 + rng.random(), n).astype(np.float32)
        true_med = np.median(vals)
        buf = np.zeros(1024, np.float32)
        buf[:z] = vals[:z]
        res = aggregates.estimate(
            "median", jnp.asarray(buf), jnp.asarray(z), jnp.asarray(n),
            jax.random.PRNGKey(t),
        )
        reps = np.asarray(res.replicates)
        lo, hi = np.percentile(reps, [1.0, 99.0])
        hits += int(lo <= true_med <= hi)
    return hits / n_trials


def run(pipelines=PIPES) -> list[str]:
    out = []
    cov = bootstrap_calibration()
    out.append(csv_row("fig11/bootstrap_coverage", 0.0, f"coverage98={cov:.3f}"))
    for name in pipelines:
        for median in (False, True):
            b = bundle(name, median=median)
            rows = serve_log(b, BiathlonConfig(**DEFAULT_CFG))
            s = summarize(rows, b.pipeline.delta_default, b.pipeline.task)
            tag = "median" if median else "orig"
            out.append(
                csv_row(
                    f"fig12/{name}/{tag}",
                    s["latency_ms"] * 1e3,
                    f"speedup={s['speedup']:.2f};frac={s['frac']:.3f};"
                    f"guarantee={s['guarantee_rate']:.2f};err={s['err']:.4f}",
                )
            )
    return out
