"""Fig. 8 (appendix A): speedup vs initial sampling ratio alpha."""
from __future__ import annotations

from benchmarks.common import DEFAULT_CFG, bundle, csv_row, serve_log, summarize
from repro.core.executor import BiathlonConfig

PIPES = ("trip_fare", "bearing_imbalance")
ALPHAS = (0.01, 0.05, 0.1, 0.2)


def run(pipelines=PIPES, alphas=ALPHAS) -> list[str]:
    out = []
    for name in pipelines:
        b = bundle(name)
        for a in alphas:
            rows = serve_log(b, BiathlonConfig(alpha=a, **DEFAULT_CFG))
            s = summarize(rows, b.pipeline.delta_default, b.pipeline.task)
            out.append(
                csv_row(
                    f"fig8/{name}/alpha={a}",
                    s["latency_ms"] * 1e3,
                    f"speedup={s['speedup']:.2f};frac={s['frac']:.3f};"
                    f"iters={s['iters']:.1f};guarantee={s['guarantee_rate']:.2f}",
                )
            )
    return out
