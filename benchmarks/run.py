"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract).  Set
``QUICK=1`` for a fast smoke pass; ``ONLY=fig4,roofline`` filters sections.
"""
from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> None:
    from benchmarks import (
        breakdown,
        end_to_end,
        feature_store,
        fused_vs_host,
        kernel_micro,
        median_bootstrap,
        median_imbalance,
        roofline,
        serving_load,
        vary_alpha,
        vary_delta,
        vary_gamma,
        vary_num_ops,
        vary_tau,
    )

    sections = {
        "fig4_end_to_end": end_to_end.run,
        "fig5_breakdown": breakdown.run,
        "fig6_vary_tau": vary_tau.run,
        "fig7_vary_delta": vary_delta.run,
        "fig8_vary_alpha": vary_alpha.run,
        "fig9_vary_gamma": vary_gamma.run,
        "fig10_vary_num_ops": vary_num_ops.run,
        "fig11_12_median": median_bootstrap.run,
        "fig13_14_imbalance": median_imbalance.run,
        "kernel_micro": kernel_micro.run,
        "perf_fused_vs_host": fused_vs_host.run,
        "perf_fused_vs_host_holistic": fused_vs_host.run_holistic,
        # incremental-AFC cap sweep (PR 5): rescan vs prefix-stats loop body
        "perf_incremental_afc": fused_vs_host.run_large_n,
        # hot-group feature cache (PR 9): cached precompute ~0, small-cap
        # speedup >= 1 (BENCH_fused.json["feature_store"])
        "perf_feature_store": feature_store.run,
        "perf_serving_load": serving_load.run,
        # SLO-aware degradation: latency/guarantee Pareto sweep + bounded
        # 3x-overload run (BENCH_serving.json["adaptive_slo"]) — wired here
        # so the tracked section can't go stale
        "perf_adaptive_slo": serving_load.run_adaptive_slo,
        # continuous batching vs fixed lanes on one saturating trace
        # (BENCH_serving.json["continuous_batching"])
        "perf_continuous": serving_load.run_continuous,
        # availability under a seeded fault storm: rollback/quarantine
        # recovery must be bitwise-exact (BENCH_serving.json["fault_recovery"])
        "perf_fault_recovery": serving_load.run_fault_recovery,
        # device-scaling sweep; fork-safe (re-execs itself with fresh
        # XLA_FLAGS), so the tracked sharded_scaling section can never go
        # stale relative to the serving_load section written above
        "perf_serving_sharded": serving_load.run_sharded_subprocess,
        "roofline": roofline.run,
    }
    only = os.environ.get("ONLY")
    if only:
        keys = [k for k in sections if any(tok in k for tok in only.split(","))]
        sections = {k: sections[k] for k in keys}

    print("name,us_per_call,derived")
    failures = []
    for key, fn in sections.items():
        t0 = time.time()
        try:
            for row in fn():
                print(row, flush=True)
        except Exception as e:  # noqa: BLE001 — keep the suite going
            traceback.print_exc()
            failures.append((key, str(e)[:120]))
        print(f"# section {key} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        print(f"# {len(failures)} section failures: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
