"""Figs. 13-14 (appendix D): MEDIAN under pathological two-value columns.

A synthetic column holds x or x+100 at a given class-imbalance ratio; the
median is discrete-uniform-pathological near ratio 1.0.  We measure the
fraction of that column Biathlon samples and the prediction error vs exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core.executor import BiathlonConfig, HostLoopExecutor, run_exact
from repro.core.pipeline import AggFeature, Pipeline
from repro.data.store import ColumnStore, build_table
from repro.models.tabular import LinearRegression

RATIOS = (0.5, 0.8, 0.9, 0.95, 1.0)


def _build(ratio: float, n_rows: int = 60001, seed: int = 0):
    rng = np.random.default_rng(seed)
    x0 = 5.0
    n_hi = int(n_rows * ratio / (1 + ratio))
    col = np.full(n_rows, x0, np.float32)
    col[:n_hi] += 100.0
    rng.shuffle(col)
    aux = rng.normal(1.0, 0.5, n_rows).astype(np.float32)
    gid = np.zeros(n_rows, np.int64)
    store = ColumnStore().add("t", build_table({"med": col, "aux": aux}, gid, seed=seed))
    X = np.array([[np.median(col), aux.mean()]])
    lr = LinearRegression()
    lr.coef = np.asarray([0.05, 1.0], np.float32)
    lr.intercept = 0.0
    pipe = Pipeline(
        name=f"imbalance_{ratio}",
        agg_features=[
            AggFeature("med", "t", "med", "median", "g"),
            AggFeature("avg_aux", "t", "aux", "avg", "g"),
        ],
        exact_features=[],
        model=lr,
        task="regression",
        scaler_mean=np.zeros(2, np.float32),
        scaler_scale=np.ones(2, np.float32),
        delta_default=1.0,
    )
    return store, pipe


def run(ratios=RATIOS) -> list[str]:
    out = []
    for ratio in ratios:
        store, pipe = _build(ratio)
        ex = HostLoopExecutor(store, BiathlonConfig(m=256, m_sobol=64, max_iters=120))
        req = {"g": 0}
        y_exact, _ = run_exact(store, pipe, req)
        r = ex.run(pipe, req, jax.random.PRNGKey(int(ratio * 100)))
        med_frac = r.z[0] / r.n[0]
        out.append(
            csv_row(
                f"fig13/ratio={ratio}",
                r.t_total * 1e6,
                f"median_frac={med_frac:.3f};total_frac={r.sample_fraction:.3f};"
                f"err={abs(r.y_hat - y_exact):.4f};iters={r.iters}",
            )
        )
    return out
