"""Fig. 4: latency + accuracy across the seven pipelines (default config)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import DEFAULT_CFG, accuracy, bundle, csv_row, serve_log, summarize
from repro.core.executor import BiathlonConfig
from repro.data.synthetic import PIPELINE_NAMES


def run(pipelines=PIPELINE_NAMES) -> list[str]:
    out = []
    for name in pipelines:
        b = bundle(name)
        cfg = BiathlonConfig(**DEFAULT_CFG)
        rows = serve_log(b, cfg)
        s = summarize(rows, b.pipeline.delta_default, b.pipeline.task)
        idx = b.meta["request_groups"][: len(rows)]
        labels = b.labels[: len(rows)]
        acc_bia = accuracy(b, np.array([r["y_hat"] for r in rows]), labels)
        acc_exact = accuracy(b, np.array([r["y_exact"] for r in rows]), labels)
        out.append(
            csv_row(
                f"fig4/{name}",
                s["latency_ms"] * 1e3,
                f"speedup={s['speedup']:.2f};io_speedup={s['io_bound_speedup']:.1f};"
                f"exact_ms={s['exact_ms']:.1f};"
                f"frac={s['frac']:.3f};iters={s['iters']:.1f};"
                f"guarantee={s['guarantee_rate']:.2f};acc={acc_bia:.4f};"
                f"acc_exact={acc_exact:.4f}",
            )
        )
    return out
