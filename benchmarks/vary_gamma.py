"""Fig. 9 (appendix B): speedup vs planner step size gamma."""
from __future__ import annotations

from benchmarks.common import DEFAULT_CFG, bundle, csv_row, serve_log, summarize
from repro.core.executor import BiathlonConfig

PIPES = ("turbofan", "student_qa")
GAMMAS = (0.005, 0.01, 0.03)


def run(pipelines=PIPES, gammas=GAMMAS) -> list[str]:
    out = []
    for name in pipelines:
        b = bundle(name)
        for g in gammas:
            rows = serve_log(b, BiathlonConfig(gamma=g, **DEFAULT_CFG))
            s = summarize(rows, b.pipeline.delta_default, b.pipeline.task)
            out.append(
                csv_row(
                    f"fig9/{name}/gamma={g}",
                    s["latency_ms"] * 1e3,
                    f"speedup={s['speedup']:.2f};frac={s['frac']:.3f};"
                    f"iters={s['iters']:.1f};guarantee={s['guarantee_rate']:.2f}",
                )
            )
    return out
