import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, "/root/repo/src")
from repro.launch.dryrun import run_cell
OUT = "/root/repo/experiments/hillclimb"
# deepseek: FSDP + grad accumulation 4 (activation temp /4 hypothesis)
run_cell("deepseek-v2-236b", "train_4k", False, OUT, tag="hc_fsdp_accum4",
         fsdp=True, train_kwargs={"grad_accum": 4})
# xlstm: bf16 chunk compute (now default in mlstm_block)
run_cell("xlstm-1.3b", "train_4k", False, OUT, tag="hc_bf16chunks")
print("HILLCLIMB ROUND 2 DONE")
