import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, "/root/repo/src")
from repro.launch.dryrun import run_cell
for mp in (False, True):
    for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        run_cell("xlstm-1.3b", shape, mp)
print("RESWEEP DONE")
