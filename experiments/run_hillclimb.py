import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses, json, sys
sys.path.insert(0, "/root/repo/src")
from repro.launch.dryrun import run_cell

OUT = "/root/repo/experiments/hillclimb"

# Cell A: deepseek-v2 train_4k — params don't fit TP-only (154 GB/dev)
run_cell("deepseek-v2-236b", "train_4k", False, OUT, tag="hc_fsdp", fsdp=True)

# Cell B: granite train_4k — collective/dispatch-bound
def smaller_groups(cfg):
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, group_size=128, capacity_factor=1.0)
    )
run_cell("granite-moe-1b-a400m", "train_4k", False, OUT, tag="hc_dispatch128",
         cfg_override=smaller_groups)
def groups64(cfg):
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, group_size=64, capacity_factor=1.0)
    )
run_cell("granite-moe-1b-a400m", "train_4k", False, OUT, tag="hc_dispatch64",
         cfg_override=groups64)

# Cell C: xlstm train_4k — after state-sharding constraint (now default)
run_cell("xlstm-1.3b", "train_4k", False, OUT, tag="hc_stateshard")
def chunk128(cfg):
    return dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=128))
run_cell("xlstm-1.3b", "train_4k", False, OUT, tag="hc_stateshard_chunk128",
         cfg_override=chunk128)
print("HILLCLIMB ROUND 1 DONE")
