import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses, sys
sys.path.insert(0, "/root/repo/src")
from repro.launch.dryrun import run_cell
OUT = "/root/repo/experiments/hillclimb"

def chunk(n):
    def f(cfg):
        return dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=n))
    return f
run_cell("xlstm-1.3b", "train_4k", False, OUT, tag="hc_chunk128", cfg_override=chunk(128))
run_cell("xlstm-1.3b", "train_4k", False, OUT, tag="hc_chunk512", cfg_override=chunk(512))
run_cell("deepseek-v2-236b", "train_4k", False, OUT, tag="hc_fsdp_accum8",
         fsdp=True, train_kwargs={"grad_accum": 8})
# granite: push dispatch further — sorted backend single-shard reference point
run_cell("granite-moe-1b-a400m", "train_4k", False, OUT, tag="hc_dispatch64_cf1",
         cfg_override=lambda c: dataclasses.replace(
             c, moe=dataclasses.replace(c.moe, group_size=64, capacity_factor=1.0)),
         )
print("HILLCLIMB ROUND 3 DONE")
