"""Extract roofline inputs from compiled XLA artifacts.

``compiled.cost_analysis()`` provides HLO FLOPs and bytes; collective traffic
is NOT in cost_analysis, so we parse the post-SPMD HLO text and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, weighting by the standard ring-algorithm factors:

    all-gather        (g-1)/g x output bytes
    all-reduce      2*(g-1)/g x buffer bytes
    reduce-scatter    (g-1)/g x input bytes
    all-to-all        (g-1)/g x buffer bytes
    collective-permute        1 x buffer bytes

Shapes in the post-partitioning module are already per-device, so the sums
are per-chip link traffic.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["CollectiveStats", "collect_collective_stats", "HW"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

# TPU v5e-class hardware constants (per the brief).
HW = {
    "peak_flops": 197e12,      # bf16 FLOP/s per chip
    "hbm_bw": 819e9,           # bytes/s per chip
    "ici_bw": 50e9,            # bytes/s per link (~per-chip effective)
}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclass
class CollectiveStats:
    per_op_bytes: dict = field(default_factory=dict)   # op kind -> raw buffer bytes
    per_op_count: dict = field(default_factory=dict)
    link_bytes: float = 0.0                            # ring-weighted per-chip bytes

    def add(self, kind: str, nbytes: float, group: int):
        self.per_op_bytes[kind] = self.per_op_bytes.get(kind, 0.0) + nbytes
        self.per_op_count[kind] = self.per_op_count.get(kind, 0) + 1
        g = max(group, 1)
        if kind == "all-reduce":
            w = 2.0 * (g - 1) / g
        elif kind == "collective-permute":
            w = 1.0
        else:
            w = (g - 1) / g
        self.link_bytes += nbytes * w

    def as_dict(self):
        return {
            "per_op_bytes": self.per_op_bytes,
            "per_op_count": self.per_op_count,
            "link_bytes": self.link_bytes,
        }


def _shape_bytes(type_str: str) -> float:
    """Bytes of 'bf16[16,4096]' or a tuple '(bf16[..], f32[..])'."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:  # iota form: replica_groups=[ngroups,group_size]<=[N]
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:  # explicit first group {0,1,2,...}
        return len(m.group(1).split(","))
    return default


def collect_collective_stats(hlo_text: str, n_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(type_str)
        if nbytes == 0:
            continue
        g = _group_size(line, n_devices)
        stats.add(kind, nbytes, g)
    return stats
