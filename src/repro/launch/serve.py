"""Serving launcher: ``python -m repro.launch.serve --pipeline <name>``.

Builds one of the seven paper pipelines and serves it through the chosen
executor, printing the paper's §4 metrics.

Modes:
  host           paper-faithful host-loop executor, one request at a time
  fused          single-XLA-program executor, one request at a time
  fused-batched  arrival-driven runtime: Poisson arrivals -> request queue
                 -> max-wait/max-size admission -> fixed-lane batched
                 dispatch (serving/runtime.py)
  fused-sharded  fused-batched with the fixed lanes sharded data-parallel
                 over a 1-D device mesh (--devices N; launch/mesh.py
                 make_serving_mesh).  On CPU, simulate devices with
                 XLA_FLAGS=--xla_force_host_platform_device_count=N.
  fused-continuous  continuous batching: a persistent lane table advanced
                 ``--chunk-iters`` planner iterations per dispatch, with
                 completed lanes recycled to queued requests at chunk
                 boundaries (serving/continuous.py + the lane-table
                 scheduler in serving/runtime.py).  Accepts --devices for
                 a sharded table; --max-wait-ms does not apply (admission
                 happens at every chunk boundary).

Holistic (MEDIAN/QUANTILE) pipelines are served by every mode: pick the
``sensor_health`` pipeline (median + tail-quantile features) or pass
``--median`` for the appendix-D AVG→MEDIAN substitution of any Table 1
pipeline.

SLO-aware graceful degradation (fused-batched / fused-sharded /
fused-continuous):
``--slo-ms`` attaches a latency budget to every arrival, ``--degrade``
installs the knob-tier admission controller (deadline-driven (delta, tau,
iter_cap) scaling + load shedding; serving/degrade.py), and
``--fault-profile`` injects a seeded fault schedule (service-time spikes,
transient executor failures, or an arrival burst; serving/faults.py) to
exercise degradation and recovery.  On fused-continuous the profiles map
to chunk-granular fault points (chunk-dispatch failures roll back to the
checkpointed chunk boundary and replay; refill failures retry the
admission) and a continuous-only ``poison`` profile NaN-scrambles a
lane's carry to exercise per-lane quarantine.  All of it composes with
``--degrade``, ``--devices``, and ``--cache-size``.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --pipeline trip_fare
  PYTHONPATH=src python -m repro.launch.serve --pipeline turbofan --mode fused
  PYTHONPATH=src python -m repro.launch.serve --pipeline sensor_health --mode fused
  PYTHONPATH=src python -m repro.launch.serve --pipeline turbofan --median \
      --mode fused-batched --arrival-rate 50 --batch-size 8 --max-wait-ms 20
  PYTHONPATH=src python -m repro.launch.serve --pipeline turbofan \
      --mode fused-batched --arrival-rate 80 --slo-ms 250 --degrade \
      --fault-profile spikes
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --pipeline turbofan --mode fused-sharded \
      --devices 4 --batch-size 8
  PYTHONPATH=src python -m repro.launch.serve --pipeline turbofan \
      --mode fused-continuous --arrival-rate 80 --batch-size 8 \
      --chunk-iters 4
  PYTHONPATH=src python -m repro.launch.serve --pipeline turbofan \
      --mode fused --cache-size 64

``--cache-size N`` (fused modes, unsharded) turns on the hot-group feature
cache: per-(group, version) device-resident sample buffers + AFC precompute
served from an N-entry LRU (serving/feature_cache.py), so repeat hits on a
hot key pay zero precompute and zero H2D transfer.
"""
from __future__ import annotations

import argparse

from repro.core.executor import BiathlonConfig
from repro.data.synthetic import (
    EXTRA_PIPELINE_NAMES,
    PIPELINE_NAMES,
    make_pipeline,
    make_pipeline_median,
    poisson_arrivals,
)
from repro.serving import BatchedFusedServer, BiathlonServer, ServingRuntime


def _print_table(d: dict) -> None:
    for k, v in d.items():
        print(f"  {k:24s} {v:.4f}" if isinstance(v, float) else f"  {k:24s} {v}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--pipeline", choices=PIPELINE_NAMES + EXTRA_PIPELINE_NAMES, required=True
    )
    ap.add_argument(
        "--mode",
        choices=("host", "fused", "fused-batched", "fused-sharded",
                 "fused-continuous"),
        default="host",
    )
    ap.add_argument(
        "--devices", type=int, default=None,
        help="serving-mesh size for fused-sharded / fused-continuous "
        "(default: every visible device for fused-sharded, unsharded for "
        "fused-continuous); batch-size must be divisible by it",
    )
    ap.add_argument(
        "--chunk-iters", type=int, default=4,
        help="planner iterations per chunk dispatch (fused-continuous); "
        "lower = finer-grained lane recycling, higher = fewer dispatches",
    )
    ap.add_argument(
        "--median", action="store_true",
        help="appendix-D variant: AVG→MEDIAN substitution, retrained",
    )
    ap.add_argument("--rows-per-group", type=int, default=20000)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--tau", type=float, default=0.95)
    ap.add_argument("--delta", type=float, default=None)
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--gamma", type=float, default=0.01)
    ap.add_argument("--m", type=int, default=500)
    # fused-batched runtime knobs
    ap.add_argument("--arrival-rate", type=float, default=20.0,
                    help="Poisson arrival rate in requests/s (fused-batched)")
    ap.add_argument("--batch-size", type=int, default=8,
                    help="fixed lane count per admission batch (fused-batched)")
    ap.add_argument("--max-wait-ms", type=float, default=20.0,
                    help="admission max-wait in milliseconds (fused-batched)")
    # SLO-aware graceful degradation + fault injection (fused-batched)
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="per-request latency budget in ms; arrivals get a "
                    "deadline of t + slo (fused-batched)")
    ap.add_argument("--degrade", action="store_true",
                    help="install the knob-tier admission controller: "
                    "deadline-driven (delta, tau, iter_cap) scaling + load "
                    "shedding (requires --slo-ms for deadline pressure)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="shed when the queue exceeds this bound (--degrade)")
    ap.add_argument("--fault-profile",
                    choices=("none", "spikes", "failures", "burst", "poison"),
                    default="none",
                    help="seeded fault schedule wrapped around the server "
                    "(serving/faults.py): serve_batch-level on fixed-lane "
                    "modes, chunk-granular on fused-continuous; 'poison' "
                    "(lane-carry NaN scramble) is fused-continuous only")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--cache-size", type=int, default=None,
                    help="enable the hot-group feature cache with this many "
                    "LRU entries: fused modes serve version-keyed "
                    "device-resident precompute (fused / fused-batched / "
                    "fused-continuous; incompatible with --devices)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    make = make_pipeline_median if args.median else make_pipeline
    bundle = make(
        args.pipeline, rows_per_group=args.rows_per_group,
        n_serve_groups=6, n_requests=args.requests,
    )
    cfg = BiathlonConfig(
        tau=args.tau, delta=args.delta, alpha=args.alpha, gamma=args.gamma,
        m=args.m, m_sobol=max(args.m // 4, 64),
    )
    delta = cfg.delta if cfg.delta is not None else bundle.pipeline.delta_default

    if args.mode == "fused-continuous":
        import time as _time

        import jax

        from repro.serving import (
            ContinuousBatchedServer,
            ContinuousServingRuntime,
            DegradationController,
            FaultProfile,
            FaultyContinuousServer,
            default_tiers,
            inject_burst,
        )

        mesh = None
        if args.devices is not None:
            from repro.launch.mesh import make_serving_mesh

            mesh = make_serving_mesh(args.devices)
        srv = ContinuousBatchedServer(
            bundle, cfg, batch_size=args.batch_size,
            chunk_iters=args.chunk_iters, mesh=mesh,
            cache_size=args.cache_size,
        )
        arrivals = poisson_arrivals(
            bundle.requests, args.arrival_rate, n=args.requests,
            seed=args.seed,
        )
        if args.fault_profile == "burst":
            mid = arrivals[len(arrivals) // 2][0]
            arrivals = inject_burst(
                arrivals, at_t=mid, n=max(args.requests, 8),
                width_s=0.05, seed=args.fault_seed,
            )
        controller = None
        if args.degrade:
            # seed the controller's per-request service estimate from one
            # measured post-warmup chunk: a request needs at most
            # ceil(max_iters / chunk_iters) chunks to converge
            cap = srv.trace_cap([a[1] for a in arrivals])
            table, _ = srv.admit(
                srv.new_table(cap), cap,
                [(l, bundle.requests[l % len(bundle.requests)], None)
                 for l in range(args.batch_size)],
            )
            table = jax.block_until_ready(srv.run_chunk(table))
            t0 = _time.perf_counter()
            jax.block_until_ready(srv.run_chunk(table))
            chunk_s = _time.perf_counter() - t0
            n_chunks_est = -(-cfg.max_iters // args.chunk_iters)
            controller = DegradationController(
                default_tiers(cfg.tau, cfg.max_iters),
                service_est_s=chunk_s * n_chunks_est,
                lanes=args.batch_size,
                max_queue=args.max_queue,
            )
        # pre-warm the INNER server before wrapping it: injected faults
        # must hit measured traffic (with call indices starting at 0),
        # never the compilation warmup
        ContinuousServingRuntime(srv).warmup([a[1] for a in arrivals])
        server = srv
        if args.fault_profile == "spikes":
            server = FaultyContinuousServer(
                srv, FaultProfile(seed=args.fault_seed, spike_prob=0.2,
                                  spike_s=0.25),
            )
        elif args.fault_profile == "failures":
            server = FaultyContinuousServer(
                srv, FaultProfile(seed=args.fault_seed, chunk_fail_prob=0.1,
                                  refill_fail_prob=0.05),
            )
        elif args.fault_profile == "poison":
            server = FaultyContinuousServer(
                srv, FaultProfile(seed=args.fault_seed, poison_prob=0.05),
            )
        runtime = ContinuousServingRuntime(
            server,
            slo_s=None if args.slo_ms is None else args.slo_ms / 1e3,
            controller=controller,
        )
        stats = runtime.run(arrivals, warmup=False)
        print(f"[serve] {args.pipeline} mode={args.mode} "
              f"rate={args.arrival_rate:.1f}rps lanes={args.batch_size} "
              f"devices={srv.n_devices} chunk_iters={args.chunk_iters} "
              f"delta={delta:.4f} slo={args.slo_ms}ms "
              f"degrade={args.degrade} faults={args.fault_profile}")
        _print_table(stats.summary())
        return

    if args.mode in ("fused-batched", "fused-sharded"):
        import time as _time

        from repro.serving import (
            DegradationController,
            FaultProfile,
            FaultyServer,
            default_tiers,
            inject_burst,
        )

        if args.fault_profile == "poison":
            ap.error("--fault-profile poison scrambles lane carry at chunk "
                     "boundaries; use --mode fused-continuous")
        mesh = None
        if args.mode == "fused-sharded":
            from repro.launch.mesh import make_serving_mesh

            mesh = make_serving_mesh(args.devices)
        srv = BatchedFusedServer(
            bundle, cfg, batch_size=args.batch_size, mesh=mesh,
            cache_size=args.cache_size,
        )
        controller = None
        if args.degrade:
            # seed the controller's service estimate with one measured
            # full-lane batch (post-warmup, so it times the steady state)
            batch = [bundle.requests[i % len(bundle.requests)]
                     for i in range(args.batch_size)]
            srv.serve_batch(batch)
            t0 = _time.perf_counter()
            srv.serve_batch(batch)
            controller = DegradationController(
                default_tiers(cfg.tau, cfg.max_iters),
                service_est_s=_time.perf_counter() - t0,
                lanes=args.batch_size,
                max_queue=args.max_queue,
            )
        arrivals = poisson_arrivals(
            bundle.requests, args.arrival_rate, n=args.requests, seed=args.seed
        )
        if args.fault_profile == "burst":
            mid = arrivals[len(arrivals) // 2][0]
            arrivals = inject_burst(
                arrivals, at_t=mid, n=max(args.requests, 8),
                width_s=0.05, seed=args.fault_seed,
            )
        # pre-warm every cap bucket on the INNER server: injected faults
        # must hit measured traffic (with call indices starting at 0),
        # never the compilation warmup
        ServingRuntime(srv).warmup([a[1] for a in arrivals])
        server = srv
        if args.fault_profile == "spikes":
            server = FaultyServer(
                srv, FaultProfile(seed=args.fault_seed, spike_prob=0.2,
                                  spike_s=0.25),
            )
        elif args.fault_profile == "failures":
            server = FaultyServer(
                srv, FaultProfile(seed=args.fault_seed, fail_prob=0.15),
            )
        runtime = ServingRuntime(
            server, max_wait_s=args.max_wait_ms / 1e3,
            slo_s=None if args.slo_ms is None else args.slo_ms / 1e3,
            controller=controller,
        )
        stats = runtime.run(arrivals)
        print(f"[serve] {args.pipeline} mode={args.mode} "
              f"rate={args.arrival_rate:.1f}rps lanes={args.batch_size} "
              f"devices={srv.n_devices} "
              f"max_wait={args.max_wait_ms:.0f}ms delta={delta:.4f} "
              f"slo={args.slo_ms}ms degrade={args.degrade} "
              f"faults={args.fault_profile}")
        _print_table(stats.summary())
        return

    if args.cache_size is not None and args.mode != "fused":
        ap.error("--cache-size requires a fused mode")
    srv = BiathlonServer(bundle, cfg, mode=args.mode,
                         cache_size=args.cache_size)
    srv.serve(bundle.requests[0])  # warm the jit caches
    stats = srv.serve_all(bundle.requests)
    s = stats.summary(bundle.pipeline.delta_default, bundle.pipeline.task)
    print(f"[serve] {args.pipeline} mode={args.mode} delta={delta:.4f}"
          + (f" cache={args.cache_size}" if args.cache_size is not None
             else ""))
    _print_table(s)
    if srv.cache is not None:
        _print_table({f"cache_{k}": v for k, v in srv.cache.stats.items()})
        srv.check_compile_contract()


if __name__ == "__main__":
    main()
