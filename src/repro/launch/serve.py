"""Serving launcher: ``python -m repro.launch.serve --pipeline <name>``.

Builds one of the seven paper pipelines and drains its request log through
the chosen executor, printing the paper's §4 metrics.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --pipeline trip_fare
  PYTHONPATH=src python -m repro.launch.serve --pipeline turbofan --mode fused
"""
from __future__ import annotations

import argparse

from repro.core.executor import BiathlonConfig
from repro.data.synthetic import PIPELINE_NAMES, make_pipeline
from repro.serving import BiathlonServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pipeline", choices=PIPELINE_NAMES, required=True)
    ap.add_argument("--mode", choices=("host", "fused"), default="host")
    ap.add_argument("--rows-per-group", type=int, default=20000)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--tau", type=float, default=0.95)
    ap.add_argument("--delta", type=float, default=None)
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--gamma", type=float, default=0.01)
    ap.add_argument("--m", type=int, default=500)
    args = ap.parse_args()

    bundle = make_pipeline(
        args.pipeline, rows_per_group=args.rows_per_group,
        n_serve_groups=6, n_requests=args.requests,
    )
    cfg = BiathlonConfig(
        tau=args.tau, delta=args.delta, alpha=args.alpha, gamma=args.gamma,
        m=args.m, m_sobol=max(args.m // 4, 64),
    )
    srv = BiathlonServer(bundle, cfg, mode=args.mode)
    srv.serve(bundle.requests[0])  # warm the jit caches
    stats = srv.serve_all(bundle.requests)
    s = stats.summary(bundle.pipeline.delta_default, bundle.pipeline.task)
    print(f"[serve] {args.pipeline} mode={args.mode} "
          f"delta={cfg.delta if cfg.delta is not None else bundle.pipeline.delta_default:.4f}")
    for k, v in s.items():
        print(f"  {k:24s} {v:.4f}" if isinstance(v, float) else f"  {k:24s} {v}")


if __name__ == "__main__":
    main()
