"""HLO-text cost model with while-loop trip-count accounting.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body once* —
useless for scan-over-layers models (a 60-layer stack reports 1/60th of its
FLOPs).  This module re-derives the three roofline inputs directly from the
post-SPMD HLO text:

* **FLOPs** — every ``dot`` (2 x prod(result dims) x contraction size),
  including dots inside fusion computations, multiplied through the while
  trip counts (nested loops multiply).
* **HBM bytes** — per top-level instruction: operand + result bytes
  (producer+consumer counting, like XLA's own 'bytes accessed'), with two
  corrections: bookkeeping ops (tuple/GTE/parameter/bitcast/constant) are
  free, and dynamic-update-slice fusions count only the update traffic (XLA
  aliases the big buffer in place).
* **Collective link bytes** — ring-weighted per-op traffic:
      all-gather (g-1)/g x out, all-reduce 2(g-1)/g x buf,
      reduce-scatter (g-1) x out (out is the post-scatter shard),
      all-to-all (g-1)/g x buf, collective-permute 1 x buf.

Trip counts come from the loop-condition computation's compare constant
(scan lowers to ``i < N`` with a literal N).  Shapes in the partitioned
module are per-device, so all results are per-chip.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["HloCost", "analyze_hlo", "while_costs"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
# type is either a (possibly /*index=N*/-commented) tuple "(...)" — HLO tuple
# types have no nested parens — or a single shape token.
_INSTR = re.compile(r"^\s*(?:ROOT )?%([\w.\-]+)\s*=\s*(\([^)]*\)|\S+?)\s+([\w\-]+)\(")
_SHAPE = re.compile(r"(\w[\w\d]*)\[([\d,]*)\]")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_CALLS = re.compile(r"calls=%([\w.\-]+)")
_COND_BODY = re.compile(r"condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
    # Fusion-optimistic TPU model: standalone elementwise/convert/broadcast
    # ops at CPU-HLO top level would be fused into neighboring matmuls or
    # fusions by the TPU backend — counting their IO would bill the same
    # activation tensor 3-5x.  Real HBM traffic is captured by dot / fusion /
    # reduce / slice / collective IO below.
    "convert", "broadcast", "add", "subtract", "multiply", "divide",
    "maximum", "minimum", "clamp", "compare", "select", "tanh", "exponential",
    "rsqrt", "sqrt", "negate", "abs", "and", "or", "not", "xor", "sign",
    "floor", "ceil", "log", "log-plus-one", "exponential-minus-one", "power",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclass
class _Comp:
    name: str
    instrs: list = field(default_factory=list)
    types: dict = field(default_factory=dict)  # %name -> type string


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    link_bytes: float = 0.0
    coll_bytes: dict = field(default_factory=dict)
    coll_count: dict = field(default_factory=dict)

    def scaled(self, k: float) -> "HloCost":
        return HloCost(
            self.flops * k,
            self.bytes * k,
            self.link_bytes * k,
            {a: b * k for a, b in self.coll_bytes.items()},
            {a: b * k for a, b in self.coll_count.items()},
        )

    def add(self, other: "HloCost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.link_bytes += other.link_bytes
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0) + v

    def as_dict(self):
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "link_bytes": self.link_bytes,
            "per_op_bytes": self.coll_bytes,
            "per_op_count": self.coll_count,
        }


def _parse_computations(text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and "{" in line:
                cur = _Comp(m.group(1))
                if line.strip().startswith("ENTRY"):
                    entry_name = m.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            name, type_str, op = m.group(1), m.group(2), m.group(3)
            cur.instrs.append(_Instr(name, type_str, op, line))
            cur.types[name] = type_str
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(cond: _Comp) -> int:
    """Trip count of a scan-lowered while: the literal the induction counter
    is compared against.

    We resolve the ROOT instruction's *operands* and take constants among
    them (the compare may be wrapped in a fusion, but the constant still
    appears as an operand by name on the root/fusion line).  Falling back to
    the max constant in the computation is wrong whenever the cond carries
    unrelated literals (observed: shape bounds leaking in and inflating
    costs 1000x), so the fallback is only used when no operand resolves.
    """
    consts: dict[str, int] = {}
    for ins in cond.instrs:
        if ins.op == "constant":
            m = _CONST_INT.search(ins.line)
            if m:
                consts[ins.name] = int(m.group(1))
    root = None
    for ins in cond.instrs:
        if "ROOT" in ins.line:
            root = ins
    root = root or (cond.instrs[-1] if cond.instrs else None)
    if root is not None:
        call_part = root.line.split(root.op + "(", 1)
        if len(call_part) == 2:
            cands = [
                consts[name]
                for name in _OPERANDS.findall(call_part[1].split(")")[0])
                if name in consts
            ]
            if cands:
                return max(max(cands), 1)
    best = 1
    for ins in cond.instrs:
        for m in _CONST_INT.finditer(ins.line):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: _Instr, comp: _Comp) -> float:
    result_elems = 1
    for _, dims in _shape_dims(ins.type_str):
        for d in dims:
            result_elems *= d
    # contraction size from lhs operand shape
    mc = _CONTRACT.search(ins.line)
    if not mc:
        return 0.0
    cdims = [int(x) for x in mc.group(1).split(",")] if mc.group(1) else []
    # first operand after the op name
    call_part = ins.line.split(ins.op + "(", 1)[1]
    ops = _OPERANDS.findall(call_part)
    if not ops:
        return 0.0
    lhs_type = comp.types.get(ops[0])
    if lhs_type is None:
        return 2.0 * result_elems  # unknown operand: assume contraction 1
    shapes = _shape_dims(lhs_type)
    if not shapes:
        return 0.0
    dims = shapes[0][1]
    csize = 1
    for cd in cdims:
        if cd < len(dims):
            csize *= dims[cd]
    return 2.0 * result_elems * csize


def _operand_bytes(ins: _Instr, comp: _Comp) -> tuple[float, float]:
    """(total operand bytes, biggest single operand bytes)."""
    call_part = ins.line.split(ins.op + "(", 1)
    if len(call_part) < 2:
        return 0.0, 0.0
    total = biggest = 0.0
    for op_name in _OPERANDS.findall(call_part[1].split(")")[0]):
        t = comp.types.get(op_name)
        if t:
            b = _type_bytes(t)
            total += b
            biggest = max(biggest, b)
    return total, biggest


def _fusion_param_kinds(callee: _Comp):
    """Classify how each fusion parameter is consumed inside the callee.

    Returns "convert_only" when the fusion is a pure dtype-cast chain, else
    {param_index: slice_bytes} for parameters read via dynamic-slice (only
    the slice hits memory), other params read fully.
    """
    param_index: dict[str, int] = {}
    ops_seen = set()
    via: dict[str, str] = {}  # alias (bitcast/copy) -> source name
    sliced: dict[int, float] = {}
    for ins in callee.instrs:
        ops_seen.add(ins.op)
        if ins.op == "parameter":
            m = re.search(r"parameter\((\d+)\)", ins.line)
            if m:
                param_index[ins.name] = int(m.group(1))
        elif ins.op in ("bitcast", "copy", "reshape"):
            srcs = _OPERANDS.findall(ins.line.split(ins.op + "(", 1)[1])
            if srcs:
                via[ins.name] = srcs[0]
        elif ins.op in ("dynamic-slice", "gather"):
            # Both address only the selected rows of their big operand:
            # charge the result bytes, not the whole table (a prefix-table
            # gather reads k rows, not the (k, cap, 4) table it indexes).
            srcs = _OPERANDS.findall(ins.line.split(ins.op + "(", 1)[1])
            if srcs:
                src = srcs[0]
                for _ in range(4):
                    src = via.get(src, src)
                if src in param_index:
                    sliced[param_index[src]] = _type_bytes(ins.type_str)
    body_ops = ops_seen - {"parameter", "constant", "bitcast", "reshape", "copy"}
    if body_ops <= {"convert"}:
        return "convert_only"
    return sliced


def _fusion_operand_bytes(ins: _Instr, comp: _Comp, sliced: dict) -> float:
    call_part = ins.line.split(ins.op + "(", 1)
    if len(call_part) < 2:
        return 0.0
    total = 0.0
    for i, op_name in enumerate(_OPERANDS.findall(call_part[1].split(")")[0])):
        if i in sliced:
            total += sliced[i]
            continue
        t = comp.types.get(op_name)
        if t:
            total += _type_bytes(t)
    return total


def _collective(ins: _Instr, n_devices: int):
    nbytes = _type_bytes(ins.type_str)
    m = _GROUPS_IOTA.search(ins.line)
    if m:
        g = int(m.group(2))
    else:
        m = _GROUPS_LIST.search(ins.line)
        g = len(m.group(1).split(",")) if m else n_devices
    g = max(g, 1)
    kind = next(k for k in _COLLECTIVES if ins.op.startswith(k))
    if kind == "all-reduce":
        w = 2.0 * (g - 1) / g
    elif kind == "reduce-scatter":
        w = float(g - 1)          # result is the post-scatter shard
    elif kind == "collective-permute":
        w = 1.0
    else:
        w = (g - 1) / g
    return kind, nbytes, nbytes * w


def _eval_comp(
    comp: _Comp, comps: dict, n_devices: int, memo: dict, flops_only_fusion=False
) -> HloCost:
    if comp.name in memo:
        return memo[comp.name]
    total = HloCost()
    for ins in comp.instrs:
        op = ins.op
        if op == "while":
            mcb = _COND_BODY.search(ins.line)
            if mcb:
                cond = comps.get(mcb.group(1))
                body = comps.get(mcb.group(2))
                trips = _trip_count(cond) if cond else 1
                if body:
                    total.add(
                        _eval_comp(body, comps, n_devices, memo).scaled(trips)
                    )
            continue
        if op == "fusion":
            mcalls = _CALLS.search(ins.line)
            callee = comps.get(mcalls.group(1)) if mcalls else None
            if callee is not None:
                sub = _eval_comp(
                    callee, comps, n_devices, memo, flops_only_fusion=True
                )
                total.flops += sub.flops            # dots inside fusions count
                total.link_bytes += sub.link_bytes  # (collectives never fuse)
            rb = _type_bytes(ins.type_str)
            if "dynamic_update_slice" in ins.line or "dynamic-update-slice" in ins.line:
                # DUS fusions alias the big buffer in place:
                # traffic = read update + write slice ~= 2 x update bytes.
                ob, biggest = _operand_bytes(ins, comp)
                total.bytes += 2.0 * max(ob - biggest, 0.0)
            elif callee is not None:
                # Per-operand accounting: params consumed via dynamic-slice
                # inside the fusion read only the slice (e.g. one layer of a
                # scanned weight stack), not the whole operand; pure-convert
                # fusions are CPU bf16->f32 staging the TPU backend never
                # emits -> free.
                kinds = _fusion_param_kinds(callee)
                if kinds == "convert_only":
                    pass
                else:
                    total.bytes += rb + _fusion_operand_bytes(ins, comp, kinds)
            else:
                ob, _ = _operand_bytes(ins, comp)
                total.bytes += ob + rb
            continue
        if op in ("call", "conditional"):
            mcalls = _CALLS.search(ins.line) or _COND_BODY.search(ins.line)
            for name in _OPERANDS.findall(ins.line.split("(", 1)[1]):
                if name in comps:
                    total.add(_eval_comp(comps[name], comps, n_devices, memo))
            continue
        if any(op.startswith(c) for c in _COLLECTIVES):
            if op.endswith("-done"):
                continue
            kind, nbytes, link = _collective(ins, n_devices)
            total.coll_bytes[kind] = total.coll_bytes.get(kind, 0.0) + nbytes
            total.coll_count[kind] = total.coll_count.get(kind, 0) + 1
            total.link_bytes += link
            total.bytes += 2 * nbytes  # collectives also touch HBM
            continue
        if op == "dot":
            total.flops += _dot_flops(ins, comp)
            if not flops_only_fusion:
                ob, _ = _operand_bytes(ins, comp)
                total.bytes += ob + _type_bytes(ins.type_str)
            continue
        if op in _FREE_OPS:
            continue
        if flops_only_fusion:
            continue  # inside fusions, non-dot ops stay in registers
        if op == "dynamic-update-slice":
            # in-place: traffic = read update + write slice = 2 x update
            ob, biggest = _operand_bytes(ins, comp)
            total.bytes += 2.0 * max(ob - biggest, 0.0)
            continue
        if op == "gather":
            # addressed traffic only: read the gathered rows + the index
            # operand, write the result — NOT the whole indexed table
            # (billing it would claim a (k, cap, 4) prefix-table read per
            # O(1) AFC lookup).  The table is specifically operand 0 of
            # gather(operand, indices) — not "the biggest operand", which
            # would mischarge whenever the index tensor outgrows the table.
            call_part = ins.line.split(op + "(", 1)
            table_bytes = 0.0
            if len(call_part) == 2:
                srcs = _OPERANDS.findall(call_part[1].split(")")[0])
                if srcs:
                    t = comp.types.get(srcs[0])
                    table_bytes = _type_bytes(t) if t else 0.0
            ob, _ = _operand_bytes(ins, comp)
            rb = _type_bytes(ins.type_str)
            total.bytes += max(ob - table_bytes, 0.0) + 2.0 * rb
            continue
        # generic top-level op: producer+consumer traffic
        ob, _ = _operand_bytes(ins, comp)
        total.bytes += ob + _type_bytes(ins.type_str)
    memo[comp.name] = total
    return total


def analyze_hlo(text: str, n_devices: int) -> HloCost:
    comps = _parse_computations(text)
    entry = comps.get("__entry__")
    if entry is None:
        return HloCost()
    memo: dict = {}
    return _eval_comp(entry, comps, n_devices, memo)


def while_costs(text: str, n_devices: int = 1) -> list[dict]:
    """Per-while-loop body costs of a compiled module.

    Returns one entry per ``while`` instruction found anywhere in the
    module: ``{"body": name, "trips": estimated trip count, "cost": HloCost
    of ONE body execution}`` (nested whiles inside the body are multiplied
    through as usual).  This is the per-iteration cost probe the
    incremental-AFC regression test uses: the fused executor's planner loop
    body must cost the same regardless of the (k, cap) buffer size, while
    the whole-program cost may scale with cap (the once-per-request
    precompute is allowed to).  Callers pick their loop of interest — the
    planner while is the one with the largest body cost (the inner Beta
    rejection loops are tiny).
    """
    comps = _parse_computations(text)
    out = []
    seen: set[str] = set()
    memo: dict = {}
    for cname, comp in comps.items():
        if cname == "__entry__" or comp.name in seen:
            continue
        seen.add(comp.name)
        for ins in comp.instrs:
            if ins.op != "while":
                continue
            mcb = _COND_BODY.search(ins.line)
            if not mcb:
                continue
            cond = comps.get(mcb.group(1))
            body = comps.get(mcb.group(2))
            if body is None:
                continue
            out.append(
                {
                    "body": body.name,
                    "trips": _trip_count(cond) if cond else 1,
                    "cost": _eval_comp(body, comps, n_devices, memo),
                }
            )
    return out
