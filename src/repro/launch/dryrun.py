"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first (before any jax import): jax locks the
device count at first init, and the production meshes need 512 placeholder
host devices.  Do NOT set that flag anywhere global — smoke tests and
benchmarks should see 1 device.

Per cell this driver:
  1. builds the production mesh (16x16 pod / 2x16x16 multi-pod),
  2. eval_shape's the full-scale params (ShapeDtypeStruct, no allocation),
  3. jit-lowers the cell's step (train_step / prefill / decode_step) with
     NamedShardings from repro.models.lm.sharding,
  4. ``.compile()``s it — any sharding mismatch, OOM-at-compile or
     unsupported collective fails the cell,
  5. records memory_analysis / cost_analysis / collective traffic to
     experiments/dryrun/<arch>__<shape>__<mesh>.json for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--jobs 1]
"""
from __future__ import annotations

# The next two lines MUST run before ANY jax import (jax locks the device
# count at first init; the production meshes need 512 placeholder devices).
import os  # noqa: E402
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, cell_applicable, get_config
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.hlo_stats import HW
from repro.launch.mesh import DP_AXES, make_production_mesh
from repro.models.lm import LM
from repro.models.lm.sharding import (
    ShardingRules,
    batch_pspec,
    cache_pspecs,
    param_pspecs,
    use_rules,
)
from repro.optim.adamw import AdamWState
from repro.train.step import build_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32
    if shape.kind == "train":
        s_text = s - cfg.n_frontend_tokens if cfg.family == "vlm" else s
        batch = {"tokens": jax.ShapeDtypeStruct((b, s_text + 1), i32)}
        if cfg.frontend:
            batch["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_model), f32
            )
        return batch
    if shape.kind == "prefill":
        s_text = s - cfg.n_frontend_tokens if cfg.family == "vlm" else s
        out = {"tokens": jax.ShapeDtypeStruct((b, s_text), i32)}
        if cfg.frontend:
            out["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_model), f32
            )
        return out
    # decode: one new token against a seq_len-deep cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}


def _opt_specs(p_specs):
    return AdamWState(step=P(), mu=p_specs, nu=jax.tree.map(lambda s: s, p_specs))


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    out_dir: str = OUT_DIR,
    *,
    tag: str = "",
    cfg_override=None,
    fsdp: bool = False,
    model_kwargs: dict | None = None,
    train_kwargs: dict | None = None,
):
    """Compile one cell.  Hillclimb variants pass ``tag`` (separate JSON),
    ``cfg_override`` (ModelConfig -> ModelConfig), ``fsdp`` (ZeRO-3 weight
    sharding) and ``model_kwargs`` (LM constructor knobs)."""
    cfg = get_config(arch)
    if cfg_override is not None:
        cfg = cfg_override(cfg)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "tag": tag,
        "kind": shape.kind,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if not ok:
        record.update({"status": "skipped", "reason": why})
        _write(record, out_dir)
        print(f"[dryrun] SKIP {arch} x {shape_name} x {mesh_name}: {why}")
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rules = ShardingRules(mesh, cfg, dp_axes=DP_AXES(multi_pod), fsdp=fsdp)
    model = LM(cfg, remat=(shape.kind == "train"), **(model_kwargs or {}))
    t0 = time.time()

    with use_rules(rules):
        params_shapes = model.init_shapes()
        p_specs = param_pspecs(rules, params_shapes)
        p_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)
        b_spec = batch_pspec(rules, shape.kind, shape.global_batch)
        specs = input_specs(arch, shape_name)
        b_shardings = {
            k: NamedSharding(mesh, b_spec.get(k, P())) for k in specs
        }

        if shape.kind == "train":
            step_fn = build_train_step(model, **(train_kwargs or {}))
            opt_specs = _opt_specs(p_specs)
            opt_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), opt_specs)
            opt_shapes = jax.eval_shape(
                lambda p: AdamWState(
                    step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
                    nu=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
                ),
                params_shapes,
            )
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_shardings, opt_shardings, b_shardings, NamedSharding(mesh, P())),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(
                params_shapes, opt_shapes, specs, jax.ShapeDtypeStruct((), jnp.int32)
            )
        elif shape.kind == "prefill":
            def serve_step(params, batch):
                if cfg.frontend:
                    return model.prefill(params, batch["tokens"], batch["frontend"])
                return model.prefill(params, batch["tokens"])

            jitted = jax.jit(serve_step, in_shardings=(p_shardings, b_shardings))
            lowered = jitted.lower(params_shapes, specs)
        else:  # decode
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            record["cache_bytes"] = int(
                sum(
                    int(np.prod(l.shape)) * l.dtype.itemsize
                    for l in jax.tree.leaves(cache_shapes)
                )
            )
            c_specs = cache_pspecs(rules, cache_shapes, shape.global_batch)
            c_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs)

            def serve_step(params, cache, batch):
                return model.decode_step(params, cache, batch["tokens"])

            jitted = jax.jit(
                serve_step,
                in_shardings=(p_shardings, c_shardings, b_shardings),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_shapes, cache_shapes, specs)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # ---- artifact stats ---------------------------------------------------
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    # XLA's cost_analysis counts while bodies once; analyze_hlo multiplies
    # through scan trip counts and adds collective link traffic (hlo_cost.py).
    hc = analyze_hlo(hlo, n_dev)

    record.update(
        {
            "status": "ok",
            "n_devices": int(n_dev),
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "hlo_flops": hc.flops,
            "hlo_bytes": hc.bytes,
            "xla_cost_flops_unscaled": float(cost.get("flops", 0.0)),
            "collectives": hc.as_dict(),
        }
    )
    if mem is not None:
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            v = getattr(mem, k, None)
            if v is not None:
                record[f"mem_{k}"] = int(v)
    # roofline terms (single-chip normalization; see benchmarks/roofline.py)
    record["terms"] = roofline_terms(record, cfg, shape)
    _write(record, out_dir)
    print(
        f"[dryrun] OK {arch} x {shape_name} x {mesh_name}: "
        f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
        f"flops/dev {hc.flops:.3e} link_bytes/dev {hc.link_bytes:.3e}"
    )
    return record


def roofline_terms(record: dict, cfg, shape) -> dict:
    """compute/memory/collective seconds per device (brief §ROOFLINE)."""
    # cost_analysis of the SPMD-partitioned module is per-device already.
    t_compute = record["hlo_flops"] / HW["peak_flops"]
    t_memory = record["hlo_bytes"] / HW["hbm_bw"]
    t_coll = record["collectives"].get("link_bytes", 0.0) / HW["ici_bw"]
    terms = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
    }
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant
    # model FLOPs: 6 N D tokens (train), 2 N D (inference fwd only)
    n_active = record["active_params"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = shape.global_batch  # one token per sequence
        model_flops = 2.0 * n_active * tokens
    terms["model_flops_total"] = model_flops
    n_dev = record.get("n_devices", 1)
    hlo_total = record["hlo_flops"] * n_dev
    terms["useful_flop_ratio"] = model_flops / hlo_total if hlo_total else 0.0
    terms["roofline_fraction"] = (
        (model_flops / n_dev / HW["peak_flops"]) / max(max(t_compute, t_memory, t_coll), 1e-30)
    )
    if shape.kind == "decode":
        # decode is memory-bound by construction (read all weights + cache
        # once per token); the meaningful roofline is bytes-based:
        # ideal = (params + cache, bf16) / chips, one pass.  Full params,
        # not active: at batch >= n_experts every expert is touched.
        ideal = (
            2.0 * record["params"] + record.get("cache_bytes", 0)
        ) / n_dev
        terms["ideal_bytes_per_dev"] = ideal
        terms["memory_roofline_fraction"] = (
            ideal / max(record["hlo_bytes"], 1e-30)
        )
    return terms


def _write(record: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    tag = f"__{record['tag']}" if record.get("tag") else ""
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}{tag}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(record, f, indent=2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    if args.all:
        failures = []
        for arch in ARCH_IDS:
            for shape_name in SHAPES:
                try:
                    run_cell(arch, shape_name, args.multi_pod, args.out)
                except Exception as e:  # noqa: BLE001 - record and continue
                    traceback.print_exc()
                    failures.append((arch, shape_name, str(e)[:200]))
        if failures:
            print(f"[dryrun] {len(failures)} FAILURES:")
            for f in failures:
                print("   ", f)
            sys.exit(1)
        print("[dryrun] all cells OK")
        return
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    run_cell(args.arch, args.shape, args.multi_pod, args.out)


if __name__ == "__main__":
    main()
