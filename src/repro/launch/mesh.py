"""Production mesh builders (functions, never module-level constants, so
importing this module never touches jax device state)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "DP_AXES"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod stacks 2 pods = 512 chips.

    Axes: 'data' carries batch (gradient all-reduce), 'model' carries tensor/
    expert/vocab parallelism (and the decode split-K axis); 'pod' composes
    with 'data' for the hierarchical cross-pod gradient reduction.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def DP_AXES(multi_pod: bool) -> tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)
