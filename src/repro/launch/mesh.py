"""Production mesh builders (functions, never module-level constants, so
importing this module never touches jax device state)."""
from __future__ import annotations

import os
import pathlib

import jax
import numpy as np

__all__ = [
    "make_production_mesh",
    "make_serving_mesh",
    "forced_host_devices_env",
    "DP_AXES",
    "LANES_AXIS",
]

#: The 1-D serving mesh axis: admission-batch lanes are data-parallel over it.
LANES_AXIS = "lanes"


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod stacks 2 pods = 512 chips.

    Axes: 'data' carries batch (gradient all-reduce), 'model' carries tensor/
    expert/vocab parallelism (and the decode split-K axis); 'pod' composes
    with 'data' for the hierarchical cross-pod gradient reduction.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def DP_AXES(multi_pod: bool) -> tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def make_serving_mesh(n_devices: int | None = None, *, devices=None):
    """1-D ``("lanes",)`` mesh for data-parallel fused serving.

    Every lane of a fixed-lane admission batch (serving/batched.py) is an
    independent while-loop, so the batched executor shards purely along a
    single ``"lanes"`` axis — no tensor axis, no collectives on the hot path.

    ``n_devices=None`` takes every visible device.  On CPU hosts, multi-device
    meshes are simulated with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    (set it BEFORE jax initializes); the error message points there because
    that is the one environment knob tests and CI need.
    """
    devs = list(jax.devices()) if devices is None else list(devices)
    n = len(devs) if n_devices is None else int(n_devices)
    if n < 1:
        raise ValueError(f"n_devices must be >= 1, got {n}")
    if n > len(devs):
        raise ValueError(
            f"n_devices={n} but only {len(devs)} devices are visible; on CPU "
            "set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} before jax initializes"
        )
    return jax.sharding.Mesh(np.asarray(devs[:n]), (LANES_AXIS,))


def forced_host_devices_env(n_devices: int) -> dict:
    """Subprocess environment with ``n_devices`` simulated CPU devices.

    jax fixes its device list at first initialization, so multi-device CPU
    work (the cross-device parity tests, the sharded benchmark sweep) must
    run in a FORKED process with ``--xla_force_host_platform_device_count``
    set before jax imports.  This is the one shared recipe: append the
    force flag to any existing ``XLA_FLAGS``, pin the platform to cpu
    (the flag only multiplies HOST devices — an accelerator platform would
    ignore it and defeat the simulation), and prepend this package's
    ``src`` root to ``PYTHONPATH`` so the child can ``import repro`` no
    matter its cwd.  Real multi-chip runs don't go through this: they pass
    ``make_serving_mesh`` over the actual devices to the server directly.
    """
    env = dict(os.environ)
    force = f"--xla_force_host_platform_device_count={int(n_devices)}"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + force).strip()
    env["JAX_PLATFORMS"] = "cpu"
    src = str(pathlib.Path(__file__).resolve().parents[2])
    extra = env.get("PYTHONPATH")
    env["PYTHONPATH"] = src + os.pathsep + extra if extra else src
    return env
