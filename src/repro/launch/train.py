"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container full-scale configs are dry-run-only, so the default
trains the REDUCED config of the chosen architecture end-to-end (real
optimizer, checkpoints, restart); ``--full`` lowers the full config against
the production mesh first (sanity) and then refuses to run on CPU.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch zamba2-2.7b --steps 20
"""
from __future__ import annotations

import argparse
import time

from repro.configs import ARCH_IDS, get_config
from repro.models.lm import LM
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--save-every", type=int, default=25)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = LM(cfg, remat=True, attn_block=64, loss_chunk=64)
    ckpt = args.ckpt or f"/tmp/repro_train_{args.arch.replace('/', '_')}"
    tc = TrainerConfig(
        batch_size=args.batch, seq_len=args.seq, total_steps=args.steps,
        save_every=args.save_every, lr=args.lr, grad_accum=args.grad_accum,
    )
    trainer = Trainer(model, ckpt, tc)
    print(f"[train] {args.arch} (reduced: {cfg.param_count()/1e6:.1f}M params) "
          f"steps={args.steps} ckpt={ckpt}")
    t0 = time.time()
    _, hist = trainer.run()
    if hist:
        print(f"[train] loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
              f"in {time.time()-t0:.1f}s; straggler events: "
              f"{trainer.straggler_events}")
    else:
        print(f"[train] already complete at step {trainer.manager.latest_step()}")


if __name__ == "__main__":
    main()
