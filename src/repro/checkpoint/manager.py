"""Fault-tolerant checkpointing: atomic, async, elastically resharding.

Design points for 1000+-node operation (DESIGN.md §5):

* **Atomicity** — write to ``step_XXXX.tmp`` then ``os.rename`` (POSIX-atomic),
  so a preemption mid-save never corrupts the latest-good checkpoint.
* **Self-describing layout** — the file stores the flattened PyTree as
  {path: (shape, dtype, bytes)} plus metadata (step, mesh shape, per-leaf
  PartitionSpec).  Restore therefore does NOT need the writing mesh: leaves
  are loaded as host arrays and ``jax.device_put`` against the *restoring*
  mesh's NamedShardings — elastic re-sharding (grow/shrink the pod count
  between runs) is just a different target sharding at load.
* **Async save** — serialization happens on a worker thread over a host
  snapshot (jax.device_get), keeping the train loop's bubble to the D2H copy.
* **Retention** — keep the last N checkpoints; GC is also atomic (rename to
  ``.trash`` then unlink) so a crash during GC cannot eat the newest file.
* **Integrity** — zstd-compressed msgpack with a per-leaf crc32; restore
  verifies before device_put.
"""
from __future__ import annotations

import json
import os
import re
import struct
import threading
import zlib
from dataclasses import dataclass
from typing import Any

import jax
import numpy as np

try:
    import zstandard as zstd

    def _compress(b: bytes) -> bytes:
        return zstd.ZstdCompressor(level=3).compress(b)

    def _decompress(b: bytes) -> bytes:
        return zstd.ZstdDecompressor().decompress(b)

except Exception:  # pragma: no cover - zstd is installed in this container

    def _compress(b: bytes) -> bytes:
        return zlib.compress(b, 3)

    def _decompress(b: bytes) -> bytes:
        return zlib.decompress(b)

__all__ = ["CheckpointManager", "save_pytree", "load_pytree"]

_MAGIC = b"RPRCKPT2"


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def save_pytree(tree, path: str, meta: dict | None = None) -> None:
    """Serialize a PyTree of arrays to ``path`` atomically."""
    leaves = _flatten_with_paths(tree)
    index = []
    blobs = []
    offset = 0
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        raw = arr.tobytes()
        blob = _compress(raw)
        index.append(
            {
                "key": key,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "offset": offset,
                "nbytes": len(blob),
                "crc": zlib.crc32(raw) & 0xFFFFFFFF,
            }
        )
        blobs.append(blob)
        offset += len(blob)
    header = json.dumps({"meta": meta or {}, "index": index}).encode()
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(_MAGIC)
        f.write(struct.pack("<Q", len(header)))
        f.write(header)
        for b in blobs:
            f.write(b)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, path)  # POSIX-atomic publish


def load_pytree(path: str, target_tree=None, shardings=None):
    """Load a checkpoint; returns (tree, meta).

    With ``target_tree`` (a PyTree of arrays or ShapeDtypeStructs) the loaded
    leaves are restructured to match it; with ``shardings`` (matching PyTree
    of NamedSharding) each leaf is device_put against the *current* mesh —
    this is the elastic-reshard path.
    """
    with open(path, "rb") as f:
        assert f.read(8) == _MAGIC, f"bad checkpoint magic in {path}"
        (hlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hlen))
        base = f.tell()
        leaves = {}
        for ent in header["index"]:
            f.seek(base + ent["offset"])
            raw = _decompress(f.read(ent["nbytes"]))
            assert zlib.crc32(raw) & 0xFFFFFFFF == ent["crc"], (
                f"crc mismatch for {ent['key']} in {path}"
            )
            leaves[ent["key"]] = np.frombuffer(raw, dtype=ent["dtype"]).reshape(
                ent["shape"]
            )

    if target_tree is None:
        return leaves, header["meta"]

    flat_target = _flatten_with_paths(target_tree)
    shard_flat = (
        [s for _, s in _flatten_with_paths(shardings)] if shardings is not None else None
    )
    out_leaves = []
    for i, (key, tgt) in enumerate(flat_target):
        if key not in leaves:
            raise KeyError(f"checkpoint {path} missing leaf {key!r}")
        arr = leaves[key]
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs target {tgt.shape}"
            )
        arr = arr.astype(tgt.dtype)
        if shard_flat is not None:
            arr = jax.device_put(arr, shard_flat[i])
        out_leaves.append(arr)
    treedef = jax.tree_util.tree_structure(target_tree)
    return jax.tree_util.tree_unflatten(treedef, out_leaves), header["meta"]


@dataclass
class CheckpointManager:
    """Directory-of-checkpoints manager with retention and async saves."""

    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}.ckpt")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.match(r"step_(\d+)\.ckpt$", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, meta: dict | None = None, block: bool = True):
        meta = dict(meta or {}, step=step)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_pytree(host_tree, self._path(step), meta)
            self._gc()

        self.wait()
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, target_tree=None, shardings=None, step: int | None = None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        return load_pytree(self._path(step), target_tree, shardings)

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            victim = self._path(s)
            trash = victim + ".trash"
            try:
                os.rename(victim, trash)
                os.unlink(trash)
            except OSError:  # pragma: no cover - concurrent GC
                pass
