from repro.kernels.sobol import ops  # noqa: F401
