"""Jit'd wrapper for Sobol point generation."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qmc import sobol_uint32
from repro.kernels.sobol.sobol import sobol_points

__all__ = ["uniforms"]


def uniforms(m: int, dim: int, skip: int = 0, *, use_kernel: bool | None = None):
    """(m, dim) f32 low-discrepancy uniforms in (0, 1)."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        x = sobol_points(m, dim, skip, interpret=jax.default_backend() != "tpu")
    else:
        x = sobol_uint32(m, dim, skip)
    return x.astype(jnp.float32) * jnp.float32(2.0**-32) + jnp.float32(0.5 * 2.0**-32)
