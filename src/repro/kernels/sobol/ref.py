"""Oracle for the Sobol kernel: re-exports the validated pure-jnp generator."""
from repro.core.qmc import sobol_uint32 as sobol_uint32_ref  # noqa: F401

__all__ = ["sobol_uint32_ref"]
