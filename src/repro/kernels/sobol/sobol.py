"""Pallas TPU kernel: Sobol low-discrepancy point generation (AMI, §3.3).

The QMC uniforms are the first thing every AMI / Sobol-index call needs:
an (m, d) grid of uint32 Sobol points.  The direct gray-code construction is
32 masked XOR steps over a (block_m, d) tile — pure VPU integer work with no
cross-tile dependence, so the grid parallelizes over m tiles and the
direction-number table (d, 32) stays VMEM-resident.

This is the TPU adaptation of "draw m low-discrepancy samples": no host
round-trip, generated where the model inference (tree_qmc / MLP matmul)
consumes it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["sobol_points"]


def _kernel(sv_ref, out_ref, *, block_m: int, skip: int):
    mi = pl.program_id(0)
    base = skip + mi * block_m
    idx = base + jax.lax.broadcasted_iota(jnp.uint32, (block_m, 1), 0)
    gray = idx ^ (idx >> 1)                      # (block_m, 1)
    sv = sv_ref[...]                             # (d, 32) uint32
    acc = jnp.zeros((block_m, sv.shape[0]), jnp.uint32)
    for b in range(32):
        bit = ((gray >> b) & 1).astype(bool)     # (block_m, 1)
        acc = jnp.where(bit, acc ^ sv[None, :, b], acc)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("m", "dim", "skip", "block_m", "interpret"))
def sobol_points(
    m: int,
    dim: int,
    skip: int = 0,
    *,
    block_m: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """(m, dim) uint32 Sobol points, bit-exact with the jnp/scipy oracle."""
    from repro.core.sobol_tables import DIRECTION_NUMBERS

    sv = jnp.asarray(DIRECTION_NUMBERS[:dim], jnp.uint32)
    block_m = min(block_m, m)
    assert m % block_m == 0
    return pl.pallas_call(
        functools.partial(_kernel, block_m=block_m, skip=skip),
        grid=(m // block_m,),
        in_specs=[pl.BlockSpec((dim, 32), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_m, dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, dim), jnp.uint32),
        interpret=interpret,
    )(sv)
