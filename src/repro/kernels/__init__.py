"""Pallas TPU kernels (+ ops wrappers + pure-jnp oracles).

Each subpackage: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
dispatch wrapper), ref.py (oracle used by the interpret-mode allclose tests).
"""
