"""Compensated float32 accumulation for the power-sum AFC paths.

The 5-power-sum pipeline (``sampled_moments`` kernel + ref oracle, and the
incremental ``prefix_stats`` tables) feeds Σv..Σv⁴ into the VAR/STD error
estimators.  At 60k-row groups with heavy-tailed columns a naive float32
accumulation of Σv⁴ loses 3-4 significant digits (sequential rounding is
O(n·ε); a handful of tail rows dominate the sum and the small rows vanish),
which surfaces as a wrong σ — i.e. a wrong Eq. 1 guarantee — exactly in the
large-``n`` regime the prefix tables exist for.

JAX float64 is globally gated behind ``jax_enable_x64`` (flipping it changes
weak-dtype semantics repo-wide), so instead every accumulation here uses
**error-free transformations** (Knuth two-sum / Dekker fast-two-sum): a
running value is carried as an unevaluated (hi, lo) float32 pair whose sum
tracks the exact result to ~2⁻⁴⁸ relative — double-precision-class accuracy
built from f32 adds, portable to the TPU VPU (which has no f64 unit at all).

* :func:`comp_cumsum` — compensated inclusive prefix sums via
  ``lax.associative_scan`` over (hi, lo) pairs: O(log n) depth, fully
  parallel, error O(ε·log n) instead of O(ε·n).
* :func:`comp_sum` — compensated total (last element of the scan).
* :func:`two_sum` / :func:`kahan_step` — the primitives, reused inside the
  Pallas kernels for the cross-tile carry (a VMEM (block_k, 5) compensation
  accumulator next to the running sums).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["two_sum", "kahan_step", "comp_cumsum", "comp_sum"]


def two_sum(a: jnp.ndarray, b: jnp.ndarray):
    """Knuth error-free addition: returns (s, e) with s = fl(a+b), s+e = a+b.

    Branch-free (no magnitude test), so it vectorizes on the VPU.  Relies on
    IEEE round-to-nearest f32 arithmetic; XLA does not re-associate across
    these named intermediates.
    """
    s = a + b
    bp = s - a
    e = (a - (s - bp)) + (b - bp)
    return s, e


def kahan_step(hi: jnp.ndarray, lo: jnp.ndarray, x: jnp.ndarray):
    """One compensated accumulation step: (hi, lo) += x.

    ``hi + lo`` tracks the exact running sum; feed ``x`` pre-corrected by the
    running compensation (Kahan-Babuska variant: the correction is *added to
    lo*, never folded into hi until the caller collapses the pair).
    """
    s, e = two_sum(hi, x)
    return s, lo + e


def _comp_combine(a, b):
    """Associative combine over (hi, lo) pairs for ``associative_scan``."""
    s, e = two_sum(a[0], b[0])
    return s, a[1] + b[1] + e


def comp_cumsum(x: jnp.ndarray, axis: int = -1, collapse: bool = True):
    """Compensated inclusive prefix sums of ``x`` along ``axis`` (float32).

    Returns ``hi + lo`` collapsed to f32 (default), or the raw (hi, lo) pair
    when ``collapse=False`` — callers that keep accumulating should stay in
    pair space.  Matches ``jnp.cumsum`` shape semantics.
    """
    x = x.astype(jnp.float32)
    hi, lo = jax.lax.associative_scan(
        _comp_combine, (x, jnp.zeros_like(x)), axis=axis
    )
    return hi + lo if collapse else (hi, lo)


def comp_sum(x: jnp.ndarray, axis: int = -1):
    """Compensated total along ``axis``: two-sum pairwise tree, O(ε·log n).

    A log-step halving fold (adjacent pairs combined with the same
    error-free transform as the scan) — total work ~2n with only the
    shrinking (hi, lo) partials live, unlike :func:`comp_cumsum` which
    materializes the full prefix array.  This sits on the rescan AFC path
    (one call per power sum per planner iteration), so the cheap reduction
    matters.
    """
    x = jnp.moveaxis(x.astype(jnp.float32), axis, -1)
    hi, lo = x, jnp.zeros_like(x)
    while hi.shape[-1] > 1:
        n = hi.shape[-1]
        if n % 2:
            pad = [(0, 0)] * (hi.ndim - 1) + [(0, 1)]
            hi = jnp.pad(hi, pad)
            lo = jnp.pad(lo, pad)
        hi, lo = _comp_combine(
            (hi[..., 0::2], lo[..., 0::2]), (hi[..., 1::2], lo[..., 1::2])
        )
    return hi[..., 0] + lo[..., 0]
