"""Jit'd wrapper: dispatches to the Pallas kernel (TPU) or oracle (CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sampled_agg.ref import sampled_moments_ref
from repro.kernels.sampled_agg.sampled_agg import sampled_moments

__all__ = ["moments", "estimates_from_moments"]


def moments(vals: jnp.ndarray, z: jnp.ndarray, *, use_kernel: bool | None = None):
    """(k, cap), (k,) -> (k, 4) [count, s1, s2, s3].

    use_kernel=None auto-selects: Pallas on TPU, oracle elsewhere (the
    interpret-mode kernel is for correctness tests, not speed).
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        return sampled_moments(
            vals, z, interpret=jax.default_backend() != "tpu"
        )
    return sampled_moments_ref(vals, z)


def estimates_from_moments(m: jnp.ndarray, n: jnp.ndarray):
    """Turn raw power sums into (mean, unbiased var, se_mean) per feature.

    n: (k,) total group sizes (finite-population correction).
    """
    count = jnp.maximum(m[:, 0], 1.0)
    mean = m[:, 1] / count
    var = jnp.maximum(m[:, 2] / count - mean**2, 0.0) * count / jnp.maximum(
        count - 1.0, 1.0
    )
    nf = n.astype(jnp.float32)
    fpc = jnp.sqrt(jnp.clip((nf - count) / jnp.maximum(nf - 1.0, 1.0), 0.0, 1.0))
    se = jnp.sqrt(var / count) * fpc
    return mean, var, se
