"""Jit'd wrappers: dispatch to the Pallas kernels (TPU) or oracles (CPU)."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.data.aggregates import estimates_from_power_sums
from repro.kernels.sampled_agg.prefix_stats import (
    prefix_power_sums as prefix_power_sums_kernel,
    prefix_power_sums_ref,
)
from repro.kernels.sampled_agg.quantile_select import masked_select_ranks
from repro.kernels.sampled_agg.ref import (
    masked_select_ranks_ref,
    sampled_moments_ref,
)
from repro.kernels.sampled_agg.sampled_agg import sampled_moments

__all__ = [
    "AFC_REF_MAX_CAP",
    "moments",
    "estimates_from_moments",
    "masked_estimates",
    "masked_quantile_estimates",
    "prefix_power_sums",
    "resolve_afc_plan",
    "bootstrap_rank_targets",
    "finish_quantile_estimates",
]

# Cap bucket at or below which the incremental prefix-table precompute does
# not amortize: BENCH_fused.json["incremental_afc"] measures the incremental
# path at 0.55-0.76x the rescan oracle for cap <= 1k groups (the per-request
# table build + argsort costs more than the few full-pass rescans it saves),
# crossing over above it.  ``resolve_afc_plan`` uses this as the "auto"
# strategy threshold when the caller supplies its cap bucket.
AFC_REF_MAX_CAP = 1024


def _resolve_backend(use_kernel: bool | None) -> bool:
    """None = auto: the REPRO_AFC_BACKEND env override (ref | kernel), else
    Pallas on TPU and the jnp oracle elsewhere.  CI runs the tier-1 suite
    under both env values so kernel/oracle parity is exercised on CPU."""
    if use_kernel is None:
        env = os.environ.get("REPRO_AFC_BACKEND", "auto").lower()
        if env == "kernel":
            return True
        if env == "ref":
            return False
        return jax.default_backend() == "tpu"
    return use_kernel


def resolve_afc_plan(
    afc_backend: str, cap: int | None = None, *, cached: bool = False
) -> tuple[bool, bool | None]:
    """Executor AFC strategy from the ``afc_backend`` build argument.

    Returns ``(incremental, use_kernel)``.  ``"ref"`` selects the
    pre-refactor **rescan** path (full masked_estimates / rank-count pass
    per planner iteration, jnp oracles) — the parity oracle CI pins via
    ``REPRO_AFC_BACKEND=ref``.  ``"kernel"`` forces the incremental
    prefix-stats path with the Pallas table kernel (interpret off-TPU);
    ``"incremental"`` (alias ``"inc"``) the same path with the jnp table
    oracle regardless of env (explicit strategy pinning for parity tests
    and the CPU benchmarks; also accepted as a REPRO_AFC_BACKEND value —
    unknown env values fall through to auto, matching
    ``_resolve_backend``).  ``"auto"`` consults the env at trace time like
    ``_resolve_backend``, then picks **per cap bucket**: executors resolve
    with their (k, cap) buffer width, and buckets at or below
    :data:`AFC_REF_MAX_CAP` take the rescan path — the prefix-table
    precompute does not amortize on small groups (0.55–0.76× measured in
    ``BENCH_fused.json["incremental_afc"]``) — while larger buckets run
    incremental with kernel-on-TPU.  ``cap=None`` (strategy validation, no
    shapes yet) keeps the incremental default.  Force-overrides — the env
    and every non-"auto" build argument — win over the heuristic, so
    parity legs stay pinned.

    ``cached=True`` declares that the executor is fed **prebuilt tables**
    from the feature-store precompute cache (serving/feature_cache.py): the
    :data:`AFC_REF_MAX_CAP` crossover was calibrated against a per-request
    rebuild, but a cache hit pays zero precompute, so the incremental path
    wins at every cap and "auto" picks it regardless of the bucket
    (``BENCH_fused.json["feature_store"]`` re-measures the crossover).
    Explicit backends and the env override still win — the ref-parity CI
    legs stay pinned even on cached paths.
    """
    if afc_backend == "auto":
        env = os.environ.get("REPRO_AFC_BACKEND", "auto").lower()
        if env == "ref":
            return False, False
        if env == "kernel":
            return True, True
        if env in ("incremental", "inc"):
            return True, False
        if cached:
            return True, None
        if cap is not None and cap <= AFC_REF_MAX_CAP:
            return False, None
        return True, None
    if afc_backend == "ref":
        return False, False
    if afc_backend == "kernel":
        return True, True
    if afc_backend in ("incremental", "inc"):
        return True, False
    raise ValueError(f"unknown afc_backend {afc_backend!r}")


def prefix_power_sums(
    vals: jnp.ndarray,
    shift: jnp.ndarray | None = None,
    *,
    use_kernel: bool | None = None,
):
    """(k, cap) -> (k, cap, 4) running prefix power sums of ``vals - shift``.

    The incremental-AFC precompute (one call per request, before the
    while_loop); backend-routed exactly like :func:`moments`.  The table row
    at ``z - 1`` is the ``[s1..s4]`` tail :func:`moments` would return at
    plan z (``prefix_stats.prefix_moments_at`` does the gather).
    """
    if _resolve_backend(use_kernel):
        return prefix_power_sums_kernel(
            vals, shift, interpret=jax.default_backend() != "tpu"
        )
    return prefix_power_sums_ref(vals, shift)


def moments(
    vals: jnp.ndarray,
    z: jnp.ndarray,
    shift: jnp.ndarray | None = None,
    *,
    use_kernel: bool | None = None,
):
    """(k, cap), (k,) -> (k, 5) [count, s1, s2, s3, s4] of ``vals - shift``.

    use_kernel=None auto-selects: Pallas on TPU, oracle elsewhere (the
    interpret-mode kernel is for correctness tests, not speed).
    """
    if _resolve_backend(use_kernel):
        return sampled_moments(
            vals, z, shift, interpret=jax.default_backend() != "tpu"
        )
    return sampled_moments_ref(vals, z, shift)


def masked_estimates(
    vals: jnp.ndarray,
    z: jnp.ndarray,
    n: jnp.ndarray,
    agg_ids: jnp.ndarray,
    *,
    use_kernel: bool | None = None,
):
    """AFC in one call: kernel/oracle power sums -> (value, sigma) per feature.

    This is the fused executor's per-iteration AFC stage: one pass over the
    (k, cap) prefix-masked buffers (the Pallas ``sampled_moments`` kernel on
    TPU, interpret-mode fallback for kernel testing, ref oracle on CPU), then
    the parametric estimator tail with finite-population correction from
    ``aggregates.estimates_from_power_sums``.  Holistic ids fall through the
    parametric select to (0, 0) and are overwritten by
    :func:`masked_quantile_estimates`.

    Sums are accumulated about each feature's first buffered sample so the
    4th-moment cancellation stays at O(std⁴) even when |mean| >> std (the
    VAR/STD σ's would otherwise collapse to zero in float32).
    """
    shift = vals[:, 0]
    return estimates_from_power_sums(
        moments(vals, z, shift, use_kernel=use_kernel), z, n, agg_ids, shift
    )


def masked_quantile_estimates(
    vals: jnp.ndarray,        # (h, cap) holistic-feature prefix buffers
    z: jnp.ndarray,           # (h,) int32 live prefix lengths
    n: jnp.ndarray,           # (h,) int32 group sizes (exactness check)
    qs: jnp.ndarray,          # (h,) f32 per-feature quantile (0.5 = median)
    key: jax.Array,           # counter-based: fold_in(base, iteration)
    n_boot: int,
    *,
    use_kernel: bool | None = None,
):
    """Holistic AFC: (value, sorted bootstrap replicates) per feature.

    Point estimate = nearest-rank quantile of the z-prefix.  Each bootstrap
    replicate is the rank-r quantile of a size-z resample-with-replacement
    (paper appendix D); instead of materializing B resamples, the replicate
    is drawn as an order statistic of the ORIGINAL sorted prefix at a random
    rank: the (r+1)-th smallest of z iid Uniform{0..z-1} index draws is
    ``floor(z·V)`` with ``V ~ Beta(r+1, z-r)`` — one Beta draw per replicate,
    distributionally identical to ``aggregates._bootstrap_replicates``'s
    explicit resample, with every shape static (lax.while_loop safe).

    All ranks are then selected in ONE kernel/oracle pass
    (``masked_select_ranks``; afc_backend-routed like ``sampled_moments``).
    Conventions match :func:`aggregates.estimate`: empty prefix (z == 0)
    yields value 0 with all-zero replicates; exact (z >= n) yields the exact
    quantile with a degenerate replicate table.  Returns
    ``(value (h,), replicates (h, n_boot) sorted ascending)``.
    """
    targets = bootstrap_rank_targets(z, qs, key, n_boot)
    if _resolve_backend(use_kernel):
        sel = masked_select_ranks(
            vals, z, targets, interpret=jax.default_backend() != "tpu"
        )
    else:
        sel = masked_select_ranks_ref(vals, z, targets)
    return finish_quantile_estimates(sel, z, n)


def _gamma_mt(key: jax.Array, d: jnp.ndarray, rounds: int) -> jnp.ndarray:
    """Marsaglia-Tsang (2000) Gamma(a ≥ 1) with ``d = a - 1/3``, sampled in
    a FIXED number of unrolled proposal rounds (no data-dependent loop).

    ``jax.random.gamma``'s exact rejection ``while_loop`` costs tens of ms
    per (h, B) draw on CPU and sits in the serving loop body; the squeeze
    accepts ≥ 96% per round for a ≥ 1, so after ``rounds`` independent
    proposals the miss probability is < (0.04)^rounds (≈ 2.6e-6 at 4) and
    the fallback — the distribution mean ``d + 1/3 ≈ a`` — is statistically
    invisible next to the B-replicate bootstrap's own MC error.
    """
    c = 1.0 / jnp.sqrt(9.0 * d)
    out = d + 1.0 / 3.0
    done = jnp.zeros(d.shape, bool)
    for kk in jax.random.split(key, rounds):
        kn, ku = jax.random.split(kk)
        x = jax.random.normal(kn, d.shape)
        v = (1.0 + c * x) ** 3
        u = jax.random.uniform(ku, d.shape, minval=1e-38)
        safe_v = jnp.where(v > 0.0, v, 1.0)
        ok = (v > 0.0) & (
            jnp.log(u) < 0.5 * x * x + d - d * safe_v + d * jnp.log(safe_v)
        )
        take = ok & ~done
        out = jnp.where(take, d * safe_v, out)
        done = done | ok
    return out


def beta_order_stat(
    key: jax.Array, a: jnp.ndarray, b: jnp.ndarray, shape, rounds: int = 4
) -> jnp.ndarray:
    """Beta(a, b) draws for a, b ≥ 1 via two fixed-round MT gammas.

    Drop-in for ``jax.random.beta`` on the bootstrap hot path (the Beta
    order-statistic trick, appendix D): same distribution up to the
    < 3e-6 proposal-truncation described in :func:`_gamma_mt`, ~500×
    cheaper on CPU because nothing in it is a rejection ``while_loop``.
    """
    ka, kb = jax.random.split(key)
    f32 = jnp.float32
    da = jnp.broadcast_to(a.astype(f32), shape) - 1.0 / 3.0
    db = jnp.broadcast_to(b.astype(f32), shape) - 1.0 / 3.0
    ga = _gamma_mt(ka, da, rounds)
    gb = _gamma_mt(kb, db, rounds)
    return ga / (ga + gb)


def bootstrap_rank_targets(
    z: jnp.ndarray, qs: jnp.ndarray, key: jax.Array, n_boot: int
) -> jnp.ndarray:
    """(h, 1+B) rank targets: [point-estimate rank | bootstrap ranks].

    Shared by the rescan path above and the incremental
    ``select_ranks_indexed`` path so both draw BITWISE-identical Beta
    replicate ranks from the same counter-based key — the z-plan parity
    contract between the two executors rests on this.
    """
    f32 = jnp.float32
    h = z.shape[0]
    zf = z.astype(f32)
    zm1 = jnp.maximum(z - 1, 0)
    rank = jnp.clip(
        jnp.floor(qs * (zf - 1.0) + 0.5).astype(jnp.int32), 0, zm1
    )
    a = (rank + 1).astype(f32)
    b = jnp.maximum(z - rank, 1).astype(f32)
    v = beta_order_stat(key, a[:, None], b[:, None], (h, n_boot))
    boot = jnp.clip(
        jnp.floor(zf[:, None] * v).astype(jnp.int32), 0, zm1[:, None]
    )
    return jnp.concatenate([rank[:, None], boot], axis=1)


def finish_quantile_estimates(
    sel: jnp.ndarray, z: jnp.ndarray, n: jnp.ndarray
):
    """Apply the estimate() conventions to selected (h, 1+B) order stats.

    Empty prefix -> (0, zeros); exact (z >= n) -> degenerate replicates at
    the exact quantile; otherwise (point value, sorted replicates).
    """
    f32 = jnp.float32
    empty = z <= 0
    value = jnp.where(empty, 0.0, sel[:, 0]).astype(f32)
    reps = jnp.sort(sel[:, 1:], axis=1)
    reps = jnp.where(
        empty[:, None],
        0.0,
        jnp.where((z >= n)[:, None], value[:, None], reps),
    ).astype(f32)
    return value, reps


def estimates_from_moments(m: jnp.ndarray, n: jnp.ndarray):
    """Turn raw power sums into (mean, unbiased var, se_mean) per feature.

    n: (k,) total group sizes (finite-population correction).
    """
    count = jnp.maximum(m[:, 0], 1.0)
    mean = m[:, 1] / count
    var = jnp.maximum(m[:, 2] / count - mean**2, 0.0) * count / jnp.maximum(
        count - 1.0, 1.0
    )
    nf = n.astype(jnp.float32)
    fpc = jnp.sqrt(jnp.clip((nf - count) / jnp.maximum(nf - 1.0, 1.0), 0.0, 1.0))
    se = jnp.sqrt(var / count) * fpc
    return mean, var, se
