"""Jit'd wrappers: dispatch to the Pallas kernels (TPU) or oracles (CPU)."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.data.aggregates import estimates_from_power_sums
from repro.kernels.sampled_agg.quantile_select import masked_select_ranks
from repro.kernels.sampled_agg.ref import (
    masked_select_ranks_ref,
    sampled_moments_ref,
)
from repro.kernels.sampled_agg.sampled_agg import sampled_moments

__all__ = [
    "moments",
    "estimates_from_moments",
    "masked_estimates",
    "masked_quantile_estimates",
]


def _resolve_backend(use_kernel: bool | None) -> bool:
    """None = auto: the REPRO_AFC_BACKEND env override (ref | kernel), else
    Pallas on TPU and the jnp oracle elsewhere.  CI runs the tier-1 suite
    under both env values so kernel/oracle parity is exercised on CPU."""
    if use_kernel is None:
        env = os.environ.get("REPRO_AFC_BACKEND", "auto").lower()
        if env == "kernel":
            return True
        if env == "ref":
            return False
        return jax.default_backend() == "tpu"
    return use_kernel


def moments(
    vals: jnp.ndarray,
    z: jnp.ndarray,
    shift: jnp.ndarray | None = None,
    *,
    use_kernel: bool | None = None,
):
    """(k, cap), (k,) -> (k, 5) [count, s1, s2, s3, s4] of ``vals - shift``.

    use_kernel=None auto-selects: Pallas on TPU, oracle elsewhere (the
    interpret-mode kernel is for correctness tests, not speed).
    """
    if _resolve_backend(use_kernel):
        return sampled_moments(
            vals, z, shift, interpret=jax.default_backend() != "tpu"
        )
    return sampled_moments_ref(vals, z, shift)


def masked_estimates(
    vals: jnp.ndarray,
    z: jnp.ndarray,
    n: jnp.ndarray,
    agg_ids: jnp.ndarray,
    *,
    use_kernel: bool | None = None,
):
    """AFC in one call: kernel/oracle power sums -> (value, sigma) per feature.

    This is the fused executor's per-iteration AFC stage: one pass over the
    (k, cap) prefix-masked buffers (the Pallas ``sampled_moments`` kernel on
    TPU, interpret-mode fallback for kernel testing, ref oracle on CPU), then
    the parametric estimator tail with finite-population correction from
    ``aggregates.estimates_from_power_sums``.  Holistic ids fall through the
    parametric select to (0, 0) and are overwritten by
    :func:`masked_quantile_estimates`.

    Sums are accumulated about each feature's first buffered sample so the
    4th-moment cancellation stays at O(std⁴) even when |mean| >> std (the
    VAR/STD σ's would otherwise collapse to zero in float32).
    """
    shift = vals[:, 0]
    return estimates_from_power_sums(
        moments(vals, z, shift, use_kernel=use_kernel), z, n, agg_ids, shift
    )


def masked_quantile_estimates(
    vals: jnp.ndarray,        # (h, cap) holistic-feature prefix buffers
    z: jnp.ndarray,           # (h,) int32 live prefix lengths
    n: jnp.ndarray,           # (h,) int32 group sizes (exactness check)
    qs: jnp.ndarray,          # (h,) f32 per-feature quantile (0.5 = median)
    key: jax.Array,           # counter-based: fold_in(base, iteration)
    n_boot: int,
    *,
    use_kernel: bool | None = None,
):
    """Holistic AFC: (value, sorted bootstrap replicates) per feature.

    Point estimate = nearest-rank quantile of the z-prefix.  Each bootstrap
    replicate is the rank-r quantile of a size-z resample-with-replacement
    (paper appendix D); instead of materializing B resamples, the replicate
    is drawn as an order statistic of the ORIGINAL sorted prefix at a random
    rank: the (r+1)-th smallest of z iid Uniform{0..z-1} index draws is
    ``floor(z·V)`` with ``V ~ Beta(r+1, z-r)`` — one Beta draw per replicate,
    distributionally identical to ``aggregates._bootstrap_replicates``'s
    explicit resample, with every shape static (lax.while_loop safe).

    All ranks are then selected in ONE kernel/oracle pass
    (``masked_select_ranks``; afc_backend-routed like ``sampled_moments``).
    Conventions match :func:`aggregates.estimate`: empty prefix (z == 0)
    yields value 0 with all-zero replicates; exact (z >= n) yields the exact
    quantile with a degenerate replicate table.  Returns
    ``(value (h,), replicates (h, n_boot) sorted ascending)``.
    """
    f32 = jnp.float32
    h, cap = vals.shape
    zf = z.astype(f32)
    zm1 = jnp.maximum(z - 1, 0)
    rank = jnp.clip(
        jnp.floor(qs * (zf - 1.0) + 0.5).astype(jnp.int32), 0, zm1
    )
    a = (rank + 1).astype(f32)
    b = jnp.maximum(z - rank, 1).astype(f32)
    v = jax.random.beta(key, a[:, None], b[:, None], (h, n_boot))
    boot = jnp.clip(
        jnp.floor(zf[:, None] * v).astype(jnp.int32), 0, zm1[:, None]
    )
    targets = jnp.concatenate([rank[:, None], boot], axis=1)   # (h, 1+B)
    if _resolve_backend(use_kernel):
        sel = masked_select_ranks(
            vals, z, targets, interpret=jax.default_backend() != "tpu"
        )
    else:
        sel = masked_select_ranks_ref(vals, z, targets)
    empty = z <= 0
    value = jnp.where(empty, 0.0, sel[:, 0]).astype(f32)
    reps = jnp.sort(sel[:, 1:], axis=1)
    reps = jnp.where(
        empty[:, None],
        0.0,
        jnp.where((z >= n)[:, None], value[:, None], reps),
    ).astype(f32)
    return value, reps


def estimates_from_moments(m: jnp.ndarray, n: jnp.ndarray):
    """Turn raw power sums into (mean, unbiased var, se_mean) per feature.

    n: (k,) total group sizes (finite-population correction).
    """
    count = jnp.maximum(m[:, 0], 1.0)
    mean = m[:, 1] / count
    var = jnp.maximum(m[:, 2] / count - mean**2, 0.0) * count / jnp.maximum(
        count - 1.0, 1.0
    )
    nf = n.astype(jnp.float32)
    fpc = jnp.sqrt(jnp.clip((nf - count) / jnp.maximum(nf - 1.0, 1.0), 0.0, 1.0))
    se = jnp.sqrt(var / count) * fpc
    return mean, var, se
