"""Jit'd wrapper: dispatches to the Pallas kernel (TPU) or oracle (CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.data.aggregates import estimates_from_power_sums
from repro.kernels.sampled_agg.ref import sampled_moments_ref
from repro.kernels.sampled_agg.sampled_agg import sampled_moments

__all__ = ["moments", "estimates_from_moments", "masked_estimates"]


def _resolve_backend(use_kernel: bool | None) -> bool:
    if use_kernel is None:
        return jax.default_backend() == "tpu"
    return use_kernel


def moments(
    vals: jnp.ndarray,
    z: jnp.ndarray,
    shift: jnp.ndarray | None = None,
    *,
    use_kernel: bool | None = None,
):
    """(k, cap), (k,) -> (k, 5) [count, s1, s2, s3, s4] of ``vals - shift``.

    use_kernel=None auto-selects: Pallas on TPU, oracle elsewhere (the
    interpret-mode kernel is for correctness tests, not speed).
    """
    if _resolve_backend(use_kernel):
        return sampled_moments(
            vals, z, shift, interpret=jax.default_backend() != "tpu"
        )
    return sampled_moments_ref(vals, z, shift)


def masked_estimates(
    vals: jnp.ndarray,
    z: jnp.ndarray,
    n: jnp.ndarray,
    agg_ids: jnp.ndarray,
    *,
    use_kernel: bool | None = None,
):
    """AFC in one call: kernel/oracle power sums -> (value, sigma) per feature.

    This is the fused executor's per-iteration AFC stage: one pass over the
    (k, cap) prefix-masked buffers (the Pallas ``sampled_moments`` kernel on
    TPU, interpret-mode fallback for kernel testing, ref oracle on CPU), then
    the parametric estimator tail with finite-population correction from
    ``aggregates.estimates_from_power_sums``.

    Sums are accumulated about each feature's first buffered sample so the
    4th-moment cancellation stays at O(std⁴) even when |mean| >> std (the
    VAR/STD σ's would otherwise collapse to zero in float32).
    """
    shift = vals[:, 0]
    return estimates_from_power_sums(
        moments(vals, z, shift, use_kernel=use_kernel), z, n, agg_ids, shift
    )


def estimates_from_moments(m: jnp.ndarray, n: jnp.ndarray):
    """Turn raw power sums into (mean, unbiased var, se_mean) per feature.

    n: (k,) total group sizes (finite-population correction).
    """
    count = jnp.maximum(m[:, 0], 1.0)
    mean = m[:, 1] / count
    var = jnp.maximum(m[:, 2] / count - mean**2, 0.0) * count / jnp.maximum(
        count - 1.0, 1.0
    )
    nf = n.astype(jnp.float32)
    fpc = jnp.sqrt(jnp.clip((nf - count) / jnp.maximum(nf - 1.0, 1.0), 0.0, 1.0))
    se = jnp.sqrt(var / count) * fpc
    return mean, var, se
