"""Pallas TPU kernel: fused prefix-masked streaming moments (AFC hot loop).

The paper's AFC stage re-scans the sampled rows once per aggregate operator
(ClickHouse computes SUM, then AVG, then STD...).  On TPU we fuse all
parametric aggregates into ONE pass: each grid step loads a (block_k,
block_c) VMEM tile of the sample buffers, applies the prefix mask with an
iota compare (branch-free — the mask IS the sample size), and accumulates
five power sums per feature into a VMEM accumulator ([count, Σv, Σv², Σv³,
Σv⁴] — the 4th power is what the VAR/STD error estimators need).

Grid: (k_tiles, c_tiles) with c innermost so each feature row's accumulator
stays resident in VMEM across its column tiles.

TPU adaptation notes (DESIGN.md §3): the paper's row-at-a-time online
aggregation becomes a tiled masked reduction — incremental sampling is a
*wider mask*, not more I/O, so planner iterations never re-touch HBM rows
they already consumed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.sampled_agg.compensated import kahan_step

__all__ = ["sampled_moments"]


def _kernel(z_ref, shift_ref, vals_ref, out_ref, comp_ref, *, block_c: int, n_c: int):
    ci = pl.program_id(1)
    # (block_k, block_c) tile of sample values
    v = vals_ref[...].astype(jnp.float32)
    z = z_ref[...]  # (block_k,) int32 live sample sizes
    shift = shift_ref[...]  # (block_k,) f32 per-feature accumulation origin
    col0 = ci * block_c
    cols = col0 + jax.lax.broadcasted_iota(jnp.int32, v.shape, 1)
    mask = (cols < z[:, None]).astype(jnp.float32)
    v = (v - shift[:, None]) * mask
    v2 = v * v
    tile = jnp.stack(
        [
            jnp.sum(mask, axis=1),
            jnp.sum(v, axis=1),
            jnp.sum(v2, axis=1),
            jnp.sum(v2 * v, axis=1),
            jnp.sum(v2 * v2, axis=1),
        ],
        axis=1,
    )  # (block_k, 5)

    @pl.when(ci == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        comp_ref[...] = jnp.zeros_like(comp_ref)

    # Kahan-compensated cross-tile carry: a plain `out += tile` loses the
    # small tiles' contribution once the running Σv⁴ dominates them (60k-row
    # heavy-tailed columns); the VMEM (hi, lo) pair keeps the accumulated
    # rounding and folds it back once on the last column tile.
    hi, lo = kahan_step(out_ref[...], comp_ref[...], tile)
    out_ref[...] = hi
    comp_ref[...] = lo

    @pl.when(ci == n_c - 1)
    def _collapse():
        out_ref[...] += comp_ref[...]


@functools.partial(jax.jit, static_argnames=("block_k", "block_c", "interpret"))
def sampled_moments(
    vals: jnp.ndarray,            # (k, cap) f32
    z: jnp.ndarray,               # (k,) int32
    shift: jnp.ndarray | None = None,  # (k,) f32 accumulation origin
    *,
    block_k: int = 8,
    block_c: int = 1024,
    interpret: bool = True,       # CPU container: interpret; TPU: False
) -> jnp.ndarray:
    """(k, 5) power sums [count, s1, s2, s3, s4] of ``vals - shift`` over
    each valid prefix (see ref.py for the shift rationale; None = no shift).

    Shapes need not divide the block sizes: inputs are zero-padded up to the
    tile grid (padded rows carry z=0, so they contribute nothing) and the
    output is sliced back to k rows.
    """
    k, cap = vals.shape
    if shift is None:
        shift = jnp.zeros((k,), jnp.float32)
    block_k = min(block_k, k)
    block_c = min(block_c, cap)
    kp = -(-k // block_k) * block_k
    capp = -(-cap // block_c) * block_c
    if (kp, capp) != (k, cap):
        vals = jnp.pad(vals, ((0, kp - k), (0, capp - cap)))
        z = jnp.pad(z, (0, kp - k))
        shift = jnp.pad(shift, (0, kp - k))
    grid = (kp // block_k, capp // block_c)
    out = pl.pallas_call(
        functools.partial(_kernel, block_c=block_c, n_c=capp // block_c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_k,), lambda i, j: (i,)),
            pl.BlockSpec((block_k,), lambda i, j: (i,)),
            pl.BlockSpec((block_k, block_c), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_k, 5), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((kp, 5), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_k, 5), jnp.float32)],
        interpret=interpret,
    )(z, shift.astype(jnp.float32), vals)
    return out[:k]
