"""Per-request prefix statistics: the incremental-AFC precompute (DESIGN.md
§ Incremental AFC).

The fused executor's while_loop used to pay O(n) per planner iteration:
``masked_estimates`` re-scanned the full (k, cap) values matrix and the
holistic path re-rank-counted the whole padded column, even when the live
prefix z was a few percent of the group.  This module hoists ALL
data-proportional work into a **once-per-request precompute** so the loop
body touches O(1)-ish state per feature:

* :func:`prefix_power_sums` — a tiled Pallas kernel (jnp oracle:
  :func:`prefix_power_sums_ref`) producing the inclusive running power sums
  ``P_p[j, c] = Σ_{i ≤ c} (v_{j,i} − shift_j)^p`` for p = 1..4.  The AFC
  (value, sigma) at ANY plan z is then one gather of the (k, 4) table row at
  ``z − 1`` fed through the unchanged ``estimates_from_power_sums`` tail —
  the per-iteration cost no longer depends on the group size.  Accumulation
  is compensated (``compensated.py``): the cross-tile carry is a Kahan
  (hi, lo) pair, the oracle an error-free-transform ``associative_scan`` —
  f32 storage with double-precision-class accumulation, since a naive f32
  running Σv⁴ visibly drifts by 60k-row heavy-tailed groups.
  Memory: (k, cap, 4) f32 = 4× the values buffer, freed with it per request
  (the values buffer itself is donated — serving/batched.py).

* :func:`build_rank_index` / :func:`select_ranks_indexed` — the holistic
  (MEDIAN/QUANTILE) equivalent.  The column is argsorted ONCE with its
  original positions attached (stable, so ties break on position exactly
  like the ``quantile_select`` rank-counting kernel).  Because the planner
  only ever visits ``z ∈ {min(z⁰ + i·γ, n)}`` (z⁰, γ and max_iters are loop
  constants), prefix membership counts are precomputed per candidate z at
  block granularity; an order statistic of the live prefix is then a
  **prefix-membership rank query**: an unrolled binary search over the
  block counts (O(log(cap/S)) gathers) plus one S-element block scan —
  O(h·B·log n)-class work per bootstrap-replicate update instead of the
  O(h·B·n) full-column rank count.  Index memory: 2·(h, cap) value/index
  rows + an (h, max_iters+1, cap/S + 1) int32 count table.

The argsort itself stays an XLA sort (not Pallas): TPU's native sort is
already one fused HBM pass, and it runs once per request outside the loop.
Backend routing (kernel vs oracle for the power-sum tables) goes through
``ops.prefix_power_sums`` exactly like ``sampled_moments``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.sampled_agg.compensated import comp_cumsum, kahan_step, two_sum

__all__ = [
    "N_POWERS",
    "prefix_power_sums",
    "prefix_power_sums_ref",
    "prefix_moments_at",
    "append_power_sums",
    "HolisticRankIndex",
    "build_rank_index",
    "merge_sorted_prefix",
    "rank_counts_from_sorted",
    "rank_index_from_sorted",
    "select_ranks_indexed",
]

N_POWERS = 4  # [Σu, Σu², Σu³, Σu⁴] — count at z is just z


# --------------------------------------------------------------------------
# Parametric: running power-sum tables
# --------------------------------------------------------------------------
def _powers(v: jnp.ndarray) -> jnp.ndarray:
    """(…, c) f32 -> (…, c, 4) stacked u, u², u³, u⁴."""
    v2 = v * v
    return jnp.stack([v, v2, v2 * v, v2 * v2], axis=-1)


def prefix_power_sums_ref(
    vals: jnp.ndarray, shift: jnp.ndarray | None = None
) -> jnp.ndarray:
    """(k, cap) f32 -> (k, cap, 4) inclusive prefix sums of (v−shift)^p.

    Compensated scan (O(ε·log n) error); the prefix row at index ``z − 1``
    is exactly the ``[s1..s4]`` tail of ``sampled_moments``'s output at plan
    z (count = z), so the two paths share ``estimates_from_power_sums``.
    """
    v = vals.astype(jnp.float32)
    if shift is not None:
        v = v - shift.astype(jnp.float32)[:, None]
    return comp_cumsum(_powers(v), axis=1)


def _prefix_kernel(shift_ref, vals_ref, out_ref, hi_ref, lo_ref, *, block_c: int):
    ci = pl.program_id(1)
    v = vals_ref[...].astype(jnp.float32) - shift_ref[...][:, None]
    p = _powers(v)                               # (block_k, block_c, 4)

    # within-tile inclusive scan: log-step doubling (Mosaic-safe static
    # slices + concatenate; error O(ε·log block_c))
    s = 1
    while s < block_c:
        p = p + jnp.concatenate(
            [jnp.zeros_like(p[:, :s]), p[:, :-s]], axis=1
        )
        s *= 2

    @pl.when(ci == 0)
    def _init():
        hi_ref[...] = jnp.zeros_like(hi_ref)
        lo_ref[...] = jnp.zeros_like(lo_ref)

    carry_hi = hi_ref[...]                        # (block_k, 4)
    carry_lo = lo_ref[...]
    # add the smaller correction first so it is not absorbed by the carry
    out_ref[...] = carry_hi[:, None, :] + (p + carry_lo[:, None, :])
    hi, lo = kahan_step(carry_hi, carry_lo, p[:, -1, :])
    hi_ref[...] = hi
    lo_ref[...] = lo


@functools.partial(jax.jit, static_argnames=("block_k", "block_c", "interpret"))
def prefix_power_sums(
    vals: jnp.ndarray,                 # (k, cap) f32
    shift: jnp.ndarray | None = None,  # (k,) f32 accumulation origin
    *,
    block_k: int = 8,
    block_c: int = 1024,
    interpret: bool = True,            # CPU container: interpret; TPU: False
) -> jnp.ndarray:
    """Pallas twin of :func:`prefix_power_sums_ref`: (k, cap, 4) tables.

    Grid (k_tiles, c_tiles) with c innermost; each feature row's running
    totals live in a VMEM (hi, lo) Kahan pair across its column tiles, so
    tile boundaries add no uncompensated rounding.  Shapes need not divide
    the blocks — inputs are zero-padded and the output sliced back to
    (k, cap).  The sliced-off padded region is NOT a valid prefix
    continuation (zero-padded columns accumulate ``(0 - shift)^p``, not 0);
    only the returned [:k, :cap] entries are meaningful.
    """
    k, cap = vals.shape
    if shift is None:
        shift = jnp.zeros((k,), jnp.float32)
    block_k = min(block_k, k)
    block_c = min(block_c, cap)
    kp = -(-k // block_k) * block_k
    capp = -(-cap // block_c) * block_c
    if (kp, capp) != (k, cap):
        vals = jnp.pad(vals, ((0, kp - k), (0, capp - cap)))
        shift = jnp.pad(shift, (0, kp - k))
    grid = (kp // block_k, capp // block_c)
    out = pl.pallas_call(
        functools.partial(_prefix_kernel, block_c=block_c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_k,), lambda i, j: (i,)),
            pl.BlockSpec((block_k, block_c), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec(
            (block_k, block_c, N_POWERS), lambda i, j: (i, j, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((kp, capp, N_POWERS), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_k, N_POWERS), jnp.float32),
            pltpu.VMEM((block_k, N_POWERS), jnp.float32),
        ],
        interpret=interpret,
    )(shift.astype(jnp.float32), vals)
    return out[:k, :cap]


def prefix_moments_at(ptab: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """Gather the (k, 5) ``[count, s1..s4]`` moments row at plan z.

    ``ptab``: (k, cap, 4) prefix tables; ``z``: (k,) int32 in [0, cap].
    This is the whole per-iteration parametric AFC read — one gather,
    independent of cap.  ``z == 0`` rows are all-zero (empty prefix).
    """
    cap = ptab.shape[1]
    idx = jnp.clip(z - 1, 0, cap - 1).astype(jnp.int32)
    row = jnp.take_along_axis(ptab, idx[:, None, None], axis=1)[:, 0]
    row = jnp.where(z[:, None] > 0, row, 0.0)
    return jnp.concatenate([z.astype(jnp.float32)[:, None], row], axis=1)


# --------------------------------------------------------------------------
# Holistic: presorted column + per-candidate-z prefix-membership counts
# --------------------------------------------------------------------------
class HolisticRankIndex(NamedTuple):
    """Argsort-with-original-index structure for holistic columns.

    sorted_vals: (h, capp) f32 ascending; positions ≥ n (and pad) are +inf.
    sorted_idx:  (h, capp) i32 original buffer position of each element
                 (stable ties — matches the rank-counting kernel's
                 tie-break); pad entries point past the buffer.
    blk_cnt:     (h, n_z, n_blk+1) i32 — blk_cnt[f, i, b] counts sorted
                 positions p < b·S whose original index < zcand[f, i]
                 (exclusive block-start prefix-membership counts; entry
                 n_blk is the total, = zcand clipped to n).
    zcand:       (h, n_z) i32 — the feature's reachable plan ladder
                 ``min(z⁰ + i·γ, n)``; every runtime z is one of these.
    """

    sorted_vals: jnp.ndarray
    sorted_idx: jnp.ndarray
    blk_cnt: jnp.ndarray
    zcand: jnp.ndarray


BLOCK_S = 128  # block-scan granularity S of the membership counts


def build_rank_index(
    vals: jnp.ndarray,      # (h, cap) holistic-feature prefix buffers
    n: jnp.ndarray,         # (h,) int32 group sizes
    zcand: jnp.ndarray,     # (h, n_z) int32 reachable plans, nondecreasing
    *,
    block: int = BLOCK_S,
) -> HolisticRankIndex:
    """One-time (per request) index build — the only O(n·n_z) holistic work.

    Runs outside the while_loop; the loop then answers every order-statistic
    query through :func:`select_ranks_indexed` without touching the raw
    column again.
    """
    h, cap = vals.shape
    block = min(block, cap)
    capp = -(-cap // block) * block
    pos = jnp.arange(cap, dtype=jnp.int32)
    padded = jnp.where(pos[None, :] < n[:, None], vals.astype(jnp.float32), jnp.inf)
    if capp != cap:
        padded = jnp.pad(padded, ((0, 0), (0, capp - cap)), constant_values=jnp.inf)
    order = jnp.argsort(padded, axis=1, stable=True).astype(jnp.int32)
    svals = jnp.take_along_axis(padded, order, axis=1)
    return rank_index_from_sorted(svals, order, zcand, block=block)


def rank_counts_from_sorted(
    sidx: jnp.ndarray,      # (h, capp) original positions, sorted-value order
    zcand: jnp.ndarray,     # (h, n_z) candidate plans
    *,
    block: int = BLOCK_S,
) -> jnp.ndarray:
    """Exclusive block-start prefix-membership counts from a sorted order.

    The count half of :func:`build_rank_index`, factored out so a column
    whose sorted order is *incrementally maintained* (merge-on-query append
    path, DESIGN.md § Online feature store) can refresh its ``blk_cnt``
    table — the only part that depends on the candidate ladder — without
    re-running the argsort.
    """
    h, capp = sidx.shape
    member = sidx[:, None, :] < zcand[:, :, None]           # (h, n_z, capp)
    per_blk = member.reshape(h, zcand.shape[1], capp // block, block).sum(
        axis=-1, dtype=jnp.int32
    )
    return jnp.concatenate(
        [
            jnp.zeros((h, zcand.shape[1], 1), jnp.int32),
            jnp.cumsum(per_blk, axis=-1, dtype=jnp.int32),
        ],
        axis=-1,
    )


def rank_index_from_sorted(
    svals: jnp.ndarray,     # (h, capp) ascending, +inf past the live prefix
    sidx: jnp.ndarray,      # (h, capp) original positions (stable tie order)
    zcand: jnp.ndarray,     # (h, n_z)
    *,
    block: int = BLOCK_S,
) -> HolisticRankIndex:
    """Assemble a :class:`HolisticRankIndex` from presorted value/index rows.

    ``build_rank_index == rank_index_from_sorted ∘ stable-argsort``; callers
    that maintain the sorted order themselves (:func:`merge_sorted_prefix`)
    use this to skip the sort.
    """
    return HolisticRankIndex(
        sorted_vals=svals,
        sorted_idx=sidx.astype(jnp.int32),
        blk_cnt=rank_counts_from_sorted(sidx, zcand, block=block),
        zcand=zcand,
    )


def select_ranks_indexed(
    index: HolisticRankIndex,
    z: jnp.ndarray,         # (h,) int32 live prefix lengths (∈ zcand rows)
    targets: jnp.ndarray,   # (h, R) int32 ranks into the sorted z-prefix
) -> jnp.ndarray:
    """(h, R) order statistics of each z-prefix — the incremental twin of
    ``masked_select_ranks_ref``.

    Per query: an unrolled binary search over the candidate-z block counts
    finds the S-block holding prefix-rank r, then one S-element scan of
    (sorted_idx, sorted_vals) selects the element whose running membership
    count hits r + 1.  Out-of-prefix ranks (r ≥ z, incl. z == 0) return
    +inf, matching the oracle's convention (callers clip/override).
    """
    svals, sidx, blk_cnt, zcand = index
    h, capp = svals.shape
    n_blk = blk_cnt.shape[-1] - 1
    block = capp // n_blk
    r = targets.astype(jnp.int32)

    # candidate row of this z (z is always a ladder member; ties → first)
    iz = jnp.sum(zcand < z[:, None], axis=1).astype(jnp.int32)
    cnt = jnp.take_along_axis(blk_cnt, iz[:, None, None], axis=1)[:, 0]

    # largest b with cnt[b] <= r — unrolled bisect_right, log2(n_blk+1)
    # static steps of one gather each (no data-dependent while)
    lo = jnp.zeros(r.shape, jnp.int32)
    hi = jnp.full(r.shape, n_blk, jnp.int32)
    steps = max(1, (n_blk + 1).bit_length())
    for _ in range(steps):
        mid = (lo + hi + 1) // 2
        cm = jnp.take_along_axis(cnt, mid, axis=1)
        go = cm <= r
        lo = jnp.where(go, mid, lo)
        hi = jnp.where(go, hi, mid - 1)
    b = jnp.minimum(lo, n_blk - 1)                          # (h, R)

    base = jnp.take_along_axis(cnt, b, axis=1)              # count before block
    posn = b[:, :, None] * block + jnp.arange(block, dtype=jnp.int32)
    gi = jax.vmap(lambda row, p: row[p])(sidx, posn)        # (h, R, S)
    gv = jax.vmap(lambda row, p: row[p])(svals, posn)
    member = gi < z[:, None, None]
    running = base[:, :, None] + jnp.cumsum(member, axis=-1)
    hit = member & (running == (r + 1)[:, :, None])
    val = jnp.sum(jnp.where(hit, gv, 0.0), axis=-1)
    return jnp.where(jnp.any(hit, axis=-1), val, jnp.inf)


# --------------------------------------------------------------------------
# Streaming-append delta updates (DESIGN.md § Online feature store)
# --------------------------------------------------------------------------
def append_power_sums(
    ptab: jnp.ndarray,       # (k, cap, 4) prefix power-sum tables
    shift: jnp.ndarray,      # (k,) the tables' accumulation origin
    j: jnp.ndarray,          # () int32 insertion position, 0 < j
    x: jnp.ndarray,          # (k,) inserted value per feature row
    aff: jnp.ndarray | None = None,  # (k,) bool — rows the event touches
) -> jnp.ndarray:
    """Delta-update prefix tables for one insertion at position ``j``.

    Inserting ``x`` at prefix position j maps the old row onto the new one
    exactly: ``P'[c] = P[c]`` for c < j and ``P'[c] = P[c−1] + (x−shift)^p``
    for c ≥ j — a shift-right plus one broadcast addition, performed as a
    Knuth :func:`two_sum` error-free transform so each delta adds at most
    one f32 rounding (vs the O(ε·log n) compensated rebuild).  On data where
    f32 arithmetic is exact (integer-valued columns within 2²⁴) the result
    is **bitwise identical** to a from-scratch :func:`prefix_power_sums_ref`
    rebuild — the append→rebuild parity tests pin exactly that; on general
    floats the two differ only in final-rounding placement (O(ε)).

    Callers must hold two preconditions the math assumes: ``j ≥ 1`` (j = 0
    replaces the shift basis ``vals[:, 0]`` — rebuild instead) and ``j``
    within the buffer (``j ≥ cap`` is a no-op: the masked update never
    fires).  ``aff`` masks the update to the feature rows whose
    (table, group) the event belongs to.
    """
    k, cap, _ = ptab.shape
    pw = _powers(x.astype(jnp.float32) - shift.astype(jnp.float32))  # (k, 4)
    shifted = jnp.concatenate(
        [jnp.zeros((k, 1, N_POWERS), jnp.float32), ptab[:, :-1]], axis=1
    )
    s, e = two_sum(shifted, pw[:, None, :])
    upd = s + e
    c = jnp.arange(cap, dtype=jnp.int32)
    mask = (c[None, :] >= j) & (j < cap)
    if aff is not None:
        mask = mask & aff[:, None]
    return jnp.where(mask[:, :, None], upd, ptab)


def merge_sorted_prefix(
    svals: jnp.ndarray,      # (h, capp) sorted values, +inf past the prefix
    sidx: jnp.ndarray,       # (h, capp) original positions
    n: jnp.ndarray,          # (h,) int32 live prefix lengths (<= cap)
    cap: int,                # buffer width the positions index into
    j: jnp.ndarray,          # () int32 insertion position
    x: jnp.ndarray,          # (h,) inserted value per feature row
    aff: jnp.ndarray | None = None,  # (h,) bool — rows the event touches
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Merge one appended element into maintained sorted-prefix runs.

    The merge-on-query half of the holistic append path: the compacted base
    run is the cached index's own (sorted_vals, sorted_idx) pair, the
    pending run is the store's append log, and this routine merges one
    pending event in O(capp) data movement — no argsort.  Ordering is
    (value, original position) lexicographic, exactly the stable-argsort
    order :func:`build_rank_index` produces, so the merged arrays are
    **bitwise identical** to a full re-sort (finite column values assumed).

    Steps per affected row: renumber live positions ≥ j (the buffer shifted
    right), drop the element pushed past ``cap`` (at most one, only when the
    buffer was full), insert (x, j) at its lexicographic rank, and normalize
    the +inf tail to the argsort convention (positions in order).  ``j ≥
    cap`` is a no-op (the row landed beyond the prefix buffer).  Returns the
    merged ``(svals, sidx, n)``.
    """
    h, capp = svals.shape
    pos = jnp.arange(capp, dtype=jnp.int32)

    def merge_one(sv, si, nf, xf):
        live = si < nf
        si_r = jnp.where(live & (si >= j), si + 1, si)
        drop = live & (si_r >= cap)
        order = jnp.argsort(drop.astype(jnp.int32), stable=True)
        sv2, si2 = sv[order], si_r[order]
        nlive = nf - jnp.sum(drop).astype(jnp.int32)
        before = (pos < nlive) & ((sv2 < xf) | ((sv2 == xf) & (si2 < j)))
        ins = jnp.sum(before).astype(jnp.int32)
        sv_prev = jnp.concatenate([sv2[:1], sv2[:-1]])
        si_prev = jnp.concatenate([si2[:1], si2[:-1]])
        sv3 = jnp.where(pos < ins, sv2, jnp.where(pos == ins, xf, sv_prev))
        si3 = jnp.where(pos < ins, si2, jnp.where(pos == ins, j, si_prev))
        n2 = jnp.minimum(nlive + 1, cap)
        sv4 = jnp.where(pos < n2, sv3, jnp.inf)
        si4 = jnp.where(pos < n2, si3, pos)
        return sv4, si4.astype(jnp.int32), n2

    msv, msi, mn = jax.vmap(merge_one)(
        svals, sidx, n.astype(jnp.int32), x.astype(jnp.float32)
    )
    apply = jnp.asarray(j, jnp.int32) < cap
    if aff is not None:
        apply = apply & aff
    apply = jnp.broadcast_to(apply, (h,))
    return (
        jnp.where(apply[:, None], msv, svals),
        jnp.where(apply[:, None], msi, sidx),
        jnp.where(apply, mn, n.astype(jnp.int32)),
    )
