from repro.kernels.sampled_agg import ops  # noqa: F401
