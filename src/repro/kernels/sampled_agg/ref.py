"""Pure-jnp oracle for the sampled-aggregation kernel.

Semantics: given k prefix-masked sample buffers (k, cap) and live sample
sizes z (k,), compute per-feature streaming moments in ONE pass:

    count  = z
    sum    = sum of the first z values
    sum2   = sum of squares
    sum4   = centered 4th power sum is NOT computed here (needs the mean);
             instead we return raw power sums so the host can build any of
             SUM / COUNT / AVG / VAR / STD estimators (aggregates.py).

This mirrors the paper's AFC inner loop (§3.2): one scan over the sampled
rows produces every parametric aggregate at once.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["sampled_moments_ref"]


def sampled_moments_ref(vals: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """vals: (k, cap) f32; z: (k,) int32 -> (k, 4) [count, sum, sum2, sum3].

    Raw power sums over the valid prefix; padding contributes zero.
    """
    k, cap = vals.shape
    mask = (jnp.arange(cap)[None, :] < z[:, None]).astype(jnp.float32)
    v = vals.astype(jnp.float32) * mask
    count = jnp.sum(mask, axis=1)
    s1 = jnp.sum(v, axis=1)
    s2 = jnp.sum(v * v, axis=1)
    s3 = jnp.sum(v * v * v, axis=1)
    return jnp.stack([count, s1, s2, s3], axis=1)
