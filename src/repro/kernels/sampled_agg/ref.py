"""Pure-jnp oracle for the sampled-aggregation kernel.

Semantics: given k prefix-masked sample buffers (k, cap) and live sample
sizes z (k,), compute per-feature streaming moments in ONE pass:

    count  = z
    sum    = sum of the first z values
    sum2   = sum of squares
    sum3   = sum of cubes
    sum4   = sum of 4th powers (centered moments need the mean, so the
             kernel returns raw power sums; the host turns them into any of
             SUM / COUNT / AVG / VAR / STD estimators *and* their error
             stddevs — aggregates.estimates_from_power_sums).

This mirrors the paper's AFC inner loop (§3.2): one scan over the sampled
rows produces every parametric aggregate at once, including the 4th-moment
term the VAR/STD uncertainty estimators need.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.sampled_agg.compensated import comp_sum

__all__ = ["sampled_moments_ref", "masked_select_ranks_ref", "N_MOMENTS"]

N_MOMENTS = 5  # [count, s1, s2, s3, s4]


def sampled_moments_ref(
    vals: jnp.ndarray, z: jnp.ndarray, shift: jnp.ndarray | None = None
) -> jnp.ndarray:
    """vals: (k, cap) f32; z: (k,) int32 -> (k, 5) [count, s1, s2, s3, s4].

    Raw power sums of ``vals - shift`` over the valid prefix; padding
    contributes zero.  ``shift`` (k,) is an arbitrary per-feature origin —
    centered moments are shift-invariant, so accumulating about a value
    near the data (e.g. the first buffered sample) avoids the float32
    cancellation that raw 4th powers suffer when |mean| >> std.  None means
    no shift (sums of the raw values).

    Accumulation is compensated (``compensated.comp_sum``): Σv⁴ on 60k-row
    heavy-tailed columns drifts measurably under plain f32 reduction order,
    and a drifted s4 is a wrong VAR/STD sigma — i.e. a wrong guarantee.
    """
    k, cap = vals.shape
    mask = (jnp.arange(cap)[None, :] < z[:, None]).astype(jnp.float32)
    v = vals.astype(jnp.float32)
    if shift is not None:
        v = v - shift.astype(jnp.float32)[:, None]
    v = v * mask
    count = jnp.sum(mask, axis=1)
    v2 = v * v
    s1 = comp_sum(v, axis=1)
    s2 = comp_sum(v2, axis=1)
    s3 = comp_sum(v2 * v, axis=1)
    s4 = comp_sum(v2 * v2, axis=1)
    return jnp.stack([count, s1, s2, s3, s4], axis=1)


def masked_select_ranks_ref(
    vals: jnp.ndarray, z: jnp.ndarray, targets: jnp.ndarray
) -> jnp.ndarray:
    """Order statistics of each valid prefix at the requested ranks.

    vals: (k, cap) f32; z: (k,) int32; targets: (k, R) int32 ranks into the
    ascending-sorted z-prefix -> (k, R) f32 selected values.  Out-of-prefix
    positions sort as +inf, so a target rank >= z gathers +inf — callers
    clip targets to [0, z-1] (and handle z == 0 themselves).

    This is the oracle for the Pallas ``masked_select_ranks`` kernel, which
    computes the same selection by stable rank *counting* instead of a sort
    (the quantile/bootstrap AFC stage, paper appendix D).
    """
    k, cap = vals.shape
    padded = jnp.where(
        jnp.arange(cap)[None, :] < z[:, None], vals.astype(jnp.float32), jnp.inf
    )
    s = jnp.sort(padded, axis=1)
    return jnp.take_along_axis(s, jnp.clip(targets, 0, cap - 1), axis=1)
