"""Pallas TPU kernel: masked-quantile + bootstrap-resample selection.

The holistic AFC stage (MEDIAN / QUANTILE, paper appendix D) needs, per
feature, the order statistics of the live z-prefix at a handful of ranks:
the point-estimate rank plus ``B`` bootstrap-replicate ranks (the empirical
inverse-CDF table the AMI sampler draws from).  A general sort is awkward on
the VPU; selecting *given* ranks is not.  This kernel selects by **stable
rank counting**:

* grid ``(k_tiles, ci_tiles, cj_tiles)`` with ``cj`` innermost: tile ``ci``
  holds the candidate elements, tile ``cj`` streams the comparison elements;
* out-of-prefix columns compare as +inf (iota-vs-z mask, branch-free), ties
  break on column index, so every element has a unique rank and prefix
  elements occupy ranks ``0..z-1`` exactly;
* a VMEM scratch accumulates each candidate's rank across ``cj`` tiles; on
  the last ``cj`` tile the candidates matching the requested target ranks
  are selected into the ``(block_k, R)`` output accumulator.

Cost is O(cap²/VPU-width) masked compares per feature — quadratic, but one
fused VMEM-resident pass with no data-dependent shapes, which is what the
``lax.while_loop`` executor needs.  Beyond ~4k-row caps the XLA-sort oracle
(`ref.masked_select_ranks_ref`) wins; ``ops.masked_quantile_estimates``
routes between them per ``afc_backend`` exactly like ``sampled_moments``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["masked_select_ranks"]


def _kernel(
    z_ref, vals_i_ref, vals_j_ref, targets_ref, out_ref, rank_ref,
    *, block_ci: int, block_cj: int, n_cj: int
):
    ci = pl.program_id(1)
    cj = pl.program_id(2)
    z = z_ref[...]                                   # (block_k,)
    vi = vals_i_ref[...].astype(jnp.float32)         # (block_k, block_ci)
    vj = vals_j_ref[...].astype(jnp.float32)         # (block_k, block_cj)
    coli = ci * block_ci + jax.lax.broadcasted_iota(jnp.int32, vi.shape, 1)
    colj = cj * block_cj + jax.lax.broadcasted_iota(jnp.int32, vj.shape, 1)
    vi = jnp.where(coli < z[:, None], vi, jnp.inf)
    vj = jnp.where(colj < z[:, None], vj, jnp.inf)

    @pl.when(cj == 0)
    def _init_ranks():
        rank_ref[...] = jnp.zeros_like(rank_ref)

    # stable rank of candidate i = #{j : v_j < v_i  or  (v_j == v_i, j < i)};
    # +inf padding ties resolve on index too, so ranks are a permutation.
    less = vj[:, None, :] < vi[:, :, None]
    tie = (vj[:, None, :] == vi[:, :, None]) & (
        colj[:, None, :] < coli[:, :, None]
    )
    rank_ref[...] += jnp.sum(less | tie, axis=2).astype(jnp.int32)

    @pl.when(cj == n_cj - 1)
    def _select():
        @pl.when(ci == 0)
        def _init_out():
            out_ref[...] = jnp.zeros_like(out_ref)

        t = targets_ref[...]                          # (block_k, R)
        hit = rank_ref[...][:, :, None] == t[:, None, :]
        # where() keeps +inf out of unselected lanes (inf * 0 would be NaN)
        out_ref[...] += jnp.sum(
            jnp.where(hit, vi[:, :, None], 0.0), axis=1
        )


@functools.partial(
    jax.jit, static_argnames=("block_k", "block_ci", "block_cj", "interpret")
)
def masked_select_ranks(
    vals: jnp.ndarray,        # (k, cap) f32
    z: jnp.ndarray,           # (k,) int32 live prefix lengths
    targets: jnp.ndarray,     # (k, R) int32 ranks into the sorted prefix
    *,
    block_k: int = 4,
    block_ci: int = 128,
    block_cj: int = 128,
    interpret: bool = True,   # CPU container: interpret; TPU: False
) -> jnp.ndarray:
    """(k, R) order statistics of each z-prefix at ``targets`` ranks.

    Semantics match :func:`ref.masked_select_ranks_ref`: out-of-prefix
    positions sort as +inf, so target ranks must lie in [0, z-1] for finite
    results (callers clip; ``z == 0`` rows return +inf and are overridden by
    the empty-prefix convention upstream).  Shapes need not divide the block
    sizes — inputs are padded (padded rows carry z = 0, padded targets point
    past the buffer and select nothing, contributing 0 to unsliced rows).
    """
    k, cap = vals.shape
    r = targets.shape[1]
    block_k = min(block_k, k)
    block_ci = min(block_ci, cap)
    block_cj = min(block_cj, cap)
    kp = -(-k // block_k) * block_k
    # pad columns to a common multiple so BOTH tile grids cover every column
    # (padding max(block_ci, block_cj) alone would drop trailing candidates
    # whenever the smaller block does not divide it)
    tile = math.lcm(block_ci, block_cj)
    capp = -(-cap // tile) * tile
    rp = -(-r // 128) * 128 if not interpret else r
    if (kp, capp) != (k, cap):
        vals = jnp.pad(vals, ((0, kp - k), (0, capp - cap)))
        z = jnp.pad(z, (0, kp - k))
        targets = jnp.pad(targets, ((0, kp - k), (0, 0)))
    if rp != r:
        # pad with an impossible rank: selects nothing, contributes 0.0
        targets = jnp.pad(
            targets, ((0, 0), (0, rp - r)), constant_values=capp + 1
        )
    n_cj = capp // block_cj
    grid = (kp // block_k, capp // block_ci, n_cj)
    out = pl.pallas_call(
        functools.partial(
            _kernel, block_ci=block_ci, block_cj=block_cj, n_cj=n_cj
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_k,), lambda i, ci, cj: (i,)),
            pl.BlockSpec((block_k, block_ci), lambda i, ci, cj: (i, ci)),
            pl.BlockSpec((block_k, block_cj), lambda i, ci, cj: (i, cj)),
            pl.BlockSpec((block_k, rp), lambda i, ci, cj: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_k, rp), lambda i, ci, cj: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((kp, rp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_k, block_ci), jnp.int32)],
        interpret=interpret,
    )(z, vals, vals, targets)
    return out[:k, :r]
