"""Pallas TPU kernel: tree-ensemble inference over the QMC sample batch.

AMI (§3.3) evaluates the pipeline model on m~1000 QMC rows, and the Sobol-
Saltelli estimator on m(k+2) more — for the paper's tree pipelines (LGBM /
XGB / RF) this batched ensemble inference IS the serving hot spot once AFC
is approximated away.

TPU adaptation (DESIGN.md §3): trees are tensorized Hummingbird-style into
complete node arrays, and traversal is a branch-free level-wise gather chain

    idx <- (x[row, feat[tree, idx]] <= thr[tree, idx]) ? L[idx] : R[idx]

Grid: (row tiles, tree tiles).  A (block_t, max_nodes) slab of node tables
and a (block_m, F) row tile live in VMEM; `depth` gather rounds happen
entirely on-chip; per-tree leaf values are summed and accumulated into the
output row tile across tree tiles (innermost grid dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ensemble_sum"]


def _kernel(feat_ref, thr_ref, left_ref, right_ref, val_ref, x_ref, out_ref, *, depth):
    ti = pl.program_id(1)
    feat = feat_ref[...]          # (bt, M) int32
    thr = thr_ref[...]            # (bt, M) f32
    left = left_ref[...]
    right = right_ref[...]
    val = val_ref[...]
    x = x_ref[...]                # (bm, F) f32
    bt, _ = feat.shape
    bm = x.shape[0]

    idx = jnp.zeros((bt, bm), jnp.int32)
    for _ in range(depth):
        f = jnp.take_along_axis(feat, idx, axis=1)            # (bt, bm)
        t = jnp.take_along_axis(thr, idx, axis=1)
        xv = jnp.take_along_axis(x, f.T, axis=1).T            # x[row, f]
        go_left = xv <= t
        nl = jnp.take_along_axis(left, idx, axis=1)
        nr = jnp.take_along_axis(right, idx, axis=1)
        idx = jnp.where(go_left, nl, nr)
    leaves = jnp.take_along_axis(val, idx, axis=1)            # (bt, bm)
    tile_sum = jnp.sum(leaves, axis=0)                        # (bm,)

    @pl.when(ti == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += tile_sum


@functools.partial(
    jax.jit, static_argnames=("depth", "block_m", "block_t", "interpret")
)
def ensemble_sum(
    feature: jnp.ndarray,         # (T, M) int32
    threshold: jnp.ndarray,       # (T, M) f32
    left: jnp.ndarray,            # (T, M) int32
    right: jnp.ndarray,           # (T, M) int32
    value: jnp.ndarray,           # (T, M) f32
    x: jnp.ndarray,               # (m, F) f32
    *,
    depth: int,
    block_m: int = 256,
    block_t: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    """(m,) sum of per-tree leaf values (caller adds base / divides)."""
    t, m_nodes = feature.shape
    m, f = x.shape
    block_m = min(block_m, m)
    block_t = min(block_t, t)
    assert m % block_m == 0 and t % block_t == 0
    grid = (m // block_m, t // block_t)
    tree_spec = pl.BlockSpec((block_t, m_nodes), lambda i, j: (j, 0))
    return pl.pallas_call(
        functools.partial(_kernel, depth=depth),
        grid=grid,
        in_specs=[
            tree_spec,
            tree_spec,
            tree_spec,
            tree_spec,
            tree_spec,
            pl.BlockSpec((block_m, f), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=interpret,
    )(feature, threshold, left, right, value, x)
