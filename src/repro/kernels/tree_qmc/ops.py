"""Jit'd wrapper: ensemble prediction for QMC batches."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.tree_qmc.tree_qmc import ensemble_sum
from repro.models.tabular.trees import TreeEnsemble, ensemble_predict_sum

__all__ = ["predict_sum"]


def predict_sum(
    ens: TreeEnsemble, x: jnp.ndarray, *, use_kernel: bool | None = None
) -> jnp.ndarray:
    """(m, F) -> (m,) sum of leaf values across the ensemble."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        m = x.shape[0]
        block_m = m if m < 256 else 256
        # pad rows to a block multiple
        pad = (-m) % block_m
        xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
        out = ensemble_sum(
            ens.feature,
            ens.threshold,
            ens.left,
            ens.right,
            ens.value,
            xp.astype(jnp.float32),
            depth=ens.depth,
            block_m=block_m,
            interpret=jax.default_backend() != "tpu",
        )
        return out[:m]
    return ensemble_predict_sum(ens, x)
