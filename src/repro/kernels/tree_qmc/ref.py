"""Oracle for the tree-ensemble QMC kernel: the tensorized jnp traversal."""
from repro.models.tabular.trees import TreeEnsemble, ensemble_predict_sum

__all__ = ["TreeEnsemble", "ensemble_predict_sum"]
