from repro.kernels.tree_qmc import ops  # noqa: F401
