"""Oracle for the flash-attention kernel: plain materialized attention."""
from repro.models.lm.layers import attention_full  # noqa: F401

__all__ = ["attention_full"]
