"""Jit'd wrapper: flash attention with GQA head expansion + layout shim."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention

__all__ = ["attention"]


def attention(
    q: jnp.ndarray,   # (B, S, H, D)   — model layout
    k: jnp.ndarray,   # (B, S, Hkv, D)
    v: jnp.ndarray,   # (B, S, Hkv, Dv)
    *,
    causal: bool = True,
    use_kernel: bool | None = None,
) -> jnp.ndarray:
    """Returns (B, S, H, Dv)."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    b, s, h, d = q.shape
    hkv = k.shape[2]
    if hkv != h:
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention(
        qt, kt, vt, causal=causal, interpret=jax.default_backend() != "tpu"
    )
    return o.transpose(0, 2, 1, 3)
