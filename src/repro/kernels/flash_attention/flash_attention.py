"""Pallas TPU kernel: blockwise online-softmax (flash) attention.

The substrate hot spot for the LM zoo's train/prefill cells.  Grid is
(batch*heads, q_tiles, kv_tiles) with kv innermost; the running max /
denominator / output accumulator live in f32 VMEM scratch across kv tiles
(never touching HBM), and causal masking skips fully-masked kv tiles via
``pl.when`` — the work saved is exactly the upper triangle, which the pure
XLA fallback (models.lm.layers.attention_blockwise) cannot skip.

Tile defaults (128 x 128 on the MXU's native 128-lane layout) keep the live
set at q(128, d) + k/v(128, d) + scores(128, 128) f32 ~ 0.4 MB for d=128 —
far under the ~16 MB VMEM budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
    block_q: int, block_k: int, scale: float, causal: bool, nk: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # causal: tiles entirely above the diagonal contribute nothing
    run = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        v = v_ref[0].astype(jnp.float32)                    # (bk, dv)
        s = q @ k.T                                         # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,   # (B, H, Sq, D)
    k: jnp.ndarray,   # (B, H, Sk, D)
    v: jnp.ndarray,   # (B, H, Sk, Dv)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """(B, H, Sq, Dv) attention output; KV heads must be pre-expanded."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    dv = v.shape[-1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    nq, nk = sq // block_q, sk // block_k
    scale = d ** -0.5

    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, dv)

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            block_q=block_q,
            block_k=block_k,
            scale=scale,
            causal=causal,
            nk=nk,
        ),
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, block_k, dv), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, dv)
