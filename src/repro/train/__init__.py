from repro.train.step import build_train_step, init_train_state
from repro.train.trainer import Trainer, TrainerConfig, synthetic_batch

__all__ = ["build_train_step", "init_train_state", "Trainer", "TrainerConfig", "synthetic_batch"]
