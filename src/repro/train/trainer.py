"""The training loop: deterministic data, checkpoints, restart, stragglers.

Fault-tolerance contract (DESIGN.md §5):

* **Deterministic, step-indexed data** — every batch is a pure function of
  (seed, step), so a restarted / re-joined worker regenerates exactly the
  batch stream it missed, with no shared data-service state.
* **Auto-resume** — on construction the trainer restores the newest intact
  checkpoint (atomicity guaranteed by CheckpointManager) and continues from
  its step; a mid-save crash costs at most ``save_every`` steps.
* **Elastic resharding** — restore() device_puts against the *current* mesh,
  so the same checkpoint resumes on 1 chip, 256 or 512 (tested in
  tests/test_checkpoint.py with different host meshes).
* **Straggler mitigation (design)** — in SPMD everyone executes one program,
  so stragglers surface as step-time outliers; the loop tracks an EWMA of
  step time and flags >3x outliers (the hook where a production deployment
  triggers hot-spare pod swap + elastic restore; actual swap needs real
  infra, documented in EXPERIMENTS.md).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.optim.adamw import adamw_init, linear_warmup_cosine
from repro.train.step import build_train_step

__all__ = ["Trainer", "TrainerConfig", "synthetic_batch"]


def synthetic_batch(model, batch_size: int, seq_len: int, seed: int, step: int):
    """Deterministic LM batch as a pure function of (seed, step)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    cfg = model.cfg
    s_text = seq_len - cfg.n_frontend_tokens if cfg.family == "vlm" else seq_len
    batch = {
        "tokens": jax.random.randint(
            key, (batch_size, s_text + 1), 0, cfg.vocab, dtype=jnp.int32
        )
    }
    if cfg.frontend:
        batch["frontend"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (batch_size, cfg.n_frontend_tokens, cfg.d_model),
            jnp.float32,
        )
    return batch


@dataclass
class TrainerConfig:
    batch_size: int = 8
    seq_len: int = 256
    total_steps: int = 200
    lr: float = 3e-4
    warmup: int = 20
    save_every: int = 50
    keep: int = 3
    seed: int = 0
    grad_accum: int = 1
    straggler_ewma: float = 0.9
    straggler_factor: float = 3.0


@dataclass
class Trainer:
    model: object
    ckpt_dir: str
    config: TrainerConfig = field(default_factory=TrainerConfig)
    batch_fn: Callable | None = None     # (step) -> batch; default synthetic

    def __post_init__(self):
        cfg = self.config
        self.manager = CheckpointManager(self.ckpt_dir, keep=cfg.keep)
        self.step_fn = jax.jit(
            build_train_step(
                self.model,
                lr_schedule=linear_warmup_cosine(cfg.lr, cfg.warmup, cfg.total_steps),
                grad_accum=cfg.grad_accum,
            ),
            donate_argnums=(0, 1),
        )
        self._ewma_dt: float | None = None
        self.straggler_events: list[int] = []

    # ------------------------------------------------------------------
    def init_state(self, key=None):
        params = self.model.init(key if key is not None else jax.random.PRNGKey(0))
        return params, adamw_init(params)

    def _batch(self, step: int):
        if self.batch_fn is not None:
            return self.batch_fn(step)
        return synthetic_batch(
            self.model, self.config.batch_size, self.config.seq_len,
            self.config.seed, step,
        )

    # ------------------------------------------------------------------
    def run(self, steps: int | None = None, state=None):
        """Train from the latest checkpoint (or fresh); returns final state."""
        cfg = self.config
        start_step = 0
        if state is None:
            params, opt = self.init_state()
            restored, meta = self.manager.restore((params, opt))
            if restored is not None:
                params, opt = restored
                start_step = int(meta["step"])
            state = (params, opt)
        params, opt = state

        total = steps if steps is not None else cfg.total_steps
        history = []
        for step in range(start_step, min(start_step + total, cfg.total_steps)):
            t0 = time.perf_counter()
            batch = self._batch(step)
            params, opt, metrics = self.step_fn(
                params, opt, batch, jnp.asarray(step, jnp.int32)
            )
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self._track_stragglers(step, dt)
            history.append(
                {"step": step, "loss": float(metrics["loss"]), "dt": dt,
                 "grad_norm": float(metrics["grad_norm"])}
            )
            if (step + 1) % cfg.save_every == 0 or step + 1 == cfg.total_steps:
                self.manager.save(step + 1, (params, opt), block=False)
        self.manager.wait()
        return (params, opt), history

    def _track_stragglers(self, step: int, dt: float):
        cfg = self.config
        if self._ewma_dt is None:
            self._ewma_dt = dt
            return
        if dt > cfg.straggler_factor * self._ewma_dt and step > 5:
            # production hook: trigger spare-pod swap + elastic restore here
            self.straggler_events.append(step)
        self._ewma_dt = cfg.straggler_ewma * self._ewma_dt + (1 - cfg.straggler_ewma) * dt
