"""Distributed train-step builder: loss -> grads -> clip -> AdamW.

The returned ``train_step`` is a single pjit-able function over
(params, opt_state, batch, step); optimizer state shards exactly like the
parameters (ZeRO-for-free under pjit).  Microbatch gradient accumulation is
a lax.scan over batch slices — the standard way to trade activation memory
for steps when a cell does not fit (one of the §Perf knobs).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWState, adamw_init, adamw_update, clip_by_global_norm

__all__ = ["build_train_step", "init_train_state"]


def init_train_state(params):
    return adamw_init(params)


def build_train_step(
    model,
    *,
    lr_schedule: Callable | None = None,
    grad_accum: int = 1,
    max_grad_norm: float = 1.0,
    weight_decay: float = 0.1,
) -> Callable:
    lr_schedule = lr_schedule or (lambda step: 3e-4)

    def loss_fn(params, batch):
        loss, metrics = model.train_loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: AdamWState, batch, step):
        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                acc, loss_acc = carry
                (l, _), g = grad_fn(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + l), None

            micro_batches = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum, *x.shape[1:]),
                batch,
            )
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_sum), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), micro_batches
            )
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum
            metrics = {}

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = lr_schedule(step)
        params, opt_state = adamw_update(
            grads, opt_state, params, lr, weight_decay=weight_decay
        )
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        out_metrics.update({k: v for k, v in metrics.items()})
        return params, opt_state, out_metrics

    return train_step
