"""Qwen3-8B (hf:Qwen/Qwen3-8B) — GQA kv=8, qk_norm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12288,
    vocab=151936,
    qk_norm=True,
    act="swiglu",
    rope_theta=1000000.0,
)
