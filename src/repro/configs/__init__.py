"""Architecture registry: one module per assigned architecture."""
from repro.configs.base import (
    MLAConfig,
    MoEConfig,
    ModelConfig,
    ShapeConfig,
    SHAPES,
    SSMConfig,
    cell_applicable,
)

_MODULES = {
    "deepseek-v2-236b": "deepseek_v2_236b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen3-14b": "qwen3_14b",
    "qwen1.5-0.5b": "qwen15_0_5b",
    "gemma-7b": "gemma_7b",
    "qwen3-8b": "qwen3_8b",
    "xlstm-1.3b": "xlstm_1_3b",
    "zamba2-2.7b": "zamba2_2_7b",
    "internvl2-1b": "internvl2_1b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    import importlib

    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def cells():
    """All applicable (arch, shape) dry-run cells with skip reasons."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = cell_applicable(cfg, s)
            out.append((a, s.name, ok, why))
    return out


__all__ = [
    "ARCH_IDS",
    "get_config",
    "cells",
    "ModelConfig",
    "MoEConfig",
    "MLAConfig",
    "SSMConfig",
    "ShapeConfig",
    "SHAPES",
    "cell_applicable",
]
