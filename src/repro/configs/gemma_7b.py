"""Gemma-7B (arXiv:2403.08295) — GeGLU, head_dim=256."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    d_ff=24576,
    vocab=256000,
    head_dim=256,
    act="geglu",
)
