"""Qwen3-14B (hf:Qwen/Qwen3-14B family) — GQA kv=8, qk_norm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab=151936,
    qk_norm=True,
    act="swiglu",
    rope_theta=1000000.0,
    pad_heads_to=16,  # 16-way TP divisibility (zero-padded q heads)
)
