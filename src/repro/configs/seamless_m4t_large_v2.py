"""SeamlessM4T-large-v2 (arXiv:2308.11596; hf) — enc-dec, speech stub."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,              # decoder layers
    enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    act="geglu",
    frontend="audio_stub",
    n_frontend_tokens=1024,   # precomputed speech frame embeddings (encoder input)
)
