"""IBM Granite 3.0 1B-A400M (hf:ibm-granite/granite-3.0-1b-a400m-base)."""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    act="swiglu",
    moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512, group_size=256),
)
