"""xLSTM-1.3B (arXiv:2405.04517) — mLSTM backbone with interleaved sLSTM."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                   # xLSTM blocks carry their own up-projection
    vocab=50304,
    ssm=SSMConfig(kind="xlstm", head_dim=512, chunk=256, slstm_every=8),
)
