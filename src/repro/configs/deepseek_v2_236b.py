"""DeepSeek-V2 236B (arXiv:2405.04434; hf) — MLA + 160-expert MoE top-6."""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,          # MLA: latent-compressed KV, heads expanded on read
    d_ff=12288,              # the single leading dense layer's FFN width
    vocab=102400,
    head_dim=128,
    act="swiglu",
    rope_theta=10000.0,
    dense_layers=1,
    moe=MoEConfig(
        n_experts=160,
        top_k=6,
        d_ff_expert=1536,
        n_shared=2,
        d_ff_shared=1536,
        group_size=512,
    ),
    mla=MLAConfig(kv_lora=512, q_lora=1536, rope_dim=64, nope_dim=128, v_dim=128),
)
