"""Model/shape configuration system for the assigned architecture pool."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    group_size: int = 1024        # dispatch group (tokens) for the scan
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention dims (arXiv:2405.04434)."""

    kv_lora: int = 512
    q_lora: int = 1536
    rope_dim: int = 64            # decoupled RoPE key dim
    nope_dim: int = 128           # per-head non-rope q/k dim
    v_dim: int = 128              # per-head value dim


@dataclass(frozen=True)
class SSMConfig:
    kind: str                     # "mamba2" | "xlstm"
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64            # mamba2 P dim
    chunk: int = 256
    slstm_every: int = 0          # xlstm: one sLSTM per this many mLSTM layers


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None   # default d_model // n_heads
    act: str = "swiglu"           # swiglu | geglu
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    attn_every: int = 0           # hybrid: shared attn block every k ssm layers
    enc_layers: int = 0           # enc-dec: encoder depth (n_layers = decoder)
    frontend: str | None = None   # "vit_stub" | "audio_stub"
    n_frontend_tokens: int = 256
    dense_layers: int = 0         # moe: leading dense-FFN layers (deepseek=1)
    sliding_window: int = 0       # >0: cap attention window (hybrid long-ctx)
    pad_heads_to: int = 1         # zero-pad q heads to a multiple (TP divisibility)
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k-token contexts? (SSM state or windowed.)"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D model FLOPs)."""
        d, v = self.d_model, self.vocab
        hd = self.resolved_head_dim
        emb = v * d * 2  # in + out embedding (untied)
        per_attn = (
            d * self.n_heads * hd
            + 2 * d * self.n_kv_heads * hd
            + self.n_heads * hd * d
        )
        if self.mla:
            m = self.mla
            per_attn = (
                d * m.q_lora
                + m.q_lora * self.n_heads * (m.nope_dim + m.rope_dim)
                + d * (m.kv_lora + m.rope_dim)
                + m.kv_lora * self.n_heads * (m.nope_dim + m.v_dim)
                + self.n_heads * m.v_dim * d
            )
        gates = 3 if self.act in ("swiglu", "geglu") else 2
        per_dense_ffn = gates * d * self.d_ff
        if self.ssm is not None and self.ssm.kind == "mamba2":
            di = self.ssm.expand * d
            per_ssm = d * (2 * di + 2 * self.ssm.d_state) + di * d + di
            n_ssm = self.n_layers
            n_attn_apps = 0 if self.attn_every == 0 else 1  # shared weights
            total = emb + n_ssm * per_ssm + n_attn_apps * (per_attn + per_dense_ffn)
            return int(total)
        if self.ssm is not None and self.ssm.kind == "xlstm":
            di = 2 * d
            per_m = d * 3 * di + di * d + 3 * di  # mlstm proj + gates-ish
            return int(emb + self.n_layers * per_m)
        if self.moe:
            mo = self.moe
            per_moe_ffn = (
                mo.n_experts * 3 * d * mo.d_ff_expert
                + mo.n_shared * 3 * d * max(mo.d_ff_shared, mo.d_ff_expert)
                + d * mo.n_experts
            )
            n_moe = self.n_layers - self.dense_layers
            total = (
                emb
                + self.n_layers * per_attn
                + self.dense_layers * per_dense_ffn
                + n_moe * per_moe_ffn
            )
            return int(total)
        n_blocks = self.n_layers + self.enc_layers
        return int(emb + n_blocks * (per_attn + per_dense_ffn))

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared experts only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        mo = self.moe
        per_moe_active = (mo.top_k + mo.n_shared) * 3 * d * mo.d_ff_expert
        per_moe_total = (
            mo.n_experts * 3 * d * mo.d_ff_expert
            + mo.n_shared * 3 * d * max(mo.d_ff_shared, mo.d_ff_expert)
        )
        n_moe = self.n_layers - self.dense_layers
        return int(self.param_count() - n_moe * (per_moe_total - per_moe_active))

    def reduced(self) -> "ModelConfig":
        """Smoke-test scale: same family/topology, tiny dims."""
        kw: dict = dict(
            n_layers=min(self.n_layers, 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=256,
            vocab=512,
            head_dim=32 if self.head_dim else None,
            enc_layers=min(self.enc_layers, 2),
            dense_layers=min(self.dense_layers, 1),
            n_frontend_tokens=8 if self.frontend else self.n_frontend_tokens,
            sliding_window=64 if self.sliding_window else 0,
            pad_heads_to=1,
            attn_every=2 if self.attn_every else 0,
        )
        if self.moe:
            kw["moe"] = replace(
                self.moe,
                n_experts=8,
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=64,
                d_ff_shared=64 if self.moe.n_shared else 0,
                group_size=64,
            )
        if self.mla:
            kw["mla"] = MLAConfig(kv_lora=32, q_lora=48, rope_dim=8, nope_dim=16, v_dim=16)
        if self.ssm:
            kw["ssm"] = replace(
                self.ssm, d_state=16, head_dim=16, chunk=32,
                slstm_every=4 if self.ssm.slstm_every else 0,
            )
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str        # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    def reduced(self) -> "ShapeConfig":
        return ShapeConfig(
            self.name, self.kind, min(self.seq_len, 64), min(self.global_batch, 2)
        )


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Which (arch x shape) cells run; mirrors DESIGN.md §Arch-applicability."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k decode is not sub-quadratic (skip per brief)"
    return True, ""
