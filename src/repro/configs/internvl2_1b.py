"""InternVL2-1B (arXiv:2404.16821; hf) — Qwen2-0.5B-class LM + ViT stub."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    qkv_bias=True,
    act="swiglu",
    frontend="vit_stub",
    pad_heads_to=16,  # 16-way TP divisibility (zero-padded q heads)
    n_frontend_tokens=256,    # precomputed InternViT patch embeddings
)
