"""Zamba2-2.7B (arXiv:2411.15242; hf) — Mamba2 backbone + shared attn block."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm=SSMConfig(kind="mamba2", d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    attn_every=6,             # one shared attention+MLP block every 6 mamba layers
    sliding_window=4096,      # caps shared-attn KV for the 500k-decode cell
)
