"""Datastore + online-aggregation substrate."""
from repro.data import aggregates
from repro.data.store import ColumnStore, Table, build_table, bucket_size

__all__ = ["aggregates", "ColumnStore", "Table", "build_table", "bucket_size"]
