"""In-memory columnar datastore with group-indexed incremental sampling.

Plays the role ClickHouse plays in the paper (§4 System Setup): each *table*
holds row-aligned columns plus a **group index** (e.g. rows per user / per
trip region).  At build time rows are permuted once *within each group* with a
fixed seed, so that

    prefix of length z  ==  simple random sample of size z without replacement

and growing a plan from z to z' touches only rows [z, z') — the paper's
incremental online-aggregation property.  On a real TPU cluster the column
buffers live sharded in HBM and the gather below is the ``sampled_agg``
Pallas kernel's DMA; here they live in host memory / device 0.

**Streaming append** (DESIGN.md § Online feature store): the paper's setting
is *online* aggregation over continuously arriving rows, so the store is not
a frozen snapshot.  :meth:`Table.append` extends a group's permuted prefix by
drawing the new row's position ``j ~ Uniform{0..m}`` from the table's own
seeded RNG stream (the sequential construction of a uniform random
permutation), which preserves the prefix-is-SRS invariant for every prefix
length after every append.  Each insertion bumps the group's **version** —
the cache-invalidation signal for device-resident precompute
(serving/feature_cache.py) — and is recorded in a bounded per-group append
log so cached prefix tables can be *delta-updated* instead of rebuilt.

**Crash recovery** (DESIGN.md § Fault tolerance): the bounded per-group log
is a cache-refresh convenience, not a durability story, so every append is
ALSO written to an unbounded **journal** stamped with a table-wide monotone
sequence number.  The raw column arrays play the durable-storage role; the
derived index state (``perm`` / ``group_ptr`` / ``versions`` / the bounded
log) is exactly what a crash or a partial write can corrupt, and
:meth:`Table.recover` rebuilds all of it by replaying the journal over the
build-time base state — byte-identical to the never-crashed table, because
each journal entry carries the ORIGINAL drawn prefix position ``j`` (no
re-draws on replay).  ``recover`` can also revalidate attached feature
caches so device-resident entries whose version/checksum no longer match
the rebuilt store are dropped instead of served.

**Input sanitization**: a NaN/Inf smuggled into a column poisons every
prefix power sum built over it, so :meth:`Table.append` polices values at
the edge — ``sanitize="reject"`` (default) raises naming the table, column
and offending row; ``sanitize="clamp"`` maps NaN to 0.0 (the store's
neutral pad value) and ±Inf to the column's observed finite range.

The store is deliberately framework-agnostic (plain numpy in, jnp out) so the
serving runtime, the fused executor, and the benchmarks all share it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping

import jax.numpy as jnp
import numpy as np

__all__ = ["Table", "ColumnStore", "bucket_size", "build_table", "MAX_APPEND_LOG"]

#: Append-log depth per group.  A cached entry older than this many
#: insertions can no longer be delta-refreshed and falls back to a full
#: rebuild — bounding both log memory and worst-case delta-chain length.
MAX_APPEND_LOG = 64


def bucket_size(z: int, minimum: int = 64) -> int:
    """Round a sample size up to the next power of two (bounds recompiles)."""
    cap = minimum
    while cap < z:
        cap *= 2
    return cap


@dataclass
class Table:
    """Row-aligned columns + CSR-style group index over a permutation.

    ``versions[g]`` counts insertions into dense group ``g`` since build
    (0 = pristine); any append bumps it, so ``(table, group, version)`` is a
    sound cache key.  ``rng`` continues the build-time seeded stream, making
    the whole append trajectory deterministic given (seed, append sequence).
    """

    columns: dict[str, np.ndarray]
    group_ptr: np.ndarray          # (G+1,) offsets into perm
    perm: np.ndarray               # (R,) row ids, permuted within each group
    group_ids: dict[int, int]      # external group key -> dense group index
    name: str = ""
    rng: np.random.Generator = field(
        default_factory=lambda: np.random.default_rng(0), repr=False
    )
    versions: list[int] = field(default_factory=list, repr=False)
    # dense group -> [(version, j, row_id)] for the last MAX_APPEND_LOG
    # insertions, oldest first (version = the group version the insertion
    # produced; j = the drawn prefix position; row_id indexes ``columns``).
    _log: dict[int, list[tuple[int, int, int]]] = field(
        default_factory=dict, repr=False
    )
    #: Table-wide monotone sequence number; stamped on every journal entry.
    seq: int = field(default=0, repr=False)
    # Complete append journal, oldest first: (seq, external group key, j,
    # row_id), with j = -1 marking a group registration (add_group) event.
    # Unlike the bounded ``_log`` this is never truncated — it is the
    # replay source for :meth:`recover`.
    _journal: list[tuple[int, int, int, int]] = field(
        default_factory=list, repr=False
    )

    def __post_init__(self) -> None:
        # Build-time base state recover() replays the journal over.  These
        # are index-only copies (permutation + CSR offsets), never column
        # data — the raw columns are the durable record.
        self._base_perm = self.perm.copy()
        self._base_ptr = self.group_ptr.copy()
        self._base_gids = dict(self.group_ids)
        self._base_versions = list(self.versions)

    @property
    def n_rows(self) -> int:
        return int(self.perm.shape[0])

    @property
    def n_groups(self) -> int:
        return int(self.group_ptr.shape[0] - 1)

    def _group_index(self, gid: int) -> int:
        """Dense index of an external group key, or a loud ValueError.

        Streaming ingest makes unknown keys an expected runtime condition
        (a request for a user the store has never seen), so the error names
        the table and the key instead of leaking a bare KeyError.
        """
        try:
            return self.group_ids[int(gid)]
        except KeyError:
            raise ValueError(
                f"table {self.name or '<unnamed>'!r}: unknown group key "
                f"{int(gid)} (known groups: {len(self.group_ids)})"
            ) from None

    def version(self, gid: int) -> int:
        """Insertions into the group since build — the cache-key component."""
        g = self._group_index(gid)
        return self.versions[g] if g < len(self.versions) else 0

    def group_size(self, gid: int) -> int:
        g = self._group_index(gid)
        return int(self.group_ptr[g + 1] - self.group_ptr[g])

    def sample_prefix(self, column: str, gid: int, cap: int) -> np.ndarray:
        """First ``min(cap, N)`` permuted rows of the group, padded to cap.

        The prefix is the group's canonical SRS order; callers mask with the
        live ``z``.  Padding repeats 0.0 (masked out by estimators); an
        empty group is therefore the all-zero buffer with n = 0.
        """
        g = self._group_index(gid)
        start, stop = int(self.group_ptr[g]), int(self.group_ptr[g + 1])
        take = min(cap, stop - start)
        rows = self.perm[start : start + take]
        out = np.zeros((cap,), np.float32)
        out[:take] = self.columns[column][rows]
        return out

    def full_values(self, column: str, gid: int) -> np.ndarray:
        g = self._group_index(gid)
        start, stop = int(self.group_ptr[g]), int(self.group_ptr[g + 1])
        return self.columns[column][self.perm[start:stop]].astype(np.float32)

    def lookup(self, column: str, gid: int) -> float:
        """Point lookup (lightweight datastore op — computed exactly).

        An empty group (a just-registered user with no history) reads as
        0.0 — the same neutral value the padded sample buffers use — rather
        than silently reading the next group's first row.
        """
        g = self._group_index(gid)
        start, stop = int(self.group_ptr[g]), int(self.group_ptr[g + 1])
        if start == stop:
            return 0.0
        return float(self.columns[column][self.perm[start]])

    # --- streaming append --------------------------------------------------
    def add_group(self, gid: int) -> int:
        """Register an empty group (a new user); returns its dense index.

        Idempotent for known keys.  The group starts at version 0 with zero
        rows: lookups read 0.0 and sample buffers come back all-pad until
        the first append.
        """
        key = int(gid)
        if key in self.group_ids:
            return self.group_ids[key]
        g = self._register_group(key)
        self.seq += 1
        self._journal.append((self.seq, key, -1, -1))
        return g

    def _register_group(self, key: int) -> int:
        """Grow the index for a new group WITHOUT journaling (replay path)."""
        g = self.n_groups
        self.group_ptr = np.append(self.group_ptr, self.group_ptr[-1])
        self.group_ids[key] = g
        self._ensure_versions(g)
        return g

    def _ensure_versions(self, g: int) -> None:
        while len(self.versions) <= g:
            self.versions.append(0)

    def _sanitize_columns(
        self, new_cols: dict[str, np.ndarray], policy: str
    ) -> dict[str, np.ndarray]:
        """Police NaN/Inf at the ingest edge (they poison prefix power sums).

        ``reject`` raises naming the table, column and offending row within
        the append batch; ``clamp`` maps NaN to 0.0 (the store's neutral pad
        value) and ±Inf to the column's observed finite range.
        """
        if policy not in ("reject", "clamp"):
            raise ValueError(
                f"table {self.name or '<unnamed>'!r}: unknown sanitize "
                f"policy {policy!r} (expected 'reject' or 'clamp')"
            )
        for k, v in new_cols.items():
            if not np.issubdtype(v.dtype, np.floating):
                continue
            bad = ~np.isfinite(v)
            if not bad.any():
                continue
            if policy == "reject":
                i = int(np.flatnonzero(bad)[0])
                raise ValueError(
                    f"table {self.name or '<unnamed>'!r}: non-finite value "
                    f"{float(v[i])!r} in append column {k!r} at batch row "
                    f"{i} (sanitize='reject'; pass sanitize='clamp' to "
                    f"coerce)"
                )
            old = self.columns[k]
            pool = np.concatenate([old[np.isfinite(old)], v[~bad]])
            hi = float(pool.max()) if pool.size else 0.0
            lo = float(pool.min()) if pool.size else 0.0
            w = v.copy()
            w[np.isnan(v)] = 0.0
            w[v == np.inf] = hi
            w[v == -np.inf] = lo
            new_cols[k] = w
        return new_cols

    def append(
        self,
        rows: Mapping[str, np.ndarray],
        group_key,
        *,
        sanitize: str = "reject",
    ) -> None:
        """Append rows, drawing each one's SRS position from the seeded RNG.

        ``rows`` maps every existing column name to a (r,) array;
        ``group_key`` gives each row's group (unknown keys register new
        groups).  Row i lands at position ``j ~ Uniform{0..m}`` inside its
        group's permuted prefix (m = the group's size before the insertion)
        — the sequential construction of a uniform random permutation, so
        every prefix stays a simple random sample after every append.

        Each insertion bumps the group's version and is logged (bounded at
        ``MAX_APPEND_LOG`` per group) so device-resident caches can
        delta-update instead of rebuilding — and journaled (unbounded,
        sequence-stamped) so :meth:`recover` can rebuild the index state.
        """
        group_key = np.atleast_1d(np.asarray(group_key))
        r = group_key.shape[0]
        missing = sorted(set(self.columns) - set(rows))
        extra = sorted(set(rows) - set(self.columns))
        if missing or extra:
            raise ValueError(
                f"table {self.name or '<unnamed>'!r}: append columns must "
                f"match the table (missing {missing}, unexpected {extra})"
            )
        new_cols = {
            k: np.atleast_1d(np.asarray(v)).astype(self.columns[k].dtype)
            for k, v in rows.items()
        }
        for k, v in new_cols.items():
            if v.shape[0] != r:
                raise ValueError(
                    f"table {self.name or '<unnamed>'!r}: column {k!r} has "
                    f"{v.shape[0]} rows, group_key has {r}"
                )
        new_cols = self._sanitize_columns(new_cols, sanitize)
        base = self.n_rows
        for k in self.columns:
            self.columns[k] = np.concatenate([self.columns[k], new_cols[k]])
        for i in range(r):
            key = int(group_key[i])
            g = self.add_group(key)
            row_id = base + i
            start = int(self.group_ptr[g])
            m = int(self.group_ptr[g + 1]) - start
            j = int(self.rng.integers(0, m + 1))
            self.perm = np.insert(self.perm, start + j, row_id)
            self.group_ptr[g + 1 :] += 1
            self._ensure_versions(g)
            self.versions[g] += 1
            log = self._log.setdefault(g, [])
            log.append((self.versions[g], j, row_id))
            del log[:-MAX_APPEND_LOG]
            self.seq += 1
            self._journal.append((self.seq, key, j, row_id))

    def events_since(
        self, gid: int, version: int
    ) -> list[tuple[int, int]] | None:
        """The ``(j, row_id)`` insertions after ``version``, oldest first.

        Returns ``None`` when the bounded log no longer reaches back to
        ``version`` (or the group predates version tracking) — the caller
        must fall back to a full rebuild.
        """
        g = self._group_index(gid)
        current = self.versions[g] if g < len(self.versions) else 0
        if version == current:
            return []
        log = self._log.get(g, [])
        if not log or log[0][0] > version + 1:
            return None
        return [(j, row_id) for (v, j, row_id) in log if v > version]

    # --- crash recovery ----------------------------------------------------
    def recover(self, caches: tuple = ()) -> dict[str, int]:
        """Rebuild the derived index state by replaying the append journal.

        The raw column arrays are the durable record; ``perm`` /
        ``group_ptr`` / ``group_ids`` / ``versions`` / the bounded log are
        all derived, and a crash mid-append (or a corrupted buffer) can
        leave any of them torn.  Replaying the journal over the build-time
        base state rebuilds them byte-identical to the never-crashed table:
        each entry carries the ORIGINAL drawn prefix position ``j``, so no
        randomness is re-drawn and the SRS trajectory is reproduced exactly.

        ``caches`` are :class:`~repro.serving.feature_cache.FeatureCache`
        instances to revalidate afterwards — entries whose stored version or
        checksum no longer match the rebuilt store are dropped rather than
        served.  Returns counters: events replayed, groups rebuilt, cache
        entries dropped.
        """
        seqs = [e[0] for e in self._journal]
        if seqs and seqs != list(range(seqs[0], seqs[0] + len(seqs))):
            raise ValueError(
                f"table {self.name or '<unnamed>'!r}: append journal is not "
                f"a gap-free monotone sequence — cannot recover"
            )
        perm = self._base_perm.copy()
        ptr = self._base_ptr.copy()
        gids = dict(self._base_gids)
        versions = list(self._base_versions)
        log: dict[int, list[tuple[int, int, int]]] = {}
        for (_seq, key, j, row_id) in self._journal:
            if j < 0:
                if key not in gids:
                    gids[key] = len(ptr) - 1
                    ptr = np.append(ptr, ptr[-1])
                    while len(versions) < len(ptr) - 1:
                        versions.append(0)
                continue
            g = gids[key]
            start = int(ptr[g])
            perm = np.insert(perm, start + j, row_id)
            ptr[g + 1 :] += 1
            while len(versions) <= g:
                versions.append(0)
            versions[g] += 1
            glog = log.setdefault(g, [])
            glog.append((versions[g], j, row_id))
            del glog[:-MAX_APPEND_LOG]
        self.perm = perm
        self.group_ptr = ptr
        self.group_ids = gids
        self.versions = versions
        self._log = log
        dropped = sum(int(c.revalidate()) for c in caches)
        return {
            "replayed": len(self._journal),
            "groups": len(gids),
            "cache_entries_dropped": dropped,
        }


def build_table(
    columns: Mapping[str, np.ndarray],
    group_key: np.ndarray,
    seed: int = 0,
) -> Table:
    """Index ``columns`` by ``group_key`` and fix the per-group sample order."""
    group_key = np.asarray(group_key)
    uniq, inverse = np.unique(group_key, return_inverse=True)
    order = np.argsort(inverse, kind="stable")
    counts = np.bincount(inverse, minlength=len(uniq))
    ptr = np.zeros(len(uniq) + 1, np.int64)
    np.cumsum(counts, out=ptr[1:])
    rng = np.random.default_rng(seed)
    perm = order.copy()
    for g in range(len(uniq)):
        s, e = ptr[g], ptr[g + 1]
        perm[s:e] = rng.permutation(perm[s:e])
    cols = {k: np.asarray(v) for k, v in columns.items()}
    gids = {int(k): i for i, k in enumerate(uniq)}
    return Table(
        columns=cols, group_ptr=ptr, perm=perm, group_ids=gids,
        rng=rng, versions=[0] * len(uniq),
    )


@dataclass
class ColumnStore:
    """A named collection of tables — the serving datastore."""

    tables: dict[str, Table] = field(default_factory=dict)

    def add(self, name: str, table: Table) -> "ColumnStore":
        table.name = table.name or name
        self.tables[name] = table
        return self

    def __getitem__(self, name: str) -> Table:
        return self.tables[name]

    # --- fused-executor support -------------------------------------------
    def request_buffers(
        self,
        specs: list[tuple[str, str, int]],
        cap: int,
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Gather (k, cap) padded prefix buffers + (k,) group sizes.

        One host->device transfer per request; afterwards the whole
        iterate-until-guaranteed loop runs on device (FusedExecutor).
        ``specs`` is [(table, column, gid), ...] per aggregate feature.
        """
        bufs = np.stack(
            [self.tables[t].sample_prefix(c, g, cap) for (t, c, g) in specs]
        )
        sizes = np.array(
            [min(self.tables[t].group_size(g), cap) for (t, c, g) in specs],
            np.int32,
        )
        return jnp.asarray(bufs), jnp.asarray(sizes)

    def spec_versions(self, specs: list[tuple[str, str, int]]) -> tuple[int, ...]:
        """Per-spec group versions — the freshness half of a cache key."""
        return tuple(self.tables[t].version(g) for (t, _c, g) in specs)
