"""In-memory columnar datastore with group-indexed incremental sampling.

Plays the role ClickHouse plays in the paper (§4 System Setup): each *table*
holds row-aligned columns plus a **group index** (e.g. rows per user / per
trip region).  At build time rows are permuted once *within each group* with a
fixed seed, so that

    prefix of length z  ==  simple random sample of size z without replacement

and growing a plan from z to z' touches only rows [z, z') — the paper's
incremental online-aggregation property.  On a real TPU cluster the column
buffers live sharded in HBM and the gather below is the ``sampled_agg``
Pallas kernel's DMA; here they live in host memory / device 0.

The store is deliberately framework-agnostic (plain numpy in, jnp out) so the
serving runtime, the fused executor, and the benchmarks all share it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping

import jax.numpy as jnp
import numpy as np

__all__ = ["Table", "ColumnStore", "bucket_size"]


def bucket_size(z: int, minimum: int = 64) -> int:
    """Round a sample size up to the next power of two (bounds recompiles)."""
    cap = minimum
    while cap < z:
        cap *= 2
    return cap


@dataclass
class Table:
    """Row-aligned columns + CSR-style group index over a permutation."""

    columns: dict[str, np.ndarray]
    group_ptr: np.ndarray          # (G+1,) offsets into perm
    perm: np.ndarray               # (R,) row ids, permuted within each group
    group_ids: dict[int, int]      # external group key -> dense group index

    @property
    def n_rows(self) -> int:
        return int(self.perm.shape[0])

    @property
    def n_groups(self) -> int:
        return int(self.group_ptr.shape[0] - 1)

    def group_size(self, gid: int) -> int:
        g = self.group_ids[int(gid)]
        return int(self.group_ptr[g + 1] - self.group_ptr[g])

    def sample_prefix(self, column: str, gid: int, cap: int) -> np.ndarray:
        """First ``min(cap, N)`` permuted rows of the group, padded to cap.

        The prefix is the group's canonical SRS order; callers mask with the
        live ``z``.  Padding repeats 0.0 (masked out by estimators).
        """
        g = self.group_ids[int(gid)]
        start, stop = int(self.group_ptr[g]), int(self.group_ptr[g + 1])
        take = min(cap, stop - start)
        rows = self.perm[start : start + take]
        out = np.zeros((cap,), np.float32)
        out[:take] = self.columns[column][rows]
        return out

    def full_values(self, column: str, gid: int) -> np.ndarray:
        g = self.group_ids[int(gid)]
        start, stop = int(self.group_ptr[g]), int(self.group_ptr[g + 1])
        return self.columns[column][self.perm[start:stop]].astype(np.float32)

    def lookup(self, column: str, gid: int) -> float:
        """Point lookup (lightweight datastore op — computed exactly)."""
        g = self.group_ids[int(gid)]
        row = self.perm[int(self.group_ptr[g])]
        return float(self.columns[column][row])


def build_table(
    columns: Mapping[str, np.ndarray],
    group_key: np.ndarray,
    seed: int = 0,
) -> Table:
    """Index ``columns`` by ``group_key`` and fix the per-group sample order."""
    group_key = np.asarray(group_key)
    uniq, inverse = np.unique(group_key, return_inverse=True)
    order = np.argsort(inverse, kind="stable")
    counts = np.bincount(inverse, minlength=len(uniq))
    ptr = np.zeros(len(uniq) + 1, np.int64)
    np.cumsum(counts, out=ptr[1:])
    rng = np.random.default_rng(seed)
    perm = order.copy()
    for g in range(len(uniq)):
        s, e = ptr[g], ptr[g + 1]
        perm[s:e] = rng.permutation(perm[s:e])
    cols = {k: np.asarray(v) for k, v in columns.items()}
    gids = {int(k): i for i, k in enumerate(uniq)}
    return Table(columns=cols, group_ptr=ptr, perm=perm, group_ids=gids)


@dataclass
class ColumnStore:
    """A named collection of tables — the serving datastore."""

    tables: dict[str, Table] = field(default_factory=dict)

    def add(self, name: str, table: Table) -> "ColumnStore":
        self.tables[name] = table
        return self

    def __getitem__(self, name: str) -> Table:
        return self.tables[name]

    # --- fused-executor support -------------------------------------------
    def request_buffers(
        self,
        specs: list[tuple[str, str, int]],
        cap: int,
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Gather (k, cap) padded prefix buffers + (k,) group sizes.

        One host->device transfer per request; afterwards the whole
        iterate-until-guaranteed loop runs on device (FusedExecutor).
        ``specs`` is [(table, column, gid), ...] per aggregate feature.
        """
        bufs = np.stack(
            [self.tables[t].sample_prefix(c, g, cap) for (t, c, g) in specs]
        )
        sizes = np.array(
            [min(self.tables[t].group_size(g), cap) for (t, c, g) in specs],
            np.int32,
        )
        return jnp.asarray(bufs), jnp.asarray(sizes)
