"""Synthetic re-creations of the paper's seven inference pipelines (Table 1).

The original datasets (NYC-Taxi 3B rows, Forex 1.1B, ...) are not
redistributable and far exceed this container, so each pipeline gets a
*structurally matched* synthetic workload:

* identical model class (LGBM→GradientBoosting, XGB→GradientBoosting,
  RF→RandomForest, LR→LinearRegression, MLP→MLP),
* identical aggregate-feature count and operator mix (Table 1 AGG column),
* identical non-aggregate feature count,
* group-structured tables where each request selects one large row-group
  (the expensive online aggregation the paper targets),
* a held-out request log with true labels.

Generation model: every group g has latent factors L[g]; row-level columns
are noisy draws around per-group means driven by L; the label is a nonlinear
function of L plus request-level exact features.  The pipeline's models are
**trained in-repo** on exact aggregate features of training groups, and
``delta_default`` is set to the trained model's held-out MAE — exactly the
paper's §4 default (δ = MAE).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.core.pipeline import AggFeature, ExactFeature, Pipeline
from repro.data.store import ColumnStore, build_table
from repro.models.tabular import (
    GradientBoosting,
    LinearRegression,
    MLP,
    RandomForest,
)

__all__ = [
    "PipelineBundle",
    "make_pipeline",
    "make_pipeline_median",
    "PIPELINE_NAMES",
    "EXTRA_PIPELINE_NAMES",
    "poisson_arrivals",
]


def poisson_arrivals(
    requests: list[dict],
    rate_rps: float,
    n: int | None = None,
    seed: int = 0,
    start_t: float = 0.0,
) -> list[tuple[float, dict]]:
    """Timestamped Poisson arrival trace over a request log.

    Inter-arrival gaps are Exp(rate) — the M/*/1 open-loop workload the
    serving runtime replays (serving/runtime.py).  Requests are cycled from
    ``requests`` when ``n`` exceeds the log.  Returns ``[(t_seconds, req)]``
    sorted by time; deterministic in ``seed``.

    Degenerate inputs are pinned explicitly rather than left to numpy:
    ``rate_rps`` must be a positive finite number (zero, negative, and NaN
    all raise — NaN would silently satisfy neither branch of a ``<= 0``
    check), ``n < 0`` raises, and ``n == 0`` is a well-defined EMPTY trace
    (not whatever an empty ``cumsum`` happens to produce downstream).
    """
    if not (rate_rps > 0) or not np.isfinite(rate_rps):
        raise ValueError(f"rate_rps must be a positive finite number, got {rate_rps}")
    if n is not None and n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if not requests or n == 0:
        return []
    n = len(requests) if n is None else n
    rng = np.random.default_rng(seed)
    ts = start_t + np.cumsum(rng.exponential(1.0 / rate_rps, n))
    return [(float(t), requests[i % len(requests)]) for i, t in enumerate(ts)]

PIPELINE_NAMES = (
    "trip_fare",
    "tick_price",
    "battery",
    "turbofan",
    "bearing_imbalance",
    "fraud_detection",
    "student_qa",
)

# Beyond-Table-1 workloads (holistic-aggregate coverage, appendix D / Fig. 10).
EXTRA_PIPELINE_NAMES = ("sensor_health",)


@dataclass
class PipelineBundle:
    """Everything needed to serve + evaluate one pipeline."""

    pipeline: Pipeline
    store: ColumnStore
    requests: list[dict]
    labels: np.ndarray          # true held-out label per request
    table_rows: int
    name: str = ""
    meta: dict = field(default_factory=dict)


# --------------------------------------------------------------------------
# Spec-driven generator
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class _ColSpec:
    name: str
    kind: str = "normal"      # "normal" | "indicator"
    row_noise: float = 1.0    # stddev of row-level noise around the group mean


@dataclass(frozen=True)
class _PipeSpec:
    name: str
    table: str
    cols: tuple[_ColSpec, ...]
    # (op, column) or (op, column, q) — q only meaningful for "quantile"
    aggs: tuple[tuple, ...]
    exact_fields: tuple[str, ...]            # request-provided scalars
    model_kind: str                          # lgbm | xgb | rf | lr | mlp
    task: str                                # regression | classification
    # label_fn(agg_latents (G, k), exact (G, E), rng) -> (G,) float labels
    label_fn: Callable = None


def _norm_agg(entry: tuple) -> tuple[str, str, float]:
    """Normalize an agg spec entry to (op, column, q)."""
    if len(entry) == 2:
        return entry[0], entry[1], 0.5
    return entry


def _agg_latent(
    op: str,
    group_mean: np.ndarray,
    group_std: np.ndarray,
    n: int,
    row_noise: float,
    q: float = 0.5,
):
    """Population value of the aggregate, given group-level generative params.

    ``row_noise`` scales the realized row-level spread (rows are drawn as
    mean + noise*std*row_noise), so std/var latents must include it for the
    population feature to match what exact aggregation over rows computes.
    """
    if op == "avg" or op == "median":
        return group_mean  # rows are symmetric around the group mean
    if op == "quantile":
        from statistics import NormalDist

        return group_mean + group_std * row_noise * NormalDist().inv_cdf(q)
    if op == "sum":
        return group_mean * n
    if op == "count":
        return group_mean * n  # indicator column: count = N * rate
    if op == "std":
        return group_std * row_noise
    if op == "var":
        return (group_std * row_noise) ** 2
    raise ValueError(op)


def _make_model(kind: str, task: str, seed: int):
    if kind in ("lgbm", "xgb"):
        return GradientBoosting(
            n_trees=60, max_depth=5, task=task, seed=seed, learning_rate=0.15
        )
    if kind == "rf":
        return RandomForest(n_trees=40, max_depth=8, task=task, seed=seed)
    if kind == "lr":
        return (
            LinearRegression() if task == "regression" else None
        )
    if kind == "mlp":
        return MLP(hidden=(48, 24), task=task, epochs=25, seed=seed)
    raise ValueError(kind)


def _build_from_spec(
    spec: _PipeSpec,
    seed: int,
    rows_per_group: int,
    n_train_groups: int,
    n_serve_groups: int,
    n_requests: int,
) -> PipelineBundle:
    rng = np.random.default_rng(seed)
    G = n_train_groups + n_serve_groups
    k = len(spec.aggs)
    E = len(spec.exact_fields)
    cols = {c.name: c for c in spec.cols}

    # --- group-level generative parameters --------------------------------
    group_mean = {}
    group_std = {}
    for c in spec.cols:
        if c.kind == "indicator":
            group_mean[c.name] = rng.uniform(0.05, 0.6, G)
            group_std[c.name] = np.sqrt(
                group_mean[c.name] * (1 - group_mean[c.name])
            )
        else:
            group_mean[c.name] = rng.normal(0.0, 2.0, G)
            group_std[c.name] = rng.uniform(0.5, 3.0, G)

    # group sizes vary ±25% around rows_per_group
    sizes = rng.integers(
        max(int(rows_per_group * 0.75), 8), int(rows_per_group * 1.25) + 1, G
    )

    # --- population (exact) aggregate values per group ---------------------
    norm_aggs = tuple(_norm_agg(a) for a in spec.aggs)
    agg_pop = np.stack(
        [
            _agg_latent(
                op,
                group_mean[cname],
                group_std[cname],
                sizes,
                1.0 if cols[cname].kind == "indicator" else cols[cname].row_noise,
                q,
            )
            for (op, cname, q) in norm_aggs
        ],
        axis=1,
    )  # (G, k)

    # --- request-level exact features (shared generative law) --------------
    exact_all = rng.normal(0.0, 1.0, (G, E)) if E else np.zeros((G, 0))

    labels = spec.label_fn(agg_pop, exact_all, rng)  # (G,)

    # --- materialize rows only for SERVE groups (training uses population
    #     aggregates; serving aggregates over real rows) --------------------
    serve_slice = slice(n_train_groups, G)
    serve_sizes = sizes[serve_slice]
    total_rows = int(serve_sizes.sum())
    gid_rows = np.repeat(np.arange(n_serve_groups), serve_sizes)
    data_cols = {}
    for c in spec.cols:
        mu = group_mean[c.name][serve_slice][gid_rows]
        sd = group_std[c.name][serve_slice][gid_rows]
        if c.kind == "indicator":
            data_cols[c.name] = (rng.random(total_rows) < mu).astype(np.float32)
        else:
            data_cols[c.name] = (mu + rng.normal(0, 1, total_rows) * sd * c.row_noise).astype(
                np.float32
            )
    table = build_table(data_cols, gid_rows, seed=seed + 1)
    store = ColumnStore().add(spec.table, table)

    # --- exact aggregates of serve groups, for faithful model features -----
    # (the model is trained on features distributed like the *served* ones)
    serve_exact_aggs = np.zeros((n_serve_groups, k), np.float32)
    for j, (op, cname, q) in enumerate(norm_aggs):
        for g in range(n_serve_groups):
            vals = table.full_values(cname, g)
            if op in ("avg",):
                serve_exact_aggs[g, j] = vals.mean()
            elif op == "median":
                serve_exact_aggs[g, j] = np.median(vals)
            elif op == "quantile":
                serve_exact_aggs[g, j] = np.quantile(vals, q)
            elif op == "sum":
                serve_exact_aggs[g, j] = vals.sum()
            elif op == "count":
                serve_exact_aggs[g, j] = vals.sum()  # indicator col
            elif op == "std":
                serve_exact_aggs[g, j] = vals.std(ddof=1)
            elif op == "var":
                serve_exact_aggs[g, j] = vals.var(ddof=1)

    # --- train the model ----------------------------------------------------
    X_train = np.concatenate(
        [agg_pop[:n_train_groups], exact_all[:n_train_groups]], axis=1
    ).astype(np.float32)
    y_train = labels[:n_train_groups].astype(np.float32)
    scaler_mean = X_train.mean(0)
    scaler_scale = np.maximum(X_train.std(0), 1e-6)
    Xs = (X_train - scaler_mean) / scaler_scale

    model = _make_model(spec.model_kind, spec.task, seed)
    if model is None:  # LR classification fallback (unused by the 7 pipelines)
        raise ValueError("invalid model/task combo")
    model.fit(Xs, y_train)

    # --- held-out MAE -> paper-default delta --------------------------------
    import jax.numpy as jnp

    X_serve = np.concatenate([serve_exact_aggs, exact_all[serve_slice]], axis=1)
    Xs_serve = ((X_serve - scaler_mean) / scaler_scale).astype(np.float32)
    pred_serve = np.asarray(model.predict(jnp.asarray(Xs_serve))).astype(np.float64)
    y_serve = labels[serve_slice]
    if spec.task == "regression":
        delta = float(np.mean(np.abs(pred_serve - y_serve)))
    else:
        delta = 0.0

    # --- pipeline object ----------------------------------------------------
    agg_features = [
        AggFeature(
            name=f"{op}{int(q * 100) if op == 'quantile' else ''}_{cname}",
            table=spec.table,
            column=cname,
            agg=op,
            group_field="gid",
            quantile=q,
        )
        for (op, cname, q) in norm_aggs
    ]
    exact_features = [
        ExactFeature(name=f, kind="request", request_field=f) for f in spec.exact_fields
    ]
    pipeline = Pipeline(
        name=spec.name,
        agg_features=agg_features,
        exact_features=exact_features,
        model=model,
        task=spec.task,
        n_classes=2 if spec.task == "classification" else 0,
        scaler_mean=scaler_mean.astype(np.float32),
        scaler_scale=scaler_scale.astype(np.float32),
        delta_default=delta,
    )

    # --- request log --------------------------------------------------------
    req_groups = rng.integers(0, n_serve_groups, n_requests)
    requests = []
    for i, g in enumerate(req_groups):
        req = {"gid": int(g)}
        for e_idx, fname in enumerate(spec.exact_fields):
            req[fname] = float(exact_all[n_train_groups + g, e_idx])
        requests.append(req)
    req_labels = labels[serve_slice][req_groups]

    return PipelineBundle(
        pipeline=pipeline,
        store=store,
        requests=requests,
        labels=req_labels,
        table_rows=total_rows,
        name=spec.name,
        meta={
            "model": spec.model_kind,
            "task": spec.task,
            "k": k,
            "delta": delta,
            "exact_serve_pred": pred_serve,
            "request_groups": req_groups,
        },
    )


# --------------------------------------------------------------------------
# The seven pipeline specs (Table 1)
# --------------------------------------------------------------------------
def _spec_trip_fare():
    # LGBM regression; 3 AGG (COUNT + 2 AVG from trip history), 5 non-AGG.
    def label(agg, ex, rng):
        cnt, avg_d, avg_t = agg[:, 0], agg[:, 1], agg[:, 2]
        hour, dist, pax, wknd, surge = ex.T
        return (
            2.5
            + 1.9 * np.abs(dist)
            + 0.45 * avg_d
            + 0.0015 * cnt
            + 1.1 * avg_t
            + 0.8 * np.sin(hour)
            + 0.5 * wknd * np.abs(dist)
            + 0.3 * surge**2
            + rng.normal(0, 0.25, len(cnt))
        )

    return _PipeSpec(
        name="trip_fare",
        table="trips",
        cols=(
            _ColSpec("is_long", "indicator"),
            _ColSpec("distance"),
            _ColSpec("tip"),
        ),
        aggs=(("count", "is_long"), ("avg", "distance"), ("avg", "tip")),
        exact_fields=("hour", "req_distance", "passengers", "weekend", "surge"),
        model_kind="lgbm",
        task="regression",
        label_fn=label,
    )


def _spec_tick_price():
    # LR regression; 1 AGG (AVG price over tick window), 6 non-AGG.
    def label(agg, ex, rng):
        avg_p = agg[:, 0]
        bid, ask, spread, vol, hour, lag = ex.T
        return (
            0.72 * avg_p
            + 0.18 * lag
            + 0.06 * (bid + ask)
            - 0.04 * spread
            + 0.02 * vol
            + rng.normal(0, 0.05, len(avg_p))
        )

    return _PipeSpec(
        name="tick_price",
        table="ticks",
        # ticks within a window cluster tightly around the window mean —
        # low row-level spread, like real sub-second FX tick streams
        cols=(_ColSpec("price", row_noise=0.12),),
        aggs=(("avg", "price"),),
        exact_fields=("bid", "ask", "spread", "vol", "hour", "lag_price"),
        model_kind="lr",
        task="regression",
        label_fn=label,
    )


def _spec_battery():
    # LGBM regression; 10 AGG (avg+std of 5 measurement channels), 1 non-AGG.
    def label(agg, ex, rng):
        a = agg
        cyc = ex[:, 0]
        return (
            40.0
            - 3.0 * a[:, 0]                    # avg voltage
            + 1.5 * a[:, 1]                    # std voltage
            - 1.2 * a[:, 2] * np.tanh(a[:, 4]) # current x temp interaction
            + 0.8 * a[:, 6]
            - 0.5 * a[:, 8] ** 2 * 0.1
            - 2.0 * np.tanh(cyc)
            + rng.normal(0, 0.4, len(cyc))
        )

    cols = tuple(
        _ColSpec(c) for c in ("voltage", "current", "temp", "capacity", "resistance")
    )
    aggs = tuple(
        (op, c.name) for c in cols for op in ("avg", "std")
    )
    return _PipeSpec(
        name="battery",
        table="cycles",
        cols=cols,
        aggs=aggs,
        exact_fields=("cycle_idx",),
        model_kind="lgbm",
        task="regression",
        label_fn=label,
    )


def _spec_turbofan():
    # RF regression; 9 AGG over sensor channels, 0 non-AGG.
    def label(agg, ex, rng):
        a = agg
        rul = (
            120.0
            - 6.0 * a[:, 0]
            - 3.0 * np.tanh(a[:, 1]) * a[:, 2]
            - 2.0 * a[:, 3]
            + 1.0 * a[:, 4]
            - 0.8 * a[:, 5] * 0.2
            - 0.02 * np.abs(a[:, 6])
            + 5e-4 * a[:, 7]   # SUM feature scales with N; keep its share O(1)
            - 0.3 * a[:, 8] * 0.1
        )
        return rul + rng.normal(0, 1.0, len(rul))

    cols = tuple(_ColSpec(f"s{i}") for i in range(1, 7))
    aggs = (
        ("avg", "s1"),
        ("avg", "s2"),
        ("avg", "s3"),
        ("avg", "s4"),
        ("std", "s1"),
        ("std", "s2"),
        ("std", "s3"),
        ("sum", "s5"),
        ("avg", "s6"),
    )
    return _PipeSpec(
        name="turbofan",
        table="sensors",
        cols=cols,
        aggs=aggs,
        exact_fields=(),
        model_kind="rf",
        task="regression",
        label_fn=label,
    )


def _spec_bearing():
    # MLP binary classification; 8 AGG (vibration channel stats), 0 non-AGG.
    def label(agg, ex, rng):
        a = agg
        score = (
            1.4 * a[:, 1]          # std x
            + 1.2 * a[:, 3]        # std y
            + 0.9 * a[:, 5]        # std z
            + 0.4 * a[:, 0] * a[:, 2]
            + 0.25 * a[:, 6]
            - 0.2 * np.abs(a[:, 4])
        )
        thr = np.median(score)
        return (score + rng.normal(0, 0.25, len(score)) > thr).astype(np.float64)

    cols = (_ColSpec("vx"), _ColSpec("vy"), _ColSpec("vz"))
    aggs = (
        ("avg", "vx"),
        ("std", "vx"),
        ("avg", "vy"),
        ("std", "vy"),
        ("avg", "vz"),
        ("std", "vz"),
        ("var", "vx"),
        ("var", "vy"),
    )
    return _PipeSpec(
        name="bearing_imbalance",
        table="vibration",
        cols=cols,
        aggs=aggs,
        exact_fields=(),
        model_kind="mlp",
        task="classification",
        label_fn=label,
    )


def _spec_fraud():
    # XGB binary classification; 3 AGG (click counts), 6 non-AGG.
    def label(agg, ex, rng):
        # higher click / repeat / burst counts => more likely fraud
        c1, c2, c3 = agg[:, 0], agg[:, 1], agg[:, 2]
        app, dev, os_, chan, hour, gap = ex.T
        score = (
            0.004 * c1
            + 0.006 * c2
            + 0.003 * c3
            + 0.5 * np.tanh(app)
            - 0.4 * np.abs(gap)
            + 0.3 * chan
        )
        thr = np.quantile(score, 0.7)
        return (score + rng.normal(0, 0.3, len(score)) > thr).astype(np.float64)

    cols = (
        _ColSpec("is_click", "indicator"),
        _ColSpec("is_repeat", "indicator"),
        _ColSpec("is_burst", "indicator"),
    )
    return _PipeSpec(
        name="fraud_detection",
        table="clicks",
        cols=cols,
        aggs=(("count", "is_click"), ("count", "is_repeat"), ("count", "is_burst")),
        exact_fields=("app", "device", "os", "channel", "hour", "click_gap"),
        model_kind="xgb",
        task="classification",
        label_fn=label,
    )


def _spec_student_qa():
    # RF binary classification; 21 AGG over game-log channels, 0 non-AGG.
    def label(agg, ex, rng):
        a = agg
        score = (
            0.8 * a[:, 0]
            + 0.6 * a[:, 1]
            - 0.5 * a[:, 2]
            + 0.4 * np.tanh(a[:, 3])
            + 0.3 * a[:, 4] * np.sign(a[:, 5])
            + 0.002 * a[:, 16]
            + 0.15 * a[:, 8]
            - 0.1 * a[:, 12]
        )
        thr = np.median(score)
        return (score + rng.normal(0, 0.35, len(score)) > thr).astype(np.float64)

    # 8 AVG (the appendix-D MEDIAN substitution targets these), 4 STD,
    # 3 COUNT, 2 SUM, 4 VAR  => 21 aggregate features over 11 columns.
    cols = tuple(_ColSpec(f"c{i}") for i in range(1, 9)) + (
        _ColSpec("f1", "indicator"),
        _ColSpec("f2", "indicator"),
        _ColSpec("f3", "indicator"),
    )
    aggs = (
        tuple(("avg", f"c{i}") for i in range(1, 9))
        + tuple(("std", f"c{i}") for i in range(1, 5))
        + (("count", "f1"), ("count", "f2"), ("count", "f3"))
        + (("sum", "c5"), ("sum", "c6"))
        + tuple(("var", f"c{i}") for i in range(5, 9))
    )
    return _PipeSpec(
        name="student_qa",
        table="gamelog",
        cols=cols,
        aggs=aggs,
        exact_fields=(),
        model_kind="rf",
        task="classification",
        label_fn=label,
    )


def _spec_sensor_health():
    # Holistic-featured workload (beyond Table 1): robust location/tail
    # statistics over noisy sensor channels — MEDIAN + tail QUANTILE next to
    # parametric AVG/STD, the operator mix appendix D covers.  LGBM
    # regression; 5 AGG, 1 non-AGG.
    def label(agg, ex, rng):
        med_t, p90_v, avg_p, std_t, med_v = agg.T
        age = ex[:, 0]
        health = (
            50.0
            - 2.2 * med_t
            - 1.4 * p90_v
            + 0.9 * avg_p
            - 1.1 * std_t * np.abs(med_v)
            - 1.5 * np.tanh(age)
        )
        return health + rng.normal(0, 0.4, len(med_t))

    cols = (
        _ColSpec("temp", row_noise=1.4),
        _ColSpec("vib"),
        _ColSpec("pressure", row_noise=0.6),
    )
    aggs = (
        ("median", "temp"),
        ("quantile", "vib", 0.9),
        ("avg", "pressure"),
        ("std", "temp"),
        ("median", "vib"),
    )
    return _PipeSpec(
        name="sensor_health",
        table="telemetry",
        cols=cols,
        aggs=aggs,
        exact_fields=("age",),
        model_kind="lgbm",
        task="regression",
        label_fn=label,
    )


_SPECS = {
    "trip_fare": _spec_trip_fare,
    "tick_price": _spec_tick_price,
    "battery": _spec_battery,
    "turbofan": _spec_turbofan,
    "bearing_imbalance": _spec_bearing,
    "fraud_detection": _spec_fraud,
    "student_qa": _spec_student_qa,
    "sensor_health": _spec_sensor_health,
}


def make_pipeline(
    name: str,
    seed: int = 0,
    rows_per_group: int = 20000,
    n_train_groups: int = 400,
    n_serve_groups: int = 24,
    n_requests: int = 64,
) -> PipelineBundle:
    """Build one of the seven paper pipelines at the requested scale.

    ``rows_per_group`` controls how expensive the exact aggregation is —
    benchmarks use 20k-50k (seconds-scale exact latency, mirroring the
    paper's >1s baselines), tests use ~500.
    """
    if name not in _SPECS:
        raise KeyError(
            f"unknown pipeline {name!r}; choose from "
            f"{PIPELINE_NAMES + EXTRA_PIPELINE_NAMES}"
        )
    spec = _SPECS[name]()
    # substitute aggregate operators if requested via name suffix elsewhere
    return _build_from_spec(
        spec,
        seed=seed,
        rows_per_group=rows_per_group,
        n_train_groups=n_train_groups,
        n_serve_groups=n_serve_groups,
        n_requests=n_requests,
    )


def make_pipeline_median(
    name: str,
    seed: int = 0,
    rows_per_group: int = 20000,
    n_train_groups: int = 400,
    n_serve_groups: int = 24,
    n_requests: int = 64,
) -> PipelineBundle:
    """Appendix D: the pipeline with AVG→MEDIAN substitution (COUNT→MEDIAN
    for fraud_detection), retrained — mirrors the paper's §D methodology."""
    spec = _SPECS[name]()
    aggs = tuple(_norm_agg(a) for a in spec.aggs)
    target = "avg" if any(op == "avg" for op, _, _ in aggs) else "count"
    new_aggs = tuple(
        ("median", c) if op == target else (op, c, q) for (op, c, q) in aggs
    )
    spec = _PipeSpec(
        name=f"{name}_median",
        table=spec.table,
        cols=spec.cols,
        aggs=new_aggs,
        exact_fields=spec.exact_fields,
        model_kind=spec.model_kind,
        task=spec.task,
        label_fn=spec.label_fn,
    )
    return _build_from_spec(
        spec,
        seed=seed,
        rows_per_group=rows_per_group,
        n_train_groups=n_train_groups,
        n_serve_groups=n_serve_groups,
        n_requests=n_requests,
    )
