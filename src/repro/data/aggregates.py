"""Online-aggregation estimators with uncertainty (paper §3.2, AFC).

Every estimator consumes a fixed-capacity *prefix-masked* sample buffer:
``vals`` has shape (cap,), the first ``z`` entries are a simple random sample
(without replacement — the datastore pre-permutes rows within each group, so
a prefix IS an SRS, and growing the plan is just widening the prefix: the
paper's incremental-sampling property, §3.2).

Parametric aggregates (SUM / COUNT / AVG / VAR / STD) get Normal(0, σ) error
distributions via CLT with the finite-population correction (sampling without
replacement from a group of N rows).  Holistic aggregates (MEDIAN / QUANTILE)
get empirical-bootstrap replicate tables (paper appendix D).

Everything here is pure jnp with static shapes — usable from the host-loop
executor (with bucketed caps), the fused ``lax.while_loop`` executor, and the
Pallas ``sampled_agg`` kernel's reference oracle.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "AggResult",
    "estimate",
    "exact_value",
    "PARAMETRIC_AGGS",
    "HOLISTIC_AGGS",
    "AGG_IDS",
    "AGG_IDS_FULL",
    "HOLISTIC_ID_MIN",
    "masked_estimates_batch",
    "estimates_from_power_sums",
]

PARAMETRIC_AGGS = ("sum", "count", "avg", "var", "std")
HOLISTIC_AGGS = ("median", "quantile")


class AggResult(NamedTuple):
    value: jnp.ndarray        # () point estimate (already scaled by κ)
    sigma: jnp.ndarray        # () Normal error stddev (0 for holistic/exact)
    replicates: jnp.ndarray   # (B,) sorted bootstrap replicates (value-filled if parametric)
    is_empirical: jnp.ndarray  # () bool


def _masked_moments(vals: jnp.ndarray, z: jnp.ndarray):
    cap = vals.shape[0]
    mask = (jnp.arange(cap) < z).astype(jnp.float32)
    zf = jnp.maximum(z.astype(jnp.float32), 1.0)
    mean = jnp.sum(vals * mask) / zf
    d = (vals - mean) * mask
    m2 = jnp.sum(d**2) / zf                      # biased second moment
    m4 = jnp.sum(d**4) / zf
    s2 = m2 * zf / jnp.maximum(zf - 1.0, 1.0)    # unbiased sample variance
    return mean, s2, m2, m4, zf


def _fpc(z: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """Finite-population correction for SRS without replacement."""
    nf = n.astype(jnp.float32)
    zf = z.astype(jnp.float32)
    return jnp.sqrt(
        jnp.clip((nf - zf) / jnp.maximum(nf - 1.0, 1.0), 0.0, 1.0)
    )


def _masked_quantile(vals: jnp.ndarray, z: jnp.ndarray, q: float) -> jnp.ndarray:
    """Quantile of the valid prefix: sort with +inf padding, nearest-rank.

    An empty prefix (``z == 0``) returns 0.0 — the same empty-prefix
    convention as the parametric mean — instead of gathering the +inf
    padding at rank 0.
    """
    cap = vals.shape[0]
    padded = jnp.where(jnp.arange(cap) < z, vals, jnp.inf)
    s = jnp.sort(padded)
    rank = jnp.clip(
        jnp.floor(q * (z.astype(jnp.float32) - 1.0) + 0.5).astype(jnp.int32),
        0,
        jnp.maximum(z - 1, 0),
    )
    return jnp.where(z > 0, s[rank], 0.0)


def _bootstrap_replicates(
    vals: jnp.ndarray, z: jnp.ndarray, q: float, key: jax.Array, n_boot: int
) -> jnp.ndarray:
    """(B,) sorted bootstrap replicate quantiles (resample-with-replacement)."""
    cap = vals.shape[0]
    u = jax.random.uniform(key, (n_boot, cap))
    idx = jnp.floor(u * z.astype(jnp.float32)).astype(jnp.int32)  # uniform over prefix
    res = vals[idx]  # (B, cap); only first-z columns meaningful via mask below
    reps = jax.vmap(lambda r: _masked_quantile(r, z, q))(res)
    return jnp.sort(reps)


@functools.partial(jax.jit, static_argnames=("agg", "n_boot", "quantile"))
def estimate(
    agg: str,
    vals: jnp.ndarray,
    z: jnp.ndarray,
    n: jnp.ndarray,
    key: jax.Array,
    *,
    n_boot: int = 256,
    quantile: float = 0.5,
) -> AggResult:
    """Estimate aggregate ``agg`` of the whole group from a z-prefix sample.

    vals: (cap,) buffer; z: () int32 valid prefix; n: () int32 group size.
    When ``z >= n`` the result is exact (σ=0, degenerate replicates) — the
    worst-case fallback the paper guarantees termination with.
    """
    z = jnp.minimum(z.astype(jnp.int32), n.astype(jnp.int32))
    mean, s2, m2, m4, zf = _masked_moments(vals, z)
    nf = n.astype(jnp.float32)
    fpc = _fpc(z, n)
    se_mean = jnp.sqrt(jnp.maximum(s2, 0.0) / zf) * fpc

    if agg == "avg":
        value, sigma = mean, se_mean
    elif agg == "sum":
        value, sigma = nf * mean, nf * se_mean
    elif agg == "count":
        # vals is a 0/1 predicate column; COUNT = N * p̂.
        value, sigma = nf * mean, nf * se_mean
    elif agg == "var":
        # Asymptotic variance of the sample variance (normal-ish data):
        # Var(s²) ≈ (m4 − m2²·(z−3)/(z−1)) / z.
        value = s2
        var_s2 = jnp.maximum(
            (m4 - m2**2 * (zf - 3.0) / jnp.maximum(zf - 1.0, 1.0)) / zf, 0.0
        )
        sigma = jnp.sqrt(var_s2) * fpc
    elif agg == "std":
        value = jnp.sqrt(jnp.maximum(s2, 0.0))
        var_s2 = jnp.maximum(
            (m4 - m2**2 * (zf - 3.0) / jnp.maximum(zf - 1.0, 1.0)) / zf, 0.0
        )
        # Delta method: Var(s) ≈ Var(s²) / (4 s²).
        sigma = jnp.sqrt(var_s2 / jnp.maximum(4.0 * s2, 1e-12)) * fpc
    elif agg in ("median", "quantile"):
        q = 0.5 if agg == "median" else quantile
        value = _masked_quantile(vals, z, q)
        reps = _bootstrap_replicates(vals, z, q, key, n_boot)
        exact = z >= n
        reps = jnp.where(exact, jnp.full_like(reps, value), reps)
        return AggResult(
            value=value.astype(jnp.float32),
            sigma=jnp.zeros((), jnp.float32),
            replicates=reps.astype(jnp.float32),
            # degenerate replicates when exact => sampling returns the exact
            # value, so keeping the empirical flag set is correct and jittable.
            is_empirical=jnp.asarray(True),
        )
    else:  # pragma: no cover - config error
        raise ValueError(f"unsupported aggregate {agg!r}")

    sigma = jnp.where(z >= n, 0.0, sigma)
    return AggResult(
        value=value.astype(jnp.float32),
        sigma=sigma.astype(jnp.float32),
        replicates=jnp.full((n_boot,), value, jnp.float32),
        is_empirical=jnp.asarray(False),
    )


def exact_value(
    agg: str, vals: jnp.ndarray, n: jnp.ndarray, *, quantile: float = 0.5
) -> jnp.ndarray:
    """Exact aggregate over the full group (baseline path)."""
    res = estimate(
        agg,
        vals,
        jnp.asarray(n, jnp.int32),
        jnp.asarray(n, jnp.int32),
        jax.random.PRNGKey(0),
        n_boot=8,
        quantile=quantile,
    )
    return res.value


# --------------------------------------------------------------------------
# Batched parametric estimation (one fused call for k features)
# --------------------------------------------------------------------------
AGG_IDS = {"avg": 0, "sum": 1, "count": 2, "var": 3, "std": 4}

# Full operator id space, including the holistic (empirical-bootstrap)
# aggregates the fused executor now serves.  Ids >= HOLISTIC_ID_MIN fall
# through the parametric ``jnp.select`` below (value/sigma 0) and are
# overwritten by the quantile/bootstrap path (kernels/sampled_agg/ops.py).
HOLISTIC_ID_MIN = 5
AGG_IDS_FULL = {**AGG_IDS, "median": 5, "quantile": 6}


def _select_value_sigma(mean, m2, m4, zf, z, n, agg_ids):
    """Shared tail of the batched parametric estimators.

    Inputs are per-feature centered moments (biased m2/m4 over zf samples);
    applies the unbiasing, FPC, delta-method σ's and the AGG_IDS select.
    """
    nf = n.astype(jnp.float32)
    s2 = m2 * zf / jnp.maximum(zf - 1.0, 1.0)
    fpc = jnp.sqrt(jnp.clip((nf - zf) / jnp.maximum(nf - 1.0, 1.0), 0.0, 1.0))
    se_mean = jnp.sqrt(jnp.maximum(s2, 0.0) / zf) * fpc
    var_s2 = jnp.maximum(
        (m4 - m2**2 * (zf - 3.0) / jnp.maximum(zf - 1.0, 1.0)) / zf, 0.0
    )
    sigma_var = jnp.sqrt(var_s2) * fpc
    sigma_std = jnp.sqrt(var_s2 / jnp.maximum(4.0 * s2, 1e-12)) * fpc
    std = jnp.sqrt(jnp.maximum(s2, 0.0))
    value = jnp.select(
        [agg_ids == 0, agg_ids == 1, agg_ids == 2, agg_ids == 3, agg_ids == 4],
        [mean, nf * mean, nf * mean, s2, std],
    )
    sigma = jnp.select(
        [agg_ids == 0, agg_ids == 1, agg_ids == 2, agg_ids == 3, agg_ids == 4],
        [se_mean, nf * se_mean, nf * se_mean, sigma_var, sigma_std],
    )
    sigma = jnp.where(z >= n, 0.0, sigma)
    return value, sigma


@jax.jit
def masked_estimates_batch(vals, z, n, agg_ids):
    """Vectorized parametric estimators over (k, cap) prefix-masked buffers.

    agg_ids: (k,) int32 per AGG_IDS.  Returns (value, sigma) each (k,).
    One XLA call replaces k per-feature ``estimate`` dispatches — the AFC
    batching optimization recorded in EXPERIMENTS.md §Perf (serving).
    """
    k, cap = vals.shape
    f32 = jnp.float32
    mask = (jnp.arange(cap)[None, :] < z[:, None]).astype(f32)
    zf = jnp.maximum(z.astype(f32), 1.0)
    mean = jnp.sum(vals * mask, axis=1) / zf
    d = (vals - mean[:, None]) * mask
    m2 = jnp.sum(d**2, axis=1) / zf
    m4 = jnp.sum(d**4, axis=1) / zf
    return _select_value_sigma(mean, m2, m4, zf, z, n, agg_ids)


@jax.jit
def estimates_from_power_sums(moments, z, n, agg_ids, shift=None):
    """(value, sigma) from the sampled_agg kernel's power sums.

    moments: (k, 5) [count, Σu, Σu², Σu³, Σu⁴] with ``u = v - shift`` over
    the z-prefix (the Pallas ``sampled_moments`` kernel's output, or its ref
    oracle; shift=None means the sums are of the raw values).  Centered
    moments are shift-invariant, so they are recovered about the shifted
    mean — accumulating about a shift near the data keeps the 4th-moment
    cancellation at O(std⁴) instead of O(mean⁴).  Then applies the same
    FPC/delta-method tail as :func:`masked_estimates_batch`, so the kernel
    path and the jnp path are numerically interchangeable up to float32
    rounding.
    """
    zf = jnp.maximum(moments[:, 0], 1.0)
    r1 = moments[:, 1] / zf               # E[u^p] over the prefix
    r2 = moments[:, 2] / zf
    r3 = moments[:, 3] / zf
    r4 = moments[:, 4] / zf
    m2 = jnp.maximum(r2 - r1**2, 0.0)
    m4 = jnp.maximum(
        r4 - 4.0 * r1 * r3 + 6.0 * r1**2 * r2 - 3.0 * r1**4, 0.0
    )
    # A single sample has zero centered moments by definition, but the
    # raw-minus-centered arithmetic leaves a float32 residual that σ would
    # amplify (SUM multiplies se_mean by N) — zero it exactly.
    m2 = jnp.where(zf <= 1.0, 0.0, m2)
    m4 = jnp.where(zf <= 1.0, 0.0, m4)
    if shift is None:
        mean = r1
    else:
        # empty prefix: sums are all zero and the mean is 0 by convention
        # (matching the masked oracle), not the arbitrary shift origin
        mean = jnp.where(moments[:, 0] < 1.0, 0.0, r1 + shift)
    return _select_value_sigma(mean, m2, m4, zf, z, n, agg_ids)
