"""Seeded contract violations: the checker's sensitivity tests.

A static checker that never fires is indistinguishable from one that
cannot fire.  Each function here builds a deliberately broken variant of a
real executable pattern — the exact regressions the contracts exist to
stop — runs the relevant lint, and returns its findings.  An empty return
means the checker MISSED the violation; ``python -m repro.analysis.check
--mutation-test`` (and ``tests/test_analysis.py``) fail on any miss, so
the pass is known-sensitive, not vacuously green.

The mutants use the toy linear-model executor (same probe as the
incremental-AFC HLO tests): real ``build_fused_executor`` programs, tiny
enough to trace and compile in milliseconds.
"""
from __future__ import annotations

from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo_lint, jaxpr_lint
from repro.analysis.jaxpr_lint import LintFinding
from repro.core.executor_fused import build_fused_executor, shard_lanes_executor
from repro.launch.mesh import make_serving_mesh

__all__ = ["MUTATIONS"]

_K = 3
_W = jnp.asarray([1.0, -2.0, 0.5])


def _toy_executor(
    model_fn: Callable[..., Any] | None = None, **overrides: Any
) -> Any:
    kwargs: dict[str, Any] = dict(k=_K, task="regression", m=16, m_sobol=8,
                                  max_iters=8, n_boot=16)
    kwargs.update(overrides)
    return build_fused_executor(
        model_fn if model_fn is not None else (lambda rows, exact: rows @ _W),
        **kwargs,
    )


def _lane_args(cap: int = 256) -> tuple[Any, ...]:
    """Single-lane executor inputs (the 8-ary fused signature)."""
    return (
        jnp.zeros((_K, cap), jnp.float32),
        jnp.full((_K,), cap, jnp.int32),
        jnp.zeros((_K,), jnp.int32),
        jnp.asarray(0.1, jnp.float32),
        jnp.zeros((0,), jnp.float32),
        jnp.asarray(True),
        jnp.asarray(0.95, jnp.float32),
        jnp.asarray(8, jnp.int32),
    )


def _batched_args(lanes: int = 4, cap: int = 256) -> tuple[Any, ...]:
    return tuple(
        jnp.broadcast_to(a, (lanes,) + a.shape) for a in _lane_args(cap)
    )


# ----------------------------------------------------------- the mutants
def injected_collective() -> list[LintFinding]:
    """A psum smuggled into the shard_map lane program.

    The sharded serving contract is zero collectives — a cross-lane
    reduction re-serializes every chunk on the slowest device.  The HLO
    linter must see the all-reduce in the compiled module (it survives
    even on a 1-device mesh).
    """
    run = _toy_executor()

    def lane(vals, n, agg_ids, delta, exact, active, tau, iter_cap):
        res = run(vals, n, agg_ids, delta, exact, active, tau, iter_cap)
        return res._replace(y_hat=jax.lax.psum(res.y_hat, "lanes"))

    mesh = make_serving_mesh(1)
    fn = shard_lanes_executor(lane, mesh)
    hlo = fn.lower(*_batched_args()).compile().as_text()
    return hlo_lint.check_collectives(
        hlo, "mutant/psum_in_shard_map", allowed=0, n_devices=1
    )


def split_rng_bootstrap() -> list[LintFinding]:
    """A split-based bootstrap sampler: key threaded through the carry.

    The classic non-counter-based pattern — each iteration splits the
    carried key.  Draws then depend on how many trips the carry's previous
    occupants ran, which breaks recycled-lane bitwise parity.
    """
    def sampler(key, vals):
        def cond(carry):
            return carry[2] < 8

        def body(carry):
            key, acc, i = carry
            key, sub = jax.random.split(key)
            idx = jax.random.randint(sub, vals.shape, 0, vals.shape[0])
            return key, acc + jnp.take(vals, idx).mean(), i + 1

        return jax.lax.while_loop(
            cond, body, (key, jnp.float32(0.0), jnp.int32(0))
        )[1]

    jaxpr = jax.make_jaxpr(sampler)(
        jax.random.PRNGKey(0), jnp.zeros((32,), jnp.float32)
    )
    return jaxpr_lint.check_rng(jaxpr, "mutant/split_bootstrap")


def dropped_donation() -> list[LintFinding]:
    """The donated values buffer no longer threads back out.

    ``donate_argnums`` alone is not a no-copy guarantee: without the
    ``lane_vals`` passthrough there is no output to alias the (lanes, k,
    cap) buffer onto, and XLA silently drops the donation.  The
    ``memory_analysis`` check must notice.
    """
    run = _toy_executor()
    fn = jax.jit(jax.vmap(run), donate_argnums=(0,))  # no passthrough
    args = _batched_args()
    compiled = fn.lower(*args).compile()
    return hlo_lint.check_donation(
        compiled, "mutant/undonated_vals",
        min_alias_bytes=args[0].nbytes,
        donated=("vals (lanes, k, cap) values buffer",),
    )


def weak_type_knob() -> list[LintFinding]:
    """A raw Python float reaching the traced call as the delta knob.

    The weak-typed scalar retraces whenever a caller's promotion context
    changes — the one-executable-per-bucket killer.  The dtype lint must
    flag the weak input aval.
    """
    run = _toy_executor()
    vals, n, agg_ids, _, exact, active, tau, iter_cap = _lane_args()
    jaxpr = jax.make_jaxpr(run)(
        vals, n, agg_ids, 0.5, exact, active, tau, iter_cap  # knob unpinned
    )
    return jaxpr_lint.check_dtypes(jaxpr, "mutant/weak_delta")


def host_callback_in_loop() -> list[LintFinding]:
    """A debug print left inside the model function.

    ``jax.debug.print`` compiles to a ``debug_callback`` inside the planner
    while body — a device->host round trip on every iteration of the hot
    path.  The host-sync lint must flag it.
    """
    def chatty_model(rows, exact):
        y = rows @ _W
        jax.debug.print("y_hat={y}", y=y)
        return y

    run = _toy_executor(model_fn=chatty_model)
    jaxpr = jax.make_jaxpr(run)(*_lane_args())
    return jaxpr_lint.check_host_sync(jaxpr, "mutant/debug_print")


def cap_leak_in_loop_body() -> list[LintFinding]:
    """O(cap) work leaked into the planner while body.

    The rescan AFC oracle recomputes all prefix work per iteration — the
    exact shape of a flatness regression — so forcing ``afc_backend="ref"``
    must trip the while-body flatness check across a 4x cap span.
    """
    texts: dict[int, str] = {}
    for cap in (1024, 4096):
        run = _toy_executor(afc_backend="ref")
        args = (
            jax.ShapeDtypeStruct((_K, cap), jnp.float32),
            jax.ShapeDtypeStruct((_K,), jnp.int32),
            jax.ShapeDtypeStruct((_K,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((0,), jnp.float32),
        )
        texts[cap] = jax.jit(run).lower(*args).compile().as_text()
    return hlo_lint.check_while_flatness(
        texts, "mutant/rescan_afc", bytes_tol=1.3
    )


def stale_cache_read() -> list[LintFinding]:
    """A feature cache keyed WITHOUT group versions.

    The classic broken serving cache: the key identifies the request shape
    but not data freshness, so ``Table.append`` leaves a stale entry
    resident and every later hit silently serves pre-append aggregates.
    ``FeatureCache``'s ``key_fn`` injection seam plants exactly that bug;
    the append-coherence probe (``analysis.check.cache_coherence_findings``)
    must see the cached server diverge from the uncached oracle.
    """
    from repro.analysis.check import cache_coherence_findings
    from repro.core.executor import BiathlonConfig
    from repro.data.store import bucket_size
    from repro.data.synthetic import make_pipeline
    from repro.serving.server import BiathlonServer

    b = make_pipeline("turbofan", rows_per_group=120, n_train_groups=20,
                      n_serve_groups=2, n_requests=2)
    cfg = BiathlonConfig(m=32, m_sobol=8, n_bootstrap=16)
    srv = BiathlonServer(b, cfg, mode="fused", cache_size=4)
    # the seeded bug: freshness dropped from the key (version-less cache)
    srv.cache._key_fn = lambda store, specs, cap: ()
    req = b.requests[0]
    srv.serve(req)  # entry now resident at the broken key
    t, _c, g = b.pipeline.agg_specs(req)[0]
    table = b.store[t]
    # grow the served group WITHOUT crossing its power-of-two bucket (a
    # bucket change would mint a fresh key and mask the staleness)
    n = table.group_size(g)
    grow = max(1, min(6, bucket_size(n) - n))
    table.append(
        {name: [float(np.asarray(col).mean()) + 5.0] * grow
         for name, col in table.columns.items()},
        group_key=np.full(grow, g),
    )
    oracle = BiathlonServer(b, cfg, mode="fused")
    return cache_coherence_findings(
        srv, oracle, [req], "mutant/stale_cache_read"
    )


def rollback_skips_bootstrap_carry() -> list[LintFinding]:
    """A chunk rollback that forgets to restore the bootstrap RNG carry.

    The checkpoint set is ALL of ``CHUNK_CARRY_LEAVES``; the bootstrap
    draws are counter-based on the carried iteration index
    (``jax.random.fold_in(base_key, it)``), so ``it`` IS the bootstrap
    carry — a rollback that restores the plan/prediction leaves but leaves
    the wrecked counter in place replays the remaining chunks with shifted
    replicate draws and a broken iter-cap ledger.  Uses ``sensor_health``
    (holistic: median + tail quantiles) so the counter-keyed bootstrap is
    actually on the hot path; the bitwise rollback-replay probe
    (``analysis.check.rollback_findings``) must see the divergence.
    """
    from repro.analysis.check import rollback_findings
    from repro.core.executor import BiathlonConfig
    from repro.data.synthetic import make_pipeline
    from repro.serving.continuous import ContinuousBatchedServer

    b = make_pipeline("sensor_health", rows_per_group=120, n_train_groups=20,
                      n_serve_groups=2, n_requests=2)
    cfg = BiathlonConfig(m=32, m_sobol=8, n_bootstrap=16)
    srv = ContinuousBatchedServer(b, cfg, batch_size=2, chunk_iters=2)
    return rollback_findings(
        srv, list(b.requests[:2]), "mutant/rollback_skips_it",
        skip_restore=("it",),  # the seeded bug: one carry leaf forgotten
    )


def quarantine_readmit_without_reset() -> list[LintFinding]:
    """A quarantine that re-admits a poisoned lane by flag-flip.

    The broken recovery shortcut: instead of evicting the lane and paying a
    full re-admission (which re-initializes every lane leaf from
    counter-based RNG), the lane's ``done``/``active`` flags are flipped
    back to live with the poisoned carry still in place — the scrambled
    plan and NaN prediction leak into the "recovered" request.  The
    quarantine-isolation probe (``analysis.check.quarantine_findings``)
    must see the re-admitted lane diverge from the never-poisoned oracle.
    """
    from repro.analysis.check import quarantine_findings
    from repro.core.executor import BiathlonConfig
    from repro.data.synthetic import make_pipeline
    from repro.serving.continuous import ContinuousBatchedServer

    b = make_pipeline("turbofan", rows_per_group=120, n_train_groups=20,
                      n_serve_groups=2, n_requests=2)
    cfg = BiathlonConfig(m=32, m_sobol=8, n_bootstrap=16)
    srv = ContinuousBatchedServer(b, cfg, batch_size=2, chunk_iters=2)
    return quarantine_findings(
        srv, list(b.requests[:2]), "mutant/quarantine_no_reset",
        reset_on_readmit=False,  # the seeded bug: carry kept across re-admit
    )


#: name -> builder; each must return >= 1 finding or the checker is blind.
MUTATIONS: dict[str, Callable[[], list[LintFinding]]] = {
    "injected_collective": injected_collective,
    "split_rng_bootstrap": split_rng_bootstrap,
    "dropped_donation": dropped_donation,
    "weak_type_knob": weak_type_knob,
    "host_callback_in_loop": host_callback_in_loop,
    "cap_leak_in_loop_body": cap_leak_in_loop_body,
    "stale_cache_read": stale_cache_read,
    "rollback_skips_bootstrap_carry": rollback_skips_bootstrap_carry,
    "quarantine_readmit_without_reset": quarantine_readmit_without_reset,
}
