"""HLO linting: collectives, donation aliasing, while-body cost flatness.

The jaxpr linter (jaxpr_lint.py) sees what the *program says*; this module
checks what the *compiler produced* — the two can disagree (SPMD
partitioning inserts collectives no jaxpr ever named; XLA silently declines
a donation when the aliased output's layout does not match).  It is built
on the existing post-SPMD HLO machinery:

* ``launch.hlo_stats.collect_collective_stats`` counts and sizes every
  all-reduce / all-gather / reduce-scatter / all-to-all /
  collective-permute — the sharded serving contract allows exactly zero;
* ``launch.hlo_cost.while_costs`` prices each while-loop body, which is
  how the incremental-AFC flatness contract (loop-body cost independent of
  the cap-bucket width) is enforced without running anything;
* donation is verified against the *compiled* executable: XLA's
  ``memory_analysis().alias_size_in_bytes`` must cover the donated buffer
  AND the module must carry an ``input_output_alias`` annotation — a
  donation that silently fell back to a copy passes neither.

All checks return :class:`~repro.analysis.jaxpr_lint.LintFinding` lists so
the checker reports jaxpr- and HLO-level violations uniformly.
"""
from __future__ import annotations

import re
from typing import Any

from repro.analysis.jaxpr_lint import LintFinding
from repro.launch.hlo_cost import HloCost, while_costs
from repro.launch.hlo_stats import collect_collective_stats

__all__ = [
    "check_collectives",
    "check_donation",
    "check_f64",
    "check_while_flatness",
    "planner_body_cost",
]

_F64 = re.compile(r"\bf64\[")


def check_collectives(
    hlo_text: str, executable: str, *, allowed: int = 0, n_devices: int = 1
) -> list[LintFinding]:
    """Compiled module must contain at most ``allowed`` collective ops.

    Counts post-SPMD instructions via ``collect_collective_stats`` — the
    authoritative place a stray ``psum`` (or a sharding constraint XLA
    resolved with an all-gather) becomes visible.
    """
    stats = collect_collective_stats(hlo_text, n_devices)
    total = sum(stats.per_op_count.values())
    if total <= allowed:
        return []
    per_op = ", ".join(
        f"{k}×{v} ({stats.per_op_bytes.get(k, 0.0):.0f}B)"
        for k, v in sorted(stats.per_op_count.items())
    )
    return [LintFinding(
        contract="collectives",
        executable=executable,
        where="<hlo>",
        message=(
            f"compiled module contains {total} collective op(s) "
            f"[{per_op}], contract allows {allowed} — the sharded lane "
            "path must stay collective-free (per-lane reductions local to "
            "the owning device; params replicated as closure constants)"
        ),
    )]


def check_f64(hlo_text: str, executable: str) -> list[LintFinding]:
    """No f64 buffers in the compiled module (f32 + compensation only)."""
    n = len(_F64.findall(hlo_text))
    if n == 0:
        return []
    return [LintFinding(
        contract="allow_f64",
        executable=executable,
        where="<hlo>",
        message=(
            f"{n} f64 buffer(s) in the compiled module — double-precision "
            "drift doubles HBM traffic; the stack is pinned to f32 with "
            "compensated accumulation (kernels/sampled_agg/compensated.py)"
        ),
    )]


def check_donation(
    compiled: Any,
    executable: str,
    *,
    min_alias_bytes: int,
    donated: tuple[str, ...],
) -> list[LintFinding]:
    """Donated inputs must ACTUALLY alias outputs in the compiled program.

    ``donate_argnums`` is a *permission*, not a guarantee: XLA drops the
    alias (and silently copies) when the output layout or shape does not
    line up.  Both signals must hold — ``memory_analysis`` reports at
    least the donated buffer's bytes aliased, and the module text carries
    the ``input_output_alias`` annotation.
    """
    findings: list[LintFinding] = []
    names = ", ".join(donated) or "<buffers>"
    try:
        alias = int(compiled.memory_analysis().alias_size_in_bytes)
    except Exception as e:  # backend without memory_analysis support
        return [LintFinding(
            contract="donated",
            executable=executable,
            where="<memory_analysis>",
            message=f"cannot verify donation of {names}: {e}",
        )]
    if alias < min_alias_bytes:
        findings.append(LintFinding(
            contract="donated",
            executable=executable,
            where="<memory_analysis>",
            message=(
                f"donated input(s) {names} not aliased: "
                f"alias_size_in_bytes={alias} < expected {min_alias_bytes} "
                "— XLA fell back to a per-dispatch copy (is the buffer "
                "threaded back out as an output, e.g. FusedResult.lane_vals?)"
            ),
        ))
    if "input_output_alias" not in compiled.as_text():
        findings.append(LintFinding(
            contract="donated",
            executable=executable,
            where="<hlo>",
            message=(
                f"no input_output_alias annotation in the compiled module — "
                f"donation of {names} was dropped entirely"
            ),
        ))
    return findings


def planner_body_cost(hlo_text: str) -> HloCost | None:
    """Cost of ONE iteration of the module's most expensive while body.

    The planner loop is the while with the largest body bytes (the inner
    Beta-rejection loops are tiny) — same convention as the incremental-AFC
    regression test.  None when the module has no while loop at all.
    """
    costs = while_costs(hlo_text)
    if not costs:
        return None
    return max(costs, key=lambda c: c["cost"].bytes)["cost"]


def check_while_flatness(
    texts_by_cap: dict[int, str],
    executable: str,
    *,
    bytes_tol: float = 1.3,
    flops_tol: float = 1.1,
) -> list[LintFinding]:
    """Loop-body cost must be independent of the cap-bucket width.

    ``texts_by_cap`` maps cap -> compiled HLO text of the SAME executable
    lowered at that cap.  The smallest cap is the reference; every larger
    cap's planner-body bytes must stay within ``bytes_tol`` of it (FLOPs
    within ``flops_tol``) — the incremental-AFC promise that all O(cap)
    work lives in the once-per-request precompute, outside the loop.
    """
    if len(texts_by_cap) < 2:
        raise ValueError("need >= 2 caps to check flatness")
    caps = sorted(texts_by_cap)
    base = planner_body_cost(texts_by_cap[caps[0]])
    if base is None:
        return [LintFinding(
            contract="while_body_flat",
            executable=executable,
            where=f"<hlo cap={caps[0]}>",
            message="no while loop found in the compiled module",
        )]
    findings: list[LintFinding] = []
    for cap in caps[1:]:
        cost = planner_body_cost(texts_by_cap[cap])
        if cost is None:
            findings.append(LintFinding(
                contract="while_body_flat",
                executable=executable,
                where=f"<hlo cap={cap}>",
                message="no while loop found in the compiled module",
            ))
            continue
        if cost.bytes > bytes_tol * max(base.bytes, 1.0):
            findings.append(LintFinding(
                contract="while_body_flat",
                executable=executable,
                where=f"<hlo cap={cap}>",
                message=(
                    f"while-body HBM bytes scale with cap: {cost.bytes:.0f}B "
                    f"at cap {cap} vs {base.bytes:.0f}B at cap {caps[0]} "
                    f"(> {bytes_tol}x) — O(cap) work leaked from the "
                    "once-per-request precompute into the loop body"
                ),
            ))
        if cost.flops > flops_tol * max(base.flops, 1.0):
            findings.append(LintFinding(
                contract="while_body_flat",
                executable=executable,
                where=f"<hlo cap={cap}>",
                message=(
                    f"while-body FLOPs scale with cap: {cost.flops:.0f} at "
                    f"cap {cap} vs {base.flops:.0f} at cap {caps[0]} "
                    f"(> {flops_tol}x)"
                ),
            ))
    return findings
