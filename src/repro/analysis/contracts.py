"""Executable contracts: declared invariants of the compiled serving programs.

A contract is the machine-readable half of an executable builder's
docstring: how many jit cache entries it may mint per power-of-two cap
bucket, whether its compiled module may contain collectives, which inputs
must be donated-and-aliased, and what RNG discipline its loop bodies must
follow.  Builders declare their contract **next to the code it constrains**
(``core/executor_fused.py``, ``serving/batched.py``,
``serving/continuous.py`` call :func:`register_contract` at import time),
and three consumers read the registry:

* the static checker (``repro.analysis.check``) lints traced jaxprs and
  compiled HLO against it and diffs the results against the checked-in
  baseline;
* the serving tests assert their trace-hook compile counts *through*
  :func:`assert_compile_contract`, so a test and the checker can never
  disagree about the expected executable count;
* humans, via ``python -m repro.analysis.check --list``.

This module is dependency-free (no jax import) so declaring a contract
costs nothing at import time.
"""
from __future__ import annotations

from collections.abc import Sequence
from dataclasses import dataclass, fields
from typing import Any

__all__ = [
    "ExecutableContract",
    "all_contracts",
    "assert_compile_contract",
    "contract_for",
    "register_contract",
]

#: RNG disciplines a contract can demand of loop bodies.
RNG_COUNTER_BASED = "counter_based"
RNG_FREE = "free"


@dataclass(frozen=True)
class ExecutableContract:
    """Invariants one executable builder promises about its compiled output.

    ``executables_per_bucket``
        jit cache entries the owning server may mint per power-of-two cap
        bucket (1 for the fixed-lane batch program; 2 for the continuous
        table's refill + chunk pair).  Enforced by
        :func:`assert_compile_contract` against the server's trace-hook
        counters.
    ``collectives``
        cross-device collective ops (all-reduce / all-gather /
        reduce-scatter / all-to-all / collective-permute) the compiled
        module may contain.  The sharded serving path promises 0.
    ``donated``
        human-readable names of inputs that must be donated AND aliased to
        an output (XLA ``input_output_alias``) — the no-copy contract for
        the (lanes, k, cap) values buffer / the continuous lane table.
        Empty tuple = no donation requirement.
    ``rng``
        ``"counter_based"`` forbids ``jax.random.split`` and key-typed
        carries inside loop bodies (bootstrap draws must ``fold_in`` a
        loop counter on a closure key — the lane-recycling parity
        property); ``"free"`` lifts the restriction.
    ``weak_type_inputs``
        whether weak-typed input avals are tolerated.  False means every
        traced input must carry a strong dtype — a weak scalar (a raw
        Python float) re-traces the program whenever a caller's promotion
        context changes, silently breaking ``executables_per_bucket``.
    ``allow_f64``
        whether f64 values may appear anywhere in the traced program
        (they never should: the stack is pinned to f32 with compensated
        accumulation — see kernels/sampled_agg/compensated.py).
    ``while_body_flat``
        whether the planner while-loop body's HLO cost must be independent
        of the cap-bucket width (the incremental-AFC promise; checked via
        ``launch.hlo_cost.while_costs`` at two caps).
    """

    name: str
    builder: str
    executables_per_bucket: int
    collectives: int = 0
    donated: tuple[str, ...] = ()
    rng: str = RNG_COUNTER_BASED
    weak_type_inputs: bool = False
    allow_f64: bool = False
    while_body_flat: bool = False
    description: str = ""

    def __post_init__(self) -> None:
        if self.executables_per_bucket < 0:
            raise ValueError(
                f"contract {self.name!r}: executables_per_bucket must be >= 0"
            )
        if self.collectives < 0:
            raise ValueError(f"contract {self.name!r}: collectives must be >= 0")
        if self.rng not in (RNG_COUNTER_BASED, RNG_FREE):
            raise ValueError(
                f"contract {self.name!r}: rng must be "
                f"{RNG_COUNTER_BASED!r} or {RNG_FREE!r}, got {self.rng!r}"
            )

    def as_dict(self) -> dict[str, Any]:
        d = {f.name: getattr(self, f.name) for f in fields(self)}
        d["donated"] = list(self.donated)
        return d


_REGISTRY: dict[str, ExecutableContract] = {}


def register_contract(contract: ExecutableContract) -> ExecutableContract:
    """Register a builder's contract; returns it for inline declaration.

    Re-registering the IDENTICAL contract is a no-op (modules may be
    re-imported); registering a conflicting contract under an existing name
    raises — two builders silently fighting over one name is exactly the
    drift this registry exists to surface.
    """
    prev = _REGISTRY.get(contract.name)
    if prev is not None and prev != contract:
        raise ValueError(
            f"conflicting contract registration for {contract.name!r}: "
            f"{prev} vs {contract}"
        )
    _REGISTRY[contract.name] = contract
    return contract


def contract_for(name: str) -> ExecutableContract:
    """The registered contract, or a loud error naming what IS registered."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no contract registered under {name!r}; known: "
            f"{sorted(_REGISTRY)} (builders register at import time — "
            "import the owning module first)"
        ) from None


def all_contracts() -> dict[str, ExecutableContract]:
    """Snapshot of the registry (name -> contract), declaration-order."""
    return dict(_REGISTRY)


def assert_compile_contract(
    server: Any,
    name: str | Sequence[str],
    *,
    buckets: Sequence[int] | None = None,
) -> None:
    """Assert a server's observed compile counters match its contract(s).

    The one place the expected-executable arithmetic lives: a server that
    exposes ``compile_count`` (trace-hook cache-miss counter) and
    ``compiled_buckets`` (cap buckets served) must satisfy

        compile_count == sum(executables_per_bucket) * len(compiled_buckets)

    ``name`` is a contract name or a sequence of them — a server built from
    several executables (the continuous table's refill + chunk pair) sums
    their per-bucket budgets.  ``buckets`` (optional) additionally pins the
    exact bucket list.  Both the serving tests and the runtime checker call
    this, so the test suite and ``python -m repro.analysis.check`` cannot
    drift apart on what "no recompiles" means.  Raises ``AssertionError``
    naming the violated contract(s).
    """
    names = (name,) if isinstance(name, str) else tuple(name)
    cs = [contract_for(n) for n in names]
    observed = int(server.compile_count)
    got_buckets = list(server.compiled_buckets)
    per_bucket = sum(c.executables_per_bucket for c in cs)
    expected = per_bucket * len(got_buckets)
    label = " + ".join(repr(c.name) for c in cs)
    if observed != expected:
        builders = ", ".join(sorted({c.builder for c in cs}))
        raise AssertionError(
            f"contract {label} (builder {builders}) violated: "
            f"{observed} executables compiled for {len(got_buckets)} cap "
            f"bucket(s) {got_buckets}, contract allows "
            f"{per_bucket} per bucket = {expected}"
        )
    if buckets is not None and got_buckets != sorted(buckets):
        raise AssertionError(
            f"contract {label}: served cap buckets {got_buckets} != "
            f"expected {sorted(buckets)}"
        )
