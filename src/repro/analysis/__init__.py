"""Static contract checking for the serving stack's compiled executables.

Biathlon's serving speedups (DESIGN.md) rest on invariants that are easy to
break silently in a later PR and expensive to debug from symptoms:

* the jit cache holds a FIXED number of executables per power-of-two cap
  bucket (one for the fixed-lane batch program, refill + chunk for the
  continuous table) — a weak-typed scalar knob or an unpinned dtype turns
  that into one compile per request;
* the sharded hot path runs ZERO collectives under ``shard_map`` — a stray
  ``psum`` re-serializes every chunk on the slowest device;
* the donated lane buffers really alias input to output — a dropped
  passthrough silently re-materializes the (lanes, k, cap) transfer per
  batch;
* all hot-loop RNG is counter-based (``fold_in`` on the per-request
  iteration index) — a ``jax.random.split`` threaded through the carry
  breaks bitwise lane-recycling parity with no test naming the cause.

This package makes those invariants *declared* (``contracts`` — each
executable builder registers its contract next to the code it constrains),
*checkable before execution* (``jaxpr_lint`` walks traced jaxprs,
``hlo_lint`` scans lowered/compiled HLO on the ``launch.hlo_cost`` /
``launch.hlo_stats`` machinery), and *known-sensitive* (``mutations`` holds
deliberately seeded violations the checker must catch).  ``check`` is the
CLI / CI gate: ``python -m repro.analysis.check``.

Only the registry is re-exported here; the linters import jax and the
checker imports the serving stack, so they stay submodule imports
(``repro.analysis.jaxpr_lint`` etc.) to keep contract declaration cheap for
the modules that do it at import time.
"""
from repro.analysis.contracts import (
    ExecutableContract,
    all_contracts,
    assert_compile_contract,
    contract_for,
    register_contract,
)

__all__ = [
    "ExecutableContract",
    "all_contracts",
    "assert_compile_contract",
    "contract_for",
    "register_contract",
]
