"""The contract checker CLI / CI gate: ``python -m repro.analysis.check``.

Builds every shipped serving executable at test scale — the fixed-lane
batch program (``fused``), its shard_map twin (``sharded_lanes``), and the
continuous table's ``refill`` + ``chunk`` pair, for each pipeline in
``--pipelines`` — then enforces each one's registered
:class:`~repro.analysis.contracts.ExecutableContract` three ways:

1. **compile contract** — serve real fills through the server and assert
   the trace-hook counters via ``check_compile_contract`` (one executable
   per cap bucket; two for the continuous pair);
2. **jaxpr lint** — trace the jitted callable and run
   :mod:`repro.analysis.jaxpr_lint` (counter-based RNG in loop bodies, no
   host callbacks, no weak-typed inputs, no f64);
3. **HLO lint** — lower + compile and run :mod:`repro.analysis.hlo_lint`
   (zero collectives, donation actually aliases via ``memory_analysis``,
   no f64 buffers), plus a pipeline-independent while-body **flatness
   probe** of the incremental-AFC path at two caps.

Observed facts (collective counts, donation aliasing, finding counts) are
diffed against the checked-in ``baseline.json`` next to this module, so
drift fails loudly with a diff even when a contract was loosened to match;
``--update-baseline`` rewrites it.  ``--mutation-test`` runs the seeded
violations in :mod:`repro.analysis.mutations` and fails unless every one
is caught — the checker must be known-sensitive, not vacuously green.

Exit status: 0 clean, 1 on findings / baseline drift / missed mutations.
"""
from __future__ import annotations

import argparse
import difflib
import json
import sys
from collections.abc import Sequence
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo_lint, jaxpr_lint
from repro.analysis.contracts import ExecutableContract, all_contracts, contract_for
from repro.analysis.jaxpr_lint import LintFinding
from repro.core.executor import BiathlonConfig
from repro.core.executor_fused import build_fused_executor
from repro.data.synthetic import make_pipeline
from repro.launch.hlo_stats import collect_collective_stats
from repro.launch.mesh import make_serving_mesh
from repro.serving.batched import BatchedFusedServer, lane_request_inputs
from repro.serving.continuous import ContinuousBatchedServer
from repro.serving.server import BiathlonServer

__all__ = ["main", "run_checks"]

BASELINE_PATH = Path(__file__).with_name("baseline.json")
DEFAULT_PIPELINES = ("turbofan", "sensor_health")
#: test-scale data (same knobs the serving tests use): one cap bucket,
#: millisecond dispatches, but the REAL builders and the REAL servers.
SMALL = dict(rows_per_group=300, n_train_groups=30, n_serve_groups=4,
             n_requests=6)
CFG = BiathlonConfig(m=64, m_sobol=16, n_bootstrap=32)
LANES = 4
#: caps for the incremental-AFC while-body flatness probe (4x apart — a
#: rescan body scales ~linearly, so leakage is unmistakable at this ratio).
FLATNESS_CAPS = (2048, 8192)


# ---------------------------------------------------------------- helpers
def _batch_args(
    srv: BatchedFusedServer, requests: Sequence[dict[str, Any]]
) -> tuple[Any, ...]:
    """The exact (8-tuple) device arguments ``serve_batch`` would build."""
    p = srv.bundle.pipeline
    store = srv.bundle.store
    lanes = srv.batch_size
    cap = srv.batch_cap(requests)
    r = len(requests)
    vals = np.zeros((lanes, p.k, cap), np.float32)
    ns = np.zeros((lanes, p.k), np.int32)
    exacts = np.zeros((lanes, len(p.exact_features)), np.float32)
    for i, req in enumerate(requests):
        vals[i], ns[i], _, exacts[i] = lane_request_inputs(p, store, req, cap)
    delta = srv.config.delta if srv.config.delta is not None else p.delta_default
    return (
        jnp.asarray(vals),
        jnp.asarray(ns),
        jnp.broadcast_to(srv._agg_ids, (lanes, p.k)),
        jnp.asarray(np.full((lanes,), delta, np.float32)),
        jnp.asarray(exacts),
        jnp.asarray(np.arange(lanes) < r),
        jnp.asarray(np.full((lanes,), srv.config.tau, np.float32)),
        jnp.asarray(np.full((lanes,), srv.config.max_iters, np.int32)),
    )


def _lint_static(
    fn: Any, args: tuple[Any, ...], contract: ExecutableContract, exe: str,
    *, min_alias_bytes: int, n_devices: int,
) -> tuple[list[LintFinding], dict[str, Any]]:
    """Jaxpr + HLO lint of one jitted callable against its contract.

    Returns ``(findings, facts)`` — ``facts`` are the version-stable
    observations recorded in the baseline.
    """
    findings: list[LintFinding] = []
    jaxpr, trace_findings = jaxpr_lint.trace_for_lint(fn, *args, executable=exe)
    findings += trace_findings
    if jaxpr is not None:
        findings += jaxpr_lint.lint_jaxpr(
            jaxpr, exe,
            rng=contract.rng,
            allow_weak_inputs=contract.weak_type_inputs,
            allow_f64=contract.allow_f64,
        )
    compiled = fn.lower(*args).compile()
    hlo = compiled.as_text()
    findings += hlo_lint.check_collectives(
        hlo, exe, allowed=contract.collectives, n_devices=n_devices
    )
    if not contract.allow_f64:
        findings += hlo_lint.check_f64(hlo, exe)
    if contract.donated:
        findings += hlo_lint.check_donation(
            compiled, exe,
            min_alias_bytes=min_alias_bytes, donated=contract.donated,
        )
    stats = collect_collective_stats(hlo, n_devices)
    facts = {
        "contract": contract.name,
        "collectives": int(sum(stats.per_op_count.values())),
        "donation_aliased": bool(contract.donated) and not any(
            f.contract == "donated" for f in findings
        ),
        "rng_findings": sum(1 for f in findings if f.contract == "rng"),
        "host_sync_findings": sum(
            1 for f in findings if f.contract == "host_sync"
        ),
        "weak_type_inputs": sum(
            1 for f in findings if f.contract == "weak_type_inputs"
        ),
        "f64": bool(hlo_lint.check_f64(hlo, exe)),
    }
    return findings, facts


def _compile_contract_findings(srv: Any, exe: str) -> list[LintFinding]:
    """Run the server's own compile-contract assertion as a lint check."""
    try:
        srv.check_compile_contract()
        return []
    except AssertionError as e:
        return [LintFinding(
            contract="executables_per_bucket", executable=exe,
            where="<trace hooks>", message=str(e),
        )]


def cache_coherence_findings(
    cached: Any, oracle: Any, requests: Sequence[dict[str, Any]], exe: str
) -> list[LintFinding]:
    """Serve the same log through a cache-fed server and an uncached oracle.

    Any divergence is a stale read: the z-plans are a bitwise contract
    (incremental and rescan AFC agree exactly, the PR-5 parity property),
    so a cached entry whose versions lag the store shows up as a z or
    prediction mismatch.  Also the sensitivity oracle for the
    ``stale_cache_read`` mutant (analysis/mutations.py) — a cache keyed
    without group versions must trip this probe.
    """
    findings: list[LintFinding] = []
    for i, req in enumerate(requests):
        a = cached.serve(req)
        b = oracle.serve(req)
        same_z = bool(np.array_equal(a["z"], b["z"]))
        scale = max(abs(b["y_hat"]), 1.0)
        same_y = abs(a["y_hat"] - b["y_hat"]) <= 1e-4 * scale
        if not (same_z and same_y):
            findings.append(LintFinding(
                contract="cache_version_key", executable=exe,
                where=f"request[{i}]",
                message=(
                    "cache-fed serve diverged from the uncached oracle "
                    f"(y {a['y_hat']:.6g} vs {b['y_hat']:.6g}, "
                    f"z match={same_z}): stale entry served — the cache "
                    "key must include the per-spec group versions"
                ),
            ))
    return findings


def _drain_lanes(srv: Any, table: Any, lanes: Sequence[int],
                 max_chunks: int = 128) -> tuple[Any, dict]:
    """Chunk until every named lane reports done (bounded), then read back."""
    for _ in range(max_chunks):
        out = srv.readback(table)
        if all(bool(out["done"][l]) for l in lanes):
            return table, out
        table = srv.run_chunk(table)
    return table, srv.readback(table)


def rollback_findings(
    srv: Any, requests: Sequence[dict[str, Any]], exe: str,
    *, skip_restore: Sequence[str] = (),
) -> list[LintFinding]:
    """Crash a chunk mid-flight, roll back, replay; diff bitwise vs oracle.

    The rollback invariant (DESIGN.md § Fault tolerance): restoring the
    CHUNK_CARRY_LEAVES snapshot after a mid-chunk wreck and replaying must
    be bitwise-identical to the fault-free run — the bootstrap RNG is
    counter-based on the restored iteration index, so nothing is re-drawn.
    ``skip_restore`` is the sensitivity seam for the
    ``rollback_skips_bootstrap_carry`` mutant (analysis/mutations.py): a
    rollback that forgets a carry leaf must trip this probe.
    """
    from repro.serving import faults

    lanes = list(range(min(srv.batch_size, len(requests))))
    reqs = [requests[l] for l in lanes]
    cap = srv.trace_cap(reqs)
    assignments = [(l, reqs[l], None) for l in lanes]
    table = srv.new_table(cap)
    table, _ = srv.admit(table, cap, assignments)
    _, want = _drain_lanes(srv, table, lanes)

    table = srv.new_table(cap)
    table, _ = srv.admit(table, cap, assignments)
    table = srv.run_chunk(table)
    ckpt = srv.snapshot(table)
    wreck = faults.scramble_chunk_carry(table)  # simulated mid-chunk crash
    kept = {k: v for k, v in ckpt.items() if k not in skip_restore}
    table = srv.restore(wreck, kept)
    _, got = _drain_lanes(srv, table, lanes)

    findings: list[LintFinding] = []
    for l in lanes:
        same_z = bool(np.array_equal(want["z"][l], got["z"][l]))
        same_y = bool(
            np.asarray(want["y_hat"][l]).tobytes()
            == np.asarray(got["y_hat"][l]).tobytes()
        )
        if not (same_z and same_y):
            findings.append(LintFinding(
                contract="rollback_replay", executable=exe,
                where=f"lane[{l}]",
                message=(
                    "replay after chunk rollback diverged from the "
                    f"fault-free oracle (z match={same_z}, y_hat "
                    f"{got['y_hat'][l]:.6g} vs {want['y_hat'][l]:.6g}): "
                    "the checkpoint must restore every chunk-mutable "
                    "carry leaf"
                ),
            ))
    return findings


def quarantine_findings(
    srv: Any, requests: Sequence[dict[str, Any]], exe: str,
    *, reset_on_readmit: bool = True,
) -> list[LintFinding]:
    """Poison one lane's carry, quarantine + re-admit, diff bitwise vs oracle.

    Two invariants: the poisoned lane's FULL re-admission must converge to
    the same result as a never-poisoned run (admits re-init every lane leaf
    from counter-based RNG), and the neighbor lane must be bitwise
    untouched (quarantine is per-lane, never table-wide).
    ``reset_on_readmit=False`` is the sensitivity seam for the
    ``quarantine_readmit_without_reset`` mutant: flag-flipping the lane
    back to live while keeping its poisoned carry must trip this probe.
    """
    import jax

    from repro.serving import faults

    lanes = [0, 1]
    reqs = list(requests[:2])
    cap = srv.trace_cap(reqs)
    assignments = [(l, reqs[l], None) for l in lanes]
    table = srv.new_table(cap)
    table, _ = srv.admit(table, cap, assignments)
    _, want = _drain_lanes(srv, table, lanes)

    table = srv.new_table(cap)
    table, _ = srv.admit(table, cap, assignments)
    table = srv.run_chunk(table)
    table = faults.poison_lane_carry(table, 0)
    if reset_on_readmit:
        table = srv.clear_lanes(table, [0])
        table, _ = srv.admit(table, cap, [(0, reqs[0], None)])
    else:
        # the seeded bug: "re-admit" by flipping the lane flags back to
        # live while keeping the poisoned carry
        done = np.asarray(table.done).copy()
        active = np.asarray(table.active).copy()
        done[0] = False
        active[0] = True
        table = table._replace(
            done=jax.device_put(done, table.done.sharding),
            active=jax.device_put(active, table.active.sharding),
        )
    _, got = _drain_lanes(srv, table, lanes)

    findings: list[LintFinding] = []
    for l, label in ((0, "re-admitted"), (1, "neighbor")):
        same_z = bool(np.array_equal(want["z"][l], got["z"][l]))
        same_y = bool(
            np.asarray(want["y_hat"][l]).tobytes()
            == np.asarray(got["y_hat"][l]).tobytes()
        )
        if not (same_z and same_y):
            findings.append(LintFinding(
                contract="quarantine_isolation", executable=exe,
                where=f"lane[{l}] ({label})",
                message=(
                    f"{label} lane diverged from the never-poisoned oracle "
                    f"(z match={same_z}, y_hat {got['y_hat'][l]:.6g} vs "
                    f"{want['y_hat'][l]:.6g}): quarantine must fully "
                    "re-initialize the poisoned lane and touch nothing else"
                ),
            ))
    return findings


def store_recovery_findings(bundle: Any, exe: str) -> list[LintFinding]:
    """Journal-replay recovery must rebuild the index byte-identical.

    Appends a few rows (journaled), tears the derived index state the way a
    crash mid-append would (shuffled permutation, bumped offsets, cleared
    version counters), then requires :meth:`Table.recover` to rebuild
    ``perm`` / ``group_ptr`` / ``versions`` exactly equal to the
    never-crashed state.
    """
    findings: list[LintFinding] = []
    t, _c, g = bundle.pipeline.agg_specs(bundle.requests[0])[0]
    table = bundle.store[t]
    for shift in (0.5, -1.25):
        table.append(
            {name: [float(np.asarray(col[np.isfinite(col)]).mean()) + shift]
             for name, col in table.columns.items()},
            group_key=g,
        )
    want = (table.perm.copy(), table.group_ptr.copy(),
            dict(table.group_ids), list(table.versions))
    # tear the derived state: recover() must not depend on any of it
    rng = np.random.default_rng(0)
    table.perm = rng.permutation(table.perm)
    table.group_ptr = table.group_ptr + 7
    table.versions = []
    table.recover()
    got = (table.perm, table.group_ptr, table.group_ids, table.versions)
    same = (
        np.array_equal(want[0], got[0])
        and np.array_equal(want[1], got[1])
        and want[2] == dict(got[2])
        and want[3] == list(got[3])
    )
    if not same:
        findings.append(LintFinding(
            contract="store_recovery", executable=exe,
            where=f"table[{t}]",
            message=(
                "journal replay did not rebuild the index byte-identical "
                "to the never-crashed table (perm/group_ptr/versions "
                "mismatch)"
            ),
        ))
    return findings


def cache_integrity_findings(bundle: Any, exe: str) -> list[LintFinding]:
    """A flipped byte in a resident entry must be detected, never served."""
    from repro.serving import corrupt_cache_entry

    findings: list[LintFinding] = []
    srv = BiathlonServer(bundle, CFG, mode="fused", cache_size=4)
    req = bundle.requests[0]
    want = srv.serve(req)
    srv.cache.verify_hits = True
    if not corrupt_cache_entry(srv.cache, seed=0):
        findings.append(LintFinding(
            contract="cache_integrity", executable=exe, where="<cache>",
            message="corruption probe found no resident entry to flip",
        ))
        return findings
    got = srv.serve(req)  # must detect, drop, rebuild cold
    if srv.cache.corruptions < 1:
        findings.append(LintFinding(
            contract="cache_integrity", executable=exe, where="<cache>",
            message=(
                "a flipped byte in a resident entry went undetected: the "
                "power-sum checksum must fail the entry on the hit path"
            ),
        ))
    if not (np.array_equal(want["z"], got["z"])
            and want["y_hat"] == got["y_hat"]):
        findings.append(LintFinding(
            contract="cache_integrity", executable=exe, where="<cache>",
            message=(
                "post-corruption rebuild diverged from the pre-corruption "
                f"serve (y {got['y_hat']:.6g} vs {want['y_hat']:.6g})"
            ),
        ))
    return findings


# --------------------------------------------------------- per-executable
def check_fused(
    bundle: Any, *, mesh: Any = None, n_devices: int = 1
) -> tuple[str, list[LintFinding], dict[str, Any]]:
    """Fixed-lane batch program (sharded when ``mesh`` is given)."""
    name = "sharded_lanes" if mesh is not None else "fused"
    exe = f"{bundle.name}/{name}"
    srv = BatchedFusedServer(bundle, CFG, batch_size=LANES, mesh=mesh)
    reqs = list(bundle.requests[:3])
    srv.serve_batch(reqs[:1])
    srv.serve_batch(reqs)  # fill variation: same bucket, zero new compiles
    findings = _compile_contract_findings(srv, exe)
    args = _batch_args(srv, reqs)
    # memory_analysis reports PER-DEVICE bytes; the lanes axis shards the
    # donated values buffer, so the per-shard slice is the floor.
    f2, facts = _lint_static(
        srv._batched, args, contract_for(name), exe,
        min_alias_bytes=args[0].nbytes // max(n_devices, 1),
        n_devices=n_devices,
    )
    return exe, findings + f2, facts


def check_continuous(
    bundle: Any,
) -> list[tuple[str, list[LintFinding], dict[str, Any]]]:
    """Continuous lane table: the refill + chunk executable pair."""
    srv = ContinuousBatchedServer(bundle, CFG, batch_size=LANES, chunk_iters=2)
    p = srv.bundle.pipeline
    reqs = list(bundle.requests[:3])
    cap = srv.trace_cap(reqs)
    table = srv.new_table(cap)
    table, _ = srv.admit(table, cap, [(0, reqs[0], None), (1, reqs[1], None)])
    for _ in range(2):
        table = srv.run_chunk(table)
    table, _ = srv.admit(table, cap, [(2, reqs[2], None)])  # recycling admit
    exe_r = f"{bundle.name}/refill"
    exe_c = f"{bundle.name}/chunk"
    findings = _compile_contract_findings(srv, f"{bundle.name}/refill+chunk")

    vals, n, _, exact = lane_request_inputs(p, bundle.store, reqs[0], cap)
    delta = CFG.delta if CFG.delta is not None else p.delta_default
    refill_args = (
        table,
        jnp.asarray(vals),
        jnp.asarray(n),
        srv._agg_ids,
        jnp.asarray(delta, jnp.float32),
        jnp.asarray(exact),
        jnp.asarray(CFG.tau, jnp.float32),
        jnp.asarray(CFG.max_iters, jnp.int32),
        jnp.asarray(0, jnp.int32),
    )
    table_bytes = int(table.vals.nbytes)
    fr, facts_r = _lint_static(
        srv._refill, refill_args, contract_for("refill"), exe_r,
        min_alias_bytes=table_bytes, n_devices=1,
    )
    fc, facts_c = _lint_static(
        srv._chunk, (table,), contract_for("chunk"), exe_c,
        min_alias_bytes=table_bytes, n_devices=1,
    )
    return [(exe_r, findings + fr, facts_r), (exe_c, fc, facts_c)]


def check_feature_cache(
    bundle: Any,
) -> tuple[str, list[LintFinding], dict[str, Any]]:
    """Cache-fed serving (PR 9): hits mint nothing, appends stay coherent.

    Three probes on top of the static lint of the prebuilt batch program:

    1. compile contract — the cached server's trace hooks must show exactly
       ``fused_prebuilt + afc_precompute`` executables per cap bucket;
    2. hit path — re-serving a resident key must compile ZERO new
       executables (the whole point of device-resident precompute);
    3. append coherence — after ``Table.append`` on a served group, the
       cached server must match an uncached oracle (version-keyed entries
       can never serve stale data).
    """
    exe = f"{bundle.name}/fused_prebuilt"
    srv = BiathlonServer(bundle, CFG, mode="fused", cache_size=8)
    reqs = list(bundle.requests[:3])
    for req in reqs:
        srv.serve(req)
    findings = _compile_contract_findings(srv, exe)
    before = srv.compile_count
    srv.serve(reqs[0])
    hit_clean = srv.compile_count == before
    if not hit_clean:
        findings.append(LintFinding(
            contract="executables_per_bucket", executable=exe,
            where="<cache hit>",
            message=(
                f"cache-hit serve minted {srv.compile_count - before} "
                "executable(s); hits must re-dispatch the bucket's "
                "existing prebuilt program"
            ),
        ))
    # append-coherence probe: grow a served group, then diff against an
    # uncached oracle (fresh server; the store is shared, so the oracle
    # re-gathers the post-append truth)
    oracle = BiathlonServer(bundle, CFG, mode="fused")
    t, _c, g = bundle.pipeline.agg_specs(reqs[0])[0]
    table = bundle.store[t]
    table.append(
        {name: [float(np.asarray(col).mean()) + 3.0]
         for name, col in table.columns.items()},
        group_key=g,
    )
    coherence = cache_coherence_findings(srv, oracle, reqs, exe)
    findings += coherence

    # static lint of the prebuilt batch program: the donated stacked values
    # buffer must still alias through lane_vals with tables as an input
    bsrv = BatchedFusedServer(bundle, CFG, batch_size=LANES, cache_size=8)
    bsrv.serve_batch(reqs)
    cap = bsrv.batch_cap(reqs)
    p = bundle.pipeline
    entries = [bsrv.cache.get(p.agg_specs(r), cap) for r in reqs]
    lane_entries = entries + [entries[0]] * (LANES - len(reqs))
    args = (
        jnp.stack([e.vals for e in lane_entries]),
        jnp.stack([e.n for e in lane_entries]),
        jnp.broadcast_to(bsrv._agg_ids, (LANES, p.k)),
        jnp.zeros((LANES,), jnp.float32) + jnp.float32(1.0),
        jnp.zeros((LANES, len(p.exact_features)), jnp.float32),
        jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[e.tables for e in lane_entries]
        ),
        jnp.asarray(np.arange(LANES) < len(reqs)),
        jnp.full((LANES,), CFG.tau, jnp.float32),
        jnp.full((LANES,), CFG.max_iters, jnp.int32),
    )
    f2, facts = _lint_static(
        bsrv._batched, args, contract_for("fused_prebuilt"), exe,
        min_alias_bytes=args[0].nbytes, n_devices=1,
    )
    facts["hit_zero_compiles"] = hit_clean
    facts["append_coherent"] = not coherence
    return exe, findings + f2, facts


def check_recovery(
    bundle: Any,
) -> tuple[str, list[LintFinding], dict[str, Any]]:
    """Fault-tolerance probes (PR 10): rollback, quarantine, recovery.

    Four dynamic invariants on the REAL servers (no fault profile — the
    probes crash the state directly, so they are deterministic):

    1. chunk rollback — restore + replay is bitwise-identical to fault-free;
    2. lane quarantine — a poisoned lane's full re-admission matches the
       never-poisoned oracle and its neighbor is untouched;
    3. store recovery — journal replay rebuilds the derived index
       byte-identical after a torn crash state;
    4. cache integrity — a flipped byte in a resident entry is detected by
       the power-sum checksum and rebuilt, never served.

    Mutates the store (journaled appends + recover), so it must run LAST
    for its pipeline.
    """
    exe = f"{bundle.name}/recovery"
    srv = ContinuousBatchedServer(bundle, CFG, batch_size=2, chunk_iters=2)
    reqs = list(bundle.requests[:2])
    f_roll = rollback_findings(srv, reqs, exe)
    f_quar = quarantine_findings(srv, reqs, exe)
    f_cache = cache_integrity_findings(bundle, exe)
    f_store = store_recovery_findings(bundle, exe)
    facts = {
        "contract": "recovery",
        "rollback_bitwise": not f_roll,
        "quarantine_isolated": not f_quar,
        "store_recover_exact": not f_store,
        "cache_corruption_detected": not f_cache,
    }
    return exe, f_roll + f_quar + f_cache + f_store, facts


def check_flatness() -> tuple[str, list[LintFinding], dict[str, Any]]:
    """Incremental-AFC while-body flatness probe (pipeline-independent).

    Explicitly pins ``afc_backend="incremental"`` so the probe stays
    meaningful under the CI legs that force ``REPRO_AFC_BACKEND=ref`` —
    env overrides only apply to "auto".
    """
    exe = "probe/incremental_flatness"
    k = 3
    w = jnp.asarray([1.0, -2.0, 0.5])
    texts: dict[int, str] = {}
    for cap in FLATNESS_CAPS:
        fused = build_fused_executor(
            lambda rows, exact: rows @ w,
            k=k, task="regression", m=16, m_sobol=8, max_iters=8, n_boot=16,
            holistic=(1,), quantiles=(0.5,), afc_backend="incremental",
        )
        args = (
            jax.ShapeDtypeStruct((k, cap), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((0,), jnp.float32),
        )
        texts[cap] = jax.jit(fused).lower(*args).compile().as_text()
    findings = hlo_lint.check_while_flatness(texts, exe)
    facts = {
        "contract": "fused",
        "caps": list(FLATNESS_CAPS),
        "flat": not findings,
    }
    return exe, findings, facts


# ----------------------------------------------------------------- driver
def run_checks(
    pipelines: Sequence[str] = DEFAULT_PIPELINES, *, flatness: bool = True
) -> tuple[list[LintFinding], dict[str, dict[str, Any]]]:
    """Run every check; returns ``(findings, facts_by_executable)``."""
    n_dev = len(jax.devices())
    mesh_dev = next((d for d in (4, 2) if d <= n_dev and LANES % d == 0), 1)
    findings: list[LintFinding] = []
    facts: dict[str, dict[str, Any]] = {}
    for pname in pipelines:
        bundle = make_pipeline(pname, **SMALL)
        exe, f, fa = check_fused(bundle)
        findings += f
        facts[exe] = fa
        mesh = make_serving_mesh(mesh_dev)
        exe, f, fa = check_fused(bundle, mesh=mesh, n_devices=mesh_dev)
        findings += f
        facts[exe] = fa
        for exe, f, fa in check_continuous(bundle):
            findings += f
            facts[exe] = fa
        # LAST per pipeline: these probes mutate the store (append
        # coherence, then journaled appends + recovery)
        exe, f, fa = check_feature_cache(bundle)
        findings += f
        facts[exe] = fa
        exe, f, fa = check_recovery(bundle)
        findings += f
        facts[exe] = fa
    if flatness:
        exe, f, fa = check_flatness()
        findings += f
        facts[exe] = fa
    return findings, facts


def _baseline_diff(
    facts: dict[str, Any], baseline_path: Path
) -> list[str]:
    """Unified diff of observed facts vs the checked-in baseline."""
    got = json.dumps(facts, indent=2, sort_keys=True) + "\n"
    if not baseline_path.exists():
        return [f"baseline {baseline_path} missing — run with --update-baseline"]
    want = baseline_path.read_text()
    if want == got:
        return []
    return list(difflib.unified_diff(
        want.splitlines(), got.splitlines(),
        fromfile=str(baseline_path), tofile="<observed>", lineterm="",
    ))


def _run_mutations() -> int:
    """Run the seeded violations; returns the number NOT caught."""
    from repro.analysis import mutations

    missed = 0
    for name, fn in mutations.MUTATIONS.items():
        caught = fn()
        status = "caught" if caught else "MISSED"
        print(f"mutation {name:<24s} {status}")
        for f in caught:
            print(f"    {f}")
        if not caught:
            missed += 1
    return missed


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="Static contract checker for the serving executables.",
    )
    ap.add_argument("--pipelines", default=",".join(DEFAULT_PIPELINES),
                    help="comma-separated pipeline names (data/synthetic.py)")
    ap.add_argument("--baseline", type=Path, default=BASELINE_PATH,
                    help="facts baseline to diff against")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from this run's facts")
    ap.add_argument("--no-flatness", action="store_true",
                    help="skip the incremental-AFC flatness probe")
    ap.add_argument("--list", action="store_true",
                    help="print the registered contracts and exit")
    ap.add_argument("--mutation-test", action="store_true",
                    help="verify the checker catches every seeded violation")
    args = ap.parse_args(argv)

    if args.list:
        for name, c in sorted(all_contracts().items()):
            print(f"{name}: {json.dumps(c.as_dict(), indent=2)}")
        return 0

    rc = 0
    if args.mutation_test:
        missed = _run_mutations()
        if missed:
            print(f"FAIL: {missed} seeded mutation(s) not caught")
            return 1
        print("all seeded mutations caught")
        return 0

    pipelines = tuple(p for p in args.pipelines.split(",") if p)
    findings, facts = run_checks(pipelines, flatness=not args.no_flatness)
    for f in findings:
        print(f"VIOLATION {f}")
    if args.update_baseline:
        args.baseline.write_text(
            json.dumps(facts, indent=2, sort_keys=True) + "\n"
        )
        print(f"baseline written: {args.baseline}")
    else:
        diff = _baseline_diff(facts, args.baseline)
        if diff:
            print("baseline drift:")
            for line in diff:
                print(f"  {line}")
            rc = 1
    if findings:
        rc = 1
    n = len(facts)
    print(("FAIL" if rc else "OK") + f": {n} executables checked, "
          f"{len(findings)} violation(s)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
