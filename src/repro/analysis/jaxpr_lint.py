"""Jaxpr linting: RNG discipline, host-sync hazards, dtype drift.

Works on traced (un-lowered) programs, so violations are caught with jax
names still attached — ``random_split`` in a while body is reported as
exactly that, not as an opaque HLO fusion.  The walker recurses through
every nested jaxpr a primitive carries in its params (``while`` cond/body,
``scan``, ``cond`` branches, ``pjit``, custom-derivative wrappers), tracking
whether the current jaxpr executes inside a device loop body.

Checks (each returns a list of :class:`LintFinding`):

* :func:`check_rng` — counter-based RNG discipline.  Inside loop bodies,
  ``random_split`` is forbidden (bootstrap replicate draws must
  ``fold_in`` the per-request iteration counter on a closure key —
  ``executor_fused._executor_core.afc`` — or lane-recycling loses bitwise
  parity with serial replay), and the loop carry must not thread a PRNG
  key (neither a typed ``key<...>`` aval nor a raw u32 key that the body
  re-wraps and re-emits): a threaded key makes a lane's draw depend on how
  many iterations *previous occupants* of the carry ran.
* :func:`check_host_sync` — callback primitives (``pure_callback``,
  ``io_callback``, ``debug_callback``) anywhere in the program: each one is
  a device->host round trip serializing the hot path the fused executor
  exists to avoid.  (The other host-sync hazard — coercing a traced value
  to a Python bool — cannot appear in a jaxpr at all: it raises at trace
  time, and :func:`trace_for_lint` converts that raise into a finding.)
* :func:`check_dtypes` — weak-typed input avals (each one is a retrace
  waiting for a caller that promotes differently — the
  executables-per-bucket killer) and f64 leaks anywhere in the program.

Findings carry the violated contract *field* so the checker can report
"executable X violates contract Y: <message>" without string-matching.
"""
from __future__ import annotations

from dataclasses import dataclass
from collections.abc import Callable, Iterator
from typing import Any

import jax

try:  # jax >= 0.5 removes these from jax.core; jax.extend.core has both
    from jax.extend import core as jax_core
except ImportError:  # pragma: no cover - old jax
    from jax import core as jax_core  # type: ignore[no-redef]

__all__ = [
    "LintFinding",
    "check_dtypes",
    "check_host_sync",
    "check_rng",
    "iter_jaxprs",
    "lint_jaxpr",
    "trace_for_lint",
]

#: Primitives that are a device->host synchronization on every execution.
HOST_CALLBACK_PRIMITIVES = frozenset(
    {"pure_callback", "io_callback", "debug_callback", "callback"}
)

#: Loop primitives whose nested jaxprs execute once per iteration.
_LOOP_PRIMITIVES = frozenset({"while", "scan"})


@dataclass(frozen=True)
class LintFinding:
    """One contract violation found by a lint pass.

    ``contract`` names the violated :class:`ExecutableContract` field
    (``"rng"``, ``"collectives"``, ``"donated"``, ``"weak_type_inputs"``,
    ``"allow_f64"``, ``"while_body_flat"``, ``"host_sync"``), ``where`` the
    jaxpr path (e.g. ``"while.body"``) or HLO location, ``message`` the
    actionable description.
    """

    contract: str
    executable: str
    where: str
    message: str

    def __str__(self) -> str:
        return (
            f"[{self.executable}] contract {self.contract!r} violated at "
            f"{self.where}: {self.message}"
        )


def _as_jaxpr(obj: Any) -> Any:
    """Unwrap ClosedJaxpr -> Jaxpr; pass Jaxpr through; else None."""
    if isinstance(obj, jax_core.ClosedJaxpr):
        return obj.jaxpr
    if isinstance(obj, jax_core.Jaxpr):
        return obj
    return None


def iter_jaxprs(
    jaxpr: Any, path: str = "", in_loop: bool = False
) -> Iterator[tuple[str, Any, bool]]:
    """Yield ``(path, jaxpr, in_loop)`` for a jaxpr and every nested jaxpr.

    ``in_loop`` is True when the yielded jaxpr executes inside a device
    loop body (a ``while`` body or ``scan`` body, at any nesting depth).
    ``while`` *cond* jaxprs are visited but not marked as loop bodies —
    they run per trip too, but never mutate carried state, and the RNG
    rules only concern state evolution.
    """
    root = _as_jaxpr(jaxpr)
    if root is None:
        return
    yield path or "<root>", root, in_loop
    for i, eqn in enumerate(root.eqns):
        prim = eqn.primitive.name
        for key, val in eqn.params.items():
            vals = val if isinstance(val, (tuple, list)) else (val,)
            for j, sub in enumerate(vals):
                sub_j = _as_jaxpr(sub)
                if sub_j is None:
                    continue
                tag = f"{key}[{j}]" if isinstance(val, (tuple, list)) else key
                sub_path = f"{path}.{prim}:{i}.{tag}" if path else f"{prim}:{i}.{tag}"
                body = in_loop or (
                    prim in _LOOP_PRIMITIVES and "cond" not in key
                )
                yield from iter_jaxprs(sub, sub_path, body)


def _is_key_aval(aval: Any) -> bool:
    """Typed PRNG-key aval (``key<fry>[...]``)?"""
    dtype = getattr(aval, "dtype", None)
    if dtype is None:
        return False
    try:
        return jax.dtypes.issubdtype(dtype, jax.dtypes.prng_key)
    except TypeError:  # non-jax dtype objects
        return False


def _threaded_raw_key_slots(body_jaxpr: Any, nconsts: int) -> list[int]:
    """Carry slots that smell like a raw (u32) PRNG key threaded per trip.

    A raw key threaded through a while carry shows up as: the carry invar
    feeds ``random_wrap`` (the body consumes it as a key) AND the matching
    outvar is produced by ``random_unwrap`` (the body emits an *evolved*
    key back into the carry).  ``fold_in`` on a closure key never matches:
    its key is a constvar, not a carry slot.
    """
    jx = _as_jaxpr(body_jaxpr)
    if jx is None:
        return []
    wrapped_invars: set[Any] = set()
    unwrap_outvars: set[Any] = set()
    for eqn in jx.eqns:
        if eqn.primitive.name == "random_wrap":
            for v in eqn.invars:
                if isinstance(v, jax_core.Var):
                    wrapped_invars.add(v)
        if eqn.primitive.name == "random_unwrap":
            for v in eqn.outvars:
                unwrap_outvars.add(v)
    slots: list[int] = []
    carry_in = jx.invars[nconsts:]
    for idx, (iv, ov) in enumerate(zip(carry_in, jx.outvars)):
        emitted = isinstance(ov, jax_core.Var) and ov in unwrap_outvars
        if iv in wrapped_invars and emitted:
            slots.append(idx)
    return slots


def _subtree_has_fold_in(jaxpr: Any) -> bool:
    """Does the jaxpr tree contain a ``random_fold_in`` anywhere?"""
    for _, jx, _ in iter_jaxprs(jaxpr):
        for eqn in jx.eqns:
            if eqn.primitive.name == "random_fold_in":
                return True
    return False


def check_rng(jaxpr: Any, executable: str) -> list[LintFinding]:
    """Counter-based RNG discipline: no split, no key threaded in a carry.

    A loop body may fan a key out with ``random_split`` *provided* the
    body's RNG is rooted in a ``random_fold_in`` (the counter-based
    pattern: ``key = fold_in(base, it)`` then split into a fixed number of
    per-draw subkeys — draws depend only on the iteration index, so a
    recycled lane replays bitwise).  A body that splits with NO fold_in
    anywhere is evolving a key per trip — the parity breaker.  Carried
    keys (typed or raw) are flagged unconditionally.
    """
    findings: list[LintFinding] = []
    loop_bodies_without_fold_in: list[str] = []
    for path, jx, in_loop in iter_jaxprs(jaxpr):
        for i, eqn in enumerate(jx.eqns):
            prim = eqn.primitive.name
            if prim == "random_split" and in_loop and any(
                path == p or path.startswith(f"{p}.")
                for p in loop_bodies_without_fold_in
            ):
                findings.append(LintFinding(
                    contract="rng",
                    executable=executable,
                    where=f"{path}.eqn[{i}]",
                    message=(
                        "jax.random.split inside a loop body whose RNG is "
                        "not rooted in fold_in — per-iteration keys must "
                        "derive from fold_in on the iteration counter "
                        "(counter-based RNG keeps recycled-lane trajectories "
                        "bitwise-reproducible; see executor_fused._executor_core)"
                    ),
                ))
            if prim in _LOOP_PRIMITIVES:
                body = eqn.params.get("body_jaxpr") or eqn.params.get("jaxpr")
                nconsts = int(
                    eqn.params.get("body_nconsts", eqn.params.get("num_consts", 0))
                )
                body_jx = _as_jaxpr(body)
                if body_jx is None:
                    continue
                # parent jaxprs are always visited before their children, so
                # recording the body path here covers the splits inside it
                # (path format must mirror iter_jaxprs)
                base = "" if path == "<root>" else path
                tag = "body_jaxpr" if "body_jaxpr" in eqn.params else "jaxpr"
                body_path = f"{base}.{prim}:{i}.{tag}" if base else f"{prim}:{i}.{tag}"
                if not _subtree_has_fold_in(body):
                    loop_bodies_without_fold_in.append(body_path)
                for slot, iv in enumerate(body_jx.invars[nconsts:]):
                    if _is_key_aval(iv.aval):
                        findings.append(LintFinding(
                            contract="rng",
                            executable=executable,
                            where=f"{path}.eqn[{i}].carry[{slot}]",
                            message=(
                                f"PRNG key {iv.aval} threaded through the "
                                "loop carry — a carried key evolves with the "
                                "trip count, so a recycled lane's draws "
                                "depend on its predecessors; fold_in a "
                                "counter on a closure key instead"
                            ),
                        ))
                for slot in _threaded_raw_key_slots(body, nconsts):
                    findings.append(LintFinding(
                        contract="rng",
                        executable=executable,
                        where=f"{path}.eqn[{i}].carry[{slot}]",
                        message=(
                            "raw u32 PRNG key threaded through the loop "
                            "carry (random_wrap on the carry-in, "
                            "random_unwrap back into the carry-out) — "
                            "fold_in a counter on a closure key instead"
                        ),
                    ))
    return findings


def check_host_sync(jaxpr: Any, executable: str) -> list[LintFinding]:
    """Callback primitives = device->host round trips on the hot path."""
    findings: list[LintFinding] = []
    for path, jx, in_loop in iter_jaxprs(jaxpr):
        for i, eqn in enumerate(jx.eqns):
            if eqn.primitive.name in HOST_CALLBACK_PRIMITIVES:
                where_note = (
                    "inside a loop body — per-iteration"
                    if in_loop else "a per-dispatch"
                )
                findings.append(LintFinding(
                    contract="host_sync",
                    executable=executable,
                    where=f"{path}.eqn[{i}]",
                    message=(
                        f"{eqn.primitive.name} is {where_note} device->host "
                        "round trip; the fused hot path must stay on device "
                        "(move the callback outside the compiled program)"
                    ),
                ))
    return findings


def check_dtypes(
    jaxpr: Any,
    executable: str,
    *,
    allow_weak_inputs: bool = False,
    allow_f64: bool = False,
) -> list[LintFinding]:
    """Weak-typed inputs (retrace hazards) and f64 leaks."""
    findings: list[LintFinding] = []
    root = _as_jaxpr(jaxpr)
    if root is None:
        return findings
    if not allow_weak_inputs:
        for i, v in enumerate(root.invars):
            if getattr(v.aval, "weak_type", False):
                findings.append(LintFinding(
                    contract="weak_type_inputs",
                    executable=executable,
                    where=f"<root>.invars[{i}]",
                    message=(
                        f"input {i} has weak-typed aval {v.aval} — a raw "
                        "Python scalar reached the traced call; pin the "
                        "dtype at the call site (np.float32 / "
                        "jnp.asarray(x, jnp.float32)) or every promotion-"
                        "context change mints a new executable"
                    ),
                ))
    if not allow_f64:
        for path, jx, _ in iter_jaxprs(jaxpr):
            for i, eqn in enumerate(jx.eqns):
                for v in eqn.outvars:
                    dtype = getattr(v.aval, "dtype", None)
                    if dtype is not None and str(dtype) == "float64":
                        findings.append(LintFinding(
                            contract="allow_f64",
                            executable=executable,
                            where=f"{path}.eqn[{i}]",
                            message=(
                                f"{eqn.primitive.name} produces f64 {v.aval} "
                                "— the stack is pinned to f32 with "
                                "compensated accumulation; f64 doubles HBM "
                                "traffic and halves TPU throughput"
                            ),
                        ))
                        break  # one finding per eqn is enough
    return findings


def lint_jaxpr(
    jaxpr: Any,
    executable: str,
    *,
    rng: str = "counter_based",
    allow_weak_inputs: bool = False,
    allow_f64: bool = False,
) -> list[LintFinding]:
    """All jaxpr checks an :class:`ExecutableContract` implies, in one pass."""
    findings: list[LintFinding] = []
    if rng == "counter_based":
        findings += check_rng(jaxpr, executable)
    findings += check_host_sync(jaxpr, executable)
    findings += check_dtypes(
        jaxpr, executable,
        allow_weak_inputs=allow_weak_inputs, allow_f64=allow_f64,
    )
    return findings


def trace_for_lint(
    fn: Callable[..., Any], *args: Any, executable: str = "<fn>"
) -> tuple[Any, list[LintFinding]]:
    """Trace ``fn(*args)`` to a jaxpr, converting trace-time host-sync
    errors (coercing a traced value to a Python bool / implicit
    concretization) into findings instead of raising.

    Returns ``(closed_jaxpr_or_None, findings)`` — a None jaxpr means the
    trace itself failed, and the findings say why.
    """
    try:
        return jax.make_jaxpr(fn)(*args), []
    except jax.errors.TracerBoolConversionError as e:
        return None, [LintFinding(
            contract="host_sync",
            executable=executable,
            where="<trace>",
            message=(
                "traced value coerced to a Python bool — this is a "
                "device->host sync that would abort compilation of the hot "
                f"path (use lax.cond / jnp.where): {e}"
            ),
        )]
    except jax.errors.ConcretizationTypeError as e:
        return None, [LintFinding(
            contract="host_sync",
            executable=executable,
            where="<trace>",
            message=(
                "traced value concretized on the host (implicit "
                f"device-to-host transfer): {e}"
            ),
        )]
