"""Pure-JAX AdamW + gradient clipping + LR schedules (no optax on purpose —
the brief requires every substrate built in-repo).

The optimizer state is a plain PyTree mirroring the params PyTree, so it
shards with the same ``NamedSharding`` rules as the parameters (fully sharded
optimizer state — ZeRO-style — falls out of pjit for free) and checkpoints
through ``repro.checkpoint`` like any other tree.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "cosine_schedule",
    "linear_warmup_cosine",
]

PyTree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray  # () int32
    mu: PyTree         # first moment
    nu: PyTree         # second moment


def adamw_init(params: PyTree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=zeros,
        nu=jax.tree.map(jnp.copy, zeros),
    )


def adamw_update(
    grads: PyTree,
    state: AdamWState,
    params: PyTree,
    lr: jnp.ndarray | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> tuple[PyTree, AdamWState]:
    """One AdamW step; returns (new_params, new_state).

    Moments are kept in f32 even for bf16 params (mixed-precision training
    convention); the update is computed in f32 and cast back to the param
    dtype at the end.
    """
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1**t
    c2 = 1.0 - b2**t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    new = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([x[0] for x in new])
    new_m = treedef.unflatten([x[1] for x in new])
    new_v = treedef.unflatten([x[2] for x in new])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jnp.ndarray]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def cosine_schedule(base_lr: float, total_steps: int) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def sched(step):
        frac = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))

    return sched


def linear_warmup_cosine(
    base_lr: float, warmup: int, total_steps: int, min_frac: float = 0.1
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def sched(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        frac = jnp.clip((s - warmup) / jnp.maximum(total_steps - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1.0 - min_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return base_lr * jnp.where(s < warmup, warm, cos)

    return sched
