"""Gradient compression for cross-pod reduction (distributed-optimization).

On the multi-pod mesh the 'pod' axis is the slow (DCN-class) hop; the
standard trick is to reduce-scatter in-pod at full precision and compress
the cross-pod leg.  We implement int8 block-quantized all-reduce with
**error feedback** (the quantization residual is carried and added to the
next step's gradient — provably keeps SGD/Adam convergence).

Usage: wrap grads between backward and optimizer:

    grads, ef_state = compress_grads_for_pod(grads, ef_state, axis="pod")

On a single-pod mesh this is the identity.  The quantizer itself is exact
infrastructure (tested for round-trip error bounds in tests/); the actual
cross-pod psum placement is wired in train/step.py when ``compress_pod`` is
set (a §Perf knob: it cuts the 'pod'-axis collective term by ~4x at the cost
of <1e-2 relative gradient error per step).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compress_with_error_feedback"]


def quantize_int8(x: jnp.ndarray, block: int = 256):
    """Blockwise symmetric int8 quantization; returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), x.shape, pad


def dequantize_int8(q, scale, orig_shape, pad):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(orig_shape)


def compress_with_error_feedback(
    grads: Any, ef_state: Any | None, block: int = 256
) -> tuple[Any, Any, jnp.ndarray]:
    """Quantize grads with error feedback; returns (new_grads, ef, rel_err).

    new_grads are the dequantized (what the slow-axis reduce would carry);
    ef accumulates the per-leaf quantization residual for the next step.
    """
    if ef_state is None:
        ef_state = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s, shape, pad = quantize_int8(target, block)
        deq = dequantize_int8(q, s, shape, pad)
        return deq.astype(g.dtype), (target - deq)

    pairs = jax.tree.map(one, grads, ef_state)
    new_grads = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    num = sum(jnp.sum((a.astype(jnp.float32) - b.astype(jnp.float32)) ** 2)
              for a, b in zip(jax.tree.leaves(new_grads), jax.tree.leaves(grads)))
    den = sum(jnp.sum(b.astype(jnp.float32) ** 2) for b in jax.tree.leaves(grads))
    rel_err = jnp.sqrt(num / jnp.maximum(den, 1e-30))
    return new_grads, new_ef, rel_err
