"""Transformer layer primitives shared by the 10 assigned architectures.

Everything is functional: params are nested dicts of arrays, layers are pure
functions, and per-layer stacks are driven by ``jax.lax.scan`` in model.py
(stacked leaf arrays keep the HLO small enough that full-scale 236B configs
lower in seconds — essential for the 80-cell multi-pod dry-run on one CPU).

Attention comes in three flavors:

* ``attention_full``       — plain causal attention (short seqs / smoke);
* ``attention_blockwise``  — lax.scan over KV blocks with online softmax
  (flash-style memory behaviour in pure XLA: the (S, S) score matrix is never
  materialized — this is what makes the 32k-prefill cells compile inside HBM
  budgets; the Pallas ``flash_attention`` kernel is the TPU fast path with
  identical semantics);
* ``attention_decode``     — single-position query against a KV cache
  (optionally sliding-window for the hybrid long-context cells).

Numerics: bf16 params/activations, f32 for norms, softmax logits, and
routers — the standard TPU mixed-precision recipe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig

f32 = jnp.float32


# --------------------------------------------------------------------------
# Norms / positional
# --------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(f32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * w.astype(f32)).astype(x.dtype)


def _rotate_half(x):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([-x2, x1], axis=-1)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding; x: (..., S, H, D) or (..., S, D); positions: (..., S)."""
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=f32) / d))  # (d/2,)
    ang = positions.astype(f32)[..., None] * freqs  # (..., S, d/2)
    ang = jnp.concatenate([ang, ang], axis=-1)      # (..., S, d)
    if x.ndim == ang.ndim + 1:                      # head axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    return (x.astype(f32) * cos + _rotate_half(x.astype(f32)) * sin).astype(x.dtype)


# --------------------------------------------------------------------------
# Attention cores
# --------------------------------------------------------------------------
def _expand_kv(k: jnp.ndarray, n_q_heads: int) -> jnp.ndarray:
    """(B, S, Hkv, D) -> (B, S, Hq, D) by repeating each KV head."""
    hkv = k.shape[-2]
    if hkv == n_q_heads:
        return k
    return jnp.repeat(k, n_q_heads // hkv, axis=-2)


def attention_full(
    q: jnp.ndarray,           # (B, Sq, H, D)
    k: jnp.ndarray,           # (B, Sk, Hkv, D)
    v: jnp.ndarray,           # (B, Sk, Hkv, Dv)
    *,
    causal: bool,
    q_offset: int | jnp.ndarray = 0,
    window: int = 0,
    scale: float | None = None,
) -> jnp.ndarray:
    """Reference attention; materializes (B, H, Sq, Sk). Short-seq path."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(f32), k.astype(f32)) * scale
    qpos = jnp.asarray(q_offset) + jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(f32)).astype(q.dtype)


def attention_blockwise(
    q: jnp.ndarray,           # (B, Sq, H, D)
    k: jnp.ndarray,           # (B, Sk, Hkv, D)
    v: jnp.ndarray,           # (B, Sk, Hkv, Dv)
    *,
    causal: bool,
    q_offset: int = 0,
    window: int = 0,
    block: int = 1024,
    scale: float | None = None,
) -> jnp.ndarray:
    """Flash-style online-softmax attention via lax.scan over KV blocks.

    Never materializes the full score matrix: peak live score tile is
    (B, H, Sq_blk, block).  Both Sq and Sk are scanned, so 32k x 32k prefill
    attention costs O(block^2) live memory per (head, tile).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    dv = v.shape[-1]
    if sk % block != 0 or sq % block != 0:
        return attention_full(
            q, k, v, causal=causal, q_offset=q_offset, window=window, scale=scale
        )
    scale = scale if scale is not None else d ** -0.5
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    nq, nk = sq // block, sk // block
    qb = q.reshape(b, nq, block, h, d).transpose(1, 0, 3, 2, 4)  # (nq,B,H,bq,d)
    kb = k.reshape(b, nk, block, h, d).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, block, h, dv).transpose(1, 0, 3, 2, 4)

    def q_block(carry, qi):
        qt = qb[qi].astype(f32) * scale  # (B,H,bq,d)
        qpos = q_offset + qi * block + jnp.arange(block)

        def kv_step(state, ki):
            m, l, acc = state
            kt = kb[ki].astype(f32)
            vt = vb[ki].astype(f32)
            s = jnp.einsum("bhqd,bhkd->bhqk", qt, kt)  # (B,H,bq,bk)
            kpos = ki * block + jnp.arange(block)
            msk = jnp.ones((block, block), bool)
            if causal:
                msk &= kpos[None, :] <= qpos[:, None]
            if window > 0:
                msk &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(msk[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vt)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, h, block), -1e30, f32),
            jnp.zeros((b, h, block), f32),
            jnp.zeros((b, h, block, dv), f32),
        )
        # causal: only blocks with kpos_start <= qpos_end contribute; scanning
        # all keeps shapes static — the -1e30 mask zeroes the rest (the Pallas
        # kernel skips them for real; see kernels/flash_attention).
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return carry, out.transpose(0, 2, 1, 3)  # (B,bq,H,dv)

    _, blocks = jax.lax.scan(q_block, 0, jnp.arange(nq))  # (nq,B,bq,H,dv)
    return blocks.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dv).astype(q.dtype)


def attention_decode(
    q: jnp.ndarray,           # (B, 1, H, D)
    k_cache: jnp.ndarray,     # (B, S, Hkv, D)
    v_cache: jnp.ndarray,     # (B, S, Hkv, Dv)
    pos: jnp.ndarray,         # () int32 — current position (cache validity)
    *,
    window: int = 0,
    scale: float | None = None,
) -> jnp.ndarray:
    """One decode step against a (possibly windowed) KV cache.

    The reduction over S is the split-K / FlashDecoding axis — the dry-run
    shards it over the ``model`` mesh axis, turning the per-token attention
    into local partial-softmax + a tiny cross-chip reduce.
    """
    b, _, h, d = q.shape
    s = k_cache.shape[1]
    scale = scale if scale is not None else d ** -0.5
    k = _expand_kv(k_cache, h)
    v = _expand_kv(v_cache, h)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(f32), k.astype(f32)) * scale
    kpos = jnp.arange(s)
    valid = kpos[None, :] <= pos
    if window > 0:
        valid &= kpos[None, :] > pos - window
    logits = jnp.where(valid[None, :, None, :].transpose(0, 2, 1, 3), logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(f32)).astype(q.dtype)


# --------------------------------------------------------------------------
# Standard (GQA) attention block
# --------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    d, h, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    # Zero-pad q heads to a TP-divisible multiple (e.g. qwen3-14b's 40 -> 48
    # on a 16-way 'model' axis).  Padding is PER KV GROUP (interleaved): GQA
    # maps q head i to kv head i // (H/Hkv), so appending pad heads at the
    # end would silently remap every live head's kv group.  Padded heads have
    # zero wq AND zero wo rows, so the logical model is exact; KV heads are
    # never padded (zero keys would corrupt the softmax) — non-divisible KV
    # replicates instead (sharding.py divisibility guard).
    pad = cfg.pad_heads_to
    hp = ((h + pad - 1) // pad) * pad
    while hp % hkv != 0:  # keep per-group padding equal
        hp += pad
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = d ** -0.5
    wq = jax.random.normal(k1, (d, hp, hd)) * std
    wo = jax.random.normal(k4, (hp, hd, d)) * (h * hd) ** -0.5
    if hp != h:
        gq, gq_p = h // hkv, hp // hkv
        live = (jnp.arange(gq_p) < gq).astype(wq.dtype)       # per-group mask
        live = jnp.tile(live, hkv)                            # (hp,)
        wq = wq * live[None, :, None]
        wo = wo * live[:, None, None]
    p = {
        "wq": wq.astype(dtype),
        "wk": (jax.random.normal(k2, (d, hkv, hd)) * std).astype(dtype),
        "wv": (jax.random.normal(k3, (d, hkv, hd)) * std).astype(dtype),
        "wo": wo.astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hp, hd), dtype)
        p["bk"] = jnp.zeros((hkv, hd), dtype)
        p["bv"] = jnp.zeros((hkv, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attention_qkv(p: dict, x: jnp.ndarray, cfg: ModelConfig, positions) -> tuple:
    """Project + rope; returns (q, k, v) with shapes (B,S,H*,Dh)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    positions: jnp.ndarray | None = None,
    window: int = 0,
    block: int = 1024,
) -> jnp.ndarray:
    """Full-sequence attention (train / prefill)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = attention_qkv(p, x, cfg, positions)
    if s > 2 * block and s % block == 0:
        o = attention_blockwise(q, k, v, causal=causal, window=window, block=block)
    else:
        o = attention_full(q, k, v, causal=causal, window=window)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def attention_block_with_kv(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    window: int = 0,
    block: int = 1024,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Prefill attention that also returns (k, v) for cache population."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = attention_qkv(p, x, cfg, positions)
    if s > 2 * block and s % block == 0:
        o = attention_blockwise(q, k, v, causal=causal, window=window, block=block)
    else:
        o = attention_full(q, k, v, causal=causal, window=window)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), k, v


def attention_block_decode(
    p: dict,
    x: jnp.ndarray,           # (B, 1, D)
    cache_k: jnp.ndarray,     # (B, S, Hkv, Dh)
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,         # () int32 current position
    cfg: ModelConfig,
    *,
    window: int = 0,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode step: update cache at ``pos``, attend, project."""
    positions = jnp.full((x.shape[0], 1), pos)
    q, k, v = attention_qkv(p, x, cfg, positions)
    # windowed caches store ring-buffer style; full caches store absolute.
    slot = pos % cache_k.shape[1] if window > 0 else pos
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
    # Full cache: mask is ``slot <= pos``.  Windowed ring buffer: slot i
    # holds a key iff i <= pos on the first lap and always once wrapped;
    # softmax attention is permutation-invariant over keys (RoPE was applied
    # at write time with absolute positions), so the same mask is exact.
    o = attention_decode(q, cache_k, cache_v, pos, window=0)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), cache_k, cache_v


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2 latent attention)
# --------------------------------------------------------------------------
def init_mla(key, cfg: ModelConfig, dtype) -> dict:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    std = d ** -0.5
    return {
        "wq_a": (jax.random.normal(ks[0], (d, m.q_lora)) * std).astype(dtype),
        "q_norm": jnp.ones((m.q_lora,), dtype),
        "wq_b": (
            jax.random.normal(ks[1], (m.q_lora, h, m.nope_dim + m.rope_dim))
            * m.q_lora ** -0.5
        ).astype(dtype),
        "wkv_a": (
            jax.random.normal(ks[2], (d, m.kv_lora + m.rope_dim)) * std
        ).astype(dtype),
        "kv_norm": jnp.ones((m.kv_lora,), dtype),
        "wkv_b": (
            jax.random.normal(ks[3], (m.kv_lora, h, m.nope_dim + m.v_dim))
            * m.kv_lora ** -0.5
        ).astype(dtype),
        "wo": (
            jax.random.normal(ks[4], (h, m.v_dim, d)) * (h * m.v_dim) ** -0.5
        ).astype(dtype),
    }


def mla_block(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    positions: jnp.ndarray | None = None,
    block: int = 1024,
) -> jnp.ndarray:
    """MLA attention, naive-expansion path (train / prefill)."""
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    if positions is None:
        positions = jnp.arange(s)[None, :]
    cq = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsl,lhk->bshk", cq, p["wq_b"])
    q_nope, q_pe = q[..., : m.nope_dim], q[..., m.nope_dim :]
    q_pe = rope(q_pe, positions, cfg.rope_theta)

    ckv_full = x @ p["wkv_a"]                      # (B,S,kv_lora+rope)
    ckv = rms_norm(ckv_full[..., : m.kv_lora], p["kv_norm"], cfg.norm_eps)
    k_pe = rope(ckv_full[..., m.kv_lora :], positions, cfg.rope_theta)  # (B,S,r)

    kv = jnp.einsum("bsl,lhk->bshk", ckv, p["wkv_b"])
    k_nope, v = kv[..., : m.nope_dim], kv[..., m.nope_dim :]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (b, s, h, m.rope_dim))], -1
    )
    qq = jnp.concatenate([q_nope, q_pe], -1)
    scale = (m.nope_dim + m.rope_dim) ** -0.5
    if s > 2 * block and s % block == 0:
        o = attention_blockwise(qq, k, v, causal=True, block=block, scale=scale)
    else:
        o = attention_full(qq, k, v, causal=True, scale=scale)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def mla_block_with_cache(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    block: int = 1024,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """MLA prefill that also returns the latent cache (ckv, k_pe)."""
    m: MLAConfig = cfg.mla
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]
    ckv_full = x @ p["wkv_a"]
    ckv = rms_norm(ckv_full[..., : m.kv_lora], p["kv_norm"], cfg.norm_eps)
    k_pe = rope(ckv_full[..., m.kv_lora :], positions, cfg.rope_theta)
    out = mla_block(p, x, cfg, positions=positions, block=block)
    return out, ckv, k_pe


def mla_block_decode(
    p: dict,
    x: jnp.ndarray,            # (B, 1, D)
    cache_ckv: jnp.ndarray,    # (B, S, kv_lora)
    cache_kpe: jnp.ndarray,    # (B, S, rope_dim)
    pos: jnp.ndarray,
    cfg: ModelConfig,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """MLA decode with the *absorbed* latent-cache trick: the per-head K/V
    up-projections are folded into the query / output sides, so the cache
    holds only (kv_lora + rope) floats per token — the paper-config 512+64
    vs 128 heads x 256 for naive GQA (a 64x KV-cache shrink; this is why the
    MLA cells are memory-roofline winners in §Roofline)."""
    m: MLAConfig = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    positions = jnp.full((b, 1), pos)
    cq = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsl,lhk->bshk", cq, p["wq_b"])  # (B,1,H,nope+rope)
    q_nope, q_pe = q[..., : m.nope_dim], q[..., m.nope_dim :]
    q_pe = rope(q_pe, positions, cfg.rope_theta)

    ckv_full = x @ p["wkv_a"]
    ckv_new = rms_norm(ckv_full[..., : m.kv_lora], p["kv_norm"], cfg.norm_eps)
    kpe_new = rope(ckv_full[..., m.kv_lora :], positions, cfg.rope_theta)
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, ckv_new.astype(cache_ckv.dtype), pos, axis=1
    )
    cache_kpe = jax.lax.dynamic_update_slice_in_dim(
        cache_kpe, kpe_new.astype(cache_kpe.dtype), pos, axis=1
    )

    wkv_k = p["wkv_b"][..., : m.nope_dim]          # (kv_lora, H, nope)
    wkv_v = p["wkv_b"][..., m.nope_dim :]          # (kv_lora, H, v)
    q_lat = jnp.einsum("bshk,lhk->bshl", q_nope, wkv_k)  # (B,1,H,kv_lora)

    s = cache_ckv.shape[1]
    scale = (m.nope_dim + m.rope_dim) ** -0.5
    logits = (
        jnp.einsum("bshl,btl->bhst", q_lat.astype(f32), cache_ckv.astype(f32))
        + jnp.einsum("bshr,btr->bhst", q_pe.astype(f32), cache_kpe.astype(f32))
    ) * scale
    valid = jnp.arange(s)[None, :] <= pos
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    pr = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhst,btl->bshl", pr, cache_ckv.astype(f32))  # (B,1,H,l)
    o = jnp.einsum("bshl,lhk->bshk", o_lat, wkv_v.astype(f32))       # (B,1,H,v)
    out = jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype), p["wo"])
    return out, cache_ckv, cache_kpe


# --------------------------------------------------------------------------
# GLU FFN
# --------------------------------------------------------------------------
def init_ffn(key, d: int, f: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": (jax.random.normal(k1, (d, f)) * d ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(k2, (d, f)) * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(k3, (f, d)) * f ** -0.5).astype(dtype),
    }


def glu_ffn(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    g = x @ p["w_gate"]
    u = x @ p["w_up"]
    if act == "swiglu":
        g = jax.nn.silu(g.astype(f32)).astype(x.dtype)
    elif act == "geglu":
        g = jax.nn.gelu(g.astype(f32), approximate=True).astype(x.dtype)
    else:  # pragma: no cover
        raise ValueError(act)
    return (g * u) @ p["w_down"]
