"""State-space / recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

Training/prefill uses **chunked-parallel** forms (lax.scan over chunks; all
within-chunk work is batched matmuls so the MXU stays busy; the only
sequential dependence is the O(L/Q) inter-chunk state recurrence).  Decode
uses the exact O(1)-per-token recurrence on a carried state — this is what
makes the ``long_500k`` cells runnable for the ssm/hybrid archs while the
full-attention archs are skipped (DESIGN.md §Arch-applicability).

All decays are computed in log space and are <= 0 before exponentiation
(Mamba2), or explicitly stabilized with running-max stabilizers (mLSTM /
sLSTM, following the xLSTM appendix), so everything is overflow-safe in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.lm.layers import rms_norm

f32 = jnp.float32


def _fit_chunk(length: int, chunk: int) -> int:
    """Largest divisor of ``length`` not exceeding ``chunk`` (>=1)."""
    q = min(chunk, length)
    while length % q != 0:
        q -= 1
    return q


# ==========================================================================
# Mamba2 / SSD
# ==========================================================================
def init_mamba2(key, cfg: ModelConfig, dtype) -> dict:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    h = di // s.head_dim
    n = s.d_state
    ks = jax.random.split(key, 4)
    d_in = 2 * di + 2 * n + h  # z, x, B, C, dt
    return {
        "in_proj": (jax.random.normal(ks[0], (d, d_in)) * d ** -0.5).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, di + 2 * n)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * n,), dtype),
        "dt_bias": jnp.zeros((h,), f32),
        "a_log": jnp.zeros((h,), f32),       # A = -exp(a_log) = -1 at init
        "d_skip": jnp.ones((h,), f32),
        "out_norm": jnp.ones((di,), dtype),
        "out_proj": (jax.random.normal(ks[2], (di, d)) * di ** -0.5).astype(dtype),
    }


def _split_mamba_proj(proj: jnp.ndarray, cfg: ModelConfig):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    h = di // s.head_dim
    n = s.d_state
    z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    return z, xbc, dt, di, h, n


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d, window K.  xbc: (B, L, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return jax.nn.silu((out + b).astype(f32)).astype(xbc.dtype)


def _ssd_chunked(
    x: jnp.ndarray,   # (B, L, H, P)
    dt: jnp.ndarray,  # (B, L, H) positive
    a: jnp.ndarray,   # (H,) negative
    b_: jnp.ndarray,  # (B, L, N)
    c_: jnp.ndarray,  # (B, L, N)
    chunk: int,
    h0: jnp.ndarray | None = None,  # (B, H, N, P)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan (Mamba2 paper §6); returns (y, final_state)."""
    bsz, L, H, P = x.shape
    N = b_.shape[-1]
    Q = _fit_chunk(L, chunk)
    nc = L // Q
    lga = (dt * a[None, None, :]).astype(f32)       # (B,L,H) log-decay <= 0
    xbar = (x.astype(f32) * dt[..., None])          # (B,L,H,P)

    def rs(t, tail):  # (B, L, ...) -> (nc, B, Q, ...)
        return t.reshape(bsz, nc, Q, *tail).transpose(1, 0, 2, *range(3, 3 + len(tail)))

    lga_c = rs(lga, (H,))
    x_c = rs(xbar, (H, P))
    b_c = rs(b_.astype(f32), (N,))
    c_c = rs(c_.astype(f32), (N,))

    init = jnp.zeros((bsz, H, N, P), f32) if h0 is None else h0.astype(f32)

    def step(h_prev, inputs):
        lg, xc, bc, cc = inputs                      # (B,Q,H), (B,Q,H,P), (B,Q,N)x2
        cum = jnp.cumsum(lg, axis=1)                 # (B,Q,H) inclusive
        cum_t = cum.transpose(0, 2, 1)               # (B,H,Q)
        total = cum_t[:, :, -1]                      # (B,H)
        # ---- intra-chunk (masked decay attention) --------------------------
        scores = jnp.einsum("bin,bjn->bij", cc, bc)  # (B,Q,Q)
        decay = jnp.exp(cum_t[:, :, :, None] - cum_t[:, :, None, :])  # (B,H,Q,Q)
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        w = scores[:, None] * jnp.where(mask[None, None], decay, 0.0)
        y = jnp.einsum("bhij,bjhp->bihp", w, xc)
        # ---- inter-chunk (carried state) -----------------------------------
        y = y + jnp.einsum("bin,bhnp->bihp", cc, h_prev) * jnp.exp(cum)[..., None]
        # ---- state update ----------------------------------------------------
        to_end = jnp.exp(total[:, None, :] - cum)    # (B,Q,H)
        xw = xc * to_end[..., None]
        h_new = jnp.exp(total)[:, :, None, None] * h_prev + jnp.einsum(
            "bjn,bjhp->bhnp", bc, xw
        )
        return h_new, y

    h_fin, ys = jax.lax.scan(step, init, (lga_c, x_c, b_c, c_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, L, H, P)
    return y, h_fin


def mamba2_block(
    p: dict, x: jnp.ndarray, cfg: ModelConfig, *, return_state: bool = False
):
    """Full-sequence Mamba2 block (train / prefill).  x: (B, L, D).

    With ``return_state`` also returns (final ssm state, conv-window tail),
    i.e. exactly what :func:`mamba2_decode` needs to continue the sequence.
    """
    s = cfg.ssm
    proj = x @ p["in_proj"]
    z, xbc_raw, dtr, di, h, n = _split_mamba_proj(proj, cfg)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xs, b_, c_ = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dtr.astype(f32) + p["dt_bias"])     # (B,L,H)
    a = -jnp.exp(p["a_log"])
    xh = xs.reshape(*xs.shape[:2], h, s.head_dim)
    y, h_fin = _ssd_chunked(xh, dt, a, b_, c_, s.chunk)
    y = y + p["d_skip"][None, None, :, None] * xh.astype(f32)
    y = y.reshape(*xs.shape[:2], di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(f32)).astype(x.dtype)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        k = s.d_conv
        conv_tail = xbc_raw[:, -(k - 1) :, :]                # (B, K-1, di+2N)
        return out, h_fin, conv_tail
    return out


def mamba2_decode(
    p: dict,
    x: jnp.ndarray,            # (B, 1, D)
    conv_state: jnp.ndarray,   # (B, K-1, di + 2N)
    ssm_state: jnp.ndarray,    # (B, H, N, P)
    cfg: ModelConfig,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """O(1) recurrent decode step."""
    s = cfg.ssm
    proj = x @ p["in_proj"]
    z, xbc, dtr, di, h, n = _split_mamba_proj(proj[:, 0], cfg)
    # conv over the ring of last K inputs
    win = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # (B,K,C)
    conv = jnp.einsum("bkc,kc->bc", win, p["conv_w"]) + p["conv_b"]
    conv = jax.nn.silu(conv.astype(f32)).astype(x.dtype)
    conv_state = win[:, 1:, :]
    xs, b_, c_ = jnp.split(conv, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dtr.astype(f32) + p["dt_bias"])      # (B,H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a[None, :])                          # (B,H)
    xh = xs.reshape(-1, h, s.head_dim).astype(f32)            # (B,H,P)
    xbar = xh * dt[..., None]
    ssm_state = decay[:, :, None, None] * ssm_state + jnp.einsum(
        "bn,bhp->bhnp", b_.astype(f32), xbar
    )
    y = jnp.einsum("bn,bhnp->bhp", c_.astype(f32), ssm_state)
    y = y + p["d_skip"][None, :, None] * xh
    y = y.reshape(-1, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(f32)).astype(x.dtype)[:, None, :]
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    return y @ p["out_proj"], conv_state, ssm_state


# ==========================================================================
# mLSTM (xLSTM matrix-memory cell), chunkwise-parallel + recurrent
# ==========================================================================
def init_mlstm(key, cfg: ModelConfig, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    h = cfg.n_heads
    ks = jax.random.split(key, 7)
    std = d ** -0.5
    return {
        "w_q": (jax.random.normal(ks[0], (d, di)) * std).astype(dtype),
        "w_k": (jax.random.normal(ks[1], (d, di)) * std).astype(dtype),
        "w_v": (jax.random.normal(ks[2], (d, di)) * std).astype(dtype),
        "w_i": (jax.random.normal(ks[3], (d, h)) * std).astype(f32),
        "w_f": (jax.random.normal(ks[4], (d, h)) * std).astype(f32),
        "b_i": jnp.zeros((h,), f32),
        "b_f": jnp.full((h,), 3.0, f32),  # open forget gates at init
        "w_gate": (jax.random.normal(ks[5], (d, di)) * std).astype(dtype),
        "out_norm": jnp.ones((di,), dtype),
        "out_proj": (jax.random.normal(ks[6], (di, d)) * di ** -0.5).astype(dtype),
    }


def _mlstm_chunked(q, k, v, log_i, log_f, chunk, state=None, compute_dtype=f32):
    """Stabilized chunkwise mLSTM.

    q,k,v: (B,L,H,P); log_i/log_f: (B,L,H).
    state: (C (B,H,P,P), n (B,H,P), m (B,H)) with true scale exp(m)·stored.
    Returns (h (B,L,H,P), final state).

    ``compute_dtype=bf16`` keeps the big (B,Q,H,P) operands of the chunk
    einsums in bf16 (f32 accumulation via preferred_element_type); the
    carried state and all gate/log math stay f32.  Halves the memory-term
    bytes of the chunk scan (§Perf hillclimb 3, iteration 2).
    """
    bsz, L, H, P = q.shape
    Q = _fit_chunk(L, chunk)
    nc = L // Q
    scale = P ** -0.5

    def rs(t, tail):
        return t.reshape(bsz, nc, Q, *tail).transpose(1, 0, 2, *range(3, 3 + len(tail)))

    qc, kc, vc = (
        rs(q.astype(compute_dtype), (H, P)),
        rs(k.astype(compute_dtype), (H, P)),
        rs(v.astype(compute_dtype), (H, P)),
    )
    lic, lfc = rs(log_i, (H,)), rs(log_f, (H,))

    if state is None:
        state = (
            jnp.zeros((bsz, H, P, P), f32),
            jnp.zeros((bsz, H, P), f32),
            jnp.full((bsz, H), -1e30, f32),
        )

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, li, lf = inp
        b = jnp.cumsum(lf, axis=1).transpose(0, 2, 1)      # (B,H,Q) inclusive
        li_t = li.transpose(0, 2, 1)                       # (B,H,Q)
        total = b[:, :, -1]                                # (B,H)
        # log-weight of key j for query i (j <= i): b_i - b_j + log_i_j
        logw = b[:, :, :, None] - b[:, :, None, :] + li_t[:, :, None, :]
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        logw = jnp.where(mask[None, None], logw, -jnp.inf)
        m_intra = jnp.max(logw, axis=-1)                   # (B,H,Q)
        m_inter = m[:, :, None] + b                        # (B,H,Q)
        m_i = jnp.maximum(jnp.maximum(m_intra, m_inter), -1e30)
        w = jnp.exp(logw - m_i[..., None])                 # (B,H,Q,Q)
        qk = jnp.einsum("bihp,bjhp->bhij", qt, kt,
                        preferred_element_type=f32) * scale
        num = jnp.einsum("bhij,bjhp->bihp", w * qk, vt)
        den = jnp.sum(w * qk, axis=-1)                     # (B,H,Q)
        inter_scale = jnp.exp(m_inter - m_i)               # (B,H,Q)
        num = num + jnp.einsum("bihp,bhpr->bihr", qt * scale, C) * (
            inter_scale.transpose(0, 2, 1)[..., None]
        )
        den = den + jnp.einsum("bihp,bhp->bhi", qt * scale, n) * inter_scale
        hden = jnp.maximum(jnp.abs(den), jnp.exp(-m_i))    # (B,H,Q)
        h = num / hden.transpose(0, 2, 1)[..., None]       # (B,Q,H,P)
        # ---- state update -------------------------------------------------
        lw_state = total[:, :, None] - b + li_t            # (B,H,Q) log-weights
        m_new = jnp.maximum(m + total, jnp.max(lw_state, axis=-1))
        sw = jnp.exp(lw_state - m_new[:, :, None])         # (B,H,Q)
        C_new = jnp.exp(m + total - m_new)[:, :, None, None] * C + jnp.einsum(
            "bhj,bjhp,bjhr->bhpr", sw, kt, vt
        )
        n_new = jnp.exp(m + total - m_new)[:, :, None] * n + jnp.einsum(
            "bhj,bjhp->bhp", sw, kt
        )
        return (C_new, n_new, m_new), h

    (C, n, m), hs = jax.lax.scan(step, state, (qc, kc, vc, lic, lfc))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(bsz, L, H, P)
    return h, (C, n, m)


def mlstm_block(
    p: dict, x: jnp.ndarray, cfg: ModelConfig, *, return_state: bool = False
):
    s = cfg.ssm
    bsz, L, d = x.shape
    h_heads = cfg.n_heads
    di = s.expand * d
    P = di // h_heads
    q = (x @ p["w_q"]).reshape(bsz, L, h_heads, P)
    k = (x @ p["w_k"]).reshape(bsz, L, h_heads, P)
    v = (x @ p["w_v"]).reshape(bsz, L, h_heads, P)
    # NB (§Perf, refuted hypothesis): constraining the P head_dim onto the
    # TP axis to shard the (B,H,P,P) matrix memory was measured WORSE —
    # P is the contracted dim of the qk/num einsums, so sharding it turns
    # every chunk step into a cross-shard partial-sum (collective term
    # 9.4s -> 25.7s on xlstm train_4k).  Keep P replicated; memory is
    # attacked via bf16 chunk inputs instead (mlstm_compute_dtype).
    # xLSTM uses an *exponential* input gate: log i = the preactivation itself
    li = x.astype(f32) @ p["w_i"] + p["b_i"]
    lf = jax.nn.log_sigmoid(x.astype(f32) @ p["w_f"] + p["b_f"])
    # chunk einsum operands in the model dtype (bf16 on TPU), f32 accumulation
    y, state = _mlstm_chunked(q, k, v, li, lf, s.chunk, compute_dtype=x.dtype)
    y = y.reshape(bsz, L, di).astype(x.dtype)
    y = y * jax.nn.silu((x @ p["w_gate"]).astype(f32)).astype(x.dtype)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        return out, state
    return out


def mlstm_decode(
    p: dict, x: jnp.ndarray, state: tuple, cfg: ModelConfig
) -> tuple[jnp.ndarray, tuple]:
    """x: (B,1,D); state: (C, n, m)."""
    s = cfg.ssm
    bsz, _, d = x.shape
    H = cfg.n_heads
    di = s.expand * d
    P = di // H
    xt = x[:, 0]
    q = (xt @ p["w_q"]).reshape(bsz, H, P).astype(f32) * P ** -0.5
    k = (xt @ p["w_k"]).reshape(bsz, H, P).astype(f32)
    v = (xt @ p["w_v"]).reshape(bsz, H, P).astype(f32)
    li = xt.astype(f32) @ p["w_i"] + p["b_i"]                # (B,H)
    lf = jax.nn.log_sigmoid(xt.astype(f32) @ p["w_f"] + p["b_f"])
    C, n, m = state
    m_new = jnp.maximum(lf + m, li)
    C = jnp.exp(lf + m - m_new)[:, :, None, None] * C + jnp.exp(li - m_new)[
        :, :, None, None
    ] * jnp.einsum("bhp,bhr->bhpr", k, v)
    n = jnp.exp(lf + m - m_new)[:, :, None] * n + jnp.exp(li - m_new)[:, :, None] * k
    num = jnp.einsum("bhp,bhpr->bhr", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q, n)), jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(bsz, 1, di).astype(x.dtype)
    y = y * jax.nn.silu((x @ p["w_gate"]).astype(f32)).astype(x.dtype)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    return y @ p["out_proj"], (C, n, m_new)


# ==========================================================================
# sLSTM (scalar-memory cell with exponential gating)
# ==========================================================================
def init_slstm(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    hs = cfg.n_heads
    dh = d // hs
    ks = jax.random.split(key, 4)
    return {
        "w": (jax.random.normal(ks[0], (d, 4 * d)) * d ** -0.5).astype(f32),
        "r": (jax.random.normal(ks[1], (hs, dh, 4 * dh)) * dh ** -0.5).astype(f32),
        "b": jnp.concatenate(
            [jnp.zeros((2 * d,), f32), jnp.full((d,), 3.0, f32), jnp.zeros((d,), f32)]
        ),
        "out_norm": jnp.ones((d,), dtype),
        "up": (jax.random.normal(ks[2], (d, 4 * d // 3)) * d ** -0.5).astype(dtype),
        "down": (
            jax.random.normal(ks[3], (4 * d // 3, d)) * (4 * d // 3) ** -0.5
        ).astype(dtype),
    }


def _slstm_scan(p, x_seq: jnp.ndarray, cfg: ModelConfig, state=None):
    """x_seq: (B, L, D) -> (h (B,L,D), final state).  Sequential lax.scan."""
    bsz, L, d = x_seq.shape
    hs = cfg.n_heads
    dh = d // hs
    if state is None:
        zeros = jnp.zeros((bsz, d), f32)
        state = (zeros, zeros, jnp.full((bsz, d), -1e30, f32), zeros)  # c,n,m,h

    wx = x_seq.astype(f32) @ p["w"] + p["b"]  # (B,L,4D): precompute input part

    def step(carry, wx_t):
        c, n, m, h_prev = carry
        rec = jnp.einsum(
            "bhd,hdk->bhk", h_prev.reshape(bsz, hs, dh), p["r"]
        ).reshape(bsz, 4 * d)
        za, ia, fa, oa = jnp.split(wx_t + rec, 4, axis=-1)
        z = jnp.tanh(za)
        log_i = ia
        log_f = jax.nn.log_sigmoid(fa)
        o = jax.nn.sigmoid(oa)
        m_new = jnp.maximum(log_f + m, log_i)
        c_new = jnp.exp(log_f + m - m_new) * c + jnp.exp(log_i - m_new) * z
        n_new = jnp.exp(log_f + m - m_new) * n + jnp.exp(log_i - m_new)
        h = o * c_new / jnp.maximum(n_new, jnp.exp(-m_new))
        return (c_new, n_new, m_new, h), h

    final, hs_seq = jax.lax.scan(step, state, wx.transpose(1, 0, 2))
    return hs_seq.transpose(1, 0, 2), final


def slstm_block(
    p: dict, x: jnp.ndarray, cfg: ModelConfig, *, return_state: bool = False
):
    h, state = _slstm_scan(p, x, cfg)
    h = rms_norm(h.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    u = jax.nn.gelu((h @ p["up"]).astype(f32), approximate=True).astype(x.dtype)
    out = u @ p["down"]
    if return_state:
        return out, state
    return out


def slstm_decode(p: dict, x: jnp.ndarray, state: tuple, cfg: ModelConfig):
    h, new_state = _slstm_scan(p, x, cfg, state)
    h = rms_norm(h.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    u = jax.nn.gelu((h @ p["up"]).astype(f32), approximate=True).astype(x.dtype)
    return u @ p["down"], new_state
