"""Sharding rules: logical axes -> mesh axes, param/activation/cache specs.

Strategy (classic 2D/3D: DP x TP, optional pod axis composing with DP):

* batch            -> ('pod', 'data')      (gradient all-reduce hierarchy)
* attention heads  -> 'model'              (Megatron TP; GSPMD pads uneven
                                            head counts like 40 or 14)
* kv heads         -> 'model' iff divisible, else replicated (GQA small-kv)
* ffn hidden / moe expert axis / vocab -> 'model'
* decode KV-cache sequence -> 'model'      (split-K / FlashDecoding reduce)
* ssm state heads (or head_dim when heads < tp) -> 'model'

``constrain(x, spec)`` is a no-op unless a mesh context is active, so model
code is importable and runnable on a single host with zero ceremony.
"""
from __future__ import annotations

import contextlib
import re
from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

__all__ = [
    "ShardingRules",
    "use_rules",
    "active_rules",
    "constrain",
    "param_pspecs",
    "batch_pspec",
    "cache_pspecs",
]

_ACTIVE: list["ShardingRules"] = []


@dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    cfg: ModelConfig
    dp_axes: tuple[str, ...] = ("data",)   # ('pod','data') on the multi-pod mesh
    tp_axis: str = "model"
    # FSDP / ZeRO-3: additionally shard every large param's biggest free dim
    # over 'data' (weights are all-gathered per layer by GSPMD).  Required
    # for cells whose TP-16 param+optimizer shard exceeds HBM (deepseek-v2:
    # 154 GB/dev TP-only -> 9.6 GB/dev with FSDP; §Perf hillclimb 2).
    fsdp: bool = False
    fsdp_min_elems: int = 1 << 20

    @property
    def tp(self) -> int:
        return self.mesh.shape[self.tp_axis]

    def dp(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.dp_axes]))

    # logical resolution -----------------------------------------------------
    def axis(self, logical: str | None):
        if logical is None:
            return None
        if logical == "batch":
            if not self.dp_axes:
                return None
            return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]
        if logical == "model":
            return self.tp_axis
        if logical == "kv_heads":
            return self.tp_axis if self.cfg.n_kv_heads % self.tp == 0 else None
        raise KeyError(logical)

    def pspec(self, *logical) -> P:
        return P(*[self.axis(l) for l in logical])

    def sharding(self, *logical) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(*logical))


@contextlib.contextmanager
def use_rules(rules: ShardingRules):
    _ACTIVE.append(rules)
    try:
        with rules.mesh:
            yield rules
    finally:
        _ACTIVE.pop()


def active_rules() -> ShardingRules | None:
    return _ACTIVE[-1] if _ACTIVE else None


def constrain(x, *logical):
    """with_sharding_constraint against the active rules (no-op otherwise)."""
    r = active_rules()
    if r is None:
        return x
    return jax.lax.with_sharding_constraint(x, r.sharding(*logical))


# --------------------------------------------------------------------------
# Parameter specs by path pattern
# --------------------------------------------------------------------------
# (regex over '/'-joined path, spec builder given leaf ndim)
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$", ("model", None)),                  # (V, D) vocab-sharded
    (r"unembed$", (None, "model")),                # (D, V)
    (r"frontend_adapter$", (None, None)),
    (r"(wq|wk|wv)$", (None, "model", None)),       # (D, H, hd) head-sharded
    (r"wo$", ("model", None, None)),               # (H, hd, D)
    (r"(bq|bk|bv)$", ("model", None)),             # (H, hd)
    (r"wq_a$", (None, None)),                      # MLA low-rank: small, replicated
    (r"wq_b$", (None, "model", None)),
    (r"wkv_a$", (None, None)),
    (r"wkv_b$", (None, "model", None)),
    (r"(w_gate|w_up)$", (None, "model")),          # dense FFN (D, F)
    (r"w_down$", ("model", None)),                 # (F, D)
    (r"router$", (None, None)),
    (r"experts?/(w_gate|w_up)$", ("model", None, None)),  # (E, D, F) EP
    (r"experts?/w_down$", ("model", None, None)),
    (r"in_proj$", (None, "model")),                # mamba (D, d_in)
    (r"out_proj$", ("model", None)),               # (di, D)
    (r"(w_q|w_k|w_v)$", (None, "model")),          # mlstm (D, di)
    (r"^.*conv_[wb]$", None),                      # replicate small tensors
    (r"(a_log|d_skip|dt_bias|b_i|b_f|w_i|w_f)$", None),
    (r"slstm.*/w$", (None, "model")),
    (r"slstm.*/r$", None),
    (r"up$", (None, "model")),
    (r"down$", ("model", None)),
]


def _match_spec(path: str, shape: tuple, rules: ShardingRules) -> P:
    ndim = len(shape)
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path):
            if spec is None:
                return P()
            # leading stacked-layer axes are never sharded: left-pad with None
            pad = ndim - len(spec)
            if pad < 0:
                return P()
            logical = (None,) * pad + tuple(spec)
            resolved = [rules.axis(l) for l in logical]
            # divisibility guard: jit in_shardings require exact divisibility
            # (e.g. granite's 8 KV heads on a 16-way model axis -> replicate)
            for i, ax in enumerate(resolved):
                if ax is None:
                    continue
                size = rules.mesh.shape[ax] if isinstance(ax, str) else int(
                    np.prod([rules.mesh.shape[a] for a in ax])
                )
                if shape[i] % size != 0:
                    resolved[i] = None
            if rules.fsdp and int(np.prod(shape)) >= rules.fsdp_min_elems:
                dp = rules.axis("batch")
                dp_size = (
                    0 if dp is None else
                    rules.mesh.shape[dp] if isinstance(dp, str) else
                    int(np.prod([rules.mesh.shape[a] for a in dp]))
                )
                if dp_size > 1:
                    # biggest still-unsharded, divisible dim gets 'data'
                    free = [
                        (shape[i], i) for i, ax in enumerate(resolved)
                        if ax is None and shape[i] % dp_size == 0
                    ]
                    if free:
                        _, i = max(free)
                        resolved[i] = dp
            return P(*resolved)
    return P()  # default: replicate (norm scales, biases, gates)


def param_pspecs(rules: ShardingRules, params_tree) -> dict:
    """PyTree of PartitionSpec mirroring ``params_tree`` (shapes or arrays)."""

    def walk(subtree, path):
        if isinstance(subtree, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in subtree.items()}
        if isinstance(subtree, (list, tuple)):
            return type(subtree)(walk(v, f"{path}/{i}") for i, v in enumerate(subtree))
        # moe expert tensors live under 'moe/' with 3D leaves (E, D, F)
        p = path
        if re.search(r"moe/(w_gate|w_up|w_down)$", path):
            p = path.replace("moe/", "moe/experts/")
        return _match_spec(p, tuple(subtree.shape), rules)

    return walk(params_tree, "")


def batch_pspec(rules: ShardingRules, kind: str, global_batch: int) -> dict:
    """Input specs: tokens/labels batch-sharded when divisible, else replicated."""
    b_axis = "batch" if global_batch % rules.dp() == 0 else None
    spec = {
        "tokens": rules.pspec(b_axis, None),
    }
    if rules.cfg.frontend:
        spec["frontend"] = rules.pspec(b_axis, None, None)
    return spec


def cache_pspecs(rules: ShardingRules, cache_tree, global_batch: int | None = None) -> dict:
    """Decode-cache specs: batch on DP, cache sequence on TP (split-K)."""
    if global_batch is not None and global_batch % rules.dp() != 0:
        # e.g. long_500k single-stream decode: batch cannot data-parallelize
        rules = ShardingRules(rules.mesh, rules.cfg, dp_axes=(), tp_axis=rules.tp_axis)

    def leaf_spec(path: str, ndim: int) -> P:
        if path.endswith("pos"):
            return P()
        if re.search(r"(ckv|kpe)", path):       # MLA latent: (L?, B, S, r)
            pad = ndim - 3
            return rules.pspec(*(None,) * pad, "batch", "model", None)
        if re.search(r"/(k|v)$", path):          # (L?, B, S, H, hd)
            pad = ndim - 4
            return rules.pspec(*(None,) * pad, "batch", "model", None, None)
        if re.search(r"conv$", path):            # (.., B, K-1, C)
            pad = ndim - 3
            return rules.pspec(*(None,) * pad, "batch", None, "model")
        if re.search(r"ssm$", path):             # (.., B, H, N, P)
            pad = ndim - 4
            return rules.pspec(*(None,) * pad, "batch", "model", None, None)
        if re.search(r"mC$", path):              # (.., B, H, P, P)
            pad = ndim - 4
            return rules.pspec(*(None,) * pad, "batch", None, "model", None)
        if re.search(r"mn$", path):              # (.., B, H, P)
            pad = ndim - 3
            return rules.pspec(*(None,) * pad, "batch", None, "model")
        if re.search(r"mm$", path):              # (.., B, H)
            pad = ndim - 2
            return rules.pspec(*(None,) * pad, "batch", None)
        if re.search(r"s[cnmh]$", path):         # slstm scalar states (.., B, D)
            pad = ndim - 2
            return rules.pspec(*(None,) * pad, "batch", "model")
        if re.search(r"enc_out$", path):         # (B, S_enc, D)
            return rules.pspec("batch", None, None)
        return P()

    def walk(subtree, path):
        if isinstance(subtree, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in subtree.items()}
        return leaf_spec(path, len(subtree.shape))

    return walk(cache_tree, "")
