"""LM model zoo: the 10 assigned architectures behind one composable stack."""
from repro.models.lm.model import LM

__all__ = ["LM"]
