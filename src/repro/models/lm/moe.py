"""Mixture-of-Experts FFN: token-choice top-k with two dispatch backends.

* ``moe_ffn_einsum``  — GShard-style grouped one-hot einsum dispatch.  SPMD-
  friendly (the dispatch tensors partition cleanly over the mesh; resharding
  token-sharded activations against expert-sharded weights makes XLA emit the
  expected all-to-alls), used for the multi-pod dry-run baseline.  Its known
  cost: the dispatch/combine einsums add ~S_g·cf/(3·d_ff) of the expert FLOPs
  as overhead — visible in §Roofline's MODEL_FLOPS/HLO_FLOPS ratio and
  attacked in the §Perf hillclimb.

* ``moe_ffn_sorted``  — sort-based ragged dispatch (argsort by expert,
  scatter into (E, C, D) buffers, batched expert GEMMs, scatter-add back).
  No dispatch matmul at all: FLOPs are exactly the expert GEMMs.  This is the
  single-shard fast path and the shape the TPU kernel wants; used per data
  shard (where the sort is local) in the optimized config.

Both honor expert capacity C = tokens·top_k/E·capacity_factor with
drop-on-overflow (standard GShard semantics) and renormalized top-k gates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig

f32 = jnp.float32


def init_moe(key, d: int, cfg: MoEConfig, dtype) -> dict:
    ks = jax.random.split(key, 5)
    e, fe = cfg.n_experts, cfg.d_ff_expert
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * d ** -0.5).astype(f32),
        "w_gate": (jax.random.normal(ks[1], (e, d, fe)) * d ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, fe)) * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, fe, d)) * fe ** -0.5).astype(dtype),
    }
    if cfg.n_shared:
        fs = max(cfg.d_ff_shared, cfg.d_ff_expert) * cfg.n_shared
        kg, ku, kd = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": (jax.random.normal(kg, (d, fs)) * d ** -0.5).astype(dtype),
            "w_up": (jax.random.normal(ku, (d, fs)) * d ** -0.5).astype(dtype),
            "w_down": (jax.random.normal(kd, (fs, d)) * fs ** -0.5).astype(dtype),
        }
    return p


def _router(p, x_flat: jnp.ndarray, cfg: MoEConfig):
    """Top-k routing with renormalized gates; router math in f32."""
    logits = x_flat.astype(f32) @ p["router"]                # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)             # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx


def _expert_gemm(p, h: jnp.ndarray, act_dtype) -> jnp.ndarray:
    """(E, C, D) -> (E, C, D) batched SwiGLU expert FFN."""
    g = jnp.einsum("ecd,edf->ecf", h, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", h, p["w_up"])
    g = jax.nn.silu(g.astype(f32)).astype(act_dtype)
    return jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])


def _shared_ffn(p, x: jnp.ndarray) -> jnp.ndarray:
    sp = p["shared"]
    g = jax.nn.silu((x @ sp["w_gate"]).astype(f32)).astype(x.dtype)
    return (g * (x @ sp["w_up"])) @ sp["w_down"]


def moe_ffn_einsum(p: dict, x: jnp.ndarray, cfg: MoEConfig) -> jnp.ndarray:
    """GShard grouped-einsum dispatch.  x: (B, S, D) -> (B, S, D).

    Tokens are split into groups of ``cfg.group_size``; the group axis maps
    onto the data-parallel mesh axes (it is a reshape of (B, S)), experts map
    onto 'model'.  All groups are processed in ONE batched einsum — the group
    axis stays fully parallel, and the g<->e resharding in the dispatch
    einsum is exactly the all-to-all an expert-parallel system performs.
    """
    b, s, d = x.shape
    t = b * s
    gsz = min(cfg.group_size, t)
    n_groups = t // gsz
    assert n_groups * gsz == t, f"tokens {t} not divisible by group {gsz}"
    cap = max(int(gsz * cfg.top_k / cfg.n_experts * cfg.capacity_factor), 1)
    xg = x.reshape(n_groups, gsz, d)

    gates, idx = _router(p, xg.reshape(t, d), cfg)           # (T,K)
    gates = gates.reshape(n_groups, gsz, cfg.top_k)
    idx = idx.reshape(n_groups, gsz, cfg.top_k)
    onehot = jax.nn.one_hot(idx, cfg.n_experts, dtype=f32)   # (G,g,K,E)
    # position of each (token, k) inside its expert queue (within the group)
    flat = onehot.reshape(n_groups, gsz * cfg.top_k, cfg.n_experts)
    pos = jnp.cumsum(flat, axis=1).reshape(onehot.shape) - onehot  # exclusive
    within = (pos < cap) & (onehot > 0)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=f32)  # (G,g,K,E,C)
    keep = (pos_oh * within[..., None]).astype(x.dtype)
    dispatch = keep.sum(2)                                   # (G,g,E,C)
    combine = (gates[..., None, None].astype(x.dtype) * keep).sum(2)
    h = jnp.einsum("gtec,gtd->gecd", dispatch, xg)           # (G,E,C,D)
    hg = jnp.einsum("gecd,edf->gecf", h, p["w_gate"])
    hu = jnp.einsum("gecd,edf->gecf", h, p["w_up"])
    hg = jax.nn.silu(hg.astype(f32)).astype(x.dtype)
    out = jnp.einsum("gecf,efd->gecd", hg * hu, p["w_down"])
    y = jnp.einsum("gtec,gecd->gtd", combine, out)           # (G,g,D)
    y = y.reshape(b, s, d)
    if "shared" in p:
        y = y + _shared_ffn(p, x)
    return y


def moe_ffn_sorted(p: dict, x: jnp.ndarray, cfg: MoEConfig) -> jnp.ndarray:
    """Sort-based ragged dispatch (no dispatch matmul).  x: (B,S,D)."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    gates, idx = _router(p, xt, cfg)                          # (T,K)
    k = cfg.top_k
    e = cfg.n_experts
    cap = max(int(t * k / e * cfg.capacity_factor), 1)

    e_flat = idx.reshape(t * k)
    g_flat = gates.reshape(t * k)
    tok = jnp.arange(t * k, dtype=jnp.int32) // k
    order = jnp.argsort(e_flat, stable=True)
    e_s, tok_s, g_s = e_flat[order], tok[order], g_flat[order]
    counts = jnp.bincount(e_flat, length=e)
    seg_start = jnp.cumsum(counts) - counts                   # (E,)
    pos = jnp.arange(t * k, dtype=jnp.int32) - seg_start[e_s]
    keep = pos < cap
    slot = jnp.where(keep, e_s * cap + pos, e * cap)          # overflow slot

    buf = jnp.zeros((e * cap + 1, d), xt.dtype)
    buf = buf.at[slot].add(xt[tok_s] * keep[:, None].astype(xt.dtype))
    # NB: capacity guarantees <=1 writer per slot, so 'add' == 'set' but is
    # cheaper for XLA to parallelize deterministically.
    h = buf[: e * cap].reshape(e, cap, d)
    out = _expert_gemm(p, h, xt.dtype).reshape(e * cap, d)
    contrib = out[jnp.minimum(slot, e * cap - 1)] * (
        g_s * keep.astype(f32)
    )[:, None].astype(xt.dtype)
    y = jnp.zeros((t, d), xt.dtype).at[tok_s].add(contrib)
    y = y.reshape(b, s, d)
    if "shared" in p:
        y = y + _shared_ffn(p, x)
    return y


def moe_ffn(p: dict, x: jnp.ndarray, cfg: MoEConfig, backend: str = "einsum"):
    if backend == "einsum":
        return moe_ffn_einsum(p, x, cfg)
    if backend == "sorted":
        return moe_ffn_sorted(p, x, cfg)
    raise ValueError(backend)  # pragma: no cover
