"""Serving state: KV caches / SSM states, prefill population, decode steps.

Cache layouts (leading stacked-layer axes are scanned, never sharded):

* dense / vlm / moe : {"k","v": (L, B, S, Hkv, hd), "pos"}
* deepseek (MLA)    : {"ckv": (L, B, S, kv_lora), "kpe": (L, B, S, rope), "pos"}
                      — the 64x-smaller latent cache (DESIGN.md §4)
* hybrid (zamba2)   : {"conv": (G, per, B, K-1, C), "ssm": (G, per, B, H, N, P),
                       "k","v": (G, B, W, Hkv, hd), "pos"} — W = sliding window
* ssm (xlstm)       : {"mC": (G, M, B, H, P, P), "mn", "mm", "sc","sn","sm","sh"}
* audio (seamless)  : {"k","v": self-attn, "ck","cv": (L, B, S_enc, H, hd), "pos"}

``decode_step`` threads per-layer cache slices through the same lax.scan that
drives the parameter stacks, so the whole serve step is one compact HLO —
the unit the decode_32k / long_500k dry-run cells lower.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm.layers import (
    attention_block_decode,
    attention_block_with_kv,
    attention_decode,
    attention_qkv,
    glu_ffn,
    mla_block_decode,
    mla_block_with_cache,
    rms_norm,
)
from repro.models.lm import moe as moe_lib
from repro.models.lm import ssm as ssm_lib

f32 = jnp.float32


# ==========================================================================
# init_cache
# ==========================================================================
def init_cache(model, batch: int, max_seq: int):
    cfg = model.cfg
    dt = model.dtype
    hd = cfg.resolved_head_dim
    fam = cfg.family
    pos = jnp.zeros((), jnp.int32)
    if fam in ("dense", "vlm") or (fam == "moe" and not cfg.mla):
        L = cfg.n_layers
        return {
            "k": jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, hd), dt),
            "pos": pos,
        }
    if fam == "moe" and cfg.mla:
        L = cfg.n_layers
        m = cfg.mla
        return {
            "ckv": jnp.zeros((L, batch, max_seq, m.kv_lora), dt),
            "kpe": jnp.zeros((L, batch, max_seq, m.rope_dim), dt),
            "pos": pos,
        }
    if fam == "hybrid":
        s = cfg.ssm
        per = cfg.attn_every
        g = cfg.n_layers // per
        di = s.expand * cfg.d_model
        h = di // s.head_dim
        w = min(cfg.sliding_window or max_seq, max_seq)
        return {
            "conv": jnp.zeros((g, per, batch, s.d_conv - 1, di + 2 * s.d_state), dt),
            "ssm": jnp.zeros((g, per, batch, h, s.d_state, s.head_dim), f32),
            "k": jnp.zeros((g, batch, w, cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((g, batch, w, cfg.n_kv_heads, hd), dt),
            "pos": pos,
        }
    if fam == "ssm":  # xlstm
        s = cfg.ssm
        per = s.slstm_every
        g = cfg.n_layers // per
        m = per - 1
        di = s.expand * cfg.d_model
        p_dim = di // cfg.n_heads
        d = cfg.d_model
        return {
            "mC": jnp.zeros((g, m, batch, cfg.n_heads, p_dim, p_dim), f32),
            "mn": jnp.zeros((g, m, batch, cfg.n_heads, p_dim), f32),
            "mm": jnp.full((g, m, batch, cfg.n_heads), -1e30, f32),
            "sc": jnp.zeros((g, batch, d), f32),
            "sn": jnp.zeros((g, batch, d), f32),
            "sm": jnp.full((g, batch, d), -1e30, f32),
            "sh": jnp.zeros((g, batch, d), f32),
            "pos": pos,
        }
    if fam == "audio":
        L = cfg.n_layers
        s_enc = cfg.n_frontend_tokens
        return {
            "k": jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, hd), dt),
            "ck": jnp.zeros((L, batch, s_enc, cfg.n_kv_heads, hd), dt),
            "cv": jnp.zeros((L, batch, s_enc, cfg.n_kv_heads, hd), dt),
            "pos": pos,
        }
    raise ValueError(fam)  # pragma: no cover


# ==========================================================================
# prefill
# ==========================================================================
# Decode slots reserved past the prefill length when the caller does not pass
# an explicit ``max_seq``.  Without headroom the FIRST decode step corrupts
# the cache: ``dynamic_update_slice`` clamps its start index so a write at
# ``pos == cache_len`` lands on slot ``cache_len - 1``, silently overwriting
# the last prefilled key/value (the long-standing qwen prefill/decode
# consistency failure).  Positions past ``pos`` are masked in attention, so
# the zero padding never leaks into logits.
DECODE_RESERVE = 64


def build_prefill_cache(model, params, tokens, frontend=None, max_seq=None):
    """Run the full-sequence forward, returning (last logits, decode cache).

    ``max_seq`` bounds the total sequence (prefill + decode steps) the cache
    can hold; defaults to ``prefill_len + DECODE_RESERVE``.  Decoding past
    it requires re-prefilling with a larger ``max_seq`` (shapes are static).
    State-space / windowed families carry O(1) state and ignore it.
    """
    cfg = model.cfg
    b, s = tokens.shape
    x = params["embed"][jnp.clip(tokens, 0, model.vp - 1)].astype(model.dtype)
    fam = cfg.family

    if fam in ("dense", "vlm", "moe"):
        if fam == "vlm" and frontend is not None:
            fe = frontend.astype(model.dtype) @ params["frontend_adapter"]
            x = jnp.concatenate([fe, x], axis=1)
            s = x.shape[1]
        caches_extra = []
        for bp in params.get("dense0", []):
            x, extra = _prefill_attn_ffn(model, bp, x)
            caches_extra.append(extra)

        def body(h, bp):
            h, extra = _prefill_attn_ffn(model, bp, h)
            return h, extra

        x, extras = jax.lax.scan(body, x, params["blocks"])
        cache = _assemble_prefill_cache(model, caches_extra, extras, b, s, max_seq)
    elif fam == "hybrid":
        shared = params["shared_block"]
        w = min(cfg.sliding_window or s, s)

        def group(h, gp):
            def m_body(hh, mp):
                out, st, conv = ssm_lib.mamba2_block(
                    mp["cell"],
                    rms_norm(hh, mp["ln"], cfg.norm_eps),
                    cfg,
                    return_state=True,
                )
                return hh + out, (st, conv)

            h, (ssm_st, conv_st) = jax.lax.scan(m_body, h, gp)
            hh = rms_norm(h, shared["ln1"], cfg.norm_eps)
            a, k, v = attention_block_with_kv(
                shared["attn"], hh, cfg, window=cfg.sliding_window, block=model.attn_block
            )
            h = h + a
            h = h + glu_ffn(shared["ffn"], rms_norm(h, shared["ln2"], cfg.norm_eps), cfg.act)
            # Ring-consistent window cache: when s >= w keep the last w keys
            # (slot alignment needs s % w == 0 — true for all our shapes);
            # when s < w, positions ARE slots, so right-pad to w.
            if s >= w:
                kc, vc = k[:, -w:], v[:, -w:]
            else:
                pad = ((0, 0), (0, w - s), (0, 0), (0, 0))
                kc, vc = jnp.pad(k, pad), jnp.pad(v, pad)
            return h, (ssm_st, conv_st, kc, vc)

        x, (ssm_st, conv_st, ks, vs) = jax.lax.scan(group, x, params["mamba"])
        cache = {
            "conv": conv_st.astype(model.dtype),
            "ssm": ssm_st,
            "k": ks,
            "v": vs,
            "pos": jnp.asarray(s, jnp.int32),
        }
    elif fam == "ssm":

        def group(h, gp):
            def m_body(hh, mp):
                out, st = ssm_lib.mlstm_block(
                    mp["cell"], rms_norm(hh, mp["ln"], cfg.norm_eps), cfg,
                    return_state=True,
                )
                return hh + out, st

            h, mstates = jax.lax.scan(m_body, h, gp["mlstm"])
            sp = gp["slstm"]
            out, sstate = ssm_lib.slstm_block(
                sp["cell"], rms_norm(h, sp["ln"], cfg.norm_eps), cfg, return_state=True
            )
            h = h + out
            return h, (mstates, sstate)

        x, (mstates, sstates) = jax.lax.scan(
            group, x, {"mlstm": params["mlstm"], "slstm": params["slstm"]}
        )
        mc, mn, mm = mstates
        sc, sn, sm, sh = sstates
        cache = {
            "mC": mc, "mn": mn, "mm": mm,
            "sc": sc, "sn": sn, "sm": sm, "sh": sh,
            "pos": jnp.asarray(s, jnp.int32),
        }
    elif fam == "audio":
        enc_out = model._encode(params, frontend)

        def body(h, bp):
            hh = rms_norm(h, bp["ln1"], cfg.norm_eps)
            a, k, v = attention_block_with_kv(
                bp["self_attn"], hh, cfg, block=model.attn_block
            )
            h = h + a
            hh = rms_norm(h, bp["ln_x"], cfg.norm_eps)
            # cross attention (cache enc-side k/v)
            ck = jnp.einsum("bsd,dhk->bshk", enc_out, bp["cross_attn"]["wk"])
            cv = jnp.einsum("bsd,dhk->bshk", enc_out, bp["cross_attn"]["wv"])
            h = h + model._cross_attention(bp["cross_attn"], hh, enc_out)
            h = h + glu_ffn(bp["ffn"], rms_norm(h, bp["ln2"], cfg.norm_eps), cfg.act)
            return h, (k, v, ck, cv)

        x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["dec_blocks"])
        cache = {
            "k": _pad_seq(ks, s, max_seq), "v": _pad_seq(vs, s, max_seq),
            "ck": cks, "cv": cvs,
            "pos": jnp.asarray(s, jnp.int32),
        }
    else:  # pragma: no cover
        raise ValueError(fam)

    h_last = rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
    return model.logits_last(params, h_last), cache


def _prefill_attn_ffn(model, bp, x):
    cfg = model.cfg
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    if cfg.mla:
        a, ckv, kpe = mla_block_with_cache(bp["attn"], h, cfg, block=model.attn_block)
        extra = (ckv, kpe)
    else:
        a, k, v = attention_block_with_kv(bp["attn"], h, cfg, block=model.attn_block)
        extra = (k, v)
    x = x + a
    h = rms_norm(x, bp["ln2"], cfg.norm_eps)
    if "moe" in bp:
        f = moe_lib.moe_ffn(bp["moe"], h, cfg.moe, model.moe_backend)
    else:
        f = glu_ffn(bp["ffn"], h, cfg.act)
    return x + f, extra


def _pad_seq(arr, s: int, max_seq: int | None):
    """Right-pad the (stacked-layer) cache's sequence axis to ``max_seq``.

    arr: (L, B, S, ...) — pads axis 2 with zeros.  Attention masks every
    position > ``pos``, so the padding is inert until a decode step claims
    its slot.
    """
    target = s + DECODE_RESERVE if max_seq is None else max_seq
    if target <= s:
        return arr
    pad = [(0, 0)] * arr.ndim
    pad[2] = (0, target - s)
    return jnp.pad(arr, pad)


def _assemble_prefill_cache(model, dense0_extras, scanned_extras, b, s, max_seq=None):
    cfg = model.cfg
    pos = jnp.asarray(s, jnp.int32)
    if cfg.mla:
        ckv, kpe = scanned_extras
        if dense0_extras:
            ckv = jnp.concatenate(
                [jnp.stack([e[0] for e in dense0_extras]), ckv], axis=0
            )
            kpe = jnp.concatenate(
                [jnp.stack([e[1] for e in dense0_extras]), kpe], axis=0
            )
        ckv = _pad_seq(ckv.astype(model.dtype), s, max_seq)
        kpe = _pad_seq(kpe.astype(model.dtype), s, max_seq)
        return {"ckv": ckv, "kpe": kpe, "pos": pos}
    k, v = scanned_extras
    if dense0_extras:
        k = jnp.concatenate([jnp.stack([e[0] for e in dense0_extras]), k], axis=0)
        v = jnp.concatenate([jnp.stack([e[1] for e in dense0_extras]), v], axis=0)
    k = _pad_seq(k.astype(model.dtype), s, max_seq)
    v = _pad_seq(v.astype(model.dtype), s, max_seq)
    return {"k": k, "v": v, "pos": pos}


# ==========================================================================
# decode step
# ==========================================================================
def _check_cache_capacity(pos, limit: int) -> None:
    """Refuse writes past the cache's sequence capacity (eager calls only).

    ``dynamic_update_slice`` clamps out-of-range starts, which would silently
    overwrite the newest cached position — the bug the prefill headroom
    fixed.  Under jit ``pos`` is a tracer and the check is skipped (shapes
    are the caller's contract there).
    """
    try:
        p = int(pos)
    except (jax.errors.TracerIntegerConversionError, jax.errors.ConcretizationTypeError):
        return
    if p >= limit:
        raise ValueError(
            f"KV cache exhausted: decode position {p} >= capacity {limit}; "
            f"re-prefill with a larger max_seq (see cache.DECODE_RESERVE)"
        )


def decode_step(model, params, cache, tokens):
    """tokens (B, 1) -> (logits (B, Vp), updated cache)."""
    cfg = model.cfg
    pos = cache["pos"]
    if cfg.family in ("dense", "vlm", "moe", "audio"):
        # absolute-slot caches; hybrid rings wrap and ssm state is O(1)
        seq_cap = cache["ckv"].shape[2] if cfg.mla else cache["k"].shape[2]
        _check_cache_capacity(pos, seq_cap)
    x = params["embed"][jnp.clip(tokens, 0, model.vp - 1)].astype(model.dtype)
    fam = cfg.family

    if fam in ("dense", "vlm", "moe"):
        n_dense = len(params.get("dense0", []))
        new_cache = dict(cache)
        if cfg.mla:
            for i, bp in enumerate(params.get("dense0", [])):
                x, c1, c2 = _decode_attn_ffn(
                    model, bp, x, cache["ckv"][i], cache["kpe"][i], pos
                )
                new_cache["ckv"] = new_cache["ckv"].at[i].set(c1)
                new_cache["kpe"] = new_cache["kpe"].at[i].set(c2)

            def body(h, per_layer):
                bp, ckv, kpe = per_layer
                h, c1, c2 = _decode_attn_ffn(model, bp, h, ckv, kpe, pos)
                return h, (c1, c2)

            x, (ckvs, kpes) = jax.lax.scan(
                body, x, (params["blocks"], cache["ckv"][n_dense:], cache["kpe"][n_dense:])
            )
            new_cache["ckv"] = jax.lax.dynamic_update_slice_in_dim(
                new_cache["ckv"], ckvs, n_dense, axis=0
            )
            new_cache["kpe"] = jax.lax.dynamic_update_slice_in_dim(
                new_cache["kpe"], kpes, n_dense, axis=0
            )
        else:

            def body(h, per_layer):
                bp, k, v = per_layer
                h, k2, v2 = _decode_attn_ffn(model, bp, h, k, v, pos)
                return h, (k2, v2)

            x, (ks, vs) = jax.lax.scan(
                body, x, (params["blocks"], cache["k"], cache["v"])
            )
            new_cache["k"], new_cache["v"] = ks, vs
    elif fam == "hybrid":
        shared = params["shared_block"]
        w = cache["k"].shape[2]

        def group(h, per_group):
            gp, conv, sst, k, v = per_group

            def m_body(hh, per_layer):
                mp, cs, ss = per_layer
                out, cs2, ss2 = ssm_lib.mamba2_decode(
                    mp["cell"], rms_norm(hh, mp["ln"], cfg.norm_eps), cs, ss, cfg
                )
                return hh + out, (cs2, ss2)

            h, (conv2, sst2) = jax.lax.scan(m_body, h, (gp, conv, sst))
            hh = rms_norm(h, shared["ln1"], cfg.norm_eps)
            a, k2, v2 = attention_block_decode(
                shared["attn"], hh, k, v, pos, cfg, window=w
            )
            h = h + a
            h = h + glu_ffn(shared["ffn"], rms_norm(h, shared["ln2"], cfg.norm_eps), cfg.act)
            return h, (conv2, sst2, k2, v2)

        x, (conv2, sst2, ks, vs) = jax.lax.scan(
            group, x, (params["mamba"], cache["conv"], cache["ssm"], cache["k"], cache["v"])
        )
        new_cache = {"conv": conv2, "ssm": sst2, "k": ks, "v": vs, "pos": pos}
    elif fam == "ssm":

        def group(h, per_group):
            gp, mC, mn, mm, sc, sn, sm, sh = per_group

            def m_body(hh, per_layer):
                mp, c_, n_, m_ = per_layer
                out, st = ssm_lib.mlstm_decode(
                    mp["cell"], rms_norm(hh, mp["ln"], cfg.norm_eps), (c_, n_, m_), cfg
                )
                return hh + out, st

            h, (mC2, mn2, mm2) = jax.lax.scan(
                m_body, h, (gp["mlstm"], mC, mn, mm)
            )
            sp = gp["slstm"]
            out, (sc2, sn2, sm2, sh2) = ssm_lib.slstm_decode(
                sp["cell"], rms_norm(h, sp["ln"], cfg.norm_eps), (sc, sn, sm, sh), cfg
            )
            h = h + out
            return h, (mC2, mn2, mm2, sc2, sn2, sm2, sh2)

        x, outs = jax.lax.scan(
            group,
            x,
            (
                {"mlstm": params["mlstm"], "slstm": params["slstm"]},
                cache["mC"], cache["mn"], cache["mm"],
                cache["sc"], cache["sn"], cache["sm"], cache["sh"],
            ),
        )
        mC2, mn2, mm2, sc2, sn2, sm2, sh2 = outs
        new_cache = {
            "mC": mC2, "mn": mn2, "mm": mm2,
            "sc": sc2, "sn": sn2, "sm": sm2, "sh": sh2, "pos": pos,
        }
    elif fam == "audio":

        def body(h, per_layer):
            bp, k, v, ck, cv = per_layer
            hh = rms_norm(h, bp["ln1"], cfg.norm_eps)
            a, k2, v2 = attention_block_decode(bp["self_attn"], hh, k, v, pos, cfg)
            h = h + a
            hh = rms_norm(h, bp["ln_x"], cfg.norm_eps)
            cp = bp["cross_attn"]
            q = jnp.einsum("bsd,dhk->bshk", hh, cp["wq"])
            o = attention_decode(q, ck, cv, jnp.asarray(ck.shape[1] - 1))
            h = h + jnp.einsum("bshk,hkd->bsd", o, cp["wo"])
            h = h + glu_ffn(bp["ffn"], rms_norm(h, bp["ln2"], cfg.norm_eps), cfg.act)
            return h, (k2, v2)

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["k"], cache["v"], cache["ck"], cache["cv"])
        )
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = ks, vs
    else:  # pragma: no cover
        raise ValueError(fam)

    new_cache["pos"] = pos + 1
    h_last = rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
    return model.logits_last(params, h_last), new_cache


def _decode_attn_ffn(model, bp, x, c1, c2, pos):
    cfg = model.cfg
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    if cfg.mla:
        a, c1, c2 = mla_block_decode(bp["attn"], h, c1, c2, pos, cfg)
    else:
        a, c1, c2 = attention_block_decode(bp["attn"], h, c1, c2, pos, cfg)
    x = x + a
    h = rms_norm(x, bp["ln2"], cfg.norm_eps)
    if "moe" in bp:
        f = moe_lib.moe_ffn(bp["moe"], h, cfg.moe, model.moe_backend)
    else:
        f = glu_ffn(bp["ffn"], h, cfg.act)
    return x + f, c1, c2
