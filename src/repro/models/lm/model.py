"""LM assembly: one composable stack covering all 10 assigned architectures.

Families and their block topologies (DESIGN.md §4):

* dense / vlm          — scan over L x [preLN -> GQA attn -> preLN -> GLU FFN]
                         (vlm prepends ``n_frontend_tokens`` stub patch
                         embeddings and masks them out of the loss)
* moe                  — same, FFN replaced by token-choice top-k MoE;
                         deepseek additionally: MLA attention + 1 leading
                         dense-FFN layer (unrolled) + 2 shared experts
* ssm (xlstm)          — scan over groups of [7 x mLSTM + 1 x sLSTM] blocks
* hybrid (zamba2)      — scan over groups of [6 x Mamba2] + ONE weight-shared
                         attention+FFN block applied after every group
* audio (seamless)     — enc-dec: 24-layer bidirectional encoder over stub
                         frame embeddings, 24-layer decoder w/ cross-attn

Layer stacks use ``jax.lax.scan`` over stacked parameter leaves so that even
the 236B config lowers to a compact HLO — the property the 80-cell multi-pod
dry-run depends on.  Losses never materialize (B, S, V) logits: the unembed
matmul + softmax-xent run inside a scan over sequence chunks with the vocab
dim sharded ('model'), which is what keeps the 100k-256k-vocab train cells
inside HBM.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.lm import moe as moe_lib
from repro.models.lm import ssm as ssm_lib
from repro.models.lm.layers import (
    attention_block,
    attention_block_decode,
    attention_full,
    glu_ffn,
    init_attention,
    init_ffn,
    init_mla,
    mla_block,
    mla_block_decode,
    rms_norm,
)
from repro.models.lm.sharding import constrain

f32 = jnp.float32
PyTree = Any


def _padded_vocab(v: int, multiple: int = 256) -> int:
    return ((v + multiple - 1) // multiple) * multiple


# --------------------------------------------------------------------------
# Vocab-sharded streaming softmax-xent as an explicit shard_map.
#
# Why shard_map: under jax.grad + lax.scan, GSPMD resolves the sharding of
# the saved logits residuals / cotangents to REPLICATED, emitting full-vocab
# all-gathers and 16x-redundant backward matmuls (measured: ~40% of link
# traffic and 2x the FLOPs on the train_4k cells).  Inside shard_map every
# collective is explicit: per-chunk local (B_loc, c, V_loc) logits, a
# (B, c)-sized psum for logsumexp/gold, and autodiff transposes psum to the
# cheap broadcast — no partitioner guesswork anywhere.
# --------------------------------------------------------------------------
def _sharded_chunk_xent(rules, vp: int, vocab: int, n_chunks: int):
    """Returns shard_mapped fn(h, w, labels, mask) -> (loss_sum, correct)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mesh = rules.mesh
    dp = rules.axis("batch")
    tp = rules.tp_axis

    def local_fn(h, w, labels, mask):
        # shapes here are per-shard: h (B_loc, S, D), w (D, V_loc)
        b, s, d = h.shape
        c = s // n_chunks
        v_loc = w.shape[-1]
        shard = jax.lax.axis_index(tp)
        vocab_ids = shard * v_loc + jnp.arange(v_loc)          # global ids
        ok = (vocab_ids < vocab)[None, None, :]

        hc = h.reshape(b, n_chunks, c, d).transpose(1, 0, 2, 3)
        lc = labels.reshape(b, n_chunks, c).transpose(1, 0, 2)
        mc = mask.reshape(b, n_chunks, c).transpose(1, 0, 2)

        def chunk(carry, inp):
            hh, ll, mm = inp
            logits = (hh @ w).astype(f32)                      # (B_loc, c, V_loc)
            logits = jnp.where(ok, logits, -1e30)
            # stop_gradient(max) keeps d lse/d logits == softmax exactly and
            # avoids differentiating through pmax.
            mx_loc = jnp.max(logits, axis=-1)
            mx = jax.lax.pmax(jax.lax.stop_gradient(mx_loc), tp)
            z = jax.lax.psum(jnp.sum(jnp.exp(logits - mx[..., None]), -1), tp)
            lse = jnp.log(z) + mx
            sel = vocab_ids[None, None, :] == ll[..., None]
            gold = jax.lax.psum(
                jnp.sum(jnp.where(sel, logits, 0.0), axis=-1), tp
            )
            loss = jnp.sum((lse - gold) * mm)
            correct = jnp.sum((gold >= mx) * mm)
            return (carry[0] + loss, carry[1] + correct), None

        (loss_sum, correct), _ = jax.lax.scan(
            chunk, (jnp.zeros((), f32), jnp.zeros((), f32)), (hc, lc, mc)
        )
        # replicate across data shards too -> fully-replicated scalars out
        if dp is not None:
            loss_sum = jax.lax.psum(loss_sum, dp)
            correct = jax.lax.psum(correct, dp)
        return loss_sum, correct

    b_axis = dp
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(b_axis, None, None),
            P(None, tp),
            P(b_axis, None),
            P(b_axis, None),
        ),
        out_specs=(P(), P()),
        check_rep=False,
    )


class LM:
    """Functional LM; params are plain nested dicts of arrays."""

    def __init__(
        self,
        cfg: ModelConfig,
        *,
        moe_backend: str = "einsum",
        attn_block: int = 1024,
        remat: bool = True,
        loss_chunk: int = 512,
    ):
        self.cfg = cfg
        self.moe_backend = moe_backend
        self.attn_block = attn_block
        self.remat = remat
        self.loss_chunk = loss_chunk
        self.vp = _padded_vocab(cfg.vocab)
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    # ==================================================================
    # Init
    # ==================================================================
    def _init_attn_ffn_block(self, key, use_moe: bool) -> dict:
        cfg, dt = self.cfg, self.dtype
        k1, k2 = jax.random.split(key)
        blk = {"ln1": jnp.ones((cfg.d_model,), dt), "ln2": jnp.ones((cfg.d_model,), dt)}
        if cfg.mla:
            blk["attn"] = init_mla(k1, cfg, dt)
        else:
            blk["attn"] = init_attention(k1, cfg, dt)
        if use_moe:
            blk["moe"] = moe_lib.init_moe(k2, cfg.d_model, cfg.moe, dt)
        else:
            blk["ffn"] = init_ffn(k2, cfg.d_model, cfg.d_ff, dt)
        return blk

    def _init_cross_block(self, key) -> dict:
        cfg, dt = self.cfg, self.dtype
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": jnp.ones((cfg.d_model,), dt),
            "self_attn": init_attention(k1, cfg, dt),
            "ln_x": jnp.ones((cfg.d_model,), dt),
            "cross_attn": init_attention(k2, cfg, dt),
            "ln2": jnp.ones((cfg.d_model,), dt),
            "ffn": init_ffn(k3, cfg.d_model, cfg.d_ff, dt),
        }

    def init(self, key) -> PyTree:
        cfg, dt = self.cfg, self.dtype
        keys = jax.random.split(key, 8)
        params: dict = {
            "embed": (
                jax.random.normal(keys[0], (self.vp, cfg.d_model)) * 0.02
            ).astype(dt),
            "unembed": (
                jax.random.normal(keys[1], (cfg.d_model, self.vp))
                * cfg.d_model ** -0.5
            ).astype(dt),
            "final_norm": jnp.ones((cfg.d_model,), dt),
        }
        if cfg.frontend:
            params["frontend_adapter"] = (
                jax.random.normal(keys[2], (cfg.d_model, cfg.d_model))
                * cfg.d_model ** -0.5
            ).astype(dt)

        fam = cfg.family
        if fam in ("dense", "vlm") or (fam == "moe"):
            use_moe = fam == "moe"
            n_scan = cfg.n_layers - cfg.dense_layers
            bkeys = jax.random.split(keys[3], n_scan)
            params["blocks"] = jax.vmap(
                lambda k: self._init_attn_ffn_block(k, use_moe)
            )(bkeys)
            if cfg.dense_layers:
                dkeys = jax.random.split(keys[4], cfg.dense_layers)
                params["dense0"] = [
                    self._init_attn_ffn_block(k, False) for k in dkeys
                ]
        elif fam == "ssm":  # xlstm
            per = cfg.ssm.slstm_every
            n_groups = cfg.n_layers // per
            n_m = per - 1
            mkeys = jax.random.split(keys[3], (n_groups, n_m))
            params["mlstm"] = jax.vmap(
                jax.vmap(
                    lambda k: {
                        "ln": jnp.ones((cfg.d_model,), dt),
                        "cell": ssm_lib.init_mlstm(k, cfg, dt),
                    }
                )
            )(mkeys)
            skeys = jax.random.split(keys[4], n_groups)
            params["slstm"] = jax.vmap(
                lambda k: {
                    "ln": jnp.ones((cfg.d_model,), dt),
                    "cell": ssm_lib.init_slstm(k, cfg, dt),
                }
            )(skeys)
        elif fam == "hybrid":  # zamba2
            per = cfg.attn_every
            n_groups = cfg.n_layers // per
            mkeys = jax.random.split(keys[3], (n_groups, per))
            params["mamba"] = jax.vmap(
                jax.vmap(
                    lambda k: {
                        "ln": jnp.ones((cfg.d_model,), dt),
                        "cell": ssm_lib.init_mamba2(k, cfg, dt),
                    }
                )
            )(mkeys)
            params["shared_block"] = self._init_attn_ffn_block(keys[4], False)
        elif fam == "audio":  # seamless enc-dec
            ekeys = jax.random.split(keys[3], cfg.enc_layers)
            params["enc_blocks"] = jax.vmap(
                lambda k: self._init_attn_ffn_block(k, False)
            )(ekeys)
            dkeys = jax.random.split(keys[4], cfg.n_layers)
            params["dec_blocks"] = jax.vmap(lambda k: self._init_cross_block(k))(
                dkeys
            )
            params["enc_norm"] = jnp.ones((cfg.d_model,), dt)
        else:  # pragma: no cover
            raise ValueError(fam)
        return params

    def init_shapes(self) -> PyTree:
        """ShapeDtypeStruct params (no allocation) — dry-run entry point."""
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # ==================================================================
    # Block applications (full sequence)
    # ==================================================================
    def _apply_attn_ffn(self, bp, x, *, causal=True, window=0):
        cfg = self.cfg
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        if cfg.mla:
            a = mla_block(bp["attn"], h, cfg, block=self.attn_block)
        else:
            a = attention_block(
                bp["attn"], h, cfg, causal=causal, window=window, block=self.attn_block
            )
        x = x + a
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        if "moe" in bp:
            f = moe_lib.moe_ffn(bp["moe"], h, cfg.moe, self.moe_backend)
        else:
            f = glu_ffn(bp["ffn"], h, cfg.act)
        x = x + f
        return constrain(x, "batch", None, None)

    def _maybe_remat(self, fn):
        return jax.checkpoint(fn) if self.remat else fn

    def _backbone(self, params, x):
        """Full-sequence forward through all blocks.  x: (B, S, D)."""
        cfg = self.cfg
        fam = cfg.family
        if fam in ("dense", "vlm", "moe"):
            for bp in params.get("dense0", []):
                x = self._apply_attn_ffn(bp, x)

            body = self._maybe_remat(
                lambda h, bp: (self._apply_attn_ffn(bp, h), None)
            )
            x, _ = jax.lax.scan(body, x, params["blocks"])
            return x
        if fam == "ssm":

            def group(h, gp):
                def m_body(hh, mp):
                    hh = hh + ssm_lib.mlstm_block(
                        mp["cell"], rms_norm(hh, mp["ln"], cfg.norm_eps), cfg
                    )
                    return constrain(hh, "batch", None, None), None

                h, _ = jax.lax.scan(self._maybe_remat(m_body), h, gp["mlstm"])
                sp = gp["slstm"]
                h = h + ssm_lib.slstm_block(
                    sp["cell"], rms_norm(h, sp["ln"], cfg.norm_eps), cfg
                )
                return constrain(h, "batch", None, None), None

            x, _ = jax.lax.scan(
                group, x, {"mlstm": params["mlstm"], "slstm": params["slstm"]}
            )
            return x
        if fam == "hybrid":
            shared = params["shared_block"]

            def group(h, gp):
                def m_body(hh, mp):
                    hh = hh + ssm_lib.mamba2_block(
                        mp["cell"], rms_norm(hh, mp["ln"], cfg.norm_eps), cfg
                    )
                    return constrain(hh, "batch", None, None), None

                h, _ = jax.lax.scan(self._maybe_remat(m_body), h, gp)
                h = self._apply_attn_ffn(shared, h, window=cfg.sliding_window)
                return h, None

            x, _ = jax.lax.scan(group, x, params["mamba"])
            return x
        raise ValueError(fam)  # pragma: no cover

    def _encode(self, params, frontend):
        """Audio encoder over stub frame embeddings."""
        cfg = self.cfg
        x = frontend.astype(self.dtype) @ params["frontend_adapter"]

        body = self._maybe_remat(
            lambda h, bp: (self._apply_attn_ffn(bp, h, causal=False), None)
        )
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _apply_cross_block(self, bp, x, enc_out):
        cfg = self.cfg
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        x = x + attention_block(bp["self_attn"], h, cfg, causal=True, block=self.attn_block)
        h = rms_norm(x, bp["ln_x"], cfg.norm_eps)
        x = x + self._cross_attention(bp["cross_attn"], h, enc_out)
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        x = x + glu_ffn(bp["ffn"], h, cfg.act)
        return constrain(x, "batch", None, None)

    def _cross_attention(self, p, x, enc_out):
        cfg = self.cfg
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
        o = attention_full(q, k, v, causal=False)
        return jnp.einsum("bshk,hkd->bsd", o, p["wo"])

    def _decoder(self, params, x, enc_out):
        body = self._maybe_remat(
            lambda h, bp: (self._apply_cross_block(bp, h, enc_out), None)
        )
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        return x

    # ==================================================================
    # Losses
    # ==================================================================
    def _chunked_xent(self, params, h, labels, mask):
        """Streaming softmax-xent: scan over sequence chunks.

        h: (B, S, D); labels: (B, S) int32; mask: (B, S) f32.
        Never materializes (B, S, V); per-chunk logits are (B, c, V) with V
        sharded on 'model'.
        """
        cfg = self.cfg
        b, s, d = h.shape
        c = min(self.loss_chunk, s)
        while s % c != 0:  # largest divisor of s not exceeding loss_chunk
            c -= 1
        n_chunks = s // c
        w = params["unembed"]

        from repro.models.lm.sharding import active_rules

        rules = active_rules()
        if rules is not None:
            fn = _sharded_chunk_xent(rules, self.vp, cfg.vocab, n_chunks)
            loss_sum, correct = fn(h, w, labels, mask.astype(f32))
            denom = jnp.maximum(mask.sum(), 1.0)
            return loss_sum / denom, {"acc": correct / denom, "tokens": denom}

        # single-host path (smoke tests / examples): same math, plain jnp
        vocab_ok = (jnp.arange(self.vp) < cfg.vocab)[None, None, :]
        hc = h.reshape(b, n_chunks, c, d).transpose(1, 0, 2, 3)
        lc = labels.reshape(b, n_chunks, c).transpose(1, 0, 2)
        mc = mask.reshape(b, n_chunks, c).transpose(1, 0, 2)

        def chunk(carry, inp):
            hh, ll, mm = inp
            logits = (hh @ w).astype(f32)  # (B, c, Vp)
            logits = jnp.where(vocab_ok, logits, -1e30)
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            sel = jnp.arange(self.vp)[None, None, :] == ll[..., None]
            gold = jnp.sum(jnp.where(sel, logits, 0.0), axis=-1)
            loss = jnp.sum((lse - gold) * mm)
            mx = jnp.max(logits, axis=-1)
            correct = jnp.sum((gold >= mx) * mm)
            return (carry[0] + loss, carry[1] + correct), None

        (loss_sum, correct), _ = jax.lax.scan(
            chunk, (jnp.zeros((), f32), jnp.zeros((), f32)), (hc, lc, mc)
        )
        denom = jnp.maximum(mask.sum(), 1.0)
        return loss_sum / denom, {"acc": correct / denom, "tokens": denom}

    def train_loss(self, params, batch) -> tuple[jnp.ndarray, dict]:
        """batch: {"tokens": (B, S+1) [, "frontend": (B, P, D)]}."""
        cfg = self.cfg
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        mask = (labels >= 0).astype(f32)
        labels = jnp.maximum(labels, 0)
        x = params["embed"][jnp.clip(inputs, 0, self.vp - 1)].astype(self.dtype)
        x = constrain(x, "batch", None, None)

        if cfg.family == "audio":
            enc_out = self._encode(params, batch["frontend"])
            h = self._decoder(params, x, enc_out)
        elif cfg.family == "vlm":
            fe = batch["frontend"].astype(self.dtype) @ params["frontend_adapter"]
            x = jnp.concatenate([fe, x], axis=1)
            h = self._backbone(params, x)
            p = cfg.n_frontend_tokens
            h = h[:, p:]  # loss only over text positions
        else:
            h = self._backbone(params, x)

        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        return self._chunked_xent(params, h, labels, mask)

    # ==================================================================
    # Serving: prefill + decode (caches built in cache.py)
    # ==================================================================
    def prefill(self, params, tokens, frontend=None, max_seq=None):
        """Returns (last-position logits (B, Vp), populated cache).

        The cache reserves decode headroom up to ``max_seq`` total positions
        (default: prefill length + ``cache.DECODE_RESERVE``) so subsequent
        ``decode_step`` writes land on fresh slots.
        """
        from repro.models.lm.cache import build_prefill_cache

        return build_prefill_cache(self, params, tokens, frontend, max_seq)

    def decode_step(self, params, cache, tokens):
        """tokens: (B, 1) -> (logits (B, Vp), updated cache)."""
        from repro.models.lm.cache import decode_step

        return decode_step(self, params, cache, tokens)

    def init_cache(self, batch: int, max_seq: int) -> PyTree:
        from repro.models.lm.cache import init_cache

        return init_cache(self, batch, max_seq)

    def logits_last(self, params, h_last):
        """h_last: (B, D) -> (B, Vp) f32 logits (vocab padded masked)."""
        logits = (h_last @ params["unembed"]).astype(f32)
        return jnp.where(jnp.arange(self.vp)[None, :] < self.cfg.vocab, logits, -1e30)
