"""Linear / logistic models (the paper's Tick-Price pipeline uses LR)."""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LinearRegression", "LogisticRegression"]


@dataclass
class LinearRegression:
    """Ridge-regularized least squares, closed form."""

    l2: float = 1e-6
    task: str = "regression"
    coef: np.ndarray = field(default_factory=lambda: np.zeros(0, np.float32))
    intercept: float = 0.0

    def fit(self, X: np.ndarray, y: np.ndarray) -> "LinearRegression":
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        Xa = np.concatenate([X, np.ones((len(X), 1))], axis=1)
        A = Xa.T @ Xa + self.l2 * np.eye(Xa.shape[1])
        b = Xa.T @ y
        w = np.linalg.solve(A, b)
        self.coef = w[:-1].astype(np.float32)
        self.intercept = float(w[-1])
        return self

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        return x @ jnp.asarray(self.coef) + self.intercept


@dataclass
class LogisticRegression:
    """Binary logistic regression via Newton-ish full-batch gradient descent."""

    l2: float = 1e-4
    n_steps: int = 300
    lr: float = 0.5
    task: str = "classification"
    coef: np.ndarray = field(default_factory=lambda: np.zeros(0, np.float32))
    intercept: float = 0.0

    def fit(self, X: np.ndarray, y: np.ndarray) -> "LogisticRegression":
        X = jnp.asarray(X, jnp.float32)
        y = jnp.asarray(y, jnp.float32)
        w = jnp.zeros((X.shape[1] + 1,), jnp.float32)
        Xa = jnp.concatenate([X, jnp.ones((X.shape[0], 1), jnp.float32)], axis=1)

        def loss(w):
            logits = Xa @ w
            nll = jnp.mean(
                jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
            )
            return nll + 0.5 * self.l2 * jnp.sum(w[:-1] ** 2)

        g = jax.jit(jax.grad(loss))
        for _ in range(self.n_steps):
            w = w - self.lr * g(w)
        w = np.asarray(w)
        self.coef = w[:-1]
        self.intercept = float(w[-1])
        return self

    def predict_logit(self, x: jnp.ndarray) -> jnp.ndarray:
        return x @ jnp.asarray(self.coef) + self.intercept

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        return (self.predict_logit(x) > 0).astype(jnp.int32)

    def predict_proba(self, x: jnp.ndarray) -> jnp.ndarray:
        return jax.nn.sigmoid(self.predict_logit(x))
