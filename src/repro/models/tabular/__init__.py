"""Tabular model zoo (trained in-repo; used by the paper's seven pipelines)."""
from repro.models.tabular.linear import LinearRegression, LogisticRegression
from repro.models.tabular.mlp import MLP
from repro.models.tabular.trees import GradientBoosting, RandomForest, TreeEnsemble

__all__ = [
    "LinearRegression",
    "LogisticRegression",
    "MLP",
    "GradientBoosting",
    "RandomForest",
    "TreeEnsemble",
]
