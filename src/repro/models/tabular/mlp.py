"""Small MLP classifier/regressor (the paper's Bearing-Imbalance model).

Trained with the in-repo AdamW (``repro.optim``); inference is a two-matmul
jit — exactly the kind of model whose QMC batch (m=1000 rows) is one MXU tile
on TPU.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import adamw_init, adamw_update

__all__ = ["MLP"]


def _init_params(key, sizes):
    params = []
    for i, (fan_in, fan_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (fan_in, fan_out)) * jnp.sqrt(2.0 / fan_in)
        params.append({"w": w.astype(jnp.float32), "b": jnp.zeros((fan_out,), jnp.float32)})
    return params


def _forward(params, x):
    h = x
    for layer in params[:-1]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
    out = h @ params[-1]["w"] + params[-1]["b"]
    return out[..., 0]


@dataclass
class MLP:
    hidden: tuple[int, ...] = (64, 32)
    task: str = "classification"
    epochs: int = 60
    batch_size: int = 512
    lr: float = 3e-3
    seed: int = 0
    params: Any = None

    def fit(self, X: np.ndarray, y: np.ndarray) -> "MLP":
        X = jnp.asarray(X, jnp.float32)
        y = jnp.asarray(y, jnp.float32)
        key = jax.random.PRNGKey(self.seed)
        params = _init_params(key, (X.shape[1], *self.hidden, 1))
        opt = adamw_init(params)

        if self.task == "classification":

            def loss_fn(p, xb, yb):
                logits = _forward(p, xb)
                return jnp.mean(
                    jnp.maximum(logits, 0)
                    - logits * yb
                    + jnp.log1p(jnp.exp(-jnp.abs(logits)))
                )

        else:

            def loss_fn(p, xb, yb):
                return jnp.mean((_forward(p, xb) - yb) ** 2)

        @jax.jit
        def step(p, o, xb, yb):
            g = jax.grad(loss_fn)(p, xb, yb)
            return adamw_update(g, o, p, self.lr, weight_decay=1e-4)

        n = X.shape[0]
        rng = np.random.default_rng(self.seed)
        for _ in range(self.epochs):
            order = rng.permutation(n)
            for s in range(0, n - self.batch_size + 1, self.batch_size):
                idx = order[s : s + self.batch_size]
                params, opt = step(params, opt, X[idx], y[idx])
        self.params = params
        return self

    def predict_logit(self, x: jnp.ndarray) -> jnp.ndarray:
        return _forward(self.params, x)

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        out = self.predict_logit(x)
        if self.task == "classification":
            return (out > 0).astype(jnp.int32)
        return out

    def predict_proba(self, x: jnp.ndarray) -> jnp.ndarray:
        return jax.nn.sigmoid(self.predict_logit(x))
