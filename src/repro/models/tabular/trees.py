"""Tree-ensemble models (Random Forest + Gradient Boosting) built in-repo.

The paper's pipelines use LightGBM / XGBoost / Random Forests (Table 1).  We
implement both *training* (histogram-based CART with second-order gradient
boosting, LightGBM/XGBoost-style) and *inference*.

Inference is the part Biathlon hammers — AMI evaluates the model on
``m·(k+2) ≈ 23k`` QMC rows per planner iteration — so trees are stored
**tensorized** (Hummingbird-style complete arrays) and traversed level-wise
with gathers:

    idx ← 0;  repeat depth times:  idx ← (x[feat[idx]] ≤ thr[idx]) ? L[idx] : R[idx]

Leaves self-loop, so the traversal is branch-free and maps directly onto the
TPU Pallas kernel in ``repro.kernels.tree_qmc`` (this module's ``predict`` is
its reference oracle).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["TreeEnsemble", "RandomForest", "GradientBoosting", "fit_tree_arrays"]


# --------------------------------------------------------------------------
# Tensorized ensemble representation
# --------------------------------------------------------------------------
class TreeEnsemble(NamedTuple):
    """Padded, stacked decision trees.

    feature:  (T, M) int32 — split feature per node (leaves: 0, unused: 0)
    threshold:(T, M) f32   — split threshold
    left:     (T, M) int32 — left-child node id  (leaves: self)
    right:    (T, M) int32 — right-child node id (leaves: self)
    value:    (T, M) f32   — leaf prediction (internal nodes: 0)
    depth:    int          — max tree depth (traversal iterations)
    """

    feature: jnp.ndarray
    threshold: jnp.ndarray
    left: jnp.ndarray
    right: jnp.ndarray
    value: jnp.ndarray
    depth: int

    @property
    def n_trees(self) -> int:
        return self.feature.shape[0]


def ensemble_predict_sum(ens: TreeEnsemble, x: jnp.ndarray) -> jnp.ndarray:
    """Sum of per-tree leaf values; x: (n, F) -> (n,).  Pure-jnp oracle."""

    def one_tree(feat, thr, left, right, value):
        idx = jnp.zeros((x.shape[0],), jnp.int32)
        for _ in range(ens.depth):
            f = feat[idx]                       # (n,)
            go_left = x[jnp.arange(x.shape[0]), f] <= thr[idx]
            idx = jnp.where(go_left, left[idx], right[idx])
        return value[idx]

    per_tree = jax.vmap(one_tree)(
        ens.feature, ens.threshold, ens.left, ens.right, ens.value
    )  # (T, n)
    return jnp.sum(per_tree, axis=0)


# --------------------------------------------------------------------------
# Histogram CART training (numpy; second-order gain, XGBoost-style)
# --------------------------------------------------------------------------
def _quantile_bins(X: np.ndarray, n_bins: int) -> np.ndarray:
    """Per-feature bin edges (F, n_bins-1) from quantiles."""
    qs = np.linspace(0, 1, n_bins + 1)[1:-1]
    return np.quantile(X, qs, axis=0).T.astype(np.float32)  # (F, n_bins-1)


def _apply_bins(X: np.ndarray, edges: np.ndarray) -> np.ndarray:
    out = np.empty(X.shape, np.int32)
    for f in range(X.shape[1]):
        out[:, f] = np.searchsorted(edges[f], X[:, f], side="right")
    return out


def fit_tree_arrays(
    Xb: np.ndarray,          # (n, F) int32 binned features
    edges: np.ndarray,       # (F, n_bins-1) bin edges
    grad: np.ndarray,        # (n,) first-order gradients
    hess: np.ndarray,        # (n,) second-order gradients (1.0 for plain CART)
    max_depth: int,
    min_child_weight: float = 1.0,
    reg_lambda: float = 1.0,
    feature_frac: float = 1.0,
    rng: np.random.Generator | None = None,
) -> dict:
    """Grow one tree greedily (BFS), return complete node arrays.

    Gain = ½ [ G_L²/(H_L+λ) + G_R²/(H_R+λ) − G²/(H+λ) ];
    leaf value = −G/(H+λ).  With grad = (pred − y), hess = 1 this reduces to
    variance-reduction CART; with logistic grad/hess it is XGBoost.
    """
    n, F = Xb.shape
    n_bins = int(edges.shape[1]) + 1
    rng = rng or np.random.default_rng(0)
    max_nodes = 2 ** (max_depth + 1) - 1
    feature = np.zeros(max_nodes, np.int32)
    threshold = np.zeros(max_nodes, np.float32)
    split_bin = np.zeros(max_nodes, np.int32)     # bin-space threshold (training)
    left = np.arange(max_nodes, dtype=np.int32)   # default: self-loop (leaf)
    right = np.arange(max_nodes, dtype=np.int32)
    value = np.zeros(max_nodes, np.float32)
    next_free = 1

    # frontier: (node_id, row_idx, depth)
    frontier = [(0, np.arange(n), 0)]
    while frontier:
        node, rows, depth = frontier.pop()
        g, h = grad[rows], hess[rows]
        G, H = g.sum(), h.sum()
        value[node] = -G / (H + reg_lambda)
        if depth >= max_depth or rows.size < 2 or H < 2 * min_child_weight:
            continue
        feats = (
            rng.choice(F, max(1, int(F * feature_frac)), replace=False)
            if feature_frac < 1.0
            else np.arange(F)
        )
        best = (0.0, -1, -1)  # (gain, feature, bin)
        xb = Xb[rows]
        base = 0.5 * G * G / (H + reg_lambda)
        for f in feats:
            hg = np.bincount(xb[:, f], weights=g, minlength=n_bins)
            hh = np.bincount(xb[:, f], weights=h, minlength=n_bins)
            GL = np.cumsum(hg)[:-1]
            HL = np.cumsum(hh)[:-1]
            GR, HR = G - GL, H - HL
            ok = (HL >= min_child_weight) & (HR >= min_child_weight)
            gain = np.where(
                ok,
                0.5 * (GL**2 / (HL + reg_lambda) + GR**2 / (HR + reg_lambda)) - base,
                -np.inf,
            )
            b = int(np.argmax(gain))
            if gain[b] > best[0]:
                best = (float(gain[b]), int(f), b)
        gain, f, b = best
        if f < 0 or gain <= 1e-12 or next_free + 1 >= max_nodes:
            continue
        lo, hi = next_free, next_free + 1
        next_free += 2
        feature[node] = f
        # training went left iff bin <= b iff x < edges[f, b]; nextafter makes
        # the float-space rule ``x <= thr`` match the bin-space rule exactly.
        threshold[node] = np.nextafter(edges[f, b], -np.inf)
        split_bin[node] = b
        left[node], right[node] = lo, hi
        go_left = Xb[rows, f] <= b
        frontier.append((lo, rows[go_left], depth + 1))
        frontier.append((hi, rows[~go_left], depth + 1))

    return dict(
        feature=feature,
        threshold=threshold,
        split_bin=split_bin,
        left=left,
        right=right,
        value=value,
    )


def _stack_trees(trees: list[dict], depth: int) -> TreeEnsemble:
    return TreeEnsemble(
        feature=jnp.asarray(np.stack([t["feature"] for t in trees])),
        threshold=jnp.asarray(np.stack([t["threshold"] for t in trees])),
        left=jnp.asarray(np.stack([t["left"] for t in trees])),
        right=jnp.asarray(np.stack([t["right"] for t in trees])),
        value=jnp.asarray(np.stack([t["value"] for t in trees])),
        depth=depth,
    )


# --------------------------------------------------------------------------
# Random Forest
# --------------------------------------------------------------------------
@dataclass
class RandomForest:
    """Bagged CART forest; regression or binary classification.

    Stands in for the paper's sklearn RandomForest (Turbofan, Student-QA).
    """

    n_trees: int = 50
    max_depth: int = 8
    n_bins: int = 64
    feature_frac: float = 0.7
    task: str = "regression"
    seed: int = 0
    ensemble: TreeEnsemble | None = None
    base: float = 0.0

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RandomForest":
        X = np.asarray(X, np.float32)
        y = np.asarray(y, np.float32)
        rng = np.random.default_rng(self.seed)
        edges = _quantile_bins(X, self.n_bins)
        Xb = _apply_bins(X, edges)
        self.base = float(y.mean())
        trees = []
        for _ in range(self.n_trees):
            rows = rng.integers(0, len(y), len(y))  # bootstrap
            # CART via boosting identity: grad = base − y, hess = 1 at the
            # root means each tree independently fits (y − base).
            g = (self.base - y[rows]).astype(np.float64)
            h = np.ones_like(g)
            trees.append(
                fit_tree_arrays(
                    Xb[rows],
                    edges,
                    g,
                    h,
                    self.max_depth,
                    feature_frac=self.feature_frac,
                    rng=rng,
                )
            )
        self.ensemble = _stack_trees(trees, self.max_depth)
        return self

    # jittable prediction paths ------------------------------------------
    def predict_raw(self, x: jnp.ndarray) -> jnp.ndarray:
        ens = self.ensemble
        return self.base + ensemble_predict_sum(ens, x) / ens.n_trees

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        raw = self.predict_raw(x)
        if self.task == "classification":
            return (raw > 0.5).astype(jnp.int32)
        return raw

    def predict_proba(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.clip(self.predict_raw(x), 0.0, 1.0)


# --------------------------------------------------------------------------
# Gradient Boosting (LightGBM / XGBoost stand-in)
# --------------------------------------------------------------------------
@dataclass
class GradientBoosting:
    """Second-order gradient boosting; squared loss or logistic loss."""

    n_trees: int = 100
    max_depth: int = 6
    n_bins: int = 64
    learning_rate: float = 0.1
    reg_lambda: float = 1.0
    task: str = "regression"
    seed: int = 0
    ensemble: TreeEnsemble | None = None
    base: float = 0.0

    def fit(self, X: np.ndarray, y: np.ndarray) -> "GradientBoosting":
        X = np.asarray(X, np.float32)
        y = np.asarray(y, np.float64)
        rng = np.random.default_rng(self.seed)
        edges = _quantile_bins(X, self.n_bins)
        Xb = _apply_bins(X, edges)
        if self.task == "classification":
            p = np.clip(y.mean(), 1e-6, 1 - 1e-6)
            self.base = float(np.log(p / (1 - p)))
        else:
            self.base = float(y.mean())
        pred = np.full(len(y), self.base)
        trees = []
        for _ in range(self.n_trees):
            if self.task == "classification":
                p = 1.0 / (1.0 + np.exp(-pred))
                g, h = p - y, np.maximum(p * (1 - p), 1e-6)
            else:
                g, h = pred - y, np.ones_like(y)
            t = fit_tree_arrays(
                Xb, edges, g, h, self.max_depth, reg_lambda=self.reg_lambda, rng=rng
            )
            trees.append(t)
            # update predictions with the new tree's (shrunk) leaf values
            contrib = _numpy_tree_predict(t, Xb, edges, self.max_depth)
            pred = pred + self.learning_rate * contrib
        # fold the learning rate into the stored leaf values
        for t in trees:
            t["value"] = (t["value"] * self.learning_rate).astype(np.float32)
        self.ensemble = _stack_trees(trees, self.max_depth)
        return self

    def predict_raw(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.base + ensemble_predict_sum(self.ensemble, x)

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        raw = self.predict_raw(x)
        if self.task == "classification":
            return (raw > 0.0).astype(jnp.int32)
        return raw

    def predict_proba(self, x: jnp.ndarray) -> jnp.ndarray:
        return jax.nn.sigmoid(self.predict_raw(x))


def _numpy_tree_predict(
    tree: dict, Xb: np.ndarray, edges: np.ndarray, depth: int
) -> np.ndarray:
    """Training-time tree application on binned features (numpy, host)."""
    del edges  # traversal happens in bin space
    n = Xb.shape[0]
    idx = np.zeros(n, np.int32)
    rows = np.arange(n)
    for _ in range(depth):
        f = tree["feature"][idx]
        go_left = Xb[rows, f] <= tree["split_bin"][idx]
        idx = np.where(go_left, tree["left"][idx], tree["right"][idx]).astype(np.int32)
    return tree["value"][idx].astype(np.float64)
