"""Arrival-driven serving runtime: queue → admission → fixed-lane dispatch.

The serving layer so far drained a request *list* synchronously; real
user-facing load is a timestamped arrival process.  This module adds the
missing runtime around :class:`~repro.serving.batched.BatchedFusedServer`
(DESIGN.md §Serving runtime):

* a FIFO **request queue** fed by timestamped arrivals (Poisson traces come
  from ``repro.data.synthetic.poisson_arrivals``);
* an **admission batcher** with the classic max-wait / max-size policy: a
  batch launches when ``max_batch`` requests are waiting OR the oldest
  request has waited ``max_wait_s`` (or the trace is drained) — the
  InferLine-style knob trading per-request queueing delay against batch
  efficiency;
* **fixed-lane dispatch**: every admission batch is padded to the server's
  ``batch_size`` lanes (inactive lanes predicated out on device), so the jit
  cache holds exactly ONE executable per power-of-two cap bucket regardless
  of batch fill — varying load never recompiles;
* per-request **queueing delay vs execution latency** records, the numbers a
  provisioning decision actually needs.

SLO-aware graceful degradation (DESIGN.md § Graceful degradation & fault
injection) threads **deadlines** through the same loop: arrivals may carry a
per-request SLO budget (``Arrival.slo_s``, or the runtime-wide ``slo_s``
default), and a :class:`~repro.serving.degrade.DegradationController` maps
each admitted request's remaining budget + the current queue depth to a
knob tier — (delta, tau, iter_cap) are *traced* per-lane executor inputs,
so tier changes never compile.  Requests whose deadline even the loosest
tier cannot meet are **shed** at admission (an explicit ``shed``
disposition instead of unbounded queueing), and transient executor
failures (:class:`~repro.serving.faults.TransientExecutorError`) are
retried with bounded exponential backoff on the virtual clock before a
batch is marked ``failed``.

Time model: arrivals and queueing evolve on a *virtual* clock (so a trace
replays identically regardless of host speed), while each batch's service
time is the real measured wall-clock of ``serve_batch`` — the runtime is a
single-server queueing simulation whose service process is the actual
compiled executor.  Backoff delays are virtual (added to the clock, never
slept), so fault-recovery tests replay deterministically.
"""
from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.serving.batched import (
    BatchedFusedServer,
    chunked_straggler_report,
    device_fill,
)
from repro.serving.continuous import ContinuousBatchedServer
from repro.serving.degrade import DegradationController
from repro.serving.faults import TransientExecutorError

__all__ = [
    "Arrival",
    "RequestRecord",
    "AdmissionBatcher",
    "RuntimeStats",
    "ServingRuntime",
    "ContinuousServingRuntime",
]


@dataclass(frozen=True)
class Arrival:
    """A timestamped request: ``t`` seconds on the virtual arrival clock.

    ``slo_s`` is the request's latency budget (its deadline is ``t +
    slo_s``); ``None`` defers to the runtime-wide default (which may also
    be ``None`` — no deadline, never shed).
    """

    t: float
    request: dict
    slo_s: float | None = None


@dataclass(frozen=True)
class RequestRecord:
    """Per-request accounting emitted by the runtime.

    ``disposition`` is ``"ok"`` (served), ``"shed"`` (rejected at admission
    because no degradation tier could meet its deadline, or the queue hit
    its bound), ``"failed"`` (its batch exhausted transient-failure
    retries), or ``"poisoned"`` (continuous only: its lane failed the
    post-chunk numerical-health check and exhausted its bounded
    re-admission attempts — see DESIGN.md § Fault tolerance).  Shed/failed/
    poisoned records carry ``y_hat = nan`` and ``batch_id = -1`` / the
    failed batch id; latency for a shed request is the time it spent queued
    before the runtime gave up on it.  ``tier``/``tau``/
    ``delta`` echo the degradation knobs the request was served under
    (baseline values when no controller is installed) so the summary's
    guarantee rate can be computed against the tau each request was
    actually promised.

    Continuous batching (:class:`ContinuousServingRuntime`) reinterprets
    the batch-granularity fields at chunk granularity: ``admit_t`` is the
    time the request entered a LANE (queue-delay = time-to-first-lane),
    ``exec_s`` the lane-resident wall time (the request spans multiple
    chunk dispatches), ``batch_id`` the admission-event index and
    ``batch_fill`` the occupied-lane count right after it.  ``lane`` /
    ``n_chunks`` record where it ran and how many chunk dispatches it
    spanned (fixed-lane records keep the ``-1`` / ``0`` defaults), and
    ``z`` the final per-feature plan — the recycling-parity tests compare
    it bitwise against a serial replay.
    """

    req_id: int
    arrival_t: float
    admit_t: float          # when its admission batch started executing
    done_t: float
    queue_delay_s: float    # admit_t - arrival_t  (the batching cost)
    exec_s: float           # its batch's wall-clock service time
    latency_s: float        # done_t - arrival_t   (what the user sees)
    batch_id: int
    batch_fill: int         # active lanes in its batch
    y_hat: float
    prob: float
    iters: int
    sample_frac: float
    deadline_t: float = math.inf
    disposition: str = "ok"
    tier: int = 0
    tau: float | None = None     # the confidence target it was served under
    delta: float | None = None   # the error bound it was served under
    deadline_met: bool = True
    lane: int = -1               # lane it ran in (continuous; -1 = fixed-lane)
    n_chunks: int = 0            # chunk dispatches it spanned (continuous)
    z: tuple | None = None       # final per-feature plan (continuous)


class AdmissionBatcher:
    """max-wait / max-size admission policy (pure, for unit testing)."""

    # tolerance for "the wait expired": the runtime advances its clock to
    # ``t_oldest + max_wait_s`` and recomputes ``now - t_oldest``, which can
    # round to just under max_wait_s — without the epsilon that state admits
    # nothing and the virtual clock stops advancing (a livelock).
    _EPS = 1e-9

    def __init__(self, max_size: int, max_wait_s: float):
        if max_size < 1:
            raise ValueError("max_size must be >= 1")
        if max_wait_s < 0:
            raise ValueError("max_wait_s must be >= 0")
        self.max_size = max_size
        self.max_wait_s = max_wait_s

    def ready(self, queue_len: int, oldest_wait_s: float, more_coming: bool) -> bool:
        """Admit now?  Full batch, expired wait, or a drained trace."""
        if queue_len <= 0:
            return False
        return (
            queue_len >= self.max_size
            or oldest_wait_s >= self.max_wait_s - self._EPS
            or not more_coming
        )


@dataclass
class RuntimeStats:
    """Everything one load run produced; ``summary()`` is the §4-style table.

    ``tau`` is the server's baseline confidence target and is REQUIRED —
    a defaulted value here once diverged silently from the server config's,
    and with per-lane degradation the summary must anyway prefer each
    record's own tau (the target the request was actually served under);
    the baseline only backfills legacy records that carry none.
    """

    tau: float
    records: list[RequestRecord] = field(default_factory=list)
    makespan_s: float = 0.0     # first arrival -> last completion (virtual)
    busy_s: float = 0.0         # total wall time spent inside serve_batch
    n_batches: int = 0
    compile_count: int = 0      # executables built DURING the run (post-warmup)
    compiled_buckets: list[int] = field(default_factory=list)
    n_devices: int = 1          # serving-mesh size the lanes were sharded over
    lanes: int = 0              # fixed lane count (0 = unknown/legacy)
    n_shed: int = 0             # rejected at admission (deadline/queue bound)
    n_failed: int = 0           # batches' requests that exhausted retries
    n_retries: int = 0          # transient-failure retries (backoff events)
    n_rollbacks: int = 0        # chunk-boundary checkpoint restores (continuous)
    n_poisoned: int = 0         # lanes quarantined past their re-admission bound
    n_chunks: int = 0           # chunk dispatches (continuous; 0 = fixed-lane)
    n_recycles: int = 0         # admissions into a previously-used lane
    lane_occupancy: float = 0.0  # mean occupied-lane fraction over chunks
    chunk_stats: dict = field(default_factory=dict)  # chunked_straggler_report

    def _device_fill_stats(self) -> dict:
        """Per-device fill + lane imbalance, averaged over admission batches.

        Lanes partition contiguously over the 1-D serving mesh and fills are
        front-packed, so a batch's fill determines each device's active-lane
        count (``batched.device_fill``).  Reported only when the mesh has
        more than one device — a single-device run has nothing to split —
        and well-defined (zeros) on an empty record set OR when the lane
        count is unknown (``lanes == 0``: a hand-built stats object) — a
        guessed partition would fabricate balance numbers.  Shed records
        never reached a batch (``batch_id == -1``) and are excluded.

        Continuous runs override the front-packed guess entirely: recycled
        lanes are refilled IN PLACE (any occupancy pattern), so the numbers
        come from the occupancy matrix (``chunked_straggler_report``) — the
        well-defined accounting when a lane serves many requests per
        window.
        """
        if self.chunk_stats:
            return {
                "per_device_fill": [
                    float(x) for x in self.chunk_stats["per_device_fill"]
                ],
                "mean_lane_imbalance": float(
                    self.chunk_stats["lane_imbalance"]
                ),
            }
        fills = {
            r.batch_id: r.batch_fill for r in self.records if r.batch_id >= 0
        }
        if not fills or not self.lanes:
            return {
                "per_device_fill": [0.0] * self.n_devices,
                "mean_lane_imbalance": 0.0,
            }
        lanes = self.lanes
        per_dev = np.stack(
            [
                device_fill(f, lanes, self.n_devices) / (lanes // self.n_devices)
                for f in fills.values()
            ]
        )  # (batches, n_devices) fill fractions
        return {
            "per_device_fill": [float(x) for x in per_dev.mean(0)],
            "mean_lane_imbalance": float(
                (per_dev.max(1) - per_dev.min(1)).mean()
            ),
        }

    def summary(self) -> dict:
        served = [r for r in self.records if r.disposition == "ok"]
        n = len(served)
        n_offered = len(self.records)
        device = (
            {"n_devices": self.n_devices, **self._device_fill_stats()}
            if self.n_devices > 1
            else {"n_devices": self.n_devices}
        )
        degrade = {
            "n_offered": n_offered,
            "n_shed": int(self.n_shed),
            "n_failed": int(self.n_failed),
            "n_retries": int(self.n_retries),
            "n_rollbacks": int(self.n_rollbacks),
            "n_poisoned": int(self.n_poisoned),
            "shed_rate": float(self.n_shed / n_offered) if n_offered else 0.0,
        }
        with_deadline = [r for r in self.records if math.isfinite(r.deadline_t)]
        degrade["deadline_met_rate"] = (
            float(np.mean([r.deadline_met for r in with_deadline]))
            if with_deadline
            else float("nan")
        )
        continuous = (
            {
                "n_chunks": int(self.n_chunks),
                "n_recycles": int(self.n_recycles),
                "lane_occupancy": float(self.lane_occupancy),
                "chunk_wasted_frac": float(
                    self.chunk_stats.get("wasted_frac", 0.0)
                ),
            }
            if self.chunk_stats  # set by every continuous run, even 0-chunk
            else {}
        )
        if n == 0:
            return {
                "n": 0,
                "throughput_rps": 0.0,
                "p50_latency_ms": float("nan"),
                "p99_latency_ms": float("nan"),
                "mean_latency_ms": float("nan"),
                "mean_queue_delay_ms": float("nan"),
                "p99_queue_delay_ms": float("nan"),
                "mean_exec_ms": float("nan"),
                "mean_batch_fill": 0.0,
                "n_batches": 0,
                "utilization": 0.0,
                "mean_sample_frac": float("nan"),
                "guarantee_rate": 0.0,
                "mean_tier": 0.0,
                "max_tier": 0,
                "compile_count": int(self.compile_count),
                "compiled_buckets": list(self.compiled_buckets),
                **degrade,
                **continuous,
                **device,
            }
        lat = np.array([r.latency_s for r in served]) * 1e3
        qd = np.array([r.queue_delay_s for r in served]) * 1e3
        ex = np.array([r.exec_s for r in served]) * 1e3
        fill = np.array([r.batch_fill for r in served], np.float64)
        frac = np.array([r.sample_frac for r in served])
        prob = np.array([r.prob for r in served])
        # the guarantee each request was SERVED under: its own (possibly
        # degraded) tau, falling back to the baseline for legacy records
        taus = np.array(
            [self.tau if r.tau is None else r.tau for r in served]
        )
        tiers = np.array([r.tier for r in served])
        span = max(self.makespan_s, 1e-12)
        return {
            "n": n,
            "throughput_rps": n / span,
            "p50_latency_ms": float(np.percentile(lat, 50)),
            "p99_latency_ms": float(np.percentile(lat, 99)),
            "mean_latency_ms": float(lat.mean()),
            "mean_queue_delay_ms": float(qd.mean()),
            "p99_queue_delay_ms": float(np.percentile(qd, 99)),
            "mean_exec_ms": float(ex.mean()),
            "mean_batch_fill": float(fill.mean()),
            "n_batches": int(self.n_batches),
            "utilization": float(self.busy_s / span),
            # the paper's §4 quality metrics, so the CLI table is comparable
            # across host / fused / fused-batched modes (a request also counts
            # as satisfied when it provably exhausted its groups); under
            # degradation each request is judged against ITS OWN tau
            "mean_sample_frac": float(frac.mean()),
            "guarantee_rate": float(
                np.mean((prob >= taus) | (frac >= 0.999))
            ),
            "mean_tier": float(tiers.mean()),
            "max_tier": int(tiers.max(initial=0)),
            "compile_count": int(self.compile_count),
            "compiled_buckets": list(self.compiled_buckets),
            **degrade,
            **continuous,
            **device,
        }


class ServingRuntime:
    """Single-server arrival loop over a :class:`BatchedFusedServer`.

    ``slo_s`` attaches a default latency budget to arrivals that carry none;
    ``controller`` (a :class:`~repro.serving.degrade.DegradationController`)
    enables deadline-driven knob scaling and load shedding.  Transient
    executor failures are retried up to ``max_retries`` times with
    exponential backoff (``backoff_s · 2^attempt``, virtual-clock) before
    the batch's requests are recorded as ``failed``.
    """

    def __init__(
        self,
        server: BatchedFusedServer,
        max_wait_s: float = 0.05,
        max_batch: int | None = None,
        *,
        slo_s: float | None = None,
        controller: DegradationController | None = None,
        max_retries: int = 2,
        backoff_s: float = 0.02,
    ):
        self.server = server
        max_batch = max_batch if max_batch is not None else server.batch_size
        if max_batch > server.batch_size:
            raise ValueError(
                f"max_batch {max_batch} exceeds the server's fixed lane count "
                f"{server.batch_size}"
            )
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if backoff_s < 0:
            raise ValueError("backoff_s must be >= 0")
        self.batcher = AdmissionBatcher(max_batch, max_wait_s)
        self.slo_s = slo_s
        self.controller = controller
        self.max_retries = max_retries
        self.backoff_s = backoff_s

    # ------------------------------------------------------------------
    def warmup(self, requests: list[dict] | None = None) -> list[int]:
        """Compile every cap bucket the request population can hit.

        A mixed batch's cap is ``bucket(max group)`` = the max of its
        members' single-request caps, so warming one full-lane batch per
        distinct per-request cap covers every batch composition.  Returns
        the warmed buckets.
        """
        reqs = requests if requests is not None else self.server.bundle.requests
        by_cap: dict[int, dict] = {}
        for req in reqs:
            by_cap.setdefault(self.server.batch_cap([req]), req)
        already = set(self.server.compiled_buckets)
        for cap in sorted(by_cap):
            if cap not in already:  # don't re-pay a warm bucket every run()
                self.server.serve_batch([by_cap[cap]])
        return sorted(by_cap)

    # ------------------------------------------------------------------
    def _default_delta(self) -> float:
        cfg, p = self.server.config, self.server.bundle.pipeline
        return cfg.delta if cfg.delta is not None else p.delta_default

    def _serve_with_retries(self, requests, make_knobs, stats, now):
        """serve_batch under the bounded-retry/backoff policy.

        ``make_knobs(now)`` builds the per-lane knob list for the CURRENT
        virtual clock (or None without a controller) and is re-invoked after
        every backoff, so a request that burned deadline budget on retries
        is re-tiered against its post-retry slack — retries and degradation
        stay coherent instead of serving late at full accuracy.

        Returns ``(result_or_None, knobs_used, new_now)``; failed attempts
        charge their real wall-clock to ``busy_s``/the virtual clock, and
        each retry adds an exponential virtual backoff delay (never slept —
        deterministic replay).  ``None`` means retries were exhausted.
        """
        attempt = 0
        knobs = make_knobs(now)
        while True:
            t0 = time.perf_counter()
            try:
                if knobs is None:
                    res = self.server.serve_batch(requests)
                else:
                    res = self.server.serve_batch(requests, knobs=knobs)
            except TransientExecutorError:
                dt = time.perf_counter() - t0
                now += dt
                stats.busy_s += dt
                if attempt >= self.max_retries:
                    return None, knobs, now
                now += self.backoff_s * (2.0**attempt)
                attempt += 1
                stats.n_retries += 1
                knobs = make_knobs(now)  # post-retry slack, re-priced
                continue
            dt = time.perf_counter() - t0
            return (res, dt), knobs, now

    # ------------------------------------------------------------------
    def run(self, arrivals, warmup: bool = True) -> RuntimeStats:
        """Replay a timestamped arrival trace; returns per-request records.

        ``arrivals``: iterable of :class:`Arrival`, ``(t, request)`` or
        ``(t, request, slo_s)`` tuples (seconds on the virtual clock; sorted
        internally).
        """
        arr = sorted(
            (
                a if isinstance(a, Arrival) else Arrival(float(a[0]), *a[1:])
                for a in arrivals
            ),
            key=lambda a: a.t,
        )
        if warmup:
            self.warmup([a.request for a in arr])
        compiles_before = self.server.compile_count

        stats = RuntimeStats(
            tau=self.server.config.tau,
            n_devices=self.server.n_devices,
            lanes=self.server.batch_size,
        )
        if not arr:
            stats.compiled_buckets = self.server.compiled_buckets
            return stats

        deadlines = [
            a.t + a.slo_s
            if a.slo_s is not None
            else (a.t + self.slo_s if self.slo_s is not None else math.inf)
            for a in arr
        ]
        base_delta = self._default_delta()
        ctl = self.controller

        records: list[RequestRecord | None] = [None] * len(arr)
        queue: deque[int] = deque()
        now = arr[0].t
        i = 0
        batch_id = 0
        while i < len(arr) or queue:
            if not queue:
                now = max(now, arr[i].t)
            while i < len(arr) and arr[i].t <= now:
                queue.append(i)
                i += 1
            oldest_wait = now - arr[queue[0]].t
            if not self.batcher.ready(len(queue), oldest_wait, i < len(arr)):
                # idle until the next decision point: the oldest request's
                # max-wait deadline or the next arrival, whichever is first
                # (both are strictly > now, so the loop always progresses)
                now = min(arr[queue[0]].t + self.batcher.max_wait_s, arr[i].t)
                continue
            # ---- admission: shed infeasible requests, then fill the batch
            idxs: list[int] = []
            while queue and len(idxs) < self.batcher.max_size:
                j = queue[0]
                slack = (
                    deadlines[j] - now
                    if math.isfinite(deadlines[j])
                    else None
                )
                if ctl is not None and ctl.should_shed(slack, len(queue)):
                    queue.popleft()
                    records[j] = RequestRecord(
                        req_id=j,
                        arrival_t=arr[j].t,
                        admit_t=now,
                        done_t=now,
                        queue_delay_s=now - arr[j].t,
                        exec_s=0.0,
                        latency_s=now - arr[j].t,
                        batch_id=-1,
                        batch_fill=0,
                        y_hat=float("nan"),
                        prob=0.0,
                        iters=0,
                        sample_frac=0.0,
                        deadline_t=deadlines[j],
                        disposition="shed",
                        tier=len(ctl.tiers) - 1,
                        deadline_met=False,
                    )
                    stats.n_shed += 1
                    continue
                queue.popleft()
                idxs.append(j)
            if not idxs:
                continue  # everything was shed; rerun the admission decision
            # ---- knob assignment: remaining budget + congestion -> tier.
            # Built as a closure over the batch so the retry path can
            # re-price each request's slack after every virtual backoff.
            depth = len(queue)  # still-waiting requests behind this batch

            def make_knobs(t, idxs=idxs, depth=depth):
                if ctl is None:
                    return None
                return [
                    ctl.retier(
                        deadlines[j] - t
                        if math.isfinite(deadlines[j])
                        else None,
                        depth,
                        base_delta,
                    )
                    for j in idxs
                ]

            admit_t = now
            out, knobs, now = self._serve_with_retries(
                [arr[j].request for j in idxs], make_knobs, stats, now
            )
            if out is None:  # retries exhausted: the whole batch failed
                for lane, j in enumerate(idxs):
                    kn = knobs[lane] if knobs is not None else None
                    records[j] = RequestRecord(
                        req_id=j,
                        arrival_t=arr[j].t,
                        admit_t=admit_t,
                        done_t=now,
                        queue_delay_s=admit_t - arr[j].t,
                        exec_s=0.0,
                        latency_s=now - arr[j].t,
                        batch_id=batch_id,
                        batch_fill=len(idxs),
                        y_hat=float("nan"),
                        prob=0.0,
                        iters=0,
                        sample_frac=0.0,
                        deadline_t=deadlines[j],
                        disposition="failed",
                        tier=kn.tier if kn is not None else 0,
                        tau=kn.tau if kn is not None else None,
                        delta=kn.delta if kn is not None else None,
                        deadline_met=False,
                    )
                    stats.n_failed += 1
                batch_id += 1
                if ctl is not None:
                    ctl.observe(ctl.service_est_s, len(queue))
                continue
            res, dt = out
            now += dt
            stats.busy_s += dt
            for lane, j in enumerate(idxs):
                kn = knobs[lane] if knobs is not None else None
                records[j] = RequestRecord(
                    req_id=j,
                    arrival_t=arr[j].t,
                    admit_t=admit_t,
                    done_t=now,
                    queue_delay_s=admit_t - arr[j].t,
                    exec_s=dt,
                    latency_s=now - arr[j].t,
                    batch_id=batch_id,
                    batch_fill=len(idxs),
                    y_hat=float(res.y_hat[lane]),
                    prob=float(res.prob[lane]),
                    iters=int(res.iters[lane]),
                    sample_frac=float(res.sample_frac[lane]),
                    deadline_t=deadlines[j],
                    disposition="ok",
                    tier=kn.tier if kn is not None else 0,
                    tau=kn.tau if kn is not None else None,
                    delta=kn.delta if kn is not None else None,
                    deadline_met=bool(now <= deadlines[j]),
                )
            batch_id += 1
            if ctl is not None:
                # post-batch feedback: EWMA the measured service time and
                # step the hysteretic load tier from the residual queue
                ctl.observe(dt, len(queue))

        stats.records = [r for r in records if r is not None]
        stats.makespan_s = now - arr[0].t
        stats.n_batches = batch_id
        stats.compile_count = self.server.compile_count - compiles_before
        stats.compiled_buckets = self.server.compiled_buckets
        return stats


class ContinuousServingRuntime:
    """Chunk-granularity lane-table scheduler (continuous batching).

    Drives a :class:`~repro.serving.continuous.ContinuousBatchedServer`:
    instead of admitting a batch and holding every lane until the slowest
    request exits, the runtime dispatches the chunked executor —
    ``chunk_iters`` planner iterations at a time — and at every chunk
    boundary refills lanes whose requests converged with the next requests
    from the queue (iteration-level lane recycling).  There is no max-wait
    admission batcher: a request waits exactly until a lane frees up
    (queue-delay = time-to-first-lane).

    Accounting is per chunk, not per batch: each request's
    :class:`RequestRecord` spans the chunks it was lane-resident for
    (``exec_s`` = lane-resident wall time, ``n_chunks``/``lane`` recorded),
    ``RuntimeStats`` gains ``n_chunks`` / ``n_recycles`` /
    ``lane_occupancy``, and straggler waste is charged per chunk against
    the chunk-boundary device-block maxima
    (``batched.chunked_straggler_report`` over the recorded occupancy and
    per-chunk-iteration matrices).

    SLO-aware degradation (PR 6) composes at the RIGHT time scale:
    shed/tier decisions are re-evaluated when a request is admitted INTO A
    LANE — with its remaining deadline slack and the queue depth at that
    boundary — not when it joined the queue; the knobs ride the refill
    dispatch as traced per-lane inputs, so tier changes never compile.
    The controller's ``observe`` feedback runs per chunk (service estimate
    = EWMA of chunk wall time).

    Time model matches :class:`ServingRuntime`: virtual arrival clock,
    measured wall-clock for every refill and chunk dispatch.

    Fault tolerance (DESIGN.md § Fault tolerance): before every chunk
    dispatch the runtime snapshots the table's chunk-mutable carry
    (``server.snapshot`` — host copies of the small leaves, zero
    executables); a :class:`~repro.serving.faults.TransientExecutorError`
    rolls the carry back to that chunk boundary (onto the wreck a
    :class:`~repro.serving.faults.ChunkDispatchError` hands back, when it
    does) and replays — bitwise-identical to a fault-free run, because the
    bootstrap RNG is counter-based on the restored per-request iteration
    index.  Admissions are idempotent (same re-init, same counters), so a
    failed ``admit`` is simply retried whole, with each assignment's knobs
    re-priced against its post-retry slack.  After every successful chunk a
    numerical-health check runs over the occupied lanes (NaN/Inf in
    ``y_hat``/``prob``, z outside ``[0, cap]`` or regressing vs the
    monotone-growth invariant, a ``done`` flag the knobs cannot explain);
    unhealthy lanes are quarantined INDIVIDUALLY — the request is re-queued
    for up to ``poison_retries`` full re-admissions (a re-init resets all
    lane state) and recorded ``disposition="poisoned"`` past that bound —
    while every other lane's carry proceeds untouched.  When chunk retries
    are exhausted, the lane-resident requests are recorded ``failed`` and
    their lanes cleared, so a dead device costs its residents — never the
    table, the queue, or the cache.
    """

    def __init__(
        self,
        server: ContinuousBatchedServer,
        *,
        slo_s: float | None = None,
        controller: DegradationController | None = None,
        max_retries: int = 2,
        backoff_s: float = 0.02,
        poison_retries: int = 1,
    ):
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if backoff_s < 0:
            raise ValueError("backoff_s must be >= 0")
        if poison_retries < 0:
            raise ValueError("poison_retries must be >= 0")
        self.server = server
        self.slo_s = slo_s
        self.controller = controller
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.poison_retries = poison_retries

    # ------------------------------------------------------------------
    def warmup(self, requests: list[dict] | None = None) -> list[int]:
        """Compile the refill + chunk executables for the trace's cap.

        A continuous run serves its whole trace from ONE table at the
        trace-wide max cap bucket, so warming that single bucket (one
        refill + one chunk on a throwaway table) covers the run.  Returns
        the warmed bucket.
        """
        import jax

        reqs = requests if requests is not None else self.server.bundle.requests
        cap = self.server.trace_cap(reqs)
        if cap in self.server.compiled_buckets:
            return [cap]
        table = self.server.new_table(cap)
        table, _ = self.server.admit(table, cap, [(0, reqs[0], None)])
        jax.block_until_ready(self.server.run_chunk(table))
        return [cap]

    def _default_delta(self) -> float:
        cfg, p = self.server.config, self.server.bundle.pipeline
        return cfg.delta if cfg.delta is not None else p.delta_default

    def _lane_health(self, out, lane, prev_z_lane, cap, kn) -> str | None:
        """Post-chunk numerical-health verdict for one occupied lane.

        Returns a reason string when the lane's carry violates an invariant
        a healthy executor cannot: non-finite ``y_hat``/``prob``, a
        guarantee probability outside [0, 1], a plan outside ``[0, cap]``
        or shrinking against the monotone-growth invariant, or a ``done``
        flag the knobs cannot explain (guarantee unmet, groups unexhausted,
        iterations left).  ``None`` = healthy.
        """
        y = float(out["y_hat"][lane])
        p = float(out["prob"][lane])
        if not (math.isfinite(y) and math.isfinite(p)):
            return "non-finite y_hat/prob"
        if not (0.0 <= p <= 1.0 + 1e-6):
            return f"prob {p} outside [0, 1]"
        z = np.asarray(out["z"][lane])
        if (z < 0).any() or (z > cap).any():
            return "z outside [0, cap]"
        if (z < prev_z_lane).any():
            return "z regression (monotone-growth invariant)"
        if bool(out["done"][lane]):
            cfg = self.server.config
            tau = float(kn.tau) if kn is not None else float(cfg.tau)
            iter_cap = (
                int(kn.iter_cap) if kn is not None else int(cfg.max_iters)
            )
            exhausted = bool(
                (z >= np.minimum(np.asarray(out["n"][lane]), cap)).all()
            )
            if (
                p < tau - 1e-6
                and not exhausted
                and int(out["it"][lane]) < iter_cap
            ):
                return "done flag inconsistent with the guarantee"
        return None

    # ------------------------------------------------------------------
    def run(self, arrivals, warmup: bool = True) -> RuntimeStats:
        """Replay a timestamped arrival trace through the lane table."""
        import jax

        arr = sorted(
            (
                a if isinstance(a, Arrival) else Arrival(float(a[0]), *a[1:])
                for a in arrivals
            ),
            key=lambda a: a.t,
        )
        stats = RuntimeStats(
            tau=self.server.config.tau,
            n_devices=self.server.n_devices,
            lanes=self.server.batch_size,
        )
        if not arr:
            stats.compiled_buckets = self.server.compiled_buckets
            return stats
        if warmup:
            self.warmup([a.request for a in arr])
        compiles_before = self.server.compile_count

        deadlines = [
            a.t + a.slo_s
            if a.slo_s is not None
            else (a.t + self.slo_s if self.slo_s is not None else math.inf)
            for a in arr
        ]
        base_delta = self._default_delta()
        ctl = self.controller
        lanes = self.server.batch_size
        cap = self.server.trace_cap([a.request for a in arr])
        table = self.server.new_table(cap)

        records: list[RequestRecord | None] = [None] * len(arr)
        queue: deque[int] = deque()
        # lane bookkeeping is HOST state: the device table never learns
        # which request a lane holds, only its buffers and carry
        occupied: list[int | None] = [None] * lanes
        admit_ts = [0.0] * lanes
        admit_ids = [0] * lanes      # admission-event index -> batch_id
        admit_fill = [0] * lanes     # occupied lanes right after admission
        knobs_by_lane = [None] * lanes
        chunks_by_lane = [0] * lanes
        true_rows = [1] * lanes
        lane_used = [False] * lanes
        prev_it = np.zeros(lanes, np.int64)
        # monotone-z tracking for the post-chunk health check: each occupied
        # lane's plan at its last healthy boundary (set from z⁰ at admission)
        prev_z = np.zeros((lanes, self.server.bundle.pipeline.k), np.int64)
        poison_attempts: dict[int, int] = {}
        occ_rows: list[np.ndarray] = []
        iter_rows: list[np.ndarray] = []
        admissions = 0
        n_chunks = 0
        now = arr[0].t
        i = 0

        def finalize(lane: int, out: dict, t_done: float) -> None:
            j = occupied[lane]
            kn = knobs_by_lane[lane]
            z = np.asarray(out["z"][lane])
            records[j] = RequestRecord(
                req_id=j,
                arrival_t=arr[j].t,
                admit_t=admit_ts[lane],
                done_t=t_done,
                queue_delay_s=admit_ts[lane] - arr[j].t,
                exec_s=t_done - admit_ts[lane],
                latency_s=t_done - arr[j].t,
                batch_id=admit_ids[lane],
                batch_fill=admit_fill[lane],
                y_hat=float(out["y_hat"][lane]),
                prob=float(out["prob"][lane]),
                iters=int(out["it"][lane]),
                sample_frac=float(
                    np.minimum(z, np.asarray(out["n"][lane])).sum()
                )
                / max(true_rows[lane], 1),
                deadline_t=deadlines[j],
                disposition="ok",
                tier=kn.tier if kn is not None else 0,
                tau=kn.tau if kn is not None else None,
                delta=kn.delta if kn is not None else None,
                deadline_met=bool(t_done <= deadlines[j]),
                lane=lane,
                n_chunks=chunks_by_lane[lane],
                z=tuple(int(x) for x in z),
            )
            occupied[lane] = None
            knobs_by_lane[lane] = None

        def drop(lane: int, disposition: str, t: float) -> None:
            """Record a lane-resident request as failed/poisoned and free
            its host bookkeeping (the device lane is cleared separately)."""
            j = occupied[lane]
            kn = knobs_by_lane[lane]
            records[j] = RequestRecord(
                req_id=j,
                arrival_t=arr[j].t,
                admit_t=admit_ts[lane],
                done_t=t,
                queue_delay_s=admit_ts[lane] - arr[j].t,
                exec_s=t - admit_ts[lane],
                latency_s=t - arr[j].t,
                batch_id=admit_ids[lane],
                batch_fill=admit_fill[lane],
                y_hat=float("nan"),
                prob=0.0,
                iters=0,
                sample_frac=0.0,
                deadline_t=deadlines[j],
                disposition=disposition,
                tier=kn.tier if kn is not None else 0,
                tau=kn.tau if kn is not None else None,
                delta=kn.delta if kn is not None else None,
                deadline_met=False,
                lane=lane,
                n_chunks=chunks_by_lane[lane],
            )
            occupied[lane] = None
            knobs_by_lane[lane] = None

        while i < len(arr) or queue or any(l is not None for l in occupied):
            if not queue and all(l is None for l in occupied):
                if i >= len(arr):
                    break
                now = max(now, arr[i].t)  # idle: jump to the next arrival
            while i < len(arr) and arr[i].t <= now:
                queue.append(i)
                i += 1
            # ---- chunk-boundary admission into free lanes: shed/tier
            # decisions are made HERE, with the slack and queue depth of
            # the moment the request actually gets a lane
            free = [l for l in range(lanes) if occupied[l] is None]
            assignments = []
            while queue and free:
                j = queue.popleft()
                slack = (
                    deadlines[j] - now
                    if math.isfinite(deadlines[j])
                    else None
                )
                if ctl is not None and ctl.should_shed(slack, len(queue) + 1):
                    records[j] = RequestRecord(
                        req_id=j,
                        arrival_t=arr[j].t,
                        admit_t=now,
                        done_t=now,
                        queue_delay_s=now - arr[j].t,
                        exec_s=0.0,
                        latency_s=now - arr[j].t,
                        batch_id=-1,
                        batch_fill=0,
                        y_hat=float("nan"),
                        prob=0.0,
                        iters=0,
                        sample_frac=0.0,
                        deadline_t=deadlines[j],
                        disposition="shed",
                        tier=len(ctl.tiers) - 1,
                        deadline_met=False,
                    )
                    stats.n_shed += 1
                    continue
                lane = free.pop(0)
                kn = None
                if ctl is not None:
                    kn = ctl.knobs_for(
                        ctl.tier_for(slack, len(queue)), base_delta
                    )
                assignments.append((lane, arr[j].request, kn))
                occupied[lane] = j
                admit_ts[lane] = now
                admit_ids[lane] = admissions
                chunks_by_lane[lane] = 0
                knobs_by_lane[lane] = kn
                prev_it[lane] = 0
                if lane_used[lane]:
                    stats.n_recycles += 1
                lane_used[lane] = True
            if assignments:
                admissions += 1
                # admission is idempotent (the refill re-inits the whole
                # lane from counter-based RNG), so a transient failure just
                # retries the WHOLE admit — with every assignment's knobs
                # re-priced against its post-retry slack
                attempt = 0
                admitted = True
                while True:
                    t0 = time.perf_counter()
                    try:
                        table, tr = self.server.admit(table, cap, assignments)
                        jax.block_until_ready(table)
                    except TransientExecutorError:
                        dt = time.perf_counter() - t0
                        now += dt
                        stats.busy_s += dt
                        if attempt >= self.max_retries:
                            admitted = False
                            break
                        now += self.backoff_s * (2.0**attempt)
                        attempt += 1
                        stats.n_retries += 1
                        if ctl is not None:
                            assignments = [
                                (
                                    lane,
                                    req,
                                    ctl.retier(
                                        deadlines[occupied[lane]] - now
                                        if math.isfinite(
                                            deadlines[occupied[lane]]
                                        )
                                        else None,
                                        len(queue),
                                        base_delta,
                                    ),
                                )
                                for lane, req, _kn in assignments
                            ]
                            for lane, _req, kn in assignments:
                                knobs_by_lane[lane] = kn
                        continue
                    dt = time.perf_counter() - t0
                    now += dt
                    stats.busy_s += dt
                    break
                if not admitted:
                    # retries exhausted before any lane was (fully) refilled:
                    # the assigned requests fail; their lanes are cleared in
                    # case a partial admit left them active
                    dead = [lane for lane, _req, _kn in assignments]
                    for lane in dead:
                        drop(lane, "failed", now)
                        stats.n_failed += 1
                    table = self.server.clear_lanes(table, dead)
                    continue
                fill = sum(l is not None for l in occupied)
                for lane, rows in tr.items():
                    true_rows[lane] = rows
                    admit_fill[lane] = fill
                # a fresh lane can be done straight from z⁰ (guarantee met
                # at the initial plan) — recycle it before paying a chunk
                out = self.server.readback(table)
                for lane, _, _ in assignments:
                    prev_z[lane] = np.asarray(out["z"][lane], np.int64)
                    if out["done"][lane]:
                        finalize(lane, out, now)
            if all(l is None for l in occupied):
                continue  # everything shed or instantly done; re-admit
            # ---- one chunk dispatch, checkpointed at the boundary: the
            # snapshot holds host copies of the chunk-mutable carry leaves
            # (CHUNK_CARRY_LEAVES); a transient dispatch failure rolls the
            # table back to this boundary and replays — counter-based RNG
            # makes the replay bitwise-identical, and both snapshot and
            # restore are host buffer swaps (zero new executables)
            ckpt = self.server.snapshot(table)
            attempt = 0
            dispatched = True
            while True:
                t0 = time.perf_counter()
                try:
                    table = self.server.run_chunk(table)
                    jax.block_until_ready(table)
                except TransientExecutorError as e:
                    dt = time.perf_counter() - t0
                    now += dt
                    stats.busy_s += dt
                    # the raiser may hand back the wrecked table (e.g. a
                    # mid-chunk crash leaving scrambled carry); adopt it so
                    # the rollback is exercised against real damage, then
                    # restore the last good boundary
                    wreck = getattr(e, "table", None)
                    if wreck is not None:
                        table = wreck
                    table = self.server.restore(table, ckpt)
                    stats.n_rollbacks += 1
                    if attempt >= self.max_retries:
                        dispatched = False
                        break
                    now += self.backoff_s * (2.0**attempt)
                    attempt += 1
                    stats.n_retries += 1
                    continue
                dt = time.perf_counter() - t0
                now += dt
                stats.busy_s += dt
                break
            if not dispatched:
                # persistent dispatch failure: fail every resident request
                # and clear their lanes so draining continues (bounded p99
                # instead of an infinite retry loop)
                dead = [l for l in range(lanes) if occupied[l] is not None]
                for lane in dead:
                    drop(lane, "failed", now)
                    stats.n_failed += 1
                table = self.server.clear_lanes(table, dead)
                continue
            n_chunks += 1
            out = self.server.readback(table)
            occ = np.array([l is not None for l in occupied])
            occ_rows.append(occ)
            iter_rows.append(np.where(occ, out["it"] - prev_it, 0))
            prev_it = out["it"].copy()
            # ---- post-chunk numerical-health check: quarantine poisoned
            # lanes (NaN/Inf carry, z regression, inconsistent done flag)
            # without touching their healthy neighbors
            poisoned: list[int] = []
            for lane in range(lanes):
                if occupied[lane] is None:
                    continue
                chunks_by_lane[lane] += 1
                verdict = self._lane_health(
                    out, lane, prev_z[lane], cap, knobs_by_lane[lane]
                )
                if verdict is None:
                    prev_z[lane] = np.asarray(out["z"][lane], np.int64)
                    if out["done"][lane]:
                        finalize(lane, out, now)
                    continue
                poisoned.append(lane)
                j = occupied[lane]
                poison_attempts[j] = poison_attempts.get(j, 0) + 1
                if poison_attempts[j] <= self.poison_retries:
                    # bounded re-admission: the request goes back to the
                    # FRONT of the queue and gets a full fresh admit (which
                    # re-initializes every lane leaf), not a carry patch
                    queue.appendleft(j)
                    occupied[lane] = None
                    knobs_by_lane[lane] = None
                else:
                    drop(lane, "poisoned", now)
                    stats.n_poisoned += 1
            if poisoned:
                table = self.server.clear_lanes(table, poisoned)
            if ctl is not None:
                ctl.observe(dt, len(queue))

        stats.records = [r for r in records if r is not None]
        stats.makespan_s = now - arr[0].t
        stats.n_batches = admissions
        stats.n_chunks = n_chunks
        occ_m = (
            np.stack(occ_rows) if occ_rows else np.zeros((0, lanes), bool)
        )
        it_m = (
            np.stack(iter_rows) if iter_rows else np.zeros((0, lanes), np.int64)
        )
        stats.chunk_stats = chunked_straggler_report(
            it_m, occ_m, lanes=lanes, n_devices=self.server.n_devices
        )
        stats.lane_occupancy = stats.chunk_stats["lane_occupancy"]
        stats.compile_count = self.server.compile_count - compiles_before
        stats.compiled_buckets = self.server.compiled_buckets
        return stats
