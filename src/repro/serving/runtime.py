"""Arrival-driven serving runtime: queue → admission → fixed-lane dispatch.

The serving layer so far drained a request *list* synchronously; real
user-facing load is a timestamped arrival process.  This module adds the
missing runtime around :class:`~repro.serving.batched.BatchedFusedServer`
(DESIGN.md §Serving runtime):

* a FIFO **request queue** fed by timestamped arrivals (Poisson traces come
  from ``repro.data.synthetic.poisson_arrivals``);
* an **admission batcher** with the classic max-wait / max-size policy: a
  batch launches when ``max_batch`` requests are waiting OR the oldest
  request has waited ``max_wait_s`` (or the trace is drained) — the
  InferLine-style knob trading per-request queueing delay against batch
  efficiency;
* **fixed-lane dispatch**: every admission batch is padded to the server's
  ``batch_size`` lanes (inactive lanes predicated out on device), so the jit
  cache holds exactly ONE executable per power-of-two cap bucket regardless
  of batch fill — varying load never recompiles;
* per-request **queueing delay vs execution latency** records, the numbers a
  provisioning decision actually needs.

Time model: arrivals and queueing evolve on a *virtual* clock (so a trace
replays identically regardless of host speed), while each batch's service
time is the real measured wall-clock of ``serve_batch`` — the runtime is a
single-server queueing simulation whose service process is the actual
compiled executor.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.serving.batched import BatchedFusedServer, device_fill

__all__ = [
    "Arrival",
    "RequestRecord",
    "AdmissionBatcher",
    "RuntimeStats",
    "ServingRuntime",
]


@dataclass(frozen=True)
class Arrival:
    """A timestamped request: ``t`` seconds on the virtual arrival clock."""

    t: float
    request: dict


@dataclass(frozen=True)
class RequestRecord:
    """Per-request accounting emitted by the runtime."""

    req_id: int
    arrival_t: float
    admit_t: float          # when its admission batch started executing
    done_t: float
    queue_delay_s: float    # admit_t - arrival_t  (the batching cost)
    exec_s: float           # its batch's wall-clock service time
    latency_s: float        # done_t - arrival_t   (what the user sees)
    batch_id: int
    batch_fill: int         # active lanes in its batch
    y_hat: float
    prob: float
    iters: int
    sample_frac: float


class AdmissionBatcher:
    """max-wait / max-size admission policy (pure, for unit testing)."""

    # tolerance for "the wait expired": the runtime advances its clock to
    # ``t_oldest + max_wait_s`` and recomputes ``now - t_oldest``, which can
    # round to just under max_wait_s — without the epsilon that state admits
    # nothing and the virtual clock stops advancing (a livelock).
    _EPS = 1e-9

    def __init__(self, max_size: int, max_wait_s: float):
        if max_size < 1:
            raise ValueError("max_size must be >= 1")
        if max_wait_s < 0:
            raise ValueError("max_wait_s must be >= 0")
        self.max_size = max_size
        self.max_wait_s = max_wait_s

    def ready(self, queue_len: int, oldest_wait_s: float, more_coming: bool) -> bool:
        """Admit now?  Full batch, expired wait, or a drained trace."""
        if queue_len <= 0:
            return False
        return (
            queue_len >= self.max_size
            or oldest_wait_s >= self.max_wait_s - self._EPS
            or not more_coming
        )


@dataclass
class RuntimeStats:
    """Everything one load run produced; ``summary()`` is the §4-style table."""

    records: list[RequestRecord] = field(default_factory=list)
    makespan_s: float = 0.0     # first arrival -> last completion (virtual)
    busy_s: float = 0.0         # total wall time spent inside serve_batch
    n_batches: int = 0
    compile_count: int = 0      # executables built DURING the run (post-warmup)
    compiled_buckets: list[int] = field(default_factory=list)
    tau: float = 0.95           # the server's confidence target (for summary)
    n_devices: int = 1          # serving-mesh size the lanes were sharded over
    lanes: int = 0              # fixed lane count (0 = unknown/legacy)

    def _device_fill_stats(self) -> dict:
        """Per-device fill + lane imbalance, averaged over admission batches.

        Lanes partition contiguously over the 1-D serving mesh and fills are
        front-packed, so a batch's fill determines each device's active-lane
        count (``batched.device_fill``).  Reported only when the mesh has
        more than one device — a single-device run has nothing to split —
        and well-defined (zeros) on an empty record set OR when the lane
        count is unknown (``lanes == 0``: a hand-built stats object) — a
        guessed partition would fabricate balance numbers.
        """
        fills = {r.batch_id: r.batch_fill for r in self.records}
        if not fills or not self.lanes:
            return {
                "per_device_fill": [0.0] * self.n_devices,
                "mean_lane_imbalance": 0.0,
            }
        lanes = self.lanes
        per_dev = np.stack(
            [
                device_fill(f, lanes, self.n_devices) / (lanes // self.n_devices)
                for f in fills.values()
            ]
        )  # (batches, n_devices) fill fractions
        return {
            "per_device_fill": [float(x) for x in per_dev.mean(0)],
            "mean_lane_imbalance": float(
                (per_dev.max(1) - per_dev.min(1)).mean()
            ),
        }

    def summary(self) -> dict:
        n = len(self.records)
        device = (
            {"n_devices": self.n_devices, **self._device_fill_stats()}
            if self.n_devices > 1
            else {"n_devices": self.n_devices}
        )
        if n == 0:
            return {
                "n": 0,
                "throughput_rps": 0.0,
                "p50_latency_ms": float("nan"),
                "p99_latency_ms": float("nan"),
                "mean_latency_ms": float("nan"),
                "mean_queue_delay_ms": float("nan"),
                "p99_queue_delay_ms": float("nan"),
                "mean_exec_ms": float("nan"),
                "mean_batch_fill": 0.0,
                "n_batches": 0,
                "utilization": 0.0,
                "mean_sample_frac": float("nan"),
                "guarantee_rate": 0.0,
                "compile_count": int(self.compile_count),
                "compiled_buckets": list(self.compiled_buckets),
                **device,
            }
        lat = np.array([r.latency_s for r in self.records]) * 1e3
        qd = np.array([r.queue_delay_s for r in self.records]) * 1e3
        ex = np.array([r.exec_s for r in self.records]) * 1e3
        fill = np.array([r.batch_fill for r in self.records], np.float64)
        frac = np.array([r.sample_frac for r in self.records])
        prob = np.array([r.prob for r in self.records])
        span = max(self.makespan_s, 1e-12)
        return {
            "n": n,
            "throughput_rps": n / span,
            "p50_latency_ms": float(np.percentile(lat, 50)),
            "p99_latency_ms": float(np.percentile(lat, 99)),
            "mean_latency_ms": float(lat.mean()),
            "mean_queue_delay_ms": float(qd.mean()),
            "p99_queue_delay_ms": float(np.percentile(qd, 99)),
            "mean_exec_ms": float(ex.mean()),
            "mean_batch_fill": float(fill.mean()),
            "n_batches": int(self.n_batches),
            "utilization": float(self.busy_s / span),
            # the paper's §4 quality metrics, so the CLI table is comparable
            # across host / fused / fused-batched modes (a request also counts
            # as satisfied when it provably exhausted its groups)
            "mean_sample_frac": float(frac.mean()),
            "guarantee_rate": float(
                np.mean((prob >= self.tau) | (frac >= 0.999))
            ),
            "compile_count": int(self.compile_count),
            "compiled_buckets": list(self.compiled_buckets),
            **device,
        }


class ServingRuntime:
    """Single-server arrival loop over a :class:`BatchedFusedServer`."""

    def __init__(
        self,
        server: BatchedFusedServer,
        max_wait_s: float = 0.05,
        max_batch: int | None = None,
    ):
        self.server = server
        max_batch = max_batch if max_batch is not None else server.batch_size
        if max_batch > server.batch_size:
            raise ValueError(
                f"max_batch {max_batch} exceeds the server's fixed lane count "
                f"{server.batch_size}"
            )
        self.batcher = AdmissionBatcher(max_batch, max_wait_s)

    # ------------------------------------------------------------------
    def warmup(self, requests: list[dict] | None = None) -> list[int]:
        """Compile every cap bucket the request population can hit.

        A mixed batch's cap is ``bucket(max group)`` = the max of its
        members' single-request caps, so warming one full-lane batch per
        distinct per-request cap covers every batch composition.  Returns
        the warmed buckets.
        """
        reqs = requests if requests is not None else self.server.bundle.requests
        by_cap: dict[int, dict] = {}
        for req in reqs:
            by_cap.setdefault(self.server.batch_cap([req]), req)
        already = set(self.server.compiled_buckets)
        for cap in sorted(by_cap):
            if cap not in already:  # don't re-pay a warm bucket every run()
                self.server.serve_batch([by_cap[cap]])
        return sorted(by_cap)

    # ------------------------------------------------------------------
    def run(self, arrivals, warmup: bool = True) -> RuntimeStats:
        """Replay a timestamped arrival trace; returns per-request records.

        ``arrivals``: iterable of :class:`Arrival` or ``(t, request)`` pairs
        (seconds on the virtual clock; sorted internally).
        """
        arr = sorted(
            (
                a if isinstance(a, Arrival) else Arrival(float(a[0]), a[1])
                for a in arrivals
            ),
            key=lambda a: a.t,
        )
        if warmup:
            self.warmup([a.request for a in arr])
        compiles_before = self.server.compile_count

        stats = RuntimeStats(
            tau=self.server.config.tau,
            n_devices=self.server.n_devices,
            lanes=self.server.batch_size,
        )
        if not arr:
            stats.compiled_buckets = self.server.compiled_buckets
            return stats

        records: list[RequestRecord | None] = [None] * len(arr)
        queue: deque[int] = deque()
        now = arr[0].t
        i = 0
        batch_id = 0
        while i < len(arr) or queue:
            if not queue:
                now = max(now, arr[i].t)
            while i < len(arr) and arr[i].t <= now:
                queue.append(i)
                i += 1
            oldest_wait = now - arr[queue[0]].t
            if not self.batcher.ready(len(queue), oldest_wait, i < len(arr)):
                # idle until the next decision point: the oldest request's
                # max-wait deadline or the next arrival, whichever is first
                # (both are strictly > now, so the loop always progresses)
                now = min(arr[queue[0]].t + self.batcher.max_wait_s, arr[i].t)
                continue
            idxs = [
                queue.popleft()
                for _ in range(min(self.batcher.max_size, len(queue)))
            ]
            admit_t = now
            t0 = time.perf_counter()
            res = self.server.serve_batch([arr[j].request for j in idxs])
            dt = time.perf_counter() - t0
            now += dt
            stats.busy_s += dt
            for lane, j in enumerate(idxs):
                records[j] = RequestRecord(
                    req_id=j,
                    arrival_t=arr[j].t,
                    admit_t=admit_t,
                    done_t=now,
                    queue_delay_s=admit_t - arr[j].t,
                    exec_s=dt,
                    latency_s=now - arr[j].t,
                    batch_id=batch_id,
                    batch_fill=len(idxs),
                    y_hat=float(res.y_hat[lane]),
                    prob=float(res.prob[lane]),
                    iters=int(res.iters[lane]),
                    sample_frac=float(res.sample_frac[lane]),
                )
            batch_id += 1

        stats.records = [r for r in records if r is not None]
        stats.makespan_s = now - arr[0].t
        stats.n_batches = batch_id
        stats.compile_count = self.server.compile_count - compiles_before
        stats.compiled_buckets = self.server.compiled_buckets
        return stats
