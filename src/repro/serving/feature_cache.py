"""Hot-group precompute cache: (table, group, version)-keyed device residency.

The head of a real key distribution is where serving cost concentrates
(InferLine's provisioning argument; Willump's statistically-aware feature
caching is the exemplar fix — PAPERS.md), and in this stack the dominated
per-request cost at small caps is the incremental-AFC precompute + the H2D
transfer of the (k, cap) sample buffers.  :class:`FeatureCache` keeps both
device-resident per *(request spec row, group version)*:

* **key** — ``((table, column, gid), ...) + (cap,)`` identifies the request
  shape, and the tuple of per-spec **group versions** (bumped by every
  ``Table.append``) identifies freshness.  A version mismatch can never
  serve stale data: the entry is either delta-refreshed to the new version
  or rebuilt.
* **hit** — returns the cached ``(vals, n, PrebuiltTables)`` untouched:
  zero precompute, zero H2D, zero new executables (the prebuilt executor is
  already compiled for the bucket).
* **stale hit** — replays the store's bounded append log through the
  ``refresh`` delta executable (``build_afc_precompute``): the values
  buffer shifts, power-sum tables get two-sum row updates, the holistic
  index merges its sorted runs — no argsort, no full rescan.  Events that
  land at prefix position 0 (they replace the power-sum shift basis) or
  that have aged out of the log fall back to a cold rebuild.
* **miss** — gathers host buffers once and runs the ``cold`` precompute
  executable; the entry then lives in an LRU of ``maxsize`` groups.

The cache itself is host-side bookkeeping (a dict of device-array handles);
all numeric work happens in the two jitted executables its owner supplies,
so servers can route them through their compile-counting trace hooks and
the ``repro.analysis`` contracts can assert the hit path compiles nothing.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable

import jax.numpy as jnp
import numpy as np

from repro.core.executor_fused import PrebuiltTables
from repro.data.store import ColumnStore

__all__ = ["CacheEntry", "FeatureCache", "entry_checksum"]


@dataclass
class CacheEntry:
    """Device-resident precompute for one (spec row, cap) request shape."""

    vals: jnp.ndarray          # (k, cap) padded prefix buffers
    n: jnp.ndarray             # (k,) int32 group sizes clamped to cap
    tables: PrebuiltTables
    versions: tuple[int, ...]  # per-spec group versions the entry reflects
    #: Power-sum checksum of (vals, n) at build/refresh time — see
    #: :func:`entry_checksum`.  ``None`` marks a legacy entry built before
    #: integrity checking (always treated as valid).
    checksum: tuple[float, float, int] | None = None


def entry_checksum(vals, n) -> tuple[float, float, int]:
    """Order-invariant integrity fingerprint of an entry's numeric payload.

    f64 power sums (Σx, Σx²) over the values buffer plus the total group
    size: the same primitive the AFC estimators are built on, cheap to
    recompute, and sensitive to any single flipped element.  It is a
    corruption detector, not a cryptographic MAC — the threat model is bit
    rot / torn writes in device-resident state, not an adversary.
    """
    v = np.asarray(vals, np.float64)
    return (float(v.sum()), float((v * v).sum()), int(np.asarray(n).sum()))


class FeatureCache:
    """LRU of :class:`CacheEntry` keyed by ``(specs, cap)`` + group versions.

    ``cold(vals, n) -> PrebuiltTables`` and ``refresh(vals, n, tables, j, x,
    aff) -> (vals, n, tables)`` are the owner's (possibly compile-counted)
    jitted executables from ``build_afc_precompute``.  ``key_fn`` computes
    the freshness component from the store — it exists as an injection seam
    so the mutation test can build the classic broken cache (keyed without
    versions) and prove the checker catches the stale read.
    """

    def __init__(
        self,
        store: ColumnStore,
        cold: Callable[..., PrebuiltTables],
        refresh: Callable[..., Any] | None = None,
        *,
        maxsize: int = 64,
        key_fn: Callable[[ColumnStore, list, int], tuple] | None = None,
        verify_hits: bool = False,
    ) -> None:
        self.store = store
        self.cold = cold
        self.refresh = refresh
        self.maxsize = int(maxsize)
        self._key_fn = key_fn or (
            lambda store, specs, cap: store.spec_versions(specs)
        )
        # verify_hits trades the hit path's zero-cost property (the checksum
        # recompute is a D2H sync of the (k, cap) buffer) for detection of
        # corrupted device-resident state; serving keeps it off by default
        # and the fault-injection/recovery paths switch it on.
        self.verify_hits = bool(verify_hits)
        self._entries: OrderedDict[tuple, CacheEntry] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.refreshes = 0
        self.corruptions = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def stats(self) -> dict[str, int]:
        return dict(
            hits=self.hits, misses=self.misses, refreshes=self.refreshes,
            corruptions=self.corruptions, entries=len(self._entries),
        )

    @staticmethod
    def _intact(entry: CacheEntry) -> bool:
        if entry.checksum is None:
            return True
        return entry_checksum(entry.vals, entry.n) == entry.checksum

    def get(self, specs: list[tuple[str, str, int]], cap: int) -> CacheEntry:
        """The entry for this request, built/refreshed/fetched as needed."""
        specs = [tuple(s) for s in specs]
        base = (tuple(specs), int(cap))
        want = tuple(self._key_fn(self.store, specs, cap))
        entry = self._entries.get(base)
        if entry is not None and self.verify_hits and not self._intact(entry):
            # corrupted device-resident state: never serve it — drop the
            # entry and fall through to a cold rebuild.
            self.corruptions += 1
            del self._entries[base]
            entry = None
        if entry is not None:
            if entry.versions == want:
                self.hits += 1
                self._entries.move_to_end(base)
                return entry
            refreshed = self._try_refresh(entry, specs, cap, want)
            if refreshed is not None:
                self.refreshes += 1
                self._entries[base] = refreshed
                self._entries.move_to_end(base)
                return refreshed
        self.misses += 1
        vals, sizes = self.store.request_buffers(specs, cap)
        entry = CacheEntry(
            vals=vals, n=sizes, tables=self.cold(vals, sizes), versions=want,
            checksum=entry_checksum(vals, sizes),
        )
        self._entries[base] = entry
        self._entries.move_to_end(base)
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
        return entry

    def revalidate(self) -> int:
        """Drop entries that are stale or corrupted; returns the count.

        The store-recovery hook (``Table.recover``): after an index rebuild
        every resident entry is re-checked against the CURRENT store
        versions and its own power-sum checksum, so device state that no
        longer reflects the recovered table is evicted instead of served.
        """
        dead = []
        for base, entry in self._entries.items():
            specs, cap = list(base[0]), base[1]
            want = tuple(self._key_fn(self.store, specs, cap))
            if entry.versions != want or not self._intact(entry):
                dead.append(base)
        for base in dead:
            if not self._intact(self._entries[base]):
                self.corruptions += 1
            del self._entries[base]
        return len(dead)

    def _try_refresh(
        self,
        entry: CacheEntry,
        specs: list[tuple[str, str, int]],
        cap: int,
        want: tuple,
    ) -> CacheEntry | None:
        """Delta-update a stale entry from the append logs, or None."""
        if self.refresh is None:
            return None
        # one event stream per distinct (table, gid) the specs reference
        groups: dict[tuple[str, int], list[tuple[int, int]]] = {}
        for si, (t, _c, g) in enumerate(specs):
            gk = (t, g)
            if gk in groups:
                continue
            base_version = entry.versions[si]
            events = self.store[t].events_since(g, base_version)
            if events is None or any(j == 0 for (j, _r) in events):
                return None  # log aged out / shift-basis change: rebuild
            groups[gk] = events
        vals, n, tables = entry.vals, entry.n, entry.tables
        for (t, g), events in groups.items():
            table = self.store[t]
            aff = np.array(
                [(st == t and sg == g) for (st, _sc, sg) in specs], bool
            )
            for (j, row_id) in events:
                x = np.array(
                    [
                        float(table.columns[sc][row_id]) if aff[si] else 0.0
                        for si, (_st, sc, _sg) in enumerate(specs)
                    ],
                    np.float32,
                )
                vals, n, tables = self.refresh(
                    vals, n, tables, jnp.asarray(j, jnp.int32),
                    jnp.asarray(x), jnp.asarray(aff),
                )
        return CacheEntry(
            vals=vals, n=n, tables=tables, versions=want,
            checksum=entry_checksum(vals, n),
        )
