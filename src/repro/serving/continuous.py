"""Continuous batching: a lane table served by the chunked fused executor.

The fixed-lane server (serving/batched.py) holds every lane of an admission
batch hostage until the SLOWEST request's while-loop exits — the straggler
waste ``straggler_report`` measures.  Continuous batching applies the
LLM-serving idea to planner loops: the executor runs ``chunk_iters``
planner iterations per dispatch over a persistent **lane table** (a
:class:`~repro.core.executor_fused.LaneState` pytree batched over lanes),
and a lane whose request converges is refilled from the admission queue at
the next chunk boundary — capacity approaches the per-device block-sum
bound instead of lanes·max(iters).

Two executables per power-of-two cap bucket, REGARDLESS of fill, chunk
count, or refill pattern (the compile contract ``compile_count`` /
``compiled_buckets`` make testable):

* **refill** — a SINGLE-LANE chunked-executor ``init`` scattered into the
  donated table at a traced lane index (``dynamic_update_slice`` per
  leaf): admitting a request costs exactly one lane's init — the AFC
  precompute, z⁰ evaluation and (k, cap) transfer for THAT request only —
  and admitting any lane reuses the one executable, because the index is
  data.  (A full-width masked-init refill was measured 8-20x more
  expensive per admission: every event re-ran the precompute for all
  lanes and shipped the whole (lanes, k, cap) buffer.)  Shapes depend
  only on (k, cap).
* **chunk** — the vmapped ``chunk`` advancing every lane at most
  ``chunk_iters`` iterations; done/inactive lanes cost zero loop trips.
  Shapes depend only on (cap, lanes, chunk_iters).

A ``mesh`` (1-D ``("lanes",)``, ``launch.mesh.make_serving_mesh``) shards
the table data-parallel via ``shard_map`` exactly like the fixed-lane
path: every LaneState leaf partitions on its leading lanes dimension and
the compiled programs stay **collective-free**.  The refill scatter
receives the fresh lane replicated and the global lane index as data;
each device translates it to a local row and only the owner writes its
shard — per-device lane recycling with no cross-device traffic.

The scheduler that drives this (arrival queue -> free-lane admission at
chunk boundaries -> chunk-granularity accounting) is
``serving/runtime.ContinuousServingRuntime``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import assert_compile_contract
from repro.core.executor_fused import (
    CHUNK_CARRY_LEAVES,
    build_afc_precompute,
    build_chunked_executor,
    pipeline_executor_kwargs,
    shard_lanes_state_executor,
)
from repro.core.pipeline import make_fused_model_fn
from repro.data.store import bucket_size
from repro.serving.batched import (
    lane_request_inputs,
    sanitize_lane_inputs,
    validate_serving_mesh,
)
from repro.serving.feature_cache import FeatureCache

__all__ = ["ContinuousBatchedServer"]


class ContinuousBatchedServer:
    """Lane-table server over the chunked fused executor.

    ``batch_size`` is the lane count of the persistent table,
    ``chunk_iters`` the planner iterations per chunk dispatch — the
    continuous-batching knob trading scheduling granularity (how quickly a
    freed lane is refilled) against per-dispatch overhead.  ``max_cap``,
    ``mesh``, ``afc_backend`` and ``cache_size`` mean exactly what they
    mean on :class:`~repro.serving.batched.BatchedFusedServer`: with a
    cache, every admission feeds a version-keyed LRU entry's
    ``(vals, n, PrebuiltTables)`` into a ``prebuilt=True`` refill — the
    single-lane init skips its AFC precompute — at the price of one extra
    executable per bucket (the cold precompute; ``cache_size`` and
    ``mesh`` are mutually exclusive).

    The server is deliberately schedule-free: it owns the compiled
    executables and the buffer assembly, while the caller owns the table
    and the lane bookkeeping — ``new_table`` -> (``admit`` | ``run_chunk``)*
    -> ``readback``.  One table serves one cap bucket (the trace-wide max);
    per-request degradation knobs are traced refill inputs, so tier changes
    never compile (the PR-6 contract survives recycling).
    """

    def __init__(self, bundle, config, batch_size: int = 8,
                 chunk_iters: int = 4, max_cap: int | None = None,
                 mesh=None, afc_backend: str = "auto",
                 cache_size: int | None = None, sanitize: str = "reject"):
        self.bundle = bundle
        self.config = config
        self.batch_size = batch_size
        self.chunk_iters = int(chunk_iters)
        self.mesh = mesh
        if sanitize not in ("reject", "clamp"):
            raise ValueError(
                f"sanitize must be 'reject' or 'clamp', got {sanitize!r}"
            )
        self.sanitize = sanitize
        self.n_devices = validate_serving_mesh(mesh, batch_size)
        if cache_size is not None and mesh is not None:
            raise ValueError(
                "cache_size and mesh are mutually exclusive: cached "
                "admissions feed host-tracked cache entries into the refill "
                "scatter, sharded tables partition device-resident buffers"
            )
        self._cache_size = cache_size
        self.cache: FeatureCache | None = None
        cached = cache_size is not None
        #: registered contracts governing this server's compiled executables
        #: (repro.analysis.contracts; declared in core/executor_fused.py) —
        #: the refill + chunk pair sums to the 2-per-bucket compile budget;
        #: the cache-fed table adds the cold precompute for 3 per bucket
        self.contract = (
            ("refill", "chunk", "afc_precompute")
            if cached
            else ("refill", "chunk")
        )
        p = bundle.pipeline
        feat_kwargs = pipeline_executor_kwargs(p.agg_features)
        self._agg_ids = feat_kwargs.pop("agg_ids")
        self._init_fn, chunk_fn = build_chunked_executor(
            make_fused_model_fn(p), chunk_iters=self.chunk_iters,
            k=p.k, task=p.task, n_classes=max(p.n_classes, 2),
            m=config.m, m_sobol=config.m_sobol, alpha=config.alpha,
            gamma=config.gamma, tau=config.tau, max_iters=config.max_iters,
            n_boot=config.n_bootstrap, afc_backend=afc_backend,
            prebuilt=cached, **feat_kwargs,
        )

        # trace hooks: fire once per jit cache miss (= per compiled
        # executable), exactly like BatchedFusedServer._counted — they sit
        # INSIDE the vmap/shard_map wrappers so the sharded path counts too
        self._refill_compiles = 0
        self._chunk_compiles = 0
        self._cold_compiles = 0

        if cached:
            pre = build_afc_precompute(
                k=p.k, alpha=config.alpha, gamma=config.gamma,
                max_iters=config.max_iters,
                holistic=feat_kwargs["holistic"],
                quantiles=feat_kwargs["quantiles"],
                approximate=feat_kwargs["approximate"],
            )
            self._pre_cold = pre.cold
            inner_cold = pre.cold

            def _counted_cold(vals, n):
                self._cold_compiles += 1
                return inner_cold(vals, n)

            self.cache = FeatureCache(
                bundle.store, jax.jit(_counted_cold), pre.refresh,
                maxsize=cache_size,
            )

            def _counted_init(vals, n, agg_ids, delta, exact, active, tau,
                              cap, tables):
                self._refill_compiles += 1
                return self._init_fn(vals, n, agg_ids, delta, exact, active,
                                     tau, cap, tables)
        else:

            def _counted_init(vals, n, agg_ids, delta, exact, active, tau,
                              cap):
                self._refill_compiles += 1
                return self._init_fn(vals, n, agg_ids, delta, exact, active,
                                     tau, cap)

        def _counted_chunk(state):
            self._chunk_compiles += 1
            return chunk_fn(state)

        def _write_lane(table, fresh, row):
            # one lane's slice of the donated table rewritten in place;
            # every other row aliases through untouched
            return jax.tree_util.tree_map(
                lambda old, new: jax.lax.dynamic_update_index_in_dim(
                    old, new.astype(old.dtype), row, 0
                ),
                table, fresh,
            )

        if mesh is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec

            spec = PartitionSpec("lanes")
            rows_per_dev = batch_size // self.n_devices

            def _refill_shard(table, vals, n, agg_ids, delta, exact, tau,
                              cap, lane):
                # inside shard_map: `table` is this device's row block, the
                # fresh-lane inputs are replicated.  Every device runs the
                # (cheap, single-lane) init; only the owner of the global
                # lane index writes its shard — no collectives.
                fresh = _counted_init(vals, n, agg_ids, delta, exact,
                                      jnp.asarray(True), tau, cap)
                local = lane - jax.lax.axis_index("lanes") * rows_per_dev
                mine = (local >= 0) & (local < rows_per_dev)
                row = jnp.clip(local, 0, rows_per_dev - 1)
                keep = jax.tree_util.tree_map(
                    lambda old: jax.lax.dynamic_index_in_dim(
                        old, row, 0, keepdims=False
                    ),
                    table,
                )
                safe = jax.tree_util.tree_map(
                    lambda new, old: jnp.where(mine, new.astype(old.dtype),
                                               old),
                    fresh, keep,
                )
                return _write_lane(table, safe, row)

            refill_fn = shard_map(
                _refill_shard, mesh=mesh,
                in_specs=(spec,) + (PartitionSpec(),) * 8,
                out_specs=spec, check_rep=False,
            )
            self._chunk = shard_lanes_state_executor(_counted_chunk, mesh)
        elif cached:

            def refill_fn(table, vals, n, agg_ids, delta, exact, tau, cap,
                          lane, tables):
                fresh = _counted_init(vals, n, agg_ids, delta, exact,
                                      jnp.asarray(True), tau, cap, tables)
                return _write_lane(table, fresh, lane)

            self._chunk = jax.jit(jax.vmap(_counted_chunk),
                                  donate_argnums=(0,))
        else:

            def refill_fn(table, vals, n, agg_ids, delta, exact, tau, cap,
                          lane):
                fresh = _counted_init(vals, n, agg_ids, delta, exact,
                                      jnp.asarray(True), tau, cap)
                return _write_lane(table, fresh, lane)

            self._chunk = jax.jit(jax.vmap(_counted_chunk),
                                  donate_argnums=(0,))

        self._refill = jax.jit(refill_fn, donate_argnums=(0,))
        self._caps_seen: set[int] = set()
        max_n = max(
            bundle.store[f.table].group_size(g)
            for f in p.agg_features
            for g in bundle.store[f.table].group_ids
        )
        self._max_cap = bucket_size(max_n)
        if max_cap is not None:
            self._max_cap = min(self._max_cap, bucket_size(max_cap))

    # ------------------------------------------------------------------
    @property
    def compiled_buckets(self) -> list[int]:
        """Cap buckets served so far (≤ log2(max_cap) entries ever)."""
        return sorted(self._caps_seen)

    @property
    def compile_count(self) -> int:
        """Executables built so far, per cap bucket.

        Must equal ``2 * len(compiled_buckets)`` (refill + chunk) — or 3
        with the feature cache enabled (+ the cold AFC precompute) — the
        continuous compile contract (``refill_compiles`` /
        ``chunk_compiles`` / ``cold_compiles`` split it).
        """
        return self._refill_compiles + self._chunk_compiles + self._cold_compiles

    @property
    def cold_compiles(self) -> int:
        return self._cold_compiles

    @property
    def refill_compiles(self) -> int:
        return self._refill_compiles

    @property
    def chunk_compiles(self) -> int:
        return self._chunk_compiles

    def check_compile_contract(self, *, buckets=None) -> None:
        """Assert observed compiles match the registered ``refill`` +
        ``chunk`` contracts (two executables per cap bucket, total)."""
        assert_compile_contract(self, self.contract, buckets=buckets)

    def request_cap(self, req: dict) -> int:
        """Power-of-two bucket over THIS request's largest group."""
        p = self.bundle.pipeline
        max_n = int(p.group_sizes(self.bundle.store, req).max())
        return min(bucket_size(max_n), self._max_cap)

    def trace_cap(self, requests) -> int:
        """The shared table cap for a trace: max over its requests."""
        return max(self.request_cap(r) for r in requests)

    # ------------------------------------------------------------------
    def new_table(self, cap: int):
        """An all-pad lane table at a cap bucket (device-resident zeros).

        Leaf shapes come from ``jax.eval_shape`` on the init function — no
        compile, no transfer of real data.  Zero leaves are a valid empty
        table: ``active=False`` forces every lane's loop predicate false,
        so a chunk over pad lanes runs zero trips (``done`` is only read
        for occupied lanes; the scheduler owns occupancy).
        """
        p = self.bundle.pipeline
        k, e = p.k, len(p.exact_features)
        dummy = (
            jax.ShapeDtypeStruct((k, cap), np.float32),   # vals
            jax.ShapeDtypeStruct((k,), np.int32),          # n
            jax.ShapeDtypeStruct((k,), np.int32),          # agg_ids
            jax.ShapeDtypeStruct((), np.float32),          # delta
            jax.ShapeDtypeStruct((e,), np.float32),        # exact
            jax.ShapeDtypeStruct((), bool),                # active
            jax.ShapeDtypeStruct((), np.float32),          # tau
            jax.ShapeDtypeStruct((), np.int32),            # iter_cap
        )
        if self.cache is not None:
            # the prebuilt init also takes a PrebuiltTables — its shapes come
            # from eval_shape on the cold precompute (no compile either)
            tables = jax.eval_shape(self._pre_cold, dummy[0], dummy[1])
            lane = jax.eval_shape(self._init_fn, *dummy, tables)
        else:
            lane = jax.eval_shape(self._init_fn, *dummy)
        lanes = self.batch_size
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros((lanes,) + s.shape, s.dtype), lane
        )

    # ------------------------------------------------------------------
    def admit(self, table, cap: int, assignments):
        """Refill lanes with fresh requests: one single-lane dispatch each.

        ``assignments`` is a list of ``(lane, request, knobs_or_None)``;
        each named lane's ENTIRE LaneState slice is overwritten with the
        freshly initialized request (buffers, prefix tables, z⁰ carry,
        knobs), other lanes pass through untouched (the donated table
        aliases them in place).  An admission costs exactly the admitted
        request's own init — never a full-table re-init — and the lane
        index is traced data, so every dispatch reuses the bucket's one
        refill executable.  Returns ``(table, true_rows)`` where
        ``true_rows`` maps lane -> the request's TRUE total group rows (the
        honest ``sample_frac`` denominator the paper's §4 uses — cap
        clipping only shrinks the numerator).
        """
        p = self.bundle.pipeline
        store = self.bundle.store
        cfg = self.config
        delta_default = (
            cfg.delta if cfg.delta is not None else p.delta_default
        )
        lanes = self.batch_size
        seen: set[int] = set()
        true_rows: dict[int, int] = {}
        for lane, req, kn in assignments:
            if not 0 <= lane < lanes:
                raise ValueError(f"lane {lane} outside 0..{lanes - 1}")
            if lane in seen:
                raise ValueError(f"lane {lane} assigned twice in one admit")
            if self.request_cap(req) > cap:
                raise ValueError(
                    f"request needs cap {self.request_cap(req)} > table "
                    f"cap {cap}; size the table with trace_cap"
                )
            seen.add(lane)
        self._caps_seen.add(cap)
        for lane, req, kn in assignments:
            if self.cache is not None:
                # cached admission: vals/n/tables come device-resident from
                # the LRU; the refill scatter copies them into the lane row,
                # so the entry itself is never aliased by the donated table
                entry = self.cache.get(p.agg_specs(req), cap)
                vals, n = entry.vals, entry.n
                true_n = np.asarray(p.group_sizes(store, req), np.int64)
                exact = np.asarray(
                    p.exact_feature_values(store, req), np.float32
                )
                # cached vals are device-resident — checking them here would
                # cost a D2H sync per admission and defeat the zero-H2D hit
                # path; they are protected by append-time sanitization plus
                # the cache's power-sum integrity check instead.
                exact = sanitize_lane_inputs(
                    None, exact, policy=self.sanitize,
                    where=f"admit lane {lane}",
                )[1]
            else:
                vals, n, true_n, exact = lane_request_inputs(
                    p, store, req, cap
                )
                vals, exact = sanitize_lane_inputs(
                    vals, exact, policy=self.sanitize,
                    where=f"admit lane {lane}",
                )
            true_rows[lane] = int(true_n.sum())
            delta = delta_default if kn is None else kn.delta
            tau = cfg.tau if kn is None else kn.tau
            iter_cap = (
                cfg.max_iters if kn is None
                else min(int(kn.iter_cap), cfg.max_iters)
            )
            refill_args = (
                table,
                jnp.asarray(vals),
                jnp.asarray(n),
                self._agg_ids,
                jnp.asarray(delta, jnp.float32),
                jnp.asarray(exact),
                jnp.asarray(tau, jnp.float32),
                jnp.asarray(iter_cap, jnp.int32),
                jnp.asarray(lane, jnp.int32),
            )
            if self.cache is not None:
                table = self._refill(*refill_args, entry.tables)
            else:
                table = self._refill(*refill_args)
        return table, true_rows

    def run_chunk(self, table):
        """Advance every lane at most ``chunk_iters`` planner iterations."""
        return self._chunk(table)

    # ------------------------------------------------------------------
    @staticmethod
    def readback(table) -> dict:
        """Host copies of the small per-lane leaves the scheduler reads.

        Never touches ``vals``/``ptab``/``rindex`` — the big buffers stay
        device-resident across the whole table lifetime.
        """
        return dict(
            done=np.asarray(table.done),
            active=np.asarray(table.active),
            it=np.asarray(table.it, np.int64),
            z=np.asarray(table.z),
            n=np.asarray(table.n),
            y_hat=np.asarray(table.y_hat),
            prob=np.asarray(table.prob),
        )

    # --- chunk-boundary checkpoint / rollback --------------------------
    @staticmethod
    def snapshot(table) -> dict[str, np.ndarray]:
        """Checkpoint of the chunk-mutable carry: host copies of exactly
        the :data:`~repro.core.executor_fused.CHUNK_CARRY_LEAVES`.

        Every other LaneState leaf is content-invariant across a chunk
        dispatch (the big buffers are donated/aliased through with values
        unchanged), so this is the WHOLE state a rollback needs — a few KB
        per lane, no executables, no device work beyond the D2H copy.
        """
        return {
            name: np.asarray(getattr(table, name))
            for name in CHUNK_CARRY_LEAVES
        }

    @staticmethod
    def restore(table, ckpt: dict[str, np.ndarray]):
        """Roll the carry back to a :meth:`snapshot` — zero executables.

        Each checkpointed leaf is re-uploaded with ``device_put`` onto its
        current sharding (so sharded tables restore shard-local) and swapped
        into the pytree with ``_replace``; the untouched big buffers keep
        their device residency.  Replaying the chunk after a restore is
        bitwise-identical to a fault-free run because the bootstrap RNG is
        counter-based on the restored ``it``.
        """
        return table._replace(**{
            name: jax.device_put(val, getattr(table, name).sharding)
            for name, val in ckpt.items()
        })

    @staticmethod
    def clear_lanes(table, lanes):
        """Host-side eviction of specific lanes (quarantine / failure).

        Flips ``active=False`` / ``done=True`` for the named lanes so the
        chunk predicate never runs them again — the same all-pad posture
        ``new_table`` starts from.  The lane's other leaves keep their
        (possibly poisoned) values; they are unreadable until the next
        ``admit`` overwrites the whole slice.  Pure host swap + device_put:
        no executables.
        """
        active = np.asarray(table.active).copy()
        done = np.asarray(table.done).copy()
        for lane in lanes:
            active[lane] = False
            done[lane] = True
        return table._replace(
            active=jax.device_put(active, table.active.sharding),
            done=jax.device_put(done, table.done.sharding),
        )
