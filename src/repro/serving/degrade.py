"""SLO-aware graceful degradation: knob tiers, deadline controller, shedding.

Biathlon's premise is that accuracy is a *spendable* resource — Eq. 1 prices
it with (delta, tau) and the planner spends samples until the guarantee
holds.  Under overload the serving runtime previously spent none of it:
queue delay absorbed every burst while the knobs stayed pinned
(`BENCH_serving.json["serving_load"]`).  This module supplies the missing
policy layer (Loki-style joint accuracy/capacity scaling; InferLine's SLO
vocabulary):

* a **knob-tier ladder** (:class:`KnobTier`): an ordered
  strictest → loosest sequence of (delta_scale, tau, iter_cap) settings.
  Looser tiers admit a wider error bound, a lower confidence target, and a
  smaller planner-iteration ceiling — all three are *traced* inputs of the
  fused executor (`executor_fused.build_fused_executor`), so moving between
  tiers never compiles a new executable;
* a :class:`DegradationController` mapping each request's **remaining SLO
  budget** (slack) and the current **queue depth** to a tier, with two
  deterministic pure decision functions (`tier_for`, `should_shed`) over
  explicit controller state (an EWMA service-time estimate and a
  hysteretic load tier).  Monotonicity contract: *tighter slack or a deeper
  queue never yields a stricter (slower) tier* — pinned by property tests;
* **load shedding**: when even the loosest tier cannot meet a request's
  deadline (`slack < floor_speedup · service_est`), or the queue exceeds
  its bound, the request is rejected at admission with a ``shed``
  disposition instead of queueing unboundedly;
* **hysteresis**: the load tier ratchets up immediately when the queue
  crosses its high watermark but steps back down only after ``cooldown``
  consecutive calm observations — degradation is fast, recovery is damped,
  so the system does not oscillate at the boundary.

The runtime integration (deadline threading, shed records, retry/backoff)
lives in `serving/runtime.py`; the fault harness that makes the behavior
testable lives in `serving/faults.py`.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass

import numpy as np

__all__ = [
    "KnobTier",
    "LaneKnobs",
    "DegradationController",
    "default_tiers",
    "validate_tiers",
]


@dataclass(frozen=True)
class KnobTier:
    """One rung of the degradation ladder (strictest tier = index 0).

    ``delta_scale`` multiplies the pipeline's baseline error bound,
    ``tau`` is the absolute Eq. 1 confidence target, ``iter_cap`` the
    planner-iteration ceiling (clamped to the executor's static
    ``max_iters``).  All three are data to the compiled executor.
    """

    name: str
    delta_scale: float
    tau: float
    iter_cap: int


@dataclass(frozen=True)
class LaneKnobs:
    """Resolved per-lane knob vector handed to ``serve_batch``.

    Values are pinned to strong numpy dtypes at construction: a raw
    Python scalar handed to a jitted call traces as a weak-typed aval,
    and a weak-typed knob re-traces the executable whenever a caller's
    promotion context changes — silently breaking the
    one-executable-per-cap-bucket contract the static checker enforces
    (``repro.analysis``, contract field ``weak_type_inputs``).
    """

    delta: float
    tau: float
    iter_cap: int
    tier: int = 0

    def __post_init__(self) -> None:
        object.__setattr__(self, "delta", np.float32(self.delta))
        object.__setattr__(self, "tau", np.float32(self.tau))
        object.__setattr__(self, "iter_cap", np.int32(self.iter_cap))


def default_tiers(tau: float, max_iters: int) -> tuple[KnobTier, ...]:
    """The stock 4-rung ladder around a pipeline's (tau, max_iters).

    Scales are chosen so each rung roughly halves the expected planner
    iteration budget: a wider delta satisfies Eq. 1 at a smaller plan, a
    lower tau accepts the guarantee earlier, and the iter_cap hard-bounds
    the while_loop for requests whose groups resist both.
    """
    return (
        KnobTier("baseline", 1.0, tau, max_iters),
        KnobTier("relaxed", 1.5, max(tau - 0.03, 0.5), max(max_iters // 2, 1)),
        KnobTier("degraded", 2.5, max(tau - 0.07, 0.5), max(max_iters // 4, 1)),
        KnobTier("floor", 4.0, max(tau - 0.15, 0.5), 1),
    )


def validate_tiers(tiers) -> tuple[KnobTier, ...]:
    """Tiers must run strictest → loosest; returns them as a tuple.

    Monotonicity here is what makes the controller's monotonicity
    meaningful: non-decreasing delta_scale, non-increasing tau,
    non-increasing iter_cap.  Rejects empty ladders and out-of-range taus.
    """
    tiers = tuple(tiers)
    if not tiers:
        raise ValueError("degradation ladder needs at least one tier")
    for t in tiers:
        if not (0.0 < t.tau <= 1.0):
            raise ValueError(f"tier {t.name!r}: tau {t.tau} outside (0, 1]")
        if t.delta_scale < 1.0:
            raise ValueError(
                f"tier {t.name!r}: delta_scale {t.delta_scale} < 1 would be "
                "stricter than baseline"
            )
        if t.iter_cap < 0:
            raise ValueError(f"tier {t.name!r}: iter_cap {t.iter_cap} < 0")
    for a, b in zip(tiers, tiers[1:]):
        if b.delta_scale < a.delta_scale or b.tau > a.tau or b.iter_cap > a.iter_cap:
            raise ValueError(
                f"tiers must run strictest->loosest: {a.name!r} -> {b.name!r} "
                "tightens a knob"
            )
    return tiers


class DegradationController:
    """Maps (remaining SLO budget, queue depth) → knob tier; sheds the rest.

    Decision state is explicit and small: an EWMA **service-time estimate**
    (seconds per admission batch, whatever tier is currently running) and a
    hysteretic **load tier**.  Both decision functions are *pure* in
    (args, state) — identical (queue state, deadline, capacity estimate)
    always produce identical decisions, which is what makes shedding
    auditable and the property tests meaningful.

    ``tier_for`` computes a dimensionless *pressure* — expected completion
    wait over remaining slack, ``(queue_depth/lanes + 1) · est / slack`` —
    and bisects it into ``pressure_thresholds`` (one fewer than the tier
    count, increasing); the result is floored by the load tier, so a
    deadline-rich request still degrades when the queue says the system is
    drowning.  ``should_shed`` rejects a request whose slack is below what
    even the loosest tier could deliver (``floor_speedup · est``; looser
    tiers run faster, so the floor is a fraction of the current estimate)
    or that would grow the queue past ``max_queue``.
    """

    def __init__(
        self,
        tiers,
        *,
        service_est_s: float,
        lanes: int = 8,
        pressure_thresholds: tuple[float, ...] | None = None,
        floor_speedup: float = 0.5,
        max_queue: int | None = None,
        queue_high: float = 2.0,
        queue_low: float = 0.5,
        cooldown: int = 3,
        ewma_alpha: float = 0.5,
    ):
        self.tiers = validate_tiers(tiers)
        if service_est_s <= 0:
            raise ValueError("service_est_s must be > 0")
        if lanes < 1:
            raise ValueError("lanes must be >= 1")
        if pressure_thresholds is None:
            # geometric defaults: tier i engages when the expected wait
            # crosses 2^(i-1) x half the remaining budget
            pressure_thresholds = tuple(
                0.5 * 2.0**i for i in range(len(self.tiers) - 1)
            )
        thresholds = tuple(float(x) for x in pressure_thresholds)
        if len(thresholds) != len(self.tiers) - 1:
            raise ValueError(
                f"need {len(self.tiers) - 1} pressure thresholds for "
                f"{len(self.tiers)} tiers, got {len(thresholds)}"
            )
        if any(b <= a for a, b in zip(thresholds, thresholds[1:])):
            raise ValueError("pressure_thresholds must be strictly increasing")
        if not (0.0 < floor_speedup <= 1.0):
            raise ValueError("floor_speedup must be in (0, 1]")
        if not (0.0 < ewma_alpha <= 1.0):
            raise ValueError("ewma_alpha must be in (0, 1]")
        if queue_low > queue_high:
            raise ValueError("queue_low watermark above queue_high")
        self.lanes = int(lanes)
        self._thresholds = thresholds
        self.floor_speedup = float(floor_speedup)
        self.max_queue = max_queue
        self.queue_high = float(queue_high)
        self.queue_low = float(queue_low)
        self.cooldown = int(cooldown)
        self.ewma_alpha = float(ewma_alpha)
        self._service_est_s = float(service_est_s)
        self._load_tier = 0
        self._calm = 0

    # ---------------------------------------------------------------- state
    @property
    def service_est_s(self) -> float:
        """Current EWMA estimate of one admission batch's service time."""
        return self._service_est_s

    @property
    def load_tier(self) -> int:
        """Hysteretic queue-driven tier floor (0 = baseline)."""
        return self._load_tier

    @property
    def min_service_s(self) -> float:
        """Estimated service time of the LOOSEST tier — the shed floor."""
        return self.floor_speedup * self._service_est_s

    # -------------------------------------------------- pure decision fns
    def pressure(self, slack_s: float, queue_depth: int) -> float:
        """Expected completion wait over remaining budget (dimensionless)."""
        wait = (queue_depth / self.lanes + 1.0) * self._service_est_s
        return wait / max(slack_s, 1e-9)

    def tier_for(self, slack_s: float | None, queue_depth: int) -> int:
        """Deterministic tier choice; monotone in both arguments.

        Less slack or a deeper queue can only move the answer toward looser
        tiers.  ``slack_s=None`` (no deadline) contributes no deadline
        pressure — the request still inherits the hysteretic load tier.
        """
        deadline_tier = 0
        if slack_s is not None:
            deadline_tier = bisect.bisect_right(
                self._thresholds, self.pressure(slack_s, queue_depth)
            )
        return max(deadline_tier, self._load_tier)

    def should_shed(self, slack_s: float | None, queue_depth: int) -> bool:
        """Reject now rather than queue unboundedly?  Deterministic.

        True when even the loosest tier's estimated service time exceeds
        the remaining budget, or the queue is past its hard bound.
        Monotone: shedding at some slack implies shedding at any smaller
        slack (same queue depth and state).
        """
        if self.max_queue is not None and queue_depth > self.max_queue:
            return True
        if slack_s is None:
            return False
        return slack_s < self.min_service_s

    # ------------------------------------------------------- state updates
    def observe(self, service_s: float, queue_depth: int) -> None:
        """Post-batch bookkeeping: EWMA the estimate, step the load tier.

        The load tier ratchets UP immediately whenever the queue is at or
        above ``queue_high`` full batches, but steps DOWN one rung only
        after ``cooldown`` consecutive observations at or below
        ``queue_low`` — tighten-back is hysteretic so a borderline queue
        does not flap between tiers.
        """
        a = self.ewma_alpha
        self._service_est_s = (1.0 - a) * self._service_est_s + a * float(service_s)
        if queue_depth >= self.queue_high * self.lanes:
            self._load_tier = min(self._load_tier + 1, len(self.tiers) - 1)
            self._calm = 0
        elif queue_depth <= self.queue_low * self.lanes:
            self._calm += 1
            if self._calm >= self.cooldown and self._load_tier > 0:
                self._load_tier -= 1
                self._calm = 0
        else:
            self._calm = 0

    # ------------------------------------------------------------- resolve
    def knobs_for(self, tier: int, base_delta: float) -> LaneKnobs:
        """Resolve a tier index into the absolute per-lane knob vector."""
        t = self.tiers[min(max(tier, 0), len(self.tiers) - 1)]
        return LaneKnobs(
            delta=float(base_delta) * t.delta_scale,
            tau=t.tau,
            iter_cap=t.iter_cap,
            tier=min(max(tier, 0), len(self.tiers) - 1),
        )

    def retier(
        self,
        slack_s: float | None,
        queue_depth: int,
        base_delta: float,
    ) -> LaneKnobs:
        """``tier_for`` + ``knobs_for`` in one call — the retry-path seam.

        Both runtimes re-price a request's knobs from its CURRENT slack
        whenever that slack changes (admission, and again after every
        retry backoff), so budget burned on retries degrades the request
        coherently instead of serving it late at full accuracy.
        """
        return self.knobs_for(self.tier_for(slack_s, queue_depth), base_delta)
