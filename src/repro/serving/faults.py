"""Fault-injection harness for the serving runtime: spikes, failures, bursts.

Degradation and recovery behavior must be *testable*, not assumed, so this
module wraps ``serve_batch`` in a deterministic, seeded fault layer
(DESIGN.md § Graceful degradation & fault injection):

* **service-time spikes** — a seeded subset of ``serve_batch`` calls sleeps
  an extra ``spike_s`` before dispatching; the runtime measures wall-clock,
  so a spike propagates exactly like a real slow batch (queue builds on the
  virtual clock, the controller's EWMA estimate rises, tiers loosen);
* **transient executor exceptions** — a seeded subset of calls raises
  :class:`TransientExecutorError` *instead of* serving; the runtime retries
  with bounded exponential backoff (serving/runtime.py) and marks the batch
  ``failed`` only when retries are exhausted;
* **arrival bursts** — :func:`inject_burst` splices a compressed clump of
  extra arrivals into a trace, the overload pattern shedding exists for.

The schedule is a pure function of ``(FaultProfile, call index)`` —
counter-based RNG per call, no global state — so two runs over the same
trace inject identical faults and every test is reproducible.  The wrapper
delegates everything else (``batch_size``, ``config``, ``compile_count``,
``batch_cap``...) to the inner server, so :class:`FaultyServer` drops into
``ServingRuntime`` anywhere a ``BatchedFusedServer`` does.

The continuous path gets its own chunk-granular fault points
(DESIGN.md § Fault tolerance) through :class:`FaultyContinuousServer`:

* **chunk-dispatch failures** — a seeded subset of ``run_chunk`` calls
  raises :class:`ChunkDispatchError` carrying a carry-scrambled copy of the
  lane table (the wreck a preempted device leaves behind); the runtime
  rolls back to its chunk-boundary checkpoint and replays;
* **refill-dispatch failures** — a seeded subset of ``admit`` calls raises
  before any dispatch; admission is idempotent (counter-based RNG re-init),
  so the runtime simply retries the whole admit;
* **lane poisoning** — after a successful chunk, a seeded lane's carry is
  NaN'd / driven out of the monotone-z invariant, exercising the runtime's
  post-chunk health check and per-lane quarantine;
* **cache corruption** — a pinned subset of admit calls flips a value in
  the most-recently-used :class:`~repro.serving.feature_cache.FeatureCache`
  entry, exercising the power-sum integrity check.

All injection helpers are host-side buffer swaps (``device_put`` onto the
leaf's existing sharding) — a fault run mints ZERO executables beyond the
fault-free pair, which the recovery tests assert.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.core.executor_fused import CHUNK_CARRY_LEAVES

__all__ = [
    "TransientExecutorError",
    "ChunkDispatchError",
    "FaultProfile",
    "FaultyServer",
    "FaultyContinuousServer",
    "corrupt_cache_entry",
    "inject_burst",
    "poison_lane_carry",
    "scramble_chunk_carry",
]


class TransientExecutorError(RuntimeError):
    """A retryable executor failure (the kind a real backend throws on a
    preempted device, a dropped RPC, or an OOM-evicted program)."""


class ChunkDispatchError(TransientExecutorError):
    """A chunk dispatch that died mid-flight, leaving the table wrecked.

    ``table`` (when not None) is the poisoned lane table the failed
    dispatch left behind — the runtime must treat it as garbage and restore
    its chunk-boundary checkpoint onto it rather than resume from it.
    """

    def __init__(self, msg: str, table=None):
        super().__init__(msg)
        self.table = table


@dataclass(frozen=True)
class FaultProfile:
    """Deterministic, seeded fault schedule over ``serve_batch`` call indices.

    ``spike_calls`` / ``fail_calls`` pin faults to explicit 0-based call
    indices (exact, for unit tests); ``spike_prob`` / ``fail_prob`` add
    seeded Bernoulli faults on top (counter-based RNG on the call index, so
    the schedule is reproducible and independent of call timing).  A call
    scheduled to fail raises *before* any service work; a call scheduled to
    spike sleeps ``spike_s`` of real wall-clock before delegating.
    """

    seed: int = 0
    spike_s: float = 0.0
    spike_calls: tuple[int, ...] = ()
    spike_prob: float = 0.0
    fail_calls: tuple[int, ...] = ()
    fail_prob: float = 0.0
    # continuous-path fault points (chunk-granular; see
    # FaultyContinuousServer).  Each keys its own RNG stream so enabling
    # one never perturbs another's schedule.
    chunk_fail_calls: tuple[int, ...] = ()
    chunk_fail_prob: float = 0.0
    refill_fail_calls: tuple[int, ...] = ()
    refill_fail_prob: float = 0.0
    poison_calls: tuple[int, ...] = ()
    poison_prob: float = 0.0
    cache_corrupt_calls: tuple[int, ...] = ()

    def _bernoulli(self, stream: int, call: int, prob: float) -> bool:
        if prob <= 0.0:
            return False
        rng = np.random.default_rng((self.seed, stream, call))
        return bool(rng.random() < prob)

    def spikes_at(self, call: int) -> bool:
        return call in self.spike_calls or self._bernoulli(
            0, call, self.spike_prob
        )

    def fails_at(self, call: int) -> bool:
        return call in self.fail_calls or self._bernoulli(
            1, call, self.fail_prob
        )

    def chunk_fails_at(self, call: int) -> bool:
        return call in self.chunk_fail_calls or self._bernoulli(
            2, call, self.chunk_fail_prob
        )

    def refill_fails_at(self, call: int) -> bool:
        return call in self.refill_fail_calls or self._bernoulli(
            3, call, self.refill_fail_prob
        )

    def poisons_at(self, call: int) -> bool:
        return call in self.poison_calls or self._bernoulli(
            4, call, self.poison_prob
        )

    def poison_lane(self, call: int, lanes: int) -> int:
        """The (seeded) lane a poison event at ``call`` lands on."""
        rng = np.random.default_rng((self.seed, 5, call))
        return int(rng.integers(lanes))


class FaultyServer:
    """``serve_batch`` interceptor injecting the profile's faults.

    ``calls`` counts every attempt (including ones that raise), which is the
    index the schedule keys on; ``events`` logs ``(call, kind)`` for test
    assertions.  All other attributes proxy to the wrapped server.
    """

    def __init__(self, server, profile: FaultProfile, *, sleep=time.sleep):
        self._server = server
        self.profile = profile
        self.calls = 0
        self.events: list[tuple[int, str]] = []
        self._sleep = sleep  # injectable for fast tests

    def __getattr__(self, name):
        return getattr(self._server, name)

    def serve_batch(self, requests, knobs=None):
        call = self.calls
        self.calls += 1
        if self.profile.fails_at(call):
            self.events.append((call, "fail"))
            raise TransientExecutorError(
                f"injected transient failure at serve_batch call {call}"
            )
        if self.profile.spikes_at(call):
            self.events.append((call, "spike"))
            self._sleep(self.profile.spike_s)
        return self._server.serve_batch(requests, knobs=knobs)


def scramble_chunk_carry(table):
    """A carry-wrecked copy of a lane table (what a dead dispatch leaves).

    Every chunk-mutable leaf (:data:`CHUNK_CARRY_LEAVES`) is overwritten
    with garbage — NaN floats, -1 integers, cleared flags — while the big
    immutable buffers pass through untouched.  Host-side ``device_put``
    onto each leaf's existing sharding: no executables.
    """
    wreck = {}
    for name in CHUNK_CARRY_LEAVES:
        leaf = getattr(table, name)
        v = np.asarray(leaf).copy()
        if v.dtype == np.bool_:
            v[...] = False
        elif np.issubdtype(v.dtype, np.integer):
            v[...] = -1
        else:
            v[...] = np.nan
        wreck[name] = jax.device_put(v, leaf.sharding)
    return table._replace(**wreck)


def poison_lane_carry(table, lane: int):
    """NaN/corrupt ONE lane's carry in place (a partial-write fault).

    ``y_hat``/``prob``/``reps`` go NaN and ``z`` goes -1 (out of range AND
    a monotonicity regression) for the named lane only — the runtime's
    post-chunk health check must quarantine exactly this lane and leave
    its neighbors bitwise-untouched.  Host-side swap; no executables.
    """
    out = {}
    for name in ("y_hat", "prob", "reps"):
        leaf = getattr(table, name)
        v = np.asarray(leaf).copy()
        if v[lane].size:  # reps is zero-size on purely parametric pipelines
            v[lane] = np.nan
        out[name] = jax.device_put(v, leaf.sharding)
    z = np.asarray(table.z).copy()
    z[lane] = -1
    out["z"] = jax.device_put(z, table.z.sharding)
    return table._replace(**out)


def corrupt_cache_entry(cache, seed=0) -> bool:
    """Flip one value in the cache's most-recently-used entry's buffer.

    Models bit rot / a torn write in device-resident state: the entry's
    stored power-sum checksum no longer matches its contents, which the
    cache's integrity check (``verify_hits`` / ``revalidate``) must catch.
    The flip is checksum-changing by construction: a sign-bit flip on -0.0
    leaves the float's sums untouched, and flipping a pad zero into a
    denormal changes the float but drowns in the f64 accumulation — so
    candidates are retried until the recomputed power sums actually differ
    from the stored checksum.  Returns False when the cache is empty.
    """
    from repro.serving.feature_cache import entry_checksum

    entries = list(cache._entries.values())
    if not entries:
        return False
    entry = entries[-1]  # most recently used
    v = np.array(entry.vals)  # host copy
    flat = v.reshape(-1)
    orig = flat.copy()
    want = entry_checksum(entry.vals, entry.n)
    rng = np.random.default_rng(seed)
    for _ in range(32):
        i = int(rng.integers(flat.size))
        b = int(rng.integers(flat.itemsize))
        flat.view(np.uint8)[flat.itemsize * i + b] ^= 0xFF
        got = entry_checksum(v, entry.n)
        # NaN sums compare unequal to anything — detectable too
        if got != want:
            break
        flat[i] = orig[i]
    else:
        flat[0] = orig[0] + 1.0
    entry.vals = jax.device_put(v, entry.vals.sharding)
    return True


class FaultyContinuousServer:
    """Chunk-granular fault interceptor around a ``ContinuousBatchedServer``.

    ``run_chunk`` and ``admit`` are intercepted with their OWN call
    counters (the schedule indices); everything else proxies to the inner
    server, so the wrapper drops into ``ContinuousServingRuntime`` anywhere
    the real server does.  ``events`` logs ``(call, kind)`` per injection
    for test assertions; two runs with the same profile inject byte-
    identical fault sequences.
    """

    def __init__(self, server, profile: FaultProfile, *, sleep=time.sleep):
        self._server = server
        self.profile = profile
        self.chunk_calls = 0
        self.admit_calls = 0
        self.events: list[tuple[int, str]] = []
        self._sleep = sleep  # injectable for fast tests

    def __getattr__(self, name):
        return getattr(self._server, name)

    def admit(self, table, cap, assignments):
        call = self.admit_calls
        self.admit_calls += 1
        prof = self.profile
        cache = getattr(self._server, "cache", None)
        if call in prof.cache_corrupt_calls and cache is not None:
            if corrupt_cache_entry(cache, seed=(prof.seed, 6, call)):
                self.events.append((call, "cache_corrupt"))
        if prof.refill_fails_at(call):
            self.events.append((call, "refill_fail"))
            raise TransientExecutorError(
                f"injected refill failure at admit call {call}"
            )
        return self._server.admit(table, cap, assignments)

    def run_chunk(self, table):
        call = self.chunk_calls
        self.chunk_calls += 1
        prof = self.profile
        if prof.spikes_at(call):
            self.events.append((call, "spike"))
            self._sleep(prof.spike_s)
        if prof.chunk_fails_at(call):
            self.events.append((call, "chunk_fail"))
            raise ChunkDispatchError(
                f"injected chunk-dispatch failure at chunk call {call}",
                table=scramble_chunk_carry(table),
            )
        table = self._server.run_chunk(table)
        if prof.poisons_at(call):
            lane = prof.poison_lane(call, self._server.batch_size)
            self.events.append((call, f"poison:{lane}"))
            table = poison_lane_carry(table, lane)
        return table


def inject_burst(
    arrivals,
    *,
    at_t: float,
    n: int,
    width_s: float,
    seed: int = 0,
    slo_s: float | None = None,
):
    """Splice ``n`` extra arrivals uniformly into ``[at_t, at_t + width_s)``.

    Requests for the burst are drawn (seeded) from the base trace's own
    request population, so the burst stresses admission, not new cap
    buckets.  Accepts and returns ``(t, request)`` / ``(t, request, slo_s)``
    tuples sorted by time; ``slo_s`` attaches a deadline budget to the
    injected arrivals (burst traffic usually carries the same SLO as the
    rest).  Raises on an empty base trace or non-positive width.
    """
    base = sorted(arrivals, key=lambda a: a[0])
    if not base:
        raise ValueError("cannot inject a burst into an empty trace")
    if width_s <= 0:
        raise ValueError("width_s must be > 0")
    if n < 0:
        raise ValueError("n must be >= 0")
    rng = np.random.default_rng(seed)
    reqs = [a[1] for a in base]
    ts = np.sort(rng.uniform(at_t, at_t + width_s, n))
    extra = []
    for t in ts:
        req = reqs[int(rng.integers(len(reqs)))]
        extra.append(
            (float(t), req) if slo_s is None else (float(t), req, slo_s)
        )
    return sorted(base + extra, key=lambda a: a[0])
