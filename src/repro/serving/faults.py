"""Fault-injection harness for the serving runtime: spikes, failures, bursts.

Degradation and recovery behavior must be *testable*, not assumed, so this
module wraps ``serve_batch`` in a deterministic, seeded fault layer
(DESIGN.md § Graceful degradation & fault injection):

* **service-time spikes** — a seeded subset of ``serve_batch`` calls sleeps
  an extra ``spike_s`` before dispatching; the runtime measures wall-clock,
  so a spike propagates exactly like a real slow batch (queue builds on the
  virtual clock, the controller's EWMA estimate rises, tiers loosen);
* **transient executor exceptions** — a seeded subset of calls raises
  :class:`TransientExecutorError` *instead of* serving; the runtime retries
  with bounded exponential backoff (serving/runtime.py) and marks the batch
  ``failed`` only when retries are exhausted;
* **arrival bursts** — :func:`inject_burst` splices a compressed clump of
  extra arrivals into a trace, the overload pattern shedding exists for.

The schedule is a pure function of ``(FaultProfile, call index)`` —
counter-based RNG per call, no global state — so two runs over the same
trace inject identical faults and every test is reproducible.  The wrapper
delegates everything else (``batch_size``, ``config``, ``compile_count``,
``batch_cap``...) to the inner server, so :class:`FaultyServer` drops into
``ServingRuntime`` anywhere a ``BatchedFusedServer`` does.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

__all__ = [
    "TransientExecutorError",
    "FaultProfile",
    "FaultyServer",
    "inject_burst",
]


class TransientExecutorError(RuntimeError):
    """A retryable executor failure (the kind a real backend throws on a
    preempted device, a dropped RPC, or an OOM-evicted program)."""


@dataclass(frozen=True)
class FaultProfile:
    """Deterministic, seeded fault schedule over ``serve_batch`` call indices.

    ``spike_calls`` / ``fail_calls`` pin faults to explicit 0-based call
    indices (exact, for unit tests); ``spike_prob`` / ``fail_prob`` add
    seeded Bernoulli faults on top (counter-based RNG on the call index, so
    the schedule is reproducible and independent of call timing).  A call
    scheduled to fail raises *before* any service work; a call scheduled to
    spike sleeps ``spike_s`` of real wall-clock before delegating.
    """

    seed: int = 0
    spike_s: float = 0.0
    spike_calls: tuple[int, ...] = ()
    spike_prob: float = 0.0
    fail_calls: tuple[int, ...] = ()
    fail_prob: float = 0.0

    def spikes_at(self, call: int) -> bool:
        if call in self.spike_calls:
            return True
        if self.spike_prob <= 0.0:
            return False
        rng = np.random.default_rng((self.seed, 0, call))
        return bool(rng.random() < self.spike_prob)

    def fails_at(self, call: int) -> bool:
        if call in self.fail_calls:
            return True
        if self.fail_prob <= 0.0:
            return False
        rng = np.random.default_rng((self.seed, 1, call))
        return bool(rng.random() < self.fail_prob)


class FaultyServer:
    """``serve_batch`` interceptor injecting the profile's faults.

    ``calls`` counts every attempt (including ones that raise), which is the
    index the schedule keys on; ``events`` logs ``(call, kind)`` for test
    assertions.  All other attributes proxy to the wrapped server.
    """

    def __init__(self, server, profile: FaultProfile, *, sleep=time.sleep):
        self._server = server
        self.profile = profile
        self.calls = 0
        self.events: list[tuple[int, str]] = []
        self._sleep = sleep  # injectable for fast tests

    def __getattr__(self, name):
        return getattr(self._server, name)

    def serve_batch(self, requests, knobs=None):
        call = self.calls
        self.calls += 1
        if self.profile.fails_at(call):
            self.events.append((call, "fail"))
            raise TransientExecutorError(
                f"injected transient failure at serve_batch call {call}"
            )
        if self.profile.spikes_at(call):
            self.events.append((call, "spike"))
            self._sleep(self.profile.spike_s)
        return self._server.serve_batch(requests, knobs=knobs)


def inject_burst(
    arrivals,
    *,
    at_t: float,
    n: int,
    width_s: float,
    seed: int = 0,
    slo_s: float | None = None,
):
    """Splice ``n`` extra arrivals uniformly into ``[at_t, at_t + width_s)``.

    Requests for the burst are drawn (seeded) from the base trace's own
    request population, so the burst stresses admission, not new cap
    buckets.  Accepts and returns ``(t, request)`` / ``(t, request, slo_s)``
    tuples sorted by time; ``slo_s`` attaches a deadline budget to the
    injected arrivals (burst traffic usually carries the same SLO as the
    rest).  Raises on an empty base trace or non-positive width.
    """
    base = sorted(arrivals, key=lambda a: a[0])
    if not base:
        raise ValueError("cannot inject a burst into an empty trace")
    if width_s <= 0:
        raise ValueError("width_s must be > 0")
    if n < 0:
        raise ValueError("n must be >= 0")
    rng = np.random.default_rng(seed)
    reqs = [a[1] for a in base]
    ts = np.sort(rng.uniform(at_t, at_t + width_s, n))
    extra = []
    for t in ts:
        req = reqs[int(rng.integers(len(reqs)))]
        extra.append(
            (float(t), req) if slo_s is None else (float(t), req, slo_s)
        )
    return sorted(base + extra, key=lambda a: a[0])
