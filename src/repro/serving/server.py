"""Serving runtime: request queue, batcher, Biathlon-integrated serve path.

Two execution modes per pipeline:

* ``host``  — the paper-faithful HostLoopExecutor (dynamic plans, bucketed
  shapes).  One request at a time, like the paper's evaluation.
* ``fused`` — the beyond-paper single-XLA-program executor; requests are
  admitted from the queue, their (k, cap) sample buffers gathered once, and
  the whole iterate-until-guaranteed loop runs on device.  Compiled once per
  pipeline; per-request state (exact features, group sizes, delta) is data.

``ServerStats`` mirrors the paper's §4 metrics: mean latency, speedup vs the
exact baseline, sample fraction, guarantee satisfaction rate, accuracy.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import assert_compile_contract
from repro.core.executor import BiathlonConfig, HostLoopExecutor, run_exact
from repro.core.executor_fused import (
    build_afc_precompute,
    build_fused_executor,
    pipeline_executor_kwargs,
)
from repro.core.pipeline import make_fused_model_fn
from repro.data.store import bucket_size
from repro.data.synthetic import PipelineBundle
from repro.serving.feature_cache import FeatureCache

__all__ = ["BiathlonServer", "ServerStats"]


@dataclass
class ServerStats:
    latencies: list = field(default_factory=list)
    exact_latencies: list = field(default_factory=list)
    errors_vs_exact: list = field(default_factory=list)
    sample_fracs: list = field(default_factory=list)
    iters: list = field(default_factory=list)
    satisfied: list = field(default_factory=list)
    y_hats: list = field(default_factory=list)
    y_exacts: list = field(default_factory=list)

    def summary(self, delta: float, task: str) -> dict:
        lat = np.array(self.latencies)
        if len(lat) == 0:
            # zero served requests: well-defined zeros/NaNs, never a crash
            return {
                "n": 0,
                "mean_latency_s": float("nan"),
                "p95_latency_s": float("nan"),
                "mean_exact_latency_s": float("nan"),
                "speedup": 0.0,
                "mean_sample_frac": float("nan"),
                "mean_iters": 0.0,
                "guarantee_rate": 0.0,
                "mean_abs_err_vs_exact": float("nan"),
            }
        ex = np.array(self.exact_latencies) if self.exact_latencies else np.array([np.nan])
        err = np.array(self.errors_vs_exact)
        within = (
            (err <= max(delta, 1e-12) + 1e-9)
            if task == "regression"
            else (err == 0)
        )
        return {
            "n": len(lat),
            "mean_latency_s": float(lat.mean()),
            "p95_latency_s": float(np.percentile(lat, 95)),
            "mean_exact_latency_s": float(np.nanmean(ex)),
            "speedup": float(np.nanmean(ex) / lat.mean()),
            "mean_sample_frac": float(np.mean(self.sample_fracs)),
            "mean_iters": float(np.mean(self.iters)),
            "guarantee_rate": float(np.mean(within)) if len(err) else 0.0,
            "mean_abs_err_vs_exact": float(err.mean()) if len(err) else float("nan"),
        }


class BiathlonServer:
    def __init__(
        self,
        bundle: PipelineBundle,
        config: BiathlonConfig | None = None,
        mode: str = "host",
        max_cap: int | None = None,
        afc_backend: str = "auto",
        cache_size: int | None = None,
    ):
        self.bundle = bundle
        self.config = config or BiathlonConfig()
        self.mode = mode
        self.pipeline = bundle.pipeline
        self.store = bundle.store
        self._host = HostLoopExecutor(self.store, self.config)
        self._fused = None
        self._max_cap_override = max_cap
        # "auto"/"kernel" = incremental prefix-stats AFC (the serving
        # default); "ref" = the pre-refactor rescan oracle (parity/bench
        # baseline) — see executor_fused.build_fused_executor.
        self._afc_backend = afc_backend
        # cache_size enables the hot-group feature cache (fused mode): the
        # executor is built prebuilt=True and fed device-resident tables
        # from a (table, group, version)-keyed LRU of ``cache_size`` groups.
        self._cache_size = cache_size
        self.cache: FeatureCache | None = None
        self._compile_count = 0
        self._caps_seen: set[int] = set()
        self.contract = (
            ("fused_prebuilt", "afc_precompute")
            if cache_size is not None
            else ("fused",)
        )
        if mode == "fused":
            self._build_fused()

    # ------------------------------------------------------------------
    def _build_fused(self):
        p = self.pipeline
        cfg = self.config
        feat_kwargs = pipeline_executor_kwargs(p.agg_features)
        self._agg_ids = feat_kwargs.pop("agg_ids")
        cached = self._cache_size is not None
        self._fused = build_fused_executor(
            make_fused_model_fn(p),
            k=p.k,
            task=p.task,
            n_classes=max(p.n_classes, 2),
            m=cfg.m,
            m_sobol=cfg.m_sobol,
            alpha=cfg.alpha,
            gamma=cfg.gamma,
            tau=cfg.tau,
            max_iters=cfg.max_iters,
            n_boot=cfg.n_bootstrap,
            afc_backend=self._afc_backend,
            prebuilt=cached,
            **feat_kwargs,
        )
        if cached:
            pre = build_afc_precompute(
                k=p.k, alpha=cfg.alpha, gamma=cfg.gamma,
                max_iters=cfg.max_iters,
                holistic=feat_kwargs["holistic"],
                quantiles=feat_kwargs["quantiles"],
                approximate=feat_kwargs["approximate"],
            )
            inner_run, inner_cold = self._fused, pre.cold

            # trace hooks: bodies execute once per jit cache miss, so the
            # counter observes exactly the executables the bucket minted
            def _counted_run(vals, n, agg_ids, delta, exact, tables):
                self._compile_count += 1
                return inner_run(vals, n, agg_ids, delta, exact, tables)

            def _counted_cold(vals, n):
                self._compile_count += 1
                return inner_cold(vals, n)

            self._fused = jax.jit(_counted_run)
            self.cache = FeatureCache(
                self.store, jax.jit(_counted_cold), pre.refresh,
                maxsize=self._cache_size,
            )
        max_n = max(
            self.store[f.table].group_size(g)
            for f in p.agg_features
            for g in self.store[f.table].group_ids
        )
        # store-wide ceiling; each request gathers at its own power-of-two
        # bucket below this, so small groups skip the worst-case padding
        self._cap = bucket_size(max_n)
        if self._max_cap_override is not None:
            self._cap = min(self._cap, bucket_size(self._max_cap_override))

    # ------------------------------------------------------------------
    def serve(self, request: dict, key=None):
        p = self.pipeline
        delta = (
            self.config.delta if self.config.delta is not None else p.delta_default
        )
        if self.mode == "host":
            r = self._host.run(p, request, key)
            return {
                "y_hat": r.y_hat,
                "latency": r.t_total,
                "iters": r.iters,
                "sample_frac": r.sample_fraction,
                "prob": r.prob,
                "z": np.asarray(r.z),
                "n": np.asarray(r.n),
            }
        t0 = time.perf_counter()
        specs = p.agg_specs(request)
        n_np = p.group_sizes(self.store, request)
        cap = min(bucket_size(int(max(n_np.max(), 1))), self._cap)
        n_true = jnp.asarray(n_np, jnp.int32)
        exact = jnp.asarray(p.exact_feature_values(self.store, request))
        self._caps_seen.add(cap)
        if self.cache is not None:
            entry = self.cache.get(specs, cap)
            res = self._fused(
                entry.vals, entry.n, self._agg_ids,
                jnp.asarray(delta, jnp.float32), exact, entry.tables,
            )
        else:
            vals, sizes = self.store.request_buffers(specs, cap)
            res = self._fused(
                vals, jnp.minimum(n_true, cap), self._agg_ids,
                jnp.asarray(delta, jnp.float32), exact,
            )
        y = float(res.y_hat)
        dt = time.perf_counter() - t0
        return {
            "y_hat": y,
            "latency": dt,
            "iters": int(res.iters),
            "sample_frac": float(res.samples_used) / max(int(n_true.sum()), 1),
            "prob": float(res.prob),
            "z": np.asarray(res.z),
            "n": np.asarray(jnp.minimum(n_true, cap)),
        }

    # ------------------------------------------------------------------
    # compile-contract accessors (cached fused mode): the trace hooks above
    # count executable mints; assert_compile_contract does the arithmetic.
    @property
    def compile_count(self) -> int:
        return self._compile_count

    @property
    def compiled_buckets(self) -> list[int]:
        return sorted(self._caps_seen)

    def check_compile_contract(self) -> None:
        assert_compile_contract(self, self.contract)

    # ------------------------------------------------------------------
    def serve_all(self, requests=None, compare_exact: bool = True, seed: int = 0):
        """Drain a request log; returns ServerStats."""
        requests = requests if requests is not None else self.bundle.requests
        stats = ServerStats()
        p = self.pipeline
        for i, req in enumerate(requests):
            out = self.serve(req, jax.random.PRNGKey(seed + i))
            stats.latencies.append(out["latency"])
            stats.iters.append(out["iters"])
            stats.sample_fracs.append(out["sample_frac"])
            stats.y_hats.append(out["y_hat"])
            if compare_exact:
                y_ex, t_ex = run_exact(self.store, p, req)
                stats.exact_latencies.append(t_ex)
                stats.errors_vs_exact.append(abs(out["y_hat"] - y_ex))
                stats.y_exacts.append(y_ex)
        return stats
