from repro.serving.server import BiathlonServer, ServerStats
from repro.serving.batched import (
    BatchedFusedServer,
    BatchResult,
    device_fill,
    straggler_report,
)
from repro.serving.runtime import (
    AdmissionBatcher,
    Arrival,
    RequestRecord,
    RuntimeStats,
    ServingRuntime,
)

__all__ = [
    "BiathlonServer",
    "ServerStats",
    "BatchedFusedServer",
    "BatchResult",
    "device_fill",
    "straggler_report",
    "AdmissionBatcher",
    "Arrival",
    "RequestRecord",
    "RuntimeStats",
    "ServingRuntime",
]
