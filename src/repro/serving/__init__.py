from repro.serving.server import BiathlonServer, ServerStats
from repro.serving.batched import (
    BatchedFusedServer,
    BatchResult,
    device_fill,
    straggler_report,
)
from repro.serving.degrade import (
    DegradationController,
    KnobTier,
    LaneKnobs,
    default_tiers,
    validate_tiers,
)
from repro.serving.faults import (
    FaultProfile,
    FaultyServer,
    TransientExecutorError,
    inject_burst,
)
from repro.serving.runtime import (
    AdmissionBatcher,
    Arrival,
    RequestRecord,
    RuntimeStats,
    ServingRuntime,
)

__all__ = [
    "BiathlonServer",
    "ServerStats",
    "BatchedFusedServer",
    "BatchResult",
    "device_fill",
    "straggler_report",
    "DegradationController",
    "KnobTier",
    "LaneKnobs",
    "default_tiers",
    "validate_tiers",
    "FaultProfile",
    "FaultyServer",
    "TransientExecutorError",
    "inject_burst",
    "AdmissionBatcher",
    "Arrival",
    "RequestRecord",
    "RuntimeStats",
    "ServingRuntime",
]
