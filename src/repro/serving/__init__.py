from repro.serving.server import BiathlonServer, ServerStats
from repro.serving.batched import (
    BatchedFusedServer,
    BatchResult,
    chunked_straggler_report,
    device_fill,
    lane_request_inputs,
    straggler_report,
    validate_serving_mesh,
)
from repro.serving.continuous import ContinuousBatchedServer
from repro.serving.degrade import (
    DegradationController,
    KnobTier,
    LaneKnobs,
    default_tiers,
    validate_tiers,
)
from repro.serving.faults import (
    FaultProfile,
    FaultyServer,
    TransientExecutorError,
    inject_burst,
)
from repro.serving.runtime import (
    AdmissionBatcher,
    Arrival,
    ContinuousServingRuntime,
    RequestRecord,
    RuntimeStats,
    ServingRuntime,
)

__all__ = [
    "BiathlonServer",
    "ServerStats",
    "BatchedFusedServer",
    "BatchResult",
    "ContinuousBatchedServer",
    "ContinuousServingRuntime",
    "chunked_straggler_report",
    "device_fill",
    "lane_request_inputs",
    "straggler_report",
    "validate_serving_mesh",
    "DegradationController",
    "KnobTier",
    "LaneKnobs",
    "default_tiers",
    "validate_tiers",
    "FaultProfile",
    "FaultyServer",
    "TransientExecutorError",
    "inject_burst",
    "AdmissionBatcher",
    "Arrival",
    "RequestRecord",
    "RuntimeStats",
    "ServingRuntime",
]
