from repro.serving.server import BiathlonServer, ServerStats

__all__ = ["BiathlonServer", "ServerStats"]
from repro.serving.batched import BatchedFusedServer  # noqa: E402

__all__.append("BatchedFusedServer")
