"""Batched Biathlon serving: many concurrent requests in ONE XLA program.

The fused executor's state is fixed-shape, so a batch of requests vmaps
cleanly: each request carries its own sample buffers, group sizes, exact
features and delta; per-request early exit happens by predication inside
the shared while_loop (the loop runs until EVERY request in the admission
batch satisfies Eq. 1 or exhausts — the standard continuous-batching trade:
stragglers in a batch pay for each other, so admission batches should be
sized to the arrival rate).

Two mechanisms bound the jit cache:

* **Fixed lanes** — every admission batch is padded to exactly
  ``batch_size`` rows; pad lanes carry zero buffers and an ``active=False``
  flag that forces their while_loop predicate false inside the executor
  (executor_fused.py).  The compiled shape is therefore
  ``(batch_size, k, cap)`` for ANY batch fill 1..batch_size — one executable
  per cap bucket, not one per distinct fill.
* **Per-batch cap bucketing** — the (lanes, k, cap) gather pads to the next
  power of two above the BATCH's largest group, not the store-wide worst
  case, so a batch of small-group requests does proportionally small AFC
  work (the same power-of-two trick ``HostLoopExecutor`` uses).

``straggler_report`` makes the batching trade measurable (per-request
iterations vs the batch's shared iteration count, over ACTIVE lanes only).

This is the throughput-serving mode a TPU deployment would run: one
(lanes, k, cap) gather, one program, R guarantees.  The arrival-driven
admission loop that feeds it lives in serving/runtime.py.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import assert_compile_contract
from repro.core.executor_fused import (
    build_afc_precompute,
    build_fused_executor,
    pipeline_executor_kwargs,
    shard_lanes_executor,
)
from repro.core.pipeline import make_fused_model_fn
from repro.data.store import bucket_size
from repro.serving.feature_cache import FeatureCache

__all__ = [
    "BatchedFusedServer",
    "BatchResult",
    "chunked_straggler_report",
    "device_fill",
    "lane_request_inputs",
    "sanitize_lane_inputs",
    "straggler_report",
    "validate_serving_mesh",
]


def sanitize_lane_inputs(vals, exact, *, policy: str, where: str):
    """Police NaN/Inf in a lane's host-side inputs at the serving edge.

    A non-finite feature value entering the executor propagates through
    every prefix power sum and megabatch evaluation of its lane — and with
    continuous batching the poisoned carry then LIVES in the lane table.
    ``policy='reject'`` raises naming the offending buffer, feature row and
    position; ``policy='clamp'`` zeroes non-finite entries (0.0 is the
    store's neutral pad value, masked out by estimators at true prefix
    lengths).  ``vals`` may be ``None`` (cached admissions keep their
    values device-resident and are protected by the cache's integrity
    check instead).  Returns the (possibly rewritten) ``(vals, exact)``.
    """
    if policy not in ("reject", "clamp"):
        raise ValueError(
            f"{where}: unknown sanitize policy {policy!r} "
            f"(expected 'reject' or 'clamp')"
        )
    out = []
    for name, buf in (("vals", vals), ("exact", exact)):
        if buf is None:
            out.append(None)
            continue
        buf = np.asarray(buf)
        bad = ~np.isfinite(buf)
        if not bad.any():
            out.append(buf)
            continue
        if policy == "reject":
            pos = tuple(int(x) for x in np.argwhere(bad)[0])
            raise ValueError(
                f"{where}: non-finite value {float(buf[pos])!r} in request "
                f"{name} buffer at {pos} (sanitize='reject'; use "
                f"sanitize='clamp' to coerce, or fix the store column)"
            )
        buf = buf.copy()
        buf[bad] = 0.0
        out.append(buf)
    return tuple(out)


def validate_serving_mesh(mesh, lanes: int) -> int:
    """Validate a serving mesh against a fixed lane count; returns its size.

    Shared by the fixed-lane and continuous servers: the mesh must be 1-D,
    named ``lanes`` (shard_map partitions on the literal axis name — a
    differently-named mesh would only fail deep inside tracing at the first
    dispatch), and must divide the lane count evenly.  ``None`` means
    unsharded (returns 1).
    """
    if mesh is None:
        return 1
    if mesh.devices.ndim != 1:
        raise ValueError(
            f"serving mesh must be 1-D over 'lanes', got shape "
            f"{mesh.devices.shape}"
        )
    names = tuple(getattr(mesh, "axis_names", ()))
    if names and names != ("lanes",):
        raise ValueError(
            f"serving mesh axis must be named 'lanes', got {names}; "
            "build it with launch.mesh.make_serving_mesh"
        )
    n_devices = int(mesh.devices.size)
    if lanes % n_devices != 0:
        raise ValueError(
            f"batch_size {lanes} must be divisible by the mesh's "
            f"{n_devices} devices"
        )
    return n_devices


def lane_request_inputs(pipeline, store, req: dict, cap: int):
    """One request's lane inputs at a cap bucket.

    Returns ``(vals (k, cap) f32, n (k,) i32 clamped, true_n (k,) i64,
    exact (e,) f32)`` — the per-lane buffer assembly shared by the
    fixed-lane batch path and the continuous refill path, so both feed the
    executor identical data (a precondition of the recycling-parity
    contract).
    """
    v, _ = store.request_buffers(pipeline.agg_specs(req), cap)
    true_n = np.asarray(pipeline.group_sizes(store, req), np.int64)
    return (
        np.asarray(v, np.float32),
        np.minimum(true_n, cap).astype(np.int32),
        true_n,
        np.asarray(pipeline.exact_feature_values(store, req), np.float32),
    )


class BatchResult(NamedTuple):
    y_hat: np.ndarray
    prob: np.ndarray
    iters: np.ndarray       # (R,) per-request planner iterations (active lanes)
    sample_frac: np.ndarray  # samples touched / TRUE group rows (paper §4)
    batch_iters: int        # shared while_loop trip count = max(iters)
    cap: int                # bucketed buffer cap used for this batch
    lanes: int              # padded lane count the executable was compiled for
    z: np.ndarray | None = None  # (R, k) final per-request plans (active lanes)
    n_devices: int = 1      # mesh size the lanes were sharded over


def device_fill(fill: int, lanes: int, n_devices: int) -> np.ndarray:
    """Active lanes per device for a front-packed fill of a sharded batch.

    Lanes partition contiguously over the 1-D serving mesh (lane block
    ``d*lanes/D .. (d+1)*lanes/D - 1`` lives on device ``d``) and admission
    fills lanes front-to-back, so a batch with ``fill`` active lanes puts
    ``clip(fill - d·L/D, 0, L/D)`` of them on device ``d``.  Returns the
    (n_devices,) int array of active-lane counts.
    """
    if lanes % max(n_devices, 1) != 0:
        raise ValueError(f"lanes {lanes} not divisible by n_devices {n_devices}")
    per_dev = lanes // max(n_devices, 1)
    d = np.arange(max(n_devices, 1))
    return np.clip(fill - d * per_dev, 0, per_dev).astype(np.int64)


def straggler_report(res: BatchResult) -> dict:
    """How much the admission batch paid for its slowest request.

    ``wasted_iters[i]`` counts loop trips request i sat through after its own
    guarantee was met (predicated no-ops that still burn compute in the
    shared program); ``wasted_frac`` is their share of the batch's total
    *active*-lane-iterations — the admission-sizing signal.  Pad lanes never
    iterate (their predicate is forced false), so they are excluded from the
    waste accounting; ``fill`` reports how full the fixed-lane batch was.

    On a sharded batch (``res.n_devices > 1``) a lane only waits for the
    stragglers sharing its OWN device — each device's while-loop exits
    independently — so the waste accounting is per-device (lane i waits for
    its device-block max, not the global max) instead of silently charging
    every lane the global straggler.  ``per_device_fill`` gives each
    device's active-lane fraction (lanes partition contiguously, fills are
    front-packed) and ``lane_imbalance`` the max−min spread of those
    fractions — 0 means perfectly balanced, 1 means some device is full
    while another is all padding.

    An empty batch (zero active lanes) yields zeros and ``straggler == -1``.
    """
    iters = np.asarray(res.iters)
    n_dev = max(int(getattr(res, "n_devices", 1)), 1)
    lanes = max(int(res.lanes), 1)
    dev_active = device_fill(iters.size, lanes, n_dev)
    per_dev_fill = dev_active / (lanes // n_dev)
    if iters.size == 0:
        return {
            "batch_iters": 0,
            "per_request_iters": iters,
            "wasted_iters": iters,
            "wasted_frac": 0.0,
            "straggler": -1,
            "cap": int(res.cap),
            "lanes": int(res.lanes),
            "fill": 0.0,
            "n_devices": n_dev,
            "per_device_fill": per_dev_fill,
            "lane_imbalance": 0.0,
        }
    # lane i's device is i // (lanes/D): waste is measured against the max of
    # its own device block (== batch_iters when n_devices == 1)
    dev_of = np.arange(iters.size) // (lanes // n_dev)
    dev_max = np.zeros(n_dev, iters.dtype)
    np.maximum.at(dev_max, dev_of, iters)
    wasted = dev_max[dev_of] - iters
    total = max(int(dev_max[dev_of].sum()), 1)
    return {
        "batch_iters": int(res.batch_iters),
        "per_request_iters": iters,
        "wasted_iters": wasted,
        "wasted_frac": float(wasted.sum()) / total,
        "straggler": int(np.argmax(iters)),
        "cap": int(res.cap),
        "lanes": int(res.lanes),
        "fill": float(len(iters)) / lanes,
        "n_devices": n_dev,
        "per_device_fill": per_dev_fill,
        "lane_imbalance": float(per_dev_fill.max() - per_dev_fill.min()),
    }


def chunked_straggler_report(
    chunk_iters, occupied, *, lanes: int, n_devices: int = 1
) -> dict:
    """Chunk-granularity waste accounting for recycled lanes.

    With continuous batching a lane serves many requests per batch window
    and fills are NOT front-packed (a freed lane is refilled in place), so
    :func:`straggler_report`'s batch-global and :func:`device_fill`'s
    front-packed assumptions both break.  This report charges waste per
    **chunk** against each device block's chunk-boundary maximum: inputs
    are the (n_chunks, lanes) matrices of per-chunk planner-iteration
    counts and lane occupancy the scheduler records at every chunk
    boundary.

    ``wasted_iters[l]`` counts the loop trips lane ``l`` sat through beyond
    its own work while some co-resident lane on its device was still
    iterating — summed over chunks, so a lane recycled mid-window is only
    ever charged against the stragglers it ACTUALLY shared a dispatch with
    (the fixed-lane report would charge the whole batch window).
    ``per_device_fill`` / ``lane_imbalance`` are occupancy-true: mean
    occupied-lane fraction per device block over chunks, well-defined for
    any refill pattern and empty-safe (zero chunks -> zeros).
    """
    lanes = int(lanes)
    n_dev = max(int(n_devices), 1)
    if lanes % n_dev != 0:
        raise ValueError(f"lanes {lanes} not divisible by n_devices {n_dev}")
    per_dev = lanes // n_dev
    it = np.asarray(chunk_iters, np.int64).reshape(-1, lanes)
    occ = np.asarray(occupied, bool).reshape(-1, lanes)
    if it.shape != occ.shape:
        raise ValueError(
            f"chunk_iters {it.shape} and occupied {occ.shape} must align"
        )
    n_chunks = it.shape[0]
    if n_chunks == 0:
        return {
            "n_chunks": 0,
            "lanes": lanes,
            "n_devices": n_dev,
            "lane_occupancy": 0.0,
            "per_device_fill": [0.0] * n_dev,
            "lane_imbalance": 0.0,
            "wasted_iters": np.zeros(lanes, np.int64),
            "wasted_frac": 0.0,
            "total_iters": 0,
        }
    it = np.where(occ, it, 0)
    blk = it.reshape(n_chunks, n_dev, per_dev)
    occ_blk = occ.reshape(n_chunks, n_dev, per_dev)
    # each dispatch, a lane waits for its OWN device block's straggler —
    # the chunk-boundary device-block max, not the batch-window global max
    blk_max = blk.max(axis=2)                                   # (C, D)
    wasted = np.where(occ_blk, blk_max[:, :, None] - blk, 0)    # (C, D, L/D)
    charged = np.where(occ_blk, blk_max[:, :, None], 0)
    occ_frac = occ_blk.mean(axis=2)                             # (C, D)
    return {
        "n_chunks": int(n_chunks),
        "lanes": lanes,
        "n_devices": n_dev,
        "lane_occupancy": float(occ.mean()),
        "per_device_fill": [float(x) for x in occ_frac.mean(axis=0)],
        "lane_imbalance": float((occ_frac.max(1) - occ_frac.min(1)).mean()),
        "wasted_iters": wasted.reshape(n_chunks, lanes).sum(axis=0),
        "wasted_frac": float(wasted.sum()) / max(int(charged.sum()), 1),
        "total_iters": int(it.sum()),
    }


class BatchedFusedServer:
    """vmapped FusedExecutor over fixed-lane admission batches of requests.

    One compiled program per power-of-two cap bucket: batches are padded to
    exactly ``batch_size`` lanes (inactive lanes predicated out on device),
    so the jit cache is keyed by ``(batch_size, k, cap)`` only — varying
    batch fill never recompiles.  ``compile_count`` / ``compiled_buckets``
    make that observable (and testable).

    ``max_cap`` optionally lowers the store-wide buffer ceiling (bounded
    device memory); groups larger than the cap degrade gracefully — the
    executor exhausts at ``cap`` rows and ``sample_frac`` stays honest
    because its denominator is the TRUE group size.

    ``mesh`` (a 1-D ``("lanes",)`` mesh from ``launch.mesh.make_serving_mesh``)
    shards the fixed lanes data-parallel across its devices via
    ``shard_map``: lane ``i`` lives on device ``i // (batch_size/D)``, model
    params stay replicated, and the hot path runs no collectives.  The
    fixed-lane contract is mesh-invariant — still ONE executable per
    power-of-two cap bucket, for every fill and any device count — and
    per-lane results are identical to the unsharded server (bitwise for the
    integer plans; fp-tolerance for predictions, since XLA recompiles at a
    different per-device lane count).  ``batch_size`` must divide evenly
    over the mesh.

    The (lanes, k, cap) values buffer is **donated** on both paths and
    threaded back out as ``FusedResult.lane_vals``, so XLA aliases it in
    place instead of copying it per batch; ``afc_backend`` is forwarded to
    :func:`build_fused_executor` ("auto" = incremental prefix-stats AFC,
    "ref" = the pre-refactor rescan oracle).

    ``cache_size`` enables the hot-group feature cache: every lane's
    ``(vals, n, PrebuiltTables)`` comes from a version-keyed LRU
    (serving/feature_cache.py), the executor runs ``prebuilt=True``, and the
    per-lane stacks are fresh ``jnp.stack`` copies — so donating the stacked
    buffer never aliases a cache entry.  Incompatible with ``mesh`` (the
    sharded path owns its lane buffers device-side).
    """

    def __init__(self, bundle, config, batch_size: int = 8,
                 max_cap: int | None = None, mesh=None,
                 afc_backend: str = "auto", cache_size: int | None = None,
                 sanitize: str = "reject"):
        self.bundle = bundle
        self.config = config
        self.batch_size = batch_size
        self.mesh = mesh
        if sanitize not in ("reject", "clamp"):
            raise ValueError(
                f"sanitize must be 'reject' or 'clamp', got {sanitize!r}"
            )
        self.sanitize = sanitize
        self.n_devices = validate_serving_mesh(mesh, batch_size)
        if cache_size is not None and mesh is not None:
            raise ValueError(
                "cache_size and mesh are mutually exclusive: cached lanes "
                "stack host-tracked cache entries, sharded lanes partition "
                "device-resident buffers"
            )
        self._cache_size = cache_size
        self.cache: FeatureCache | None = None
        cached = cache_size is not None
        #: registered contract governing this server's compiled executables
        #: (repro.analysis.contracts; declared in core/executor_fused.py)
        if cached:
            self.contract = ("fused_prebuilt", "afc_precompute")
        elif mesh is not None:
            self.contract = ("sharded_lanes",)
        else:
            self.contract = ("fused",)
        p = bundle.pipeline
        feat_kwargs = pipeline_executor_kwargs(p.agg_features)
        self._agg_ids = feat_kwargs.pop("agg_ids")
        self._run = build_fused_executor(
            make_fused_model_fn(p), k=p.k, task=p.task,
            n_classes=max(p.n_classes, 2),
            m=config.m, m_sobol=config.m_sobol, alpha=config.alpha,
            gamma=config.gamma, tau=config.tau, max_iters=config.max_iters,
            n_boot=config.n_bootstrap, afc_backend=afc_backend,
            prebuilt=cached, **feat_kwargs,
        )

        # jit caches one executable per distinct (lanes, k, cap) input shape;
        # fixed lanes + power-of-two caps bound that to one per cap bucket.
        # The trace hook fires exactly once per cache miss (= per compile),
        # making the compile count observable without backend internals.
        self._compile_count = 0

        if cached:
            pre = build_afc_precompute(
                k=p.k, alpha=config.alpha, gamma=config.gamma,
                max_iters=config.max_iters,
                holistic=feat_kwargs["holistic"],
                quantiles=feat_kwargs["quantiles"],
                approximate=feat_kwargs["approximate"],
            )
            inner_cold = pre.cold

            def _counted_pre(vals, ns, agg_ids, delta, exacts, tables,
                             active, tau, iter_cap):
                self._compile_count += 1
                res = self._run(vals, ns, agg_ids, delta, exacts, tables,
                                active, tau, iter_cap)
                return res._replace(lane_vals=vals)

            def _counted_cold(vals, n):
                self._compile_count += 1
                return inner_cold(vals, n)

            self._batched = jax.jit(jax.vmap(_counted_pre),
                                    donate_argnums=(0,))
            self.cache = FeatureCache(
                bundle.store, jax.jit(_counted_cold), pre.refresh,
                maxsize=cache_size,
            )
        else:
            def _counted(vals, ns, agg_ids, delta, exacts, active, tau,
                         iter_cap):
                self._compile_count += 1
                res = self._run(vals, ns, agg_ids, delta, exacts, active, tau,
                                iter_cap)
                # thread the donated values buffer back out as lane state:
                # the identity passthrough becomes an XLA input-output alias,
                # so the (lanes, k, cap) buffer is neither copied per batch
                # nor kept alive twice (no-copy contract; see
                # shard_lanes_executor).
                return res._replace(lane_vals=vals)

            # the trace hook sits INSIDE the vmap/shard_map wrappers, so it
            # still fires exactly once per jit cache miss on the sharded path
            if mesh is not None:
                self._batched = shard_lanes_executor(
                    _counted, mesh, donate_vals=True
                )
            else:
                self._batched = jax.jit(jax.vmap(_counted), donate_argnums=(0,))
        self._caps_seen: set[int] = set()
        max_n = max(
            bundle.store[f.table].group_size(g)
            for f in p.agg_features
            for g in bundle.store[f.table].group_ids
        )
        self._max_cap = bucket_size(max_n)  # store-wide ceiling, not the default
        if max_cap is not None:
            self._max_cap = min(self._max_cap, bucket_size(max_cap))

    # ------------------------------------------------------------------
    @property
    def compiled_buckets(self) -> list[int]:
        """Cap buckets served so far (≤ log2(max_cap) entries ever)."""
        return sorted(self._caps_seen)

    @property
    def compile_count(self) -> int:
        """Executables built so far — must equal ``len(compiled_buckets)``."""
        return self._compile_count

    def check_compile_contract(self, *, buckets=None) -> None:
        """Assert observed compiles match the registered ``fused`` /
        ``sharded_lanes`` contract (one executable per cap bucket)."""
        assert_compile_contract(self, self.contract, buckets=buckets)

    def batch_cap(self, requests: list[dict]) -> int:
        """Power-of-two bucket over THIS batch's largest group."""
        p = self.bundle.pipeline
        max_n = max(
            int(p.group_sizes(self.bundle.store, req).max()) for req in requests
        )
        return min(bucket_size(max_n), self._max_cap)

    # ------------------------------------------------------------------
    def serve_batch(self, requests: list[dict], knobs=None) -> BatchResult:
        """Serve an admission batch of 0..batch_size requests.

        The batch is padded to exactly ``batch_size`` lanes; results are
        sliced back to the real requests before returning.  Oversize lists
        are rejected — admitting them would compile one executable per
        distinct oversize fill, breaking the fixed-lane no-recompile
        contract (callers chunk at admission time; serving/runtime.py does).

        ``knobs`` (optional, aligned with ``requests``) carries per-lane
        degradation settings — :class:`~repro.serving.degrade.LaneKnobs`
        entries (or ``None`` for the config defaults).  delta, tau, and the
        planner iteration cap are all *traced* ``(lanes,)`` inputs of the
        fused executor, so an SLO controller can vary them every batch
        without minting a new executable per cap bucket (the fixed-lane
        compile contract is knob-invariant; pad lanes carry the defaults).
        """
        p = self.bundle.pipeline
        store = self.bundle.store
        delta = (
            self.config.delta if self.config.delta is not None else p.delta_default
        )
        r = len(requests)
        if r > self.batch_size:
            raise ValueError(
                f"admission batch of {r} exceeds the fixed lane count "
                f"{self.batch_size}; chunk before dispatch"
            )
        if knobs is not None and len(knobs) != r:
            raise ValueError(
                f"knobs ({len(knobs)}) must align with requests ({r})"
            )
        if r == 0:
            empty = np.zeros((0,), np.float32)
            return BatchResult(
                y_hat=empty, prob=empty, iters=np.zeros((0,), np.int32),
                sample_frac=empty, batch_iters=0, cap=0, lanes=self.batch_size,
                z=np.zeros((0, p.k), np.int32), n_devices=self.n_devices,
            )
        lanes = self.batch_size
        cap = self.batch_cap(requests)
        true_ns = np.zeros((r, p.k), np.int64)
        exacts = np.zeros((lanes, len(p.exact_features)), np.float32)
        entries = None
        if self.cache is not None:
            # cached lanes: vals/n/tables come device-resident from the LRU;
            # only the cheap scalars (true sizes, exact features) touch host
            entries = []
            for i, req in enumerate(requests):
                entries.append(self.cache.get(p.agg_specs(req), cap))
                true_ns[i] = np.asarray(p.group_sizes(store, req), np.int64)
                exacts[i] = np.asarray(
                    p.exact_feature_values(store, req), np.float32
                )
                exacts[i] = sanitize_lane_inputs(
                    None, exacts[i], policy=self.sanitize,
                    where=f"serve_batch lane {i}",
                )[1]
        else:
            vals = np.zeros((lanes, p.k, cap), np.float32)
            ns = np.zeros((lanes, p.k), np.int32)
            for i, req in enumerate(requests):
                vals[i], ns[i], true_ns[i], exacts[i] = lane_request_inputs(
                    p, store, req, cap
                )
                vals[i], exacts[i] = sanitize_lane_inputs(
                    vals[i], exacts[i], policy=self.sanitize,
                    where=f"serve_batch lane {i}",
                )
        active = np.arange(lanes) < r
        # per-lane degradation knobs: traced data, never part of the cache
        # key (pad lanes + unknobbed requests get the config defaults)
        deltas = np.full((lanes,), delta, np.float32)
        taus = np.full((lanes,), self.config.tau, np.float32)
        caps = np.full((lanes,), self.config.max_iters, np.int32)
        if knobs is not None:
            for i, kn in enumerate(knobs):
                if kn is None:
                    continue
                deltas[i] = kn.delta
                taus[i] = kn.tau
                caps[i] = min(int(kn.iter_cap), self.config.max_iters)
        self._caps_seen.add(cap)
        if entries is not None:
            # pad lanes reuse the first entry (active=False predicates them
            # out); jnp.stack COPIES, so the donated stacked buffer can never
            # alias — and never corrupt — a live cache entry
            lane_entries = entries + [entries[0]] * (lanes - r)
            res = self._batched(
                jnp.stack([e.vals for e in lane_entries]),
                jnp.stack([e.n for e in lane_entries]),
                jnp.broadcast_to(self._agg_ids, (lanes, p.k)),
                jnp.asarray(deltas),
                jnp.asarray(exacts),
                jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs),
                    *[e.tables for e in lane_entries],
                ),
                jnp.asarray(active),
                jnp.asarray(taus),
                jnp.asarray(caps),
            )
        else:
            res = self._batched(
                jnp.asarray(vals),
                jnp.asarray(ns),
                jnp.broadcast_to(self._agg_ids, (lanes, p.k)),
                jnp.asarray(deltas),
                jnp.asarray(exacts),
                jnp.asarray(active),
                jnp.asarray(taus),
                jnp.asarray(caps),
            )
        iters = np.asarray(res.iters)[:r]
        return BatchResult(
            y_hat=np.asarray(res.y_hat)[:r],
            prob=np.asarray(res.prob)[:r],
            iters=iters,
            # paper §4 sample fraction: touched rows over TRUE group rows
            # (matches BiathlonServer.serve across modes; cap clipping only
            # shrinks the numerator)
            sample_frac=np.asarray(res.samples_used)[:r]
            / np.maximum(true_ns.sum(1), 1),
            batch_iters=int(iters.max(initial=0)),
            cap=cap,
            lanes=lanes,
            z=np.asarray(res.z)[:r],
            n_devices=self.n_devices,
        )
