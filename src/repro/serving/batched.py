"""Batched Biathlon serving: many concurrent requests in ONE XLA program.

The fused executor's state is fixed-shape, so a batch of requests vmaps
cleanly: each request carries its own sample buffers, group sizes, exact
features and delta; per-request early exit happens by predication inside
the shared while_loop (the loop runs until EVERY request in the admission
batch satisfies Eq. 1 or exhausts — the standard continuous-batching trade:
stragglers in a batch pay for each other, so admission batches should be
sized to the arrival rate).

This is the throughput-serving mode a TPU deployment would run: one
(R, k, cap) gather, one program, R guarantees.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor_fused import build_fused_executor
from repro.data.aggregates import AGG_IDS
from repro.data.store import bucket_size

__all__ = ["BatchedFusedServer"]


class BatchResult(NamedTuple):
    y_hat: np.ndarray
    prob: np.ndarray
    iters: np.ndarray
    sample_frac: np.ndarray


class BatchedFusedServer:
    """vmapped FusedExecutor over admission batches of requests."""

    def __init__(self, bundle, config, batch_size: int = 8):
        self.bundle = bundle
        self.config = config
        self.batch_size = batch_size
        p = bundle.pipeline
        unsupported = [f.agg for f in p.agg_features if f.agg not in AGG_IDS]
        if unsupported:
            raise ValueError(f"parametric aggregates only, got {unsupported}")
        mean = jnp.asarray(p.scaler_mean)
        scale = jnp.asarray(p.scaler_scale)
        model = p.model

        def model_fn(agg_rows, exact):
            m = agg_rows.shape[0]
            full = jnp.concatenate(
                [agg_rows, jnp.broadcast_to(exact[None, :], (m, exact.shape[0]))], 1
            )
            if mean.shape[0] == full.shape[1]:
                full = (full - mean[None, :]) / scale[None, :]
            return model.predict(full)

        run = build_fused_executor(
            model_fn, k=p.k, task=p.task, n_classes=max(p.n_classes, 2),
            m=config.m, m_sobol=config.m_sobol, alpha=config.alpha,
            gamma=config.gamma, tau=config.tau, max_iters=config.max_iters,
        )
        self._batched = jax.jit(jax.vmap(run))
        self._agg_ids = jnp.asarray([AGG_IDS[f.agg] for f in p.agg_features], jnp.int32)
        max_n = max(
            bundle.store[f.table].group_size(g)
            for f in p.agg_features
            for g in bundle.store[f.table].group_ids
        )
        self._cap = bucket_size(max_n)

    def serve_batch(self, requests: list[dict]) -> BatchResult:
        p = self.bundle.pipeline
        store = self.bundle.store
        delta = (
            self.config.delta if self.config.delta is not None else p.delta_default
        )
        r = len(requests)
        vals = np.zeros((r, p.k, self._cap), np.float32)
        ns = np.zeros((r, p.k), np.int32)
        exacts = np.zeros((r, len(p.exact_features)), np.float32)
        for i, req in enumerate(requests):
            v, _ = store.request_buffers(p.agg_specs(req), self._cap)
            vals[i] = np.asarray(v)
            ns[i] = np.minimum(p.group_sizes(store, req), self._cap)
            exacts[i] = p.exact_feature_values(store, req)
        res = self._batched(
            jnp.asarray(vals),
            jnp.asarray(ns),
            jnp.broadcast_to(self._agg_ids, (r, p.k)),
            jnp.full((r,), delta, jnp.float32),
            jnp.asarray(exacts),
        )
        return BatchResult(
            y_hat=np.asarray(res.y_hat),
            prob=np.asarray(res.prob),
            iters=np.asarray(res.iters),
            sample_frac=np.asarray(res.samples_used) / np.maximum(ns.sum(1), 1),
        )
