"""Batched Biathlon serving: many concurrent requests in ONE XLA program.

The fused executor's state is fixed-shape, so a batch of requests vmaps
cleanly: each request carries its own sample buffers, group sizes, exact
features and delta; per-request early exit happens by predication inside
the shared while_loop (the loop runs until EVERY request in the admission
batch satisfies Eq. 1 or exhausts — the standard continuous-batching trade:
stragglers in a batch pay for each other, so admission batches should be
sized to the arrival rate).

Buffer caps are *bucketed per admission batch*: the (R, k, cap) gather pads
to the next power of two above the BATCH's largest group, not the store-wide
worst case, so a batch of small-group requests does proportionally small AFC
work (power-of-two caps bound recompilation, the same trick
``HostLoopExecutor`` uses for its bucketed shapes).  Each bucket gets its own
compiled executor; ``straggler_report`` makes the batching trade measurable
(per-request iterations vs the batch's shared iteration count).

This is the throughput-serving mode a TPU deployment would run: one
(R, k, cap) gather, one program, R guarantees.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor_fused import build_fused_executor
from repro.data.aggregates import AGG_IDS
from repro.data.store import bucket_size

__all__ = ["BatchedFusedServer", "BatchResult", "straggler_report"]


class BatchResult(NamedTuple):
    y_hat: np.ndarray
    prob: np.ndarray
    iters: np.ndarray       # (R,) per-request planner iterations
    sample_frac: np.ndarray
    batch_iters: int        # shared while_loop trip count = max(iters)
    cap: int                # bucketed buffer cap used for this batch


def straggler_report(res: BatchResult) -> dict:
    """How much the admission batch paid for its slowest request.

    ``wasted_iters[i]`` counts loop trips request i sat through after its own
    guarantee was met (predicated no-ops that still burn compute in the
    shared program); ``wasted_frac`` is their share of the batch's total
    lane-iterations — the admission-sizing signal.
    """
    iters = np.asarray(res.iters)
    wasted = res.batch_iters - iters
    total = max(int(res.batch_iters) * len(iters), 1)
    return {
        "batch_iters": int(res.batch_iters),
        "per_request_iters": iters,
        "wasted_iters": wasted,
        "wasted_frac": float(wasted.sum()) / total,
        "straggler": int(np.argmax(iters)),
        "cap": int(res.cap),
    }


class BatchedFusedServer:
    """vmapped FusedExecutor over admission batches of requests.

    One compiled program per power-of-two cap bucket: the jit cache is keyed
    by the gathered (R, k, cap) shapes, so bucketing caps (and keeping
    admission batches at a fixed size) bounds the number of compilations
    while letting small-group batches skip the store-wide worst-case padding.
    """

    def __init__(self, bundle, config, batch_size: int = 8):
        self.bundle = bundle
        self.config = config
        self.batch_size = batch_size
        p = bundle.pipeline
        unsupported = [f.agg for f in p.agg_features if f.agg not in AGG_IDS]
        if unsupported:
            raise ValueError(f"parametric aggregates only, got {unsupported}")
        mean = jnp.asarray(p.scaler_mean)
        scale = jnp.asarray(p.scaler_scale)
        model = p.model

        def model_fn(agg_rows, exact):
            m = agg_rows.shape[0]
            full = jnp.concatenate(
                [agg_rows, jnp.broadcast_to(exact[None, :], (m, exact.shape[0]))], 1
            )
            if mean.shape[0] == full.shape[1]:
                full = (full - mean[None, :]) / scale[None, :]
            return model.predict(full)

        self._run = build_fused_executor(
            model_fn, k=p.k, task=p.task, n_classes=max(p.n_classes, 2),
            m=config.m, m_sobol=config.m_sobol, alpha=config.alpha,
            gamma=config.gamma, tau=config.tau, max_iters=config.max_iters,
        )
        # jit caches one executable per distinct (R, k, cap) input shape, so
        # power-of-two cap bucketing alone bounds compilations; the set just
        # makes the buckets observable.
        self._batched = jax.jit(jax.vmap(self._run))
        self._caps_seen: set[int] = set()
        self._agg_ids = jnp.asarray([AGG_IDS[f.agg] for f in p.agg_features], jnp.int32)
        max_n = max(
            bundle.store[f.table].group_size(g)
            for f in p.agg_features
            for g in bundle.store[f.table].group_ids
        )
        self._max_cap = bucket_size(max_n)  # store-wide ceiling, not the default

    # ------------------------------------------------------------------
    @property
    def compiled_buckets(self) -> list[int]:
        """Cap buckets served so far (≤ log2(max_cap) entries ever)."""
        return sorted(self._caps_seen)

    def batch_cap(self, requests: list[dict]) -> int:
        """Power-of-two bucket over THIS batch's largest group."""
        p = self.bundle.pipeline
        max_n = max(
            int(p.group_sizes(self.bundle.store, req).max()) for req in requests
        )
        return min(bucket_size(max_n), self._max_cap)

    # ------------------------------------------------------------------
    def serve_batch(self, requests: list[dict]) -> BatchResult:
        p = self.bundle.pipeline
        store = self.bundle.store
        delta = (
            self.config.delta if self.config.delta is not None else p.delta_default
        )
        r = len(requests)
        cap = self.batch_cap(requests)
        vals = np.zeros((r, p.k, cap), np.float32)
        ns = np.zeros((r, p.k), np.int32)
        exacts = np.zeros((r, len(p.exact_features)), np.float32)
        for i, req in enumerate(requests):
            v, _ = store.request_buffers(p.agg_specs(req), cap)
            vals[i] = np.asarray(v)
            ns[i] = np.minimum(p.group_sizes(store, req), cap)
            exacts[i] = p.exact_feature_values(store, req)
        self._caps_seen.add(cap)
        res = self._batched(
            jnp.asarray(vals),
            jnp.asarray(ns),
            jnp.broadcast_to(self._agg_ids, (r, p.k)),
            jnp.full((r,), delta, jnp.float32),
            jnp.asarray(exacts),
        )
        iters = np.asarray(res.iters)
        return BatchResult(
            y_hat=np.asarray(res.y_hat),
            prob=np.asarray(res.prob),
            iters=iters,
            sample_frac=np.asarray(res.samples_used) / np.maximum(ns.sum(1), 1),
            batch_iters=int(iters.max(initial=0)),
            cap=cap,
        )
