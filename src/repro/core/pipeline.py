"""Inference-pipeline definition (paper §2): feature prep operators + model.

A pipeline is a small DAG flattened into:

* ``agg_features``   — expensive datastore aggregations (the ones Biathlon
  approximates; SUM/COUNT/AVG/VAR/STD/MEDIAN/QUANTILE over a request-selected
  group of rows),
* ``exact_features`` — lightweight ops computed exactly: point lookups
  (indexed datastore access) and request-provided scalars,
* a transformation stage (standard scaling — the paper's pipelines use
  sklearn ``StandardScaler``-style transforms; they are cheap and exact),
* the model-inference operator (any jittable ``(n, D) -> (n,)`` predictor).

Feature vector layout is ``[agg features..., exact features...]`` — the model
closure used by AMI / Sobol-index estimation tiles the exact part and varies
only the aggregate part, which is the paper's setup (only aggregation
features carry uncertainty).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.data.store import ColumnStore

__all__ = [
    "AggFeature",
    "ExactFeature",
    "Pipeline",
    "make_model_fn",
    "make_fused_model_fn",
]


@dataclass(frozen=True)
class AggFeature:
    """An expensive aggregation feature over a request-selected row group."""

    name: str
    table: str
    column: str
    agg: str                  # sum | count | avg | var | std | median | quantile
    group_field: str          # request field selecting the group (e.g. "user_id")
    quantile: float = 0.5
    approximate: bool = True  # False -> always computed exactly (Fig. 10 knob)


@dataclass(frozen=True)
class ExactFeature:
    """A cheap, exactly-computed feature."""

    name: str
    kind: str                 # "lookup" | "request"
    table: str = ""
    column: str = ""
    group_field: str = ""     # for lookups
    request_field: str = ""   # for request passthroughs
    transform: str = "id"     # id | log1p  (lightweight transformation ops)


@dataclass
class Pipeline:
    """A runnable inference pipeline (Table 1 row equivalent)."""

    name: str
    agg_features: Sequence[AggFeature]
    exact_features: Sequence[ExactFeature]
    model: Any                      # TabularModel: .predict(X) jittable
    task: str                       # "regression" | "classification"
    n_classes: int = 0
    # StandardScaler params over the full feature vector (fit at train time).
    scaler_mean: np.ndarray = field(default_factory=lambda: np.zeros(0, np.float32))
    scaler_scale: np.ndarray = field(default_factory=lambda: np.ones(0, np.float32))
    # Default error bound: MAE of the trained model on held-out data (paper §4).
    delta_default: float = 0.0

    @property
    def k(self) -> int:
        return len(self.agg_features)

    @property
    def n_features(self) -> int:
        return len(self.agg_features) + len(self.exact_features)

    # ------------------------------------------------------------------
    def exact_feature_values(self, store: ColumnStore, request: dict) -> np.ndarray:
        out = np.zeros((len(self.exact_features),), np.float32)
        for i, f in enumerate(self.exact_features):
            if f.kind == "lookup":
                v = store[f.table].lookup(f.column, request[f.group_field])
            elif f.kind == "request":
                v = float(request[f.request_field])
            else:  # pragma: no cover - config error
                raise ValueError(f"unknown exact-feature kind {f.kind!r}")
            if f.transform == "log1p":
                v = float(np.log1p(max(v, 0.0)))
            out[i] = v
        return out

    def agg_specs(self, request: dict) -> list[tuple[str, str, int]]:
        return [
            (f.table, f.column, int(request[f.group_field]))
            for f in self.agg_features
        ]

    def group_sizes(self, store: ColumnStore, request: dict) -> np.ndarray:
        return np.array(
            [
                store[f.table].group_size(int(request[f.group_field]))
                for f in self.agg_features
            ],
            np.int64,
        )


def make_model_fn(
    pipeline: Pipeline, exact_vals: np.ndarray
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Close over the request's exact features: ``(m, k) aggs -> (m,) preds``.

    This is the black-box ``M`` that AMI (propagation) and the Sobol-index
    estimator batch-evaluate; scaling is folded in so the closure is the
    *whole* downstream pipeline after aggregation.
    """
    mean = jnp.asarray(pipeline.scaler_mean, jnp.float32)
    scale = jnp.asarray(pipeline.scaler_scale, jnp.float32)
    exact = jnp.asarray(exact_vals, jnp.float32)
    k = pipeline.k

    def model_fn(agg_x: jnp.ndarray) -> jnp.ndarray:
        m = agg_x.shape[0]
        full = jnp.concatenate(
            [agg_x, jnp.broadcast_to(exact[None, :], (m, exact.shape[0]))], axis=1
        )
        if mean.shape[0] == full.shape[1]:
            full = (full - mean[None, :]) / scale[None, :]
        return pipeline.model.predict(full)

    return model_fn


def make_fused_model_fn(pipeline: Pipeline):
    """Request-agnostic model closure for the fused executors.

    ``(agg_rows (m, k), exact (e,)) -> (m,) preds`` — the exact features are
    data (per request/lane), not a closure constant, so ONE compiled
    executor serves every request of the pipeline.  Shared by
    ``BiathlonServer`` (fused mode) and ``BatchedFusedServer``.
    """
    mean = jnp.asarray(pipeline.scaler_mean, jnp.float32)
    scale = jnp.asarray(pipeline.scaler_scale, jnp.float32)
    model = pipeline.model

    def model_fn(agg_rows: jnp.ndarray, exact: jnp.ndarray) -> jnp.ndarray:
        m = agg_rows.shape[0]
        full = jnp.concatenate(
            [agg_rows, jnp.broadcast_to(exact[None, :], (m, exact.shape[0]))], 1
        )
        if mean.shape[0] == full.shape[1]:
            full = (full - mean[None, :]) / scale[None, :]
        return model.predict(full)

    return model_fn
