"""First-order Sobol' main-effect indices via the Saltelli QMC estimator.

Paper §3.4: the Planner needs, per feature j, the share of inference-result
variance attributable to feature j's uncertainty,

    I_j = Var_{X_j}( E_{¬X_j}[ Y | X_j ] ) / Var(Y).

We use the Saltelli (2002/2010) pick-freeze scheme the paper cites ([68]):
draw two (m, k) QMC matrices A and B, plus k hybrids AB_j (A with column j
replaced from B), and estimate

    V_j    = 1/m Σ_i f(B)_i · ( f(AB_j)_i − f(A)_i )        (first-order)
    Var(Y) = var over all f evaluations.

All m·(k+2) model evaluations are stacked into ONE batched call — on TPU this
is a single pass through the (tensorized) model, which is the whole point of
the kernelized tree/MLP inference in ``repro.kernels``.

For classification pipelines, Y is a class id; variance decomposition is
performed on the *agreement indicator* f = 1[M(x) == ŷ] (Bernoulli), whose
variance p(1−p) is exactly the quantity the planner drives down (the paper's
``Var(Y|z)`` for discrete outputs).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.propagation import qmc_uniforms
from repro.core.uncertainty import FeatureUncertainty, sample_features

__all__ = ["SobolEstimate", "main_effect_indices"]


class SobolEstimate(NamedTuple):
    indices: jnp.ndarray   # (k,) first-order main-effect indices, clipped to [0, 1]
    var_y: jnp.ndarray     # () total variance of f across all evaluations
    n_evals: int           # m * (k + 2)


def _build_eval_matrix(unc: FeatureUncertainty, m: int, key) -> jnp.ndarray:
    """Stack [A; B; AB_1; ...; AB_k] feature samples: ((k+2)*m, k)."""
    k = unc.k
    u = qmc_uniforms(m, 2 * k, key)          # (m, 2k)
    ua, ub = u[:, :k], u[:, k:]
    xa = sample_features(unc, ua)            # (m, k)
    xb = sample_features(unc, ub)            # (m, k)
    eye = jnp.eye(k, dtype=bool)             # (k, k)
    # AB_j: column j from B, the rest from A -> (k, m, k)
    xab = jnp.where(eye[:, None, :], xb[None, :, :], xa[None, :, :])
    return jnp.concatenate([xa, xb, xab.reshape(k * m, k)], axis=0)


def main_effect_indices(
    model_fn: Callable[[jnp.ndarray], jnp.ndarray],
    unc: FeatureUncertainty,
    m: int,
    key: jax.Array | None = None,
    *,
    task: str = "regression",
    y_hat: jnp.ndarray | None = None,
) -> SobolEstimate:
    """Estimate first-order indices with one batched model call.

    model_fn: ``(n, k) -> (n,)`` (float for regression, int class ids for
    classification — converted to the agreement indicator internally).
    """
    k = unc.k
    x_all = _build_eval_matrix(unc, m, key)          # ((k+2) m, k)
    f_all = model_fn(x_all)
    if task == "classification":
        if y_hat is None:
            raise ValueError("classification indices need y_hat")
        f_all = (f_all.astype(jnp.int32) == y_hat.astype(jnp.int32))
    f_all = f_all.astype(jnp.float32).reshape((k + 2) * m)

    # Center f before the pick-freeze product: with an uncentered f the
    # estimator's variance scales with E[f]^2 (a y~16 mean drowns a sd~0.1
    # signal at m=O(100)) — centering is the standard Saltelli practice.
    f_all = f_all - jnp.mean(f_all)
    fa = f_all[:m]
    fb = f_all[m : 2 * m]
    fab = f_all[2 * m :].reshape(k, m)

    var_y = jnp.var(f_all)
    # Saltelli 2010 first-order estimator.
    v_j = jnp.mean(fb[None, :] * (fab - fa[None, :]), axis=1)  # (k,)
    safe_var = jnp.maximum(var_y, 1e-12)
    idx = jnp.clip(v_j / safe_var, 0.0, 1.0)
    # If total variance is ~0 nothing matters; report zeros.
    idx = jnp.where(var_y <= 1e-12, jnp.zeros_like(idx), idx)
    return SobolEstimate(indices=idx, var_y=var_y, n_evals=(k + 2) * m)
