"""Quasi-Monte-Carlo primitives: Sobol low-discrepancy sequences in pure JAX.

Biathlon's AMI stage (paper §3.3) and the Sobol'-Saltelli index estimator
(paper §3.4) both draw *low-discrepancy* feature samples so that ``m`` model
evaluations converge like ~1/m instead of ~1/sqrt(m).  This module provides

* :func:`sobol_sequence` — the raw Sobol sequence, bit-exact with
  ``scipy.stats.qmc.Sobol(scramble=False)`` (validated in tests),
* :func:`digital_shift` — cheap randomization (XOR shift) preserving the
  low-discrepancy structure, used to decorrelate repeated planner iterations,
* :func:`uniform_to_normal` — inverse-CDF transform.

The default TPU execution path for large ``(m, d)`` grids is the Pallas kernel
in ``repro.kernels.sobol``; this module is the reference/portable path (the
kernel's ``ref.py`` re-exports from here).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sobol_tables import BITS, DIRECTION_NUMBERS, MAX_DIM

__all__ = [
    "sobol_sequence",
    "sobol_uint32",
    "digital_shift",
    "uniform_to_normal",
    "normal_qmc_samples",
]


def _direction_numbers(dim: int) -> jnp.ndarray:
    if dim > MAX_DIM:
        raise ValueError(
            f"sobol_sequence supports up to {MAX_DIM} dimensions, got {dim} "
            "(the paper's pipelines use at most 21 aggregate features; "
            "extend sobol_tables.py if you need more)"
        )
    return jnp.asarray(DIRECTION_NUMBERS[:dim], dtype=jnp.uint32)  # (d, 32)


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def sobol_uint32(n: int, dim: int, skip: int = 0) -> jnp.ndarray:
    """Raw Sobol points as uint32 integers, shape ``(n, dim)``.

    Uses the *direct* (non-recursive) gray-code construction so the whole grid
    is computed in parallel — point ``i`` is the XOR over set bits ``b`` of
    ``gray(i) = i ^ (i >> 1)`` of direction number ``v[dim, b]``.  This maps
    onto the TPU VPU as 32 masked XOR steps with no sequential dependence on
    ``n`` (unlike the classic one-point-at-a-time recurrence).
    """
    sv = _direction_numbers(dim)  # (dim, 32)
    idx = jnp.arange(skip, skip + n, dtype=jnp.uint32)
    gray = idx ^ (idx >> 1)  # (n,)
    out = jnp.zeros((n, dim), dtype=jnp.uint32)
    for b in range(BITS):
        bit = ((gray >> b) & 1).astype(bool)  # (n,)
        out = jnp.where(bit[:, None], out ^ sv[None, :, b], out)
    return out


def sobol_sequence(
    n: int, dim: int, skip: int = 0, *, shift_half: bool = True
) -> jnp.ndarray:
    """Sobol points in [0, 1), shape ``(n, dim)``, float32.

    ``shift_half=True`` adds the half-integer offset ``(x + 0.5) / 2**32`` so
    the first point is not exactly 0 (which would map to -inf under the
    normal inverse CDF).  ``shift_half=False`` reproduces scipy bit-exactly.
    """
    x = sobol_uint32(n, dim, skip)
    u = x.astype(jnp.float32) * jnp.float32(2.0**-32)
    if shift_half:
        u = u + jnp.float32(0.5 * 2.0**-32)
    return u


def digital_shift(key: jax.Array, points: jnp.ndarray) -> jnp.ndarray:
    """Random digital (XOR) shift of raw uint32 Sobol points.

    A digital shift preserves the (t, m, s)-net structure of the sequence
    while randomizing it, giving unbiased randomized-QMC estimates across
    planner iterations.  ``points`` must be the uint32 grid from
    :func:`sobol_uint32`.
    """
    shift = jax.random.bits(key, (points.shape[-1],), dtype=jnp.uint32)
    return points ^ shift[None, :]


def uniform_to_normal(u: jnp.ndarray) -> jnp.ndarray:
    """Inverse-CDF transform of uniforms in (0,1) to standard normals."""
    # Clamp away from {0, 1} to keep ndtri finite in float32.
    eps = jnp.float32(1e-7)
    u = jnp.clip(u, eps, 1.0 - eps)
    return jax.scipy.special.ndtri(u).astype(jnp.float32)


def normal_qmc_samples(
    n: int, dim: int, key: jax.Array | None = None, skip: int = 0
) -> jnp.ndarray:
    """``(n, dim)`` standard-normal QMC samples (optionally digitally shifted)."""
    x = sobol_uint32(n, dim, skip)
    if key is not None:
        x = digital_shift(key, x)
    u = x.astype(jnp.float32) * jnp.float32(2.0**-32) + jnp.float32(0.5 * 2.0**-32)
    return uniform_to_normal(u)


def discrepancy_proxy(points: np.ndarray) -> float:
    """Cheap L2-star discrepancy proxy used by property tests.

    Exact star discrepancy is exponential; the Warnock formula for the L2-star
    discrepancy is O(n^2 d) and fine at test sizes.
    """
    pts = np.asarray(points, dtype=np.float64)
    n, d = pts.shape
    # Warnock: D2*^2 = 3^-d - (2^{1-d}/n) sum_i prod_k (1 - x_ik^2)
    #                + (1/n^2) sum_ij prod_k (1 - max(x_ik, x_jk))
    t1 = (2.0 ** (1 - d) / n) * np.prod(1.0 - pts**2, axis=1).sum()
    t2 = np.prod(1.0 - np.maximum(pts[:, None, :], pts[None, :, :]), axis=2).sum() / n**2
    return float(3.0**-d - t1 + t2)
