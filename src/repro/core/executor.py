"""Biathlon executors: the Planner ⇄ Executor feedback loop (paper §3.1).

Two implementations of the same algorithm:

* :class:`HostLoopExecutor` — **paper-faithful**: a Python feedback loop
  calling jitted AFC/AMI/Planner stages with *bucketed* sample buffers
  (power-of-two caps bound recompilation while compute tracks the live
  sample size, like an actual online-aggregation scan).  This is the
  reproduction baseline recorded in EXPERIMENTS.md.

* :class:`FusedExecutor` (in ``executor_fused.py``) — beyond-paper TPU
  adaptation: the whole iterate-until-guaranteed loop as one
  ``jax.lax.while_loop`` program over prefix-masked buffers.

Algorithm per request (paper Fig. 3):

    z ← ceil(α·N)
    loop:
        AFC:  x̂, U_x  ← online-aggregation estimates at plan z
        AMI:  ŷ, U_y  ← QMC uncertainty propagation (m samples)
        if Pr(|Y−ŷ| ≤ δ) ≥ τ:  return ŷ
        I  ← Sobol main-effect indices (Saltelli, QMC)
        z  ← min(z + γ·onehot(argmax_j I_j/(N_j−z_j)), N)
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import guarantee, planner
from repro.core.pipeline import Pipeline, make_model_fn
from repro.core.propagation import (
    propagate_classification,
    propagate_regression,
)
from repro.core.sobol_indices import main_effect_indices
from repro.core.uncertainty import FeatureUncertainty
from repro.data import aggregates
from repro.data.store import ColumnStore, bucket_size

__all__ = ["BiathlonConfig", "RequestResult", "HostLoopExecutor", "run_exact"]


@dataclass(frozen=True)
class BiathlonConfig:
    """Default configuration = the paper's §4 defaults."""

    alpha: float = 0.05        # initial sampling ratio
    gamma: float = 0.01        # step size as fraction of Σ N_j
    tau: float = 0.95          # confidence level
    delta: float | None = None  # error bound; None -> pipeline.delta_default
    m: int = 1000              # QMC samples for AMI
    m_sobol: int = 256         # QMC base samples for Saltelli indices
    n_bootstrap: int = 256     # bootstrap replicates for holistic aggs
    max_iters: int = 64        # safety cap (loop provably terminates at z=N)
    batch_afc: bool = True     # §Perf: one fused AFC call for parametric
                               # features + cached buffers (False = naive
                               # per-feature dispatch, the original baseline)
    adaptive_ami: bool = False  # §Perf (beyond-paper): screen with m/8 QMC
                                # samples; pay full m only when the coarse
                                # prob lands inside (tau-margin, tau+margin).
                                # Conservative: coarse PASS still requires
                                # prob >= tau + margin.
    ami_margin: float = 0.04


@dataclass
class RequestResult:
    y_hat: float
    prob: float
    satisfied: bool
    iters: int
    samples_used: int
    samples_total: int
    z: np.ndarray
    n: np.ndarray
    t_afc: float = 0.0
    t_ami: float = 0.0
    t_planner: float = 0.0
    t_total: float = 0.0

    @property
    def sample_fraction(self) -> float:
        return self.samples_used / max(self.samples_total, 1)


class HostLoopExecutor:
    """Paper-faithful iterative executor (dynamic plans, bucketed shapes)."""

    def __init__(self, store: ColumnStore, config: BiathlonConfig | None = None):
        self.store = store
        self.config = config or BiathlonConfig()

    # --- AFC ---------------------------------------------------------------
    def _afc(
        self,
        pipeline: Pipeline,
        request: dict,
        z: np.ndarray,
        n: np.ndarray,
        key: jax.Array,
        buffers: dict | None = None,
    ) -> FeatureUncertainty:
        """Approximate Feature Computation at plan ``z``.

        ``buffers`` is a per-request cache {j: (cap, np_buffer)} — incremental
        sampling means a wider prefix of the SAME buffer, so we only re-gather
        a feature when its bucket grows (paper §3.2's no-repeated-access
        property, preserved across planner iterations).
        """
        cfg = self.config
        if not cfg.batch_afc:
            return self._afc_naive(pipeline, request, z, n, key)
        k = pipeline.k
        zs = np.where(
            [f.approximate for f in pipeline.agg_features], np.minimum(z, n), n
        ).astype(np.int64)
        cap = bucket_size(int(max(zs.max(), 1)))
        buffers = buffers if buffers is not None else {}
        # (k, cap) stacked buffers; re-gather only on bucket growth
        if buffers.get("cap", 0) < cap:
            stack = np.zeros((k, cap), np.float32)
            for j, f in enumerate(pipeline.agg_features):
                stack[j] = self.store[f.table].sample_prefix(
                    f.column, int(request[f.group_field]), cap
                )
            buffers["cap"] = cap
            buffers["stack"] = stack
        stack = buffers["stack"][:, : buffers["cap"]]

        param_idx = [
            j for j, f in enumerate(pipeline.agg_features)
            if f.agg in aggregates.PARAMETRIC_AGGS
        ]
        hol_idx = [j for j in range(k) if j not in param_idx]

        value = np.zeros((k,), np.float32)
        sigma = np.zeros((k,), np.float32)
        reps = np.zeros((k, cfg.n_bootstrap), np.float32)
        emp = np.zeros((k,), bool)

        if param_idx:
            ids = jnp.asarray(
                [aggregates.AGG_IDS[pipeline.agg_features[j].agg] for j in param_idx],
                jnp.int32,
            )
            v, s = aggregates.masked_estimates_batch(
                jnp.asarray(stack[param_idx]),
                jnp.asarray(zs[param_idx], jnp.int32),
                jnp.asarray(n[param_idx], jnp.int32),
                ids,
            )
            value[param_idx] = np.asarray(v)
            sigma[param_idx] = np.asarray(s)
            reps[param_idx] = value[param_idx, None]

        keys = jax.random.split(key, max(len(hol_idx), 1))
        for i, j in enumerate(hol_idx):
            f = pipeline.agg_features[j]
            res = aggregates.estimate(
                f.agg,
                jnp.asarray(stack[j]),
                jnp.asarray(int(zs[j]), jnp.int32),
                jnp.asarray(int(n[j]), jnp.int32),
                keys[i],
                n_boot=cfg.n_bootstrap,
                quantile=f.quantile,
            )
            value[j] = float(res.value)
            sigma[j] = float(res.sigma)
            reps[j] = np.asarray(res.replicates)
            emp[j] = bool(res.is_empirical)

        return FeatureUncertainty(
            value=jnp.asarray(value),
            sigma=jnp.asarray(sigma),
            replicates=jnp.asarray(reps),
            is_empirical=jnp.asarray(emp),
        )

    def _afc_naive(
        self,
        pipeline: Pipeline,
        request: dict,
        z: np.ndarray,
        n: np.ndarray,
        key: jax.Array,
    ) -> FeatureUncertainty:
        """Original per-feature dispatch path (the §Perf 'before')."""
        cfg = self.config
        vals, sigmas, reps, emps = [], [], [], []
        keys = jax.random.split(key, pipeline.k)
        for j, f in enumerate(pipeline.agg_features):
            # non-approximated operators (Fig. 10 ablation) are always exact
            zj = int(min(z[j], n[j])) if f.approximate else int(n[j])
            cap = bucket_size(max(zj, 1))
            buf = self.store[f.table].sample_prefix(
                f.column, int(request[f.group_field]), cap
            )
            res = aggregates.estimate(
                f.agg,
                jnp.asarray(buf),
                jnp.asarray(zj, jnp.int32),
                jnp.asarray(int(n[j]), jnp.int32),
                keys[j],
                n_boot=cfg.n_bootstrap,
                quantile=f.quantile,
            )
            vals.append(res.value)
            sigmas.append(res.sigma)
            reps.append(res.replicates)
            emps.append(res.is_empirical)
        return FeatureUncertainty(
            value=jnp.stack(vals),
            sigma=jnp.stack(sigmas),
            replicates=jnp.stack(reps),
            is_empirical=jnp.stack(emps),
        )

    # --- full request ---------------------------------------------------
    def run(
        self, pipeline: Pipeline, request: dict, key: jax.Array | None = None
    ) -> RequestResult:
        cfg = self.config
        key = key if key is not None else jax.random.PRNGKey(0)
        delta = cfg.delta if cfg.delta is not None else pipeline.delta_default
        if pipeline.task == "classification" and delta != 0.0:
            raise ValueError("classification pipelines require delta == 0 (paper §3)")

        t0 = time.perf_counter()
        n = pipeline.group_sizes(self.store, request)
        exact_vals = pipeline.exact_feature_values(self.store, request)
        model_fn = make_model_fn(pipeline, exact_vals)
        z = np.asarray(planner.initial_plan(jnp.asarray(n), cfg.alpha))
        approx = np.array([f.approximate for f in pipeline.agg_features])
        z = np.where(approx, z, n)  # exact-only operators consume full groups
        step = int(planner.gamma_abs(jnp.asarray(n), cfg.gamma))

        t_afc = t_ami = t_plan = 0.0
        it = 0
        prob = 0.0
        y_hat = 0.0
        buffers: dict = {}
        while True:
            it += 1
            key, k_afc, k_ami, k_sob = jax.random.split(key, 4)

            t = time.perf_counter()
            unc = self._afc(pipeline, request, z, n, k_afc, buffers)
            jax.block_until_ready(unc.value)
            t_afc += time.perf_counter() - t

            t = time.perf_counter()

            def _propagate(m_samples):
                if pipeline.task == "regression":
                    return propagate_regression(model_fn, unc, m_samples, k_ami)
                return propagate_classification(
                    model_fn, unc, m_samples, pipeline.n_classes, k_ami
                )

            if cfg.adaptive_ami:
                infu = _propagate(max(cfg.m // 8, 64))
                prob_j, _ = guarantee.satisfied(infu, delta, cfg.tau, pipeline.task)
                coarse = float(prob_j)
                if abs(coarse - cfg.tau) <= cfg.ami_margin:
                    infu = _propagate(cfg.m)          # uncertain band: full m
                    prob_j, _ = guarantee.satisfied(
                        infu, delta, cfg.tau, pipeline.task
                    )
                prob = float(prob_j)
                ok = prob >= cfg.tau
            else:
                infu = _propagate(cfg.m)
                prob_j, ok = guarantee.satisfied(infu, delta, cfg.tau, pipeline.task)
                prob = float(prob_j)
            y_hat = float(infu.y_hat)
            t_ami += time.perf_counter() - t

            exhausted = bool(np.all(z >= n))
            if bool(ok) or exhausted or it >= cfg.max_iters:
                break

            t = time.perf_counter()
            est = main_effect_indices(
                model_fn,
                unc,
                cfg.m_sobol,
                k_sob,
                task=pipeline.task,
                y_hat=jnp.asarray(y_hat, jnp.float32),
            )
            d = planner.direction(est.indices, jnp.asarray(z), jnp.asarray(n))
            z = np.asarray(planner.next_plan(jnp.asarray(z), d, step, jnp.asarray(n)))
            t_plan += time.perf_counter() - t

        t_total = time.perf_counter() - t0
        return RequestResult(
            y_hat=y_hat,
            prob=prob,
            satisfied=bool(prob >= cfg.tau) or bool(np.all(z >= n)),
            iters=it,
            samples_used=int(np.minimum(z, n).sum()),
            samples_total=int(n.sum()),
            z=np.minimum(z, n),
            n=n,
            t_afc=t_afc,
            t_ami=t_ami,
            t_planner=t_plan,
            t_total=t_total,
        )


def run_exact(
    store: ColumnStore, pipeline: Pipeline, request: dict
) -> tuple[float, float]:
    """The unoptimized baseline: every aggregate over ALL rows.

    Returns (prediction, wall_seconds).  This is `Y` in Eq. 1 and the
    denominator of every speedup number in §4.
    """
    t0 = time.perf_counter()
    feats = []
    for f in pipeline.agg_features:
        gid = int(request[f.group_field])
        n = store[f.table].group_size(gid)
        cap = bucket_size(n)  # bucketed buffer -> jit caches across requests
        buf = store[f.table].sample_prefix(f.column, gid, cap)
        res = aggregates.estimate(
            f.agg,
            jnp.asarray(buf),
            jnp.asarray(n, jnp.int32),
            jnp.asarray(n, jnp.int32),
            jax.random.PRNGKey(0),
            n_boot=8,
            quantile=f.quantile,
        )
        feats.append(float(res.value))
    exact_vals = pipeline.exact_feature_values(store, request)
    model_fn = make_model_fn(pipeline, exact_vals)
    y = model_fn(jnp.asarray(feats, jnp.float32)[None, :])
    y = float(np.asarray(y).reshape(()))
    return y, time.perf_counter() - t0
