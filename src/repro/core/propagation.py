"""Approximate Model Inference (AMI): QMC uncertainty propagation (paper §3.3).

Given approximate features ``x̂`` with uncertainty ``U_x``, estimate the
distribution of the *exact* inference result ``Y`` by

1. drawing ``m`` low-discrepancy feature samples ``x^i ~ x̂ + U_x``,
2. running the model on all of them **in one batch** (the paper runs them in
   parallel; on TPU this is a single (m, k) matmul-shaped call),
3. fitting Normal(ȳ, σ_y²) for regression / Categorical(p) for
   classification,
4. deriving the inference uncertainty ``U_y = Y − ŷ``.

The model is a black box: any callable ``(m, k) -> (m,)`` (regression) or
``(m, k) -> (m,) int / (m, C) logits`` (classification) works — this is what
makes Biathlon model-agnostic (LR, MLP, forests, GBDTs, LM heads, ...).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.qmc import sobol_uint32, digital_shift, uniform_to_normal
from repro.core.uncertainty import FeatureUncertainty, sample_features

__all__ = ["InferenceUncertainty", "propagate_regression", "propagate_classification", "qmc_uniforms"]


class InferenceUncertainty(NamedTuple):
    """Distribution of Y and of U_y = Y - ŷ (paper §3.3 step 3-4)."""

    y_hat: jnp.ndarray       # () — M(x̂), the returned approximate result
    mean: jnp.ndarray        # () — ȳ (regression) or p_ŷ (classification)
    std: jnp.ndarray         # () — σ_y (regression; 0 for classification)
    probs: jnp.ndarray       # (C,) — class probabilities (classification; [] for regression)
    samples: jnp.ndarray     # (m,) — raw y^i inference samples (for diagnostics / KDE)


def qmc_uniforms(m: int, dim: int, key: jax.Array | None = None) -> jnp.ndarray:
    """(m, dim) low-discrepancy uniforms with optional digital shift."""
    x = sobol_uint32(m, dim, 0)
    if key is not None:
        x = digital_shift(key, x)
    return x.astype(jnp.float32) * jnp.float32(2.0**-32) + jnp.float32(
        0.5 * 2.0**-32
    )


def propagate_regression(
    model_fn: Callable[[jnp.ndarray], jnp.ndarray],
    unc: FeatureUncertainty,
    m: int,
    key: jax.Array | None = None,
) -> InferenceUncertainty:
    """Regression: Y ~ N(ȳ, σ_y²); U_y ~ N(ȳ − ŷ, σ_y²)."""
    u = qmc_uniforms(m, unc.k, key)
    x = sample_features(unc, u)                       # (m, k)
    # one batched model call covers the m QMC rows AND the point estimate
    # (row m) — halves the dispatch count per AMI stage (§Perf, serving)
    x_all = jnp.concatenate([x, unc.value[None, :]], axis=0)
    y_all = model_fn(x_all).astype(jnp.float32).reshape(m + 1)
    y, y_hat = y_all[:m], y_all[m]
    y_bar = jnp.mean(y)
    # Paper's σ_y² uses deviations from ŷ: E[(Y − ȳ)²] ≃ 1/m Σ (y_i − ŷ)²;
    # we follow the (standard) centered second moment around ȳ and carry the
    # bias term (ȳ − ŷ) explicitly in the guarantee check, which is equivalent
    # and numerically better behaved.
    sigma = jnp.sqrt(jnp.mean((y - y_bar) ** 2))
    return InferenceUncertainty(
        y_hat=y_hat,
        mean=y_bar,
        std=sigma,
        probs=jnp.zeros((0,), jnp.float32),
        samples=y,
    )


def propagate_classification(
    model_fn: Callable[[jnp.ndarray], jnp.ndarray],
    unc: FeatureUncertainty,
    m: int,
    n_classes: int,
    key: jax.Array | None = None,
) -> InferenceUncertainty:
    """Classification: Y ~ Categorical(p); U_y ~ Bernoulli(1 − p_ŷ).

    ``model_fn`` must return hard class ids ``(m,) int32`` (the guarantee is
    about the *decided* class, matching the paper's δ=0 requirement).
    """
    u = qmc_uniforms(m, unc.k, key)
    x = sample_features(unc, u)
    x_all = jnp.concatenate([x, unc.value[None, :]], axis=0)
    y_all = model_fn(x_all).astype(jnp.int32).reshape(m + 1)
    y, y_hat = y_all[:m], y_all[m]
    probs = jnp.bincount(y, length=n_classes).astype(jnp.float32) / m
    p_yhat = probs[y_hat]
    return InferenceUncertainty(
        y_hat=y_hat.astype(jnp.float32),
        mean=p_yhat,
        std=jnp.zeros((), jnp.float32),
        probs=probs,
        samples=y.astype(jnp.float32),
    )
