"""Feature-uncertainty representation (paper §3.2, ``U_x``).

Biathlon represents the error distribution of every approximated aggregate
feature explicitly (not just a scalar stddev) so that the AMI stage can draw
feature samples from it.  Two families are supported, exactly as in the paper:

* **parametric** — Normal(0, sigma) errors for SUM / COUNT / AVG / VAR / STD
  (CLT, following Mozafari & Niu [53]); sampling uses the inverse normal CDF;
* **empirical** — bootstrap replicate tables for holistic aggregates
  (MEDIAN / QUANTILE, paper appendix D); sampling uses the replicate
  empirical inverse CDF.

Both are packed into one fixed-shape struct so a *batch of heterogeneous
features* is a single PyTree of arrays — jittable, vmappable, and usable
inside ``lax.while_loop`` (the fused executor) and in the multi-pod dry-run.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.qmc import uniform_to_normal

__all__ = [
    "FeatureUncertainty",
    "sample_features",
    "sample_features_fused",
    "exact_uncertainty",
]


class FeatureUncertainty(NamedTuple):
    """Uncertainty of ``k`` features, fixed shapes (k,), (k, B).

    value:        point estimate x̂ per feature.
    sigma:        Normal error stddev (0 when exact or empirical).
    replicates:   sorted bootstrap replicates per feature (value-padded when
                  parametric, so gathering from them is always safe).
    is_empirical: which features use the replicate table.
    """

    value: jnp.ndarray        # (k,) float32
    sigma: jnp.ndarray        # (k,) float32
    replicates: jnp.ndarray   # (k, B) float32, sorted along B
    is_empirical: jnp.ndarray  # (k,) bool

    @property
    def k(self) -> int:
        return self.value.shape[-1]

    @property
    def n_replicates(self) -> int:
        return self.replicates.shape[-1]

    def effective_std(self) -> jnp.ndarray:
        """Stddev of the error distribution regardless of representation."""
        emp_std = jnp.std(self.replicates, axis=-1)
        return jnp.where(self.is_empirical, emp_std, self.sigma)


def exact_uncertainty(values: jnp.ndarray, n_replicates: int = 1) -> FeatureUncertainty:
    """Zero-uncertainty wrapper for exactly-computed features."""
    values = jnp.asarray(values, jnp.float32)
    k = values.shape[-1]
    return FeatureUncertainty(
        value=values,
        sigma=jnp.zeros((k,), jnp.float32),
        replicates=jnp.broadcast_to(values[:, None], (k, n_replicates)).astype(
            jnp.float32
        ),
        is_empirical=jnp.zeros((k,), bool),
    )


def sample_features(unc: FeatureUncertainty, u: jnp.ndarray) -> jnp.ndarray:
    """Draw feature vectors from ``x̂ + U_x`` via inverse-CDF on uniforms.

    u: ``(m, k)`` low-discrepancy uniforms in (0, 1).
    returns ``(m, k)`` feature samples; exact features (sigma==0, parametric)
    come out constant, so a fully-exact plan degenerates to m identical rows —
    which is precisely what makes the guarantee check trivially pass then.
    """
    m, k = u.shape
    # Parametric path: x̂ + sigma * Phi^{-1}(u).
    parametric = unc.value[None, :] + unc.sigma[None, :] * uniform_to_normal(u)
    # Empirical path: inverse CDF of the sorted replicate table.
    b = unc.n_replicates
    idx = jnp.clip((u * b).astype(jnp.int32), 0, b - 1)  # (m, k)
    empirical = jax.vmap(
        lambda col, i: col[i], in_axes=(0, 1), out_axes=1
    )(unc.replicates, idx)  # gather per-feature replicate columns -> (m, k)
    return jnp.where(unc.is_empirical[None, :], empirical, parametric)


def sample_features_fused(
    value: jnp.ndarray,        # (k,) point estimates
    sigma: jnp.ndarray,        # (k,) Normal error stddevs (0 for holistic)
    replicates: jnp.ndarray,   # (h, B) sorted replicate table, holistic rows
    hol_idx: jnp.ndarray | None,  # (h,) static holistic feature indices
    u: jnp.ndarray,            # (m, k) low-discrepancy uniforms
) -> jnp.ndarray:
    """:func:`sample_features`, fused-loop-state edition.

    The fused executor carries (value, sigma) for all k features plus a
    compact (h, B) replicate table for just the holistic ones (``hol_idx``
    names them, statically), instead of a full ``FeatureUncertainty``
    pytree with value-padded (k, B) replicates.  Sampling semantics are
    identical: parametric features draw ``x̂ + σ·Φ⁻¹(u)``, holistic
    features the empirical inverse CDF of their replicate row at the SAME
    uniform column.  Shared by the megabatch sampler in
    ``core/executor_fused.py`` (AMI rows and Saltelli A/B blocks alike).
    """
    rows = value[None, :] + sigma[None, :] * uniform_to_normal(u)
    if hol_idx is not None and replicates.shape[0]:
        b = replicates.shape[1]
        idx = jnp.clip((u[:, hol_idx] * b).astype(jnp.int32), 0, b - 1)
        emp = jax.vmap(
            lambda col, i: col[i], in_axes=(0, 1), out_axes=1
        )(replicates, idx)                            # (m, h)
        rows = rows.at[:, hol_idx].set(emp)
    return rows
