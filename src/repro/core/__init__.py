"""Biathlon core: the paper's contribution as a composable JAX library."""
from repro.core.executor import BiathlonConfig, HostLoopExecutor, RequestResult, run_exact
from repro.core.pipeline import AggFeature, ExactFeature, Pipeline, make_model_fn
from repro.core.planner import direction, gamma_abs, initial_plan, next_plan
from repro.core.propagation import (
    InferenceUncertainty,
    propagate_classification,
    propagate_regression,
)
from repro.core.qmc import normal_qmc_samples, sobol_sequence, sobol_uint32
from repro.core.sobol_indices import main_effect_indices
from repro.core.uncertainty import FeatureUncertainty, exact_uncertainty, sample_features

__all__ = [
    "BiathlonConfig",
    "HostLoopExecutor",
    "RequestResult",
    "run_exact",
    "AggFeature",
    "ExactFeature",
    "Pipeline",
    "make_model_fn",
    "direction",
    "gamma_abs",
    "initial_plan",
    "next_plan",
    "InferenceUncertainty",
    "propagate_classification",
    "propagate_regression",
    "normal_qmc_samples",
    "sobol_sequence",
    "sobol_uint32",
    "main_effect_indices",
    "FeatureUncertainty",
    "exact_uncertainty",
    "sample_features",
]
