"""The Biathlon Planner (paper §3.4): approximation plans and step directions.

A plan ``z`` is a (k,) int32 vector of per-feature sample sizes.  Each
iteration the planner moves ``z`` along the direction of maximum inference-
variance reduction per unit cost (paper Eq. 4), estimated in closed form from
the Sobol main-effect indices (Eq. 8):

    d  =  argmax_{Δz ∈ {0,1}^k}  ( I / (N − z) )ᵀ Δz / ‖Δz‖₁

Because the objective is the *mean* of the selected coefficients
``c_j = I_j / (N_j − z_j)``, the maximum is attained by selecting exactly the
top coefficient (ties broken toward lower index) — that is the LFP closed-form
solution the paper references.  Exhausted features (z_j == N_j) are excluded.

``γ`` (step size) follows the paper's default: 1% of the total number of
records across all features, i.e. a fixed *absolute* per-iteration budget.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

__all__ = ["PlanState", "initial_plan", "direction", "next_plan", "gamma_abs"]


class PlanState(NamedTuple):
    z: jnp.ndarray  # (k,) int32 current sample sizes
    n: jnp.ndarray  # (k,) int32 total records per feature


def gamma_abs(n: jnp.ndarray, gamma_frac: float) -> jnp.ndarray:
    """Paper default step: γ = gamma_frac · Σ_j N_j (at least 1)."""
    return jnp.maximum(
        jnp.ceil(gamma_frac * jnp.sum(n).astype(jnp.float32)).astype(jnp.int32), 1
    )


def initial_plan(n: jnp.ndarray, alpha: float, min_samples: int = 2) -> jnp.ndarray:
    """z⁰ = ceil(α·N), clipped to [min_samples, N] (need ≥2 for a variance)."""
    z0 = jnp.ceil(alpha * n.astype(jnp.float32)).astype(jnp.int32)
    return jnp.clip(z0, jnp.minimum(min_samples, n), n)


def direction(indices: jnp.ndarray, z: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """One-hot (k,) int32 direction: the LFP argmax of I_j / (N_j − z_j).

    Features already exact get -inf score.  If *all* features are exact the
    direction is all-zeros (the executor will have stopped already — an
    all-exact plan always satisfies Eq. 1).
    """
    remaining = (n - z).astype(jnp.float32)
    score = jnp.where(remaining > 0, indices / jnp.maximum(remaining, 1.0), -jnp.inf)
    best = jnp.argmax(score)
    d = jnp.zeros_like(z).at[best].set(1)
    return jnp.where(jnp.all(remaining <= 0), jnp.zeros_like(d), d)


def next_plan(
    z: jnp.ndarray, d: jnp.ndarray, step: jnp.ndarray | int, n: jnp.ndarray
) -> jnp.ndarray:
    """z^{i+1} = min(z + step·d, N)   (paper Eq. 3, clipped; monotone)."""
    return jnp.minimum(z + d * jnp.asarray(step, z.dtype), n)
