"""FusedExecutor: the whole Biathlon feedback loop as ONE XLA program.

Beyond-paper TPU adaptation (DESIGN.md §2): the HostLoopExecutor mirrors the
paper — a Python loop dispatching AFC / AMI / Planner stages per iteration,
paying a host<->device round trip + dispatch latency every cycle.  Once the
datastore I/O is approximated away, those round trips dominate single-digit-
millisecond serving budgets.

The fused variant expresses the iterate-until-guaranteed loop as a
``jax.lax.while_loop`` over fixed-shape state:

* sample growth is a *monotone prefix mask* over pre-gathered, pre-permuted
  (k, cap) buffers — the plan z is data, not shape;
* AFC covers the FULL operator set and is **incremental** (PR 5, DESIGN.md
  § Incremental AFC): a once-per-request precompute before the while_loop
  builds running prefix power-sum tables (``prefix_stats`` Pallas kernel /
  jnp oracle, compensated f32 accumulation) for the parametric aggregates
  (SUM/COUNT/AVG/VAR/STD) and an argsort-with-original-index rank
  structure for the holistic columns; the loop body then reads
  (value, sigma) for ANY plan z with O(1) gathers through the unchanged
  ``estimates_from_power_sums`` finite-population tail, and answers
  holistic order statistics by prefix-membership rank queries — the body's
  cost is independent of the group size.  Holistic aggregates
  (MEDIAN/QUANTILE, paper appendix D) keep their fixed-shape ``(h, B)``
  sorted bootstrap-replicate table recomputed each iteration: replicate
  ranks come from counter-based RNG (``jax.random.fold_in`` on the
  iteration index, so shapes and keys are static inside the while_loop).
  ``afc_backend="ref"`` retains the pre-refactor full-pass rescan
  (``masked_estimates`` / ``masked_select_ranks`` per iteration) as the
  parity oracle; under plain "auto" (no env override) the strategy is now
  picked **per cap bucket** — rescan at or below ``ops.AFC_REF_MAX_CAP``
  where the precompute does not amortize, incremental above it;
* the megabatch row sampler ports ``uncertainty.sample_features``:
  parametric features draw ``value + sigma·Φ⁻¹(u)``, holistic features draw
  the empirical inverse CDF of their replicate table at the same QMC
  uniform — so a MEDIAN feature's uncertainty is propagated exactly as the
  host loop propagates it;
* AMI + Sobol indices share ONE fused QMC evaluation megabatch: the m AMI
  rows, the single point-estimate row, and the (k+2)·m_sobol Saltelli
  A/B/AB rows are concatenated and evaluated with a single ``model_fn``
  call per planner iteration — ``m + 1 + (k+2)·m_sobol`` model rows,
  sliced afterwards for the Eq. 1 guarantee check and the main-effect
  indices (the Saltelli-style model-call amortization);
* the loop state carries ``(z, iter, y_hat, prob, indices, replicates)`` so
  each iteration steps the plan with the *previous* evaluation's indices
  and then evaluates the new plan exactly once — no duplicate pre-step
  call;
* features declared ``approximate=False`` (the paper's Fig. 10 exactness
  ablation) are pinned to ``z_j = n_j`` from z⁰ onward, exactly as the host
  loop pins them — the planner never grows them (they are exhausted) and
  their sigma/replicates are degenerate, so they contribute zero
  uncertainty;
* the initial plan gets a cheap AMI-only dispatch (m+1 rows); its Sobol
  block runs under ``lax.cond`` only when the guarantee fails at z⁰, so
  immediately-satisfied requests (the common case at the paper's α) never
  pay Saltelli rows — in the single-request path.  Under ``vmap`` (batched
  serving) a batched predicate executes both cond branches, so admission
  batches always pay the init Sobol block;
* the loop condition is the Eq. 1 guarantee check.

**Chunked execution** (continuous batching, DESIGN.md § Continuous
batching): :func:`build_chunked_executor` factors the same loop into an
``init`` (per-request precompute + z⁰ evaluation) and a ``chunk`` that runs
at most ``chunk_iters`` planner iterations per dispatch, both over a
first-class :class:`LaneState` pytree that carries the FULL per-lane state
— request buffers, prefix-table handles, the planner carry (z, iteration
counter = the counter-based bootstrap-RNG fold-in index, Sobol main-effect
state, replicates), the traced degradation knobs, and a ``done`` flag.
Because the state is data, a caller can swap a finished lane's state for a
fresh request *between* chunks (iteration-level lane recycling) without
touching the executable: the chunk program's shapes depend only on
(cap, lanes, chunk_iters).  Both executors share one per-iteration core
(``_executor_core``), so a chunked run with ``chunk_iters = max_iters`` is
bitwise-identical to the monolithic while_loop — the monolithic path stays
as the parity oracle.

Cost model (EXPERIMENTS.md §Perf): one model dispatch of
``m + 1 + (k+2)·m_sobol`` rows per iteration, zero host round trips, and a
loop body whose AFC work is cap-independent — one (k, 5) prefix-table
gather for the parametric features plus, per holistic feature, ``(1+B)``
rank queries of O(log(cap/S)) gathers + one S-element block scan each
(B = ``n_boot`` replicates, default 256; ``h·B`` Beta draws for the
replicate ranks).  All O(cap) work happens once per request in the
precompute (prefix tables + argsort); pipelines with ``h = 0`` compile to
exactly the parametric-only program.  The remaining restriction vs the
host loop is the ``cap``-row buffer bound (the guarantee's worst case
degrades to exact-over-cap).  Batched serving vmaps this executor over
concurrent requests with power-of-two bucketed caps, donating the values
buffer to the compiled program (serving/batched.py); continuous serving
vmaps the chunked executor and donates the whole lane table
(serving/continuous.py).
"""
from __future__ import annotations

from types import SimpleNamespace
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.analysis.contracts import ExecutableContract, register_contract
from repro.core.planner import direction, gamma_abs, initial_plan, next_plan
from repro.core.propagation import qmc_uniforms
from repro.core.uncertainty import sample_features_fused
from repro.data.aggregates import AGG_IDS_FULL, HOLISTIC_AGGS, estimates_from_power_sums
from repro.kernels.sampled_agg.ops import (
    bootstrap_rank_targets,
    finish_quantile_estimates,
    masked_estimates,
    masked_quantile_estimates,
    prefix_power_sums,
    resolve_afc_plan,
)
from repro.kernels.sampled_agg.prefix_stats import (
    HolisticRankIndex,
    append_power_sums,
    build_rank_index,
    merge_sorted_prefix,
    prefix_moments_at,
    rank_index_from_sorted,
    select_ranks_indexed,
)

f32 = jnp.float32

__all__ = [
    "CHUNK_CARRY_LEAVES",
    "FusedResult",
    "LaneState",
    "PrebuiltTables",
    "build_afc_precompute",
    "build_chunked_executor",
    "build_fused_executor",
    "empty_rank_index",
    "fused_rows_per_iteration",
    "pipeline_executor_kwargs",
    "shard_lanes_executor",
    "shard_lanes_state_executor",
]


class PrebuiltTables(NamedTuple):
    """Device-resident incremental-AFC precompute for one request.

    The handle the feature-store cache (serving/feature_cache.py) passes to
    a ``prebuilt=True`` executor instead of letting it run its internal
    ``core.precompute``: ``ptab (k, cap, 4)`` prefix power-sum tables,
    ``shift (k,)`` their accumulation origin (= ``vals[:, 0]``), and the
    holistic :class:`HolisticRankIndex` (zero-size when the pipeline has no
    holistic features).  Built by :func:`build_afc_precompute`, which also
    owns the append-event delta refresh — the executor only ever reads it.
    """

    ptab: jnp.ndarray
    shift: jnp.ndarray
    rindex: HolisticRankIndex


class FusedResult(NamedTuple):
    y_hat: jnp.ndarray
    prob: jnp.ndarray
    iters: jnp.ndarray
    z: jnp.ndarray          # (k,) final plan
    samples_used: jnp.ndarray
    # Batched serving threads the donated (lanes, k, cap) values buffer back
    # out as lane state: the identity passthrough gives XLA an input-output
    # alias for the donated argument, so per-batch serving provably does NOT
    # copy the big buffer (asserted via memory_analysis in tests).  None on
    # the single-request path (returning an undonated input would force the
    # copy this field exists to avoid).
    lane_vals: jnp.ndarray | None = None


class LaneState(NamedTuple):
    """One lane's complete carry between chunked-executor dispatches.

    A first-class pytree (vmapped over a leading ``lanes`` dimension by the
    continuous server) holding everything a request's planner loop needs to
    resume — so swapping a lane = overwriting its slice of every leaf, and
    the chunk executable's shapes depend only on (cap, lanes, chunk_iters):

    request inputs
      ``vals (k, cap)``  pre-gathered, pre-permuted sample buffers
      ``n (k,)``         group sizes clamped to cap
      ``agg_ids (k,)``   operator ids
      ``delta ()``       error bound (traced knob)
      ``exact (e,)``     exactly-computed feature values
      ``active ()``      pad-lane flag (False = never iterates)
      ``tau ()``         confidence target (traced knob)
      ``iter_cap ()``    planner-iteration ceiling (traced knob)
    planner carry
      ``z (k,)``         current plan
      ``it ()``          iteration counter — also the counter-based
                         bootstrap-RNG fold-in index, so replicate draws
                         are per-request-deterministic wherever the lane
                         lives (the recycling-parity property)
      ``y_hat / prob ()`` last evaluation + Eq. 1 guarantee probability
      ``idx (k,)``       Sobol main-effect indices steering the next step
      ``reps (h, B)``    holistic bootstrap replicate table
      ``done ()``        guarantee met / exhausted / capped — the lane is
                         recyclable
    incremental-AFC handles (PR 5)
      ``ptab (k, cap, 4)``  prefix power-sum tables ((k, 0, 4) under rescan)
      ``shift (k,)``        the tables' numerical shift
      ``rindex``            :class:`HolisticRankIndex` (zero-size leaves
                            when rescan or no holistic features)

    The zero-size placeholders keep the pytree structure identical across
    AFC strategies *for a given cap bucket* (the strategy is resolved from
    the cap at trace time, so one bucket always yields one structure).
    """

    vals: jnp.ndarray
    n: jnp.ndarray
    agg_ids: jnp.ndarray
    delta: jnp.ndarray
    exact: jnp.ndarray
    active: jnp.ndarray
    tau: jnp.ndarray
    iter_cap: jnp.ndarray
    z: jnp.ndarray
    it: jnp.ndarray
    y_hat: jnp.ndarray
    prob: jnp.ndarray
    idx: jnp.ndarray
    reps: jnp.ndarray
    done: jnp.ndarray
    ptab: jnp.ndarray
    shift: jnp.ndarray
    rindex: HolisticRankIndex


#: The LaneState leaves the chunk executable actually mutates (its
#: ``state._replace`` set).  Every other leaf — request inputs, knobs, AFC
#: handles — is content-invariant across a chunk dispatch (donated and
#: aliased through, values unchanged), so a chunk-boundary checkpoint is
#: host copies of exactly these small per-lane leaves: the recovery layer
#: (serving/runtime.py) snapshots them before each dispatch and restores
#: them with plain ``device_put`` — zero new executables.
CHUNK_CARRY_LEAVES = ("z", "it", "y_hat", "prob", "idx", "reps", "done")


def empty_rank_index() -> HolisticRankIndex:
    """Zero-size :class:`HolisticRankIndex` placeholder (rescan / h == 0)."""
    zi = jnp.zeros((0, 0), jnp.int32)
    return HolisticRankIndex(
        sorted_vals=jnp.zeros((0, 0), f32),
        sorted_idx=zi,
        blk_cnt=jnp.zeros((0, 0, 0), jnp.int32),
        zcand=zi,
    )


def fused_rows_per_iteration(k: int, m: int, m_sobol: int) -> int:
    """Model rows evaluated per planner iteration (the single megabatch)."""
    return m + 1 + (k + 2) * m_sobol


def shard_lanes_executor(lane_fn, mesh, *, axis: str = "lanes", donate_vals: bool = False):
    """Data-parallel lane sharding of a per-lane fused executor.

    ``lane_fn`` is a single-lane ``run(vals, n, agg_ids, delta, exact,
    active, tau, iter_cap)`` (the :func:`build_fused_executor` signature
    with the trailing optionals made mandatory so the arity is static); the
    result maps it over a leading ``lanes`` dimension — ``jax.vmap`` within
    each device, ``shard_map`` across the ``mesh``'s 1-D ``axis`` — and
    jits the whole thing.

    Because every lane is an independent while-loop over its own buffers,
    ALL eight inputs and every :class:`FusedResult` leaf partition along the
    leading dimension and the compiled program contains **zero cross-device
    collectives**: model params and the QMC/bootstrap constants are
    closure-captured and replicated, per-lane reductions stay local to the
    device that owns the lane.  A device whose lane block finishes (or is
    all pad lanes) exits its while-loop independently — stragglers only
    stall the lanes that share their device, which is the scaling win over
    the single-device megabatch.

    The leading dimension of every argument must be divisible by the mesh
    size (callers pad to a fixed lane count anyway).  ``check_rep=False``
    because the executor closes over large replicated constants and runs a
    ``while_loop`` — the conservative replication checker rejects that
    combination without adding safety for a collective-free program.

    ``donate_vals=True`` donates argument 0 (the (lanes, k, cap) values
    buffer, by far the largest per-batch transfer): when ``lane_fn``
    threads it back out (``FusedResult.lane_vals``) XLA aliases the donated
    input to that output and per-batch serving stops copying the buffer —
    the donation contract asserted via ``memory_analysis`` in tests.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    spec = PartitionSpec(axis)
    return jax.jit(
        shard_map(
            jax.vmap(lane_fn),
            mesh=mesh,
            in_specs=(spec,) * 8,
            out_specs=spec,
            check_rep=False,
        ),
        donate_argnums=(0,) if donate_vals else (),
    )


def shard_lanes_state_executor(chunk_fn, mesh, *, axis: str = "lanes",
                               donate_state: bool = True):
    """Lane sharding of a chunked per-lane ``chunk(LaneState) -> LaneState``.

    The pytree twin of :func:`shard_lanes_executor`: every
    :class:`LaneState` leaf carries a leading ``lanes`` dimension, so ONE
    ``PartitionSpec("lanes")`` applied as a pytree prefix partitions the
    whole table and the compiled chunk program stays **collective-free** —
    a per-device lane swap is just the host overwriting that device's
    slice of the table between dispatches, no cross-device traffic.  The
    table (argument 0) is donated by default so XLA updates it in place
    across chunks instead of copying every leaf each dispatch.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    spec = PartitionSpec(axis)
    return jax.jit(
        shard_map(
            jax.vmap(chunk_fn),
            mesh=mesh,
            in_specs=(spec,),
            out_specs=spec,
            check_rep=False,
        ),
        donate_argnums=(0,) if donate_state else (),
    )


#: Sharded-lane contract: the shard_map wrappers above promise a compiled
#: module with ZERO cross-device collectives (params replicated as closure
#: constants, per-lane reductions local to the owning device) and the same
#: one-executable-per-cap-bucket cache behavior as the unsharded path.
SHARDED_LANES_CONTRACT = register_contract(ExecutableContract(
    name="sharded_lanes",
    builder="repro.core.executor_fused.shard_lanes_executor",
    executables_per_bucket=1,
    collectives=0,
    donated=("vals (lanes, k, cap) values buffer",),
    while_body_flat=True,
    description=(
        "shard_map over the 1-D ('lanes',) mesh: fixed-lane batch program "
        "partitioned device-parallel, collective-free by construction"
    ),
))


def pipeline_executor_kwargs(agg_features) -> dict:
    """Per-feature executor kwargs from a pipeline's ``agg_features``.

    Returns the ``holistic`` / ``quantiles`` / ``approximate`` build
    arguments plus the runtime ``agg_ids`` row — the one place the
    feature-spec -> executor translation lives, shared by both fused
    serving paths.  Raises on operators outside AGG_IDS_FULL.
    """
    unsupported = sorted(
        {f.agg for f in agg_features if f.agg not in AGG_IDS_FULL}
    )
    if unsupported:
        raise ValueError(f"unsupported aggregates {unsupported}")
    holistic = tuple(
        j for j, f in enumerate(agg_features) if f.agg in HOLISTIC_AGGS
    )
    return dict(
        holistic=holistic,
        quantiles=tuple(
            0.5 if agg_features[j].agg == "median" else agg_features[j].quantile
            for j in holistic
        ),
        approximate=tuple(f.approximate for f in agg_features),
        agg_ids=jnp.asarray(
            [AGG_IDS_FULL[f.agg] for f in agg_features], jnp.int32
        ),
    )


def _executor_core(
    model_fn,
    *,
    k: int,
    task: str,
    n_classes: int,
    m: int,
    m_sobol: int,
    alpha: float,
    gamma: float,
    max_iters: int,
    afc_backend: str,
    hol_idx,
    n_hol: int,
    qs,
    approx,
    n_boot: int,
    base_key,
    cached: bool = False,
):
    """The per-iteration machinery BOTH executors trace through.

    Everything here is a pure function of explicit arguments (no per-call
    closures), so the monolithic while_loop and the chunked executor build
    bitwise-identical iteration bodies — the parity contract the chunked
    tests assert.  The AFC strategy is resolved per trace from the buffer
    cap (``resolve_afc_plan(afc_backend, cap)``), so a cap bucket always
    gets one consistent strategy across init/loop/chunk programs.
    ``cached=True`` (the prebuilt-tables executors) tells the resolver the
    precompute is amortized by the feature-store cache, flipping "auto" to
    the incremental path at every cap.
    """
    u_ami = qmc_uniforms(m, k)                       # (m, k) static
    u_sob = qmc_uniforms(m_sobol, 2 * k, None)       # (m_sobol, 2k)

    def sample_rows(value, sigma, reps, u):
        """uncertainty.sample_features, fused-state edition (shared impl).

        Parametric: x̂ + σ·Φ⁻¹(u).  Holistic: empirical inverse CDF of the
        sorted (h, B) replicate table at the feature's own uniform column.
        """
        return sample_features_fused(value, sigma, reps, hol_idx, u)

    def guarantee_prob(y_hat, mean, sd, delta):
        if task == "classification":
            return mean
        bias = mean - y_hat
        safe = jnp.maximum(sd, 1e-12)
        phi = jax.scipy.stats.norm.cdf
        prob = phi((delta - bias) / safe) - phi((-delta - bias) / safe)
        return jnp.where(sd <= 1e-12, (jnp.abs(bias) <= delta).astype(f32), prob)

    def ami_prob(y, y_hat, delta):
        """Eq. 1 guarantee probability from the AMI output slice."""
        if task == "regression":
            y_bar = jnp.mean(y)
            sd = jnp.sqrt(jnp.mean((y - y_bar) ** 2))
            return guarantee_prob(y_hat, y_bar, sd, delta)
        probs = (
            jnp.bincount(y.astype(jnp.int32), length=n_classes).astype(f32) / m
        )
        return probs[y_hat.astype(jnp.int32)]

    def sobol_from_outputs(f_all, y_hat):
        """Main-effect indices from the pre-evaluated Saltelli block."""
        if task == "classification":
            f_all = (f_all.astype(jnp.int32) == y_hat.astype(jnp.int32)).astype(f32)
        f_all = f_all - jnp.mean(f_all)  # center (see sobol_indices.py)
        fa, fb = f_all[:m_sobol], f_all[m_sobol : 2 * m_sobol]
        fab = f_all[2 * m_sobol :].reshape(k, m_sobol)
        var_y = jnp.var(f_all)
        v_j = jnp.mean(fb[None] * (fab - fa[None]), axis=1)
        return jnp.where(
            var_y > 1e-12, jnp.clip(v_j / jnp.maximum(var_y, 1e-12), 0, 1), 0.0
        )

    def sobol_rows(value, sigma, reps):
        """Saltelli A/B/AB block: ((k+2)*m_sobol, k)."""
        ua, ub = u_sob[:, :k], u_sob[:, k:]
        xa = sample_rows(value, sigma, reps, ua)
        xb = sample_rows(value, sigma, reps, ub)
        eye = jnp.eye(k, dtype=bool)
        xab = jnp.where(eye[:, None, :], xb[None], xa[None]).reshape(
            k * m_sobol, k
        )
        return jnp.concatenate([xa, xb, xab], 0)

    def precompute(vals, n, z0, step):
        """Incremental-AFC precompute: every data-proportional pass runs
        HERE, once per request, before the loop (DESIGN.md § Incremental
        AFC).  The plan ladder min(z⁰ + i·γ, n) enumerates every z the
        planner can reach (γ and max_iters are loop constants), which is
        what lets the holistic membership counts be precomputed per
        candidate plan.  Returns ``(None, None, None)`` under rescan.
        """
        incremental, use_kernel = resolve_afc_plan(
            afc_backend, cap=vals.shape[1], cached=cached
        )
        if not incremental:
            return None, None, None
        shift = vals[:, 0]
        ptab = prefix_power_sums(vals, shift, use_kernel=use_kernel)
        rindex = None
        if n_hol:
            zcand = jnp.minimum(
                z0[:, None]
                + jnp.arange(max_iters + 1, dtype=jnp.int32)[None, :] * step,
                n[:, None],
            )
            rindex = build_rank_index(vals[hol_idx], n[hol_idx], zcand[hol_idx])
        return ptab, shift, rindex

    def afc(vals, n, agg_ids, ptab, shift, rindex, z, it):
        """(value, sigma, replicates) at plan z — strategy-routed.

        Incremental: one (k, 5) gather into the prefix tables feeds the
        unchanged estimator tail, and holistic order statistics come
        from rank queries against the presorted column — nothing in
        here scales with cap.  Rescan ("ref"): the pre-refactor full
        pass per iteration.  Replicate ranks use counter-based RNG on
        the iteration index (identical draws on both strategies) so the
        while_loop body stays shape- and key-static and the two
        strategies stay z-plan-parity comparable.
        """
        incremental, use_kernel = resolve_afc_plan(
            afc_backend, cap=vals.shape[1], cached=cached
        )
        if incremental:
            value, sigma = estimates_from_power_sums(
                prefix_moments_at(ptab, z), z, n, agg_ids, shift
            )
        else:
            value, sigma = masked_estimates(
                vals, z, n, agg_ids, use_kernel=use_kernel
            )
        if not n_hol:
            return value, sigma, jnp.zeros((0, n_boot), f32)
        key = jax.random.fold_in(base_key, it)
        if incremental:
            targets = bootstrap_rank_targets(z[hol_idx], qs, key, n_boot)
            sel = select_ranks_indexed(rindex, z[hol_idx], targets)
            q_val, reps = finish_quantile_estimates(
                sel, z[hol_idx], n[hol_idx]
            )
        else:
            q_val, reps = masked_quantile_estimates(
                vals[hol_idx],
                z[hol_idx],
                n[hol_idx],
                qs,
                key,
                n_boot,
                use_kernel=use_kernel,
            )
        value = value.at[hol_idx].set(q_val)
        sigma = sigma.at[hol_idx].set(0.0)
        return value, sigma, reps

    def evaluate(vals, n, agg_ids, exact, delta, ptab, shift, rindex, z, it):
        """AFC + AMI + Sobol via ONE model dispatch at plan z.

        Rows: [AMI (m,k) | point estimate (1,k) | Saltelli A/B/AB
        ((k+2)*m_sobol, k)] -> slice outputs for the guarantee check and
        the main-effect indices.
        """
        value, sigma, reps = afc(vals, n, agg_ids, ptab, shift, rindex, z, it)
        x_ami = sample_rows(value, sigma, reps, u_ami)
        batch = jnp.concatenate(
            [x_ami, value[None, :], sobol_rows(value, sigma, reps)], 0
        )
        y_all = model_fn(batch, exact).astype(f32)

        y_hat = y_all[m]
        prob = ami_prob(y_all[:m], y_hat, delta)
        idx = sobol_from_outputs(y_all[m + 1 :], y_hat)
        return y_hat, prob, idx, reps

    def init_eval(vals, n, agg_ids, exact, delta, act, tau, cap_eff,
                  z0, ptab, shift, rindex):
        """z⁰ evaluation: AMI-only dispatch (m+1 rows), cond-gated Sobol.

        The Saltelli block is only evaluated — via ``lax.cond``, so
        immediately-guaranteed requests skip its cost entirely — when the
        loop will actually be entered.  (Under vmap the cond becomes a
        select and both branches run.)  Returns the initial loop carry.
        """
        value0, sigma0, reps0 = afc(
            vals, n, agg_ids, ptab, shift, rindex, z0, jnp.zeros((), jnp.int32)
        )
        y0_all = model_fn(
            jnp.concatenate(
                [sample_rows(value0, sigma0, reps0, u_ami), value0[None, :]], 0
            ),
            exact,
        ).astype(f32)
        y_hat0 = y0_all[m]
        prob0 = ami_prob(y0_all[:m], y_hat0, delta)
        idx0 = jax.lax.cond(
            act & (prob0 < tau) & jnp.any(z0 < n) & (cap_eff > 0),
            lambda: sobol_from_outputs(
                model_fn(sobol_rows(value0, sigma0, reps0), exact).astype(f32),
                y_hat0,
            ),
            lambda: jnp.zeros((k,), f32),
        )
        return (z0, jnp.zeros((), jnp.int32), y_hat0, prob0, idx0, reps0)

    def want_more(carry, act, tau, cap_eff, n):
        """The Eq. 1 while-condition: another planner iteration needed?"""
        z, it, _, prob, _, _ = carry
        return act & (prob < tau) & (it < cap_eff) & jnp.any(z < n)

    def step_plan(carry, vals, n, agg_ids, exact, delta, step,
                  ptab, shift, rindex):
        """One planner iteration: step z along the Sobol direction, evaluate."""
        z, it, _, _, idx, _ = carry
        d = direction(idx, z, n)
        z = next_plan(z, d, step, n)
        y_hat, prob, idx, reps = evaluate(
            vals, n, agg_ids, exact, delta, ptab, shift, rindex, z, it + 1
        )
        return (z, it + 1, y_hat, prob, idx, reps)

    return SimpleNamespace(
        precompute=precompute,
        init_eval=init_eval,
        want_more=want_more,
        step_plan=step_plan,
    )


def _parse_feature_spec(k, holistic, quantiles, approximate):
    hol = tuple(int(j) for j in holistic)
    n_hol = len(hol)
    hol_idx = jnp.asarray(hol, jnp.int32) if n_hol else None
    qs = jnp.asarray([0.5] * n_hol if quantiles is None else list(quantiles), f32)
    if qs.shape[0] != n_hol:
        raise ValueError("quantiles must align with holistic indices")
    approx = jnp.asarray(
        [True] * k if approximate is None else list(approximate), bool
    )
    return hol_idx, n_hol, qs, approx


def build_fused_executor(
    model_fn,
    *,
    k: int,
    task: str,
    n_classes: int = 2,
    m: int = 512,
    m_sobol: int = 128,
    alpha: float = 0.05,
    gamma: float = 0.01,
    tau: float = 0.95,
    max_iters: int = 32,
    afc_backend: str = "auto",
    holistic: Sequence[int] = (),
    quantiles: Sequence[float] | None = None,
    n_boot: int = 256,
    approximate: Sequence[bool] | None = None,
    boot_seed: int = 0,
    prebuilt: bool = False,
):
    """Returns jit-able ``run(vals (k,cap), n (k,), agg_ids (k,), delta) -> FusedResult``.

    ``prebuilt=True`` builds the cache-fed twin: ``run(vals, n, agg_ids,
    delta, exact, tables, active=None, tau=None, iter_cap=None)`` takes a
    :class:`PrebuiltTables` (built once by :func:`build_afc_precompute` and
    kept device-resident by the feature-store cache) instead of running the
    internal per-request precompute — a cache hit pays zero precompute and
    zero H2D re-transfer.  The AFC strategy resolves with ``cached=True``
    (incremental at every cap under plain "auto"; the env override still
    wins, in which case the tables ride along unused on the rescan path).
    Everything after the precompute is the same ``_executor_core`` body, so
    cache-hit and cache-miss dispatches of the same executable are
    bitwise-identical and a prebuilt run matches the plain executor
    wherever both resolve to the same strategy.

    ``model_fn``: (rows (n,k), exact (e,)) -> (n,) predictions (regression
    values or class ids); must be jittable — tabular models and LM heads both
    qualify.  ``exact`` carries the request's exactly-computed features so a
    single compiled executor serves every request of the pipeline.

    ``run`` also accepts an optional trailing ``active`` flag (scalar bool)
    used by fixed-lane admission batching (serving/runtime.py): a vmapped
    batch pads to a constant lane count and marks pad lanes inactive, so the
    jit cache sees ONE shape per cap bucket regardless of batch fill.  An
    inactive lane never enters the while_loop (its guarantee predicate is
    forced false), reports ``iters == 0`` and ``samples_used == 0``, and its
    y_hat/prob are the init-dispatch values over its zero-padded buffers —
    callers slice inactive lanes off before interpreting results.

    Two further optional trailing inputs promote degradation knobs from
    compile-time constants to **traced loop state** (SLO-aware serving,
    DESIGN.md § Graceful degradation): ``tau`` overrides the build-time
    confidence target and ``iter_cap`` the planner-iteration ceiling, per
    call (per lane under vmap).  Both are data, not shape — an admission
    controller can vary them every batch without ever minting a new
    executable per cap bucket.  ``iter_cap`` is clamped to the static
    ``max_iters``, which still bounds the while_loop and sizes the
    incremental-AFC candidate ladder (a smaller traced cap only uses a
    prefix of that ladder); ``m_sobol``/``m`` stay static because they set
    the megabatch SHAPE.  ``None`` (the single-request default) compiles
    the constants in exactly as before.

    ``model_fn`` is invoked exactly ONCE per planner iteration, on a
    ``(m + 1 + (k+2)*m_sobol, k)`` megabatch (see module docstring).

    ``afc_backend`` selects the AFC strategy (``ops.resolve_afc_plan``,
    resolved at trace time with the buffer cap): "auto" picks per cap
    bucket — the **incremental** path (a once-per-request precompute:
    ``prefix_power_sums`` tables for the parametric features, a
    ``build_rank_index`` argsort structure for the holistic columns —
    hoisting every data-proportional pass out of the while_loop, whose
    body then reads (value, sigma) by O(1) gathers and answers holistic
    order statistics by prefix-membership rank queries) above
    ``ops.AFC_REF_MAX_CAP``, the rescan path at or below it, where the
    precompute does not amortize — honoring the REPRO_AFC_BACKEND env as a
    force-override.  "kernel" forces incremental with the Pallas table
    kernel (interpret off-TPU); "incremental" (alias "inc") forces
    incremental with the jnp table oracle regardless of env (explicit
    strategy pinning for parity tests and CPU benchmarks).  "ref" keeps
    the pre-refactor **rescan** oracle — a full ``masked_estimates`` /
    ``masked_select_ranks_ref`` pass per iteration — as the parity
    baseline (CI pins it via the env).

    Holistic support (static, per-pipeline): ``holistic`` lists the feature
    indices whose ``agg_ids`` are MEDIAN/QUANTILE, ``quantiles`` their q's
    (aligned with ``holistic``; median = 0.5), ``n_boot`` the bootstrap
    replicate count B, ``boot_seed`` the base of the counter-based replicate
    RNG (folded with the iteration index; shared across vmapped lanes, like
    the QMC uniforms).  ``approximate`` flags per feature whether Biathlon
    may sample it (False = Fig. 10 exact-only: pinned to z = n).
    """
    resolve_afc_plan(afc_backend)  # validate the string at build time

    hol_idx, n_hol, qs, approx = _parse_feature_spec(
        k, holistic, quantiles, approximate
    )
    core = _executor_core(
        model_fn, k=k, task=task, n_classes=n_classes, m=m, m_sobol=m_sobol,
        alpha=alpha, gamma=gamma, max_iters=max_iters, afc_backend=afc_backend,
        hol_idx=hol_idx, n_hol=n_hol, qs=qs, approx=approx,
        n_boot=int(n_boot), base_key=jax.random.PRNGKey(boot_seed),
        cached=prebuilt,
    )
    static_tau, static_max_iters = tau, max_iters

    def _knobs(active, tau, iter_cap):
        act = jnp.asarray(True) if active is None else active
        # degradation knobs: traced when supplied, compile-time otherwise
        tau = static_tau if tau is None else tau
        cap_eff = (
            static_max_iters
            if iter_cap is None
            else jnp.minimum(jnp.asarray(iter_cap, jnp.int32), static_max_iters)
        )
        return act, tau, cap_eff

    def _finish(vals, n, agg_ids, delta, exact, act, tau, cap_eff,
                z0, step, ptab, shift, rindex) -> FusedResult:
        carry0 = core.init_eval(
            vals, n, agg_ids, exact, delta, act, tau, cap_eff,
            z0, ptab, shift, rindex,
        )
        z, iters, y_hat, prob, _, _ = jax.lax.while_loop(
            lambda c: core.want_more(c, act, tau, cap_eff, n),
            lambda c: core.step_plan(
                c, vals, n, agg_ids, exact, delta, step, ptab, shift, rindex
            ),
            carry0,
        )
        return FusedResult(
            y_hat=y_hat,
            prob=prob,
            iters=iters,
            z=z,
            samples_used=jnp.where(act, jnp.sum(jnp.minimum(z, n)), 0),
        )

    if prebuilt:

        @jax.jit
        def run_prebuilt(vals, n, agg_ids, delta, exact, tables,
                         active=None, tau=None, iter_cap=None) -> FusedResult:
            act, tau, cap_eff = _knobs(active, tau, iter_cap)
            cap = vals.shape[1]
            n = jnp.minimum(n.astype(jnp.int32), cap)
            z0 = jnp.where(approx, initial_plan(n, alpha), n)
            step = gamma_abs(n, gamma)
            incremental, _ = resolve_afc_plan(afc_backend, cap=cap, cached=True)
            ptab = tables.ptab if incremental else None
            shift = tables.shift if incremental else None
            rindex = tables.rindex if (incremental and n_hol) else None
            return _finish(vals, n, agg_ids, delta, exact, act, tau, cap_eff,
                           z0, step, ptab, shift, rindex)

        return run_prebuilt

    @jax.jit
    def run(vals, n, agg_ids, delta, exact, active=None, tau=None,
            iter_cap=None) -> FusedResult:
        act, tau, cap_eff = _knobs(active, tau, iter_cap)
        cap = vals.shape[1]
        n = jnp.minimum(n.astype(jnp.int32), cap)
        # exact-only operators (Fig. 10 ablation) consume their full groups
        # from z⁰ on — the planner then never selects them (exhausted).
        z0 = jnp.where(approx, initial_plan(n, alpha), n)
        step = gamma_abs(n, gamma)
        ptab, shift, rindex = core.precompute(vals, n, z0, step)
        return _finish(vals, n, agg_ids, delta, exact, act, tau, cap_eff,
                       z0, step, ptab, shift, rindex)

    return run


#: Fixed-lane fused contract: the vmapped ``run`` above is the whole batch
#: program, so the jit cache is keyed by (lanes, k, cap) only — one
#: executable per power-of-two cap bucket; delta/tau/iter_cap are traced
#: (lanes,) inputs, never cache keys.  Bootstrap draws are counter-based
#: (``fold_in`` of the per-request iteration index on a closure key), the
#: lane-recycling bitwise-parity property.  The planner while body must
#: price independent of cap on the incremental-AFC path (all O(cap) work in
#: the once-per-request precompute).
FUSED_CONTRACT = register_contract(ExecutableContract(
    name="fused",
    builder="repro.core.executor_fused.build_fused_executor",
    executables_per_bucket=1,
    collectives=0,
    donated=("vals (lanes, k, cap) values buffer",),
    while_body_flat=True,
    description=(
        "fixed-lane batch program (BatchedFusedServer): one executable per "
        "cap bucket, donated values buffer threaded out as lane_vals, "
        "counter-based bootstrap RNG in the planner loop"
    ),
))

#: Prebuilt-tables twin of the fused contract: identical loop body, but the
#: per-request precompute is hoisted out of the executable entirely (fed as
#: the PrebuiltTables input), so the cap bucket still mints exactly one
#: executable and cache hits re-dispatch it with zero new compiles.
FUSED_PREBUILT_CONTRACT = register_contract(ExecutableContract(
    name="fused_prebuilt",
    builder="repro.core.executor_fused.build_fused_executor (prebuilt=True)",
    executables_per_bucket=1,
    collectives=0,
    donated=("vals (lanes, k, cap) values buffer",),
    while_body_flat=True,
    description=(
        "cache-fed fused program: PrebuiltTables replace the internal "
        "precompute; one executable per cap bucket shared by cache hits "
        "and misses"
    ),
))


def build_afc_precompute(
    *,
    k: int,
    alpha: float = 0.05,
    gamma: float = 0.01,
    max_iters: int = 32,
    holistic: Sequence[int] = (),
    quantiles: Sequence[float] | None = None,
    approximate: Sequence[bool] | None = None,
):
    """The standalone incremental-AFC precompute + its append-delta refresh.

    Returns ``SimpleNamespace(cold, refresh)``:

    ``cold(vals (k, cap), n (k,)) -> PrebuiltTables``
        exactly the tables ``_executor_core.precompute`` would build inside
        a run — same shift basis (``vals[:, 0]``), same candidate ladder
        ``min(z⁰ + i·γ, n)`` — hoisted into its own jit executable so the
        feature-store cache can build once and re-dispatch many times.

    ``refresh(vals, n, tables, j, x, aff) -> (vals', n', tables')``
        applies ONE logged append event — value ``x (k,)`` (the appended
        row read through each feature's column) inserted at prefix position
        ``j`` of the groups flagged by ``aff (k,)`` — as delta updates:
        the values buffer shifts right from j, the power-sum tables get the
        :func:`append_power_sums` two-sum row update, and the holistic
        index merges the event into its maintained sorted runs
        (:func:`merge_sorted_prefix`) then recounts ``blk_cnt`` against the
        new candidate ladder (n changed, so z⁰ and the ladder move) without
        re-sorting.  Callers must route ``j == 0`` events to ``cold``
        instead — they replace the shift basis.  All of j/x/aff are traced,
        so replaying a whole append log is N dispatches of one executable.

    The ladder math is deliberately duplicated from the executor core in
    one place only (here), and the parity tests pin ``cold`` against the
    in-executor precompute via served-result equality.
    """
    hol_idx, n_hol, _qs, approx = _parse_feature_spec(
        k, holistic, quantiles, approximate
    )
    _, use_kernel = resolve_afc_plan("auto", cached=True)
    n_z = max_iters + 1

    def zcand_of(n):
        z0 = jnp.where(approx, initial_plan(n, alpha), n)
        step = gamma_abs(n, gamma)
        return jnp.minimum(
            z0[:, None] + jnp.arange(n_z, dtype=jnp.int32)[None, :] * step,
            n[:, None],
        )

    @jax.jit
    def cold(vals, n) -> PrebuiltTables:
        cap = vals.shape[1]
        n = jnp.minimum(n.astype(jnp.int32), cap)
        shift = vals[:, 0]
        ptab = prefix_power_sums(vals, shift, use_kernel=use_kernel)
        if n_hol:
            zc = zcand_of(n)
            rindex = build_rank_index(vals[hol_idx], n[hol_idx], zc[hol_idx])
        else:
            rindex = empty_rank_index()
        return PrebuiltTables(ptab=ptab, shift=shift, rindex=rindex)

    @jax.jit
    def refresh(vals, n, tables: PrebuiltTables, j, x, aff):
        cap = vals.shape[1]
        n = jnp.minimum(n.astype(jnp.int32), cap)
        j = jnp.asarray(j, jnp.int32)
        x = jnp.asarray(x, f32)
        aff = jnp.asarray(aff, bool)
        c = jnp.arange(cap, dtype=jnp.int32)
        prev = jnp.concatenate([vals[:, :1], vals[:, :-1]], axis=1)
        inserted = jnp.where(
            c[None, :] < j, vals, jnp.where(c[None, :] == j, x[:, None], prev)
        )
        vals2 = jnp.where(aff[:, None] & (j < cap), inserted, vals)
        ptab2 = append_power_sums(tables.ptab, tables.shift, j, x, aff)
        n2 = jnp.minimum(n + aff.astype(jnp.int32), cap)
        if n_hol:
            ri = tables.rindex
            msv, msi, _ = merge_sorted_prefix(
                ri.sorted_vals, ri.sorted_idx, n[hol_idx], cap,
                j, x[hol_idx], aff[hol_idx],
            )
            block = ri.sorted_vals.shape[1] // (ri.blk_cnt.shape[-1] - 1)
            rindex = rank_index_from_sorted(
                msv, msi, zcand_of(n2)[hol_idx], block=block
            )
        else:
            rindex = tables.rindex
        return vals2, n2, PrebuiltTables(
            ptab=ptab2, shift=tables.shift, rindex=rindex
        )

    return SimpleNamespace(cold=cold, refresh=refresh, n_hol=n_hol)


#: The standalone precompute is one more executable per cap bucket on the
#: cached serving paths (cold builds on cache misses; the delta refresh
#: shares its jit cache entry count — one executable each, but refresh only
#: traces when appends actually happen, so the steady-state budget is 1).
AFC_PRECOMPUTE_CONTRACT = register_contract(ExecutableContract(
    name="afc_precompute",
    builder="repro.core.executor_fused.build_afc_precompute",
    executables_per_bucket=1,
    collectives=0,
    description=(
        "once-per-cache-miss precompute: prefix power-sum tables + holistic "
        "rank index as a standalone executable whose output (PrebuiltTables) "
        "stays device-resident in the feature-store cache"
    ),
))


def build_chunked_executor(
    model_fn,
    *,
    chunk_iters: int,
    k: int,
    task: str,
    n_classes: int = 2,
    m: int = 512,
    m_sobol: int = 128,
    alpha: float = 0.05,
    gamma: float = 0.01,
    tau: float = 0.95,
    max_iters: int = 32,
    afc_backend: str = "auto",
    holistic: Sequence[int] = (),
    quantiles: Sequence[float] | None = None,
    n_boot: int = 256,
    approximate: Sequence[bool] | None = None,
    boot_seed: int = 0,
    prebuilt: bool = False,
):
    """Chunked twin of :func:`build_fused_executor` for continuous batching.

    ``prebuilt=True`` is the cache-fed admission path: ``init`` grows a
    trailing ``tables`` argument (:class:`PrebuiltTables` from the
    feature-store cache) and packs those leaves into the LaneState instead
    of running the per-request precompute; the AFC strategy resolves with
    ``cached=True`` in both init and chunk, so every cap bucket keeps one
    consistent LaneState structure (full-size ptab/rindex leaves).

    Returns ``(init, chunk)``, both jit-able per-lane functions over
    :class:`LaneState` (callers vmap/shard them; serving/continuous.py):

    ``init(vals, n, agg_ids, delta, exact, active, tau, iter_cap)``
        runs the once-per-request work — buffer clamp, z⁰ seeding, the
        incremental-AFC precompute, and the z⁰ evaluation with its
        cond-gated Sobol block — and packs EVERYTHING into a
        :class:`LaneState`.  All eight arguments are mandatory (they are
        per-lane data under vmap; ``tau``/``iter_cap``/``delta`` are the
        PR-6 traced knobs, re-assigned per admission).

    ``chunk(state) -> state``
        advances the planner at most ``chunk_iters`` iterations — the same
        ``while_loop`` as the monolithic executor with one extra conjunct
        ``j < chunk_iters`` on a per-dispatch trip counter.  Because the
        planner's own predicate is evaluated first each trip, running
        chunks back-to-back replays EXACTLY the monolithic iteration
        sequence: with ``chunk_iters >= max_iters`` one chunk IS the
        monolithic loop (bitwise-identical z/iters — the parity oracle
        relation), and a done/inactive lane costs zero trips (its
        predicate is false on entry).  ``done`` is refreshed after the
        loop so the scheduler reads recyclability without re-deriving the
        predicate.

    The per-iteration computation is shared with the monolithic executor
    (``_executor_core``), including the counter-based bootstrap RNG — a
    request's trajectory depends only on its own buffers and ``it``
    (folded from 0 per request), never on which lane or chunk boundary it
    landed on, which is what makes recycling bitwise-reproducible against
    a serial replay of the same trace.
    """
    resolve_afc_plan(afc_backend)  # validate the string at build time
    chunk_iters = int(chunk_iters)
    if chunk_iters < 1:
        raise ValueError(f"chunk_iters must be >= 1, got {chunk_iters}")

    hol_idx, n_hol, qs, approx = _parse_feature_spec(
        k, holistic, quantiles, approximate
    )
    core = _executor_core(
        model_fn, k=k, task=task, n_classes=n_classes, m=m, m_sobol=m_sobol,
        alpha=alpha, gamma=gamma, max_iters=max_iters, afc_backend=afc_backend,
        hol_idx=hol_idx, n_hol=n_hol, qs=qs, approx=approx,
        n_boot=int(n_boot), base_key=jax.random.PRNGKey(boot_seed),
        cached=prebuilt,
    )
    static_max_iters = max_iters

    def _pack(vals, n, agg_ids, delta, exact, active, tau, iter_cap,
              ptab, shift, rindex) -> LaneState:
        act = jnp.asarray(active, bool)
        tau = jnp.asarray(tau, f32)
        iter_cap = jnp.asarray(iter_cap, jnp.int32)
        delta = jnp.asarray(delta, f32)
        cap_eff = jnp.minimum(iter_cap, static_max_iters)
        z0 = jnp.where(approx, initial_plan(n, alpha), n)
        carry = core.init_eval(
            vals, n, agg_ids, exact, delta, act, tau, cap_eff,
            z0, ptab, shift, rindex,
        )
        z, it, y_hat, prob, idx, reps = carry
        return LaneState(
            vals=vals, n=n, agg_ids=agg_ids, delta=delta, exact=exact,
            active=act, tau=tau, iter_cap=iter_cap,
            z=z, it=it, y_hat=y_hat, prob=prob, idx=idx, reps=reps,
            done=~core.want_more(carry, act, tau, cap_eff, n),
            ptab=ptab if ptab is not None else jnp.zeros((k, 0, 4), f32),
            shift=shift if shift is not None else jnp.zeros((k,), f32),
            rindex=rindex if rindex is not None else empty_rank_index(),
        )

    def init(vals, n, agg_ids, delta, exact, active, tau, iter_cap) -> LaneState:
        cap = vals.shape[1]
        n = jnp.minimum(n.astype(jnp.int32), cap)
        z0 = jnp.where(approx, initial_plan(n, alpha), n)
        step = gamma_abs(n, gamma)
        ptab, shift, rindex = core.precompute(vals, n, z0, step)
        return _pack(vals, n, agg_ids, delta, exact, active, tau, iter_cap,
                     ptab, shift, rindex)

    def init_prebuilt(vals, n, agg_ids, delta, exact, active, tau, iter_cap,
                      tables: PrebuiltTables) -> LaneState:
        cap = vals.shape[1]
        n = jnp.minimum(n.astype(jnp.int32), cap)
        incremental, _ = resolve_afc_plan(afc_backend, cap=cap, cached=True)
        state = _pack(
            vals, n, agg_ids, delta, exact, active, tau, iter_cap,
            tables.ptab if incremental else None,
            tables.shift if incremental else None,
            tables.rindex if (incremental and n_hol) else None,
        )
        # keep the full-size leaves in the table even when the env override
        # forces rescan — one LaneState structure per cap bucket either way
        return state._replace(
            ptab=tables.ptab, shift=tables.shift, rindex=tables.rindex
        )

    def chunk(state: LaneState) -> LaneState:
        incremental, _ = resolve_afc_plan(
            afc_backend, cap=state.vals.shape[1], cached=prebuilt
        )
        ptab = state.ptab if incremental else None
        shift = state.shift if incremental else None
        rindex = state.rindex if (incremental and n_hol) else None
        n = state.n
        cap_eff = jnp.minimum(state.iter_cap, static_max_iters)
        step = gamma_abs(n, gamma)
        carry0 = (state.z, state.it, state.y_hat, state.prob,
                  state.idx, state.reps)

        def cond(carry_j):
            carry, j = carry_j
            return (
                core.want_more(carry, state.active, state.tau, cap_eff, n)
                & (j < chunk_iters)
            )

        def body(carry_j):
            carry, j = carry_j
            carry = core.step_plan(
                carry, state.vals, n, state.agg_ids, state.exact,
                state.delta, step, ptab, shift, rindex,
            )
            return carry, j + 1

        carry, _ = jax.lax.while_loop(
            cond, body, (carry0, jnp.zeros((), jnp.int32))
        )
        z, it, y_hat, prob, idx, reps = carry
        return state._replace(
            z=z, it=it, y_hat=y_hat, prob=prob, idx=idx, reps=reps,
            done=~core.want_more(carry, state.active, state.tau, cap_eff, n),
        )

    return (init_prebuilt if prebuilt else init), chunk


#: Continuous-table contracts: ``build_chunked_executor`` returns the
#: (refill, chunk) pair, each its own jit executable — together the
#: 2-per-cap-bucket budget of ContinuousBatchedServer.  Both donate the
#: LaneState table so iteration-level recycling updates it in place, and
#: both inherit the counter-based RNG discipline (a recycled lane replays
#: the exact bootstrap stream of a fresh one).
REFILL_CONTRACT = register_contract(ExecutableContract(
    name="refill",
    builder="repro.core.executor_fused.build_chunked_executor (init)",
    executables_per_bucket=1,
    collectives=0,
    donated=("table (LaneState pytree, lanes-leading)",),
    description=(
        "single-lane init written into the donated table at one lane row; "
        "per-request degradation knobs are traced inputs, so admitting a "
        "request never mints an executable"
    ),
))

CHUNK_CONTRACT = register_contract(ExecutableContract(
    name="chunk",
    builder="repro.core.executor_fused.build_chunked_executor (chunk)",
    executables_per_bucket=1,
    collectives=0,
    donated=("table (LaneState pytree, lanes-leading)",),
    while_body_flat=True,
    description=(
        "bounded planner burst (<= chunk_iters trips) over every occupied "
        "lane of the donated table; cost-flat while body on the "
        "incremental-AFC path"
    ),
))
