"""FusedExecutor: the whole Biathlon feedback loop as ONE XLA program.

Beyond-paper TPU adaptation (DESIGN.md §2): the HostLoopExecutor mirrors the
paper — a Python loop dispatching AFC / AMI / Planner stages per iteration,
paying a host<->device round trip + dispatch latency every cycle.  Once the
datastore I/O is approximated away, those round trips dominate single-digit-
millisecond serving budgets.

The fused variant expresses the iterate-until-guaranteed loop as a
``jax.lax.while_loop`` over fixed-shape state:

* sample growth is a *monotone prefix mask* over pre-gathered, pre-permuted
  (k, cap) buffers — the plan z is data, not shape;
* AFC = one-pass power-sum moments (the Pallas ``sampled_agg`` kernel on
  TPU, its jnp oracle elsewhere) turned into (value, sigma) with
  finite-population correction;
* AMI + Sobol indices share ONE fused QMC evaluation megabatch: the m AMI
  rows, the single point-estimate row, and the (k+2)·m_sobol Saltelli
  A/B/AB rows are concatenated and evaluated with a single ``model_fn``
  call per planner iteration — ``m + 1 + (k+2)·m_sobol`` model rows,
  sliced afterwards for the Eq. 1 guarantee check and the main-effect
  indices (the Saltelli-style model-call amortization);
* the loop state carries ``(z, iter, y_hat, prob, indices)`` so each
  iteration steps the plan with the *previous* evaluation's indices and
  then evaluates the new plan exactly once — no duplicate pre-step call;
* the initial plan gets a cheap AMI-only dispatch (m+1 rows); its Sobol
  block runs under ``lax.cond`` only when the guarantee fails at z⁰, so
  immediately-satisfied requests (the common case at the paper's α) never
  pay Saltelli rows — in the single-request path.  Under ``vmap`` (batched
  serving) a batched predicate executes both cond branches, so admission
  batches always pay the init Sobol block;
* the loop condition is the Eq. 1 guarantee check.

Restrictions vs the host loop (documented): parametric aggregates only
(SUM/COUNT/AVG/VAR/STD — bootstrap resampling for MEDIAN needs per-iteration
RNG shapes that stay host-side), and the per-request buffer is capped at
``cap`` rows (the guarantee's worst case degrades to exact-over-cap).
Batched serving vmaps this executor over concurrent requests with
power-of-two bucketed caps (serving/batched.py).

Per-iteration cost model (EXPERIMENTS.md §Perf): one model dispatch of
``m + 1 + (k+2)·m_sobol`` rows, one AFC moments pass, zero host round
trips — vs the pre-fusion body's three dispatches totalling
``2·(m+1) + (k+2)·m_sobol`` rows.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.planner import direction, next_plan
from repro.core.propagation import qmc_uniforms
from repro.core.qmc import uniform_to_normal
from repro.kernels.sampled_agg.ops import masked_estimates

f32 = jnp.float32

__all__ = ["FusedResult", "build_fused_executor", "fused_rows_per_iteration"]


class FusedResult(NamedTuple):
    y_hat: jnp.ndarray
    prob: jnp.ndarray
    iters: jnp.ndarray
    z: jnp.ndarray          # (k,) final plan
    samples_used: jnp.ndarray


def fused_rows_per_iteration(k: int, m: int, m_sobol: int) -> int:
    """Model rows evaluated per planner iteration (the single megabatch)."""
    return m + 1 + (k + 2) * m_sobol


def build_fused_executor(
    model_fn,
    *,
    k: int,
    task: str,
    n_classes: int = 2,
    m: int = 512,
    m_sobol: int = 128,
    alpha: float = 0.05,
    gamma: float = 0.01,
    tau: float = 0.95,
    max_iters: int = 32,
    afc_backend: str = "auto",
):
    """Returns jit-able ``run(vals (k,cap), n (k,), agg_ids (k,), delta) -> FusedResult``.

    ``model_fn``: (rows (n,k), exact (e,)) -> (n,) predictions (regression
    values or class ids); must be jittable — tabular models and LM heads both
    qualify.  ``exact`` carries the request's exactly-computed features so a
    single compiled executor serves every request of the pipeline.

    ``run`` also accepts an optional trailing ``active`` flag (scalar bool)
    used by fixed-lane admission batching (serving/runtime.py): a vmapped
    batch pads to a constant lane count and marks pad lanes inactive, so the
    jit cache sees ONE shape per cap bucket regardless of batch fill.  An
    inactive lane never enters the while_loop (its guarantee predicate is
    forced false), reports ``iters == 0`` and ``samples_used == 0``, and its
    y_hat/prob are the init-dispatch values over its zero-padded buffers —
    callers slice inactive lanes off before interpreting results.

    ``model_fn`` is invoked exactly ONCE per planner iteration, on a
    ``(m + 1 + (k+2)*m_sobol, k)`` megabatch (see module docstring).

    ``afc_backend``: "auto" routes the AFC moments pass through the Pallas
    ``sampled_moments`` kernel on TPU and the jnp oracle elsewhere;
    "kernel" forces the kernel (interpret-mode fallback off-TPU — correctness
    testing, not speed); "ref" forces the oracle.
    """
    use_kernel = {"auto": None, "kernel": True, "ref": False}[afc_backend]

    u_ami = qmc_uniforms(m, k)                       # (m, k) static
    u_sob = qmc_uniforms(m_sobol, 2 * k, None)       # (m_sobol, 2k)

    def sample_rows(value, sigma, u):
        return value[None, :] + sigma[None, :] * uniform_to_normal(u)

    def guarantee_prob(y_hat, mean, sd, delta):
        if task == "classification":
            return mean
        bias = mean - y_hat
        safe = jnp.maximum(sd, 1e-12)
        phi = jax.scipy.stats.norm.cdf
        prob = phi((delta - bias) / safe) - phi((-delta - bias) / safe)
        return jnp.where(sd <= 1e-12, (jnp.abs(bias) <= delta).astype(f32), prob)

    def sobol_from_outputs(f_all, y_hat):
        """Main-effect indices from the pre-evaluated Saltelli block."""
        if task == "classification":
            f_all = (f_all.astype(jnp.int32) == y_hat.astype(jnp.int32)).astype(f32)
        f_all = f_all - jnp.mean(f_all)  # center (see sobol_indices.py)
        fa, fb = f_all[:m_sobol], f_all[m_sobol : 2 * m_sobol]
        fab = f_all[2 * m_sobol :].reshape(k, m_sobol)
        var_y = jnp.var(f_all)
        v_j = jnp.mean(fb[None] * (fab - fa[None]), axis=1)
        return jnp.where(
            var_y > 1e-12, jnp.clip(v_j / jnp.maximum(var_y, 1e-12), 0, 1), 0.0
        )

    @jax.jit
    def run(vals, n, agg_ids, delta, exact, active=None) -> FusedResult:
        act = jnp.asarray(True) if active is None else active
        cap = vals.shape[1]
        n = jnp.minimum(n.astype(jnp.int32), cap)
        z0 = jnp.clip(
            jnp.ceil(alpha * n.astype(f32)).astype(jnp.int32), jnp.minimum(2, n), n
        )
        step = jnp.maximum(
            jnp.ceil(gamma * jnp.sum(n).astype(f32)).astype(jnp.int32), 1
        )

        def ami_prob(y, y_hat):
            """Eq. 1 guarantee probability from the AMI output slice."""
            if task == "regression":
                y_bar = jnp.mean(y)
                sd = jnp.sqrt(jnp.mean((y - y_bar) ** 2))
                return guarantee_prob(y_hat, y_bar, sd, delta)
            probs = (
                jnp.bincount(y.astype(jnp.int32), length=n_classes).astype(f32) / m
            )
            return probs[y_hat.astype(jnp.int32)]

        def sobol_rows(value, sigma):
            """Saltelli A/B/AB block: ((k+2)*m_sobol, k)."""
            ua, ub = u_sob[:, :k], u_sob[:, k:]
            xa = sample_rows(value, sigma, ua)
            xb = sample_rows(value, sigma, ub)
            eye = jnp.eye(k, dtype=bool)
            xab = jnp.where(eye[:, None, :], xb[None], xa[None]).reshape(
                k * m_sobol, k
            )
            return jnp.concatenate([xa, xb, xab], 0)

        def evaluate(z):
            """AFC + AMI + Sobol via ONE model dispatch at plan z.

            Rows: [AMI (m,k) | point estimate (1,k) | Saltelli A/B/AB
            ((k+2)*m_sobol, k)] -> slice outputs for the guarantee check and
            the main-effect indices.
            """
            value, sigma = masked_estimates(
                vals, z, n, agg_ids, use_kernel=use_kernel
            )
            x_ami = sample_rows(value, sigma, u_ami)
            batch = jnp.concatenate(
                [x_ami, value[None, :], sobol_rows(value, sigma)], 0
            )
            y_all = model_fn(batch, exact).astype(f32)

            y_hat = y_all[m]
            prob = ami_prob(y_all[:m], y_hat)
            idx = sobol_from_outputs(y_all[m + 1 :], y_hat)
            return y_hat, prob, idx

        def cond(state):
            z, it, y_hat, prob, idx = state
            return act & (prob < tau) & (it < max_iters) & jnp.any(z < n)

        def body(state):
            z, it, _, _, idx = state
            d = direction(idx, z, n)
            z = next_plan(z, d, step, n)
            y_hat, prob, idx = evaluate(z)
            return (z, it + 1, y_hat, prob, idx)

        # Initial plan: AMI-only dispatch (m+1 rows).  The Saltelli block is
        # only evaluated — via lax.cond, so immediately-guaranteed requests
        # skip its cost entirely — when the loop is actually entered.
        # (Under vmap the cond becomes a select and both branches run.)
        value0, sigma0 = masked_estimates(
            vals, z0, n, agg_ids, use_kernel=use_kernel
        )
        y0_all = model_fn(
            jnp.concatenate([sample_rows(value0, sigma0, u_ami), value0[None, :]], 0),
            exact,
        ).astype(f32)
        y_hat0 = y0_all[m]
        prob0 = ami_prob(y0_all[:m], y_hat0)
        idx0 = jax.lax.cond(
            act & (prob0 < tau) & jnp.any(z0 < n) & (max_iters > 0),
            lambda: sobol_from_outputs(
                model_fn(sobol_rows(value0, sigma0), exact).astype(f32), y_hat0
            ),
            lambda: jnp.zeros((k,), f32),
        )
        z, iters, y_hat, prob, _ = jax.lax.while_loop(
            cond, body, (z0, jnp.zeros((), jnp.int32), y_hat0, prob0, idx0)
        )
        return FusedResult(
            y_hat=y_hat,
            prob=prob,
            iters=iters,
            z=z,
            samples_used=jnp.where(act, jnp.sum(jnp.minimum(z, n)), 0),
        )

    return run
